"""Quickstart: build a job-marketplace graph, train LinkSAGE, evaluate
retrieval, save a checkpoint.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs.linksage import CONFIG
from repro.core.eval import retrieval_eval
from repro.core.linksage import LinkSAGETrainer
from repro.data import GraphGenConfig, generate_job_marketplace_graph


def main():
    print("== LinkSAGE quickstart ==")
    graph, truth = generate_job_marketplace_graph(
        GraphGenConfig(num_members=600, num_jobs=180, seed=0))
    census = graph.census()
    print(f"graph: {census['total_nodes']} nodes, {census['total_edges']} edges")
    for k, v in sorted(census["edges"].items()):
        print(f"  {k:22s} {v}")

    trainer = LinkSAGETrainer(CONFIG, graph, seed=0)
    print("\ntraining GNN encoder–decoder (in-batch negatives)…")
    hist = trainer.train(200, batch_size=64, verbose=True, log_every=40)
    print(f"loss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")

    m_emb = trainer.embed_nodes("member", np.arange(600))
    j_emb = trainer.embed_nodes("job", np.arange(180))
    src, dst = truth["engagements"]
    res = retrieval_eval(m_emb, j_emb, src, dst, k=10)
    rng = np.random.default_rng(0)
    rand = retrieval_eval(rng.normal(size=m_emb.shape),
                          rng.normal(size=j_emb.shape), src, dst, k=10)
    print(f"\nrecall@10: linksage={res['recall']:.3f}  random={rand['recall']:.3f}")

    path = save_checkpoint("checkpoints/quickstart", 200, trainer.state.params)
    print(f"checkpoint saved to {path}")


if __name__ == "__main__":
    main()
