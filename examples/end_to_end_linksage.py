"""End-to-end LinkSAGE driver — the full paper pipeline (Figure 3):

  1. construct the heterogeneous job-marketplace graph (§3)
  2. train the GraphSAGE encoder–decoder on engagement link prediction (§4)
  3. offline full sweep: ``publish_version()`` writes every member/job
     embedding into the versioned EmbeddingStore (§5.2)
  4. transfer-learn ALL four product surfaces (TAJ / JYMBII / JobSearch /
     EBR, §7) from embeddings read out of the store at that version, vs a
     no-GNN control arm (the A/B proxy)
  5. run the nearline pipeline on a simulated event day (§5.2) and show
     fresh jobs get embeddings in seconds vs the 24 h offline batch
  6. close the loop: a live engagement burst dirties the store, the
     recompute queue drains, and the refreshed embeddings re-rank EBR
     retrieval for the engaged member
  7. serve a traffic burst: partition the graph over 2 shards and fire an
     open-loop Poisson request trace through the DynamicBatcher + shard-
     aware Router (§10) — the full train → publish → nearline → serve loop

    PYTHONPATH=src python examples/end_to_end_linksage.py
    # CI smoke: --members 120 --jobs 40 --steps 30 --ranker-epochs 2
"""
import argparse

import numpy as np

from repro.configs.linksage import CONFIG
from repro.core.embeddings import StalenessPolicy
from repro.core.eval import retrieval_eval
from repro.core.nearline import Event, NearlineInference
from repro.data import GraphGenConfig, generate_job_marketplace_graph
from repro.core.linksage import LinkSAGETrainer
from repro.launch.transfer import build_surface_datasets, fit_surfaces


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--members", type=int, default=600)
    ap.add_argument("--jobs", type=int, default=180)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ranker-epochs", type=int, default=4)
    ap.add_argument("--fanouts", default=None,
                    help="per-hop fanouts, e.g. '10,5' or '8,4,2' (K=3)")
    ap.add_argument("--trace-out", default="linksage_burst_trace.json",
                    help="perfetto trace of the serve burst ('' disables)")
    args = ap.parse_args()
    rng = np.random.default_rng(0)
    cfg = CONFIG
    if args.fanouts:
        cfg = cfg.with_fanouts(int(f) for f in args.fanouts.split(","))

    # -- 1. graph ----------------------------------------------------------
    graph, truth = generate_job_marketplace_graph(
        GraphGenConfig(num_members=args.members, num_jobs=args.jobs, seed=0))
    print("graph:", graph.census()["total_edges"], "edges")

    # -- 2. GNN training ----------------------------------------------------
    trainer = LinkSAGETrainer(cfg, graph, seed=0)
    hist = trainer.train(args.steps, batch_size=64)
    print(f"GNN loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

    # -- 3. offline sweep into the versioned store --------------------------
    lc = trainer.make_lifecycle()
    version = lc.publish_version(clock=0.0)
    m_emb = lc.store.gather("member", np.arange(args.members), version=version)
    j_emb = lc.store.gather("job", np.arange(args.jobs), version=version)
    src, dst = truth["engagements"]
    print(f"published v{version} ({len(lc.store.table(version))} embeddings); "
          "raw-embedding EBR recall@10:",
          retrieval_eval(m_emb, j_emb, src, dst, k=10)["recall"])

    # -- 4. downstream surfaces (frozen encoder, version-pinned reads) ------
    pairs, labels, feat_tables = build_surface_datasets(
        graph, truth, num_members=args.members, num_jobs=args.jobs, seed=0)
    for arm, use_gnn in (("with GNN", True), ("control ", False)):
        tables = (dict(feat_tables, m_gnn=m_emb, j_gnn=j_emb)
                  if use_gnn else dict(feat_tables))
        rep, _ = fit_surfaces(tables, pairs, labels, embed_dim=cfg.embed_dim,
                              feat_dim=graph.feat_dim, use_gnn=use_gnn,
                              epochs=args.ranker_epochs,
                              eval_truth=truth["engagements"])
        print(f"surfaces ({arm}): "
              + "  ".join(f"{k}={v:.4f}" for k, v in rep.items()))

    # -- 5. nearline day ------------------------------------------------------
    nl = NearlineInference(cfg, trainer.state.params["encoder"], micro_batch=8)
    nl.bootstrap_from_graph(graph)
    for i in range(12):
        t = 3600.0 * i
        nl.topic.publish(Event(time=t, kind="job_created", payload={
            "job_id": args.jobs + i,
            "features": rng.normal(size=64).astype(np.float32),
            "title": int(rng.integers(0, 40)), "company": int(rng.integers(0, 80))}))
        nl.topic.publish(Event(time=t + 5, kind="engagement", payload={
            "member_id": int(rng.integers(0, args.members)),
            "job_id": args.jobs + i}))
        nl.process()
    print("nearline:", nl.metrics.summary())
    fresh = sum(nl.embedding_store.get_embedding("job", args.jobs + i) is not None
                for i in range(12))
    print(f"fresh jobs embedded during the day: {fresh}/12 "
          "(offline daily batch: 0/12 until midnight)")

    # -- 6. live-event -> dirty-set -> recompute -> re-rank -----------------
    # an engagement burst onto one member, with the FULL dependency closure
    # (every node whose K-hop tile changed goes through the recompute queue)
    nl2 = NearlineInference(cfg, trainer.state.params["encoder"],
                            micro_batch=32,
                            policy=StalenessPolicy(closure_radius=None))
    nl2.bootstrap_from_graph(graph)
    nl2.lifecycle.publish_version(clock=0.0)      # v1 baseline sweep
    member = int(src[0])
    hot_jobs = rng.choice(args.jobs, size=5, replace=False)
    for i, j in enumerate(hot_jobs):
        nl2.topic.publish(Event(time=float(i), kind="engagement", payload={
            "member_id": member, "job_id": int(j)}))
    nl2.ingest()                                  # apply events, mark dirty
    queued = nl2.lifecycle.pending()
    drained = nl2.lifecycle.drain(clock=6.0)      # priority-queue recompute
    # freeze baseline + drained updates as v2 — no re-sweep: the table IS
    # the incremental path's output
    v2 = nl2.embedding_store.publish()
    m2 = nl2.embedding_store.gather("member", np.arange(args.members), version=v2)
    j2 = nl2.embedding_store.gather("job", np.arange(args.jobs), version=v2)
    ranks = np.argsort(-(m2[member] @ j2.T))
    top = [int(j) for j in ranks[:10]]
    print(f"live burst: {len(hot_jobs)} engagements on member {member} -> "
          f"{queued} nodes dirtied (K-hop closure), {drained} recomputed "
          f"through the priority queue; "
          f"{sum(int(j) in top for j in hot_jobs)}/5 engaged jobs now in the "
          f"member's EBR top-10 (v{v2} table)")

    # -- 7. serve a traffic burst over 2 shards -----------------------------
    # the online tier: shard the graph, coalesce concurrent scoring requests
    # into encoder batches, scatter-gather embeddings across owners
    from repro.core.partition import GraphPartitioner
    from repro.obs import Tracer, format_freshness, freshness_report, set_tracer
    from repro.serving import (BatchPolicy, LoadConfig, LoadGenerator,
                               ResultCache, ShardedNearline, serve_trace)
    tracer = Tracer(clock="wall") if args.trace_out else None
    if tracer is not None:
        set_tracer(tracer)          # §15: spans observe, bits never change
    part = GraphPartitioner(2, "greedy").fit(graph)
    # feature_cache: per-shard §11 hot-node slabs in front of the feature
    # store (first touch admits; bits never change, only fetch latency)
    cluster = ShardedNearline(cfg, trainer.state.params["encoder"], part,
                              micro_batch=32, feature_cache=1024)
    cluster.bootstrap_from_graph(graph)
    for i in range(20):                       # a small live warm-up burst
        cluster.topic.publish(Event(time=float(i), kind="engagement", payload={
            "member_id": int(rng.integers(0, args.members)),
            "job_id": int(rng.integers(0, args.jobs))}))
    cluster.process()
    agg = cluster.aggregate_metrics()
    fc_hits, fc_misses = agg.feature_cache_hits, agg.feature_cache_misses
    print(f"feature cache after burst: {fc_hits}/{fc_hits + fc_misses} tile "
          f"rows served from the hot-node slabs "
          f"(hit rate {fc_hits / max(fc_hits + fc_misses, 1):.0%} across "
          f"{len(cluster.feature_caches)} shards)")
    reqs = LoadGenerator(
        LoadConfig(rate_hz=500.0, num_requests=100, candidates=8),
        num_members=args.members, num_jobs=args.jobs).requests()
    pol = BatchPolicy(max_batch=16, max_wait_s=0.02)
    serve_trace(cluster, reqs, policy=pol)    # warm the jit buckets
    report, batcher, router = serve_trace(cluster, reqs, policy=pol,
                                          cache=ResultCache(2048))
    s = report.summary()
    print(f"serving burst (2 shards, {part.cut_stats(graph)['cut_fraction']:.0%}"
          f" edge cut): {s['completed']} requests in {s['batches']} batches, "
          f"{s['throughput_rps']:.0f} req/s, p95={s['latency_p95_ms']:.0f}ms, "
          f"cache hit rate {router.cache.hit_rate():.0%}")

    # -- 8. freshness report + perfetto trace (§15) -------------------------
    # how stale is what we just served, and where did the time go?
    print(format_freshness(freshness_report(cluster)))
    if tracer is not None:
        tracer.write(args.trace_out)
        set_tracer(None)
        print(f"trace: {len(tracer.spans)} spans -> {args.trace_out} "
              f"(load in https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
