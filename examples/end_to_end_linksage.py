"""End-to-end LinkSAGE driver — the full paper pipeline (Figure 3):

  1. construct the heterogeneous job-marketplace graph (§3)
  2. train the GraphSAGE encoder–decoder on engagement link prediction (§4)
  3. precompute member/job embeddings (offline inference)
  4. transfer-learn downstream rankers (TAJ + JYMBII heads, §5.1) with the
     frozen encoder, vs a no-GNN control arm (the A/B proxy)
  5. run the nearline pipeline on a simulated event day (§5.2) and show
     fresh jobs get embeddings in seconds vs the 24 h offline batch

    PYTHONPATH=src python examples/end_to_end_linksage.py
    # CI smoke: --members 120 --jobs 40 --steps 30 --ranker-epochs 2
"""
import argparse

import numpy as np

from repro.configs.linksage import CONFIG
from repro.core.eval import auc, retrieval_eval
from repro.core.linksage import LinkSAGETrainer
from repro.core.nearline import Event, NearlineInference
from repro.core.transfer import (DownstreamRanker, RankerConfig,
                                 build_ranker_dataset)
from repro.data import GraphGenConfig, generate_job_marketplace_graph


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--members", type=int, default=600)
    ap.add_argument("--jobs", type=int, default=180)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ranker-epochs", type=int, default=4)
    ap.add_argument("--fanouts", default=None,
                    help="per-hop fanouts, e.g. '10,5' or '8,4,2' (K=3)")
    args = ap.parse_args()
    rng = np.random.default_rng(0)
    cfg = CONFIG
    if args.fanouts:
        cfg = cfg.with_fanouts(int(f) for f in args.fanouts.split(","))

    # -- 1. graph ----------------------------------------------------------
    graph, truth = generate_job_marketplace_graph(
        GraphGenConfig(num_members=args.members, num_jobs=args.jobs, seed=0))
    print("graph:", graph.census()["total_edges"], "edges")

    # -- 2. GNN training ----------------------------------------------------
    trainer = LinkSAGETrainer(cfg, graph, seed=0)
    hist = trainer.train(args.steps, batch_size=64)
    print(f"GNN loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

    # -- 3. offline embedding precompute ------------------------------------
    m_emb = trainer.embed_nodes("member", np.arange(args.members))
    j_emb = trainer.embed_nodes("job", np.arange(args.jobs))
    src, dst = truth["engagements"]
    print("EBR recall@10:", retrieval_eval(m_emb, j_emb, src, dst, k=10)["recall"])

    # -- 4. downstream rankers (frozen encoder, transfer learning) ----------
    weak_m = (graph.features["member"] * 0.1
              + rng.normal(size=graph.features["member"].shape)).astype(np.float32)
    weak_j = (graph.features["job"] * 0.1
              + rng.normal(size=graph.features["job"].shape)).astype(np.float32)
    n = len(src)
    pairs = (np.concatenate([src, rng.integers(0, args.members, n)]),
             np.concatenate([dst, rng.integers(0, args.jobs, n)]))
    labels = np.concatenate([np.ones(n), np.zeros(n)]).astype(np.float32)
    for use_gnn in (True, False):
        ds = build_ranker_dataset(weak_m, weak_j, m_emb, j_emb, pairs, labels,
                                  use_gnn=use_gnn)
        rk = DownstreamRanker(RankerConfig(name="jymbii", gnn_embed_dim=cfg.embed_dim,
                                           other_feat_dim=64, use_gnn=use_gnn))
        rk.fit(ds, epochs=args.ranker_epochs)
        print(f"JYMBII ranker AUC ({'with' if use_gnn else 'no  '} GNN):",
              f"{auc(labels, rk.score(ds)):.4f}")

    # -- 5. nearline day ------------------------------------------------------
    nl = NearlineInference(cfg, trainer.state.params["encoder"], micro_batch=8)
    nl.bootstrap_from_graph(graph)
    for i in range(12):
        t = 3600.0 * i
        nl.topic.publish(Event(time=t, kind="job_created", payload={
            "job_id": args.jobs + i,
            "features": rng.normal(size=64).astype(np.float32),
            "title": int(rng.integers(0, 40)), "company": int(rng.integers(0, 80))}))
        nl.topic.publish(Event(time=t + 5, kind="engagement", payload={
            "member_id": int(rng.integers(0, args.members)),
            "job_id": args.jobs + i}))
        nl.process()
    print("nearline:", nl.metrics.summary())
    fresh = sum(nl.embedding_store.get_embedding("job", args.jobs + i) is not None
                for i in range(12))
    print(f"fresh jobs embedded during the day: {fresh}/12 "
          "(offline daily batch: 0/12 until midnight)")


if __name__ == "__main__":
    main()
