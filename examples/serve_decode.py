"""Batched serving demo: prefill a batch of prompts, then decode with the
production serve_step (KV caches / SSM states), for any --arch smoke config.

    PYTHONPATH=src python examples/serve_decode.py --arch llama3-8b --tokens 32
    PYTHONPATH=src python examples/serve_decode.py --arch mamba2-780m
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import model_init
from repro.models.transformer import decode_step, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = model_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                       (args.batch, args.prompt_len)), jnp.int32)

    print(f"prefilling {args.batch}×{args.prompt_len} ({cfg.name})…")
    t0 = time.time()
    logits, state = prefill(params, cfg, prompts,
                            max_seq=args.prompt_len + args.tokens)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    print(f"prefill: {time.time() - t0:.2f}s")

    dstep = jax.jit(lambda p, t, s: decode_step(p, cfg, t, s))
    out = [tok]
    t0 = time.time()
    for _ in range(args.tokens - 1):
        logits, state = dstep(params, tok, state)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    gen = np.stack([np.asarray(t) for t in out], axis=1)
    print(f"decoded {args.tokens - 1} steps × {args.batch} seqs in {dt:.2f}s "
          f"({(args.tokens - 1) * args.batch / dt:.1f} tok/s)")
    print("sample token ids:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
