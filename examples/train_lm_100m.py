"""End-to-end LM training driver: a ~100M-parameter llama-family model
trained for a few hundred steps on synthetic bigram data, using the
production train_step (remat, chunked CE, AdamW, grad clip).

    PYTHONPATH=src python examples/train_lm_100m.py --steps 300

The default geometry is ~103M params (d=768, 12L, GQA 12/4, vocab 32000).
CPU throughput is the limiter; --steps 20 for a smoke pass.
"""
import argparse
import time
from dataclasses import replace

import jax
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs.base import ArchConfig
from repro.configs import get_smoke_config
from repro.data.lm_data import SyntheticTokenStream
from repro.launch.steps import make_train_step
from repro.models import model_init
from repro.nn import param_count
from repro.optim import adamw_init

LLAMA_100M = ArchConfig(
    name="llama-100m", family="dense", source="examples (llama3-family geometry)",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=4, d_ff=2048,
    vocab_size=32000, rope_theta=500_000.0,
    param_dtype="float32", act_dtype="float32", remat="none",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + 20 steps (CI-speed)")
    args = ap.parse_args()

    cfg = get_smoke_config("llama3_8b") if args.smoke else LLAMA_100M
    steps = 20 if args.smoke else args.steps

    params = model_init(jax.random.PRNGKey(0), cfg)
    print(f"model: {cfg.name}  params={param_count(params):,}")
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, lr=args.lr))

    stream = SyntheticTokenStream(cfg.vocab_size, seed=0)
    t_start = time.time()
    for i in range(steps):
        toks = stream.sample(args.batch, args.seq)
        batch = {"tokens": jax.numpy.asarray(toks[:, :-1]),
                 "labels": jax.numpy.asarray(toks[:, 1:])}
        params, opt, m = step_fn(params, opt, batch)
        if i % 20 == 0 or i == steps - 1:
            dt = time.time() - t_start
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.2f}  ({dt:.0f}s elapsed)")
    save_checkpoint("checkpoints/lm100m", steps, params)
    print("done; checkpoint saved.")


if __name__ == "__main__":
    main()
