"""Perf-iteration driver (§Perf hillclimbing):

  python -m repro.launch.perf --arch llama3-8b --shape train_4k \
      --set seq_shard=True --tag seq_shard

Runs the full dry-run (scan + unrolled passes) with ArchConfig overrides and
writes ``experiments/perf/<arch>__<shape>__<tag>.json`` for before/after
comparison against the baseline in experiments/dryrun/.
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import ast
import json


def parse_overrides(pairs):
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        try:
            out[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            out[k] = v
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="ArchConfig override key=value (repeatable)")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    from repro.configs import canonical_arch_id
    from repro.launch.dryrun import dryrun_one

    overrides = parse_overrides(args.set)
    res = dryrun_one(args.arch, args.shape, multi_pod=args.multi_pod,
                     cfg_overrides=overrides)
    res["tag"] = args.tag
    res["cfg_overrides"] = overrides
    out_dir = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "perf")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir,
                        f"{canonical_arch_id(args.arch)}__{args.shape}__{args.tag}.json")
    with open(path, "w") as f:
        json.dump(res, f, indent=1, default=str)
    print("saved", path)


if __name__ == "__main__":
    main()
