"""Roofline-term extraction from compiled dry-run artifacts.

Terms (per chip, seconds) for TPU v5e:

  compute    = HLO_FLOPs_per_device / 197e12
  memory     = HLO_bytes_per_device / 819e9
  collective = collective_bytes_per_device / 50e9

FLOPs/bytes come from ``compiled.cost_analysis()`` (the compiled module IS
the per-device program after SPMD partitioning).  Collective bytes are not
in cost_analysis: we parse the partitioned HLO text and sum operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, weighting all-reduce 2× (reduce-scatter + all-gather
phases).  The (n-1)/n ring factor is dropped (n≥16 here, <7% error).

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) measures how much of the
compiled compute is "useful" — the ratio catches remat/redundancy waste.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.configs.base import ArchConfig, InputShape
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0,
}

_COLLECTIVES = {
    "all-gather": 1.0,
    "all-reduce": 2.0,          # reduce-scatter + all-gather phases
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes per collective kind from partitioned HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+)$", line)
        if not m:
            continue
        rhs = m.group(1)
        opm = re.search(r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
                        r"collective-permute)(?:-start)?\(", rhs)
        if not opm:
            continue
        kind = opm.group(1)
        # operand shapes: everything inside the call parens
        args = rhs[opm.end():]
        shapes = _SHAPE_RE.findall(args.split("),")[0] + ")")
        if not shapes:  # fall back to result shape
            shapes = _SHAPE_RE.findall(rhs[:opm.start()])
        total = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        out[kind] += total
        counts[kind] += 1
    out["counts"] = counts
    return out


def model_flops(cfg: ArchConfig, shape: InputShape) -> float:
    """6·N_active·D (forward+backward); decode uses D = new tokens = batch."""
    n_active = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens          # forward only
    return 2.0 * n_active * shape.global_batch  # decode: 1 token per sequence


def total_params(cfg: ArchConfig) -> float:
    d, f, L, v = cfg.d_model, cfg.d_ff, cfg.num_layers, cfg.vocab_size
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    per_layer = 0.0
    for i in range(L):
        if cfg.is_attn_layer(i) and hq:
            per_layer += d * dh * (hq + 2 * hkv) + hq * dh * d
        if not cfg.is_attn_layer(i) or cfg.family == "ssm":
            di = cfg.ssm_expand * d
            n = cfg.ssm_state
            heads = di // cfg.ssm_head_dim if cfg.ssm_head_dim else 0
            per_layer += d * (2 * di + 2 * n + heads) + di * d
        if cfg.num_experts and cfg.is_moe_layer(i):
            per_layer += cfg.num_experts * 3 * d * f + d * cfg.num_experts
            if cfg.moe_dense_residual:
                per_layer += 3 * d * cfg.d_ff_dense
        elif f:
            per_layer += 3 * d * f
    embed = v * d * (1 if cfg.tie_embeddings else 2)
    return per_layer + embed


def active_params(cfg: ArchConfig) -> float:
    d, f, L, v = cfg.d_model, cfg.d_ff, cfg.num_layers, cfg.vocab_size
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    per_layer = 0.0
    for i in range(L):
        if cfg.is_attn_layer(i) and hq:
            per_layer += d * dh * (hq + 2 * hkv) + hq * dh * d
        if not cfg.is_attn_layer(i) or cfg.family == "ssm":
            di = cfg.ssm_expand * d
            n = cfg.ssm_state
            heads = di // cfg.ssm_head_dim if cfg.ssm_head_dim else 0
            per_layer += d * (2 * di + 2 * n + heads) + di * d
        if cfg.num_experts and cfg.is_moe_layer(i):
            per_layer += cfg.experts_per_token * 3 * d * f + d * cfg.num_experts
            if cfg.moe_dense_residual:
                per_layer += 3 * d * cfg.d_ff_dense
        elif f:
            per_layer += 3 * d * f
    embed = v * d * (1 if cfg.tie_embeddings else 2)
    return per_layer + embed


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_dev: float
    hlo_bytes_per_dev: float
    coll_bytes_per_dev: float
    coll_detail: dict
    model_flops_total: float
    mem_per_dev_bytes: float = 0.0
    compile_seconds: float = 0.0

    @property
    def t_compute(self):
        return self.hlo_flops_per_dev / PEAK_FLOPS_BF16

    @property
    def t_memory(self):
        return self.hlo_bytes_per_dev / HBM_BW

    @property
    def t_collective(self):
        return self.coll_bytes_per_dev / ICI_BW

    @property
    def dominant(self):
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self):
        hlo_total = self.hlo_flops_per_dev * self.chips
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "hlo_flops_per_dev": self.hlo_flops_per_dev,
            "hlo_bytes_per_dev": self.hlo_bytes_per_dev,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "coll_detail": self.coll_detail,
            "model_flops_total": self.model_flops_total,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mem_per_dev_bytes": self.mem_per_dev_bytes,
            "compile_seconds": self.compile_seconds,
        }
