"""Jitted step builders: train_step / prefill_step / serve_step per arch.

These are the functions the launcher pjit-compiles; the dry-run lowers them
against ShapeDtypeStruct inputs for every (arch × input-shape × mesh)
combination.
"""
from __future__ import annotations

import functools
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, InputShape
from repro.models import transformer as T
from repro.optim import adamw_init, adamw_update, clip_by_global_norm

# long_500k policy (DESIGN.md §4): sub-quadratic attention required.
LONG_CONTEXT_WINDOW = 8192


def effective_config(cfg: ArchConfig, shape: InputShape) -> ArchConfig:
    """Apply per-shape overrides (sliding window for long-context dense)."""
    if shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm", "audio"):
        return cfg.with_sliding_window(LONG_CONTEXT_WINDOW)
    return cfg


# -------------------------------------------------------------- input specs


def input_specs(cfg: ArchConfig, shape: InputShape):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    cfg = effective_config(cfg, shape)
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        s_text = s - cfg.num_prefix_embeddings if cfg.modality != "text" else s
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s_text), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        if cfg.modality != "text":
            specs["prefix_emb"] = jax.ShapeDtypeStruct(
                (b, cfg.num_prefix_embeddings, cfg.d_model), cfg.adtype)
        if cfg.gnn_conditioning:
            specs["gnn_emb"] = jax.ShapeDtypeStruct((b, 2 * cfg.gnn_embed_dim), cfg.adtype)
        return specs
    # decode: one new token against a cache of seq_len
    token = jax.ShapeDtypeStruct((b,), jnp.int32)
    state = jax.eval_shape(
        functools.partial(T.init_decode_state, cfg, b, s, dtype=cfg.adtype))
    return {"token": token, "state": state}


def params_spec(cfg: ArchConfig):
    return jax.eval_shape(functools.partial(T.model_init, jax.random.PRNGKey(0), cfg))


def opt_spec(params_like):
    return jax.eval_shape(adamw_init, params_like)


# -------------------------------------------------------------------- steps


def make_train_step(cfg: ArchConfig, *, mesh=None, lr: float = 3e-4,
                    aux_weight: float = 0.01, max_norm: float = 1.0):
    def train_step(params, opt, batch):
        def lf(p):
            hidden, aux = T.forward_train(
                p, cfg, batch["tokens"],
                prefix_emb=batch.get("prefix_emb"),
                gnn_emb=batch.get("gnn_emb"),
                mesh=mesh)
            loss = T.lm_loss(p, cfg, hidden, batch["labels"])
            return loss + aux_weight * aux, (loss, aux)

        (_, (loss, aux)), grads = jax.value_and_grad(lf, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, max_norm)
        params, opt = adamw_update(params, grads, opt, lr=lr)
        return params, opt, {"loss": loss, "aux": aux, "grad_norm": gnorm}

    return train_step


def make_prefill_step(cfg: ArchConfig, *, mesh=None, max_seq: int | None = None):
    def prefill_step(params, batch):
        logits, state = T.prefill(params, cfg, batch["tokens"],
                                  prefix_emb=batch.get("prefix_emb"),
                                  gnn_emb=batch.get("gnn_emb"),
                                  max_seq=max_seq, mesh=mesh)
        return logits, state

    return prefill_step


def make_serve_step(cfg: ArchConfig, *, mesh=None, greedy: bool = True):
    def serve_step(params, state, token):
        logits, state = T.decode_step(params, cfg, token, state, mesh=mesh)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, state

    return serve_step


# ----------------------------------------------------- synthetic host batch


def synthetic_batch(cfg: ArchConfig, shape_or_bs, seq: int | None = None, *,
                    seed: int = 0):
    """Materialized random batch matching input_specs (CPU examples/tests)."""
    if isinstance(shape_or_bs, InputShape):
        b, s = shape_or_bs.global_batch, shape_or_bs.seq_len
    else:
        b, s = shape_or_bs, seq
    rng = np.random.default_rng(seed)
    s_text = s - cfg.num_prefix_embeddings if cfg.modality != "text" else s
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s_text)), jnp.int32),
    }
    labels = rng.integers(0, cfg.vocab_size, (b, s))
    if cfg.modality != "text":
        labels[:, :cfg.num_prefix_embeddings] = -1
        batch["prefix_emb"] = jnp.asarray(
            rng.normal(size=(b, cfg.num_prefix_embeddings, cfg.d_model)), cfg.adtype)
    if cfg.gnn_conditioning:
        batch["gnn_emb"] = jnp.asarray(rng.normal(size=(b, 2 * cfg.gnn_embed_dim)),
                                       cfg.adtype)
    batch["labels"] = jnp.asarray(labels, jnp.int32)
    return batch
