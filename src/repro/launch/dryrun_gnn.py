"""Production-scale dry-run for the LinkSAGE GNN itself (beyond the assigned
arch matrix): lowers the encoder batch-inference step (the nearline hot path)
and the link-prediction train step on the production mesh.

  python -m repro.launch.dryrun_gnn [--multi-pod]

Tile sizes mirror production: nearline macro-batches of 65 536 query nodes
(the paper's >5K QPS × seconds of batching window), 2-hop fanout (10, 5),
64-d input features.  Embedding tables are NOT model state (LinkSAGE is
inductive) — the 1B-member scale lives in the stores, not in params, so the
GNN's device footprint is tiny and the step is batch-parallel.
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.linksage import CONFIG
from repro.core.encoder import encoder_apply, encoder_init
from repro.core.engine import ComputeGraphBatch
from repro.core.linksage import linksage_init, loss_fn
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import collective_bytes


def tile_specs(cfg, batch: int):
    """ShapeDtypeStructs of the padded K-hop tile at production batch."""
    fan = tuple(cfg.fanouts)
    d = cfg.feat_dim
    f32, i32 = jnp.float32, jnp.int32
    return ComputeGraphBatch(
        feats=tuple(jax.ShapeDtypeStruct((batch, *fan[:k], d), f32)
                    for k in range(len(fan) + 1)),
        types=tuple(jax.ShapeDtypeStruct((batch, *fan[:k]), i32)
                    for k in range(len(fan) + 1)),
        masks=tuple(jax.ShapeDtypeStruct((batch, *fan[:k]), f32)
                    for k in range(1, len(fan) + 1)),
    )


def _cost_dict(compiled) -> dict:
    # cost_analysis() returns a per-device list of dicts on newer jax
    cost = compiled.cost_analysis() or {}
    return cost[0] if isinstance(cost, (list, tuple)) else cost


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--infer-batch", type=int, default=65536)
    ap.add_argument("--train-batch", type=int, default=8192)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    batch_axes = ("pod", "data", "model") if args.multi_pod else ("data", "model")
    cfg = CONFIG

    params = jax.eval_shape(lambda: linksage_init(jax.random.PRNGKey(0), cfg))
    pshard = jax.tree.map(lambda _: NamedSharding(mesh, P()), params)

    def tile_shardings(batch):
        def spec(x):
            return NamedSharding(mesh, P(batch_axes, *([None] * (len(x.shape) - 1))))
        return jax.tree.map(spec, tile_specs(cfg, batch))

    results = {}

    # --- nearline batch inference (the serving hot path) -------------------
    def encode_step(p, tile):
        return encoder_apply(p["encoder"], cfg, tile)

    tile = tile_specs(cfg, args.infer_batch)
    t0 = time.time()
    lowered = jax.jit(encode_step,
                      in_shardings=(pshard, tile_shardings(args.infer_batch)),
                      ).lower(params, tile)
    compiled = lowered.compile()
    cost = _cost_dict(compiled)
    results["encode"] = {
        "batch": args.infer_batch, "mesh": mesh_name,
        "compile_s": time.time() - t0,
        "flops_per_dev": float(cost.get("flops", 0)),
        "bytes_per_dev": float(cost.get("bytes accessed", 0)),
        "collectives": collective_bytes(compiled.as_text()),
        "memory": str(compiled.memory_analysis()),
    }
    print("encode:", json.dumps(results["encode"], indent=1, default=str))

    # --- link-prediction train step ----------------------------------------
    def train_loss(p, m_tile, j_tile):
        return loss_fn(p, cfg, m_tile, j_tile)

    grad_step = jax.value_and_grad(train_loss)
    m_tile = tile_specs(cfg, args.train_batch)
    t0 = time.time()
    lowered = jax.jit(grad_step,
                      in_shardings=(pshard, tile_shardings(args.train_batch),
                                    tile_shardings(args.train_batch)),
                      ).lower(params, m_tile, m_tile)
    compiled = lowered.compile()
    cost = _cost_dict(compiled)
    results["train"] = {
        "batch": args.train_batch, "mesh": mesh_name,
        "compile_s": time.time() - t0,
        "flops_per_dev": float(cost.get("flops", 0)),
        "bytes_per_dev": float(cost.get("bytes accessed", 0)),
        "collectives": collective_bytes(compiled.as_text()),
    }
    print("train:", json.dumps(results["train"], indent=1, default=str))

    out = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun", f"linksage__gnn__{mesh_name}.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump({"arch": "linksage-gnn", "mesh": mesh_name,
                   "status": "compiled", **results}, f, indent=1, default=str)
    print("saved", out)


if __name__ == "__main__":
    main()
