"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

NOTE: the os.environ line below MUST run before any other import (jax locks
the device count on first init), which is why it precedes them.

For each combination this lowers the appropriate step (train_step for
train_4k / prefill_step for prefill_32k / serve_step for decode shapes)
against ShapeDtypeStruct inputs on the production meshes, compiles it,
and records memory_analysis / cost_analysis / collective-bytes into
``experiments/dryrun/<arch>__<shape>__<mesh>.json``.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import parallel as par
from repro.configs import ARCH_IDS, INPUT_SHAPES, canonical_arch_id, get_config
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (RooflineReport, _COLLECTIVES,
                                   collective_bytes, model_flops)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _mem_analysis_dict(compiled):
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_size": getattr(ma, "argument_size_in_bytes", 0),
            "output_size": getattr(ma, "output_size_in_bytes", 0),
            "temp_size": getattr(ma, "temp_size_in_bytes", 0),
            "generated_code_size": getattr(ma, "generated_code_size_in_bytes", 0),
        }
    except Exception as e:  # CPU backend may not implement everything
        return {"error": str(e)}


def _lower(arch: str, shape_name: str, *, multi_pod: bool, unroll,
           step_overrides: dict | None = None, cfg_overrides: dict | None = None):
    """Lower the right step for (arch, shape) on the chosen mesh.

    ``unroll`` ∈ {False, int}: False keeps scans with the production remat —
    that build's memory_analysis is the realistic loop-bounded peak.  An int
    k turns on roofline mode (CE/attention inner scans fully unrolled so the
    "outside the layer loop" costs are exact) and unrolls the layer scan by
    factor k.  HloCostAnalysis counts a while body once, so compiling at two
    factors k1 < k2 lets the caller reconstruct exact totals:
        per_layer = (c_k2 − c_k1)/(k2 − k1);  total = c_k1 + (N − k1)·per_layer
    at a fraction of a full-unroll compile.
    """
    from dataclasses import replace as _replace
    from repro.kernels import ops as kops

    kops.set_roofline_mode(bool(unroll))
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ecfg = ST.effective_config(cfg, shape)
    if cfg_overrides:
        ecfg = _replace(ecfg, **cfg_overrides)
    if unroll:
        # remat recompute would inflate the counting graph; drop it so
        # 'useful' counts the real fwd+bwd FLOPs (remat overhead is analytic:
        # +~1 forward ≈ ×4/3 on compute — noted in EXPERIMENTS.md).
        ecfg = _replace(ecfg, scan_unroll=int(unroll), remat="none")
    mesh = make_production_mesh(multi_pod=multi_pod)

    params_like = ST.params_spec(ecfg)
    pspecs = par.param_pspecs(ecfg, params_like, mesh)
    pshard = par.shardings_of(pspecs, mesh)

    if shape.kind == "train":
        step = ST.make_train_step(ecfg, mesh=mesh, **(step_overrides or {}))
        opt_like = ST.opt_spec(params_like)
        ospecs = par.opt_pspecs(pspecs, opt_like)
        oshard = par.shardings_of(ospecs, mesh)
        batch = ST.input_specs(ecfg, shape)
        bspecs = par.data_pspecs(ecfg, shape, mesh)
        bshard = par.shardings_of(bspecs, mesh)
        jitted = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None))
        lowered = jitted.lower(params_like, opt_like, batch)
    elif shape.kind == "prefill":
        step = ST.make_prefill_step(ecfg, mesh=mesh, max_seq=shape.seq_len)
        batch = ST.input_specs(ecfg, shape)
        batch.pop("labels")
        bspecs = par.data_pspecs(ecfg, shape, mesh)
        bspecs.pop("labels")
        bshard = par.shardings_of(bspecs, mesh)
        jitted = jax.jit(step, in_shardings=(pshard, bshard))
        lowered = jitted.lower(params_like, batch)
    else:  # decode
        step = ST.make_serve_step(ecfg, mesh=mesh)
        specs = ST.input_specs(ecfg, shape)
        sspecs = par.decode_state_pspecs(ecfg, specs["state"], shape, mesh)
        sshard = par.shardings_of(sspecs, mesh)
        ba = par._batch_axis_for(shape.global_batch, mesh)
        tshard = NamedSharding(mesh, P(ba))
        jitted = jax.jit(step, in_shardings=(pshard, sshard, tshard),
                         out_shardings=(tshard, None, sshard))
        lowered = jitted.lower(params_like, specs["state"], specs["token"])
    return lowered, ecfg, shape, mesh


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               lower_only: bool = False, verbose: bool = True,
               skip_flops: bool = False, reuse_memory: dict | None = None,
               step_overrides: dict | None = None,
               cfg_overrides: dict | None = None) -> dict:
    t0 = time.time()
    if reuse_memory is None:
        lowered, ecfg, shape, mesh = _lower(arch, shape_name, multi_pod=multi_pod,
                                            unroll=False,
                                            step_overrides=step_overrides,
                                            cfg_overrides=cfg_overrides)
    else:
        # pass 1 results provided (phase=roofline over an existing compile
        # artifact) — only derive static info, skip the scan compile
        from repro.kernels import ops as kops
        kops.set_roofline_mode(False)
        shape = INPUT_SHAPES[shape_name]
        ecfg = ST.effective_config(get_config(arch), shape)
        mesh = make_production_mesh(multi_pod=multi_pod)
    t_lower = time.time() - t0
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    chips = int(np.prod(mesh.devices.shape))
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "chips": chips, "lower_seconds": t_lower, "status": "lowered"}
    if lower_only:
        return result

    # -- pass 1 (scan): realistic memory picture + proof of compile --------
    if reuse_memory is None:
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = _mem_analysis_dict(compiled)
    else:
        mem = reuse_memory.get("memory_analysis", {})
        t_compile = reuse_memory.get("compile_seconds", 0.0)
    result["memory_analysis"] = mem
    result["compile_seconds"] = t_compile
    result["status"] = "compiled"
    mem_total = sum(v for v in mem.values() if isinstance(v, (int, float)))

    if skip_flops or multi_pod:
        # multi-pod pass proves the pod axis shards; roofline is single-pod
        if verbose:
            print(f"[{arch} × {shape_name} × {mesh_name}] compile {t_compile:.1f}s  "
                  f"mem/dev={mem_total/2**30:.2f}GiB (scan pass only)")
        return result

    # -- pass 2: two-point extrapolation for true FLOP/byte/collective counts
    # HloCostAnalysis counts a while body once; compiling the layer scan at
    # unroll factors k1 < k2 and differencing reconstructs the per-layer
    # contribution exactly (see _lower docstring).
    import math as _math

    period = 1
    from repro.models.transformer import block_period
    nblocks = ecfg.num_layers // block_period(ecfg)
    k1 = 1
    k2 = next((k for k in range(2, nblocks + 1) if nblocks % k == 0), nblocks)

    def _analyze(k):
        lowered_k, *_ = _lower(arch, shape_name, multi_pod=multi_pod, unroll=k,
                               step_overrides=step_overrides,
                               cfg_overrides=cfg_overrides)
        compiled_k = lowered_k.compile()
        cost = compiled_k.cost_analysis() or {}
        coll = collective_bytes(compiled_k.as_text())
        return {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            **{f"coll_{kind}": float(coll[kind]) for kind in _COLLECTIVES},
        }

    t0 = time.time()
    c1 = _analyze(k1)
    c2 = _analyze(k2) if k2 > k1 and nblocks > 1 else c1
    t_compile_u = time.time() - t0

    def _total(key):
        per_layer = (c2[key] - c1[key]) / max(k2 - k1, 1)
        return max(c1[key] + (nblocks - k1) * per_layer, c1[key])

    flops = _total("flops")
    bytes_acc = _total("bytes")
    coll = {kind: _total(f"coll_{kind}") for kind in _COLLECTIVES}
    coll["counts"] = {"method": f"extrapolated k1={k1} k2={k2} nblocks={nblocks}"}
    coll_total = sum(_COLLECTIVES[k] * v for k, v in coll.items() if k != "counts")

    report = RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops_per_dev=flops, hlo_bytes_per_dev=bytes_acc,
        coll_bytes_per_dev=coll_total, coll_detail=coll,
        model_flops_total=model_flops(ecfg, shape),
        mem_per_dev_bytes=mem_total,
        compile_seconds=t_compile + t_compile_u,
    )
    result.update(report.row())
    result["memory_analysis"] = mem
    result["compile_seconds_unrolled"] = t_compile_u
    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_name}] compile {t_compile:.1f}s"
              f"+{t_compile_u:.1f}s  "
              f"t_comp={report.t_compute*1e3:.2f}ms t_mem={report.t_memory*1e3:.2f}ms "
              f"t_coll={report.t_collective*1e3:.2f}ms dom={report.dominant} "
              f"useful={report.useful_flops_ratio:.2f} "
              f"mem/dev={mem_total/2**30:.2f}GiB")
        print("  memory_analysis:", mem)
    return result


def save_result(res: dict, out_dir: str = OUT_DIR) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{canonical_arch_id(res['arch'])}__{res['shape']}__{res['mesh']}.json")
    with open(path, "w") as f:
        json.dump(res, f, indent=1, default=str)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--lower-only", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--phase", choices=["full", "compile", "roofline"],
                    default="full",
                    help="compile: fast scan pass only (proves every pair "
                         "lowers+compiles); roofline: upgrade existing compile "
                         "results with the unrolled FLOP/collective pass")
    args = ap.parse_args()

    pairs = []
    archs = ARCH_IDS if (args.all or not args.arch) else [canonical_arch_id(args.arch)]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                pairs.append((a, s, mp))

    failures = []
    for a, s, mp in pairs:
        mesh_name = "2x16x16" if mp else "16x16"
        out_path = os.path.join(OUT_DIR, f"{canonical_arch_id(a)}__{s}__{mesh_name}.json")
        existing = None
        if os.path.exists(out_path):
            with open(out_path) as f:
                existing = json.load(f)
        if args.phase == "roofline":
            if mp or (existing and "t_compute_s" in existing):
                continue   # multi-pod never needs the unrolled pass
        elif args.skip_existing and existing and existing.get("status") == "compiled":
            print(f"skip {a} × {s} × {mesh_name} (exists)")
            continue
        try:
            reuse = (existing if args.phase == "roofline" and existing
                     and existing.get("status") == "compiled" else None)
            res = dryrun_one(a, s, multi_pod=mp, lower_only=args.lower_only,
                             skip_flops=(args.phase == "compile"),
                             reuse_memory=reuse)
            save_result(res)
        except Exception as e:
            traceback.print_exc()
            failures.append((a, s, mesh_name, f"{type(e).__name__}: {e}"))
            save_result({"arch": a, "shape": s, "mesh": mesh_name,
                         "status": "FAILED", "error": f"{type(e).__name__}: {e}"})
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall dry-runs OK")


if __name__ == "__main__":
    main()
