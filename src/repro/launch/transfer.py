"""Transfer launcher: ``python -m repro.launch.transfer [--smoke]``.

The full §5–§7 serving loop as one job:

  1. train the LinkSAGE encoder on engagement link prediction (§4)
  2. ``publish_version()`` — the offline full-sweep inference job writes
     every member/job embedding into the versioned EmbeddingStore (§5.2)
  3. fit ALL four product-surface heads (TAJ / JYMBII / JobSearch / EBR)
     from embeddings read out of the store at that explicit version, via
     the jitted multi-surface step sharing one embedding gather (§5.1, §7)
  4. repeat with ``use_gnn=False`` (the A/B control arm) and print the
     GNN-vs-control report: AUC per ranking surface, recall@k for EBR
  5. stand up the quantized ANN retrieval tier (§14) over the GNN arm's
     EBR job vectors: assert the exact-search config returns ids
     bit-identical to the fp32 brute-force oracle, then report the
     int8+IVF arm's recall against the same positives

The report's EBR row is the acceptance gate: the two-tower head with GNN
embeddings must beat the feature-only control on recall@k.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.eval import (auc, positives_from_edges, recall_at_k,
                             recall_from_retrieved)
from repro.core.linksage import LinkSAGETrainer
from repro.core.transfer import MultiSurfaceTrainer, surface_configs
from repro.data import GraphGenConfig, generate_job_marketplace_graph


def build_surface_datasets(graph, truth, *, num_members, num_jobs, seed=0):
    """Per-surface label table over one shared pair list (so the multi-
    surface step's single gather genuinely serves every head).

    Pairs: the positive engagement edges plus an equal number of random
    pairs.  Labels per surface:
      jymbii    — qualified application: 1 on engagement edges
      taj       — recruiter interaction after application: Bernoulli in the
                  ground-truth match quality (recruiters reach out to good
                  matches; §7.1)
      jobsearch — relevance of the job to the member's *query*: the
                  engagement label again, with the query feature table
                  (noisy member intent) riding along
      ebr       — retrieval positives: the engagement label
    """
    rng = np.random.default_rng(seed)
    src, dst = truth["engagements"]
    n = len(src)
    m_idx = np.concatenate([src, rng.integers(0, num_members, n)]).astype(np.int32)
    j_idx = np.concatenate([dst, rng.integers(0, num_jobs, n)]).astype(np.int32)
    eng_label = np.concatenate([np.ones(n), np.zeros(n)]).astype(np.float32)

    logit = truth["match_logit"](m_idx, j_idx)
    p_recruiter = 1.0 / (1.0 + np.exp(-(2.0 * logit - 2.0)))
    taj_label = (rng.random(len(m_idx)) < p_recruiter).astype(np.float32)

    labels = {"jymbii": eng_label, "taj": taj_label,
              "jobsearch": eng_label, "ebr": eng_label}

    # weak "other features" (production rankers already have features; the
    # GNN adds the graph signal they lack) + the search-query table
    weak_m = (graph.features["member"] * 0.1
              + rng.normal(size=graph.features["member"].shape)).astype(np.float32)
    weak_j = (graph.features["job"] * 0.1
              + rng.normal(size=graph.features["job"].shape)).astype(np.float32)
    q_feat = (graph.features["member"]
              + 0.5 * rng.normal(size=graph.features["member"].shape)).astype(np.float32)
    return (m_idx, j_idx), labels, {"m_feat": weak_m, "j_feat": weak_j,
                                    "q_feat": q_feat}


def fit_surfaces(tables, pairs, labels, *, embed_dim, feat_dim, use_gnn,
                 epochs, eval_truth, seed=0, k=10):
    """Fit one MultiSurfaceTrainer arm; returns {surface: metric}."""
    cfgs = surface_configs(other_feat_dim=feat_dim, gnn_embed_dim=embed_dim,
                           use_gnn=use_gnn, hidden=128,
                           query_dim=tables["q_feat"].shape[1])
    mst = MultiSurfaceTrainer(cfgs, seed=seed)
    n = len(pairs[0])
    rng = np.random.default_rng(seed)
    order = rng.permutation(n)
    tr_idx, te_idx = order[:int(0.8 * n)], order[int(0.8 * n):]
    tr_pairs = (pairs[0][tr_idx], pairs[1][tr_idx])
    te_pairs = (pairs[0][te_idx], pairs[1][te_idx])
    mst.fit(tables, tr_pairs, {k_: v[tr_idx] for k_, v in labels.items()},
            epochs=epochs, seed=seed)
    scores = mst.score(tables, te_pairs)
    report = {name: auc(labels[name][te_idx], s)
              for name, s in scores.items() if name != "ebr"}

    # EBR: genuine retrieval over the full corpus, not pair scoring
    src, dst = eval_truth
    m_vec, j_vec = mst.ebr_vectors(tables)
    positives = positives_from_edges(src, dst, m_vec.shape[0])
    members = np.array([i for i, p in enumerate(positives) if p])
    report["ebr"] = recall_at_k(m_vec[members] @ j_vec.T,
                                [positives[i] for i in members], k=k)
    return report, (m_vec, j_vec)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--members", type=int, default=600)
    ap.add_argument("--jobs", type=int, default=180)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.smoke:
        args.members, args.jobs = min(args.members, 200), min(args.jobs, 60)
        args.steps, args.epochs = min(args.steps, 60), min(args.epochs, 3)

    from dataclasses import replace
    from repro.configs.linksage import CONFIG
    cfg = replace(CONFIG, hidden_dim=64, embed_dim=64, fanouts=(8, 4))

    graph, truth = generate_job_marketplace_graph(
        GraphGenConfig(num_members=args.members, num_jobs=args.jobs,
                       seed=args.seed))
    print(f"graph: {graph.census()['total_edges']} edges")

    # 1. GNN training ------------------------------------------------------
    tr = LinkSAGETrainer(cfg, graph, seed=args.seed)
    hist = tr.train(args.steps, batch_size=64)
    print(f"GNN loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

    # 2. offline sweep into the versioned store ----------------------------
    lc = tr.make_lifecycle()
    version = lc.publish_version(clock=0.0)
    print(f"published version {version}: "
          f"{len(lc.store.table(version))} embeddings "
          f"({lc.metrics.batches} sweep batches)")

    # 3./4. per-surface fit, GNN arm vs control arm ------------------------
    pairs, labels, feat_tables = build_surface_datasets(
        graph, truth, num_members=args.members, num_jobs=args.jobs,
        seed=args.seed)
    # the §14 dense-replica read path: one sorted [N, d] matrix per type
    # out of the published version (ids are 0..N-1 here by construction)
    _, m_gnn = lc.store.dense_table("member", version=version)
    _, j_gnn = lc.store.dense_table("job", version=version)

    report, vecs = {}, {}
    for arm, tables in (("gnn", dict(feat_tables, m_gnn=m_gnn, j_gnn=j_gnn)),
                        ("control", dict(feat_tables))):
        report[arm], vecs[arm] = fit_surfaces(
            tables, pairs, labels, embed_dim=cfg.embed_dim,
            feat_dim=graph.feat_dim, use_gnn=(arm == "gnn"),
            epochs=args.epochs, seed=args.seed,
            eval_truth=truth["engagements"])

    print(f"\n{'surface':<10} {'metric':<9} {'gnn':>8} {'control':>8} {'lift':>8}")
    for name in report["gnn"]:
        metric = "recall@10" if name == "ebr" else "auc"
        g, c = report["gnn"][name], report["control"][name]
        print(f"{name:<10} {metric:<9} {g:>8.4f} {c:>8.4f} {g - c:>+8.4f}")
    ebr_ok = report["gnn"]["ebr"] > report["control"]["ebr"]
    print(f"\nEBR acceptance (gnn > control on recall@10): "
          f"{'PASS' if ebr_ok else 'FAIL'}")

    # 5. quantized ANN retrieval tier over the GNN arm's EBR vectors -------
    from repro.core.retrieval import brute_force_topk
    from repro.core.transfer import SURFACES
    m_vec, j_vec = vecs["gnn"]
    src, dst = truth["engagements"]
    positives = positives_from_edges(src, dst, m_vec.shape[0])
    members = np.array([i for i, p in enumerate(positives) if p])
    queries, pos_sub = m_vec[members], [positives[i] for i in members]
    index = SURFACES["ebr"].build_index(j_vec, quantize="per_row",
                                        num_lists=0, seed=args.seed)
    k = 10
    oracle_ids, _ = brute_force_topk(queries, j_vec, k)
    exact_ids, _ = index.search(queries, k, quantized=False)
    exact_ok = np.array_equal(exact_ids, oracle_ids)
    oracle_rec = recall_from_retrieved(oracle_ids, pos_sub, k=k)
    nprobe = max(1, index.num_lists // 4)
    ann_ids, _ = index.search(queries, k, nprobe=nprobe)
    ann_rec = recall_from_retrieved(ann_ids, pos_sub, k=k)
    print(f"\nretrieval tier ({index.num_lists} IVF lists, int8 per_row):")
    print(f"  exact-search ids bit-identical to fp32 oracle: "
          f"{'PASS' if exact_ok else 'FAIL'}")
    print(f"  recall@{k}: oracle {oracle_rec:.4f}  "
          f"int8+IVF(nprobe={nprobe}) {ann_rec:.4f}  "
          f"delta {ann_rec - oracle_rec:+.4f}")
    report["retrieval"] = {"exact_parity": bool(exact_ok),
                           "oracle_recall": oracle_rec, "ann_recall": ann_rec}
    return report


if __name__ == "__main__":
    main()
