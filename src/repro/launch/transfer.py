"""Transfer launcher: ``python -m repro.launch.transfer [--smoke]``.

The full §5–§7 serving loop as one job:

  1. train the LinkSAGE encoder on engagement link prediction (§4)
  2. ``publish_version()`` — the offline full-sweep inference job writes
     every member/job embedding into the versioned EmbeddingStore (§5.2)
  3. fit ALL four product-surface heads (TAJ / JYMBII / JobSearch / EBR)
     from embeddings read out of the store at that explicit version, via
     the jitted multi-surface step sharing one embedding gather (§5.1, §7)
  4. repeat with ``use_gnn=False`` (the A/B control arm) and print the
     GNN-vs-control report: AUC per ranking surface, recall@k for EBR

The report's EBR row is the acceptance gate: the two-tower head with GNN
embeddings must beat the feature-only control on recall@k.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.eval import auc, recall_at_k
from repro.core.linksage import LinkSAGETrainer
from repro.core.transfer import MultiSurfaceTrainer, surface_configs
from repro.data import GraphGenConfig, generate_job_marketplace_graph


def build_surface_datasets(graph, truth, *, num_members, num_jobs, seed=0):
    """Per-surface label table over one shared pair list (so the multi-
    surface step's single gather genuinely serves every head).

    Pairs: the positive engagement edges plus an equal number of random
    pairs.  Labels per surface:
      jymbii    — qualified application: 1 on engagement edges
      taj       — recruiter interaction after application: Bernoulli in the
                  ground-truth match quality (recruiters reach out to good
                  matches; §7.1)
      jobsearch — relevance of the job to the member's *query*: the
                  engagement label again, with the query feature table
                  (noisy member intent) riding along
      ebr       — retrieval positives: the engagement label
    """
    rng = np.random.default_rng(seed)
    src, dst = truth["engagements"]
    n = len(src)
    m_idx = np.concatenate([src, rng.integers(0, num_members, n)]).astype(np.int32)
    j_idx = np.concatenate([dst, rng.integers(0, num_jobs, n)]).astype(np.int32)
    eng_label = np.concatenate([np.ones(n), np.zeros(n)]).astype(np.float32)

    logit = truth["match_logit"](m_idx, j_idx)
    p_recruiter = 1.0 / (1.0 + np.exp(-(2.0 * logit - 2.0)))
    taj_label = (rng.random(len(m_idx)) < p_recruiter).astype(np.float32)

    labels = {"jymbii": eng_label, "taj": taj_label,
              "jobsearch": eng_label, "ebr": eng_label}

    # weak "other features" (production rankers already have features; the
    # GNN adds the graph signal they lack) + the search-query table
    weak_m = (graph.features["member"] * 0.1
              + rng.normal(size=graph.features["member"].shape)).astype(np.float32)
    weak_j = (graph.features["job"] * 0.1
              + rng.normal(size=graph.features["job"].shape)).astype(np.float32)
    q_feat = (graph.features["member"]
              + 0.5 * rng.normal(size=graph.features["member"].shape)).astype(np.float32)
    return (m_idx, j_idx), labels, {"m_feat": weak_m, "j_feat": weak_j,
                                    "q_feat": q_feat}


def fit_surfaces(tables, pairs, labels, *, embed_dim, feat_dim, use_gnn,
                 epochs, eval_truth, seed=0, k=10):
    """Fit one MultiSurfaceTrainer arm; returns {surface: metric}."""
    cfgs = surface_configs(other_feat_dim=feat_dim, gnn_embed_dim=embed_dim,
                           use_gnn=use_gnn, hidden=128,
                           query_dim=tables["q_feat"].shape[1])
    mst = MultiSurfaceTrainer(cfgs, seed=seed)
    n = len(pairs[0])
    rng = np.random.default_rng(seed)
    order = rng.permutation(n)
    tr_idx, te_idx = order[:int(0.8 * n)], order[int(0.8 * n):]
    tr_pairs = (pairs[0][tr_idx], pairs[1][tr_idx])
    te_pairs = (pairs[0][te_idx], pairs[1][te_idx])
    mst.fit(tables, tr_pairs, {k_: v[tr_idx] for k_, v in labels.items()},
            epochs=epochs, seed=seed)
    scores = mst.score(tables, te_pairs)
    report = {name: auc(labels[name][te_idx], s)
              for name, s in scores.items() if name != "ebr"}

    # EBR: genuine retrieval over the full corpus, not pair scoring
    src, dst = eval_truth
    m_vec, j_vec = mst.ebr_vectors(tables)
    positives = [set() for _ in range(m_vec.shape[0])]
    for m, j in zip(src, dst):
        positives[m].add(int(j))
    members = np.array([i for i, p in enumerate(positives) if p])
    report["ebr"] = recall_at_k((m_vec @ j_vec.T)[members],
                                [positives[i] for i in members], k=k)
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--members", type=int, default=600)
    ap.add_argument("--jobs", type=int, default=180)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.smoke:
        args.members, args.jobs = min(args.members, 200), min(args.jobs, 60)
        args.steps, args.epochs = min(args.steps, 60), min(args.epochs, 3)

    from dataclasses import replace
    from repro.configs.linksage import CONFIG
    cfg = replace(CONFIG, hidden_dim=64, embed_dim=64, fanouts=(8, 4))

    graph, truth = generate_job_marketplace_graph(
        GraphGenConfig(num_members=args.members, num_jobs=args.jobs,
                       seed=args.seed))
    print(f"graph: {graph.census()['total_edges']} edges")

    # 1. GNN training ------------------------------------------------------
    tr = LinkSAGETrainer(cfg, graph, seed=args.seed)
    hist = tr.train(args.steps, batch_size=64)
    print(f"GNN loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

    # 2. offline sweep into the versioned store ----------------------------
    lc = tr.make_lifecycle()
    version = lc.publish_version(clock=0.0)
    print(f"published version {version}: "
          f"{len(lc.store.table(version))} embeddings "
          f"({lc.metrics.batches} sweep batches)")

    # 3./4. per-surface fit, GNN arm vs control arm ------------------------
    pairs, labels, feat_tables = build_surface_datasets(
        graph, truth, num_members=args.members, num_jobs=args.jobs,
        seed=args.seed)
    m_gnn = lc.store.gather("member", np.arange(args.members), version=version)
    j_gnn = lc.store.gather("job", np.arange(args.jobs), version=version)

    report = {}
    for arm, tables in (("gnn", dict(feat_tables, m_gnn=m_gnn, j_gnn=j_gnn)),
                        ("control", dict(feat_tables))):
        report[arm] = fit_surfaces(
            tables, pairs, labels, embed_dim=cfg.embed_dim,
            feat_dim=graph.feat_dim, use_gnn=(arm == "gnn"),
            epochs=args.epochs, seed=args.seed,
            eval_truth=truth["engagements"])

    print(f"\n{'surface':<10} {'metric':<9} {'gnn':>8} {'control':>8} {'lift':>8}")
    for name in report["gnn"]:
        metric = "recall@10" if name == "ebr" else "auc"
        g, c = report["gnn"][name], report["control"][name]
        print(f"{name:<10} {metric:<9} {g:>8.4f} {c:>8.4f} {g - c:>+8.4f}")
    ebr_ok = report["gnn"]["ebr"] > report["control"]["ebr"]
    print(f"\nEBR acceptance (gnn > control on recall@10): "
          f"{'PASS' if ebr_ok else 'FAIL'}")
    return report


if __name__ == "__main__":
    main()
