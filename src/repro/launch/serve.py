"""Serving launcher: ``python -m repro.launch.serve [--smoke]``.

The online serving tier (DESIGN.md §10) as one job:

  1. build the job-marketplace graph and (optionally) train the encoder
  2. partition it into P shards (hash or greedy edge-cut) and bootstrap a
     :class:`ShardedNearline` cluster — one engine + lifecycle per shard
  3. replay a warm-up event burst through the nearline loop (rings move,
     dirty sets drain) so the cluster serves a LIVE graph
  4. fire an open-loop Poisson request trace through the DynamicBatcher +
     shard-aware Router (+ ResultCache) and print the SLO report
  5. (``--check-parity``) assert the sharded scatter-gather path is
     bit-identical to a single-engine ``NearlineInference`` on the same
     events — the §10 acceptance gate
  6. (``--kill-restart``) resilience arm (§12): replay the same burst on a
     second cluster under a deterministic crash schedule — checkpoint to
     disk, kill mid-stream, restore, replay the suffix — and assert the
     recovered store union is bit-identical to the uninterrupted run

Smoke: ``--smoke`` caps everything to CI-toy sizes (P=2, ~200 requests).
"""
from __future__ import annotations

import argparse
from dataclasses import replace

import numpy as np

from repro.configs.linksage import CONFIG
from repro.core.embeddings import StalenessPolicy, tables_bitwise_equal
from repro.core.nearline import NearlineInference
from repro.core.partition import GraphPartitioner
from repro.data import (GraphGenConfig, generate_job_marketplace_graph,
                        marketplace_event_stream)
from repro.serving import (BatchPolicy, LoadConfig, LoadGenerator, ResultCache,
                           ShardedNearline, serve_trace)


def make_event_burst(g, rng, n):
    """A §5.2-shaped warm-up stream: fresh jobs + engagements."""
    return marketplace_event_stream(g, rng, n, job_every=10)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-toy sizes: P=2 shards, ~200 requests")
    ap.add_argument("--members", type=int, default=600)
    ap.add_argument("--jobs", type=int, default=180)
    ap.add_argument("--steps", type=int, default=0,
                    help="GNN train steps (0 = random encoder params; the "
                         "serving tier is parameter-agnostic)")
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--partition", choices=("hash", "greedy"), default="greedy")
    ap.add_argument("--requests", type=int, default=1024)
    ap.add_argument("--rate", type=float, default=500.0, help="arrivals/s")
    ap.add_argument("--candidates", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--max-wait-ms", type=float, default=20.0)
    ap.add_argument("--slo-ms", type=float, default=100.0)
    ap.add_argument("--events", type=int, default=200,
                    help="warm-up nearline event burst size")
    ap.add_argument("--cache", type=int, default=4096,
                    help="ResultCache capacity (0 disables)")
    ap.add_argument("--check-parity", action="store_true",
                    help="assert sharded == single-engine bit parity")
    ap.add_argument("--mesh", action="store_true",
                    help="device-parallel fan-out (§13): run the P shard "
                         "replicas on a ('shards',) jax mesh — drains become "
                         "one block dispatch per round, router misses an "
                         "all_to_all collective.  Falls back to the host-"
                         "sequential oracle when the backend has fewer "
                         "devices than shards (set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=P on CPU)")
    ap.add_argument("--kill-restart", action="store_true",
                    help="crash/warm-restart arm: checkpoint to disk, kill "
                         "mid-burst, restore + replay, assert bit parity")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable §15 span tracing and write a Chrome "
                         "trace-event JSON (perfetto-loadable) at PATH; "
                         "also prints the per-stage latency decomposition")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="attach a §15 MetricsRegistry to the cluster and "
                         "write its to_json() artifact at PATH; also prints "
                         "the freshness report")
    ap.add_argument("--trace-clock", choices=("wall", "tick"), default="wall",
                    help="span clock: wall for perf runs, tick for "
                         "deterministic traces (the §15 dual-clock rule)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.smoke:
        args.members, args.jobs = min(args.members, 200), min(args.jobs, 60)
        args.shards = min(args.shards, 2)
        args.requests = min(args.requests, 200)
        args.events = min(args.events, 80)
        args.check_parity = True

    # telemetry (§15): both pillars default OFF — the hard contract is that
    # enabling them never changes bits, only observes
    tracer = registry = None
    if args.trace_out:
        from repro.obs import Tracer, set_tracer
        tracer = Tracer(clock=args.trace_clock)
        set_tracer(tracer)
    if args.metrics_out:
        from repro.obs import MetricsRegistry
        registry = MetricsRegistry()

    rng = np.random.default_rng(args.seed)
    cfg = replace(CONFIG, hidden_dim=64, embed_dim=64, fanouts=(8, 4))

    # 1. graph (+ optional training) ---------------------------------------
    graph, _ = generate_job_marketplace_graph(
        GraphGenConfig(num_members=args.members, num_jobs=args.jobs,
                       seed=args.seed))
    print(f"graph: {graph.census()['total_edges']} edges")
    if args.steps > 0:
        from repro.core.linksage import LinkSAGETrainer
        tr = LinkSAGETrainer(cfg, graph, seed=args.seed)
        hist = tr.train(args.steps, batch_size=64)
        params = tr.state.params["encoder"]
        print(f"GNN loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    else:
        import jax
        from repro.core import encoder as enc
        params = enc.encoder_init(jax.random.PRNGKey(args.seed), cfg)

    # 2. partition + cluster ----------------------------------------------
    part = GraphPartitioner(args.shards, args.partition).fit(graph)
    stats = part.cut_stats(graph)
    print(f"partition: P={args.shards} strategy={args.partition} "
          f"cut_fraction={stats['cut_fraction']:.3f} "
          f"balance={stats['balance']:.2f} sizes={stats['shard_sizes']}")
    policy = StalenessPolicy(closure_radius=None)
    cluster = ShardedNearline(cfg, params, part, micro_batch=32,
                              seed=args.seed, policy=policy)
    if registry is not None:
        cluster.attach_registry(registry)   # before any events flow
    cluster.bootstrap_from_graph(graph)
    fanout = None
    if args.mesh:
        from repro.serving import MeshFanout
        fanout = MeshFanout(cluster)
        cluster.attach_mesh(fanout)
        print(f"mesh: on_mesh={fanout.on_mesh} "
              f"({'one device per shard' if fanout.on_mesh else 'fewer devices than shards -> host-sequential oracle arm'})")

    # 3. warm-up nearline burst --------------------------------------------
    events = make_event_burst(graph, rng, args.events)
    for ev in events:
        cluster.topic.publish(ev)
    cluster.process()
    agg = cluster.aggregate_metrics()
    print(f"nearline burst: {args.events} events -> "
          f"{agg.nodes_refreshed} nodes refreshed in {agg.batches} batches "
          f"(queue peak {agg.queue_depth_peak}, "
          f"remote rows {cluster.remote_fraction():.1%})")

    if args.check_parity:
        nl = NearlineInference(cfg, params, micro_batch=32, seed=args.seed,
                               policy=policy)
        nl.bootstrap_from_graph(graph)
        for ev in events:
            nl.topic.publish(ev)
        nl.process()
        ok = tables_bitwise_equal(nl.embedding_store.live_embeddings(),
                                  cluster.live_embeddings())
        print(f"parity (sharded == single-engine, bitwise): "
              f"{'PASS' if ok else 'FAIL'}")
        assert ok, "sharded/single-engine parity violated"
        if fanout is not None:
            # §13 oracle-arm gate: the same misses through the mesh
            # collective and through the host-sequential per-owner loop
            from repro.serving import Router
            probe = ([("member", int(i)) for i in
                      rng.integers(0, args.members, 8)]
                     + [("job", int(j)) for j in rng.integers(0, args.jobs, 8)])
            probe = list(dict.fromkeys(probe))
            got = Router(cluster, mesh=fanout).resolve_embeddings(probe)
            want = Router(cluster).resolve_embeddings(probe)
            ok = all(np.array_equal(got[k], want[k]) for k in probe)
            print(f"parity (mesh collective == host oracle, bitwise): "
                  f"{'PASS' if ok else 'FAIL'}")
            assert ok, "mesh/host router parity violated"

    if args.kill_restart:
        import tempfile

        from repro.serving import (FaultInjector, load_cluster_checkpoint,
                                   restore_cluster, run_with_faults)
        part2 = GraphPartitioner(args.shards, args.partition).fit(graph)
        faulted = ShardedNearline(cfg, params, part2, micro_batch=32,
                                  seed=args.seed, policy=policy)
        faulted.bootstrap_from_graph(graph)
        for ev in events:
            faulted.topic.publish(ev)
        with tempfile.TemporaryDirectory() as ckpt_dir:
            inj = FaultInjector(kill_at=(1, 4))
            st = run_with_faults(faulted, injector=inj, checkpoint_every=2,
                                 directory=ckpt_dir)
            # cold restart: a brand-new cluster restores the LATEST on-disk
            # checkpoint and replays the remaining suffix off the durable log
            cold = restore_cluster(load_cluster_checkpoint(ckpt_dir),
                                   cfg=cfg, params=params,
                                   topic=faulted.topic)
            cold.process()
        golden = cluster.live_embeddings()
        ok = (tables_bitwise_equal(golden, faulted.live_embeddings())
              and tables_bitwise_equal(golden, cold.live_embeddings()))
        print(f"kill-restart: {st['kills']} kills / {st['checkpoints']} "
              f"checkpoints / {st['replayed']} batches replayed; "
              f"warm+cold restart parity: {'PASS' if ok else 'FAIL'}")
        assert ok, "kill/restart parity violated"

    # 4. request traffic ----------------------------------------------------
    gen = LoadGenerator(
        LoadConfig(rate_hz=args.rate, num_requests=args.requests,
                   candidates=args.candidates, seed=args.seed),
        num_members=args.members, num_jobs=args.jobs)
    reqs = gen.requests()
    pol = BatchPolicy(max_batch=args.max_batch,
                      max_wait_s=args.max_wait_ms * 1e-3)
    cache = ResultCache(args.cache) if args.cache else None
    serve_trace(cluster, reqs, policy=pol, cache=None,
                slo_ms=args.slo_ms, mesh=fanout)         # warm the jit buckets
    report, batcher, router = serve_trace(cluster, reqs, policy=pol,
                                          cache=cache, slo_ms=args.slo_ms,
                                          mesh=fanout)
    s = report.summary()
    print(f"\nserved {s['completed']} requests "
          f"({s['shed']} shed) in {s['batches']} batches "
          f"(occupancy {s['occupancy_mean']:.2f})")
    print(f"throughput: {s['throughput_rps']:.1f} req/s at rate {args.rate}/s")
    print(f"latency: p50={s['latency_p50_ms']:.1f}ms "
          f"p95={s['latency_p95_ms']:.1f}ms p99={s['latency_p99_ms']:.1f}ms")
    print(f"SLO {args.slo_ms:.0f}ms violation rate: "
          f"{s['slo_violation_rate']:.1%}")
    if cache is not None:
        print(f"cache: hit_rate={router.cache.hit_rate():.1%} "
              f"size={len(router.cache)} "
              f"invalidations={router.cache.invalidations}")

    # telemetry artifacts (§15) -------------------------------------------
    if registry is not None:
        from repro.obs import collect_cluster, format_freshness
        collect_cluster(registry, cluster, slo_report=report)
        registry.write(args.metrics_out)
        print(f"\nmetrics: {len(registry)} series -> {args.metrics_out}")
        print(format_freshness(cluster.freshness_report()))
    if tracer is not None:
        from repro.obs import set_tracer
        tracer.write(args.trace_out)
        print(f"\ntrace: {len(tracer.spans)} spans "
              f"({args.trace_clock} clock) -> {args.trace_out}")
        print(tracer.format_decomposition())
        set_tracer(None)
    return report


if __name__ == "__main__":
    main()
