"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

On real hardware this builds the production mesh and pjits the train step
with the sharding rules in repro.parallel; on CPU (this container) use
--smoke for the reduced config on a 1×1 mesh.

``--arch linksage`` trains the paper's own GNN instead: a data-parallel
link-prediction job over the synthetic marketplace graph — tiles sharded on
the batch dim over a ``("data",)`` mesh spanning every visible device, the
donated/fused train step, and the background prefetching sampler pipeline
(``--prefetch``, 0 = synchronous).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import parallel as par
from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs import get_config, get_smoke_config
from repro.data.lm_data import SyntheticTokenStream
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.launch.steps import make_train_step, synthetic_batch
from repro.models import model_init
from repro.nn import param_count
from repro.optim import adamw_init


def gnn_main(args):
    """Data-parallel LinkSAGE training (the paper's GNN job, §4).

    ``--graph-backend streaming`` trains against the evolving
    StreamingEngine (bounded neighbor rings + feature store — the same
    substrate nearline serving reads from) instead of the static CSR
    snapshot, and demonstrates the near-realtime inductive story by
    continuing training after a burst of live engagement events.
    """
    from dataclasses import replace

    from repro.configs.linksage import CONFIG, smoke as gnn_smoke
    from repro.core.engine import StreamingEngine
    from repro.core.linksage import LinkSAGETrainer
    from repro.data import GraphGenConfig, generate_job_marketplace_graph

    g, _ = generate_job_marketplace_graph(
        GraphGenConfig(num_members=args.graph_members, num_jobs=args.graph_jobs,
                       seed=0))
    cfg = gnn_smoke() if args.smoke else replace(CONFIG, hidden_dim=64,
                                                 embed_dim=64, fanouts=(8, 4))
    if args.fanouts:
        cfg = cfg.with_fanouts(int(f) for f in args.fanouts.split(","))
    engine = None
    if args.graph_backend == "streaming":
        engine = StreamingEngine(g.feat_dim)
        engine.bootstrap_from_graph(g)
    ndev = len(jax.devices())
    batch = args.batch if args.batch is not None else 128
    if batch % ndev:
        batch += ndev - batch % ndev        # batch dim must divide the mesh
    mesh = jax.make_mesh((ndev,), ("data",))
    tr = LinkSAGETrainer(cfg, g, seed=0, prefetch=args.prefetch, mesh=mesh,
                         engine=engine)
    if args.resume:
        step0 = tr.restore_checkpoint(args.resume)
        print(f"resumed full TrainState (params + opt) at step {step0} "
              f"from {args.resume}")
    print(f"arch=linksage devices={ndev} batch={batch} "
          f"backend={args.graph_backend} fanouts={cfg.fanouts} "
          f"prefetch={args.prefetch} graph={g.census()['nodes']}")
    hist = tr.train(args.steps, batch_size=batch, lr=args.lr, verbose=True)
    s = tr.last_train_stats
    print(f"final loss {hist[-1]['loss']:.4f}  "
          f"{s['steps_per_s']:.1f} steps/s  "
          f"sampler_stall {100 * s['sampler_stall_frac']:.1f}%")
    if engine is not None:
        # live event suffix: new engagements land in the rings, and the very
        # next training batches sample the evolved neighborhoods
        rng = np.random.default_rng(1)
        n_events = 10 * args.graph_jobs
        for _ in range(n_events):
            m = int(rng.integers(0, args.graph_members))
            j = int(rng.integers(0, args.graph_jobs))
            engine.add_edge("member", m, "job", j)
            engine.add_edge("job", j, "member", m)
        hist2 = tr.train(max(args.steps // 5, 1), batch_size=batch, lr=args.lr)
        print(f"after {n_events} live events: loss {hist2[-1]['loss']:.4f} "
              "(training continued on the evolved store)")
    if args.checkpoint_dir:
        path = tr.save_checkpoint(args.checkpoint_dir)
        print(f"full TrainState checkpoint saved to {path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=None,
                    help="global batch (default: 4 for LM archs, 128 for linksage)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="save the full TrainState (params + opt) here at exit")
    ap.add_argument("--resume", default=None, metavar="DIR",
                    help="restore the latest full-TrainState checkpoint from "
                         "DIR before training (structural template check)")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="GNN sampler pipeline depth (0 = synchronous)")
    ap.add_argument("--graph-backend", choices=("snapshot", "streaming"),
                    default="snapshot",
                    help="GNN graph substrate: static CSR snapshot or the "
                         "evolving neighbor-ring store (nearline's backend)")
    ap.add_argument("--fanouts", default=None,
                    help="GNN per-hop fanouts, e.g. '10,5' or '10,5,3' "
                         "(K=3 trains through the same K-hop tile path)")
    ap.add_argument("--graph-members", type=int, default=600)
    ap.add_argument("--graph-jobs", type=int, default=180)
    args = ap.parse_args()

    if args.arch == "linksage":
        return gnn_main(args)
    if args.batch is None:
        args.batch = 4

    if args.smoke:
        cfg = get_smoke_config(args.arch)
        mesh = make_local_mesh()
    else:
        cfg = get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    params = model_init(jax.random.PRNGKey(0), cfg)
    print(f"arch={cfg.name} params={param_count(params):,} "
          f"mesh={dict(mesh.shape)}")
    opt = adamw_init(params)
    step0 = 0
    if args.resume:
        step0 = latest_step(args.resume)
        assert step0 is not None, f"no checkpoints under {args.resume}"
        restored = load_checkpoint(args.resume, step0,
                                   {"params": params, "opt": opt})
        params, opt = restored["params"], restored["opt"]
        print(f"resumed params + opt at step {step0} from {args.resume}")

    pspecs = par.param_pspecs(cfg, params, mesh)
    pshard = par.shardings_of(pspecs, mesh)
    oshard = par.shardings_of(par.opt_pspecs(pspecs, opt), mesh)
    use_mesh = mesh if (cfg.num_experts and mesh.shape.get("data", 1) > 1
                        and cfg.num_experts % mesh.shape["data"] == 0) else None
    step = jax.jit(make_train_step(cfg, mesh=use_mesh, lr=args.lr),
                   in_shardings=(pshard, oshard, None),
                   out_shardings=(pshard, oshard, None))

    stream = SyntheticTokenStream(cfg.vocab_size, seed=0)
    t0 = time.time()
    for i in range(args.steps):
        toks = stream.sample(args.batch, args.seq)
        batch = {"tokens": jnp.asarray(toks[:, :-1]),
                 "labels": jnp.asarray(toks[:, 1:])}
        if cfg.modality != "text":
            rng = np.random.default_rng(i)
            batch["prefix_emb"] = jnp.asarray(
                rng.normal(size=(args.batch, cfg.num_prefix_embeddings,
                                 cfg.d_model)), cfg.adtype)
            batch["labels"] = jnp.concatenate(
                [jnp.full((args.batch, cfg.num_prefix_embeddings), -1, jnp.int32),
                 batch["labels"]], axis=1)
        params, opt, m = step(params, opt, batch)
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(m['loss']):.4f} "
                  f"({time.time() - t0:.0f}s)")
    if args.checkpoint_dir:
        # cumulative step label: a resumed run must not overwrite the
        # checkpoint it resumed from
        save_checkpoint(args.checkpoint_dir, step0 + args.steps,
                        {"params": params, "opt": opt})
        print(f"full checkpoint (params + opt) saved at step {step0 + args.steps}")


if __name__ == "__main__":
    main()
