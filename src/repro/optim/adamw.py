"""AdamW implemented directly on pytrees (no optax in this container).

State layout mirrors the params pytree: ``m``/``v`` are like-shaped trees.
Moments are kept in float32 regardless of the param dtype so that bf16
training remains numerically stable; the update is computed in float32 and
cast back to the param dtype.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # int32 scalar
    m: dict
    v: dict


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def adamw_update(params, grads, state: AdamWState, *, lr, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.1):
    """One AdamW step.  ``lr`` may be a python float or a traced scalar."""
    step = state.step + 1
    b1c = 1.0 - jnp.power(b1, step.astype(jnp.float32))
    b2c = 1.0 - jnp.power(b2, step.astype(jnp.float32))

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1.0 - b1) * g32
        v_new = b2 * v + (1.0 - b2) * g32 * g32
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
