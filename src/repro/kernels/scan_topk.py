"""Pallas TPU kernel: fused int8 corpus scan + running top-k (EBR retrieval).

The retrieval tier's inner loop scores one query block against the whole
(or an IVF-restricted) quantized corpus and keeps only the k best ids.
Unfused, that is an int8 matmul materializing [nq, N] scores in HBM
followed by a top-k pass re-reading them.  This kernel streams the corpus
through VMEM in [block_c, d] bricks and carries the running per-query
top-k (values + ids) in the revisited output block, so the [nq, N] score
matrix never exists: one HBM read of codes/scales, one [nq, k] write.

Grid (nq/bq, N/bc), corpus innermost: the output BlockSpecs ignore the
corpus index, making out_vals/out_idx accumulators across corpus steps
(the matmul-k-loop pattern).  Per step:

  1. int8 · int8 dot_general accumulated in int32 on the MXU (exact —
     d ≤ 1024 keeps |acc| < 2^24, which also makes the ref oracle's
     float32 stand-in bit-identical);
  2. dequantize: acc * (q_scale · c_scale), one fp32 multiply per entry;
  3. merge [bq, k] running top-k with the [bq, bc] block scores by k
     unrolled select-max passes (k is small; each pass is a VPU
     max/where sweep over [bq, k+bc]).

Selection order is CANONICAL — score descending, corpus row ascending on
ties — implemented as max-value then min-id-among-maxima, so the result
is independent of the block decomposition and bit-identical to the
numpy/ref paths (asserted in tests/test_retrieval.py).

Brick budget at bq=128, bc=512, d=128: codes 64+16 KB int8, scores +
merge buffers ~3 fp32 [bq, k+bc] arrays ≈ 1.6 MB — far under the ~16 MB
VMEM budget; block_c can grow to 2048 before the merge buffers matter.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_I32_MAX = 2_147_483_647


def _scan_topk_kernel(k, valid_n, q_ref, qs_ref, c_ref, cs_ref,
                      vals_ref, idx_ref):
    c_step = pl.program_id(1)
    bq = q_ref.shape[0]
    bc = c_ref.shape[0]

    @pl.when(c_step == 0)
    def _init():
        vals_ref[...] = jnp.full(vals_ref.shape, -jnp.inf, vals_ref.dtype)
        idx_ref[...] = jnp.full(idx_ref.shape, _I32_MAX, idx_ref.dtype)

    # int8 x int8 -> int32 on the MXU; exact for d <= 1024 (see module doc)
    acc = jax.lax.dot_general(q_ref[...], c_ref[...],
                              dimension_numbers=(((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.int32)   # [bq, bc]
    scale = qs_ref[...] * cs_ref[...].reshape(1, bc)              # [bq, bc]
    col = c_step * bc + jax.lax.broadcasted_iota(jnp.int32, (bq, bc), 1)
    scores = jnp.where(col < valid_n,
                       acc.astype(jnp.float32) * scale, -jnp.inf)

    vals = jnp.concatenate([vals_ref[...], scores], axis=1)   # [bq, k+bc]
    idx = jnp.concatenate([idx_ref[...], col], axis=1)
    top_v, top_i = [], []
    for _ in range(k):
        best = jnp.max(vals, axis=1, keepdims=True)               # [bq, 1]
        # canonical tie-break: lowest corpus row among the maxima
        win = jnp.min(jnp.where(vals == best, idx, _I32_MAX),
                      axis=1, keepdims=True)
        top_v.append(best)
        top_i.append(win)
        vals = jnp.where(idx == win, -jnp.inf, vals)
    vals_ref[...] = jnp.concatenate(top_v, axis=1)
    idx_ref[...] = jnp.concatenate(top_i, axis=1)


@functools.partial(jax.jit, static_argnames=("k", "valid_n", "block_q",
                                             "block_c", "interpret"))
def scan_topk(q_codes: jax.Array, q_scales: jax.Array, c_codes: jax.Array,
              c_scales: jax.Array, *, k: int, valid_n: int,
              block_q: int = 128, block_c: int = 512,
              interpret: bool = False):
    """q_codes [nq, d] int8, q_scales [nq, 1] f32, c_codes [N, d] int8,
    c_scales [N, 1] f32 -> (top-k scores [nq, k] f32, corpus rows [nq, k]
    i32), canonically ordered.  ``valid_n`` <= N marks the real corpus
    rows (the tail is block padding); requires k <= min(block_c, valid_n).
    """
    nq, d = q_codes.shape
    n = c_codes.shape[0]
    bq, bc = min(block_q, nq), min(block_c, n)
    assert nq % bq == 0 and n % bc == 0, (nq, bq, n, bc)
    assert 0 < k <= min(bc, valid_n), (k, bc, valid_n)
    grid = (nq // bq, n // bc)
    kernel = functools.partial(_scan_topk_kernel, k, valid_n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, c: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i, c: (i, 0)),
            pl.BlockSpec((bc, d), lambda i, c: (c, 0)),
            pl.BlockSpec((bc, 1), lambda i, c: (c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, c: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, c: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((nq, k), jnp.float32),
                   jax.ShapeDtypeStruct((nq, k), jnp.int32)],
        interpret=interpret,
    )(q_codes, q_scales, c_codes, c_scales)
