"""Pallas TPU kernels for the perf-critical compute layers.

Kernels (each: <name>.py with pl.pallas_call + BlockSpec, oracle in ref.py,
dispatching wrapper in ops.py):

  * neighbor_agg     — masked GraphSAGE mean aggregation (GNN hot loop)
  * sage_attention   — masked single-query neighbor attention (paper §4.2)
  * flash_attention  — flash MHA w/ GQA + sliding window, prefill + decode
  * ssd_scan         — chunked Mamba-2 SSD scan (hybrid/ssm archs)
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
