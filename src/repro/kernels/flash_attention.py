"""Pallas TPU kernels: flash attention (prefill) and decode attention.

Prefill: online-softmax flash attention with GQA and optional sliding
window.  Grid (B, Hq, Sq/bq, Sk/bk) with the key axis innermost; running
(m, l, acc) live in VMEM scratch and persist across the sequential key
iterations.  Causal/window-irrelevant key blocks are skipped via pl.when so
the sliding-window variant does O(S·window) work, which is what makes
long_500k tractable for the dense architectures.

Decode: one query token per (batch, head) against a KV cache.  Grid
(B, S/bk) with all query heads resident in the block — each key block loaded
once is shared by all heads of its GQA group (the cache read, not FLOPs, is
the decode bottleneck).

MXU alignment: bq/bk default 512/512 with head_dim 128 — all matmul dims are
multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, causal: bool, window: int, scale: float,
                  num_k_blocks: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    iq = pl.program_id(2)
    q_start = iq * bq
    k_start = ik * bk
    # block relevance: causal → k_start <= q_end; window → k covers > q_start-window
    relevant = jnp.asarray(True)
    if causal:
        relevant &= k_start <= q_start + bq - 1
    if window:
        relevant &= (k_start + bk - 1) > (q_start - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, dh]
        k = k_ref[0, 0].astype(jnp.float32)            # [bk, dh]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # [bq, bk]

        qi = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        ki = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), bool)
        if causal:
            ok &= ki <= qi
        if window:
            ok &= ki > qi - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[:, 0]                           # [bq]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(ok, p, 0.0)
        l_new = alpha * l_ref[:, 0] + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, block_q: int = 512,
                    block_k: int = 512, interpret: bool = False) -> jax.Array:
    """q [B, Hq, Sq, Dh], k/v [B, Hkv, Sk, Dh] -> [B, Hq, Sq, Dh]."""
    b, hq, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    group = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    nkb = sk // bk
    scale = 1.0 / (dh ** 0.5)

    from jax.experimental.pallas import tpu as pltpu

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, causal=causal, window=window,
        scale=scale, num_k_blocks=nkb)
    return pl.pallas_call(
        kernel,
        grid=(b, hq, sq // bq, nkb),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# ------------------------------------------------------------------ decode


def _decode_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                   bk: int, group: int, window: int, scale: float,
                   num_k_blocks: int):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = lens_ref[0, 0]
    k_start = ik * bk
    relevant = k_start < length
    if window:
        relevant &= (k_start + bk) > (length - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)               # [Hq, dh]
        k = k_ref[0].astype(jnp.float32)               # [Hkv, bk, dh]
        v = v_ref[0].astype(jnp.float32)
        hq, dh = q.shape
        hkv = k.shape[0]
        qg = q.reshape(hkv, group, dh)
        s = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (0,)))) * scale  # [Hkv, g, bk]
        s = s.reshape(hq, bk)

        ki = k_start + jax.lax.broadcasted_iota(jnp.int32, (hq, bk), 1)
        ok = ki < length
        if window:
            ok &= ki >= length - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(ok, jnp.exp(s - m_new[:, None]), 0.0)
        l_ref[...] = jnp.broadcast_to(
            (alpha * l_ref[:, 0] + jnp.sum(p, axis=-1))[:, None], l_ref.shape)
        pg = p.reshape(hkv, group, bk)
        pv = jax.lax.dot_general(pg, v, (((2,), (1,)), ((0,), (0,))))  # [Hkv, g, dh]
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv.reshape(hq, dh)
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, *, window: int = 0,
                     block_k: int = 512, interpret: bool = False) -> jax.Array:
    """q [B, Hq, Dh], caches [B, Hkv, S, Dh], cache_len [B] -> [B, Hq, Dh]."""
    b, hq, dh = q.shape
    hkv, s = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    bk = min(block_k, s)
    assert s % bk == 0
    nkb = s // bk
    scale = 1.0 / (dh ** 0.5)
    lens = cache_len.reshape(b, 1).astype(jnp.int32)

    from jax.experimental.pallas import tpu as pltpu

    kernel = functools.partial(
        _decode_kernel, bk=bk, group=group, window=window, scale=scale,
        num_k_blocks=nkb)
    return pl.pallas_call(
        kernel,
        grid=(b, nkb),
        in_specs=[
            pl.BlockSpec((1, 1), lambda ib, ik: (ib, 0)),
            pl.BlockSpec((1, hq, dh), lambda ib, ik: (ib, 0, 0)),
            pl.BlockSpec((1, hkv, bk, dh), lambda ib, ik: (ib, 0, ik, 0)),
            pl.BlockSpec((1, hkv, bk, dh), lambda ib, ik: (ib, 0, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, hq, dh), lambda ib, ik: (ib, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((hq, dh), jnp.float32),
            pltpu.VMEM((hq, 128), jnp.float32),
            pltpu.VMEM((hq, 128), jnp.float32),
        ],
        interpret=interpret,
    )(lens, q, k_cache, v_cache)
