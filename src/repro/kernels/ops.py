"""Dispatching wrappers around the Pallas kernels.

Every op has three implementations:
  * ``ref``       — pure jnp/XLA (:mod:`repro.kernels.ref`), the oracle and
                    the CPU / dry-run execution path;
  * ``pallas``    — the real TPU kernel (pl.pallas_call, compiled);
  * ``interpret`` — the same kernel body run by the Pallas interpreter on
                    CPU; used by the correctness tests.

``set_impl`` / ``impl=`` override the default, which is ``pallas`` on TPU
and ``ref`` elsewhere.  Wrappers also normalize leading batch dims so callers
can pass [..., F, D] tiles of any rank.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _flash
from repro.kernels import neighbor_agg as _nagg
from repro.kernels import ref
from repro.kernels import sage_attention as _sattn
from repro.kernels import sage_layer as _slayer
from repro.kernels import scan_topk as _scan
from repro.kernels import ssd_scan as _ssd

_IMPL = None  # resolved lazily

# Roofline mode: unroll internal scans so HloCostAnalysis counts every
# iteration (a while-loop body is only counted once), and use larger q
# chunks to bound the unroll factor.  Set by the dry-run only.
ROOFLINE_MODE = False


def set_roofline_mode(on: bool) -> None:
    global ROOFLINE_MODE
    ROOFLINE_MODE = on


def default_impl() -> str:
    global _IMPL
    if _IMPL is None:
        _IMPL = "pallas" if jax.default_backend() == "tpu" else "ref"
    return _IMPL


def set_impl(impl: str) -> None:
    """impl in {'ref', 'pallas', 'interpret'} (None resets to default)."""
    global _IMPL
    assert impl in (None, "ref", "pallas", "interpret"), impl
    _IMPL = impl


def _resolve(impl):
    return impl if impl is not None else default_impl()


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


# ------------------------------------------------------------ neighbor ops


def neighbor_mean(feats: jax.Array, mask: jax.Array, *, impl=None) -> jax.Array:
    """feats [..., F, D], mask [..., F] -> [..., D]."""
    impl = _resolve(impl)
    if impl == "ref":
        return ref.neighbor_mean(feats, mask)
    lead = feats.shape[:-2]
    f, d = feats.shape[-2:]
    x = feats.reshape(-1, f, d)
    m = mask.reshape(-1, f).astype(jnp.float32)
    x, n0 = _pad_to(x, 0, 128)
    m, _ = _pad_to(m, 0, 128)
    xp, d0 = _pad_to(x, 2, 128)
    out = _nagg.neighbor_mean(xp, m, block_n=128, block_d=min(512, xp.shape[2]),
                              interpret=(impl == "interpret"))
    return out[:n0, :d0].reshape(*lead, d)


def neighbor_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                       mask: jax.Array, *, impl=None) -> jax.Array:
    """q [..., D], k/v [..., F, D], mask [..., F] -> [..., D]."""
    impl = _resolve(impl)
    if impl == "ref":
        return ref.neighbor_attention(q, k, v, mask)
    lead = k.shape[:-2]
    f, d = k.shape[-2:]
    qq = q.reshape(-1, d)
    kk = k.reshape(-1, f, d)
    vv = v.reshape(-1, f, d)
    mm = mask.reshape(-1, f).astype(jnp.float32)
    qq, n0 = _pad_to(qq, 0, 128)
    kk, _ = _pad_to(kk, 0, 128)
    vv, _ = _pad_to(vv, 0, 128)
    mm, _ = _pad_to(mm, 0, 128)
    out = _sattn.sage_attention(qq, kk, vv, mm, block_n=128,
                                interpret=(impl == "interpret"))
    return out[:n0].reshape(*lead, d)


def _sage_layer_pallas(interpret: bool, h_self, h_neigh, mask,
                       w_self, b_self, w_neigh, b_neigh):
    """Padded kernel call at flat [N, ...] rank (the custom-VJP primal)."""
    f, d = h_neigh.shape[-2:]
    h_out = w_self.shape[1]
    hh, n0 = _pad_to(h_self, 0, 128)
    nb, _ = _pad_to(h_neigh, 0, 128)
    mm, _ = _pad_to(mask.astype(jnp.float32), 0, 128)
    # pad the contraction dim (zero rows of W contribute nothing) and the
    # output dim (extra cols are sliced off) to the 128-lane width
    hh, _ = _pad_to(hh, 1, 128)
    nb, _ = _pad_to(nb, 2, 128)
    ws, _ = _pad_to(_pad_to(w_self, 0, 128)[0], 1, 128)
    wn, _ = _pad_to(_pad_to(w_neigh, 0, 128)[0], 1, 128)
    bs, _ = _pad_to(b_self.reshape(1, -1), 1, 128)
    bn, _ = _pad_to(b_neigh.reshape(1, -1), 1, 128)
    out = _slayer.sage_layer(hh, nb, mm, ws, bs, wn, bn, block_n=128,
                             interpret=interpret)
    return out[:n0, :h_out]


# pallas_call has no autodiff rule, so the fused kernels carry a hand-written
# recompute-based jnp backward: training can run straight through the
# ``pallas`` / ``interpret`` paths (forward AND backward parity against the
# jnp oracle is asserted in tests).  ``mask`` encodes graph structure, never
# a function of params, and gets a zero cotangent.

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _sage_layer_fused(interpret, h_self, h_neigh, mask,
                      w_self, b_self, w_neigh, b_neigh):
    return _sage_layer_pallas(interpret, h_self, h_neigh, mask,
                              w_self, b_self, w_neigh, b_neigh)


def _sage_layer_fwd(interpret, h_self, h_neigh, mask,
                    w_self, b_self, w_neigh, b_neigh):
    out = _sage_layer_pallas(interpret, h_self, h_neigh, mask,
                             w_self, b_self, w_neigh, b_neigh)
    return out, (h_self, h_neigh, mask, w_self, b_self, w_neigh, b_neigh, out)


def _sage_layer_bwd(interpret, res, g):
    h_self, h_neigh, mask, w_self, b_self, w_neigh, b_neigh, out = res
    f32 = jnp.float32
    g = g.astype(f32) * (out > 0)                       # relu'(pre) ≡ out > 0
    m = mask.astype(f32)
    cnt = jnp.maximum(jnp.sum(m, axis=-1, keepdims=True), 1.0)   # [N, 1]
    agg = jnp.sum(h_neigh.astype(f32) * m[..., None], axis=1) / cnt
    d_h = (g @ w_self.astype(f32).T).astype(h_self.dtype)
    d_ws = (h_self.astype(f32).T @ g).astype(w_self.dtype)
    d_agg = g @ w_neigh.astype(f32).T
    d_wn = (agg.T @ g).astype(w_neigh.dtype)
    d_b = jnp.sum(g, axis=0)
    d_nb = ((d_agg / cnt)[:, None, :] * m[..., None]).astype(h_neigh.dtype)
    return (d_h, d_nb, jnp.zeros_like(mask), d_ws, d_b.astype(b_self.dtype),
            d_wn, d_b.astype(b_neigh.dtype))


_sage_layer_fused.defvjp(_sage_layer_fwd, _sage_layer_bwd)


def sage_layer(h_self: jax.Array, h_neigh: jax.Array, mask: jax.Array,
               w_self: jax.Array, b_self: jax.Array,
               w_neigh: jax.Array, b_neigh: jax.Array, *, impl=None) -> jax.Array:
    """Fused GraphSAGE layer (mean aggregator):
    relu(h_self@W_self + b_self + mean_mask(h_neigh)@W_neigh + b_neigh).

    h_self [..., D], h_neigh [..., F, D], mask [..., F], weights [D, H],
    biases [H] -> [..., H].  Differentiable in every input except ``mask``.
    """
    impl = _resolve(impl)
    if impl == "ref":
        return ref.sage_layer(h_self, h_neigh, mask, w_self, b_self,
                              w_neigh, b_neigh)
    lead = h_neigh.shape[:-2]
    f, d = h_neigh.shape[-2:]
    h_out = w_self.shape[1]
    out = _sage_layer_fused(impl == "interpret",
                            h_self.reshape(-1, d), h_neigh.reshape(-1, f, d),
                            mask.reshape(-1, f), w_self, b_self,
                            w_neigh, b_neigh)
    return out.reshape(*lead, h_out)


def _sage_attention_layer_pallas(interpret: bool, h_self, q, k, v, mask,
                                 w_self, b_self, w_neigh, b_neigh):
    """Padded fused attention-layer kernel call at flat [N, ...] rank."""
    f, d = k.shape[-2:]
    h_out = w_self.shape[1]
    # the softmax scale must come from the TRUE feature dim, not the padded
    # one, so it is resolved here and passed into the kernel statically
    scale = 1.0 / float(d) ** 0.5
    hh, n0 = _pad_to(h_self, 0, 128)
    qq, _ = _pad_to(q, 0, 128)
    kk, _ = _pad_to(k, 0, 128)
    vv, _ = _pad_to(v, 0, 128)
    mm, _ = _pad_to(mask.astype(jnp.float32), 0, 128)
    hh, _ = _pad_to(hh, 1, 128)
    qq, _ = _pad_to(qq, 1, 128)
    kk, _ = _pad_to(kk, 2, 128)
    vv, _ = _pad_to(vv, 2, 128)
    ws, _ = _pad_to(_pad_to(w_self, 0, 128)[0], 1, 128)
    wn, _ = _pad_to(_pad_to(w_neigh, 0, 128)[0], 1, 128)
    bs, _ = _pad_to(b_self.reshape(1, -1), 1, 128)
    bn, _ = _pad_to(b_neigh.reshape(1, -1), 1, 128)
    out = _sattn.sage_attention_layer(hh, qq, kk, vv, mm, ws, bs, wn, bn,
                                      scale=scale, block_n=128,
                                      interpret=interpret)
    return out[:n0, :h_out]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _sage_attention_layer_fused(interpret, h_self, q, k, v, mask,
                                w_self, b_self, w_neigh, b_neigh):
    return _sage_attention_layer_pallas(interpret, h_self, q, k, v, mask,
                                        w_self, b_self, w_neigh, b_neigh)


def _sage_attention_layer_fwd(interpret, h_self, q, k, v, mask,
                              w_self, b_self, w_neigh, b_neigh):
    out = _sage_attention_layer_pallas(interpret, h_self, q, k, v, mask,
                                       w_self, b_self, w_neigh, b_neigh)
    return out, (h_self, q, k, v, mask, w_self, b_self, w_neigh, b_neigh, out)


def _sage_attention_layer_bwd(interpret, res, g):
    h_self, q, k, v, mask, w_self, b_self, w_neigh, b_neigh, out = res
    f32 = jnp.float32
    g = g.astype(f32) * (out > 0)
    scale = 1.0 / float(q.shape[-1]) ** 0.5
    qf, kf, vf = q.astype(f32), k.astype(f32), v.astype(f32)
    logits = jnp.einsum("nd,nfd->nf", qf, kf) * scale
    logits = jnp.where(mask > 0, logits, -1e30)
    e = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True)) * (mask > 0)
    w = e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)   # [N, F]
    agg = jnp.einsum("nf,nfd->nd", w, vf)
    d_h = (g @ w_self.astype(f32).T).astype(h_self.dtype)
    d_ws = (h_self.astype(f32).T @ g).astype(w_self.dtype)
    d_agg = g @ w_neigh.astype(f32).T
    d_wn = (agg.T @ g).astype(w_neigh.dtype)
    d_b = jnp.sum(g, axis=0)
    d_v = (w[..., None] * d_agg[:, None, :]).astype(v.dtype)
    d_w = jnp.einsum("nd,nfd->nf", d_agg, vf)
    d_logits = w * (d_w - jnp.sum(w * d_w, axis=-1, keepdims=True))
    d_q = (jnp.einsum("nf,nfd->nd", d_logits, kf) * scale).astype(q.dtype)
    d_k = (d_logits[..., None] * qf[:, None, :] * scale).astype(k.dtype)
    return (d_h, d_q, d_k, d_v, jnp.zeros_like(mask), d_ws,
            d_b.astype(b_self.dtype), d_wn, d_b.astype(b_neigh.dtype))


_sage_attention_layer_fused.defvjp(_sage_attention_layer_fwd,
                                   _sage_attention_layer_bwd)


def sage_attention_layer(h_self: jax.Array, q: jax.Array, k: jax.Array,
                         v: jax.Array, mask: jax.Array,
                         w_self: jax.Array, b_self: jax.Array,
                         w_neigh: jax.Array, b_neigh: jax.Array,
                         *, impl=None) -> jax.Array:
    """Fused GraphSAGE layer (attention aggregator):
    relu(h_self@W_self + b_self + attn(q, k, v, mask)@W_neigh + b_neigh).

    h_self/q [..., D], k/v [..., F, D], mask [..., F], weights [D, H],
    biases [H] -> [..., H].  q/k are the caller-projected attention inputs;
    differentiable in every input except ``mask``.
    """
    impl = _resolve(impl)
    if impl == "ref":
        return ref.sage_attention_layer(h_self, q, k, v, mask,
                                        w_self, b_self, w_neigh, b_neigh)
    lead = k.shape[:-2]
    f, d = k.shape[-2:]
    h_out = w_self.shape[1]
    out = _sage_attention_layer_fused(impl == "interpret",
                                      h_self.reshape(-1, d), q.reshape(-1, d),
                                      k.reshape(-1, f, d), v.reshape(-1, f, d),
                                      mask.reshape(-1, f), w_self, b_self,
                                      w_neigh, b_neigh)
    return out.reshape(*lead, h_out)


# ------------------------------------------------------- retrieval scan


def scan_topk(q_codes: jax.Array, q_scales: jax.Array, c_codes: jax.Array,
              c_scales: jax.Array, *, k: int, impl=None,
              block_q: int = 128, block_c: int = 512):
    """Fused int8 corpus scan + per-query top-k (the EBR retrieval scorer).

    q_codes [nq, d] int8, q_scales [nq], c_codes [N, d] int8, c_scales [N]
    -> (scores [nq, k] f32, corpus row ids [nq, k] i32), ordered score-
    descending with ties broken toward the lower row (canonical order —
    identical across ref/interpret/pallas and the numpy retrieval tier).
    Requires k <= N.
    """
    nq, d = q_codes.shape
    n = c_codes.shape[0]
    assert 0 < k <= n, (k, n)
    qs = q_scales.reshape(-1, 1).astype(jnp.float32)
    cs = c_scales.reshape(-1, 1).astype(jnp.float32)
    impl = _resolve(impl)
    if impl == "ref":
        return ref.scan_topk(q_codes, qs, c_codes, cs, k=k)
    bc = max(min(block_c, n), k)       # a block must hold a full top-k
    bq = min(block_q, nq)              # tail queries pad up to one block
    q_p, nq0 = _pad_to(q_codes, 0, bq)
    qs_p, _ = _pad_to(qs, 0, bq)
    c_p, _ = _pad_to(c_codes, 0, bc)
    cs_p, _ = _pad_to(cs, 0, bc)
    # pad the contraction dim to the 128-lane width (zero codes score zero)
    q_p, _ = _pad_to(q_p, 1, 128)
    c_p, _ = _pad_to(c_p, 1, 128)
    vals, idx = _scan.scan_topk(q_p, qs_p, c_p, cs_p, k=k, valid_n=n,
                                block_q=min(block_q, q_p.shape[0]),
                                block_c=bc, interpret=(impl == "interpret"))
    return vals[:nq0], idx[:nq0]


# ------------------------------------------------------------ attention


def mha(q, k, v, *, causal=True, window=0, impl=None,
        block_q=512, block_k=512):
    """q [B,Hq,S,Dh], k/v [B,Hkv,S,Dh] -> [B,Hq,S,Dh]."""
    impl = _resolve(impl)
    if impl == "ref":
        q_chunk = min(2048 if ROOFLINE_MODE else 512, q.shape[2])
        return ref.mha(q, k, v, causal=causal, window=window,
                       q_chunk=q_chunk, unroll=ROOFLINE_MODE)
    return _flash.flash_attention(q, k, v, causal=causal, window=window,
                                  block_q=min(block_q, q.shape[2]),
                                  block_k=min(block_k, k.shape[2]),
                                  interpret=(impl == "interpret"))


def decode_attention(q, k_cache, v_cache, cache_len, *, window=0, impl=None,
                     block_k=512):
    """q [B,Hq,Dh], caches [B,Hkv,S,Dh], cache_len [B] -> [B,Hq,Dh]."""
    impl = _resolve(impl)
    if impl == "ref":
        return ref.decode_attention(q, k_cache, v_cache, cache_len, window=window)
    return _flash.decode_attention(q, k_cache, v_cache, cache_len, window=window,
                                   block_k=min(block_k, k_cache.shape[2]),
                                   interpret=(impl == "interpret"))


# ------------------------------------------------------------ SSD


def ssd(x, dt, A, B, C, *, chunk=128, impl=None, initial_state=None):
    """Chunked SSD scan; see ref.ssd_scan for shapes."""
    impl = _resolve(impl)
    if impl == "ref":
        return ref.ssd_scan_chunked(x, dt, A, B, C, chunk=min(chunk, x.shape[1]),
                                    initial_state=initial_state)
    assert initial_state is None, "kernel path starts from zero state"
    return _ssd.ssd_scan(x, dt, A, B, C, chunk=min(chunk, x.shape[1]),
                         interpret=(impl == "interpret"))


def ssd_decode(S, x_t, dt_t, A, B_t, C_t):
    """Single-token SSD decode (always XLA — trivially small)."""
    return ref.ssd_decode_step(S, x_t, dt_t, A, B_t, C_t)
