"""Pallas TPU kernel: fused GraphSAGE layer (mean aggregator).

The nearline/serving hot path applies the same three steps per layer:

    agg = masked_mean(h_neigh, mask)                  # VPU reduction
    out = relu(h_self @ W_self + b_self + agg @ W_neigh + b_neigh)

Unfused, XLA materializes ``agg`` in HBM between the reduction and the two
matmuls.  This kernel keeps the whole [bn, F, D] neighbor brick, the masked
mean, both weight matrices and the activation resident in VMEM: one HBM read
of the inputs, one HBM write of the output.

Tiling: grid (N/bn,); the full fanout F and feature dim D stay resident
(GNN hidden dims are 32-512, F is 5-25).  The weights are broadcast to every
program via a constant index_map.  Brick budget at bn=128, F=32, D=512 fp32:
h_self 0.25 MB + neigh 8 MB + 2 weights 2 MB — comfortably under the ~16 MB
v5e VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sage_layer_kernel(h_ref, n_ref, mask_ref, ws_ref, bs_ref, wn_ref, bn_ref,
                       out_ref):
    h = h_ref[...]                                    # [bn, D]
    neigh = n_ref[...]                                # [bn, F, D]
    mask = mask_ref[...]                              # [bn, F]
    m = mask.astype(jnp.float32)[..., None]
    s = jnp.sum(neigh.astype(jnp.float32) * m, axis=1)            # [bn, D]
    cnt = jnp.sum(mask.astype(jnp.float32), axis=1, keepdims=True)
    agg = s / jnp.maximum(cnt, 1.0)
    out = (jnp.dot(h.astype(jnp.float32), ws_ref[...].astype(jnp.float32),
                   preferred_element_type=jnp.float32)
           + jnp.dot(agg, wn_ref[...].astype(jnp.float32),
                     preferred_element_type=jnp.float32)
           + bs_ref[...].astype(jnp.float32) + bn_ref[...].astype(jnp.float32))
    out_ref[...] = jnp.maximum(out, 0.0).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def sage_layer(h_self: jax.Array, h_neigh: jax.Array, mask: jax.Array,
               w_self: jax.Array, b_self: jax.Array,
               w_neigh: jax.Array, b_neigh: jax.Array,
               *, block_n: int = 128, interpret: bool = False) -> jax.Array:
    """h_self [N, D], h_neigh [N, F, D], mask [N, F], weights [D, H],
    biases [1, H] -> relu(h@W_self + mean@W_neigh + biases)  [N, H]."""
    n, f, d = h_neigh.shape
    h_out = w_self.shape[1]
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    grid = (n // bn,)
    return pl.pallas_call(
        _sage_layer_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn, f, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bn, f), lambda i: (i, 0)),
            pl.BlockSpec((d, h_out), lambda i: (0, 0)),
            pl.BlockSpec((1, h_out), lambda i: (0, 0)),
            pl.BlockSpec((d, h_out), lambda i: (0, 0)),
            pl.BlockSpec((1, h_out), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, h_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h_out), h_self.dtype),
        interpret=interpret,
    )(h_self, h_neigh, mask, w_self, b_self, w_neigh, b_neigh)
