"""Pallas TPU kernel: masked single-query neighbor attention (paper §4.2).

Computes the attention aggregation  M_i = Σ_n α(i,n) f(features(n)) where
α(i,·) = softmax over the (masked) fanout of ⟨W_q h_i, W_k h_n⟩/√d.  The
projections are applied outside (plain matmuls XLA already fuses well); the
kernel fuses score → masked softmax → weighted sum so the [N, F] score
matrix never leaves VMEM.

Tiling: grid (N/bn,); the full feature dim D stays resident (GNN hidden dims
are 128–512).  Brick: q [bn, D], k/v [bn, F, D], mask [bn, F].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sage_attention_kernel(q_ref, k_ref, v_ref, mask_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)          # [bn, D]
    k = k_ref[...].astype(jnp.float32)          # [bn, F, D]
    v = v_ref[...].astype(jnp.float32)
    mask = mask_ref[...]                        # [bn, F]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    logits = jnp.sum(q[:, None, :] * k, axis=-1) * scale          # [bn, F]
    logits = jnp.where(mask > 0, logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m) * (mask > 0)
    denom = jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    w = e / denom                                                  # [bn, F]
    out_ref[...] = jnp.einsum("nf,nfd->nd", w, v).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def sage_attention(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array,
                   *, block_n: int = 128, interpret: bool = False) -> jax.Array:
    """q [N, D], k/v [N, F, D], mask [N, F] -> [N, D]."""
    n, f, d = k.shape
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    grid = (n // bn,)
    return pl.pallas_call(
        _sage_attention_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn, f, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bn, f, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bn, f), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), v.dtype),
        interpret=interpret,
    )(q, k, v, mask)
