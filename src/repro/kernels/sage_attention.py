"""Pallas TPU kernels: masked single-query neighbor attention (paper §4.2).

Computes the attention aggregation  M_i = Σ_n α(i,n) f(features(n)) where
α(i,·) = softmax over the (masked) fanout of ⟨W_q h_i, W_k h_n⟩/√d.  The
projections are applied outside (plain matmuls XLA already fuses well); the
kernel fuses score → masked softmax → weighted sum so the [N, F] score
matrix never leaves VMEM.

``sage_attention_layer`` additionally fuses the full GraphSAGE layer rule
epilogue — ``relu(h_self·W_self + b_self + agg·W_neigh + b_neigh)`` — so the
attention aggregate never round-trips through HBM between the softmax and
the dual matmul, mirroring what ``sage_layer`` does for the mean path.

Tiling: grid (N/bn,); the full feature dim D stays resident (GNN hidden dims
are 128–512).  Brick: q [bn, D], k/v [bn, F, D], mask [bn, F]; the layer
variant adds h_self [bn, D] plus the two broadcast [D, H] weight bricks
(~2 MB at D=H=512 — comfortably inside the ~16 MB v5e VMEM budget alongside
the 8 MB F=32 neighbor brick).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sage_attention_kernel(q_ref, k_ref, v_ref, mask_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)          # [bn, D]
    k = k_ref[...].astype(jnp.float32)          # [bn, F, D]
    v = v_ref[...].astype(jnp.float32)
    mask = mask_ref[...]                        # [bn, F]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    logits = jnp.sum(q[:, None, :] * k, axis=-1) * scale          # [bn, F]
    logits = jnp.where(mask > 0, logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m) * (mask > 0)
    denom = jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    w = e / denom                                                  # [bn, F]
    out_ref[...] = jnp.einsum("nf,nfd->nd", w, v).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def sage_attention(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array,
                   *, block_n: int = 128, interpret: bool = False) -> jax.Array:
    """q [N, D], k/v [N, F, D], mask [N, F] -> [N, D]."""
    n, f, d = k.shape
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    grid = (n // bn,)
    return pl.pallas_call(
        _sage_attention_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn, f, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bn, f, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bn, f), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), v.dtype),
        interpret=interpret,
    )(q, k, v, mask)


def _sage_attention_layer_kernel(h_ref, q_ref, k_ref, v_ref, mask_ref,
                                 ws_ref, bs_ref, wn_ref, bn_ref, out_ref,
                                 *, scale: float):
    # ``scale`` is passed in statically because the wrapper zero-pads the
    # feature dim: 1/√D must use the TRUE D, not the padded one.
    q = q_ref[...].astype(jnp.float32)          # [bn, D]
    k = k_ref[...].astype(jnp.float32)          # [bn, F, D]
    v = v_ref[...].astype(jnp.float32)
    mask = mask_ref[...]                        # [bn, F]
    logits = jnp.sum(q[:, None, :] * k, axis=-1) * scale          # [bn, F]
    logits = jnp.where(mask > 0, logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m) * (mask > 0)
    denom = jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    agg = jnp.einsum("nf,nfd->nd", e / denom, v)                  # [bn, D]
    out = (jnp.dot(h_ref[...].astype(jnp.float32),
                   ws_ref[...].astype(jnp.float32),
                   preferred_element_type=jnp.float32)
           + jnp.dot(agg, wn_ref[...].astype(jnp.float32),
                     preferred_element_type=jnp.float32)
           + bs_ref[...].astype(jnp.float32) + bn_ref[...].astype(jnp.float32))
    out_ref[...] = jnp.maximum(out, 0.0).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "block_n", "interpret"))
def sage_attention_layer(h_self: jax.Array, q: jax.Array, k: jax.Array,
                         v: jax.Array, mask: jax.Array,
                         w_self: jax.Array, b_self: jax.Array,
                         w_neigh: jax.Array, b_neigh: jax.Array,
                         *, scale: float | None = None, block_n: int = 128,
                         interpret: bool = False) -> jax.Array:
    """h_self/q [N, D], k/v [N, F, D], mask [N, F], weights [D, H],
    biases [1, H] -> relu(h·W_self + attn_agg·W_neigh + biases)  [N, H].

    ``scale`` defaults to 1/√D of the given (possibly padded) k; callers that
    pad the feature dim must pass the true-dim scale explicitly.
    """
    n, f, d = k.shape
    h_out = w_self.shape[1]
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    grid = (n // bn,)
    return pl.pallas_call(
        functools.partial(_sage_attention_layer_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn, f, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bn, f, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bn, f), lambda i: (i, 0)),
            pl.BlockSpec((d, h_out), lambda i: (0, 0)),
            pl.BlockSpec((1, h_out), lambda i: (0, 0)),
            pl.BlockSpec((d, h_out), lambda i: (0, 0)),
            pl.BlockSpec((1, h_out), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, h_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h_out), h_self.dtype),
        interpret=interpret,
    )(h_self, q, k, v, mask, w_self, b_self, w_neigh, b_neigh)
