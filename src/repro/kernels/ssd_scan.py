"""Pallas TPU kernel: chunked Mamba-2 SSD scan (state-space duality).

Implements the SSD block decomposition (arXiv:2405.21060 §6) as a single
kernel: the sequence is split into chunks of Q tokens; within a chunk the
output is a masked "attention" matmul (MXU-friendly), across chunks a small
[N, P] state is carried in VMEM scratch through the sequential chunk axis of
the grid.  This is the TPU-native adaptation of the CUDA SSD kernel: instead
of warp-level scans, the intra-chunk work is dense matmuls on the MXU and
the inter-chunk recurrence touches only the [N, P] state per (batch, head).

Grid (B, H, L/Q); x/dt are indexed per head, B/C are shared across heads.
Brick for Q=128, N=128, P=64: x [128, 64] + B/C [128, 128] + state [128, 64]
+ [Q, Q] intermediates ≈ 0.3 MB fp32 — deep in VMEM budget, so Q can be
raised to 256/512 for more MXU utilization.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_ref, state_ref, *,
                q_chunk: int, num_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)            # [Q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)          # [Q]
    a = a_ref[0, 0]                                   # scalar decay rate (A_h < 0)
    bm = b_ref[0].astype(jnp.float32)                 # [Q, N]
    cm = c_ref[0].astype(jnp.float32)                 # [Q, N]

    la = dt * a                                       # per-step log decay
    cum = jnp.cumsum(la)                              # [Q] inclusive

    # intra-chunk: W[t, s] = 1[s<=t] · exp(cum[t]-cum[s]) · (C_t·B_s) · dt_s
    rel = cum[:, None] - cum[None, :]
    ti = jax.lax.broadcasted_iota(jnp.int32, (q_chunk, q_chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (q_chunk, q_chunk), 1)
    causal = si <= ti
    g = jnp.where(causal, jnp.exp(jnp.where(causal, rel, 0.0)), 0.0)
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())))       # [Q, Q]
    w = cb * g * dt[None, :]
    y_intra = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())))    # [Q, P]

    # inter-chunk: y_inter[t] = exp(cum[t]) · C_t^T S_enter
    s_enter = state_ref[...]                                          # [N, P]
    cs = jax.lax.dot_general(cm, s_enter, (((1,), (0,)), ((), ())))   # [Q, P]
    y = y_intra + jnp.exp(cum)[:, None] * cs
    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    # state update: S ← exp(cum[-1])·S + Σ_s dt_s·exp(cum[-1]-cum[s])·B_s⊗x_s
    dec_to_end = jnp.exp(cum[-1] - cum) * dt                          # [Q]
    inject = jax.lax.dot_general(bm * dec_to_end[:, None], x,
                                 (((0,), (0,)), ((), ())))            # [N, P]
    state_ref[...] = jnp.exp(cum[-1]) * s_enter + inject

    @pl.when(ic == num_chunks - 1)
    def _emit_state():
        s_ref[0, 0] = state_ref[...].astype(s_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, *, chunk: int = 128, interpret: bool = False):
    """Chunked SSD scan.  Shapes as in :func:`repro.kernels.ref.ssd_scan`.

    x [b, L, H, P], dt [b, L, H], A [H], B/C [b, L, N]
    -> (y [b, L, H, P], final_state [b, H, N, P])
    """
    b, L, H, P = x.shape
    N = B.shape[-1]
    q = min(chunk, L)
    assert L % q == 0, (L, q)
    nc = L // q

    from jax.experimental.pallas import tpu as pltpu

    kernel = functools.partial(_ssd_kernel, q_chunk=q, num_chunks=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=(b, H, nc),
        in_specs=[
            pl.BlockSpec((1, q, 1, P), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, q, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1, 1), lambda ib, ih, ic: (ih, 0)),
            pl.BlockSpec((1, q, N), lambda ib, ih, ic: (ib, ic, 0)),
            pl.BlockSpec((1, q, N), lambda ib, ih, ic: (ib, ic, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, 1, P), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, 1, N, P), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, L, H, P), x.dtype),
            jax.ShapeDtypeStruct((b, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.reshape(H, 1), B, C)
    return y, state
