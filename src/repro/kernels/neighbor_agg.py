"""Pallas TPU kernel: masked neighbor mean aggregation.

The GraphSAGE mean aggregator is the encoder's inner loop: for every node in
a padded tile, average the valid neighbors' hidden vectors.  On GPU this is
a sparse segment-mean; the TPU adaptation keeps the [tile, fanout, d] block
dense in VMEM and does a masked reduction on the VPU — no gather/scatter.

Tiling: grid (N/bn, D/bd); each program reduces a [bn, F, bd] brick with its
[bn, F] mask resident in VMEM.  bd is a multiple of 128 (lane width); F is
small (paper fanouts ~5-25) so the brick fits VMEM comfortably:
bn=128, F=32, bd=512 → 8 MB fp32, under the ~16 MB v5e VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _neighbor_mean_kernel(feats_ref, mask_ref, out_ref):
    feats = feats_ref[...]                      # [bn, F, bd]
    mask = mask_ref[...]                        # [bn, F]
    m = mask.astype(feats.dtype)[..., None]
    s = jnp.sum(feats * m, axis=1)              # [bn, bd]
    cnt = jnp.sum(mask.astype(jnp.float32), axis=1, keepdims=True)
    out_ref[...] = (s / jnp.maximum(cnt, 1.0).astype(feats.dtype))


@functools.partial(jax.jit, static_argnames=("block_n", "block_d", "interpret"))
def neighbor_mean(feats: jax.Array, mask: jax.Array, *, block_n: int = 128,
                  block_d: int = 512, interpret: bool = False) -> jax.Array:
    """feats [N, F, D], mask [N, F] -> [N, D] masked mean over F."""
    n, f, d = feats.shape
    bn = min(block_n, n)
    bd = min(block_d, d)
    assert n % bn == 0 and d % bd == 0, (feats.shape, bn, bd)
    grid = (n // bn, d // bd)
    return pl.pallas_call(
        _neighbor_mean_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, f, bd), lambda i, j: (i, 0, j)),
            pl.BlockSpec((bn, f), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), feats.dtype),
        interpret=interpret,
    )(feats, mask)
