"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for kernel tests (assert_allclose against the
interpret-mode kernels) AND the XLA execution path used on CPU and in the
multi-pod dry-run (Pallas lowers to TPU custom-calls only on real TPUs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ------------------------------------------------------------ neighbor agg


def neighbor_mean(feats: jax.Array, mask: jax.Array) -> jax.Array:
    """Masked mean over the fanout axis.

    feats [..., F, D], mask [..., F] (0/1) -> [..., D].
    Zero-degree nodes (all-masked) return zeros, matching the paper's
    convention that isolated nodes fall back to their self path.
    """
    m = mask.astype(feats.dtype)[..., None]
    s = jnp.sum(feats * m, axis=-2)
    cnt = jnp.sum(m, axis=-2)
    return s / jnp.maximum(cnt, 1.0)


def sage_layer(h_self: jax.Array, h_neigh: jax.Array, mask: jax.Array,
               w_self: jax.Array, b_self: jax.Array,
               w_neigh: jax.Array, b_neigh: jax.Array) -> jax.Array:
    """Fused GraphSAGE layer rule with mean aggregation (the oracle for the
    Pallas kernel in :mod:`repro.kernels.sage_layer`):

        relu(h_self @ W_self + b_self + mean_mask(h_neigh) @ W_neigh + b_neigh)

    h_self [..., D], h_neigh [..., F, D], mask [..., F], weights [D, H],
    biases [H] -> [..., H].
    """
    agg = neighbor_mean(h_neigh, mask)
    out = (h_self @ w_self.astype(h_self.dtype) + b_self.astype(h_self.dtype)
           + agg @ w_neigh.astype(agg.dtype) + b_neigh.astype(agg.dtype))
    return jax.nn.relu(out)


def neighbor_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                       mask: jax.Array) -> jax.Array:
    """Masked single-query attention over neighbors (paper's α(i,n) agg).

    q [..., D], k [..., F, D], v [..., F, D], mask [..., F] -> [..., D].
    All-masked rows return zeros.
    """
    d = q.shape[-1]
    logits = jnp.einsum("...d,...fd->...f", q, k) / jnp.sqrt(jnp.asarray(d, q.dtype))
    logits = jnp.where(mask > 0, logits, jnp.asarray(-1e30, logits.dtype))
    w = jax.nn.softmax(logits, axis=-1)
    w = w * (mask > 0)  # all-masked rows: softmax is uniform garbage -> zero it
    return jnp.einsum("...f,...fd->...d", w, v)


def sage_attention_layer(h_self: jax.Array, q: jax.Array, k: jax.Array,
                         v: jax.Array, mask: jax.Array,
                         w_self: jax.Array, b_self: jax.Array,
                         w_neigh: jax.Array, b_neigh: jax.Array) -> jax.Array:
    """Fused GraphSAGE layer rule with attention aggregation (the oracle for
    the Pallas kernel in :mod:`repro.kernels.sage_attention`):

        agg = Σ_n α(i,n)·v_n,   α = masked softmax(⟨q_i, k_n⟩/√D)
        out = relu(h_self @ W_self + b_self + agg @ W_neigh + b_neigh)

    h_self/q [..., D], k/v [..., F, D], mask [..., F], weights [D, H],
    biases [H] -> [..., H].  The q/k projections are applied by the caller.
    """
    agg = neighbor_attention(q, k, v, mask)
    out = (h_self @ w_self.astype(h_self.dtype) + b_self.astype(h_self.dtype)
           + agg @ w_neigh.astype(agg.dtype) + b_neigh.astype(agg.dtype))
    return jax.nn.relu(out)


# ------------------------------------------------------------ scan + top-k


def scan_topk(q_codes: jax.Array, q_scales: jax.Array, c_codes: jax.Array,
              c_scales: jax.Array, *, k: int):
    """Oracle for the fused int8 scan-and-topk kernel
    (:mod:`repro.kernels.scan_topk`).

    q_codes [nq, d] int8, q_scales [nq, 1], c_codes [N, d] int8,
    c_scales [N, 1] -> (scores [nq, k] f32, corpus rows [nq, k] i32).

    Bit-identical to the kernel: the int8 dot accumulates EXACTLY in
    float32 because every partial sum is an integer below 2^24 (enforced
    by ``retrieval.quantize_int8``'s d <= 1024 bound), the dequantize
    multiply applies the combined (q_scale * c_scale) in the same order,
    and ``lax.top_k``'s tie rule (lower index first) is the kernel's
    canonical score-descending / row-ascending order.
    """
    acc = jnp.dot(q_codes.astype(jnp.float32),
                  c_codes.astype(jnp.float32).T)            # exact integers
    scores = acc * (q_scales.reshape(-1, 1) * c_scales.reshape(1, -1))
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx.astype(jnp.int32)


# ------------------------------------------------------------ attention


def _window_mask(sq: int, sk: int, *, causal: bool, window: int, q_offset: int):
    """[sq, sk] boolean validity mask.  window=0 means unlimited."""
    qi = jnp.arange(sq)[:, None] + q_offset
    ki = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok &= ki <= qi
    if window:
        ok &= ki > qi - window
    return ok


def mha(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
        window: int = 0, q_offset: int = 0, q_chunk: int = 0,
        unroll: bool = False) -> jax.Array:
    """Multi-head attention with GQA + optional sliding window.

    q [B, Hq, Sq, Dh], k/v [B, Hkv, Sk, Dh] -> [B, Hq, Sq, Dh].
    ``q_offset`` positions the query block inside the kv sequence (decode:
    Sq=1, q_offset=cache_len-1).  ``q_chunk`` > 0 processes queries in chunks
    via lax.scan so the Sq×Sk score matrix is never fully materialized (the
    XLA stand-in for the Pallas flash kernel).
    """
    b, hq, sq, dh = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))

    def block(qc, off):
        # grouped GQA einsum — never materializes repeated K/V
        qg = qc.reshape(b, hkv, group, qc.shape[2], dh)
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        m = _window_mask(qc.shape[2], k.shape[2], causal=causal, window=window,
                         q_offset=off)
        logits = jnp.where(m[None, None, None], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", w, v.astype(jnp.float32))
        return o.reshape(b, hq, qc.shape[2], dh).astype(q.dtype)

    if q_chunk and sq > q_chunk and sq % q_chunk == 0:
        nchunk = sq // q_chunk
        qs = q.reshape(b, hq, nchunk, q_chunk, dh).transpose(2, 0, 1, 3, 4)

        def body(_, qc_i):
            qc, i = qc_i
            return None, block(qc, q_offset + i * q_chunk)

        _, out = jax.lax.scan(body, None, (qs, jnp.arange(nchunk)),
                              unroll=nchunk if unroll else 1)
        return out.transpose(1, 2, 0, 3, 4).reshape(b, hq, sq, dh)
    return block(q, q_offset)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len, *, window: int = 0) -> jax.Array:
    """Single-token decode: q [B, Hq, Dh], caches [B, Hkv, S, Dh] -> [B, Hq, Dh].

    ``cache_len`` (scalar or [B]) marks the number of valid cache slots; the
    new token attends to slots [max(0, L-window), L).
    """
    b, hq, dh = q.shape
    hkv, s = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    qg = q.reshape(b, hkv, group, dh)
    logits = jnp.einsum("bhgd,bhkd->bhgk", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    ki = jnp.arange(s)[None, None, None, :]
    L = jnp.asarray(cache_len).reshape(-1, 1, 1, 1).astype(jnp.int32)
    ok = ki < L
    if window:
        ok &= ki >= L - window
    logits = jnp.where(ok, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", w, v_cache.astype(jnp.float32))
    return o.reshape(b, hq, dh).astype(q.dtype)


# ------------------------------------------------------------ mamba2 SSD


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, *, initial_state: jax.Array | None = None):
    """Naive sequential SSD recurrence (the oracle for the chunked kernel).

    Shapes (single SSM head group, G folded into N):
      x  [b, L, H, P]   token inputs per head
      dt [b, L, H]      softplus-ed timestep
      A  [H]            negative decay rate per head (A < 0)
      B  [b, L, N]      input projection  (shared across heads)
      C  [b, L, N]      output projection (shared across heads)
    Returns (y [b, L, H, P], final_state [b, H, N, P]).

    Recurrence per head:  S_t = exp(dt_t·A_h)·S_{t-1} + dt_t·(B_t ⊗ x_t)
                          y_t = C_tᵀ S_t
    """
    b, L, H, P = x.shape
    N = B.shape[-1]
    S0 = (jnp.zeros((b, H, N, P), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(S, inputs):
        xt, dtt, Bt, Ct = inputs                     # [b,H,P], [b,H], [b,N], [b,N]
        decay = jnp.exp(dtt * A[None, :])            # [b,H]
        inject = dtt[..., None, None] * (Bt[:, None, :, None] * xt[:, :, None, :])
        S = decay[..., None, None] * S + inject      # [b,H,N,P]
        y = jnp.einsum("bn,bhnp->bhp", Ct, S)
        return S, y

    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          B.transpose(1, 0, 2).astype(jnp.float32),
          C.transpose(1, 0, 2).astype(jnp.float32))
    S_final, ys = jax.lax.scan(step, S0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), S_final


def ssd_scan_chunked(x, dt, A, B, C, *, chunk: int = 64,
                     initial_state=None):
    """Chunked SSD (state-space duality, arXiv:2405.21060 §6) in pure jnp.

    Mathematically identical to :func:`ssd_scan`; restructured as
    intra-chunk "attention" + inter-chunk state recurrence.  This is both a
    second oracle (validates the algebra) and the XLA path for long
    sequences (O(L·chunk) memory instead of O(L) sequential steps).
    """
    b, L, H, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, L)
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk
    xc = x.reshape(b, nc, chunk, H, P).astype(jnp.float32)
    dtc = dt.reshape(b, nc, chunk, H).astype(jnp.float32)
    Bc = B.reshape(b, nc, chunk, N).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, N).astype(jnp.float32)

    # per-position log decay within chunk: a_t = dt_t * A_h
    la = dtc * A[None, None, None, :]                      # [b,nc,Q,H]
    cum = jnp.cumsum(la, axis=2)                           # inclusive cumsum

    # intra-chunk: y_intra[t] = Σ_{s<=t} exp(cum[t]-cum[s]) dt_s (C_t·B_s) x_s
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # [b,nc,Q,Q,H]
    qi = jnp.arange(chunk)
    causal = (qi[:, None] >= qi[None, :])[None, None, :, :, None]
    # clamp BEFORE exp: non-causal rel is large-positive; exp would overflow
    # to inf and poison the backward pass through the where (inf·0 = NaN)
    G = jnp.where(causal, jnp.exp(jnp.where(causal, rel, 0.0)), 0.0)
    CB = jnp.einsum("bcqn,bcsn->bcqs", Cc, Bc)             # [b,nc,Q,Q]
    W = CB[..., None] * G * dtc[:, :, None, :, :]          # weight[t,s,h]
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", W, xc)

    # chunk summaries: state contribution of each chunk
    dec_to_end = jnp.exp(cum[:, :, -1:, :] - cum)          # exp(Σ_{s<t<=Q} a)
    chunk_state = jnp.einsum("bcqn,bcqh,bcqhp->bchnp",
                             Bc, dtc * dec_to_end, xc)     # [b,nc,H,N,P]
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # [b,nc,H]

    # inter-chunk recurrence over chunk states
    S0 = (jnp.zeros((b, H, N, P), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(S, inp):
        st, dec = inp                                      # [b,H,N,P], [b,H]
        S_in = S                                           # state entering the chunk
        S = dec[..., None, None] * S + st
        return S, S_in

    S_final, S_enter = jax.lax.scan(
        step, S0, (chunk_state.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    S_enter = S_enter.transpose(1, 0, 2, 3, 4)             # [b,nc,H,N,P]

    # inter-chunk output: y_inter[t] = C_t^T (exp(cum[t]) S_enter)
    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp", Cc, jnp.exp(cum), S_enter)

    y = (y_intra + y_inter).reshape(b, L, H, P).astype(x.dtype)
    return y, S_final


def ssd_decode_step(S, x_t, dt_t, A, B_t, C_t):
    """One-token SSD decode: state [b,H,N,P] -> (y [b,H,P], new state)."""
    decay = jnp.exp(dt_t.astype(jnp.float32) * A[None, :])
    inject = dt_t[..., None, None].astype(jnp.float32) * (
        B_t[:, None, :, None].astype(jnp.float32) * x_t[:, :, None, :].astype(jnp.float32))
    S_new = decay[..., None, None] * S + inject
    y = jnp.einsum("bn,bhnp->bhp", C_t.astype(jnp.float32), S_new)
    return y.astype(x_t.dtype), S_new
