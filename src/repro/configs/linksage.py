"""LinkSAGE GNN configuration (the paper's own model, §4.2).

Encoder: K-hop GraphSAGE (paper default: 2 hops; ``with_fanouts`` builds
deeper variants) over the heterogeneous job-marketplace graph with
per-node-type feature transforms and mean or attention aggregation.
Decoder: in-batch negative dot-product (default), MLP, or cosine.
"""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class GNNConfig:
    name: str = "linksage"
    feat_dim: int = 64             # input feature dim (common across node types)
    hidden_dim: int = 128
    embed_dim: int = 128           # served embedding size
    num_node_types: int = 6
    fanouts: tuple = (10, 5)
    aggregator: str = "mean"       # mean | attention  (paper supports both)
    decoder: str = "inbatch"       # inbatch | mlp | cosine
    num_sage_layers: int = 2
    mlp_decoder_hidden: int = 128
    cosine_scale: float = 10.0
    # paper's in-batch decoder scores raw dot products; normalization is for
    # the served EBR embeddings, not the training objective
    l2_normalize: bool = False
    dropout: float = 0.0
    # production-scale table sizes (used ONLY by the dry-run ShapeDtypeStructs)
    prod_num_members: int = 1_000_000_000
    prod_num_jobs: int = 50_000_000

    def with_aggregator(self, agg: str) -> "GNNConfig":
        return replace(self, aggregator=agg)

    def with_decoder(self, dec: str) -> "GNNConfig":
        return replace(self, decoder=dec)

    def with_fanouts(self, fanouts) -> "GNNConfig":
        """K-hop config: one SAGE layer per hop (the encoder requires
        num_sage_layers == len(fanouts))."""
        fanouts = tuple(int(f) for f in fanouts)
        return replace(self, fanouts=fanouts, num_sage_layers=len(fanouts))


CONFIG = GNNConfig()


def smoke() -> GNNConfig:
    return replace(CONFIG, hidden_dim=32, embed_dim=32, feat_dim=16, fanouts=(4, 3))
