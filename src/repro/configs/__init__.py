from repro.configs.base import (
    ARCH_IDS,
    INPUT_SHAPES,
    ArchConfig,
    InputShape,
    all_arch_configs,
    canonical_arch_id,
    get_config,
    get_smoke_config,
    smoke_reduce,
)

__all__ = [
    "ARCH_IDS",
    "INPUT_SHAPES",
    "ArchConfig",
    "InputShape",
    "all_arch_configs",
    "canonical_arch_id",
    "get_config",
    "get_smoke_config",
    "smoke_reduce",
]
