"""Mamba-2 780M — attention-free SSM with SSD (state-space duality)
[arXiv:2405.21060]."""
from repro.configs.base import ArchConfig, smoke_reduce

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=48,
    d_model=1536,
    num_heads=0,               # attention-free
    num_kv_heads=0,
    d_ff=0,                    # mamba2 block subsumes the FFN
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
)


def smoke():
    return smoke_reduce(CONFIG)
