"""MusicGen-medium — decoder-only transformer over EnCodec tokens
[arXiv:2306.05284].

Per the carve-out, the EnCodec conv codec / mel frontend is NOT implemented:
``input_specs`` provides precomputed conditioning frame embeddings of shape
[batch, num_prefix, d_model]; the decoder autoregresses over the 2048-entry
codebook vocabulary.  Deviation noted in DESIGN.md: we use RoPE instead of
MusicGen's learned sinusoidal embeddings (positional scheme is not the
paper-under-reproduction's concern).
"""
from repro.configs.base import ArchConfig, smoke_reduce

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    source="arXiv:2306.05284",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,           # MHA
    d_ff=6144,
    vocab_size=2048,           # EnCodec codebook
    norm="layernorm",
    rope_theta=10_000.0,
    modality="audio",
    num_prefix_embeddings=256, # conditioning frames
)


def smoke():
    return smoke_reduce(CONFIG)
