"""Jamba 1.5 Large 398B — hybrid Mamba+attention, 1:7 interleave, 16-expert
top-2 MoE every other layer [arXiv:2403.19887]."""
from repro.configs.base import ArchConfig, smoke_reduce

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    num_experts=16,
    experts_per_token=2,
    moe_every=2,               # MoE FFN every other layer
    attn_layer_period=8,       # 1 attention layer per 8 (1:7 mamba:attn)
    attn_layer_offset=4,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    rope_theta=1_000_000.0,
)


def smoke():
    return smoke_reduce(CONFIG, num_layers=2, attn_layer_period=2, attn_layer_offset=1)
