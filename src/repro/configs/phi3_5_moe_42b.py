"""Phi-3.5-MoE 42B (6.6B active) — 16-expert top-2 MoE
[hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.configs.base import ArchConfig, smoke_reduce

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    source="hf:microsoft/Phi-3.5-MoE-instruct",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    num_experts=16,
    experts_per_token=2,
    rope_theta=10_000.0,
)


def smoke():
    return smoke_reduce(CONFIG)
