"""CodeQwen1.5-7B — qwen1.5-architecture dense decoder
[hf:Qwen/CodeQwen1.5-7B]."""
from repro.configs.base import ArchConfig, smoke_reduce

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    source="hf:Qwen/CodeQwen1.5-7B",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,           # MHA (assigned shape: kv=32)
    d_ff=13440,
    vocab_size=92416,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)


def smoke():
    return smoke_reduce(CONFIG)
