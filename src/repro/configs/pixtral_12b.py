"""Pixtral 12B — pixtral-ViT frontend (stubbed) + mistral-nemo style decoder
backbone [hf:mistralai/Pixtral-12B-2409].

Per the carve-out, the vision encoder is NOT implemented: ``input_specs``
provides precomputed patch embeddings of shape [batch, num_prefix, d_model]
which the decoder consumes as a prefix.
"""
from repro.configs.base import ArchConfig, smoke_reduce

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    source="hf:mistralai/Pixtral-12B-2409",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,              # mistral-nemo explicit head_dim (not d_model//heads)
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
    modality="vision",
    num_prefix_embeddings=1024,   # 1 image = 1024 patch embeddings (32x32)
)


def smoke():
    return smoke_reduce(CONFIG)
