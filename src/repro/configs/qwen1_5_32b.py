"""Qwen1.5-32B — dense decoder with QKV bias [hf:Qwen/Qwen1.5-0.5B family]."""
from repro.configs.base import ArchConfig, smoke_reduce

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,           # MHA (assigned shape: kv=40)
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)


def smoke():
    return smoke_reduce(CONFIG)
