"""Architecture/config dataclasses and the --arch registry.

Each assigned architecture lives in ``repro/configs/<id>.py`` and exposes
``CONFIG`` (the exact published configuration, cited) plus ``smoke()`` (a
reduced same-family variant for CPU tests: <=2 layers, d_model<=512,
<=4 experts).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | vlm | hybrid | ssm | audio
    source: str                      # citation (arXiv id / model card)
    num_layers: int
    d_model: int
    num_heads: int                   # 0 for attention-free families
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    # attention details
    head_dim: int = 0                # derived (d_model//num_heads) when 0
    qkv_bias: bool = False
    rope_theta: float = 500_000.0
    sliding_window: int = 0          # 0 = full attention (long_500k swaps in 8192)

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_dense_residual: bool = False # arctic: dense FFN in parallel with MoE
    moe_every: int = 1               # layer period of MoE FFNs (jamba: 2)
    d_ff_dense: int = 0              # width of the arctic parallel dense FFN

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    attn_layer_period: int = 0       # jamba: one attention layer per this many
    attn_layer_offset: int = 0

    # modality frontend (stub — precomputed embeddings arrive via input_specs)
    modality: str = "text"           # text | vision | audio
    num_prefix_embeddings: int = 0   # patch/frame embeddings per example

    # misc
    tie_embeddings: bool = False
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    param_dtype: str = "bfloat16"    # full-scale dry-run dtype
    act_dtype: str = "bfloat16"

    # LinkSAGE integration (paper technique part B): condition the ranker
    # backbone on precomputed GNN member/job embeddings.
    gnn_conditioning: bool = False
    gnn_embed_dim: int = 128

    # remat policy for train_step: none | block | full
    remat: str = "block"
    # Tensor parallelism over "model".  False = pure data parallel (the right
    # choice for sub-1B models where TP psums dominate — §Perf lever).
    tp: bool = True
    # ZeRO-3/FSDP: shard weight contraction dims over "data" (all-gather per
    # block).  Right for big-model training; wrong for serving (per-token
    # weight all-gathers) and for small models where GSPMD all-reduces
    # activation-sized partials instead (§Perf lever: fsdp=False).
    fsdp: bool = True
    # Megatron-SP-style sequence sharding of the residual stream between
    # blocks: the saved per-block activations shard over "model", cutting the
    # remat residual stack by the model-axis size (§Perf lever).
    seq_shard: bool = False
    # lax.scan unroll factor for the block stack.  The dry-run sets this to
    # num_blocks (full unroll) so cost_analysis counts every layer — XLA's
    # HloCostAnalysis counts a while-loop body once, which would undercount
    # FLOPs/collectives by the trip count.
    scan_unroll: int = 1

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adtype(self):
        return jnp.dtype(self.act_dtype)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.attn_layer_period:
            return i % self.attn_layer_period == self.attn_layer_offset
        return True

    def is_moe_layer(self, i: int) -> bool:
        return self.num_experts > 0 and (i % self.moe_every == self.moe_every - 1)

    def with_sliding_window(self, window: int) -> "ArchConfig":
        return replace(self, sliding_window=window)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "llama3_8b",
    "arctic_480b",
    "pixtral_12b",
    "jamba_1_5_large_398b",
    "mamba2_780m",
    "phi3_5_moe_42b",
    "musicgen_medium",
    "yi_6b",
    "qwen1_5_32b",
    "codeqwen1_5_7b",
]

# CLI aliases (--arch uses the dashed public ids)
_ALIASES = {
    "llama3-8b": "llama3_8b",
    "arctic-480b": "arctic_480b",
    "pixtral-12b": "pixtral_12b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "mamba2-780m": "mamba2_780m",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "phi3.5-moe-42b": "phi3_5_moe_42b",
    "musicgen-medium": "musicgen_medium",
    "yi-6b": "yi_6b",
    "qwen1.5-32b": "qwen1_5_32b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "linksage": "linksage",
}


def canonical_arch_id(name: str) -> str:
    key = _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    return key


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{canonical_arch_id(name)}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{canonical_arch_id(name)}")
    return mod.smoke()


def all_arch_configs() -> dict:
    return {aid: get_config(aid) for aid in ARCH_IDS}


def smoke_reduce(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Generic reduction preserving family structure (2 layers, d<=512, <=4 experts)."""
    d_model = min(cfg.d_model, 256)
    num_heads = min(cfg.num_heads, 4) if cfg.num_heads else 0
    ratio = max(cfg.num_heads // max(cfg.num_kv_heads, 1), 1) if cfg.num_heads else 1
    num_kv = max(num_heads // min(ratio, num_heads), 1) if num_heads else 0
    kw = dict(
        num_layers=2,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=(d_model // num_heads) if num_heads else 0,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        d_ff_dense=min(cfg.d_ff_dense, 512) if cfg.d_ff_dense else 0,
        vocab_size=min(cfg.vocab_size, 1024),
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.experts_per_token else 0,
        ssm_state=min(cfg.ssm_state, 32) if cfg.ssm_state else 0,
        ssm_head_dim=min(cfg.ssm_head_dim, 32) if cfg.ssm_head_dim else 0,
        num_prefix_embeddings=min(cfg.num_prefix_embeddings, 16),
        attn_layer_period=min(cfg.attn_layer_period, 2) if cfg.attn_layer_period else 0,
        attn_layer_offset=min(cfg.attn_layer_offset, 1) if cfg.attn_layer_period else 0,
        moe_every=min(cfg.moe_every, 2),
        param_dtype="float32",
        act_dtype="float32",
        remat="none",
    )
    kw.update(overrides)
    return replace(cfg, **kw)
