"""Yi-6B — llama-architecture dense decoder with GQA kv=4 [arXiv:2403.04652]."""
from repro.configs.base import ArchConfig, smoke_reduce

CONFIG = ArchConfig(
    name="yi-6b",
    family="dense",
    source="arXiv:2403.04652",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=5_000_000.0,
)


def smoke():
    return smoke_reduce(CONFIG)
