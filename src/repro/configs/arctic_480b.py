"""Snowflake Arctic 480B — 128-expert top-2 MoE with parallel dense residual
FFN [hf:Snowflake/snowflake-arctic-base]."""
from repro.configs.base import ArchConfig, smoke_reduce

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    source="hf:Snowflake/snowflake-arctic-base",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,                 # per-expert FFN width
    vocab_size=32000,
    num_experts=128,
    experts_per_token=2,
    moe_dense_residual=True,   # dense FFN in parallel with routed experts
    d_ff_dense=4864,
    rope_theta=1_000_000.0,
)


def smoke():
    return smoke_reduce(CONFIG)
