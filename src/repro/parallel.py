"""Sharding rules: parameter/activation/state PartitionSpecs per architecture.

Mesh axes: ``("data", "model")`` single-pod 16×16, ``("pod", "data",
"model")`` multi-pod 2×16×16.  Policy (DESIGN.md §5):

  * batch dims         → ("pod", "data") jointly (replicated when indivisible)
  * attention heads    → "model" (weights column/row-sharded)
  * FFN hidden         → "model"
  * vocab              → "model" (embedding rows + lm_head cols)
  * MoE expert dim     → "data"  (expert parallelism; shard_map all_to_all)
  * SSM inner channels → "model"
  * KV-cache heads     → "model" when divisible, else replicated (the GQA
    kv<model case — a known memory lever, see EXPERIMENTS.md §Perf)
  * long_500k KV seq   → "data" (batch=1 cannot use the data axis otherwise)

Specs are derived from parameter *paths*, so they survive the stacked-block
layout (a leading num_blocks axis maps to spec prefix None).
"""
from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape


# ------------------------------------------------------------- param rules

# path-regex -> spec builder (specs WITHOUT the stacked-block leading axis).
# 2D "FSDP + TP" sharding: the contraction/input dim of every large matrix is
# sharded over "data" (ZeRO-3 style — weights are all-gathered per block
# inside the scan) and the output/hidden dim over "model" (tensor parallel).
_RULES = [
    (r"embed/table$",            lambda cfg: P("model", "data")),
    (r"lm_head/w$",              lambda cfg: P("data", "model")),
    (r"gnn_proj/w$",             lambda cfg: P(None, None)),
    (r"gnn_proj/b$",             lambda cfg: P(None)),
    (r"attn/w[qkv]/w$",          lambda cfg: P("data", "model")),
    (r"attn/w[qkv]/b$",          lambda cfg: P("model")),
    (r"attn/wo/w$",              lambda cfg: P("model", "data")),
    (r"attn/wo/b$",              lambda cfg: P(None)),
    (r"mlp/(gate|up|in)/w$",     lambda cfg: P("data", "model")),
    (r"mlp/(gate|up|in)/b$",     lambda cfg: P("model")),
    (r"mlp/(down|out)/w$",       lambda cfg: P("model", "data")),
    (r"mlp/(down|out)/b$",       lambda cfg: P(None)),
    (r"moe/router/w$",           lambda cfg: P(None, None)),
    (r"moe/w_(gate|up)$",        lambda cfg: P("data", None, "model")),
    (r"moe/w_down$",             lambda cfg: P("data", "model", None)),
    (r"ssm/(z_proj|x_proj|dt_proj)/w$", lambda cfg: P("data", "model")),
    (r"ssm/(z_proj|x_proj|dt_proj)/b$", lambda cfg: P("model")),
    (r"ssm/(B_proj|C_proj)/w$",  lambda cfg: P("data", None)),   # small, head-shared
    (r"ssm/(B_proj|C_proj)/b$",  lambda cfg: P(None)),
    (r"ssm/out_proj/w$",         lambda cfg: P("model", "data")),
    (r"ssm/out_proj/b$",         lambda cfg: P(None)),
    (r"ssm/conv_x/w$",           lambda cfg: P(None, "model")),
    (r"ssm/conv_x/b$",           lambda cfg: P("model")),
    (r"ssm/conv_[BC]/(w|b)$",    lambda cfg: P(None)),
    (r"ssm/(A_log|dt_bias|D)$",  lambda cfg: P("model")),
    (r"ssm/norm/scale$",         lambda cfg: P("model")),
    (r"norm/(scale|bias)$",      lambda cfg: P(None)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):        # NamedTuple fields (GetAttrKey)
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _spec_for(cfg: ArchConfig, path_str: str, ndim: int, shape, mesh: Mesh) -> P:
    # strip the stacked-block container prefix "blocks/<...>/layers/<j>/"
    core = re.sub(r"^blocks/", "", path_str)
    stacked = core != path_str
    core = re.sub(r"^layers/\d+/", "", core)
    # wk/wv override: sharding the flat (hkv·dh) output dim when hkv does
    # not divide the model axis would split head_dim across devices, forcing
    # attention-logit all-reduces every layer (iteration-0 dry-run finding).
    # Replicate the small K/V projection columns instead; q stays sharded.
    if re.search(r"attn/w[kv]/", core) and cfg.num_heads:
        if cfg.num_kv_heads % mesh.shape["model"] != 0:
            spec = P("data", None) if core.endswith("/w") else P(None)
            if stacked:
                spec = P(None, *spec)
            return _drop_indivisible(spec, shape, mesh)
    # same trap for wq/wo when q heads don't divide the model axis (MHA
    # with 24/40 heads): GSPMD would split head_dim instead → per-layer
    # attention-logit all-reduces (§Perf iteration 2 finding)
    if re.search(r"attn/w[qo]/", core) and cfg.num_heads:
        if cfg.num_heads % mesh.shape["model"] != 0:
            spec = P("data", None) if core.endswith("/w") else P(None)
            if stacked:
                spec = P(None, *spec)
            return _drop_indivisible(spec, shape, mesh)
    for pat, make in _RULES:
        if re.search(pat, core):
            spec = make(cfg)
            if not cfg.fsdp and "moe/" not in core:
                # serving / small-model mode: weights resident, no ZeRO
                # all-gathers — drop the "data" factor from weight specs
                # (MoE expert sharding over "data" is EP, not FSDP: keep it)
                spec = P(*[None if ax == "data" else ax for ax in spec])
            if not cfg.tp and "moe/" not in core:
                spec = P(*[None if ax == "model" else ax for ax in spec])
            if stacked:
                spec = P(None, *spec)
            spec = _drop_indivisible(spec, shape, mesh)
            return spec
    return P(*([None] * ndim))


def _drop_indivisible(spec: P, shape, mesh: Mesh) -> P:
    """Replace axis assignments that do not divide the dim (GQA kv<model etc.)."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(ax if dim % size == 0 else None)
    return P(*out)


def param_pspecs(cfg: ArchConfig, params, mesh: Mesh):
    """Pytree of PartitionSpecs matching ``params``."""
    flat = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat[0]:
        ps = _path_str(path)
        specs.append(_spec_for(cfg, ps, np.ndim(leaf), np.shape(leaf), mesh))
    return jax.tree_util.tree_unflatten(flat[1], specs)


def param_shardings(cfg: ArchConfig, params, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(cfg, params, mesh),
                        is_leaf=lambda x: isinstance(x, P))


# ------------------------------------------------------- GNN (LinkSAGE)

# Training-parallelism policy for the GNN (DESIGN.md §7): pure data-parallel.
# LinkSAGE is inductive — no embedding tables, the 1B-member scale lives in
# the stores — so params are tiny and replicate; the batch dim of both
# compute-graph tiles shards over ("data",).  Specs reuse the same
# path-regex machinery as the transformer rules above so a future sharded
# piece (e.g. a giant per-type transform) is a one-line rule, not new code.

_GNN_RULES = [
    (r"type_transform/(w|b)$",                        None),
    (r"layers/\d+/(self|neigh|attn_q|attn_k)/(w|b)$", None),
    (r"out/(w|b)$",                                   None),
    (r"mlp/",                                         None),   # MLP decoder
]


def gnn_param_pspecs(params):
    """Pytree of PartitionSpecs for a LinkSAGE params tree (all replicated
    today; every leaf must match a rule so new params are placed on
    purpose, not by accident)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        ps = _path_str(path)
        for pat, axes in _GNN_RULES:
            if re.search(pat, ps):
                assert axes is None
                specs.append(P(*([None] * np.ndim(leaf))))
                break
        else:
            raise ValueError(f"no GNN sharding rule matches param path {ps!r}")
    return jax.tree_util.tree_unflatten(treedef, specs)


def gnn_tile_pspecs(num_hops: int = 2):
    """Batch-dim ("data",) sharding for a padded K-hop ComputeGraphBatch
    (every array leads with the batch dim; hop/feature dims replicate)."""
    from repro.core.engine import ComputeGraphBatch
    return ComputeGraphBatch(
        feats=tuple(P("data", *([None] * (k + 1))) for k in range(num_hops + 1)),
        types=tuple(P("data", *([None] * k)) for k in range(num_hops + 1)),
        masks=tuple(P("data", *([None] * k)) for k in range(1, num_hops + 1)),
    )


def shards_mesh(num_shards: int) -> Mesh | None:
    """Serving-tier mesh: one device per shard over a ``("shards",)`` axis
    (DESIGN.md §13).  Returns None when the backend exposes fewer devices
    than shards — callers fall back to the host-sequential oracle arm.  On
    CPU CI the devices come from ``--xla_force_host_platform_device_count``."""
    devs = jax.devices()
    if len(devs) < num_shards:
        return None
    return Mesh(np.array(devs[:num_shards]), ("shards",))


def gnn_tile_block_pspecs(num_hops: int = 2):
    """Specs for a stacked per-shard tile block: every leaf of
    :func:`gnn_tile_pspecs` gains a leading ``[P]`` axis sharded over
    "shards", so device p holds exactly shard p's padded tile.  The batch
    dim is NOT sharded here — each shard's whole tile is local to its
    device (serving fan-out, not data parallelism)."""
    from repro.core.engine import ComputeGraphBatch
    return ComputeGraphBatch(
        feats=tuple(P("shards", *([None] * (k + 2))) for k in range(num_hops + 1)),
        types=tuple(P("shards", *([None] * (k + 1))) for k in range(num_hops + 1)),
        masks=tuple(P("shards", *([None] * (k + 1))) for k in range(1, num_hops + 1)),
    )


def gnn_state_pspecs(state):
    """Replicated specs for the whole TrainState (params + AdamW moments)."""
    from repro.optim import AdamWState
    param_specs = gnn_param_pspecs(state.params)
    opt_specs = AdamWState(step=P(), m=gnn_param_pspecs(state.opt.m),
                           v=gnn_param_pspecs(state.opt.v))
    return type(state)(params=param_specs, opt=opt_specs)


# ---------------------------------------------------------- batch / state


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _batch_axis_for(global_batch: int, mesh: Mesh):
    axes = batch_axes(mesh)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    if global_batch % size == 0:
        return axes if len(axes) > 1 else axes[0]
    if global_batch % mesh.shape["data"] == 0:
        return "data"
    return None


def data_pspecs(cfg: ArchConfig, shape: InputShape, mesh: Mesh):
    """PartitionSpecs for the train/prefill batch dict."""
    ba = _batch_axis_for(shape.global_batch, mesh)
    specs = {"tokens": P(ba, None), "labels": P(ba, None)}
    if cfg.modality != "text":
        specs["prefix_emb"] = P(ba, None, None)
    if cfg.gnn_conditioning:
        specs["gnn_emb"] = P(ba, None)
    return specs


def decode_state_pspecs(cfg: ArchConfig, state, shape: InputShape, mesh: Mesh):
    """Specs for DecodeState: caches/SSM states stacked over blocks."""
    from repro.models.layers import KVCache
    from repro.models.ssm import SSMState

    ba = _batch_axis_for(shape.global_batch, mesh)
    long_seq = shape.global_batch == 1          # long_500k: shard cache seq

    def kv_spec(x):
        # [nblocks, B, Hkv, S, dh].  Heads shard over "model" when they
        # divide; otherwise the cache *seq* dim takes the model axis (a
        # replicated multi-GB cache costs an all-gather per step — seen in
        # the baseline llama decode_32k dry-run).  long_500k (batch=1)
        # additionally puts the idle data axis on seq.
        hkv, s = x.shape[2], x.shape[3]
        head_ax = "model" if hkv % mesh.shape["model"] == 0 else None
        seq_axes = []
        if long_seq and s % mesh.shape["data"] == 0:
            seq_axes.append("data")
        if head_ax is None and s % mesh.shape["model"] == 0:
            seq_axes.append("model")
        seq_ax = tuple(seq_axes) if len(seq_axes) > 1 else (seq_axes[0] if seq_axes else None)
        return P(None, ba, head_ax, seq_ax, None)

    def leaf_spec(path, x):
        ps = _path_str(path)
        nd = np.ndim(x)
        if nd == 0:
            return P()
        if ps.endswith("/k") or ps.endswith("/v"):
            return kv_spec(x)
        if ps.endswith("/length"):
            return P(None, ba)
        if ps.endswith("/conv_x"):                # [nb, B, W-1, d_inner]
            ax = "model" if x.shape[3] % mesh.shape["model"] == 0 else None
            return P(None, ba, None, ax)
        if ps.endswith("/conv_B") or ps.endswith("/conv_C"):
            return P(None, ba, None, None)
        if ps.endswith("/ssd"):                   # [nb, B, H, N, P]
            ax = "model" if x.shape[2] % mesh.shape["model"] == 0 else None
            return P(None, ba, ax, None, None)
        return P(*([None] * nd))

    flat = jax.tree_util.tree_flatten_with_path(state)
    specs = [leaf_spec(p, l) for p, l in flat[0]]
    return jax.tree_util.tree_unflatten(flat[1], specs)


def opt_pspecs(param_specs, opt_state):
    """AdamW m/v mirror the param specs; step is replicated."""
    from repro.optim import AdamWState
    return AdamWState(step=P(), m=param_specs, v=param_specs)


def shardings_of(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def constrain(x, mesh: Mesh, spec: P):
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
