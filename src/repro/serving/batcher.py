"""Dynamic micro-batching for the scoring request path (DESIGN.md §10).

Concurrent scoring traffic arrives one request at a time, but the encoder
is only efficient on batches — and the bucketed jit path (§5) compiles one
executable per power-of-two batch bucket.  :class:`DynamicBatcher` is the
standard serving answer: a bounded FIFO queue drained under a
max-batch-size / max-wait-time policy, so a batch fires as soon as it is
full OR its oldest request has waited ``max_wait_s`` — the classic latency
/ throughput knob (max_batch=1, max_wait=0 degenerates to the unbatched
sequential baseline the benchmark compares against).

The batcher is clock-agnostic: callers pass simulated ``now`` timestamps
(the load generator owns the clock), so policies are testable without wall
time.  Downstream the popped batch flows into ``encode_nodes``'s existing
power-of-two bucket pad — the batcher never creates a new jit shape, hence
zero new retraces.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np


# overload responses when a submit finds the queue at max_queue (§12):
#   shed        — reject the NEW request (classic bounded admission)
#   shed_oldest — drop the OLDEST queued request and admit the new one
#                 (priority shed by staleness: the head has waited longest,
#                 so it is the request most likely already past its SLO)
#   degrade     — admit the new request flagged ``degraded``: the router
#                 serves it from the last materialized (possibly stale,
#                 version-pinned) embedding records WITHOUT an encoder pass,
#                 so overload converts to staleness instead of drops
OVERLOAD_POLICIES = ("shed", "shed_oldest", "degrade")


@dataclass(frozen=True)
class BatchPolicy:
    """max_batch — coalesce at most this many requests per encoder call;
    max_wait_s — deadline: fire a partial batch once the OLDEST queued
    request has waited this long; max_queue — bounded admission: submits
    past this depth trigger the ``overload`` response (load-shedding beats
    unbounded tail latency); shed_after_s — deadline shed: a queued request
    older than this at fire time is dropped instead of scored (its answer
    would be too late to matter)."""
    max_batch: int = 32
    max_wait_s: float = 0.05
    max_queue: int = 1024
    overload: str = "shed"
    shed_after_s: float | None = None


@dataclass
class ScoreRequest:
    """One scoring call: rank ``job_ids`` for ``member_id`` (the TAJ/JYMBII
    request shape: one seeker, a small candidate set).  ``degraded`` marks
    requests admitted under overload for stale-record serving."""
    time: float                    # arrival (simulated seconds)
    member_id: int
    job_ids: tuple
    degraded: bool = False

    def keys(self) -> list:
        return ([("member", int(self.member_id))]
                + [("job", int(j)) for j in self.job_ids])


@dataclass
class BatcherMetrics:
    submitted: int = 0
    shed: int = 0                                    # total drops, all reasons
    shed_queue_full: int = 0                         # dropped at max_queue
    shed_deadline: int = 0                           # expired before firing
    degraded: int = 0                                # admitted for stale serve
    batches: int = 0
    coalesced: int = 0                               # requests popped in batches
    queue_depth_peak: int = 0
    occupancy: list = field(default_factory=list)    # batch fill / max_batch

    def summary(self) -> dict:
        occ = np.array(self.occupancy) if self.occupancy else np.array([0.0])
        return {
            "submitted": self.submitted,
            "shed": self.shed,
            "shed_queue_full": self.shed_queue_full,
            "shed_deadline": self.shed_deadline,
            "degraded": self.degraded,
            "batches": self.batches,
            "coalesced": self.coalesced,
            "queue_depth_peak": self.queue_depth_peak,
            "occupancy_mean": float(occ.mean()),
            "requests_per_batch": self.coalesced / max(self.batches, 1),
        }


class DynamicBatcher:
    """Bounded queue + (max_batch, max_wait) coalescing policy."""

    def __init__(self, policy: BatchPolicy | None = None):
        self.policy = policy or BatchPolicy()
        assert self.policy.overload in OVERLOAD_POLICIES, self.policy.overload
        self._q: deque = deque()
        self.metrics = BatcherMetrics()

    def __len__(self) -> int:
        return len(self._q)

    def submit(self, req: ScoreRequest) -> bool:
        """Admit a request; False = shed.  At max_queue the policy's
        ``overload`` response decides WHO pays: the new request (shed), the
        stalest queued one (shed_oldest), or nobody — the new request is
        admitted degraded and will be served from stale records (degrade)."""
        self.metrics.submitted += 1
        if len(self._q) >= self.policy.max_queue:
            ov = self.policy.overload
            if ov == "shed":
                self.metrics.shed += 1
                self.metrics.shed_queue_full += 1
                return False
            if ov == "shed_oldest":
                self._q.popleft()
                self.metrics.shed += 1
                self.metrics.shed_queue_full += 1
            else:                          # degrade: admit past the bound
                req.degraded = True
                self.metrics.degraded += 1
        self._q.append(req)
        self.metrics.queue_depth_peak = max(self.metrics.queue_depth_peak,
                                            len(self._q))
        return True

    def full(self) -> bool:
        return len(self._q) >= self.policy.max_batch

    def head_time(self) -> float | None:
        return self._q[0].time if self._q else None

    def deadline(self) -> float | None:
        """Simulated time the current head batch MUST fire by (oldest
        arrival + max_wait), or None when idle."""
        return None if not self._q else self._q[0].time + self.policy.max_wait_s

    def trigger_time(self) -> float | None:
        """Earliest time the policy lets a batch fire: a full batch fires
        immediately (at the arrival completing it), a partial one at its
        deadline."""
        if not self._q:
            return None
        if self.full():
            # the arrival that completed the batch is the latest of the
            # first max_batch entries (FIFO: that is entry max_batch-1)
            return self._q[self.policy.max_batch - 1].time
        return self.deadline()

    def pop_batch(self, now: float | None = None) -> list:
        """Dequeue up to ``max_batch`` requests as one tile-bound batch
        (the caller owns the clock and decides WHEN via trigger_time).
        With ``shed_after_s`` set and ``now`` given, requests whose queueing
        delay already exceeds the deadline are dropped first — scoring them
        would spend encoder time on answers nobody is still waiting for."""
        dead = self.policy.shed_after_s
        if dead is not None and now is not None:
            while self._q and now - self._q[0].time > dead:
                self._q.popleft()
                self.metrics.shed += 1
                self.metrics.shed_deadline += 1
        n = min(len(self._q), self.policy.max_batch)
        batch = [self._q.popleft() for _ in range(n)]
        if batch:
            self.metrics.batches += 1
            self.metrics.coalesced += n
            self.metrics.occupancy.append(n / self.policy.max_batch)
        return batch

    # ---- checkpoint (DESIGN.md §12) -------------------------------------
    def snapshot(self) -> dict:
        """The queued requests (ScoreRequests are plain value objects)."""
        return {"queue": [(r.time, r.member_id, r.job_ids, r.degraded)
                          for r in self._q]}

    def restore(self, state: dict) -> None:
        self._q = deque(ScoreRequest(time=t, member_id=m, job_ids=tuple(j),
                                     degraded=d)
                        for (t, m, j, d) in state["queue"])
