"""Dynamic micro-batching for the scoring request path (DESIGN.md §10).

Concurrent scoring traffic arrives one request at a time, but the encoder
is only efficient on batches — and the bucketed jit path (§5) compiles one
executable per power-of-two batch bucket.  :class:`DynamicBatcher` is the
standard serving answer: a bounded FIFO queue drained under a
max-batch-size / max-wait-time policy, so a batch fires as soon as it is
full OR its oldest request has waited ``max_wait_s`` — the classic latency
/ throughput knob (max_batch=1, max_wait=0 degenerates to the unbatched
sequential baseline the benchmark compares against).

The batcher is clock-agnostic: callers pass simulated ``now`` timestamps
(the load generator owns the clock), so policies are testable without wall
time.  Downstream the popped batch flows into ``encode_nodes``'s existing
power-of-two bucket pad — the batcher never creates a new jit shape, hence
zero new retraces.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class BatchPolicy:
    """max_batch — coalesce at most this many requests per encoder call;
    max_wait_s — deadline: fire a partial batch once the OLDEST queued
    request has waited this long; max_queue — bounded admission: submits
    past this depth are shed (load-shedding beats unbounded tail latency)."""
    max_batch: int = 32
    max_wait_s: float = 0.05
    max_queue: int = 1024


@dataclass
class ScoreRequest:
    """One scoring call: rank ``job_ids`` for ``member_id`` (the TAJ/JYMBII
    request shape: one seeker, a small candidate set)."""
    time: float                    # arrival (simulated seconds)
    member_id: int
    job_ids: tuple

    def keys(self) -> list:
        return ([("member", int(self.member_id))]
                + [("job", int(j)) for j in self.job_ids])


@dataclass
class BatcherMetrics:
    submitted: int = 0
    shed: int = 0                                    # rejected at max_queue
    batches: int = 0
    coalesced: int = 0                               # requests popped in batches
    queue_depth_peak: int = 0
    occupancy: list = field(default_factory=list)    # batch fill / max_batch

    def summary(self) -> dict:
        occ = np.array(self.occupancy) if self.occupancy else np.array([0.0])
        return {
            "submitted": self.submitted,
            "shed": self.shed,
            "batches": self.batches,
            "coalesced": self.coalesced,
            "queue_depth_peak": self.queue_depth_peak,
            "occupancy_mean": float(occ.mean()),
            "requests_per_batch": self.coalesced / max(self.batches, 1),
        }


class DynamicBatcher:
    """Bounded queue + (max_batch, max_wait) coalescing policy."""

    def __init__(self, policy: BatchPolicy | None = None):
        self.policy = policy or BatchPolicy()
        self._q: deque = deque()
        self.metrics = BatcherMetrics()

    def __len__(self) -> int:
        return len(self._q)

    def submit(self, req: ScoreRequest) -> bool:
        """Admit a request; False = shed (queue at max_queue)."""
        self.metrics.submitted += 1
        if len(self._q) >= self.policy.max_queue:
            self.metrics.shed += 1
            return False
        self._q.append(req)
        self.metrics.queue_depth_peak = max(self.metrics.queue_depth_peak,
                                            len(self._q))
        return True

    def full(self) -> bool:
        return len(self._q) >= self.policy.max_batch

    def head_time(self) -> float | None:
        return self._q[0].time if self._q else None

    def deadline(self) -> float | None:
        """Simulated time the current head batch MUST fire by (oldest
        arrival + max_wait), or None when idle."""
        return None if not self._q else self._q[0].time + self.policy.max_wait_s

    def trigger_time(self) -> float | None:
        """Earliest time the policy lets a batch fire: a full batch fires
        immediately (at the arrival completing it), a partial one at its
        deadline."""
        if not self._q:
            return None
        if self.full():
            # the arrival that completed the batch is the latest of the
            # first max_batch entries (FIFO: that is entry max_batch-1)
            return self._q[self.policy.max_batch - 1].time
        return self.deadline()

    def pop_batch(self) -> list:
        """Dequeue up to ``max_batch`` requests as one tile-bound batch
        (the caller owns the clock and decides WHEN via trigger_time)."""
        n = min(len(self._q), self.policy.max_batch)
        batch = [self._q.popleft() for _ in range(n)]
        if batch:
            self.metrics.batches += 1
            self.metrics.coalesced += n
            self.metrics.occupancy.append(n / self.policy.max_batch)
        return batch
