"""The sharded serving cluster: P graph partitions, each its own engine +
embedding lifecycle (DESIGN.md §10).

:class:`ShardedNearline` is the horizontally-partitioned counterpart of
:class:`repro.core.nearline.NearlineInference`: one
:class:`~repro.core.partition.ShardedEngine` holds the partitioned graph
state, and each shard runs its OWN :class:`EmbeddingLifecycle` (registry,
recompute queue, store, jitted encoder replica) over a shard-pinned
:class:`~repro.core.partition.ShardView` — tile builds resolve cross-shard
neighbors through the composite engine while the view accounts the remote
fan-out.  Event semantics are the shared
:func:`~repro.core.nearline.apply_marketplace_event` (zero drift vs the
single-engine tier); the dirty closure walks ONE cluster-wide reverse-edge
index and routes each dirty key to its owner's queue.

Parity contract (the acceptance gate): because every per-node store
operation routes to the node's owner, and every recompute consumes the
same per-node uniform slab, the union of the P shard stores after the same
bootstrap + event stream is BIT-IDENTICAL to the single-shard
``NearlineInference`` store — for any P and any partitioning strategy.
"""
from __future__ import annotations

from collections import defaultdict

import numpy as np

from repro.configs.linksage import GNNConfig
from repro.core.embeddings import (EmbeddingLifecycle, EmbeddingStore,
                                   LifecycleMetrics, StalenessPolicy,
                                   index_reverse_edges)
from repro.core.graph import NODE_TYPE_ID, NODE_TYPES
from repro.core.nearline import (Event, Topic, apply_marketplace_event,
                                 poll_and_apply, poll_and_process)
from repro.core.partition import GraphPartitioner, ShardedEngine, ShardView


class ShardedNearline:
    """P-shard nearline pipeline: poll → route writes by owner → dirty the
    owners' lifecycles through one shared closure index → drain every
    shard's priority queue."""

    def __init__(self, cfg: GNNConfig, encoder_params,
                 partitioner: GraphPartitioner, *, fanouts=None,
                 micro_batch: int = 64, max_neighbors: int = 64, seed: int = 0,
                 policy: StalenessPolicy | None = None,
                 jit_encoder: bool = True, feature_cache=None,
                 embed_cache=None):
        from repro.core.cache import CachedEngine, SlabCache, as_slab_cache
        # each shard owns its slab (a real deployment's caches live in the
        # shard processes) — a shared SlabCache instance would alias them
        assert not isinstance(feature_cache, SlabCache), \
            "sharded tier builds one slab per shard — pass slots or a CacheConfig"
        assert not isinstance(embed_cache, SlabCache), \
            "sharded tier builds one slab per shard — pass slots or a CacheConfig"
        self.cfg = cfg
        self.partitioner = partitioner
        self.micro_batch = micro_batch
        self.topic = Topic("job-marketplace-events")
        self.engine = ShardedEngine(cfg.feat_dim, partitioner,
                                    max_neighbors=max_neighbors)
        self._rev: dict = defaultdict(set)      # ONE cluster-wide closure index
        self.caches: list = []                  # ResultCaches to dirty-invalidate
        self.feature_caches: list = []          # per-shard tier-1 slabs (§11)
        self.embed_caches: list = []            # per-shard tier-2 slabs (§11)
        self.events_processed = 0               # cluster-level (shards see batches)
        # counters folded in from caches retired via detach_cache, so the
        # roll-up keeps their traffic after serve_trace auto-closes them
        self.retired_cache_hits = 0
        self.retired_cache_misses = 0
        self.views: list[ShardView] = []
        self.shards: list[EmbeddingLifecycle] = []
        for p in range(partitioner.num_shards):
            view = ShardView(self.engine, p)
            eng = view
            fc = as_slab_cache(feature_cache, cfg.feat_dim,
                               name=f"feature-cache-shard{p}")
            if fc is not None:
                eng = CachedEngine(view, fc)
                self.feature_caches.append(fc)
            lc = EmbeddingLifecycle(
                cfg, encoder_params, eng, fanouts=fanouts,
                store=EmbeddingStore(f"gnn-embeddings-shard{p}"),
                policy=policy, micro_batch=micro_batch, seed=seed,
                jit_encoder=jit_encoder, embed_cache=embed_cache)
            if fc is not None:
                eng.metrics = lc.metrics        # mirror hits into shard counters
                lc.store.attach_cache(fc)
            if lc.embed_cache is not None:
                self.embed_caches.append(lc.embed_cache)
            lc._rev = self._rev                 # shared: closure sees all edges
            self.views.append(view)
            self.shards.append(lc)

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    def owner(self, node_type: str, node_id: int) -> EmbeddingLifecycle:
        return self.shards[self.partitioner.shard_of(node_type, node_id)]

    # ---- bootstrap ------------------------------------------------------
    def bootstrap_from_graph(self, graph) -> None:
        self.engine.bootstrap_from_graph(graph)
        for ntype in NODE_TYPES:
            n = graph.num_nodes.get(ntype, 0)
            if not n:
                continue
            owners = self.partitioner.shard_array(
                np.full(n, NODE_TYPE_ID[ntype]), np.arange(n))
            for i in range(n):
                self.shards[owners[i]].registry.add((ntype, i))
        index_reverse_edges(graph, self._rev)

    # ---- event application ----------------------------------------------
    def _add_edge(self, src_type: str, src_id: int, dst_type: str,
                  dst_id: int) -> None:
        self.engine.add_edge(src_type, src_id, dst_type, dst_id)
        self._rev[(dst_type, int(dst_id))].add((src_type, int(src_id)))

    def _register(self, node_type: str, node_id: int) -> None:
        self.owner(node_type, node_id).register(node_type, node_id)

    def _put_feature(self, tid: int, nid: int, feat) -> None:
        # cluster writes route by owner through the ShardedEngine, bypassing
        # the shard views' CachedEngine wrappers — so tier-1 write-through
        # invalidation happens here, before the store mutates
        for fc in self.feature_caches:
            fc.invalidate(int(tid), int(nid))
        self.engine.put_feature(tid, nid, feat)

    def _apply_event(self, ev: Event):
        return apply_marketplace_event(
            ev, put_feature=self._put_feature, add_edge=self._add_edge,
            register=self._register)

    def mark_dirty(self, node_type: str, node_id: int, t: float) -> int:
        """Closure over the shared reverse index, each key routed to its
        owner shard's queue; attached ResultCaches drop the dirty keys.

        Cache coherence is NOT a policy knob: caches are invalidated over
        the FULL K-hop dependency ball even when the recompute policy runs
        a cheaper radius (radius 0 makes the *store* eventually consistent
        by design, but a cache hit must always equal a fresh recompute —
        the router's bit-identity contract)."""
        lc0 = self.shards[0]
        touched = {(node_type, int(node_id))}
        keys = lc0.dirty_closure(touched)
        for key in keys:
            self.owner(*key).enqueue_dirty(key, t)
        if self.caches or self.embed_caches:
            full = (keys if lc0.policy.closure_radius is None else
                    lc0.dirty_closure(touched, radius=len(lc0.fanouts)))
            for cache in self.caches:
                cache.invalidate(full)
            for ec in self.embed_caches:
                for nt, ni in full:
                    ec.invalidate(NODE_TYPE_ID[nt], ni)
        return len(keys)

    # ---- the serving loop ------------------------------------------------
    def ingest(self, *, upto_time: float | None = None,
               max_events: int = 10**9) -> int:
        """Apply pending events and dirty owners WITHOUT recomputing."""
        return poll_and_apply(self.topic, "sharded-nearline", self.micro_batch,
                              self._apply_event, self.mark_dirty,
                              upto_time=upto_time, max_events=max_events)

    def drain(self, *, clock: float = 0.0, max_nodes: int | None = None) -> int:
        """Drain every shard's queue (shard order is irrelevant: recomputes
        are per-node deterministic)."""
        return sum(lc.drain(clock=clock, max_nodes=max_nodes)
                   for lc in self.shards)

    def process(self, *, upto_time: float | None = None,
                max_batches: int = 10**9, clock: float | None = None) -> int:
        """Poll → apply → dirty → drain, in micro-batches (the P-shard
        instance of the one shared nearline loop)."""
        total = poll_and_process(
            self.topic, "sharded-nearline", self.micro_batch,
            self._apply_event, self.mark_dirty,
            lambda refresh: self.drain(clock=refresh),
            upto_time=upto_time, max_batches=max_batches, clock=clock)
        self.events_processed += total
        return total

    def publish_version(self, *, clock: float = 0.0) -> int:
        """Full sweep on every shard; all shard stores advance to the same
        version number (each sweeps only its owned registry)."""
        versions = {lc.publish_version(clock=clock) for lc in self.shards}
        assert len(versions) == 1, f"shard versions diverged: {versions}"
        return versions.pop()

    # ---- reads across shards --------------------------------------------
    def record(self, node_type: str, node_id: int):
        return self.owner(node_type, node_id).store.record(node_type, node_id)

    def live_embeddings(self) -> dict:
        """Union of the shard stores' live tables (the parity comparator:
        owners partition the key space, so the union is disjoint)."""
        out: dict = {}
        for lc in self.shards:
            out.update(lc.store.live_embeddings())
        return out

    def pending(self) -> int:
        return sum(lc.pending() for lc in self.shards)

    def aggregate_metrics(self) -> LifecycleMetrics:
        """Cluster-wide counter roll-up (sums; queue-depth peak is a max)."""
        agg = LifecycleMetrics()
        agg.events_processed = self.events_processed
        agg.join_reads = self.engine.join_reads    # engine-wide, not per-shard
        for lc in self.shards:
            m = lc.metrics
            agg.batches += m.batches
            agg.nodes_refreshed += m.nodes_refreshed
            agg.encoder_seconds += m.encoder_seconds
            agg.join_seconds += m.join_seconds
            agg.encoder_traces += m.encoder_traces
            agg.staleness.extend(m.staleness)
            agg.sweeps += m.sweeps
            agg.queue_depth_peak = max(agg.queue_depth_peak, m.queue_depth_peak)
        agg.cache_hits = self.retired_cache_hits
        agg.cache_misses = self.retired_cache_misses
        for cache in self.caches:          # attached serving caches
            fh, fm = getattr(cache, "_folded", (0, 0))
            agg.cache_hits += cache.metrics.cache_hits - fh
            agg.cache_misses += cache.metrics.cache_misses - fm
        # slab counters roll up from the caches themselves (robust against
        # per-shard metrics objects being swapped by benches)
        for fc in self.feature_caches:
            agg.feature_cache_hits += fc.hits
            agg.feature_cache_misses += fc.misses
            agg.feature_cache_evictions += fc.evictions
        for ec in self.embed_caches:
            agg.embed_cache_hits += ec.hits
            agg.embed_cache_misses += ec.misses
            agg.embed_cache_evictions += ec.evictions
        return agg

    def detach_cache(self, cache) -> None:
        """Remove a cache from the invalidation fan-out, folding its not-
        yet-folded hit/miss counters into the cluster roll-up (a cache can
        attach/detach repeatedly — e.g. serve_trace replays — without
        double counting)."""
        fh, fm = getattr(cache, "_folded", (0, 0))
        self.retired_cache_hits += cache.metrics.cache_hits - fh
        self.retired_cache_misses += cache.metrics.cache_misses - fm
        cache._folded = (cache.metrics.cache_hits, cache.metrics.cache_misses)
        self.caches = [c for c in self.caches if c is not cache]

    def remote_fraction(self) -> float:
        """Fraction of query rows shards resolved off-home (the scatter-
        gather network cost a real deployment would pay)."""
        local = sum(v.local_rows for v in self.views)
        remote = sum(v.remote_rows for v in self.views)
        return remote / max(local + remote, 1)
