"""The sharded serving cluster: P graph partitions, each its own engine +
embedding lifecycle (DESIGN.md §10).

:class:`ShardedNearline` is the horizontally-partitioned counterpart of
:class:`repro.core.nearline.NearlineInference`: one
:class:`~repro.core.partition.ShardedEngine` holds the partitioned graph
state, and each shard runs its OWN :class:`EmbeddingLifecycle` (registry,
recompute queue, store, jitted encoder replica) over a shard-pinned
:class:`~repro.core.partition.ShardView` — tile builds resolve cross-shard
neighbors through the composite engine while the view accounts the remote
fan-out.  Event semantics are the shared
:func:`~repro.core.nearline.apply_marketplace_event` (zero drift vs the
single-engine tier); the dirty closure walks ONE cluster-wide reverse-edge
index and routes each dirty key to its owner's queue.

Parity contract (the acceptance gate): because every per-node store
operation routes to the node's owner, and every recompute consumes the
same per-node uniform slab, the union of the P shard stores after the same
bootstrap + event stream is BIT-IDENTICAL to the single-shard
``NearlineInference`` store — for any P and any partitioning strategy.
"""
from __future__ import annotations

from collections import defaultdict

import numpy as np

from repro.configs.linksage import GNNConfig
from repro.core.embeddings import (EmbeddingLifecycle, EmbeddingStore,
                                   LifecycleMetrics, StalenessPolicy,
                                   index_reverse_edges)
from repro.core.graph import NODE_TYPE_ID, NODE_TYPES
from repro.core.nearline import (Event, Topic, apply_marketplace_event,
                                 poll_and_apply, poll_and_process)
from repro.core.partition import GraphPartitioner, ShardedEngine, ShardView


class ShardedNearline:
    """P-shard nearline pipeline: poll → route writes by owner → dirty the
    owners' lifecycles through one shared closure index → drain every
    shard's priority queue."""

    def __init__(self, cfg: GNNConfig, encoder_params,
                 partitioner: GraphPartitioner, *, fanouts=None,
                 micro_batch: int = 64, max_neighbors: int = 64, seed: int = 0,
                 policy: StalenessPolicy | None = None,
                 jit_encoder: bool = True, feature_cache=None,
                 embed_cache=None):
        from repro.core.cache import SlabCache
        # each shard owns its slab (a real deployment's caches live in the
        # shard processes) — a shared SlabCache instance would alias them
        assert not isinstance(feature_cache, SlabCache), \
            "sharded tier builds one slab per shard — pass slots or a CacheConfig"
        assert not isinstance(embed_cache, SlabCache), \
            "sharded tier builds one slab per shard — pass slots or a CacheConfig"
        self.cfg = cfg
        self.params = encoder_params
        self.partitioner = partitioner
        self.micro_batch = micro_batch
        self.seed = seed
        self.max_neighbors = max_neighbors
        self.jit_encoder = jit_encoder
        # cache SPECS (not instances) so warm restart / add_shard can build
        # identically-configured per-shard slabs
        self._cache_spec = (feature_cache, embed_cache)
        self.topic = Topic("job-marketplace-events")
        self.engine = ShardedEngine(cfg.feat_dim, partitioner,
                                    max_neighbors=max_neighbors)
        self._rev: dict = defaultdict(set)      # ONE cluster-wide closure index
        self.caches: list = []                  # ResultCaches to dirty-invalidate
        self.feature_caches: list = []          # per-shard tier-1 slabs (§11)
        self.embed_caches: list = []            # per-shard tier-2 slabs (§11)
        self.events_processed = 0               # cluster-level (shards see batches)
        # counters folded in from caches retired via detach_cache, so the
        # roll-up keeps their traffic after serve_trace auto-closes them
        self.retired_cache_hits = 0
        self.retired_cache_misses = 0
        self.views: list[ShardView] = []
        self.shards: list[EmbeddingLifecycle] = []
        self.mesh_fanout = None                 # device-parallel arm (§13)
        self.policy = policy or StalenessPolicy()
        self.fanouts = tuple(fanouts or cfg.fanouts)
        # overload-control counters folded in from retired batchers (§12),
        # mirroring the retired-cache bookkeeping above
        self.shed_queue_full = 0
        self.shed_deadline = 0
        self.requests_degraded = 0
        # §15 native-counter lane: an attached MetricsRegistry rides the
        # snapshot/restore surface below, so its monotonic counters
        # re-derive consistently under rollback + replay
        self.obs_registry = None
        for p in range(partitioner.num_shards):
            view, lc = self._make_shard(p)
            self.views.append(view)
            self.shards.append(lc)

    def _make_shard(self, p: int):
        """One shard's view + (optional) tier-1 slab + lifecycle, wired the
        same way for __init__, warm restart, and elastic add_shard."""
        from repro.core.cache import CachedEngine, as_slab_cache
        feature_cache, embed_cache = self._cache_spec
        view = ShardView(self.engine, p)
        eng = view
        fc = as_slab_cache(feature_cache, self.cfg.feat_dim,
                           name=f"feature-cache-shard{p}")
        if fc is not None:
            eng = CachedEngine(view, fc)
            self.feature_caches.append(fc)
        lc = EmbeddingLifecycle(
            self.cfg, self.params, eng, fanouts=self.fanouts,
            store=EmbeddingStore(f"gnn-embeddings-shard{p}"),
            policy=self.policy, micro_batch=self.micro_batch, seed=self.seed,
            jit_encoder=self.jit_encoder, embed_cache=embed_cache)
        if fc is not None:
            eng.metrics = lc.metrics
            lc.store.attach_cache(fc)
        if lc.embed_cache is not None:
            self.embed_caches.append(lc.embed_cache)
        lc._rev = self._rev
        return view, lc

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    def owner(self, node_type: str, node_id: int) -> EmbeddingLifecycle:
        return self.shards[self.partitioner.shard_of(node_type, node_id)]

    # ---- bootstrap ------------------------------------------------------
    def bootstrap_from_graph(self, graph) -> None:
        self.engine.bootstrap_from_graph(graph)
        for ntype in NODE_TYPES:
            n = graph.num_nodes.get(ntype, 0)
            if not n:
                continue
            owners = self.partitioner.shard_array(
                np.full(n, NODE_TYPE_ID[ntype]), np.arange(n))
            for i in range(n):
                self.shards[owners[i]].registry.add((ntype, i))
        index_reverse_edges(graph, self._rev)

    # ---- event application ----------------------------------------------
    def _add_edge(self, src_type: str, src_id: int, dst_type: str,
                  dst_id: int) -> None:
        self.engine.add_edge(src_type, src_id, dst_type, dst_id)
        self._rev[(dst_type, int(dst_id))].add((src_type, int(src_id)))

    def _register(self, node_type: str, node_id: int) -> None:
        self.owner(node_type, node_id).register(node_type, node_id)

    def _put_feature(self, tid: int, nid: int, feat) -> None:
        # cluster writes route by owner through the ShardedEngine, bypassing
        # the shard views' CachedEngine wrappers — so tier-1 write-through
        # invalidation happens here, before the store mutates
        for fc in self.feature_caches:
            fc.invalidate(int(tid), int(nid))
        self.engine.put_feature(tid, nid, feat)

    def _apply_event(self, ev: Event):
        return apply_marketplace_event(
            ev, put_feature=self._put_feature, add_edge=self._add_edge,
            register=self._register)

    # ---- telemetry (DESIGN.md §15) --------------------------------------
    def attach_registry(self, registry) -> None:
        """Wire a :class:`~repro.obs.metrics.MetricsRegistry` into the event
        path: events/dirtied-keys/refresh counters increment natively as the
        cluster processes, and the event→re-rank lag histogram records every
        drain's staleness delta.  The registry state rides ``snapshot()``/
        ``restore()``, so a §12 rollback rewinds the counters WITH the data
        and the replay re-increments them exactly once (no double-count) —
        warm and cold restarts converge to the uninterrupted run's counts."""
        self.obs_registry = registry
        self._obs_events = registry.counter("serving.events_processed")
        self._obs_dirty = registry.counter("serving.keys_dirtied")
        self._obs_refreshes = registry.counter("serving.drain_refreshes")
        self._obs_lag = registry.histogram("serving.event_to_rerank_lag_s")
        # harvest cursor per shard into metrics.staleness — process-local
        # (deliberately NOT snapshotted: the staleness lists only grow, so
        # after a warm rollback the cursor still points at the replay
        # boundary, and a cold restart starts both at zero)
        self._obs_seen = [len(lc.metrics.staleness) for lc in self.shards]

    def _obs_harvest(self) -> None:
        for p, lc in enumerate(self.shards):
            st = lc.metrics.staleness
            new = len(st) - self._obs_seen[p]
            if new > 0:
                self._obs_lag.record_many(np.asarray(st[self._obs_seen[p]:]))
                self._obs_refreshes.inc(new)
                self._obs_seen[p] = len(st)

    def freshness_report(self, *, now: float | None = None) -> dict:
        """The §15 freshness surface over this cluster's live stores."""
        from repro.obs.freshness import freshness_report
        return freshness_report(self, now=now)

    def mark_dirty(self, node_type: str, node_id: int, t: float) -> int:
        """Closure over the shared reverse index, each key routed to its
        owner shard's queue; attached ResultCaches drop the dirty keys.

        Cache coherence is NOT a policy knob: caches are invalidated over
        the FULL K-hop dependency ball even when the recompute policy runs
        a cheaper radius (radius 0 makes the *store* eventually consistent
        by design, but a cache hit must always equal a fresh recompute —
        the router's bit-identity contract)."""
        lc0 = self.shards[0]
        touched = {(node_type, int(node_id))}
        keys = lc0.dirty_closure(touched)
        for key in keys:
            self.owner(*key).enqueue_dirty(key, t)
        if self.caches or self.embed_caches:
            full = (keys if lc0.policy.closure_radius is None else
                    lc0.dirty_closure(touched, radius=len(lc0.fanouts)))
            for cache in self.caches:
                cache.invalidate(full)
            for ec in self.embed_caches:
                for nt, ni in full:
                    ec.invalidate(NODE_TYPE_ID[nt], ni)
        if self.obs_registry is not None:
            self._obs_dirty.inc(len(keys))
        return len(keys)

    # ---- the serving loop ------------------------------------------------
    def ingest(self, *, upto_time: float | None = None,
               max_events: int = 10**9) -> int:
        """Apply pending events and dirty owners WITHOUT recomputing."""
        return poll_and_apply(self.topic, "sharded-nearline", self.micro_batch,
                              self._apply_event, self.mark_dirty,
                              upto_time=upto_time, max_events=max_events)

    def attach_mesh(self, fanout) -> None:
        """Route ``drain`` through a :class:`~repro.serving.mesh.MeshFanout`
        (DESIGN.md §13).  The host-sequential arm stays available as
        :meth:`drain_host` — it is the parity oracle, not dead code."""
        assert fanout.cluster is self
        self.mesh_fanout = fanout

    def drain(self, *, clock: float = 0.0, max_nodes: int | None = None) -> int:
        """Drain every shard's queue — one mesh dispatch per lock-step
        round when a :class:`MeshFanout` is attached, else the sequential
        per-shard loop.  Bits are identical either way (per-node
        deterministic recomputes; §13 parity gate)."""
        if self.mesh_fanout is not None:
            n = self.mesh_fanout.drain(clock=clock, max_nodes=max_nodes)
        else:
            n = self.drain_host(clock=clock, max_nodes=max_nodes)
        if self.obs_registry is not None:
            self._obs_harvest()
        return n

    def drain_host(self, *, clock: float = 0.0,
                   max_nodes: int | None = None) -> int:
        """The retained host-sequential oracle arm: each shard drains its
        own queue through its own jitted encoder (shard order is
        irrelevant: recomputes are per-node deterministic)."""
        return sum(lc.drain(clock=clock, max_nodes=max_nodes)
                   for lc in self.shards)

    def process(self, *, upto_time: float | None = None,
                max_batches: int = 10**9, clock: float | None = None) -> int:
        """Poll → apply → dirty → drain, in micro-batches (the P-shard
        instance of the one shared nearline loop)."""
        total = poll_and_process(
            self.topic, "sharded-nearline", self.micro_batch,
            self._apply_event, self.mark_dirty,
            lambda refresh: self.drain(clock=refresh),
            upto_time=upto_time, max_batches=max_batches, clock=clock)
        self.events_processed += total
        if self.obs_registry is not None and total:
            self._obs_events.inc(total)
        return total

    def publish_version(self, *, clock: float = 0.0) -> int:
        """Full sweep on every shard; all shard stores advance to the same
        version number (each sweeps only its owned registry)."""
        versions = {lc.publish_version(clock=clock) for lc in self.shards}
        assert len(versions) == 1, f"shard versions diverged: {versions}"
        return versions.pop()

    # ---- reads across shards --------------------------------------------
    def record(self, node_type: str, node_id: int):
        return self.owner(node_type, node_id).store.record(node_type, node_id)

    def live_embeddings(self) -> dict:
        """Union of the shard stores' live tables (the parity comparator:
        owners partition the key space, so the union is disjoint)."""
        out: dict = {}
        for lc in self.shards:
            out.update(lc.store.live_embeddings())
        return out

    def pending(self) -> int:
        return sum(lc.pending() for lc in self.shards)

    # ---- checkpoint / warm restart (DESIGN.md §12) ----------------------
    def snapshot(self) -> dict:
        """Everything a bit-identical warm restart needs (leg (a) of the
        resilience contract): per-shard engine state (rings + features),
        per-shard lifecycle state (store records + published tables +
        recompute queue + registry), the ONE shared reverse index, the
        partitioner's ownership map, the topic consumer offset (the replay
        point — the log itself is durable, Kafka-style), and the per-shard
        slab caches (a performance warm-start, never a bits concern)."""
        return {
            "config": {"micro_batch": self.micro_batch, "seed": self.seed,
                       "max_neighbors": self.max_neighbors,
                       "fanouts": self.fanouts,
                       "policy": (self.policy.closure_radius,
                                  self.policy.max_staleness_s,
                                  self.policy.type_order)},
            "partitioner": self.partitioner.snapshot(),
            "engine": self.engine.snapshot(),
            "shards": [lc.snapshot() for lc in self.shards],
            "rev": {k: set(v) for k, v in self._rev.items()},
            "topic_offset": self.topic.offsets.get("sharded-nearline", 0),
            "events_processed": self.events_processed,
            "feature_caches": [fc.snapshot() for fc in self.feature_caches],
            "embed_caches": [ec.snapshot() for ec in self.embed_caches],
            # §15: an attached registry's counters rewind WITH the data, so
            # rollback + replay re-derives them without double-counting
            "obs_registry": (self.obs_registry.snapshot()
                             if self.obs_registry is not None else None),
        }

    def restore(self, state: dict) -> None:
        """Apply a snapshot onto a freshly-constructed, un-bootstrapped
        cluster of the same shape (same P, same cache spec).  The caller
        re-attaches the durable topic log; the restored consumer offset
        makes the next ``process()`` replay exactly the event suffix."""
        assert len(state["shards"]) == len(self.shards), \
            "restore needs a cluster with the snapshot's shard count"
        self.engine.restore(state["engine"])
        for lc, st in zip(self.shards, state["shards"]):
            lc.restore(st)
        self._rev.clear()                    # shared object: mutate in place
        self._rev.update({k: set(v) for k, v in state["rev"].items()})
        self.topic.offsets["sharded-nearline"] = int(state["topic_offset"])
        self.events_processed = int(state["events_processed"])
        for fc, st in zip(self.feature_caches, state["feature_caches"]):
            fc.restore(st)
        for ec, st in zip(self.embed_caches, state["embed_caches"]):
            ec.restore(st)
        reg_state = state.get("obs_registry")
        if reg_state is not None and self.obs_registry is not None:
            self.obs_registry.restore(reg_state)

    # ---- elastic resharding (DESIGN.md §12, leg (b)) --------------------
    def add_shard(self) -> int:
        """Grow the cluster by one EMPTY shard (partitioner + engine + view
        + lifecycle); its store starts at the cluster's current version so
        ``publish_version`` stays in lock-step.  Returns the shard index."""
        q = self.partitioner.add_shard()
        self.engine.add_shard()
        view, lc = self._make_shard(q)
        lc.store.version = self.shards[0].store.version
        self.views.append(view)
        self.shards.append(lc)
        if self.obs_registry is not None:
            self._obs_seen.append(0)
        return q

    def reshard(self, moves: dict) -> dict:
        """Online migration of ``moves`` ({(ntype, nid): dst_shard}):
        drain the event backlog (ingest — dirt is state, not loss), flip the
        ownership map, migrate each key's records / published-table entries
        / ring rows / features / registry entry / pending dirt to its new
        owner, and invalidate the affected ResultCache ball.  Gated on the
        §12 parity contract: the post-reshard store union is asserted
        bit-identical to the pre-reshard union."""
        self.ingest()                        # quiesce: no un-applied events
        moves = {(nt, int(ni)): int(dst) for (nt, ni), dst in moves.items()}
        pre_union = self.live_embeddings()
        src_of = {key: self.partitioner.shard_of(*key) for key in moves}
        stats = {"moved": 0, "records": 0, "table_entries": 0,
                 "ring_rows": 0, "dirty": 0}
        for key in sorted(moves, key=lambda k: (NODE_TYPE_ID[k[0]], k[1])):
            src, dst = src_of[key], moves[key]
            if src == dst:
                continue
            self.partitioner.assign([key], dst)
            a, b = self.shards[src], self.shards[dst]
            nt, ni = key
            # registry + pending dirt move WITH the node
            if key in a.registry:
                a.registry.discard(key)
                b.registry.add(key)
            for k, prio, trig in a.queue.extract([key]):
                b.queue.push(k, prio, trig)
                stats["dirty"] += 1
            # live record + every published-table entry
            rec = a.store._d.pop(key, None)
            if rec is not None:
                b.store._d[key] = rec
                stats["records"] += 1
            for v, tab in a.store._tables.items():
                r = tab.pop(key, None)
                if r is not None:
                    b.store._tables.setdefault(v, {})[key] = r
                    stats["table_entries"] += 1
            # engine-side state: ring rows sourced at the node + features
            stats["ring_rows"] += self.engine.migrate_node(nt, ni, src, dst)
            stats["moved"] += 1
        # invalidate the affected ball: migration never changes bits, but
        # version-pinned ResultCache entries and per-shard slab rows for the
        # moved keys are conservatively dropped (same rule as mark_dirty)
        moved = set(moves)
        full = self.shards[0].dirty_closure(moved, radius=len(self.fanouts))
        for cache in self.caches:
            cache.invalidate(full)
        for nt, ni in full:
            tid = NODE_TYPE_ID[nt]
            for fc in self.feature_caches:
                fc.invalidate(tid, ni)
            for ec in self.embed_caches:
                ec.invalidate(tid, ni)
        from repro.core.embeddings import tables_bitwise_equal
        assert tables_bitwise_equal(pre_union, self.live_embeddings()), \
            "reshard parity violated: store union changed"
        return stats

    # ---- overload-control rollup (DESIGN.md §12, leg (c)) ---------------
    def fold_batcher_metrics(self, bm) -> None:
        """Fold one retired batcher's shed/degrade counters into the cluster
        rollup (serve_trace calls this per trace — each trace owns a fresh
        batcher, so counts are never double-folded)."""
        self.shed_queue_full += bm.shed_queue_full
        self.shed_deadline += bm.shed_deadline
        self.requests_degraded += bm.degraded

    def aggregate_metrics(self) -> LifecycleMetrics:
        """Cluster-wide counter roll-up (sums; queue-depth peak is a max)."""
        agg = LifecycleMetrics()
        agg.events_processed = self.events_processed
        agg.join_reads = self.engine.join_reads    # engine-wide, not per-shard
        for lc in self.shards:
            m = lc.metrics
            agg.batches += m.batches
            agg.nodes_refreshed += m.nodes_refreshed
            agg.encoder_seconds += m.encoder_seconds
            agg.join_seconds += m.join_seconds
            agg.encoder_traces += m.encoder_traces
            agg.staleness.extend(m.staleness)
            agg.sweeps += m.sweeps
            agg.queue_depth_peak = max(agg.queue_depth_peak, m.queue_depth_peak)
        agg.shed_queue_full = self.shed_queue_full
        agg.shed_deadline = self.shed_deadline
        agg.requests_degraded = self.requests_degraded
        agg.cache_hits = self.retired_cache_hits
        agg.cache_misses = self.retired_cache_misses
        for cache in self.caches:          # attached serving caches
            fh, fm = getattr(cache, "_folded", (0, 0))
            agg.cache_hits += cache.metrics.cache_hits - fh
            agg.cache_misses += cache.metrics.cache_misses - fm
        # slab counters roll up from the caches themselves (robust against
        # per-shard metrics objects being swapped by benches)
        for fc in self.feature_caches:
            agg.feature_cache_hits += fc.hits
            agg.feature_cache_misses += fc.misses
            agg.feature_cache_evictions += fc.evictions
        for ec in self.embed_caches:
            agg.embed_cache_hits += ec.hits
            agg.embed_cache_misses += ec.misses
            agg.embed_cache_evictions += ec.evictions
        return agg

    def detach_cache(self, cache) -> None:
        """Remove a cache from the invalidation fan-out, folding its not-
        yet-folded hit/miss counters into the cluster roll-up (a cache can
        attach/detach repeatedly — e.g. serve_trace replays — without
        double counting)."""
        fh, fm = getattr(cache, "_folded", (0, 0))
        self.retired_cache_hits += cache.metrics.cache_hits - fh
        self.retired_cache_misses += cache.metrics.cache_misses - fm
        cache._folded = (cache.metrics.cache_hits, cache.metrics.cache_misses)
        self.caches = [c for c in self.caches if c is not cache]

    def remote_fraction(self) -> float:
        """Fraction of query rows shards resolved off-home (the scatter-
        gather network cost a real deployment would pay)."""
        local = sum(v.local_rows for v in self.views)
        remote = sum(v.remote_rows for v in self.views)
        return remote / max(local + remote, 1)
