"""Online serving subsystem (DESIGN.md §10, §12): sharded graph
partitions, a dynamic micro-batching request server, an open-loop
load-generator harness with latency SLOs, and the resilience layer
(crash/warm-restart parity, elastic resharding, overload control)."""
from repro.serving.batcher import (BatchPolicy, BatcherMetrics,  # noqa: F401
                                   DynamicBatcher, OVERLOAD_POLICIES,
                                   ScoreRequest)
from repro.serving.cluster import ShardedNearline  # noqa: F401
from repro.serving.loadgen import (LoadConfig, LoadGenerator,  # noqa: F401
                                   SLOReport, serve_trace, simulate_open_loop)
from repro.serving.mesh import MeshFanout  # noqa: F401
from repro.serving.resilience import (FaultInjector,  # noqa: F401
                                      hottest_shard, load_cluster_checkpoint,
                                      merge_shards, restore_cluster,
                                      run_with_faults,
                                      save_cluster_checkpoint, split_shard)
from repro.serving.router import ResultCache, Router  # noqa: F401
