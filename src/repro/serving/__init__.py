"""Online serving subsystem (DESIGN.md §10): sharded graph partitions, a
dynamic micro-batching request server, and an open-loop load-generator
harness with latency SLOs."""
from repro.serving.batcher import (BatchPolicy, BatcherMetrics,  # noqa: F401
                                   DynamicBatcher, ScoreRequest)
from repro.serving.cluster import ShardedNearline  # noqa: F401
from repro.serving.loadgen import (LoadConfig, LoadGenerator,  # noqa: F401
                                   SLOReport, serve_trace, simulate_open_loop)
from repro.serving.router import ResultCache, Router  # noqa: F401
