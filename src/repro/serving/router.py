"""Shard-aware request routing: scatter-gather scoring with a version-
pinned result cache (DESIGN.md §10).

A scoring batch needs the CURRENT embedding of every node it touches.  The
:class:`Router` resolves them in three steps: (1) :class:`ResultCache`
lookup — entries are pinned to the owner store's in-flight version and are
dropped the moment the lifecycle dirty-set touches their node, so a cache
hit is always bit-identical to a fresh recompute; (2) misses scatter by
owner shard and recompute through each shard's existing bucketed jitted
``encode_nodes`` (zero new retraces — the batcher feeds the same pow2
buckets nearline drains use); (3) results gather back into request order
and each request scores ``member · jobsᵀ``.

Determinism: resolution never depends on cache state — a hit returns the
same bits a miss would recompute (per-node uniform slabs, row-wise
encoder), so the scatter-gather scores are bit-identical to a single-shard
``NearlineInference`` encoding the same nodes, for any P and any cache
hit pattern.  That is the §10 parity gate.
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np

from repro.core.embeddings import LifecycleMetrics
from repro.obs.trace import span as _obs_span


class ResultCache:
    """LRU embedding cache keyed by (node_type, id), version-pinned.

    Every entry records the owner store's in-flight version at compute
    time; a ``get`` with a different pin misses (and evicts — the entry can
    never become valid again).  The owning cluster invalidates dirty keys
    on every ``mark_dirty``, so entries only survive while a recompute of
    their node would return the same bits.  Hit/miss counters live in a
    shared :class:`LifecycleMetrics` (the same schema nearline reports).
    """

    def __init__(self, capacity: int = 4096,
                 metrics: LifecycleMetrics | None = None):
        self.capacity = int(capacity)
        self._d: OrderedDict = OrderedDict()    # key -> (emb, version)
        self.metrics = metrics if metrics is not None else LifecycleMetrics()
        self.invalidations = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key) -> bool:
        return key in self._d

    def get(self, key, *, version: int):
        ent = self._d.get(key)
        if ent is None or ent[1] != version:
            if ent is not None:                 # stale pin: drop for good
                del self._d[key]
            self.metrics.cache_misses += 1
            return None
        self._d.move_to_end(key)
        self.metrics.cache_hits += 1
        return ent[0]

    def put(self, key, emb: np.ndarray, *, version: int) -> None:
        self._d[key] = (emb, int(version))
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.evictions += 1

    def invalidate(self, keys) -> int:
        """Drop entries for dirty keys; returns #entries removed."""
        n = 0
        for key in keys:
            if self._d.pop(key, None) is not None:
                n += 1
        self.invalidations += n
        return n

    def hit_rate(self) -> float:
        m = self.metrics
        return m.cache_hits / max(m.cache_hits + m.cache_misses, 1)


class Router:
    """Scatter-gather scoring over a :class:`ShardedNearline` cluster."""

    def __init__(self, cluster, *, cache: ResultCache | None = None,
                 mesh=None):
        self.cluster = cluster
        self.cache = cache
        # device-collective fan-out (DESIGN.md §13): misses resolve through
        # the MeshFanout's all_to_all exchange instead of the per-owner host
        # loop below (which is retained as the parity oracle).  Off-mesh the
        # fanout itself degrades to that same host loop, so bits never
        # depend on which arm ran.
        self.mesh = mesh
        self.stale_served_keys = 0      # keys served from stale records (§12)
        self.stale_fallback_keys = 0    # degraded keys with no record: fresh
        self.degraded_requests = 0
        if cache is not None and not any(c is cache for c in cluster.caches):
            cluster.caches.append(cache)        # dirty-set invalidation hook

    def close(self) -> None:
        """Detach the cache from the cluster's invalidation fan-out (its
        hit/miss counters fold into the cluster roll-up).  Call when
        retiring a router on a long-lived cluster — otherwise every
        mark_dirty keeps invalidating (and retaining) the dead cache.  The
        cache stays readable (counters, entries); it just stops receiving
        invalidations, so do not resolve through it afterwards."""
        if self.cache is not None:
            self.cluster.detach_cache(self.cache)

    def _inflight_version(self, key) -> int:
        # the version the owner's next write would carry (the cache pin)
        return self.cluster.owner(*key).store.version + 1

    def resolve_embeddings(self, keys) -> dict:
        """{key: emb} for a deduped key list: cache hits + per-owner-shard
        recompute of the misses through the shard's bucketed encoder."""
        out: dict = {}
        misses: list = []
        with _obs_span("router.cache_lookup") as sp:
            for key in keys:
                emb = (self.cache.get(key, version=self._inflight_version(key))
                       if self.cache is not None else None)
                if emb is None:
                    misses.append(key)
                else:
                    out[key] = emb
            sp.set("keys", len(out) + len(misses))
            sp.set("hits", len(out))
        if self.mesh is not None:
            resolved = self.mesh.resolve(misses)
            for key in misses:
                out[key] = resolved[key]
                if self.cache is not None:
                    self.cache.put(key, resolved[key],
                                   version=self._inflight_version(key))
            return out
        # host-sequential oracle arm: group by owner, one bucketed encode
        # per owner shard, scatter back into request order
        with _obs_span("router.exchange") as sp:
            sp.set("keys", len(misses))
            by_shard: dict = {}
            for key in misses:
                by_shard.setdefault(self.cluster.partitioner.shard_of(*key),
                                    []).append(key)
            for p, shard_keys in sorted(by_shard.items()):
                emb = self.cluster.shards[p].encode_nodes(shard_keys)
                for r, key in enumerate(shard_keys):
                    out[key] = emb[r]
                    if self.cache is not None:
                        self.cache.put(key, emb[r],
                                       version=self._inflight_version(key))
        return out

    def resolve_stale(self, keys) -> dict:
        """Degrade-to-cached-embedding mode (§12): serve each key's LAST
        materialized record — bits of a previous recompute, pinned to the
        version it was computed toward, possibly stale w.r.t. pending dirt —
        without touching the encoder.  Keys with no record yet (cold nodes)
        fall back to a fresh resolve: degradation trades freshness for
        latency, never completeness."""
        out: dict = {}
        cold: list = []
        for key in keys:
            rec = self.cluster.record(*key)
            if rec is None:
                cold.append(key)
            else:
                out[key] = rec.emb
        self.stale_served_keys += len(out)
        self.stale_fallback_keys += len(cold)
        if cold:
            out.update(self.resolve_embeddings(cold))
        return out

    def score_batch(self, requests) -> list:
        """Score a coalesced request batch; returns one [len(job_ids)]
        score vector per request (dot products in embedding space).
        Degraded requests resolve through the stale-record path; a key
        needed by BOTH a fresh and a degraded request is resolved fresh
        (the fresh requester's contract wins, and fresher never hurts the
        degraded one)."""
        with _obs_span("router.score_batch") as sp:
            fresh_keys: dict = {}
            stale_keys: dict = {}
            for req in requests:
                sink = stale_keys if req.degraded else fresh_keys
                for key in req.keys():
                    sink[key] = None
            self.degraded_requests += sum(1 for r in requests if r.degraded)
            emb = self.resolve_embeddings(list(fresh_keys))
            stale_only = [k for k in stale_keys if k not in emb]
            if stale_only:
                emb.update(self.resolve_stale(stale_only))
            scores = []
            for req in requests:
                m = emb[("member", int(req.member_id))]
                J = np.stack([emb[("job", int(j))] for j in req.job_ids])
                scores.append(J @ m)
            sp.set("requests", len(requests))
        return scores
