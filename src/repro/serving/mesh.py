"""Device-parallel shard fan-out: the serving tier on a jax mesh
(DESIGN.md §13).

:class:`ShardedNearline` models P shards as P Python-level encoder
replicas — correct, but every shard encodes sequentially on one device and
the router's scatter-gather is host-side Python grouping.
:class:`MeshFanout` maps the shard axis onto a ``("shards",)`` jax mesh
(one device per shard, :func:`repro.parallel.shards_mesh`):

  * **block encode** — the P per-shard tiles stack into one ``[P, B, ...]``
    block (leading axis sharded over "shards") and a single
    ``shard_map``-ped jit call runs P encoder replicas concurrently; the
    lock-step :meth:`drain` rides this to refresh all shards per round in
    ONE device dispatch instead of P.
  * **exchange encode** — the router's miss fan-out becomes a device
    collective: misses are laned round-robin over P requesters, grouped by
    owner into padded ``[P_req, K]`` row blocks, owner devices encode
    their blocks, and one ``all_to_all`` returns each requester lane its
    rows (:meth:`resolve`) — no per-owner host loop.

Parity contract (the §13 oracle-arm discipline): tiles are built on the
host by each shard's OWN ``tile_fn`` over REAL keys only — identical rows,
identical per-node uniform slabs, identical ``ShardView`` remote-row
accounting as the sequential path — then scattered into zero-padded block
positions (all-masked pad rows encode to garbage that is sliced off,
exactly like ``pad_tile``).  The encoder is row-wise, so block bits equal
oracle bits for any P and any lane assignment.  The host-sequential arm is
RETAINED (``ShardedNearline.drain_host``, the router's per-owner loop) and
every mesh path falls back to it when the backend has fewer devices than
shards (``on_mesh == False``) — the default single-device pytest regime
exercises the same public API with trivially-identical bits, while CPU CI
forces real devices via ``XLA_FLAGS=--xla_force_host_platform_device_count``.

What the mesh path does NOT do: consult the per-shard tier-2 embed caches
(§11) — a block encode is one device program, so resident rows are
recomputed rather than gathered.  Bits are unaffected (a cache hit equals
a fresh recompute by contract); only the hit counters differ.
"""
from __future__ import annotations

import time as _time

import jax
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import encoder as enc
from repro.core.engine import bucket_pow2, pad_tile, zero_like_tile
from repro.obs.trace import span as _obs_span
from repro.parallel import gnn_param_pspecs, gnn_tile_block_pspecs, shards_mesh


class MeshFanout:
    """P per-shard encoder replicas on a ``("shards",)`` device mesh.

    Construction places the (replicated) encoder params on every mesh
    device ONCE — per-call work is one sharded block placement + one jit
    dispatch, which is where the fan-out wins its wall-clock: the
    sequential arm pays P separate dispatch/sync/host-copy round trips per
    round, the mesh arm pays one.
    """

    def __init__(self, cluster):
        self.cluster = cluster
        self.num_shards = cluster.num_shards
        self.mesh = shards_mesh(self.num_shards)
        self.on_mesh = self.mesh is not None
        self.block_rounds = 0               # mesh-dispatch counters
        self.exchange_rounds = 0
        if not self.on_mesh:
            return
        cfg = cluster.cfg
        num_hops = len(cluster.fanouts)
        param_specs = gnn_param_pspecs(cluster.params)
        tile_specs = gnn_tile_block_pspecs(num_hops)
        self._block_sharding = jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), tile_specs,
            is_leaf=lambda x: isinstance(x, P))
        # replicate params across the mesh once — NOT per call (a device-0
        # committed tree would be re-broadcast on every dispatch)
        rep = jax.tree.map(lambda s: NamedSharding(self.mesh, s), param_specs,
                           is_leaf=lambda x: isinstance(x, P))
        self._params = jax.tree.map(jax.device_put, cluster.params, rep)

        def _encode_one(params, block):
            tile = jax.tree.map(lambda x: x[0], block)   # [1, B, ...] -> [B, ...]
            return enc.encoder_apply(params, cfg, tile)[None]

        self._encode_block = jax.jit(shard_map(
            _encode_one, mesh=self.mesh, in_specs=(param_specs, tile_specs),
            out_specs=P("shards"), check_rep=False))

        def _exchange_one(params, block):
            # owner device: encode my [P_req*K] rows, lane-major
            tile = jax.tree.map(lambda x: x[0], block)
            rows = enc.encoder_apply(params, cfg, tile)       # [P_req*K, e]
            rows = rows.reshape(self.num_shards, -1, rows.shape[-1])
            # the collective: chunk r (my rows for requester r) goes to
            # device r; I receive every owner's chunk for MY lane
            rows = jax.lax.all_to_all(rows, "shards", split_axis=0,
                                      concat_axis=0, tiled=True)
            return rows[None]                                 # [1, P_own, K, e]

        self._exchange_block = jax.jit(shard_map(
            _exchange_one, mesh=self.mesh, in_specs=(param_specs, tile_specs),
            out_specs=P("shards"), check_rep=False))

    # ---- block plumbing --------------------------------------------------
    def _put_block(self, tiles):
        """Stack P same-shape host tiles into a [P, B, ...] block placed
        directly with the "shards" sharding (device p gets slice p — no
        device-0 staging copy)."""
        block = jax.tree.map(lambda *xs: np.stack(xs), *tiles)
        return jax.tree.map(jax.device_put, block, self._block_sharding)

    def encode_block(self, tiles) -> np.ndarray:
        """One mesh dispatch over P padded per-shard tiles -> [P, B, e]
        host rows.  All tiles must share the same (bucketed) batch size."""
        assert self.on_mesh and len(tiles) == self.num_shards
        self.block_rounds += 1
        with _obs_span("mesh.block_encode") as sp:
            sp.set("shards", self.num_shards)
            return np.asarray(
                self._encode_block(self._params, self._put_block(tiles)))

    def encode_block_host(self, tiles) -> np.ndarray:
        """The sequential oracle arm of :meth:`encode_block`: the same P
        tiles through each shard's own bucketed jitted encoder, one
        dispatch + sync per shard (what the bench's speedup row divides
        by, and what parity asserts against)."""
        from repro.core.linksage import _to_jnp
        rows = [np.asarray(lc._encode(lc.params, _to_jnp(t)))
                for lc, t in zip(self.cluster.shards, tiles)]
        return np.stack(rows)

    # ---- lock-step drain (the nearline path) -----------------------------
    def drain(self, *, clock: float = 0.0, max_nodes: int | None = None) -> int:
        """Drain every shard's recompute queue in lock-step rounds: each
        round pops one micro-batch per shard, builds the per-shard tiles on
        the host (each shard's own ``tile_fn`` — accounting and bits
        identical to the sequential arm), pads them to one shared pow2
        bucket, and refreshes all shards with ONE mesh dispatch.  Per-shard
        pop order matches ``EmbeddingLifecycle.drain`` exactly, so the
        resulting stores are bit-identical to ``drain_host``."""
        cluster = self.cluster
        if not self.on_mesh:
            return cluster.drain_host(clock=clock, max_nodes=max_nodes)
        shards = cluster.shards
        for lc in shards:
            lc.enqueue_stale(clock)
            lc.metrics.queue_depth_peak = max(lc.metrics.queue_depth_peak,
                                              len(lc.queue))
        totals = [0] * self.num_shards
        while True:
            batches = []
            for p, lc in enumerate(shards):
                room = lc.micro_batch if max_nodes is None else min(
                    lc.micro_batch, max_nodes - totals[p])
                batches.append(lc.queue.pop_batch(room) if room > 0 else [])
            if not any(batches):
                break
            tiles, proto = [None] * self.num_shards, None
            for p, batch in enumerate(batches):
                if not batch:
                    continue
                lc = shards[p]
                t0 = _time.perf_counter()
                tiles[p] = lc.tile_fn([k for k, _ in batch])
                lc.metrics.join_seconds += _time.perf_counter() - t0
                proto = tiles[p]
            B = bucket_pow2(max(len(b) for b in batches))
            for p in range(self.num_shards):
                if tiles[p] is None:        # idle shard: all-masked zero tile
                    tiles[p] = zero_like_tile(proto, B)
                else:
                    tiles[p] = pad_tile(tiles[p], B)
            t0 = _time.perf_counter()
            rows = self.encode_block(tiles)               # [P, B, e]
            enc_s = _time.perf_counter() - t0
            active = [p for p, b in enumerate(batches) if b]
            for p in active:
                lc = shards[p]
                lc.metrics.encoder_seconds += enc_s / len(active)
                lc.metrics.batches += 1
                lc.metrics.nodes_refreshed += len(batches[p])
                for r, ((nt, ni), trig) in enumerate(batches[p]):
                    lc.store.put_embedding(nt, ni, rows[p, r], clock,
                                           version=lc.store.version + 1)
                    lc.metrics.staleness.append(clock - trig)
                totals[p] += len(batches[p])
        return sum(totals)

    # ---- all_to_all exchange (the router path) ---------------------------
    def resolve(self, keys) -> dict:
        """{key: emb} for a deduped miss list via the device collective.

        Host plan: lane keys round-robin over P requesters, group each
        lane by owner shard (ONE vectorized ``shard_array`` call), build
        each owner's tile over its real keys (lane-major order), scatter
        the rows into a zero [P_req*K] block (K = shared pow2 bucket of
        the largest lane×owner group).  Device execute: owners encode,
        ``all_to_all`` transposes owner-major rows into requester-major,
        one gather back to host.  Off-mesh this IS the sequential oracle:
        per-owner ``encode_nodes`` in shard order."""
        from repro.core.graph import NODE_TYPE_ID
        cluster = self.cluster
        keys = list(keys)
        if not keys:
            return {}
        if not self.on_mesh:
            # the host-sequential oracle arm wears the router.exchange span:
            # same stage, same place in the span tree, different executor
            with _obs_span("router.exchange") as sp:
                sp.set("keys", len(keys))
                out: dict = {}
                by_shard: dict = {}
                for key in keys:
                    by_shard.setdefault(cluster.partitioner.shard_of(*key),
                                        []).append(key)
                for p, shard_keys in sorted(by_shard.items()):
                    emb = cluster.shards[p].encode_nodes(shard_keys)
                    for r, key in enumerate(shard_keys):
                        out[key] = emb[r]
                return out
        Pn = self.num_shards
        self.exchange_rounds += 1
        tids = np.array([NODE_TYPE_ID[t] for t, _ in keys], np.int64)
        nids = np.array([int(i) for _, i in keys], np.int64)
        owners = cluster.partitioner.shard_array(tids, nids)
        groups = [[[] for _ in range(Pn)] for _ in range(Pn)]
        for i, key in enumerate(keys):
            groups[i % Pn][int(owners[i])].append(key)
        K = bucket_pow2(max(len(g) for lane in groups for g in lane))
        tiles, proto = [None] * Pn, None
        for p in range(Pn):
            lane_keys = [k for r in range(Pn) for k in groups[r][p]]
            if not lane_keys:
                continue
            lc = cluster.shards[p]
            t0 = _time.perf_counter()
            tile = lc.tile_fn(lane_keys)
            lc.metrics.join_seconds += _time.perf_counter() - t0
            lc.metrics.batches += 1
            lc.metrics.nodes_refreshed += len(lane_keys)
            # scatter real rows into the [P_req*K] lane-major block
            pos = []
            for r in range(Pn):
                pos.extend(range(r * K, r * K + len(groups[r][p])))
            pos = np.array(pos, np.int64)

            def scatter(x):
                out = np.zeros((Pn * K,) + x.shape[1:], x.dtype)
                out[pos] = x
                return out

            tiles[p] = jax.tree.map(scatter, tile)
            proto = tiles[p]
        for p in range(Pn):
            if tiles[p] is None:
                tiles[p] = zero_like_tile(proto, Pn * K)
        t0 = _time.perf_counter()
        with _obs_span("mesh.exchange") as sp:
            sp.set("keys", len(keys))
            sp.set("bucket", K)
            exchanged = np.asarray(
                self._exchange_block(self._params, self._put_block(tiles)))
        enc_s = _time.perf_counter() - t0
        active = [p for p in range(Pn)
                  if any(groups[r][p] for r in range(Pn))]
        for p in active:
            cluster.shards[p].metrics.encoder_seconds += enc_s / len(active)
        # exchanged[r, p, j] = owner p's row j for requester lane r
        out = {}
        for r in range(Pn):
            for p in range(Pn):
                for j, key in enumerate(groups[r][p]):
                    out[key] = exchanged[r, p, j]
        return out
