"""Open-loop Poisson load generation + latency-SLO accounting
(DESIGN.md §10, §12).

The generator draws request arrivals from a Poisson process (exponential
inter-arrival gaps, deterministic per seed) and replays them through a
:class:`DynamicBatcher` + :class:`Router` on a SIMULATED clock — open
loop: arrivals never wait for completions, so queueing delay is visible
(the closed-loop mistake of measuring latency at the server's own pace
hides exactly the tail the SLO cares about).

Overload shapes (§12): ``zipf`` skews key popularity power-law (the hot-
member/hot-job pattern the Signal Integration System paper motivates), and
``burst_*`` superimposes a flash crowd — a rate multiplier over a time
window — on the base arrival process.  Both are deterministic per seed,
and both default off with the original draw sequence bit-for-bit intact.

One simulated inference worker serves batches.  A batch fires at
``max(policy trigger, worker-free time)`` — a full batch as soon as the
worker can take it, a partial one at its deadline — and its service time
is the MEASURED wall time of the real scatter-gather scoring call (or a
caller-fixed constant — or callable, for modeled degraded service — for
deterministic tests), mapped 1:1 into simulated seconds.  Per-request
latency = completion − arrival; the report carries throughput,
p50/p95/p99, SLO-violation rate (shed requests count as violations),
per-reason shed counts, the staleness-served fraction, and occupancy.
"""
from __future__ import annotations

import time as _time
from dataclasses import dataclass, field

import numpy as np

from repro.obs import trace as _obs
from repro.obs.metrics import Histogram
from repro.serving.batcher import BatchPolicy, DynamicBatcher, ScoreRequest

_INF = float("inf")


@dataclass(frozen=True)
class LoadConfig:
    rate_hz: float = 200.0         # open-loop Poisson arrival rate
    num_requests: int = 256
    candidates: int = 8            # jobs scored per request
    seed: int = 0
    zipf: float | None = None      # power-law key popularity (None = uniform)
    burst_at_s: float | None = None    # flash crowd: window start (None = off)
    burst_duration_s: float = 0.0      # window length
    burst_factor: float = 1.0          # rate multiplier inside the window


class LoadGenerator:
    """Deterministic Poisson request trace over a member/job id space."""

    def __init__(self, cfg: LoadConfig, *, num_members: int, num_jobs: int):
        self.cfg = cfg
        self.num_members = num_members
        self.num_jobs = num_jobs

    def _skewed(self, rng, num: int):
        # same rank -> permuted-id scheme as marketplace_event_stream: the
        # hot set is a random subset, not the low ids bootstrap favors
        perm = rng.permutation(num)

        def draw(k):
            out = np.empty(k, np.int64)
            for i in range(k):
                while True:
                    r = int(rng.zipf(self.cfg.zipf))
                    if r <= num:
                        out[i] = perm[r - 1]
                        break
            return out
        return draw

    def requests(self) -> list:
        c = self.cfg
        rng = np.random.default_rng((c.seed, 0x10AD))
        if c.burst_at_s is None:
            times = np.cumsum(rng.exponential(1.0 / c.rate_hz, c.num_requests))
        else:
            # flash crowd: inter-arrival gaps shrink by burst_factor while
            # the arrival lands inside the window (rate-modulated Poisson)
            end = c.burst_at_s + c.burst_duration_s
            gaps = rng.exponential(1.0 / c.rate_hz, c.num_requests)
            times = np.empty(c.num_requests)
            t = 0.0
            for i, g in enumerate(gaps):
                t += g / (c.burst_factor if c.burst_at_s <= t < end else 1.0)
                times[i] = t
        if c.zipf is None:
            members = rng.integers(0, self.num_members, c.num_requests)
            jobs = rng.integers(0, self.num_jobs,
                                (c.num_requests, c.candidates))
        else:
            members = self._skewed(rng, self.num_members)(c.num_requests)
            draw_jobs = self._skewed(rng, self.num_jobs)
            jobs = np.stack([draw_jobs(c.candidates)
                             for _ in range(c.num_requests)])
        return [ScoreRequest(time=float(times[i]), member_id=int(members[i]),
                             job_ids=tuple(int(j) for j in jobs[i]))
                for i in range(c.num_requests)]


@dataclass
class SLOReport:
    completed: int = 0
    shed: int = 0
    shed_queue_full: int = 0       # per-reason shed split (§12)
    shed_deadline: int = 0
    degraded: int = 0              # admitted for stale-record serving
    degraded_frac: float = 0.0     # staleness-served fraction of admissions
    batches: int = 0
    throughput_rps: float = 0.0    # completed / simulated makespan
    latency_p50_ms: float = 0.0
    latency_p95_ms: float = 0.0
    latency_p99_ms: float = 0.0
    slo_ms: float = 0.0
    slo_violation_rate: float = 0.0
    occupancy_mean: float = 0.0
    latencies_s: list = field(default_factory=list, repr=False)

    def summary(self) -> dict:
        return {k: getattr(self, k) for k in
                ("completed", "shed", "shed_queue_full", "shed_deadline",
                 "degraded", "degraded_frac", "batches", "throughput_rps",
                 "latency_p50_ms", "latency_p95_ms", "latency_p99_ms",
                 "slo_ms", "slo_violation_rate", "occupancy_mean")}


def simulate_open_loop(router, batcher: DynamicBatcher, requests, *,
                       slo_ms: float = 50.0,
                       service_s=None) -> SLOReport:
    """Event-driven replay of an arrival trace through batcher + router.

    The loop interleaves two event kinds in simulated-time order: request
    arrivals (enqueue) and batch firings (dequeue + score).  A batch fires
    at ``max(trigger, worker_free)`` where the policy trigger is "full →
    now, partial → oldest + max_wait"; firing before the next arrival
    keeps causality (a batch never contains a request that arrived after
    it fired).  ``service_s`` fixes the per-batch service time for
    deterministic tests — a float is a constant, a callable is invoked as
    ``service_s(batch)`` (degraded requests are cheap: no encoder pass);
    None measures the real scoring call.
    """
    requests = sorted(requests, key=lambda r: r.time)
    lat: list = []
    m = batcher.metrics
    occ0 = len(m.occupancy)
    # report deltas on reused batchers
    shed0, qf0, dl0, dg0 = m.shed, m.shed_queue_full, m.shed_deadline, m.degraded
    free = 0.0
    i = 0

    def fire(t: float) -> None:
        nonlocal free
        start = max(t, free)
        batch = batcher.pop_batch(now=start)
        if not batch:
            return
        if _obs.enabled():
            # the simulated-time lane (§15 dual-clock rule): queue waits and
            # batch service live on the load generator's event clock, so
            # they enter via explicit-timestamp emit, never the code clock
            for r in batch:
                _obs.emit("batcher.queue_wait", r.time, start)
        if service_s is None:
            w0 = _time.perf_counter()
            router.score_batch(batch)
            svc = _time.perf_counter() - w0
        else:
            router.score_batch(batch)
            svc = service_s(batch) if callable(service_s) else service_s
        done = start + svc
        free = done
        if _obs.enabled():
            _obs.emit("serve.batch", start, done, requests=len(batch))
        lat.extend(done - r.time for r in batch)

    while i < len(requests) or len(batcher):
        nxt = requests[i].time if i < len(requests) else _INF
        trig = batcher.trigger_time()
        if trig is not None and max(trig, free) <= nxt:
            fire(max(trig, free))           # includes the final partial drain
            continue
        batcher.submit(requests[i])
        i += 1

    shed = m.shed - shed0
    degraded = m.degraded - dg0
    lat_arr = np.array(lat) if lat else np.array([0.0])
    first = requests[0].time if requests else 0.0
    makespan = max(free - first, 1e-9)
    slo_s = slo_ms * 1e-3
    violations = int((lat_arr > slo_s).sum()) + shed
    occ = m.occupancy[occ0:]
    # p50/p95/p99 through the shared log-bucket histogram (§15): exact
    # semantics documented on Histogram.quantile — within a factor of
    # √base (~4.9%) of the nearest-rank sample, clamped to exact min/max.
    # The SLO-violation count above stays exact (raw sample comparison).
    hist = Histogram()
    hist.record_many(lat_arr)
    return SLOReport(
        completed=len(lat),
        shed=shed,
        shed_queue_full=m.shed_queue_full - qf0,
        shed_deadline=m.shed_deadline - dl0,
        degraded=degraded,
        degraded_frac=degraded / max(len(lat), 1),
        batches=len(occ),
        throughput_rps=len(lat) / makespan,
        latency_p50_ms=hist.quantile(0.50) * 1e3,
        latency_p95_ms=hist.quantile(0.95) * 1e3,
        latency_p99_ms=hist.quantile(0.99) * 1e3,
        slo_ms=slo_ms,
        slo_violation_rate=violations / max(len(lat) + shed, 1),
        occupancy_mean=float(np.mean(occ)) if occ else 0.0,
        latencies_s=lat,
    )


def serve_trace(cluster, requests, *, policy: BatchPolicy | None = None,
                cache=None, slo_ms: float = 50.0,
                service_s=None, mesh=None):
    """One-call harness: build batcher + router over a cluster, replay a
    trace, return (report, batcher, router).  Teardown runs in ``finally``:
    the router is closed (its cache detaches from the cluster's
    invalidation fan-out) and the batcher's overload counters fold into the
    cluster rollup even when a request raises mid-trace — an exception must
    not leak a retired cache into the lifecycle's fan-out."""
    from repro.serving.router import Router
    batcher = DynamicBatcher(policy)
    router = Router(cluster, cache=cache, mesh=mesh)
    try:
        report = simulate_open_loop(router, batcher, requests, slo_ms=slo_ms,
                                    service_s=service_s)
    finally:
        router.close()
        cluster.fold_batcher_metrics(batcher.metrics)
    return report, batcher, router
