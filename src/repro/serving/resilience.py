"""Resilience for the serving tier: crash/warm-restart parity, elastic
resharding, and deterministic fault injection (DESIGN.md §12).

The serving tier is state it cannot afford to lose bit-exactly: per-shard
embedding records and published tables, recompute-queue dirt, neighbor
rings, and the topic consumer offset.  This module closes the loop around
the per-component ``snapshot()``/``restore()`` methods:

  FaultInjector            — deterministic kill schedule over the nearline
                             batch clock (reproducible crashes, no wall time)
  save/load_cluster_checkpoint — disk round-trip of a cluster snapshot via
                             the existing ``repro.checkpoint`` step layout
  restore_cluster          — cold-start a fresh ShardedNearline FROM a
                             snapshot (shape from the snapshot's own config,
                             weights from the caller — params are training
                             artifacts with their own checkpoint lane)
  run_with_faults          — the recovery protocol: process → checkpoint on
                             a cadence → on kill, roll back to the last
                             checkpoint and replay the event suffix
  split_shard / merge_shards / hottest_shard — elastic resharding moves
                             built on ``ShardedNearline.reshard``

Recovery model (leg (a)): the event log is durable (Kafka-style) and the
snapshot stores the consumer offset, so a crash loses only in-memory state
SINCE the last checkpoint — restore rewinds the consumer and the next
``process()`` replays exactly the lost suffix.  A shard kill takes down the
whole process group (shards share the closure index and the composite
engine), so recovery is cluster-level rollback — coarse-grained, but the
parity gate is exact: because replay applies the same events through the
same deterministic pipeline (per-node uniform slabs, full-drain regime),
the recovered store union and every subsequent router read are
BIT-IDENTICAL to an uninterrupted run, for any kill offset and any P.
"""
from __future__ import annotations

import numpy as np

from repro.checkpoint import latest_step, load_state, save_state
from repro.core.embeddings import StalenessPolicy
from repro.core.graph import NODE_TYPE_ID
from repro.core.partition import GraphPartitioner
from repro.serving.cluster import ShardedNearline

CONSUMER = "sharded-nearline"
_CKPT_NAME = "cluster"


class FaultInjector:
    """Deterministic kill schedule over the harness's batch clock.

    ``kill_at`` holds tick indices (one tick = one attempted nearline
    micro-batch, counted monotonically across crashes and replays); each
    fires exactly once.  ``shards`` records WHICH shard the fault targets —
    descriptive under the cluster-level recovery model above, where any
    shard loss takes the process group down — so the kill log reads like an
    incident report."""

    def __init__(self, kill_at=(), shards=(0,)):
        self.kill_at = frozenset(int(k) for k in kill_at)
        self.shards = tuple(int(s) for s in shards)
        self.ticks = 0
        self.kills: list = []      # tick indices that actually fired

    def tick(self) -> bool:
        """Advance the batch clock; True = a crash fires at this tick."""
        t = self.ticks
        self.ticks += 1
        if t in self.kill_at:
            self.kills.append(t)
            return True
        return False


# ---- checkpoint round-trip (disk) ---------------------------------------

def save_cluster_checkpoint(cluster: ShardedNearline, directory: str,
                            step: int) -> str:
    """Persist a full cluster snapshot under ``<dir>/step_NNNNNN/`` (the
    same step layout model checkpoints use, so serving state and weights
    can share a checkpoint root)."""
    return save_state(directory, step, cluster.snapshot(), name=_CKPT_NAME)


def load_cluster_checkpoint(directory: str, step: int | None = None) -> dict:
    """Load a cluster snapshot; ``step=None`` picks the latest step dir."""
    if step is None:
        step = latest_step(directory)
        assert step is not None, f"no checkpoints under {directory}"
    return load_state(directory, step, name=_CKPT_NAME)


def restore_cluster(state: dict, *, cfg, params, topic=None,
                    jit_encoder: bool = True, feature_cache=None,
                    embed_cache=None, registry=None) -> ShardedNearline:
    """Cold-start a cluster FROM a snapshot: shape (P, fanouts, policy,
    micro-batch, seed) comes from the snapshot's own config record, the
    ownership map from the partitioner snapshot, and all mutable state from
    ``restore``.  ``params`` are supplied by the caller (encoder weights
    live in the pytree checkpoint lane, not the serving snapshot); pass the
    durable ``topic`` to resume consumption — the restored offset makes the
    next ``process()`` replay exactly the post-checkpoint suffix.  Cache
    specs must match the crashed cluster's for the slab warm-start to
    apply.  ``registry`` (a §15 MetricsRegistry) attaches BEFORE the
    restore, so a snapshot taken with telemetry enabled re-seeds the new
    registry's counters at the checkpoint values — the replayed suffix then
    increments them to exactly the uninterrupted run's counts."""
    c = state["config"]
    radius, max_stale, type_order = c["policy"]
    cluster = ShardedNearline(
        cfg, params, GraphPartitioner.from_snapshot(state["partitioner"]),
        fanouts=c["fanouts"], micro_batch=c["micro_batch"],
        max_neighbors=c["max_neighbors"], seed=c["seed"],
        policy=StalenessPolicy(closure_radius=radius,
                               max_staleness_s=max_stale,
                               type_order=tuple(type_order)),
        jit_encoder=jit_encoder, feature_cache=feature_cache,
        embed_cache=embed_cache)
    if topic is not None:
        cluster.topic = topic
    if registry is not None:
        cluster.attach_registry(registry)
    cluster.restore(state)
    return cluster


# ---- the recovery protocol ----------------------------------------------

def run_with_faults(cluster: ShardedNearline, *,
                    injector: FaultInjector | None = None,
                    checkpoint_every: int = 2, directory: str | None = None,
                    clock: float | None = None) -> dict:
    """Drain the topic one micro-batch at a time under a crash schedule.

    Every ``checkpoint_every`` completed batches the cluster snapshots
    (in-memory, or to ``directory`` as step dirs when given — the disk
    round-trip exercises the pickle/npy path).  When the injector fires,
    ALL in-memory state is considered lost: the cluster restores from the
    last checkpoint and the rewound consumer offset replays the suffix.
    Returns counters: batches completed (including replays), checkpoints
    taken, kills fired, and batches replayed after crashes."""
    stats = {"batches": 0, "checkpoints": 0, "kills": 0, "replayed": 0}

    def take_checkpoint():
        snap = cluster.snapshot()
        if directory is not None:
            save_state(directory, stats["checkpoints"], snap, name=_CKPT_NAME)
        stats["checkpoints"] += 1
        return snap

    last = take_checkpoint()                 # batch-0 baseline
    max_offset = int(last["topic_offset"])
    while cluster.topic.lag(CONSUMER) > 0:
        if injector is not None and injector.tick():
            if directory is not None:
                last = load_state(directory, stats["checkpoints"] - 1,
                                  name=_CKPT_NAME)
            cluster.restore(last)
            stats["kills"] += 1
            continue
        done = cluster.process(max_batches=1, clock=clock)
        if done == 0:
            break
        stats["batches"] += 1
        # progress made before a crash and redone after = duplicate work
        offset = int(cluster.topic.offsets[CONSUMER])
        if offset <= max_offset:
            stats["replayed"] += 1
        else:
            max_offset = offset
        if stats["batches"] % max(checkpoint_every, 1) == 0:
            last = take_checkpoint()
    return stats


# ---- elastic resharding moves (leg (b)) ---------------------------------

def _owned_sorted(cluster: ShardedNearline, p: int) -> list:
    return sorted(cluster.shards[p].registry,
                  key=lambda k: (NODE_TYPE_ID[k[0]], k[1]))


def hottest_shard(cluster: ShardedNearline) -> int:
    """The shard owning the most registered nodes (the split candidate —
    registry size is the steady-state recompute and serving load proxy)."""
    return int(np.argmax([len(lc.registry) for lc in cluster.shards]))


def split_shard(cluster: ShardedNearline, p: int | None = None) -> dict:
    """Online split: grow the cluster by one shard and migrate every OTHER
    owned key (sorted order — deterministic halves) off shard ``p``
    (default: the hottest).  Runs through ``reshard``'s drain → flip →
    migrate → invalidate sequence and its bit-parity gate."""
    if p is None:
        p = hottest_shard(cluster)
    q = cluster.add_shard()
    owned = _owned_sorted(cluster, p)
    stats = cluster.reshard({key: q for key in owned[1::2]})
    stats.update({"src": p, "dst": q})
    return stats


def merge_shards(cluster: ShardedNearline, src: int, dst: int) -> dict:
    """Online merge: migrate EVERY key shard ``src`` owns onto ``dst``.
    The source shard stays allocated but empty (shard indices are
    load-bearing in the ownership map; draining one to zero is the merge —
    a real deployment would then decommission the empty process)."""
    assert src != dst, (src, dst)
    stats = cluster.reshard({key: dst for key in _owned_sorted(cluster, src)})
    stats.update({"src": src, "dst": dst})
    return stats
