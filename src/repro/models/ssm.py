"""Mamba-2 block (arXiv:2405.21060): SSD scan + causal conv + gating.

Layout per block (single B/C group), with SEPARATE projections per semantic
piece — a fused in_proj would shard its flat output dim across z/x/B/C/dt
boundaries and force re-layout collectives every layer (iteration-0 dry-run
finding).  Split projections shard cleanly: z/x over "model" (d_inner),
dt over "model" (heads), B/C replicated (small, shared across heads).

  z   = W_z x                     gate path        [B, L, d_inner]
  xs  = conv*(W_x x)              SSD input        [B, L, d_inner]
  Bm  = conv*(W_B x)              input proj       [B, L, N]
  Cm  = conv*(W_C x)              output proj      [B, L, N]
  dt  = softplus(W_dt x + bias)   timestep         [B, L, H]
  SSD:   y_t = C_tᵀ S_t,  S_t = exp(dt_t A) S_{t-1} + dt_t B_t ⊗ x_t
  out = W_o RMSNorm(y * silu(z))

Decode state = (per-piece conv rings, ssd state [B, H, N, P]) — the
O(1)-per-token state that makes long_500k native for ssm/hybrid archs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ArchConfig
from repro.kernels import ops as kops


class SSMState(NamedTuple):
    conv_x: jax.Array   # [B, W-1, d_inner]
    conv_B: jax.Array   # [B, W-1, N]
    conv_C: jax.Array   # [B, W-1, N]
    ssd: jax.Array      # [B, H, N, P] float32


def _dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = d_inner // cfg.ssm_head_dim
    n = cfg.ssm_state
    return d_inner, heads, n


def _conv_init(key, width: int, channels: int, dtype):
    return {
        "w": (jax.random.normal(key, (width, channels), jnp.float32)
              / jnp.sqrt(width)).astype(dtype),
        "b": jnp.zeros((channels,), dtype),
    }


def ssm_init(key, cfg: ArchConfig, dtype):
    d_inner, heads, n = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 9)
    return {
        "z_proj": nn.dense_init(ks[0], d, d_inner, dtype=dtype),
        "x_proj": nn.dense_init(ks[1], d, d_inner, dtype=dtype),
        "B_proj": nn.dense_init(ks[2], d, n, dtype=dtype),
        "C_proj": nn.dense_init(ks[3], d, n, dtype=dtype),
        "dt_proj": nn.dense_init(ks[4], d, heads, dtype=dtype),
        "conv_x": _conv_init(ks[5], cfg.ssm_conv, d_inner, dtype),
        "conv_B": _conv_init(ks[6], cfg.ssm_conv, n, dtype),
        "conv_C": _conv_init(ks[7], cfg.ssm_conv, n, dtype),
        "A_log": jnp.log(jnp.linspace(1.0, float(heads), heads, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "D": jnp.ones((heads,), jnp.float32),      # skip connection per head
        "norm": nn.rmsnorm_init(d_inner, dtype=dtype),
        "out_proj": nn.dense_init(ks[8], d_inner, d, dtype=dtype),
    }


def _causal_conv(p, x, conv_state=None):
    """Depthwise causal conv + SiLU.  x [B, L, C] -> (same, new ring)."""
    w, b = p["w"], p["b"]
    width = w.shape[0]
    if conv_state is not None:
        x_ext = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    else:
        x_ext = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(x_ext[:, i:i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
              for i in range(width))
    out = jax.nn.silu(out + b[None, None, :].astype(out.dtype))
    new_state = x_ext[:, -(width - 1):, :] if width > 1 else None
    return out, new_state


def _conv_step(p, x_t, ring):
    """One-token conv.  x_t [B, C], ring [B, W-1, C] -> (out, new ring)."""
    window = jnp.concatenate([ring.astype(x_t.dtype), x_t[:, None, :]], axis=1)
    out = jnp.sum(window * p["w"][None, :, :].astype(window.dtype), axis=1)
    out = jax.nn.silu(out + p["b"][None, :].astype(out.dtype))
    return out, window[:, 1:, :]


def ssm_apply(params, cfg: ArchConfig, x: jax.Array, *, return_state: bool = False,
              initial_state: SSMState | None = None):
    """Full-sequence SSD block.  x [B, L, d] -> [B, L, d]."""
    d_inner, heads, n = _dims(cfg)
    b, L, _ = x.shape
    z = nn.dense_apply(params["z_proj"], x)
    ist = initial_state
    xs, ring_x = _causal_conv(params["conv_x"], nn.dense_apply(params["x_proj"], x),
                              ist.conv_x if ist is not None else None)
    Bm, ring_B = _causal_conv(params["conv_B"], nn.dense_apply(params["B_proj"], x),
                              ist.conv_B if ist is not None else None)
    Cm, ring_C = _causal_conv(params["conv_C"], nn.dense_apply(params["C_proj"], x),
                              ist.conv_C if ist is not None else None)

    dt = jax.nn.softplus(nn.dense_apply(params["dt_proj"], x).astype(jnp.float32)
                         + params["dt_bias"])                       # [B, L, H]
    A = -jnp.exp(params["A_log"])                                   # [H]
    xh = xs.reshape(b, L, heads, cfg.ssm_head_dim)
    y, final = kops.ssd(xh, dt, A, Bm, Cm,
                        initial_state=(ist.ssd if ist is not None
                                       and kops.default_impl() == "ref" else None))
    y = y + params["D"][None, None, :, None].astype(y.dtype) * xh   # skip
    y = y.reshape(b, L, d_inner)
    y = nn.rmsnorm_apply(params["norm"], y * jax.nn.silu(z))
    out = nn.dense_apply(params["out_proj"], y)
    if return_state:
        return out, SSMState(conv_x=ring_x, conv_B=ring_B, conv_C=ring_C, ssd=final)
    return out


def init_ssm_state(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> SSMState:
    d_inner, heads, n = _dims(cfg)
    w1 = cfg.ssm_conv - 1
    return SSMState(
        conv_x=jnp.zeros((batch, w1, d_inner), dtype),
        conv_B=jnp.zeros((batch, w1, n), dtype),
        conv_C=jnp.zeros((batch, w1, n), dtype),
        ssd=jnp.zeros((batch, heads, n, cfg.ssm_head_dim), jnp.float32),
    )


def ssm_decode(params, cfg: ArchConfig, x_t: jax.Array, state: SSMState):
    """One-token decode.  x_t [B, d] -> ([B, d], new state)."""
    d_inner, heads, n = _dims(cfg)
    b = x_t.shape[0]
    z = nn.dense_apply(params["z_proj"], x_t)
    xs, ring_x = _conv_step(params["conv_x"], nn.dense_apply(params["x_proj"], x_t),
                            state.conv_x)
    Bm, ring_B = _conv_step(params["conv_B"], nn.dense_apply(params["B_proj"], x_t),
                            state.conv_B)
    Cm, ring_C = _conv_step(params["conv_C"], nn.dense_apply(params["C_proj"], x_t),
                            state.conv_C)

    dt = jax.nn.softplus(nn.dense_apply(params["dt_proj"], x_t).astype(jnp.float32)
                         + params["dt_bias"])                       # [B, H]
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(b, heads, cfg.ssm_head_dim)
    y, new_ssd = kops.ssd_decode(state.ssd, xh, dt, A, Bm, Cm)
    y = y + params["D"][None, :, None].astype(y.dtype) * xh
    y = y.reshape(b, d_inner)
    y = nn.rmsnorm_apply(params["norm"], y * jax.nn.silu(z))
    out = nn.dense_apply(params["out_proj"], y)
    return out, SSMState(conv_x=ring_x, conv_B=ring_B, conv_C=ring_C, ssd=new_ssd)
