"""Mixture-of-Experts FFN: top-k router + two execution paths.

* ``local`` — sort-based dispatch + ``jax.lax.ragged_dot`` grouped matmul.
  Used on a single device (smoke tests, CPU examples) and under pure GSPMD
  when no expert-parallel axis is configured.
* ``ep`` (shard_map) — GShard-style expert parallelism over the ``data``
  mesh axis: capacity-bounded dispatch buffers, all_to_all to the expert
  owners, per-expert dense matmuls with the FFN dim sharded over ``model``,
  all_to_all back, weighted combine.  This is the collective pattern the
  roofline's all-to-all term measures for the MoE architectures.

Router: softmax over expert logits, top-k (k=2 for every assigned arch),
renormalized gates, Switch-style load-balance auxiliary loss.
Arctic's parallel dense residual FFN (``moe_dense_residual``) is handled in
the transformer block, not here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ArchConfig


def moe_init(key, cfg: ArchConfig, dtype):
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    scale_in = 1.0 / jnp.sqrt(d)
    scale_out = 1.0 / jnp.sqrt(f)
    return {
        "router": nn.dense_init(ks[0], d, e, dtype=jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32) * scale_out).astype(dtype),
    }


def route(params, cfg: ArchConfig, x2d: jax.Array):
    """x2d [T, d] -> (weights [T, k], experts [T, k], aux_loss scalar)."""
    logits = x2d.astype(jnp.float32) @ params["router"]["w"]         # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, cfg.experts_per_token)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # Switch load-balance loss: E * Σ_e f_e · p_e
    e = cfg.num_experts
    assign = jax.nn.one_hot(experts[:, 0], e, dtype=jnp.float32)     # primary expert
    f_e = jnp.mean(assign, axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * p_e)
    return weights.astype(x2d.dtype), experts, aux


# ---------------------------------------------------------------- local


def moe_ffn_local(params, cfg: ArchConfig, x2d: jax.Array):
    """Sort-based dispatch + ragged grouped matmul.  x2d [T, d] -> [T, d]."""
    t, d = x2d.shape
    k = cfg.experts_per_token
    e = cfg.num_experts
    weights, experts, aux = route(params, cfg, x2d)

    flat_e = experts.reshape(-1)                                     # [T*k]
    order = jnp.argsort(flat_e)
    token_of = order // k                                            # source token
    xs = x2d[token_of]                                               # [T*k, d] sorted by expert
    group_sizes = jnp.bincount(flat_e, length=e).astype(jnp.int32)

    gate = jax.lax.ragged_dot(xs, params["w_gate"].astype(xs.dtype), group_sizes)
    up = jax.lax.ragged_dot(xs, params["w_up"].astype(xs.dtype), group_sizes)
    h = jax.nn.silu(gate) * up
    ys = jax.lax.ragged_dot(h, params["w_down"].astype(xs.dtype), group_sizes)

    w_sorted = weights.reshape(-1)[order][:, None].astype(ys.dtype)
    out = jnp.zeros((t, d), ys.dtype).at[token_of].add(ys * w_sorted)
    return out, aux


# ------------------------------------------------------------- shard_map EP


def _capacity(cfg: ArchConfig, tokens_local: int, factor: float = 1.25) -> int:
    c = int(tokens_local * cfg.experts_per_token * factor / cfg.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe_ffn_ep(params, cfg: ArchConfig, x2d: jax.Array, *, mesh, data_axis="data",
               model_axis="model", batch_axes=("data",), capacity_factor: float = 1.25):
    """Expert-parallel MoE via shard_map.  x2d [T, d] sharded over batch_axes.

    Expert weights are sharded (E over ``data_axis``, FFN dim over
    ``model_axis``).  Dispatch volume per device ≈ T_local·k·d — the real
    all-to-all bytes the roofline's collective term counts.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    e = cfg.num_experts
    k = cfg.experts_per_token
    data_size = mesh.shape[data_axis]
    e_local = e // data_size

    def body(x_loc, router_w, wg, wu, wd):
        # x_loc [T_loc, d]; wg/wu [E_loc, d, f_loc]; wd [E_loc, f_loc, d]
        t_loc, d = x_loc.shape
        cap = _capacity(cfg, t_loc, capacity_factor)
        logits = x_loc.astype(jnp.float32) @ router_w                # [T_loc, E]
        probs = jax.nn.softmax(logits, axis=-1)
        weights, experts = jax.lax.top_k(probs, k)
        weights = (weights / jnp.sum(weights, axis=-1, keepdims=True)).astype(x_loc.dtype)

        assign1 = jax.nn.one_hot(experts[:, 0], e, dtype=jnp.float32)
        aux = e * jnp.sum(jnp.mean(assign1, axis=0) * jnp.mean(probs, axis=0))
        aux = jax.lax.pmean(aux, axis_name=data_axis)

        # ---- capacity-bounded dispatch buffers ------------------------
        flat_e = experts.reshape(-1)                                 # [T_loc*k]
        flat_w = weights.reshape(-1)
        token_of = jnp.arange(t_loc * k) // k
        order = jnp.argsort(flat_e)
        sorted_e = flat_e[order]
        seg_start = jnp.cumsum(jnp.bincount(flat_e, length=e)) - jnp.bincount(flat_e, length=e)
        pos_in_e = jnp.arange(t_loc * k) - seg_start[sorted_e]
        keep = pos_in_e < cap
        buf = jnp.zeros((e, cap, d), x_loc.dtype)
        comb_w = jnp.zeros((e, cap), x_loc.dtype)
        src_tok = jnp.full((e, cap), -1, jnp.int32)
        be = jnp.where(keep, sorted_e, e - 1)
        bp = jnp.where(keep, pos_in_e, cap - 1)
        tok = token_of[order]
        buf = buf.at[be, bp].set(jnp.where(keep[:, None], x_loc[tok], buf[be, bp]))
        comb_w = comb_w.at[be, bp].set(jnp.where(keep, flat_w[order], comb_w[be, bp]))
        src_tok = src_tok.at[be, bp].set(jnp.where(keep, tok, src_tok[be, bp]))

        # ---- to expert owners: [E, cap, d] -> [E_loc, cap*data, d] ----
        # tiled all_to_all keeps a well-defined transpose (the reverse
        # exchange), which the reshape+tiled=False form does not under VJP.
        recv = jax.lax.all_to_all(buf, data_axis, split_axis=0, concat_axis=1,
                                  tiled=True)                 # [E_loc, data*cap, d]

        # ---- expert compute (f sharded over model axis) ---------------
        g = jnp.einsum("ecd,edf->ecf", recv, wg.astype(recv.dtype))
        u = jnp.einsum("ecd,edf->ecf", recv, wu.astype(recv.dtype))
        h = jax.nn.silu(g) * u
        y = jnp.einsum("ecf,efd->ecd", h, wd.astype(recv.dtype))
        y = jax.lax.psum(y, axis_name=model_axis)                    # row-shard reduce

        # ---- back to token owners -------------------------------------
        back = jax.lax.all_to_all(y, data_axis, split_axis=1, concat_axis=0,
                                  tiled=True)                 # [E, cap, d]

        # ---- weighted combine ------------------------------------------
        valid = (src_tok >= 0)
        contrib = back * comb_w[..., None] * valid[..., None].astype(back.dtype)
        out = jnp.zeros((t_loc, d), back.dtype).at[
            jnp.where(valid, src_tok, 0).reshape(-1)].add(
            contrib.reshape(-1, d) * valid.reshape(-1, 1).astype(back.dtype))
        return out, aux

    t_spec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0], None)
    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(t_spec, P(None, None), P(data_axis, None, model_axis),
                  P(data_axis, None, model_axis), P(data_axis, model_axis, None)),
        out_specs=(t_spec, P()),
        check_rep=False,
    )(x2d, params["router"]["w"], params["w_gate"], params["w_up"], params["w_down"])
    return out, aux


def moe_ffn(params, cfg: ArchConfig, x: jax.Array, *, mesh=None, **ep_kwargs):
    """x [B, S, d] -> ([B, S, d], aux loss).  Chooses local vs EP path.

    The EP path needs tokens divisible by the data axis (shard_map); small
    decode batches are zero-padded up to the axis size — padded rows route
    like real tokens but their outputs are sliced away.
    """
    b, s, d = x.shape
    x2d = x.reshape(-1, d)
    t = x2d.shape[0]
    if mesh is None or cfg.num_experts % mesh.shape.get("data", 1) != 0 \
            or mesh.shape.get("data", 1) == 1:
        out, aux = moe_ffn_local(params, cfg, x2d)
        return out.reshape(b, s, d), aux
    shard = mesh.shape["data"]
    for ax in ep_kwargs.get("batch_axes", ("data",)):
        if ax != "data":
            shard *= mesh.shape[ax]
    pad = (-t) % shard
    if pad:
        x2d = jnp.concatenate([x2d, jnp.zeros((pad, d), x2d.dtype)], axis=0)
    out, aux = moe_ffn_ep(params, cfg, x2d, mesh=mesh, **ep_kwargs)
    return out[:t].reshape(b, s, d), aux
