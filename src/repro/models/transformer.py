"""Config-driven decoder assembly for every assigned architecture family.

A model is a stack of ``num_layers`` sublayers grouped into *period blocks*
(period = lcm of the attention-interleave and MoE periods, e.g. 8 for
jamba's 1:7 mamba:attn + MoE-every-2).  Blocks are structurally identical,
so parameters are stacked along a leading axis and the forward pass is a
single ``lax.scan`` — compile time and HLO size stay O(period), not
O(num_layers), which matters at 72-layer/400B dry-run scale.  ``remat=
"block"`` wraps the scan body in jax.checkpoint.

Sublayer kinds per in-block index (static, from the config):
  mixer: attention (RoPE GQA, optional sliding window) | mamba2 SSD
  ffn:   SwiGLU dense | top-k MoE (+ arctic parallel dense residual) | none

Modality frontends (vlm/audio) are stubs per the assignment carve-out:
``prefix_emb`` [B, P, d] arrives precomputed and is concatenated before the
token embeddings.  GNN conditioning (LinkSAGE part B) projects the frozen
member/job embeddings into d_model and adds them as a soft prompt bias.
"""
from __future__ import annotations

import functools
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ArchConfig
from repro.kernels import ops as kops
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S


# ----------------------------------------------------------------- pattern


def block_period(cfg: ArchConfig) -> int:
    p = 1
    if cfg.attn_layer_period:
        p = math.lcm(p, cfg.attn_layer_period)
    if cfg.num_experts:
        p = math.lcm(p, cfg.moe_every)
    return p


def sublayer_kinds(cfg: ArchConfig):
    """[(mixer_kind, ffn_kind)] for one period block (same for all blocks)."""
    kinds = []
    for j in range(block_period(cfg)):
        mixer = "attn" if cfg.is_attn_layer(j) else "ssm"
        if cfg.family == "ssm" or (cfg.family == "hybrid" and mixer == "ssm" and cfg.d_ff == 0):
            ffn = "none" if cfg.d_ff == 0 else ("moe" if cfg.is_moe_layer(j) else "dense")
        else:
            ffn = "moe" if cfg.is_moe_layer(j) else "dense"
        kinds.append((mixer, ffn))
    return kinds


def _norm_init(cfg: ArchConfig, dtype):
    return (nn.layernorm_init(cfg.d_model, dtype=dtype) if cfg.norm == "layernorm"
            else nn.rmsnorm_init(cfg.d_model, dtype=dtype))


def _norm_apply(cfg: ArchConfig, p, x):
    return (nn.layernorm_apply(p, x) if cfg.norm == "layernorm"
            else nn.rmsnorm_apply(p, x))


# -------------------------------------------------------------------- init


def _sublayer_init(key, cfg: ArchConfig, mixer: str, ffn: str, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"mixer_norm": _norm_init(cfg, dtype)}
    if mixer == "attn":
        p["attn"] = L.attention_init(k1, cfg, dtype)
    else:
        p["ssm"] = S.ssm_init(k1, cfg, dtype)
    if ffn != "none":
        p["ffn_norm"] = _norm_init(cfg, dtype)
    if ffn == "dense":
        p["mlp"] = nn.glu_mlp_init(k2, cfg.d_model, cfg.d_ff, dtype=dtype)
    elif ffn == "moe":
        p["moe"] = M.moe_init(k3, cfg, dtype)
        if cfg.moe_dense_residual:
            p["mlp"] = nn.glu_mlp_init(k4, cfg.d_model, cfg.d_ff_dense, dtype=dtype)
    return p


def model_init(key, cfg: ArchConfig):
    dtype = cfg.pdtype
    kinds = sublayer_kinds(cfg)
    period = len(kinds)
    nblocks = cfg.num_layers // period
    assert cfg.num_layers % period == 0, (cfg.num_layers, period)

    k_embed, k_blocks, k_head, k_gnn = jax.random.split(key, 4)

    def init_block(bkey):
        ks = jax.random.split(bkey, period)
        return {"layers": [_sublayer_init(ks[j], cfg, *kinds[j], dtype)
                           for j in range(period)]}

    block_keys = jax.random.split(k_blocks, nblocks)
    blocks = jax.vmap(init_block)(block_keys)          # stacked along axis 0

    params = {
        "embed": nn.embedding_init(k_embed, cfg.vocab_size, cfg.d_model, dtype=dtype),
        "blocks": blocks,
        "final_norm": _norm_init(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = nn.dense_init(k_head, cfg.d_model, cfg.vocab_size, dtype=dtype)
    if cfg.gnn_conditioning:
        params["gnn_proj"] = nn.dense_init(k_gnn, 2 * cfg.gnn_embed_dim, cfg.d_model,
                                           use_bias=True, dtype=dtype)
    return params


# ----------------------------------------------------------------- forward


def _sublayer_apply(lp, cfg: ArchConfig, kind, x, positions, window, mesh):
    mixer, ffn = kind
    h = _norm_apply(cfg, lp["mixer_norm"], x)
    if mixer == "attn":
        x = x + L.attention_apply(lp["attn"], cfg, h, positions, window=window)
    else:
        x = x + S.ssm_apply(lp["ssm"], cfg, h)
    aux = jnp.zeros((), jnp.float32)
    if ffn == "none":
        return x, aux
    h = _norm_apply(cfg, lp["ffn_norm"], x)
    if ffn == "dense":
        x = x + nn.glu_mlp_apply(lp["mlp"], h)
    else:
        y, aux = M.moe_ffn(lp["moe"], cfg, h, mesh=mesh)
        if cfg.moe_dense_residual:
            y = y + nn.glu_mlp_apply(lp["mlp"], h)
        x = x + y
    return x, aux


def embed_inputs(params, cfg: ArchConfig, tokens, prefix_emb=None, gnn_emb=None):
    """tokens [B, S_text] (+ prefix [B, P, d]) -> (x [B, S, d], positions)."""
    x = nn.embedding_lookup(params["embed"], tokens).astype(cfg.adtype)
    if prefix_emb is not None:
        x = jnp.concatenate([prefix_emb.astype(cfg.adtype), x], axis=1)
    if gnn_emb is not None:
        bias = nn.dense_apply(params["gnn_proj"], gnn_emb.astype(cfg.adtype))
        x = x + bias[:, None, :]
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    return x, positions


def forward_train(params, cfg: ArchConfig, tokens, *, prefix_emb=None,
                  gnn_emb=None, window: int | None = None, mesh=None):
    """Full-sequence forward.  Returns (hidden [B, S, d], aux_loss)."""
    window = cfg.sliding_window if window is None else window
    kinds = sublayer_kinds(cfg)
    x, positions = embed_inputs(params, cfg, tokens, prefix_emb, gnn_emb)

    def body(carry, block):
        x = carry
        aux = jnp.zeros((), jnp.float32)
        for j, kind in enumerate(kinds):
            x, a = _sublayer_apply(block["layers"][j], cfg, kind, x, positions,
                                   window, mesh)
            aux = aux + a
        if cfg.seq_shard and mesh is not None:
            # sequence-parallel residual stream: block boundaries (= the
            # remat-saved activations) shard their seq dim over "model"
            from jax.sharding import NamedSharding, PartitionSpec as _P
            x = jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, _P(None, "model", None)))
        return x, aux

    if cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    x, auxs = jax.lax.scan(body, x, params["blocks"],
                           unroll=max(1, min(cfg.scan_unroll,
                                             cfg.num_layers // len(kinds))))
    x = _norm_apply(cfg, params["final_norm"], x)
    return x, jnp.sum(auxs)


def lm_head_weight(params, cfg: ArchConfig):
    if cfg.tie_embeddings:
        return params["embed"]["table"].T
    return params["lm_head"]["w"]


def lm_loss(params, cfg: ArchConfig, hidden, labels, *, chunk: int = 512):
    """Chunked softmax cross-entropy — never materializes [B, S, V].

    hidden [B, S, d], labels [B, S] (-1 = ignore) -> scalar mean nll.
    """
    w = lm_head_weight(params, cfg)
    b, s, d = hidden.shape
    c = min(chunk, s)
    assert s % c == 0
    hc = hidden.reshape(b, s // c, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, s // c, c).transpose(1, 0, 2)

    def chunk_loss(h, y):
        logits = (h @ w.astype(h.dtype)).astype(jnp.float32)       # [b, c, V]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(y, 0)[..., None],
                                   axis=-1)[..., 0]
        valid = (y >= 0).astype(jnp.float32)
        return jnp.sum((logz - gold) * valid), jnp.sum(valid)

    def body(acc, xs):
        h, y = xs
        nll, n = jax.checkpoint(chunk_loss)(h, y)
        return (acc[0] + nll, acc[1] + n), None

    # in roofline mode the CE scan must be FULLY unrolled (it sits outside
    # the layer loop, so the two-point extrapolation needs it exact)
    ce_unroll = (s // c) if kops.ROOFLINE_MODE else max(1, min(cfg.scan_unroll, s // c))
    (nll, n), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),) * 2, (hc, lc),
                               unroll=ce_unroll)
    return nll / jnp.maximum(n, 1.0)


def logits_for(params, cfg: ArchConfig, hidden):
    """hidden [..., d] -> logits [..., V] (decode path; no chunking needed)."""
    w = lm_head_weight(params, cfg)
    return (hidden @ w.astype(hidden.dtype)).astype(jnp.float32)


# ------------------------------------------------------------------ decode


class DecodeState(NamedTuple):
    layer_state: Any      # stacked-over-blocks pytree of per-sublayer states
    step: jax.Array       # scalar int32


def init_decode_state(cfg: ArchConfig, batch: int, max_seq: int, *,
                      window: int | None = None, dtype=None) -> DecodeState:
    window = cfg.sliding_window if window is None else window
    dtype = dtype or cfg.adtype
    kinds = sublayer_kinds(cfg)
    nblocks = cfg.num_layers // len(kinds)

    def one_block():
        states = []
        for mixer, _ in kinds:
            if mixer == "attn":
                states.append(L.init_kv_cache(cfg, batch, max_seq, window=window,
                                              dtype=dtype))
            else:
                states.append(S.init_ssm_state(cfg, batch, dtype=dtype))
        return states

    block = one_block()
    stacked = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (nblocks,) + x.shape),
                           block)
    return DecodeState(layer_state=stacked, step=jnp.zeros((), jnp.int32))


def decode_step(params, cfg: ArchConfig, token, state: DecodeState, *,
                gnn_emb=None, window: int | None = None, mesh=None):
    """One decode step.  token [B] int32 -> (logits [B, V], new state)."""
    window = cfg.sliding_window if window is None else window
    kinds = sublayer_kinds(cfg)
    x = nn.embedding_lookup(params["embed"], token).astype(cfg.adtype)  # [B, d]
    if gnn_emb is not None:
        x = x + nn.dense_apply(params["gnn_proj"], gnn_emb.astype(cfg.adtype))

    def body(x, block_and_state):
        block, states = block_and_state
        new_states = []
        for j, (mixer, ffn) in enumerate(kinds):
            lp = block["layers"][j]
            h = _norm_apply(cfg, lp["mixer_norm"], x)
            if mixer == "attn":
                dx, ns = L.attention_decode(lp["attn"], cfg, h, states[j],
                                            window=window)
            else:
                dx, ns = S.ssm_decode(lp["ssm"], cfg, h, states[j])
            x = x + dx
            new_states.append(ns)
            if ffn == "none":
                continue
            h = _norm_apply(cfg, lp["ffn_norm"], x)
            if ffn == "dense":
                x = x + nn.glu_mlp_apply(lp["mlp"], h)
            else:
                y, _ = M.moe_ffn(lp["moe"], cfg, h[:, None, :], mesh=mesh)
                y = y[:, 0, :]
                if cfg.moe_dense_residual:
                    y = y + nn.glu_mlp_apply(lp["mlp"], h)
                x = x + y
        return x, new_states

    nblocks = cfg.num_layers // len(kinds)
    x, new_layer_state = jax.lax.scan(body, x, (params["blocks"], state.layer_state),
                                      unroll=max(1, min(cfg.scan_unroll, nblocks)))
    x = _norm_apply(cfg, params["final_norm"], x)
    logits = logits_for(params, cfg, x)
    return logits, DecodeState(layer_state=new_layer_state, step=state.step + 1)


# ----------------------------------------------------------------- prefill


def prefill(params, cfg: ArchConfig, tokens, *, prefix_emb=None, gnn_emb=None,
            window: int | None = None, max_seq: int | None = None, mesh=None):
    """Run the prompt and build a DecodeState.  Returns (last_logits, state).

    Simplicity over speed: runs forward_train for hidden states, then one
    full-sequence pass per layer to collect K/V (SSM states come from the
    chunked scan's final state).  Serving-path tests cross-check against
    repeated decode_step.
    """
    window = cfg.sliding_window if window is None else window
    kinds = sublayer_kinds(cfg)
    b, s_text = tokens.shape
    x, positions = embed_inputs(params, cfg, tokens, prefix_emb, gnn_emb)
    s = x.shape[1]
    max_seq = max_seq or (s + 64)   # headroom for generated tokens
    s_alloc = min(window, max_seq) if window else max_seq

    def body(x, block):
        new_states = []
        for j, (mixer, ffn) in enumerate(kinds):
            lp = block["layers"][j]
            h = _norm_apply(cfg, lp["mixer_norm"], x)
            if mixer == "attn":
                dx, (k, v) = L.attention_apply(lp["attn"], cfg, h, positions,
                                               window=window, return_kv=True)
                cache = L.cache_from_prefill(cfg, k.astype(cfg.adtype),
                                             v.astype(cfg.adtype), s,
                                             s_alloc=s_alloc, window=window)
                new_states.append(cache)
            else:
                dx, st = S.ssm_apply(lp["ssm"], cfg, h, return_state=True)
                new_states.append(st)
            x = x + dx
            if ffn == "none":
                continue
            h = _norm_apply(cfg, lp["ffn_norm"], x)
            if ffn == "dense":
                x = x + nn.glu_mlp_apply(lp["mlp"], h)
            else:
                y, _ = M.moe_ffn(lp["moe"], cfg, h, mesh=mesh)
                if cfg.moe_dense_residual:
                    y = y + nn.glu_mlp_apply(lp["mlp"], h)
                x = x + y
        return x, new_states

    nblocks = cfg.num_layers // len(kinds)
    x, layer_state = jax.lax.scan(body, x, params["blocks"],
                                  unroll=max(1, min(cfg.scan_unroll, nblocks)))
    x = _norm_apply(cfg, params["final_norm"], x)
    logits = logits_for(params, cfg, x[:, -1, :])
    return logits, DecodeState(layer_state=layer_state,
                               step=jnp.asarray(s, jnp.int32))
