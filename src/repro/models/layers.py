"""Attention + positional layers shared by every transformer family.

KV cache semantics
------------------
``KVCache`` holds [B, Hkv, S, Dh] tensors plus a scalar-per-batch length.
Full-attention archs allocate S = max_seq; sliding-window archs allocate
S = window and write new entries into a ring buffer — the O(window) cache is
what makes long_500k feasible for dense families (DESIGN.md §4).  RoPE is
applied *before* caching, so ring order never matters.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ArchConfig
from repro.kernels import ops as kops


# ------------------------------------------------------------------- RoPE


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [B, H, S, Dh], positions [B, S] -> rotated x."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # [B,1,S,half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([
        x1 * cos - x2 * sin,
        x2 * cos + x1 * sin,
    ], axis=-1).astype(x.dtype)


# -------------------------------------------------------------- attention


class KVCache(NamedTuple):
    k: jax.Array          # [B, Hkv, S_alloc, Dh]
    v: jax.Array
    length: jax.Array     # [B] int32 — total tokens seen (may exceed window)


def attention_init(key, cfg: ArchConfig, dtype):
    hq, hkv, dh, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "wq": nn.dense_init(ks[0], d, hq * dh, use_bias=cfg.qkv_bias, dtype=dtype),
        "wk": nn.dense_init(ks[1], d, hkv * dh, use_bias=cfg.qkv_bias, dtype=dtype),
        "wv": nn.dense_init(ks[2], d, hkv * dh, use_bias=cfg.qkv_bias, dtype=dtype),
        "wo": nn.dense_init(ks[3], hq * dh, d, dtype=dtype),
    }


def _split_heads(x, num_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, num_heads, head_dim).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


def attention_apply(params, cfg: ArchConfig, x: jax.Array, positions: jax.Array,
                    *, window: int = 0, return_kv: bool = False):
    """Causal self-attention over a full sequence (train / prefill)."""
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = _split_heads(nn.dense_apply(params["wq"], x), hq, dh)
    k = _split_heads(nn.dense_apply(params["wk"], x), hkv, dh)
    v = _split_heads(nn.dense_apply(params["wv"], x), hkv, dh)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    o = kops.mha(q, k, v, causal=True, window=window)
    out = nn.dense_apply(params["wo"], _merge_heads(o))
    if return_kv:
        return out, (k, v)
    return out


def init_kv_cache(cfg: ArchConfig, batch: int, max_seq: int, *, window: int = 0,
                  dtype=jnp.bfloat16) -> KVCache:
    s_alloc = min(window, max_seq) if window else max_seq
    shape = (batch, cfg.num_kv_heads, s_alloc, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   length=jnp.zeros((batch,), jnp.int32))


def cache_from_prefill(cfg: ArchConfig, k: jax.Array, v: jax.Array, seq_len: int,
                       *, s_alloc: int, window: int = 0) -> KVCache:
    """Build a cache from prefill K/V (keeping only the window tail if set)."""
    b = k.shape[0]
    if window and seq_len > s_alloc:
        # ring layout: entry for absolute position p lives at slot p % window
        start = seq_len - s_alloc
        tail_k, tail_v = k[:, :, -s_alloc:], v[:, :, -s_alloc:]
        # tail index i holds absolute position start+i; ring wants it at slot
        # (start+i) % s_alloc, i.e. a roll by +start
        roll = start % s_alloc
        tail_k = jnp.roll(tail_k, roll, axis=2)
        tail_v = jnp.roll(tail_v, roll, axis=2)
    else:
        pad = s_alloc - seq_len
        tail_k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        tail_v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return KVCache(k=tail_k, v=tail_v,
                   length=jnp.full((b,), seq_len, jnp.int32))


def attention_decode(params, cfg: ArchConfig, x_t: jax.Array, cache: KVCache,
                     *, window: int = 0):
    """One-token decode.  x_t [B, d] -> ([B, d], new cache)."""
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    b = x_t.shape[0]
    pos = cache.length                                        # [B] current position
    q = nn.dense_apply(params["wq"], x_t).reshape(b, hq, 1, dh)
    k = nn.dense_apply(params["wk"], x_t).reshape(b, hkv, 1, dh)
    v = nn.dense_apply(params["wv"], x_t).reshape(b, hkv, 1, dh)
    posb = pos[:, None]
    q = rope(q, posb, cfg.rope_theta)
    k = rope(k, posb, cfg.rope_theta)

    s_alloc = cache.k.shape[2]
    slot = pos % s_alloc   # ring for windowed caches; in-range for full caches
    # per-batch single-slot scatter (NOT a full-cache rewrite)
    bidx = jnp.arange(b)
    k_new = cache.k.at[bidx, :, slot, :].set(k[:, :, 0, :].astype(cache.k.dtype))
    v_new = cache.v.at[bidx, :, slot, :].set(v[:, :, 0, :].astype(cache.v.dtype))
    new_len = pos + 1
    eff_len = jnp.minimum(new_len, s_alloc)
    o = kops.decode_attention(q.reshape(b, hq, dh), k_new, v_new, eff_len,
                              window=0)  # ring cache: every stored slot is valid
    out = nn.dense_apply(params["wo"], o.reshape(b, hq * dh))
    return out, KVCache(k=k_new, v=v_new, length=new_len)
