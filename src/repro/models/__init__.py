"""Model zoo: every assigned architecture family as a composable JAX module.

  layers      — RoPE, GQA attention (+ sliding window, KV cache), norms
  moe         — top-k router, ragged-dot local path, shard_map EP path
  ssm         — Mamba-2 block (SSD scan + causal conv + gating)
  transformer — config-driven assembly (dense/moe/ssm/hybrid/vlm/audio),
                train forward, prefill, single-token decode
"""
from repro.models.transformer import (
    model_init,
    forward_train,
    lm_loss,
    init_decode_state,
    decode_step,
)

__all__ = ["model_init", "forward_train", "lm_loss", "init_decode_state", "decode_step"]
