"""Core functional layers: dense, embedding, norms, MLPs.

Conventions
-----------
* ``init`` functions take an explicit PRNG key and static shape info and
  return a params pytree (nested dicts of jnp arrays).
* ``apply`` functions are pure; the params pytree is the first argument.
* ``dtype`` on init controls the *stored* parameter dtype; compute dtype is
  the dtype of the activations flowing in (we upcast norms internally).
"""
from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp

Initializer = Callable[[jax.Array, tuple, jnp.dtype], jax.Array]


def _fan_in_init(key: jax.Array, shape: tuple, dtype) -> jax.Array:
    """Truncated-normal fan-in init (std = 1/sqrt(fan_in))."""
    fan_in = shape[0] if len(shape) > 1 else 1
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def _normal_init(std: float) -> Initializer:
    def init(key, shape, dtype):
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)

    return init


# ---------------------------------------------------------------- dense


def dense_init(key, d_in: int, d_out: int, *, use_bias: bool = False,
               dtype=jnp.float32, initializer: Initializer = _fan_in_init):
    p = {"w": initializer(key, (d_in, d_out), dtype)}
    if use_bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------- embedding


def embedding_init(key, vocab: int, d: int, *, dtype=jnp.float32, std: float = 0.02):
    return {"table": _normal_init(std)(key, (vocab, d), dtype)}


def embedding_lookup(p, ids):
    return jnp.take(p["table"], ids, axis=0)


# ---------------------------------------------------------------- norms


def rmsnorm_init(d: int, *, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(p, x, *, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, *, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_apply(p, x, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------- MLPs


def mlp_init(key, d_in: int, d_hidden: int, d_out: int, *, use_bias: bool = True,
             dtype=jnp.float32):
    """Plain 2-layer MLP with GELU (used by GNN transforms / ranker heads)."""
    k1, k2 = jax.random.split(key)
    return {
        "in": dense_init(k1, d_in, d_hidden, use_bias=use_bias, dtype=dtype),
        "out": dense_init(k2, d_hidden, d_out, use_bias=use_bias, dtype=dtype),
    }


def mlp_apply(p, x):
    return dense_apply(p["out"], jax.nn.gelu(dense_apply(p["in"], x)))


def glu_mlp_init(key, d_model: int, d_ff: int, *, dtype=jnp.float32):
    """SwiGLU MLP (llama-family FFN)."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d_model, d_ff, dtype=dtype),
        "up": dense_init(k2, d_model, d_ff, dtype=dtype),
        "down": dense_init(k3, d_ff, d_model, dtype=dtype),
    }


def glu_mlp_apply(p, x):
    return dense_apply(p["down"], jax.nn.silu(dense_apply(p["gate"], x)) * dense_apply(p["up"], x))


# ---------------------------------------------------------------- utils


def param_count(tree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def param_bytes(tree) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)
