"""Minimal pure-functional NN substrate (no flax dependency).

Parameters are plain nested-dict pytrees.  Every module is an
``init(rng, ...) -> params`` / ``apply(params, ...) -> out`` pair of pure
functions.  RNG handling uses explicit jax.random key splitting.
"""
from repro.nn.core import (
    Initializer,
    dense_init,
    dense_apply,
    embedding_init,
    embedding_lookup,
    rmsnorm_init,
    rmsnorm_apply,
    layernorm_init,
    layernorm_apply,
    mlp_init,
    mlp_apply,
    glu_mlp_init,
    glu_mlp_apply,
    param_count,
    param_bytes,
    tree_cast,
)

__all__ = [
    "Initializer",
    "dense_init",
    "dense_apply",
    "embedding_init",
    "embedding_lookup",
    "rmsnorm_init",
    "rmsnorm_apply",
    "layernorm_init",
    "layernorm_apply",
    "mlp_init",
    "mlp_apply",
    "glu_mlp_init",
    "glu_mlp_apply",
    "param_count",
    "param_bytes",
    "tree_cast",
]
