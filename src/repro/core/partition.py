"""Graph partitioning for the online serving tier (DESIGN.md §10).

LiGNN-style serving shards the graph horizontally: every node has exactly
one *owner* shard holding its neighbor rings, features, and embedding
record, and a K-hop tile build scatter-gathers per-node queries across
owners.  This module is the partitioning substrate:

  GraphPartitioner — the ownership map: ``hash`` (stateless, any id) or
                     ``greedy`` (degree-ordered edge-cut minimization over
                     a snapshot, hash fallback for unseen nodes)
  ShardedEngine    — P per-shard :class:`StreamingEngine`s behind the ONE
                     :class:`GraphEngine` protocol: queries are grouped by
                     owner, answered shard-locally, and scattered back
  ShardView        — a shard-pinned engine view that counts how many rows
                     each query resolved remotely (the cross-shard traffic
                     a real deployment pays network for)

Cross-shard neighbor-resolution contract: a node's ring content is a pure
function of the per-(relation, src) event subsequence, and routing by the
*source* node preserves exactly that subsequence per owner — so every
per-node query (``counts`` / ``sample_batched`` / ``gather_features``)
returns bit-identical results to a single un-sharded StreamingEngine fed
the same bootstrap + event stream.  The only global state is the relation
*insertion order* (the merged-neighbor offset contract of DESIGN.md §2):
``bootstrap_from_graph`` therefore registers every snapshot relation in
every shard, in snapshot order, even where a shard owns no sources —
zero-count relations contribute zero-width spans, so the padding is free.
Parity then holds whenever live events only add edges of relation types
present at bootstrap (the same append-only regime as §8/§9).
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import NODE_TYPE_ID, NODE_TYPES, HeteroGraph
from repro.core.engine import StreamingEngine

STRATEGIES = ("hash", "greedy")

# splitmix-style multipliers for the stateless ownership hash
_H1, _H2 = np.int64(0x9E3779B1), np.int64(0x85EBCA77)


def _global_offsets(graph: HeteroGraph):
    """Dense global node indexing: node (tid, nid) -> ``offs[tid] + nid``.
    Snapshot ids are dense per type, so the global index space is dense too —
    every per-node quantity (degree, assignment, owner) becomes one flat
    array instead of a dict keyed by (tid, nid) tuples."""
    offs = np.zeros(len(NODE_TYPES) + 1, np.int64)
    for tname, tid in NODE_TYPE_ID.items():
        offs[tid + 1] = graph.num_nodes.get(tname, 0)
    np.cumsum(offs, out=offs)
    return offs, int(offs[-1])


def _edge_arrays(graph: HeteroGraph):
    """Every stored directed edge as flat (src, dst) GLOBAL-index arrays —
    the one O(E) pass shared by the vectorized ``fit`` and ``cut_stats``
    (replaces their per-edge Python walks)."""
    offs, total = _global_offsets(graph)
    srcs, dsts = [], []
    for (s, d), csr in graph.adj.items():
        src = np.repeat(np.arange(len(csr.indptr) - 1, dtype=np.int64),
                        np.diff(csr.indptr))
        srcs.append(src + offs[NODE_TYPE_ID[s]])
        dsts.append(csr.indices.astype(np.int64) + offs[NODE_TYPE_ID[d]])
    if srcs:
        return np.concatenate(srcs), np.concatenate(dsts), offs, total
    return (np.zeros(0, np.int64), np.zeros(0, np.int64), offs, total)


def _transpose_lists(csr, num_dst: int):
    """Type-local sources grouped by destination: (rev_indptr, rev_srcs).

    Linear time when scipy is available (its C coo->csr pass is a counting
    sort — no O(E log E) comparison sort); numpy argsort fallback
    otherwise.  Duplicate edges keep their multiplicity (each coo entry has
    a unique synthetic column, so nothing is summed), and order WITHIN a
    destination's list is unspecified — the fit only counts votes."""
    srcs = np.repeat(np.arange(len(csr.indptr) - 1, dtype=np.int64),
                     np.diff(csr.indptr))
    dsts = csr.indices.astype(np.int64)
    try:
        from scipy import sparse
    except ImportError:
        order = np.argsort(dsts)
        indptr = np.zeros(num_dst + 1, np.int64)
        np.cumsum(np.bincount(dsts, minlength=num_dst), out=indptr[1:])
        return indptr, srcs[order]
    m = sparse.csr_matrix((srcs, (dsts, np.arange(len(srcs)))),
                          shape=(num_dst, max(len(srcs), 1)))
    return m.indptr.astype(np.int64), m.data.astype(np.int64)


def _merged_adjacency(graph: HeteroGraph, offs: np.ndarray, total: int):
    """The symmetrized global-index CSR (deg, indptr, nbr) in O(E):
    every stored directed edge (u, v) contributes u->v and v->u, exactly
    the adjacency the reference fit built edge-by-edge.  Forward neighbor
    lists come straight out of the per-relation CSRs (already grouped by
    source); reverse lists via :func:`_transpose_lists`.  No global edge
    sort — the per-node neighbor ORDER differs from a sorted build, but
    the fit only counts votes per shard, so the assignment is unchanged."""
    deg = np.zeros(total, np.int64)
    contribs = []               # (global row base, per-row deg, indptr, vals)
    for (s, d), csr in graph.adj.items():
        si, di = NODE_TYPE_ID[s], NODE_TYPE_ID[d]
        nd = graph.num_nodes[d]
        fwd_deg = np.diff(csr.indptr)
        contribs.append((offs[si], fwd_deg, csr.indptr,
                         csr.indices.astype(np.int64) + offs[di]))
        deg[offs[si]:offs[si] + len(fwd_deg)] += fwd_deg
        rptr, rsrcs = _transpose_lists(csr, nd)
        rev_deg = np.diff(rptr)
        contribs.append((offs[di], rev_deg, rptr, rsrcs + offs[si]))
        deg[offs[di]:offs[di] + nd] += rev_deg
    indptr = np.zeros(total + 1, np.int64)
    np.cumsum(deg, out=indptr[1:])
    nbr = np.empty(int(indptr[-1]), np.int64)
    cursor = indptr[:-1].copy()
    for base, sub_deg, sub_ptr, vals in contribs:
        n = len(sub_deg)
        within = np.arange(len(vals), dtype=np.int64) - np.repeat(
            np.asarray(sub_ptr[:-1], np.int64), sub_deg)
        nbr[np.repeat(cursor[base:base + n], sub_deg) + within] = vals
        cursor[base:base + n] += sub_deg
    return deg, indptr, nbr


def _slice_gather(values: np.ndarray, indptr: np.ndarray,
                  rows: np.ndarray):
    """Concatenate ``values[indptr[r]:indptr[r+1]]`` for every r in ``rows``
    (the vectorized CSR multi-slice), plus the per-row repeat index."""
    counts = indptr[rows + 1] - indptr[rows]
    rep = np.repeat(np.arange(len(rows)), counts)
    ends = np.cumsum(counts)
    flat = np.arange(int(ends[-1]) if len(ends) else 0, dtype=np.int64)
    flat += np.repeat(indptr[rows] - (ends - counts), counts)
    return values[flat], rep, counts


def _hash_shard(tids: np.ndarray, nids: np.ndarray, num_shards: int) -> np.ndarray:
    """Vectorized deterministic (type, id) -> shard hash (any id, any time)."""
    with np.errstate(over="ignore"):
        h = tids.astype(np.int64) * _H1 + nids.astype(np.int64) * _H2
        h ^= h >> np.int64(15)
        h *= np.int64(0x27D4EB2F)
        h ^= h >> np.int64(13)
    return (h % num_shards + num_shards) % num_shards


class GraphPartitioner:
    """The node-ownership map over P shards.

    ``hash`` needs no fitting and covers ids that do not exist yet (fresh
    jobs arriving on the event stream).  ``greedy`` fits an edge-cut
    minimizing assignment over a snapshot graph: nodes in descending merged-
    degree order each go to the shard holding most of their already-placed
    neighbors, subject to a balance cap of ``balance_slack`` x the ideal
    shard size; nodes never seen by ``fit`` fall back to the hash map, so
    the partitioner stays total over the open world.
    """

    def __init__(self, num_shards: int, strategy: str = "hash", *,
                 balance_slack: float = 1.15):
        assert num_shards >= 1, num_shards
        assert strategy in STRATEGIES, strategy
        self.num_shards = int(num_shards)
        self.strategy = strategy
        self.balance_slack = float(balance_slack)
        self._assigned: dict = {}          # (tid, nid) -> shard (greedy fit)
        self._dense: dict = {}             # tid -> [n] owner array (greedy fit)
        # elastic resharding (DESIGN.md §12): explicit per-key reassignments
        # layered over the base map.  The hash modulus is FROZEN at
        # construction so add_shard never silently re-homes unrelated keys —
        # new shards only ever receive keys through explicit assignment.
        self._hash_mod = int(num_shards)
        self._over: dict = {}              # tid -> [n] override array (-1 = none)

    # ---- ownership ------------------------------------------------------
    def shard_of(self, node_type: str | int, node_id: int) -> int:
        tid = NODE_TYPE_ID[node_type] if isinstance(node_type, str) else int(node_type)
        nid = int(node_id)
        ov = self._over.get(tid)
        if ov is not None and 0 <= nid < len(ov) and ov[nid] >= 0:
            return int(ov[nid])
        arr = self._dense.get(tid)
        if arr is not None and 0 <= nid < len(arr):
            return int(arr[nid])
        return int(_hash_shard(np.array([tid]), np.array([nid]),
                               self._hash_mod)[0])

    def shard_array(self, tids: np.ndarray, nids: np.ndarray) -> np.ndarray:
        """Vectorized ownership for flat (tid, nid) arrays: hash everywhere,
        overridden by the dense fitted owner arrays where they cover, then
        by explicit reshard assignments."""
        tids = np.asarray(tids)
        nids = np.asarray(nids)
        out = _hash_shard(tids, nids, self._hash_mod)
        for tid, arr in self._dense.items():
            sel = (tids == tid) & (nids < len(arr))
            if sel.any():
                out[sel] = arr[nids[sel]]
        for tid, ov in self._over.items():
            sel = (tids == tid) & (nids < len(ov))
            if sel.any():
                vals = ov[nids[sel]]
                idx = np.nonzero(sel)[0][vals >= 0]
                out[idx] = vals[vals >= 0]
        return out.astype(np.int64)

    # ---- elastic resharding (DESIGN.md §12) -----------------------------
    def add_shard(self) -> int:
        """Grow the shard space by one EMPTY shard and return its index.
        Existing ownership is untouched (the hash modulus stays frozen);
        the new shard acquires keys only via ``assign``."""
        self.num_shards += 1
        return self.num_shards - 1

    def assign(self, keys, shard: int) -> None:
        """Explicitly re-home ``keys`` ((node_type|tid, nid) pairs) onto
        ``shard`` — the reshard migration map."""
        assert 0 <= int(shard) < self.num_shards, shard
        for nt, ni in keys:
            tid = NODE_TYPE_ID[nt] if isinstance(nt, str) else int(nt)
            nid = int(ni)
            ov = self._over.get(tid)
            if ov is None or nid >= len(ov):
                grown = np.full(max(nid + 1, 64,
                                    2 * (0 if ov is None else len(ov))),
                                -1, np.int64)
                if ov is not None:
                    grown[:len(ov)] = ov
                self._over[tid] = ov = grown
            ov[nid] = int(shard)

    # ---- checkpoint (DESIGN.md §12) -------------------------------------
    def snapshot(self) -> dict:
        return {"num_shards": self.num_shards, "strategy": self.strategy,
                "balance_slack": self.balance_slack,
                "hash_mod": self._hash_mod,
                "dense": {t: a.copy() for t, a in self._dense.items()},
                "over": {t: a.copy() for t, a in self._over.items()}}

    @classmethod
    def from_snapshot(cls, state: dict) -> "GraphPartitioner":
        part = cls(state["num_shards"], state["strategy"],
                   balance_slack=state["balance_slack"])
        part._hash_mod = int(state["hash_mod"])
        part._dense = {int(t): a.copy() for t, a in state["dense"].items()}
        part._over = {int(t): a.copy() for t, a in state["over"].items()}
        return part

    # ---- fitting --------------------------------------------------------
    def fit(self, graph: HeteroGraph, *,
            chunk_size: int = 8192) -> "GraphPartitioner":
        """Fit the assignment over a snapshot (no-op for ``hash``).

        Refitting replaces the previous assignment WHOLESALE: the dense
        owner arrays are rebuilt against the current ``num_shards`` and any
        per-key ``assign()`` overrides are cleared.  Precedence contract
        (DESIGN.md §13): overrides layered by elastic resharding survive
        ``add_shard`` (the hash modulus is frozen) but are RESET by ``fit``
        — a refit is a global re-optimization and stale migration pins
        would silently shadow it.

        Streaming chunked scheme (bit-identical to :meth:`_fit_reference`):
        nodes are visited in the same (-degree, key) order, in chunks.  Per
        chunk, votes from already-placed neighbors are accumulated in one
        vectorized ``np.add.at`` pass over the partial assignment; only
        votes between nodes *inside* the same chunk propagate through a
        cheap sequential inner loop (an argmax over a composite integer
        key, no per-neighbor Python iteration).  Same balance-cap
        semantics: a shard at ``ceil(total/P * balance_slack)`` closes.
        """
        if self.strategy == "hash":
            return self
        self._assigned.clear()
        self._dense.clear()
        self._over.clear()                 # refit resets reshard overrides
        offs, total = _global_offsets(graph)
        if total == 0:
            return self
        P = self.num_shards
        # symmetrized adjacency over global indices: each stored directed
        # edge contributes a->b and b->a (both endpoints' degrees count it,
        # exactly as the reference adjacency build did) — assembled in
        # O(E) from the stored CSRs, no global edge sort
        deg, indptr, nbr = _merged_adjacency(graph, offs, total)
        # global index is monotone in (tid, nid), so this reproduces the
        # reference sort key (-deg, (tid, nid)) exactly
        order = np.lexsort((np.arange(total), -deg))
        cap = max(1, int(np.ceil(total / P * self.balance_slack)))
        sizes = np.zeros(P, np.int64)
        assign = np.full(total, -1, np.int64)
        # composite selection key: votes dominate, then least-loaded open
        # shard, then shard index — max(votes*A + base) reproduces the
        # reference lexsort because A exceeds the full spread of `base`.
        # The inner loop runs over PYTHON scalars: P is tiny (shard count),
        # so a list max beats per-node numpy dispatch by ~20x.
        base = [-p for p in range(P)]      # maintained incrementally
        A = (cap + 2) * P
        CLOSED = -(1 << 62)                # below any open-shard key
        shard_range = tuple(range(1, P))
        sizes_l = [0] * P
        pos = np.full(total, -1, np.int64)  # scratch: index within chunk
        for start in range(0, total, chunk_size):
            chunk = order[start:start + chunk_size]
            C = len(chunk)
            nb, rep, _ = _slice_gather(nbr, indptr, chunk)
            placed = assign[nb]
            ok = placed >= 0
            votes = np.zeros((C, P), np.int64)
            np.add.at(votes, (rep[ok], placed[ok]), 1)
            # intra-chunk edges: a neighbor later in this chunk receives a
            # vote the moment this node is assigned (reference semantics:
            # votes count ALL already-placed neighbors)
            pos[chunk] = np.arange(C)
            nbp = pos[nb]
            intra = nbp > rep
            # rep is nondecreasing by construction and masking preserves
            # order, so isrc is already grouped — no per-chunk sort needed
            isrc = rep[intra]
            idst = nbp[intra].tolist()
            istart = np.searchsorted(isrc, np.arange(C + 1)).tolist()
            vlist = votes.tolist()
            picks = []
            append = picks.append
            for row, lo, hi in zip(vlist, istart, istart[1:]):
                best, bk = 0, row[0] * A + base[0]
                for p in shard_range:
                    k = row[p] * A + base[p]
                    if k > bk:
                        best, bk = p, k
                append(best)
                sz = sizes_l[best] + 1
                sizes_l[best] = sz
                if sz >= cap:
                    base[best] = CLOSED
                else:
                    base[best] -= P
                if lo != hi:
                    for t in idst[lo:hi]:
                        vlist[t][best] += 1
            pos[chunk] = -1
            assign[chunk] = picks          # visible to the next chunk's pass
        # dense per-type owner arrays: the hot-path lookup is a vectorized
        # take, never a per-row dict probe
        for tname, tid in NODE_TYPE_ID.items():
            n = graph.num_nodes.get(tname, 0)
            if n:
                self._dense[tid] = assign[offs[tid]:offs[tid] + n].copy()
        return self

    def _fit_reference(self, graph: HeteroGraph) -> "GraphPartitioner":
        """The original per-node Python-loop fit, retained verbatim as the
        parity oracle for the chunked :meth:`fit` (bench + tests assert
        identical assignments)."""
        if self.strategy == "hash":
            return self
        self._assigned.clear()
        self._dense.clear()
        self._over.clear()
        adj: dict = {}
        deg: dict = {}
        for (s, d), csr in graph.adj.items():
            s_tid, d_tid = NODE_TYPE_ID[s], NODE_TYPE_ID[d]
            src = np.repeat(np.arange(len(csr.indptr) - 1), np.diff(csr.indptr))
            for u, v in zip(src, csr.indices):
                a, b = (s_tid, int(u)), (d_tid, int(v))
                adj.setdefault(a, []).append(b)
                adj.setdefault(b, []).append(a)
                deg[a] = deg.get(a, 0) + 1
                deg[b] = deg.get(b, 0) + 1
        every = [(t, i) for tname, t in NODE_TYPE_ID.items()
                 for i in range(graph.num_nodes.get(tname, 0))]
        total = len(every)
        cap = max(1, int(np.ceil(total / self.num_shards * self.balance_slack)))
        sizes = np.zeros(self.num_shards, np.int64)
        # high-degree nodes first: they anchor their neighborhoods
        order = sorted(every, key=lambda k: (-deg.get(k, 0), k))
        for key in order:
            votes = np.zeros(self.num_shards, np.float64)
            for nb in adj.get(key, ()):
                s = self._assigned.get(nb)
                if s is not None:
                    votes[s] += 1.0
            # cap math guarantees an open shard: P·cap ≥ total placements
            open_ = sizes < cap
            votes[~open_] = -np.inf
            # tie-break toward the least-loaded open shard, then shard index
            best = np.lexsort((np.arange(self.num_shards), sizes, -votes))[0]
            self._assigned[key] = int(best)
            sizes[best] += 1
        for tname, tid in NODE_TYPE_ID.items():
            n = graph.num_nodes.get(tname, 0)
            if n:
                self._dense[tid] = np.array(
                    [self._assigned[(tid, i)] for i in range(n)], np.int64)
        self._assigned.clear()             # the dense arrays are the map now
        return self

    # ---- diagnostics ----------------------------------------------------
    def cut_stats(self, graph: HeteroGraph) -> dict:
        """Edge-cut fraction + shard balance over a snapshot, in one
        grouped-numpy pass over the flat edge arrays (shared with ``fit``)
        and ONE ``shard_array`` resolution per node type."""
        src, dst, offs, total = _edge_arrays(graph)
        owners = np.zeros(total, np.int64)
        sizes = np.zeros(self.num_shards, np.int64)
        for tname, tid in NODE_TYPE_ID.items():
            n = graph.num_nodes.get(tname, 0)
            if n:
                own = self.shard_array(np.full(n, tid), np.arange(n))
                owners[offs[tid]:offs[tid] + n] = own
                sizes += np.bincount(own, minlength=self.num_shards)
        cut = int((owners[src] != owners[dst]).sum()) if len(src) else 0
        mean = sizes.mean() if sizes.sum() else 1.0
        return {"cut_fraction": cut / max(len(src), 1),
                "cut_edges": cut, "total_edges": int(len(src)),
                "shard_sizes": sizes.tolist(),
                "balance": float(sizes.max() / max(mean, 1e-9))}


# ------------------------------------------------------------------ engine


class ShardedEngine:
    """P shard-local :class:`StreamingEngine`s behind one GraphEngine.

    Reads group the flat (type, id) rows by owner shard, answer each group
    on that shard's local stores, and scatter results back into row order;
    writes route by the *source* node.  Because every store operation is
    per-source-node, the composite is bit-identical to a single engine (see
    the module docstring for the relation-order caveat).
    """

    def __init__(self, feat_dim: int, partitioner: GraphPartitioner, *,
                 max_neighbors: int = 64, strategy: str = "uniform"):
        self.feat_dim = feat_dim
        self.partitioner = partitioner
        self.max_neighbors = max_neighbors
        self.strategy = strategy
        self.shards = [StreamingEngine(feat_dim, max_neighbors=max_neighbors,
                                       strategy=strategy)
                       for _ in range(partitioner.num_shards)]

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    @property
    def join_reads(self) -> int:
        return sum(sh.join_reads for sh in self.shards)

    # ---- writes ---------------------------------------------------------
    def bootstrap_from_graph(self, graph: HeteroGraph) -> None:
        """Per-shard restricted bootstrap: every shard registers EVERY
        snapshot relation (in snapshot order — the merged-offset contract),
        loaded with only the rows whose source it owns; features go to
        their owner's store."""
        part = self.partitioner
        for ntype in NODE_TYPES:
            feats = graph.features[ntype]
            n = feats.shape[0]
            if n == 0:
                continue
            tid = NODE_TYPE_ID[ntype]
            owners = part.shard_array(np.full(n, tid), np.arange(n))
            for p in range(self.num_shards):
                ids = np.nonzero(owners == p)[0]
                self.shards[p].feature_store.put_many(
                    ((tid, int(i)), feats[i]) for i in ids)
        for (s, d), csr in graph.adj.items():
            n = len(csr.indptr) - 1
            deg = np.diff(csr.indptr)
            owners = part.shard_array(np.full(n, NODE_TYPE_ID[s]), np.arange(n))
            for p in range(self.num_shards):
                keep = owners == p
                cnt = np.where(keep, deg, 0)
                indptr_p = np.zeros(n + 1, np.int64)
                np.cumsum(cnt, out=indptr_p[1:])
                indices_p = csr.indices[np.repeat(keep, deg)]
                self.shards[p].neighbor_store.bulk_load(s, d, indptr_p, indices_p)

    def add_edge(self, src_type: str, src_id: int, dst_type: str,
                 dst_id: int) -> None:
        p = self.partitioner.shard_of(src_type, src_id)
        self.shards[p].add_edge(src_type, src_id, dst_type, dst_id)

    def put_feature(self, tid: int, nid: int, feat: np.ndarray) -> None:
        p = self.partitioner.shard_of(tid, nid)
        self.shards[p].put_feature(tid, nid, feat)

    # ---- elasticity + checkpoint (DESIGN.md §12) ------------------------
    def add_shard(self) -> int:
        """Append one empty shard engine, pre-registering every relation
        shard 0 knows in the SAME insertion order (the merged-offset
        contract must hold on the new shard before any row migrates in)."""
        eng = StreamingEngine(self.feat_dim, max_neighbors=self.max_neighbors,
                              strategy=self.strategy)
        if self.shards:
            eng.neighbor_store.register_relations_like(
                self.shards[0].neighbor_store)
        self.shards.append(eng)
        return len(self.shards) - 1

    def migrate_node(self, node_type: str, node_id: int, src: int,
                     dst: int) -> int:
        """Move one node's engine-side state (ring rows sourced at it + its
        feature entry) from shard ``src`` to shard ``dst``; returns the
        number of ring rows moved.  Rows land in the destination's relations
        in the source's insertion order, which matches under the append-only
        relation regime (module docstring)."""
        a, b = self.shards[src], self.shards[dst]
        nid = int(node_id)
        rows = a.neighbor_store.export_node(node_type, nid)
        b.neighbor_store.import_node(nid, rows)
        tid = NODE_TYPE_ID[node_type]
        feat = a.feature_store._d.pop((tid, nid), None)
        if feat is not None:
            b.feature_store.put((tid, nid), feat)
        return len(rows)

    def snapshot(self) -> dict:
        return {"shards": [sh.snapshot() for sh in self.shards]}

    def restore(self, state: dict) -> None:
        assert len(state["shards"]) == len(self.shards), \
            (len(state["shards"]), len(self.shards))
        for sh, st in zip(self.shards, state["shards"]):
            sh.restore(st)

    # ---- reads (scatter by owner, gather by row) ------------------------
    def get_feature(self, tid: int, nid: int) -> np.ndarray:
        return self.shards[self.partitioner.shard_of(tid, nid)].get_feature(tid, nid)

    def neighbors(self, tid: int, nid: int):
        return self.shards[self.partitioner.shard_of(tid, nid)].neighbors(tid, nid)

    def _owner_groups(self, types: np.ndarray, ids: np.ndarray):
        owners = self.partitioner.shard_array(types, ids)
        for p in range(self.num_shards):
            sel = np.nonzero(owners == p)[0]
            if sel.size:
                yield p, sel

    def counts(self, types: np.ndarray, ids: np.ndarray) -> np.ndarray:
        out = np.zeros(len(ids), np.int64)
        for p, sel in self._owner_groups(types, ids):
            out[sel] = self.shards[p].counts(types[sel], ids[sel])
        return out

    def sample_batched(self, types: np.ndarray, ids: np.ndarray, fanout: int,
                       uniforms: np.ndarray):
        n = ids.shape[0]
        out_ty = np.zeros((n, fanout), np.int32)
        out_id = np.zeros((n, fanout), np.int32)
        out_mask = np.zeros((n, fanout), np.float32)
        for p, sel in self._owner_groups(types, ids):
            t, i, m = self.shards[p].sample_batched(types[sel], ids[sel],
                                                    fanout, uniforms[sel])
            out_ty[sel], out_id[sel], out_mask[sel] = t, i, m
        return out_ty, out_id, out_mask

    def gather_features(self, types: np.ndarray, ids: np.ndarray) -> np.ndarray:
        flat_t = types.reshape(-1).astype(np.int64)
        flat_i = ids.reshape(-1).astype(np.int64)
        out = np.zeros((flat_t.shape[0], self.feat_dim), np.float32)
        for p, sel in self._owner_groups(flat_t, flat_i):
            out[sel] = self.shards[p].gather_features(flat_t[sel], flat_i[sel])
        return out.reshape(*types.shape, self.feat_dim)


class ShardView:
    """A shard-pinned view of a :class:`ShardedEngine`.

    Implements the same GraphEngine protocol by delegating to the composite
    engine, while accounting how many query rows resolved on the home shard
    vs remotely — the scatter-gather fan-out a deployment pays network RPCs
    for.  Each shard's :class:`EmbeddingLifecycle` builds tiles through its
    own view, so remote-resolution cost is attributable per shard.
    """

    def __init__(self, engine: ShardedEngine, home: int):
        self.inner = engine
        self.home = int(home)
        self.local_rows = 0
        self.remote_rows = 0

    @property
    def feat_dim(self) -> int:
        return self.inner.feat_dim

    @property
    def join_reads(self) -> int:
        return self.inner.join_reads

    def _account(self, types, ids) -> None:
        owners = self.inner.partitioner.shard_array(
            np.asarray(types).reshape(-1), np.asarray(ids).reshape(-1))
        local = int((owners == self.home).sum())
        self.local_rows += local
        self.remote_rows += owners.size - local

    def counts(self, types, ids):
        self._account(types, ids)
        return self.inner.counts(types, ids)

    def sample_batched(self, types, ids, fanout, uniforms):
        self._account(types, ids)
        return self.inner.sample_batched(types, ids, fanout, uniforms)

    def gather_features(self, types, ids):
        self._account(types, ids)
        return self.inner.gather_features(types, ids)
