"""Transfer learning: frozen GNN encoder → downstream ranking DNNs (§5.1).

Mirrors Figure 3 (right): the downstream job-matching model concatenates the
*precomputed* GNN member/job embeddings with other relevant features and
trains its own objective; the GNN encoder is never updated here.  Each
product surface from §7 has a head:

  * TAJ      — predicts recruiter interaction after an application
  * JYMBII   — predicts qualified application (personalized recommendations)
  * JobSearch— ranking head with a query-affinity feature
  * EBR      — embedding-based retrieval (two-tower projection of GNN embs)

To avoid label leakage (§5.1) the caller must train the GNN on engagement
data strictly *preceding* the ranker's label window — enforced here by
accepting the embeddings as plain arrays (whatever snapshot produced them).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.optim import adamw_init, adamw_update


@dataclass(frozen=True)
class RankerConfig:
    name: str = "jymbii"
    other_feat_dim: int = 64         # non-GNN features (profile/job features)
    gnn_embed_dim: int = 128
    hidden: int = 256
    use_gnn: bool = True             # ablation switch (the A/B control arm)
    num_hidden_layers: int = 2


def ranker_init(key, cfg: RankerConfig):
    d_in = 2 * cfg.other_feat_dim + (2 * cfg.gnn_embed_dim if cfg.use_gnn else 0)
    ks = jax.random.split(key, cfg.num_hidden_layers + 1)
    layers = []
    d = d_in
    for i in range(cfg.num_hidden_layers):
        layers.append(nn.dense_init(ks[i], d, cfg.hidden, use_bias=True))
        d = cfg.hidden
    return {"layers": layers, "out": nn.dense_init(ks[-1], d, 1, use_bias=True)}


def ranker_apply(params, cfg: RankerConfig, m_feat, j_feat, m_gnn=None, j_gnn=None):
    parts = [m_feat, j_feat]
    if cfg.use_gnn:
        parts += [m_gnn, j_gnn]
    x = jnp.concatenate(parts, axis=-1)
    for layer in params["layers"]:
        x = jax.nn.gelu(nn.dense_apply(layer, x))
    return nn.dense_apply(params["out"], x)[..., 0]


def _bce(logits, labels):
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


class RankerState(NamedTuple):
    params: dict
    opt: object


@functools.partial(jax.jit, static_argnames=("cfg", "lr"))
def ranker_train_step(state: RankerState, cfg: RankerConfig, batch, *, lr=1e-3):
    def lf(p):
        logits = ranker_apply(p, cfg, batch["m_feat"], batch["j_feat"],
                              batch.get("m_gnn"), batch.get("j_gnn"))
        return _bce(logits, batch["label"])

    loss, grads = jax.value_and_grad(lf)(state.params)
    params, opt = adamw_update(state.params, grads, state.opt, lr=lr,
                               weight_decay=1e-4)
    return RankerState(params, opt), loss


class DownstreamRanker:
    """Trainable ranking head over frozen GNN embeddings + other features."""

    def __init__(self, cfg: RankerConfig, seed: int = 0):
        self.cfg = cfg
        params = ranker_init(jax.random.PRNGKey(seed), cfg)
        self.state = RankerState(params, adamw_init(params))

    def fit(self, dataset: dict, *, epochs: int = 5, batch_size: int = 256,
            lr: float = 1e-3, seed: int = 0):
        n = len(dataset["label"])
        rng = np.random.default_rng(seed)
        losses = []
        for _ in range(epochs):
            order = rng.permutation(n)
            for i in range(0, n - batch_size + 1, batch_size):
                idx = order[i:i + batch_size]
                batch = {k: jnp.asarray(v[idx]) for k, v in dataset.items()}
                self.state, loss = ranker_train_step(self.state, self.cfg, batch, lr=lr)
                losses.append(float(loss))
        return losses

    def score(self, dataset: dict, batch_size: int = 1024) -> np.ndarray:
        n = len(dataset["m_feat"])
        out = []
        for i in range(0, n, batch_size):
            batch = {k: jnp.asarray(v[i:i + batch_size]) for k, v in dataset.items()
                     if k != "label"}
            out.append(np.asarray(ranker_apply(
                self.state.params, self.cfg, batch["m_feat"], batch["j_feat"],
                batch.get("m_gnn"), batch.get("j_gnn"))))
        return np.concatenate(out)


def build_ranker_dataset(member_feat, job_feat, m_gnn, j_gnn, pairs, labels,
                         *, use_gnn=True):
    """Assemble the per-pair training table the nearline store would serve."""
    m_idx, j_idx = pairs
    ds = {
        "m_feat": member_feat[m_idx].astype(np.float32),
        "j_feat": job_feat[j_idx].astype(np.float32),
        "label": labels.astype(np.float32),
    }
    if use_gnn:
        ds["m_gnn"] = m_gnn[m_idx].astype(np.float32)
        ds["j_gnn"] = j_gnn[j_idx].astype(np.float32)
    return ds
