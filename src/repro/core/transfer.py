"""Transfer learning: frozen GNN encoder → per-surface downstream DNNs
(§5.1, §7).

Mirrors Figure 3 (right): each downstream model concatenates the
*precomputed* GNN member/job embeddings with other relevant features and
trains its own objective; the GNN encoder is never updated here.  Every §7
product surface has a real head in the :data:`SURFACES` registry:

  * taj       — Talent-Asset-Job: predicts recruiter interaction after an
                application (MLP ranker, §7.1)
  * jymbii    — Jobs-You-May-Be-Interested-In: predicts qualified
                application (MLP ranker, §7.2)
  * jobsearch — search ranking head with a query-affinity feature: the
                query embedding is projected into GNN space and its cosine
                against the job's GNN embedding rides along as an explicit
                feature (§7.3)
  * ebr       — embedding-based retrieval: a genuine two-tower projection
                of (features ⊕ GNN embeddings), evaluated with
                ``eval.recall_at_k`` retrieval (§7.4)

Label-leakage safety (§5.1): heads train on embeddings read out of the
versioned :class:`repro.core.embeddings.EmbeddingStore` at an *explicit
published version* (``store.gather(..., version=v)``) — training the GNN on
engagement data strictly preceding the ranker's label window is enforced by
the version pin, not by convention.  :class:`MultiSurfaceTrainer` trains all
registered heads in one jitted step that gathers the member/job embedding
rows from the version-pinned tables ONCE and fans them out to every head.

The generic :class:`DownstreamRanker` (one MLP head over plain arrays) is
retained as the minimal single-surface path.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.optim import adamw_init, adamw_update


# ------------------------------------------------------------ generic head


@dataclass(frozen=True)
class RankerConfig:
    name: str = "jymbii"
    other_feat_dim: int = 64         # non-GNN features (profile/job features)
    gnn_embed_dim: int = 128
    hidden: int = 256
    use_gnn: bool = True             # ablation switch (the A/B control arm)
    num_hidden_layers: int = 2
    query_dim: int = 0               # jobsearch: width of the query feature
    tower_dim: int = 64              # ebr: retrieval embedding width


def ranker_init(key, cfg: RankerConfig):
    d_in = 2 * cfg.other_feat_dim + (2 * cfg.gnn_embed_dim if cfg.use_gnn else 0)
    ks = jax.random.split(key, cfg.num_hidden_layers + 1)
    layers = []
    d = d_in
    for i in range(cfg.num_hidden_layers):
        layers.append(nn.dense_init(ks[i], d, cfg.hidden, use_bias=True))
        d = cfg.hidden
    return {"layers": layers, "out": nn.dense_init(ks[-1], d, 1, use_bias=True)}


def ranker_apply(params, cfg: RankerConfig, m_feat, j_feat, m_gnn=None, j_gnn=None):
    parts = [m_feat, j_feat]
    if cfg.use_gnn:
        parts += [m_gnn, j_gnn]
    x = jnp.concatenate(parts, axis=-1)
    for layer in params["layers"]:
        x = jax.nn.gelu(nn.dense_apply(layer, x))
    return nn.dense_apply(params["out"], x)[..., 0]


def _bce(logits, labels):
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


class RankerState(NamedTuple):
    params: dict
    opt: object


@functools.partial(jax.jit, static_argnames=("cfg", "lr"))
def ranker_train_step(state: RankerState, cfg: RankerConfig, batch, *, lr=1e-3):
    def lf(p):
        logits = ranker_apply(p, cfg, batch["m_feat"], batch["j_feat"],
                              batch.get("m_gnn"), batch.get("j_gnn"))
        return _bce(logits, batch["label"])

    loss, grads = jax.value_and_grad(lf)(state.params)
    params, opt = adamw_update(state.params, grads, state.opt, lr=lr,
                               weight_decay=1e-4)
    return RankerState(params, opt), loss


class DownstreamRanker:
    """Trainable ranking head over frozen GNN embeddings + other features."""

    def __init__(self, cfg: RankerConfig, seed: int = 0):
        self.cfg = cfg
        params = ranker_init(jax.random.PRNGKey(seed), cfg)
        self.state = RankerState(params, adamw_init(params))

    def fit(self, dataset: dict, *, epochs: int = 5, batch_size: int = 256,
            lr: float = 1e-3, seed: int = 0):
        n = len(dataset["label"])
        rng = np.random.default_rng(seed)
        losses = []
        for _ in range(epochs):
            order = rng.permutation(n)
            for i in range(0, n - batch_size + 1, batch_size):
                idx = order[i:i + batch_size]
                batch = {k: jnp.asarray(v[idx]) for k, v in dataset.items()}
                self.state, loss = ranker_train_step(self.state, self.cfg, batch, lr=lr)
                losses.append(float(loss))
        return losses

    def score(self, dataset: dict, batch_size: int = 1024) -> np.ndarray:
        n = len(dataset["m_feat"])
        out = []
        for i in range(0, n, batch_size):
            batch = {k: jnp.asarray(v[i:i + batch_size]) for k, v in dataset.items()
                     if k != "label"}
            out.append(np.asarray(ranker_apply(
                self.state.params, self.cfg, batch["m_feat"], batch["j_feat"],
                batch.get("m_gnn"), batch.get("j_gnn"))))
        return np.concatenate(out)


def build_ranker_dataset(member_feat, job_feat, m_gnn, j_gnn, pairs, labels,
                         *, use_gnn=True):
    """Assemble the per-pair training table the nearline store would serve."""
    m_idx, j_idx = pairs
    ds = {
        "m_feat": member_feat[m_idx].astype(np.float32),
        "j_feat": job_feat[j_idx].astype(np.float32),
        "label": labels.astype(np.float32),
    }
    if use_gnn:
        ds["m_gnn"] = m_gnn[m_idx].astype(np.float32)
        ds["j_gnn"] = j_gnn[j_idx].astype(np.float32)
    return ds


# --------------------------------------------------------- surface registry
#
# A surface is a stateless head definition: init(key, cfg) -> params and
# apply(params, cfg, batch) -> logits over a gathered per-pair batch with
# keys m_feat/j_feat [B, f], m_gnn/j_gnn [B, e] and (jobsearch) q_feat
# [B, q].  Losses are sigmoid-CE against batch["label"]; EBR additionally
# exposes its towers for recall@k retrieval evaluation.


SURFACES: dict = {}


def register_surface(cls):
    SURFACES[cls.name] = cls()
    return cls


class Surface:
    """Base: the MLP ranker over concat(features, GNN embeddings)."""

    name = "base"

    def init(self, key, cfg: RankerConfig):
        return ranker_init(key, cfg)

    def apply(self, params, cfg: RankerConfig, batch):
        return ranker_apply(params, cfg, batch["m_feat"], batch["j_feat"],
                            batch.get("m_gnn"), batch.get("j_gnn"))

    def loss(self, params, cfg: RankerConfig, batch):
        return _bce(self.apply(params, cfg, batch), batch["label"])


@register_surface
class TAJSurface(Surface):
    """Talent-Asset-Job: recruiter-interaction-after-application (§7.1)."""
    name = "taj"


@register_surface
class JYMBIISurface(Surface):
    """Jobs-You-May-Be-Interested-In: qualified application (§7.2)."""
    name = "jymbii"


@register_surface
class JobSearchSurface(Surface):
    """Search ranking with a query-affinity feature (§7.3): the query is
    projected into the job-embedding space and its cosine against the job
    tower rides along as an explicit scalar feature.  The control arm
    (use_gnn=False) computes the affinity against the raw job features, so
    the ablation isolates the GNN signal rather than the feature's shape."""

    name = "jobsearch"

    def init(self, key, cfg: RankerConfig):
        assert cfg.query_dim > 0, "jobsearch needs query_dim"
        k1, k2 = jax.random.split(key)
        d_in = (2 * cfg.other_feat_dim + cfg.query_dim + 1
                + (2 * cfg.gnn_embed_dim if cfg.use_gnn else 0))
        ks = jax.random.split(k1, cfg.num_hidden_layers + 1)
        layers = []
        d = d_in
        for i in range(cfg.num_hidden_layers):
            layers.append(nn.dense_init(ks[i], d, cfg.hidden, use_bias=True))
            d = cfg.hidden
        target = cfg.gnn_embed_dim if cfg.use_gnn else cfg.other_feat_dim
        return {"layers": layers, "out": nn.dense_init(ks[-1], d, 1, use_bias=True),
                "query_proj": nn.dense_init(k2, cfg.query_dim, target)}

    def apply(self, params, cfg: RankerConfig, batch):
        q = nn.dense_apply(params["query_proj"], batch["q_feat"])
        target = batch["j_gnn"] if cfg.use_gnn else batch["j_feat"]
        affinity = (jnp.sum(q * target, axis=-1)
                    / (jnp.linalg.norm(q, axis=-1)
                       * jnp.linalg.norm(target, axis=-1) + 1e-6))
        parts = [batch["m_feat"], batch["j_feat"], batch["q_feat"],
                 affinity[..., None]]
        if cfg.use_gnn:
            parts += [batch["m_gnn"], batch["j_gnn"]]
        x = jnp.concatenate(parts, axis=-1)
        for layer in params["layers"]:
            x = jax.nn.gelu(nn.dense_apply(layer, x))
        return nn.dense_apply(params["out"], x)[..., 0]


@register_surface
class EBRSurface(Surface):
    """Embedding-based retrieval (§7.4): a genuine two-tower projection —
    member tower over (member features ⊕ member GNN emb), job tower over
    (job features ⊕ job GNN emb) — trained on engagement labels via the
    dot-product score and evaluated with ``eval.recall_at_k``."""

    name = "ebr"

    def _tower_init(self, key, d_in, cfg: RankerConfig):
        k1, k2 = jax.random.split(key)
        return {"h": nn.dense_init(k1, d_in, cfg.hidden, use_bias=True),
                "out": nn.dense_init(k2, cfg.hidden, cfg.tower_dim, use_bias=True)}

    @staticmethod
    def _tower_apply(tp, x):
        return nn.dense_apply(tp["out"], jax.nn.gelu(nn.dense_apply(tp["h"], x)))

    def init(self, key, cfg: RankerConfig):
        d_in = cfg.other_feat_dim + (cfg.gnn_embed_dim if cfg.use_gnn else 0)
        k1, k2 = jax.random.split(key)
        return {"m_tower": self._tower_init(k1, d_in, cfg),
                "j_tower": self._tower_init(k2, d_in, cfg)}

    def towers(self, params, cfg: RankerConfig, m_in, j_in):
        """(member inputs [M, d_in], job inputs [J, d_in]) -> the retrieval
        vectors ([M, t], [J, t]); score(i, j) = m_vec_i · j_vec_j."""
        return (self._tower_apply(params["m_tower"], m_in),
                self._tower_apply(params["j_tower"], j_in))

    @staticmethod
    def tower_inputs(cfg: RankerConfig, feat, gnn):
        return (jnp.concatenate([feat, gnn], axis=-1) if cfg.use_gnn else feat)

    def apply(self, params, cfg: RankerConfig, batch):
        m_vec, j_vec = self.towers(
            params, cfg,
            self.tower_inputs(cfg, batch["m_feat"], batch.get("m_gnn")),
            self.tower_inputs(cfg, batch["j_feat"], batch.get("j_gnn")))
        return jnp.sum(m_vec * j_vec, axis=-1)

    @staticmethod
    def build_index(job_vectors, *, job_ids=None, quantize="per_row",
                    num_lists: int | None = 0, seed: int = 0,
                    version: int | None = None):
        """The serving-side retrieval tier over this surface's job tower
        output (core.retrieval, DESIGN.md §14): int8 quantized replica +
        IVF coarse lists; ``search(member_vectors, k, nprobe=...)`` replaces
        the dense ``m_vec @ j_vec.T`` scan.  ``quantize=None`` /
        ``num_lists=None`` yield the exact fp32 config, bit-identical to
        ``retrieval.brute_force_topk`` (the parity oracle)."""
        from repro.core.retrieval import RetrievalIndex
        return RetrievalIndex.build(np.asarray(job_vectors, np.float32),
                                    ids=job_ids, scheme=quantize,
                                    num_lists=num_lists, seed=seed,
                                    version=version)


def surface_configs(names=None, **overrides) -> dict:
    """Per-surface RankerConfigs with shared overrides applied; jobsearch
    defaults its query_dim to the member feature width if unset."""
    names = tuple(names or SURFACES)
    out = {}
    for name in names:
        cfg = replace(RankerConfig(name=name), **overrides)
        if name == "jobsearch" and cfg.query_dim == 0:
            cfg = replace(cfg, query_dim=cfg.other_feat_dim)
        out[name] = cfg
    return out


# ------------------------------------------------- multi-surface training


class MultiSurfaceTrainer:
    """All registered surface heads trained together over version-pinned
    embedding tables.

    The jitted step takes the per-node tables (member/job features, GNN
    embeddings from ``EmbeddingStore.gather(..., version=v)``, query
    features) plus an index batch, gathers each table's rows ONCE, and
    feeds the shared gathered batch to every head — one embedding gather
    serving four surfaces, the §5.1 "decoupled encoder, many consumers"
    dataflow in one XLA program.
    """

    def __init__(self, cfgs: dict, seed: int = 0):
        self.cfgs = dict(cfgs)
        keys = jax.random.split(jax.random.PRNGKey(seed), len(self.cfgs))
        params = {name: SURFACES[name].init(k, cfg)
                  for k, (name, cfg) in zip(keys, self.cfgs.items())}
        self.state = RankerState(params, adamw_init(params))
        self._step_cache: dict = {}

    # tables: m_feat [M,f], j_feat [J,f], m_gnn [M,e], j_gnn [J,e],
    #         q_feat [M,q] (jobsearch's query table, member-aligned)
    def _gathered_batch(self, tables, m_idx, j_idx):
        b = {"m_feat": tables["m_feat"][m_idx], "j_feat": tables["j_feat"][j_idx]}
        if "m_gnn" in tables:
            b["m_gnn"] = tables["m_gnn"][m_idx]        # THE shared gather
            b["j_gnn"] = tables["j_gnn"][j_idx]
        if "q_feat" in tables:
            b["q_feat"] = tables["q_feat"][m_idx]
        return b

    def _make_step(self, lr: float):
        cfg_items = tuple(self.cfgs.items())

        def step(state, tables, m_idx, j_idx, labels):
            def lf(p):
                shared = self._gathered_batch(tables, m_idx, j_idx)
                per = {}
                for name, cfg in cfg_items:
                    batch = dict(shared, label=labels[name])
                    per[name] = SURFACES[name].loss(p[name], cfg, batch)
                total = sum(per.values())
                return total, per

            (_, per), grads = jax.value_and_grad(lf, has_aux=True)(state.params)
            params, opt = adamw_update(state.params, grads, state.opt, lr=lr,
                                       weight_decay=1e-4)
            return RankerState(params, opt), per

        return jax.jit(step)

    def _get_step(self, lr: float):
        if lr not in self._step_cache:
            self._step_cache[lr] = self._make_step(lr)
        return self._step_cache[lr]

    def fit(self, tables: dict, pairs, labels: dict, *, epochs: int = 5,
            batch_size: int = 256, lr: float = 1e-3, seed: int = 0):
        """``pairs`` = (m_idx [N], j_idx [N]); ``labels[name]`` = [N] per
        surface.  Returns the per-surface loss history."""
        m_idx, j_idx = (np.asarray(pairs[0]), np.asarray(pairs[1]))
        n = len(m_idx)
        assert n > 0, "fit needs at least one pair"
        batch_size = min(batch_size, n)     # small datasets still take steps
        dev_tables = {k: jnp.asarray(v) for k, v in tables.items()}
        labels = {k: np.asarray(v, np.float32) for k, v in labels.items()}
        step = self._get_step(lr)
        rng = np.random.default_rng(seed)
        history = {name: [] for name in self.cfgs}
        for _ in range(epochs):
            order = rng.permutation(n)
            for i in range(0, n - batch_size + 1, batch_size):
                idx = order[i:i + batch_size]
                lb = {k: jnp.asarray(v[idx]) for k, v in labels.items()}
                self.state, per = step(self.state, dev_tables,
                                       jnp.asarray(m_idx[idx]),
                                       jnp.asarray(j_idx[idx]), lb)
                for name, l in per.items():
                    history[name].append(float(l))
        return history

    def score(self, tables: dict, pairs, batch_size: int = 2048) -> dict:
        """Per-surface logits for explicit (m_idx, j_idx) pairs."""
        m_idx, j_idx = (np.asarray(pairs[0]), np.asarray(pairs[1]))
        dev_tables = {k: jnp.asarray(v) for k, v in tables.items()}
        out = {name: [] for name in self.cfgs}
        for i in range(0, len(m_idx), batch_size):
            batch = self._gathered_batch(dev_tables,
                                         jnp.asarray(m_idx[i:i + batch_size]),
                                         jnp.asarray(j_idx[i:i + batch_size]))
            for name, cfg in self.cfgs.items():
                out[name].append(np.asarray(
                    SURFACES[name].apply(self.state.params[name], cfg, batch)))
        return {name: np.concatenate(v) for name, v in out.items()}

    def ebr_vectors(self, tables: dict):
        """Full member/job retrieval vectors from the EBR two-tower head."""
        cfg = self.cfgs["ebr"]
        ebr = SURFACES["ebr"]

        def dev(key):
            return jnp.asarray(tables[key]) if key in tables else None

        m_in = ebr.tower_inputs(cfg, dev("m_feat"), dev("m_gnn"))
        j_in = ebr.tower_inputs(cfg, dev("j_feat"), dev("j_gnn"))
        m_vec, j_vec = ebr.towers(self.state.params["ebr"], cfg, m_in, j_in)
        return np.asarray(m_vec), np.asarray(j_vec)
