"""GNN decoders + link-prediction losses (paper §4.2).

Supported decoders:
  * in-batch negatives:  score(i,j) = M_i · J_j over the full B×B grid,
    y_ij = 1 on matched pairs; sigmoid cross-entropy (paper's Loss eq).
  * MLP:     score = MLP(concat(m, j)) for explicit (m, j, label) tuples.
  * cosine:  score = s · cos(m, j).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.linksage import GNNConfig


def decoder_init(key, cfg: GNNConfig):
    if cfg.decoder == "mlp":
        return {"mlp": nn.mlp_init(key, 2 * cfg.embed_dim, cfg.mlp_decoder_hidden, 1)}
    return {}


def pair_scores(params, cfg: GNNConfig, m_emb, j_emb):
    """Scores for aligned pairs: m_emb [B,e], j_emb [B,e] -> [B]."""
    if cfg.decoder == "mlp":
        x = jnp.concatenate([m_emb, j_emb], axis=-1)
        return nn.mlp_apply(params["mlp"], x)[..., 0]
    if cfg.decoder == "cosine":
        m = m_emb / (jnp.linalg.norm(m_emb, axis=-1, keepdims=True) + 1e-6)
        j = j_emb / (jnp.linalg.norm(j_emb, axis=-1, keepdims=True) + 1e-6)
        return cfg.cosine_scale * jnp.sum(m * j, axis=-1)
    return jnp.sum(m_emb * j_emb, axis=-1)


def inbatch_score_matrix(m_emb, j_emb):
    """Full B_m × B_j dot-product score grid (in-batch negative decoder)."""
    return m_emb @ j_emb.T


def inbatch_logits(cfg: GNNConfig, m_emb, j_emb):
    """The in-batch decoder's full score grid, per decoder convention.

    The cosine arm normalizes BOTH towers before scaling — the same
    convention as :func:`pair_scores`, so the grid's diagonal agrees with
    the aligned-pair scores (regression-pinned in tests)."""
    if cfg.decoder == "cosine":
        m_emb = m_emb / (jnp.linalg.norm(m_emb, axis=-1, keepdims=True) + 1e-6)
        j_emb = j_emb / (jnp.linalg.norm(j_emb, axis=-1, keepdims=True) + 1e-6)
        return cfg.cosine_scale * inbatch_score_matrix(m_emb, j_emb)
    return inbatch_score_matrix(m_emb, j_emb)


def sigmoid_ce(logits, labels):
    """Numerically-stable sigmoid cross-entropy (paper's Loss equation)."""
    zeros = jnp.zeros_like(logits)
    return jnp.maximum(logits, zeros) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))


def inbatch_loss(cfg: GNNConfig, m_emb, j_emb, pos_mask=None):
    """Paper's in-batch negative loss: positives on the diagonal by default.

    ``pos_mask`` ([B,B] 0/1) overrides the diagonal when the batch contains
    duplicate members/jobs (y_ij from the label tuples).
    """
    scores = inbatch_logits(cfg, m_emb, j_emb)
    b = scores.shape[0]
    y = jnp.eye(b, dtype=scores.dtype) if pos_mask is None else pos_mask.astype(scores.dtype)
    return jnp.mean(sigmoid_ce(scores, y))


def pairwise_loss(params, cfg: GNNConfig, m_emb, j_emb, labels):
    """Explicit (member, job, label) tuple loss for the MLP/cosine decoders."""
    logits = pair_scores(params, cfg, m_emb, j_emb)
    return jnp.mean(sigmoid_ce(logits, labels.astype(logits.dtype)))
