"""GraphSAGE encoder (paper §4.2) with per-node-type transforms.

Implements both aggregation variants from the paper:

  mean:       M_i = (1/|N(i)|) Σ_n f(features(n))
  attention:  M_i = Σ_n α(i,n) · f(features(n))

f is a per-node-type linear transform (heterogeneity-aware); α is a masked
scaled-dot-product attention between the query node's hidden state and its
neighbors.  The aggregation inner loop is the perf-critical hot spot; BOTH
layer rules are served by fused Pallas kernels (``kops.sage_layer`` for the
mean path, ``kops.sage_attention_layer`` for attention) which dispatch to
the pure-jnp reference on CPU and to the compiled kernels on TPU.

Layer rule (GraphSAGE):  h_v ← σ(W_self·h_v + W_neigh·AGG_{n∈N(v)} h_n)
applied innermost-hop-first over the padded K-hop tile: at stage l every
remaining depth aggregates its children, so after K stages the query row
has absorbed its full K-hop neighborhood (K = len(cfg.fanouts) =
cfg.num_sage_layers; each stage's kernels flatten the leading hop dims, so
K=3 runs through the same fused Pallas kernels as K=2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.linksage import GNNConfig
from repro.kernels import ops as kops


def encoder_init(key, cfg: GNNConfig):
    ks = jax.random.split(key, 8)
    T, d_in, h, e = cfg.num_node_types, cfg.feat_dim, cfg.hidden_dim, cfg.embed_dim
    params = {
        # per-node-type input transform f_t (stacked over types)
        "type_transform": {
            "w": jax.random.truncated_normal(ks[0], -2, 2, (T, d_in, h), jnp.float32) / jnp.sqrt(d_in),
            "b": jnp.zeros((T, h), jnp.float32),
        },
        "layers": [],
        "out": nn.dense_init(ks[1], h, e),
    }
    for i in range(cfg.num_sage_layers):
        kl = jax.random.split(ks[2 + i], 4)
        layer = {
            "self": nn.dense_init(kl[0], h, h, use_bias=True),
            "neigh": nn.dense_init(kl[1], h, h, use_bias=True),
        }
        if cfg.aggregator == "attention":
            layer["attn_q"] = nn.dense_init(kl[2], h, h)
            layer["attn_k"] = nn.dense_init(kl[3], h, h)
        params["layers"].append(layer)
    return params


# Crossover for _type_transform: the weight gather moves O(d·h) bytes per
# element while the masked select spends O(T·d·h) FLOPs per element; dense
# hardware (MXU / AVX) trades ~100 matmul FLOPs per byte of gather traffic,
# so per-element weights only win once there are many node types.
_GATHER_MIN_TYPES = 16


def _type_transform(p, x, types):
    """Per-type linear: x [..., d_in], types [...] int -> [..., h].

    Many types: gather each element's own W_t/b_t (take along the type axis)
    and do one batched contraction — FLOPs are O(N·d·h) independent of the
    number of node types.  Few types (the 6-type marketplace graph): a fused
    masked accumulation that, unlike the old compute-all-T-projections-then-
    select, never materializes the [..., T, h] projection tensor.
    """
    T = p["w"].shape[0]
    w = p["w"].astype(x.dtype)
    b = p["b"].astype(x.dtype)
    if T >= _GATHER_MIN_TYPES:
        ws = jnp.take(w, types, axis=0)                    # [..., d, h]
        return jnp.einsum("...d,...dh->...h", x, ws) + jnp.take(b, types, axis=0)
    out = jnp.take(b, types, axis=0)
    for t in range(T):
        sel = (types == t)[..., None].astype(x.dtype)
        out = out + sel * (x @ w[t])
    return out


def _sage_layer(layer, cfg: GNNConfig, h_self, h_neigh, mask):
    if cfg.aggregator == "mean":
        # fused kernel: masked mean + dual matmul + ReLU in one VMEM pass
        return kops.sage_layer(h_self, h_neigh, mask,
                               layer["self"]["w"], layer["self"]["b"],
                               layer["neigh"]["w"], layer["neigh"]["b"])
    # fused kernel: score → masked softmax → weighted sum → dual matmul →
    # ReLU in one VMEM pass; the q/k projections stay outside (plain
    # matmuls XLA already fuses well)
    q = nn.dense_apply(layer["attn_q"], h_self)
    k = nn.dense_apply(layer["attn_k"], h_neigh)
    return kops.sage_attention_layer(h_self, q, k, h_neigh, mask,
                                     layer["self"]["w"], layer["self"]["b"],
                                     layer["neigh"]["w"], layer["neigh"]["b"])


def encoder_apply(params, cfg: GNNConfig, tile) -> jax.Array:
    """Encode the query nodes of a padded K-hop tile -> [B, embed_dim].

    ``tile`` is a ComputeGraphBatch (or pytree of jnp arrays with the same
    structure).  Stage l updates every remaining depth k from its children
    at depth k+1 (innermost-first GraphSAGE): for K=2 this is exactly the
    classic h_n1 = L1(x_n1, x_n2), h_q = L2(L1(x_q, x_n1), h_n1) schedule.
    """
    hs = [_type_transform(params["type_transform"], f, t)
          for f, t in zip(tile.feats, tile.types)]
    num_hops = len(hs) - 1
    layers = params["layers"]
    assert len(layers) == num_hops, (
        f"num_sage_layers ({len(layers)}) must equal len(fanouts) "
        f"({num_hops}); use GNNConfig.with_fanouts")
    for l in range(num_hops):
        hs = [_sage_layer(layers[l], cfg, hs[k], hs[k + 1], tile.masks[k])
              for k in range(num_hops - l)]

    emb = nn.dense_apply(params["out"], hs[0])
    if cfg.l2_normalize:
        emb = emb / (jnp.linalg.norm(emb, axis=-1, keepdims=True) + 1e-6)
    return emb
