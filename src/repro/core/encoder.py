"""GraphSAGE encoder (paper §4.2) with per-node-type transforms.

Implements both aggregation variants from the paper:

  mean:       M_i = (1/|N(i)|) Σ_n f(features(n))
  attention:  M_i = Σ_n α(i,n) · f(features(n))

f is a per-node-type linear transform (heterogeneity-aware); α is a masked
scaled-dot-product attention between the query node's hidden state and its
neighbors.  The aggregation inner loop is the perf-critical hot spot and is
served by the Pallas kernels in :mod:`repro.kernels` (interpret-mode on CPU).

Layer rule (GraphSAGE):  h_v ← σ(W_self·h_v + W_neigh·AGG_{n∈N(v)} h_n)
applied innermost-hop-first over the padded 2-hop tile.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.linksage import GNNConfig
from repro.kernels import ops as kops


def encoder_init(key, cfg: GNNConfig):
    ks = jax.random.split(key, 8)
    T, d_in, h, e = cfg.num_node_types, cfg.feat_dim, cfg.hidden_dim, cfg.embed_dim
    params = {
        # per-node-type input transform f_t (stacked over types)
        "type_transform": {
            "w": jax.random.truncated_normal(ks[0], -2, 2, (T, d_in, h), jnp.float32) / jnp.sqrt(d_in),
            "b": jnp.zeros((T, h), jnp.float32),
        },
        "layers": [],
        "out": nn.dense_init(ks[1], h, e),
    }
    for i in range(cfg.num_sage_layers):
        kl = jax.random.split(ks[2 + i], 4)
        layer = {
            "self": nn.dense_init(kl[0], h, h, use_bias=True),
            "neigh": nn.dense_init(kl[1], h, h, use_bias=True),
        }
        if cfg.aggregator == "attention":
            layer["attn_q"] = nn.dense_init(kl[2], h, h)
            layer["attn_k"] = nn.dense_init(kl[3], h, h)
        params["layers"].append(layer)
    return params


def _type_transform(p, x, types):
    """Per-type linear: x [..., d_in], types [...] int -> [..., h]."""
    onehot = jax.nn.one_hot(types, p["w"].shape[0], dtype=x.dtype)      # [..., T]
    # project with every type's W, then select — T is tiny (6)
    proj = jnp.einsum("...d,tdh->...th", x, p["w"].astype(x.dtype))
    proj = proj + p["b"].astype(x.dtype)
    return jnp.einsum("...th,...t->...h", proj, onehot)


def _aggregate(layer, cfg: GNNConfig, h_query, h_neigh, mask):
    """AGG over the second-to-last axis of h_neigh ([..., F, h])."""
    if cfg.aggregator == "mean":
        return kops.neighbor_mean(h_neigh, mask)
    q = nn.dense_apply(layer["attn_q"], h_query)
    k = nn.dense_apply(layer["attn_k"], h_neigh)
    return kops.neighbor_attention(q, k, h_neigh, mask)


def _sage_layer(layer, cfg: GNNConfig, h_self, h_neigh, mask):
    agg = _aggregate(layer, cfg, h_self, h_neigh, mask)
    out = nn.dense_apply(layer["self"], h_self) + nn.dense_apply(layer["neigh"], agg)
    return jax.nn.relu(out)


def encoder_apply(params, cfg: GNNConfig, tile) -> jax.Array:
    """Encode the query nodes of a padded 2-hop tile -> [B, embed_dim].

    ``tile`` is a ComputeGraphBatch (or pytree of jnp arrays with the same
    fields).
    """
    x_q = _type_transform(params["type_transform"], tile.q_feat, tile.q_type)
    x_n1 = _type_transform(params["type_transform"], tile.n1_feat, tile.n1_type)
    x_n2 = _type_transform(params["type_transform"], tile.n2_feat, tile.n2_type)

    l1, l2 = params["layers"][0], params["layers"][1]
    # hop-1 nodes aggregate their own (hop-2) neighbors
    h_n1 = _sage_layer(l1, cfg, x_n1, x_n2, tile.n2_mask)               # [B, F1, h]
    # query nodes aggregate raw hop-1 feats at layer 1 ...
    h_q = _sage_layer(l1, cfg, x_q, x_n1, tile.n1_mask)                 # [B, h]
    # ... then the refined hop-1 states at layer 2
    h_q = _sage_layer(l2, cfg, h_q, h_n1, tile.n1_mask)                 # [B, h]

    emb = nn.dense_apply(params["out"], h_q)
    if cfg.l2_normalize:
        emb = emb / (jnp.linalg.norm(emb, axis=-1, keepdims=True) + 1e-6)
    return emb
