"""GraphSAGE encoder (paper §4.2) with per-node-type transforms.

Implements both aggregation variants from the paper:

  mean:       M_i = (1/|N(i)|) Σ_n f(features(n))
  attention:  M_i = Σ_n α(i,n) · f(features(n))

f is a per-node-type linear transform (heterogeneity-aware); α is a masked
scaled-dot-product attention between the query node's hidden state and its
neighbors.  The aggregation inner loop is the perf-critical hot spot; BOTH
layer rules are served by fused Pallas kernels (``kops.sage_layer`` for the
mean path, ``kops.sage_attention_layer`` for attention) which dispatch to
the pure-jnp reference on CPU and to the compiled kernels on TPU.

Layer rule (GraphSAGE):  h_v ← σ(W_self·h_v + W_neigh·AGG_{n∈N(v)} h_n)
applied innermost-hop-first over the padded 2-hop tile.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.linksage import GNNConfig
from repro.kernels import ops as kops


def encoder_init(key, cfg: GNNConfig):
    ks = jax.random.split(key, 8)
    T, d_in, h, e = cfg.num_node_types, cfg.feat_dim, cfg.hidden_dim, cfg.embed_dim
    params = {
        # per-node-type input transform f_t (stacked over types)
        "type_transform": {
            "w": jax.random.truncated_normal(ks[0], -2, 2, (T, d_in, h), jnp.float32) / jnp.sqrt(d_in),
            "b": jnp.zeros((T, h), jnp.float32),
        },
        "layers": [],
        "out": nn.dense_init(ks[1], h, e),
    }
    for i in range(cfg.num_sage_layers):
        kl = jax.random.split(ks[2 + i], 4)
        layer = {
            "self": nn.dense_init(kl[0], h, h, use_bias=True),
            "neigh": nn.dense_init(kl[1], h, h, use_bias=True),
        }
        if cfg.aggregator == "attention":
            layer["attn_q"] = nn.dense_init(kl[2], h, h)
            layer["attn_k"] = nn.dense_init(kl[3], h, h)
        params["layers"].append(layer)
    return params


# Crossover for _type_transform: the weight gather moves O(d·h) bytes per
# element while the masked select spends O(T·d·h) FLOPs per element; dense
# hardware (MXU / AVX) trades ~100 matmul FLOPs per byte of gather traffic,
# so per-element weights only win once there are many node types.
_GATHER_MIN_TYPES = 16


def _type_transform(p, x, types):
    """Per-type linear: x [..., d_in], types [...] int -> [..., h].

    Many types: gather each element's own W_t/b_t (take along the type axis)
    and do one batched contraction — FLOPs are O(N·d·h) independent of the
    number of node types.  Few types (the 6-type marketplace graph): a fused
    masked accumulation that, unlike the old compute-all-T-projections-then-
    select, never materializes the [..., T, h] projection tensor.
    """
    T = p["w"].shape[0]
    w = p["w"].astype(x.dtype)
    b = p["b"].astype(x.dtype)
    if T >= _GATHER_MIN_TYPES:
        ws = jnp.take(w, types, axis=0)                    # [..., d, h]
        return jnp.einsum("...d,...dh->...h", x, ws) + jnp.take(b, types, axis=0)
    out = jnp.take(b, types, axis=0)
    for t in range(T):
        sel = (types == t)[..., None].astype(x.dtype)
        out = out + sel * (x @ w[t])
    return out


def _sage_layer(layer, cfg: GNNConfig, h_self, h_neigh, mask):
    if cfg.aggregator == "mean":
        # fused kernel: masked mean + dual matmul + ReLU in one VMEM pass
        return kops.sage_layer(h_self, h_neigh, mask,
                               layer["self"]["w"], layer["self"]["b"],
                               layer["neigh"]["w"], layer["neigh"]["b"])
    # fused kernel: score → masked softmax → weighted sum → dual matmul →
    # ReLU in one VMEM pass; the q/k projections stay outside (plain
    # matmuls XLA already fuses well)
    q = nn.dense_apply(layer["attn_q"], h_self)
    k = nn.dense_apply(layer["attn_k"], h_neigh)
    return kops.sage_attention_layer(h_self, q, k, h_neigh, mask,
                                     layer["self"]["w"], layer["self"]["b"],
                                     layer["neigh"]["w"], layer["neigh"]["b"])


def encoder_apply(params, cfg: GNNConfig, tile) -> jax.Array:
    """Encode the query nodes of a padded 2-hop tile -> [B, embed_dim].

    ``tile`` is a ComputeGraphBatch (or pytree of jnp arrays with the same
    fields).
    """
    x_q = _type_transform(params["type_transform"], tile.q_feat, tile.q_type)
    x_n1 = _type_transform(params["type_transform"], tile.n1_feat, tile.n1_type)
    x_n2 = _type_transform(params["type_transform"], tile.n2_feat, tile.n2_type)

    l1, l2 = params["layers"][0], params["layers"][1]
    # hop-1 nodes aggregate their own (hop-2) neighbors
    h_n1 = _sage_layer(l1, cfg, x_n1, x_n2, tile.n2_mask)               # [B, F1, h]
    # query nodes aggregate raw hop-1 feats at layer 1 ...
    h_q = _sage_layer(l1, cfg, x_q, x_n1, tile.n1_mask)                 # [B, h]
    # ... then the refined hop-1 states at layer 2
    h_q = _sage_layer(l2, cfg, h_q, h_n1, tile.n1_mask)                 # [B, h]

    emb = nn.dense_apply(params["out"], h_q)
    if cfg.l2_normalize:
        emb = emb / (jnp.linalg.norm(emb, axis=-1, keepdims=True) + 1e-6)
    return emb
