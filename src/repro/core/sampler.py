"""Training-side sampling front-end over the shared graph substrate.

The DeepGNN-role engine itself lives in :mod:`repro.core.engine`
(DESIGN.md §8): :class:`NeighborSampler` is now a thin front-end binding a
:class:`SnapshotEngine` to the shared K-hop :class:`TileBuilder`, so the
trainer samples through exactly the same code path as nearline serving.
Every batch of query nodes becomes a fixed-shape padded K-hop tile
(DESIGN.md §3):

    hop0   feats[0] [B, d]           types[0] [B]
    hop k  feats[k] [B, F1..Fk, d]   types[k] [B, F1..Fk]   masks[k-1] [B, F1..Fk]

Neighbors are sampled uniformly (or degree-weighted) *across all outgoing
edge types* of a node; heterogeneity is preserved by carrying the neighbor's
node-type id, which selects the per-type feature transform in the encoder.

This module also keeps the :class:`BatchPrefetcher` (the background-thread
training pipeline) and re-exports the tile/adjacency types it historically
owned.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Callable

import numpy as np

from repro.core.engine import (ComputeGraphBatch, MergedAdjacency,  # noqa: F401
                               SnapshotEngine, TileBuilder)
from repro.core.graph import HeteroGraph


@dataclass(frozen=True)
class SamplerConfig:
    fanouts: tuple = (10, 5)          # one entry per hop, arbitrary K
    strategy: str = "uniform"         # uniform | degree_weighted
    seed: int = 0


class NeighborSampler:
    """Fixed-fanout K-hop sampler: a SnapshotEngine + the shared TileBuilder."""

    def __init__(self, graph: HeteroGraph, cfg: SamplerConfig | None = None):
        self.graph = graph
        self.cfg = cfg or SamplerConfig()
        self.engine = SnapshotEngine(graph, strategy=self.cfg.strategy)
        self.builder = TileBuilder(self.engine, self.cfg.fanouts)
        self.madj = self.engine.madj
        self.rng = np.random.default_rng(self.cfg.seed)

    # -- one hop: (types[N], ids[N]) -> (types[N,F], ids[N,F], mask[N,F])
    def _sample_hop(self, types: np.ndarray, ids: np.ndarray, fanout: int,
                    rng: np.random.Generator | None = None):
        rng = self.rng if rng is None else rng
        u = rng.random((ids.shape[0], fanout))
        return self.engine.sample_batched(np.asarray(types).astype(np.int64),
                                          np.asarray(ids).astype(np.int64),
                                          fanout, u)

    def _degree_of(self, tid: int, nid: int) -> int:
        return self.engine.degree(tid, nid)

    def sample_batch(self, node_type: str, node_ids: np.ndarray,
                     rng: np.random.Generator | None = None) -> ComputeGraphBatch:
        """Build the padded K-hop compute-graph tile for a batch of queries.

        ``rng`` overrides the sampler's own (stateful) stream — the training
        pipeline passes a per-step generator keyed by step index so batches
        are a pure function of (seed, step) and the prefetching pipeline
        reproduces the synchronous one bit-for-bit.
        """
        return self.builder.build(node_type, np.asarray(node_ids),
                                  rng=self.rng if rng is None else rng)

    def sample_pair_batch(self, member_ids: np.ndarray, job_ids: np.ndarray,
                          rng: np.random.Generator | None = None):
        """(member tile, job tile) for link-prediction batches."""
        rng = self.rng if rng is None else rng
        return (self.sample_batch("member", member_ids, rng),
                self.sample_batch("job", job_ids, rng))


# ---------------------------------------------------------------- prefetch


class BatchPrefetcher:
    """Background-thread batch pipeline for the training loop.

    A worker thread builds batch ``i`` by calling ``build(i)`` (host-side
    numpy sampling) and pushes it through ``transfer`` (typically
    ``jax.device_put``, so the host→device copy ALSO happens off the main
    thread) into a bounded queue of depth ``depth`` — double-buffering by
    default.  The main thread pops batches in step order while the device
    runs the current step, so sampler time is hidden behind compute.

    Reproducibility contract: ``build`` must be a pure function of the step
    index (per-step RNG streams — see :meth:`NeighborSampler.sample_batch`),
    which makes the prefetched run bit-identical to a synchronous loop
    calling ``build(i)`` inline.

    ``stall_seconds`` accumulates the time the consumer spent blocked on an
    empty queue — the sampler-stall metric the train benchmark reports.
    """

    _STOP = object()

    def __init__(self, build: Callable[[int], object], num_steps: int, *,
                 depth: int = 2, transfer: Callable | None = None,
                 start_step: int = 0):
        assert depth >= 1, depth
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._build = build
        self._transfer = transfer or (lambda x: x)
        self._stop = False
        self._error: BaseException | None = None
        self.stall_seconds = 0.0
        self.batches = 0
        self._thread = threading.Thread(
            target=self._run, args=(start_step, num_steps), daemon=True)
        self._thread.start()

    def _run(self, start: int, num_steps: int) -> None:
        try:
            for i in range(start, start + num_steps):
                if self._stop:
                    return
                item = self._transfer(self._build(i))
                while not self._stop:
                    try:
                        self._q.put(item, timeout=0.05)
                        break
                    except queue.Full:
                        continue
        except BaseException as e:        # surfaced on the consumer side
            self._error = e
            self._q.put(self._STOP)

    def get(self):
        """Next batch in step order; blocks (and accounts the stall) if the
        producer is behind."""
        t0 = time.perf_counter()
        item = self._q.get()
        self.stall_seconds += time.perf_counter() - t0
        if item is self._STOP:
            raise RuntimeError("prefetch worker failed") from self._error
        self.batches += 1
        return item

    def close(self) -> None:
        """Stop the worker and release anything still queued.  Never raises:
        worker errors surface through :meth:`get` (close may run while an
        exception is already propagating and must not mask it)."""
        self._stop = True
        while self._thread.is_alive():
            try:
                self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=0.05)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
