"""Multi-hop fixed-fanout neighborhood sampler (the DeepGNN role, §4.1/§4.3).

TPU adaptation (see DESIGN.md §3): instead of ragged gather/scatter compute
graphs, every batch of query nodes becomes a *fixed-shape padded tile*:

    hop0   q_feat  [B, d]          q_type  [B]
    hop1   n1_feat [B, F1, d]      n1_type [B, F1]      n1_mask [B, F1]
    hop2   n2_feat [B, F1, F2, d]  n2_type [B, F1, F2]  n2_mask [B, F1, F2]

Neighbors are sampled uniformly (or degree-weighted) *across all outgoing
edge types* of a node; heterogeneity is preserved by carrying the neighbor's
node-type id, which selects the per-type feature transform in the encoder.
A merged adjacency (one CSR per node type whose entries are (dst_type,
dst_id) pairs) is precomputed so sampling is vectorized numpy.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Callable, NamedTuple

import numpy as np

from repro.core.graph import NODE_TYPES, NODE_TYPE_ID, HeteroGraph


@dataclass(frozen=True)
class SamplerConfig:
    fanouts: tuple = (10, 5)          # (hop1, hop2)
    strategy: str = "uniform"         # uniform | degree_weighted
    seed: int = 0


class ComputeGraphBatch(NamedTuple):
    """Padded 2-hop tile; arrays are numpy on the host, moved to device whole."""
    q_feat: np.ndarray
    q_type: np.ndarray
    n1_feat: np.ndarray
    n1_type: np.ndarray
    n1_mask: np.ndarray
    n2_feat: np.ndarray
    n2_type: np.ndarray
    n2_mask: np.ndarray


class MergedAdjacency:
    """Per-node-type merged CSR over all outgoing edge types.

    Alongside (indptr, dst_id, dst_ty) we precompute, for the
    degree-weighted strategy, each entry's *neighbor degree* and the
    per-type cumulative weight array ``wcum`` (cumsum of degree + 1) so
    weighted sampling is a vectorized inverse-CDF searchsorted instead of a
    per-row ``rng.choice`` with per-neighbor degree lookups.
    """

    def __init__(self, graph: HeteroGraph):
        self.graph = graph
        self.merged = {}
        for ntype in NODE_TYPES:
            rels = graph.relations_from(ntype)
            n = graph.num_nodes[ntype]
            if not rels:
                self.merged[ntype] = None
                continue
            per_rel = [graph.adj[r] for r in rels]
            # concatenate all (src, dst, dst_type) triples, stable-sort by src
            src_all = np.concatenate([np.repeat(np.arange(n), np.diff(csr.indptr))
                                      for csr in per_rel])
            dst_all = np.concatenate([csr.indices for csr in per_rel])
            ty_all = np.concatenate([np.full(csr.num_edges, NODE_TYPE_ID[d], np.int8)
                                     for (s, d), csr in zip(rels, per_rel)])
            order = np.argsort(src_all, kind="stable")
            counts = np.bincount(src_all, minlength=n)
            indptr = np.zeros(n + 1, np.int64)
            np.cumsum(counts, out=indptr[1:])
            self.merged[ntype] = (indptr, dst_all[order].astype(np.int32),
                                  ty_all[order])
        # second pass: per-entry neighbor degree + cumulative weights
        self.wcum = {}
        for ntype in NODE_TYPES:
            m = self.merged[ntype]
            if m is None:
                self.wcum[ntype] = None
                continue
            _, dst_id, dst_ty = m
            nb_deg = np.zeros(dst_id.shape[0], np.float64)
            for tid, tname in enumerate(NODE_TYPES):
                sel = np.nonzero(dst_ty == tid)[0]
                if sel.size:
                    nb_deg[sel] = self.degrees(tname)[dst_id[sel]]
            self.wcum[ntype] = np.cumsum(nb_deg + 1.0)

    def degrees(self, ntype: str) -> np.ndarray:
        m = self.merged[ntype]
        if m is None:
            return np.zeros(self.graph.num_nodes[ntype], np.int64)
        return np.diff(m[0])


class NeighborSampler:
    """Vectorized fixed-fanout sampler over a MergedAdjacency."""

    def __init__(self, graph: HeteroGraph, cfg: SamplerConfig | None = None):
        self.graph = graph
        self.cfg = cfg or SamplerConfig()
        self.madj = MergedAdjacency(graph)
        self.rng = np.random.default_rng(self.cfg.seed)
        self._feat = [graph.features[t] for t in NODE_TYPES]
        self._dim = graph.feat_dim

    # -- one hop: (types[N], ids[N]) -> (types[N,F], ids[N,F], mask[N,F])
    def _sample_hop(self, types: np.ndarray, ids: np.ndarray, fanout: int,
                    rng: np.random.Generator | None = None):
        rng = self.rng if rng is None else rng
        n = ids.shape[0]
        out_id = np.zeros((n, fanout), np.int32)
        out_ty = np.zeros((n, fanout), np.int8)
        out_mask = np.zeros((n, fanout), bool)
        for tid, tname in enumerate(NODE_TYPES):
            sel = np.nonzero(types == tid)[0]
            if sel.size == 0:
                continue
            m = self.madj.merged[tname]
            if m is None:
                continue
            indptr, dst_id, dst_ty = m
            node_ids = ids[sel]
            deg = (indptr[node_ids + 1] - indptr[node_ids]).astype(np.int64)
            has = deg > 0
            if not has.any():
                continue
            rows = sel[has]
            base = indptr[node_ids[has]]
            d = deg[has]
            if self.cfg.strategy == "degree_weighted":
                # DeepGNN-style weighted sampling: bias neighbor choice by
                # the *neighbor's* own degree (well-connected nodes carry
                # more information; §4.1 lists weighted sampling support).
                # Inverse-CDF over the precomputed cumulative weights: draw a
                # uniform in each row's [wcum_lo, wcum_hi) span and
                # searchsorted back to a global entry index.
                wcum = self.madj.wcum[tname]
                lo = np.where(base > 0, wcum[base - 1], 0.0)
                hi = wcum[base + d - 1]
                u = rng.random((rows.size, fanout))
                targets = lo[:, None] + u * (hi - lo)[:, None]
                gidx = np.searchsorted(wcum, targets, side="right")
                offs = np.clip(gidx - base[:, None], 0, (d - 1)[:, None])
            else:
                # uniform with replacement: offsets in [0, deg)
                offs = (rng.random((rows.size, fanout)) * d[:, None]).astype(np.int64)
            flat = base[:, None] + offs
            out_id[rows] = dst_id[flat]
            out_ty[rows] = dst_ty[flat]
            out_mask[rows] = True
        return out_ty, out_id, out_mask

    def _degree_of(self, tid: int, nid: int) -> int:
        m = self.madj.merged[NODE_TYPES[tid]]
        if m is None:
            return 0
        indptr = m[0]
        return int(indptr[nid + 1] - indptr[nid])

    def _gather_feats(self, types: np.ndarray, ids: np.ndarray) -> np.ndarray:
        flat_t = types.reshape(-1)
        flat_i = ids.reshape(-1)
        out = np.zeros((flat_t.shape[0], self._dim), np.float32)
        for tid in range(len(NODE_TYPES)):
            sel = np.nonzero(flat_t == tid)[0]
            if sel.size:
                out[sel] = self._feat[tid][flat_i[sel]]
        return out.reshape(*types.shape, self._dim)

    def sample_batch(self, node_type: str, node_ids: np.ndarray,
                     rng: np.random.Generator | None = None) -> ComputeGraphBatch:
        """Build the padded 2-hop compute-graph tile for a batch of queries.

        ``rng`` overrides the sampler's own (stateful) stream — the training
        pipeline passes a per-step generator keyed by step index so batches
        are a pure function of (seed, step) and the prefetching pipeline
        reproduces the synchronous one bit-for-bit.
        """
        f1, f2 = self.cfg.fanouts
        b = node_ids.shape[0]
        q_type = np.full(b, NODE_TYPE_ID[node_type], np.int8)
        q_ids = node_ids.astype(np.int32)

        n1_ty, n1_id, n1_mask = self._sample_hop(q_type, q_ids, f1, rng)
        n2_ty, n2_id, n2_mask_flat = self._sample_hop(
            n1_ty.reshape(-1), n1_id.reshape(-1), f2, rng)
        n2_ty = n2_ty.reshape(b, f1, f2)
        n2_id = n2_id.reshape(b, f1, f2)
        n2_mask = n2_mask_flat.reshape(b, f1, f2) & n1_mask[:, :, None]

        return ComputeGraphBatch(
            q_feat=self._gather_feats(q_type, q_ids),
            q_type=q_type.astype(np.int32),
            n1_feat=self._gather_feats(n1_ty, n1_id) * n1_mask[..., None],
            n1_type=n1_ty.astype(np.int32),
            n1_mask=n1_mask.astype(np.float32),
            n2_feat=self._gather_feats(n2_ty, n2_id) * n2_mask[..., None],
            n2_type=n2_ty.astype(np.int32),
            n2_mask=n2_mask.astype(np.float32),
        )

    def sample_pair_batch(self, member_ids: np.ndarray, job_ids: np.ndarray,
                          rng: np.random.Generator | None = None):
        """(member tile, job tile) for link-prediction batches."""
        return (self.sample_batch("member", member_ids, rng),
                self.sample_batch("job", job_ids, rng))


# ---------------------------------------------------------------- prefetch


class BatchPrefetcher:
    """Background-thread batch pipeline for the training loop.

    A worker thread builds batch ``i`` by calling ``build(i)`` (host-side
    numpy sampling) and pushes it through ``transfer`` (typically
    ``jax.device_put``, so the host→device copy ALSO happens off the main
    thread) into a bounded queue of depth ``depth`` — double-buffering by
    default.  The main thread pops batches in step order while the device
    runs the current step, so sampler time is hidden behind compute.

    Reproducibility contract: ``build`` must be a pure function of the step
    index (per-step RNG streams — see :meth:`NeighborSampler.sample_batch`),
    which makes the prefetched run bit-identical to a synchronous loop
    calling ``build(i)`` inline.

    ``stall_seconds`` accumulates the time the consumer spent blocked on an
    empty queue — the sampler-stall metric the train benchmark reports.
    """

    _STOP = object()

    def __init__(self, build: Callable[[int], object], num_steps: int, *,
                 depth: int = 2, transfer: Callable | None = None,
                 start_step: int = 0):
        assert depth >= 1, depth
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._build = build
        self._transfer = transfer or (lambda x: x)
        self._stop = False
        self._error: BaseException | None = None
        self.stall_seconds = 0.0
        self.batches = 0
        self._thread = threading.Thread(
            target=self._run, args=(start_step, num_steps), daemon=True)
        self._thread.start()

    def _run(self, start: int, num_steps: int) -> None:
        try:
            for i in range(start, start + num_steps):
                if self._stop:
                    return
                item = self._transfer(self._build(i))
                while not self._stop:
                    try:
                        self._q.put(item, timeout=0.05)
                        break
                    except queue.Full:
                        continue
        except BaseException as e:        # surfaced on the consumer side
            self._error = e
            self._q.put(self._STOP)

    def get(self):
        """Next batch in step order; blocks (and accounts the stall) if the
        producer is behind."""
        t0 = time.perf_counter()
        item = self._q.get()
        self.stall_seconds += time.perf_counter() - t0
        if item is self._STOP:
            raise RuntimeError("prefetch worker failed") from self._error
        self.batches += 1
        return item

    def close(self) -> None:
        """Stop the worker and release anything still queued.  Never raises:
        worker errors surface through :meth:`get` (close may run while an
        exception is already propagating and must not mask it)."""
        self._stop = True
        while self._thread.is_alive():
            try:
                self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=0.05)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
