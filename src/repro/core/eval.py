"""Offline evaluation: recall@k, AUC, per-segment metrics.

These are the offline proxies for the paper's online A/B metrics (§7): the
synthetic graph's ground-truth match function defines relevance, so recall
and AUC measure exactly what the GNN is supposed to learn.
"""
from __future__ import annotations

import numpy as np


def recall_at_k(scores: np.ndarray, positives: list, k: int = 10) -> float:
    """scores [num_members, num_jobs]; positives[i] = set of relevant job ids.

    Fully vectorized: one dense [n, num_jobs] membership matrix gathered at
    the top-k indices replaces the per-member set-intersection loop.
    Out-of-range positive ids count toward the denominator but can never be
    retrieved (identical to the old set-based semantics).
    """
    n, num_jobs = scores.shape
    topk = np.argpartition(-scores, min(k, num_jobs - 1), axis=1)[:, :k]
    lens = np.fromiter((len(p) for p in positives), np.int64, n)
    if not (lens > 0).any():
        return 0.0
    rows = np.repeat(np.arange(n), lens)
    cols = np.fromiter((j for p in positives for j in p), np.int64, lens.sum())
    ok = (cols >= 0) & (cols < num_jobs)
    pos_mat = np.zeros((n, num_jobs), bool)
    pos_mat[rows[ok], cols[ok]] = True
    hits = int(pos_mat[np.arange(n)[:, None], topk].sum())
    total = int(np.minimum(lens, k).sum())
    return hits / max(total, 1)


def auc(labels: np.ndarray, scores: np.ndarray) -> float:
    """Rank-based AUC (no sklearn dependency).

    Tied scores share their average rank (the Mann-Whitney convention: a
    pos/neg tie counts 1/2), computed vectorized from the unique-value run
    boundaries — rank of a run ending at position e with count c averages
    to e - (c-1)/2.
    """
    uniq, inv, counts = np.unique(scores, return_inverse=True,
                                  return_counts=True)
    ends = np.cumsum(counts)
    ranks = (ends - (counts - 1) / 2.0)[inv]
    pos = labels > 0
    n_pos, n_neg = int(pos.sum()), int((~pos).sum())
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return (ranks[pos].sum() - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg)


def retrieval_eval(member_emb: np.ndarray, job_emb: np.ndarray,
                   eng_src: np.ndarray, eng_dst: np.ndarray,
                   *, k: int = 10, segment_mask: np.ndarray | None = None):
    """EBR-style evaluation: dot-product retrieval vs ground-truth engagements."""
    positives = [set() for _ in range(member_emb.shape[0])]
    for m, j in zip(eng_src, eng_dst):
        positives[m].add(int(j))
    scores = member_emb @ job_emb.T
    members = [i for i, p in enumerate(positives) if p]
    if segment_mask is not None:
        members = [i for i in members if segment_mask[i]]
    if not members:
        return {"recall": 0.0, "num_members": 0}
    sub = np.array(members)
    r = recall_at_k(scores[sub], [positives[i] for i in sub], k=k)
    return {"recall": r, "num_members": len(members)}


def pairwise_auc_eval(score_fn, pos_pairs, neg_pairs):
    """AUC over explicit positive/negative (member, job) pair lists."""
    pm, pj = pos_pairs
    nm, nj = neg_pairs
    s_pos = score_fn(pm, pj)
    s_neg = score_fn(nm, nj)
    labels = np.concatenate([np.ones(len(s_pos)), np.zeros(len(s_neg))])
    return auc(labels, np.concatenate([s_pos, s_neg]))
