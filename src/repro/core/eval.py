"""Offline evaluation: recall@k, AUC, per-segment metrics.

These are the offline proxies for the paper's online A/B metrics (§7): the
synthetic graph's ground-truth match function defines relevance, so recall
and AUC measure exactly what the GNN is supposed to learn.
"""
from __future__ import annotations

import numpy as np


def recall_at_k(scores: np.ndarray, positives: list, k: int = 10) -> float:
    """scores [num_members, num_jobs]; positives[i] = set of relevant job ids."""
    hits, total = 0, 0
    topk = np.argpartition(-scores, min(k, scores.shape[1] - 1), axis=1)[:, :k]
    for i, pos in enumerate(positives):
        if not pos:
            continue
        hits += len(set(topk[i].tolist()) & pos)
        total += min(len(pos), k)
    return hits / max(total, 1)


def auc(labels: np.ndarray, scores: np.ndarray) -> float:
    """Rank-based AUC (no sklearn dependency)."""
    order = np.argsort(scores, kind="mergesort")
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(scores) + 1)
    # average ties
    sorted_scores = scores[order]
    i = 0
    while i < len(sorted_scores):
        j = i
        while j + 1 < len(sorted_scores) and sorted_scores[j + 1] == sorted_scores[i]:
            j += 1
        if j > i:
            ranks[order[i:j + 1]] = ranks[order[i:j + 1]].mean()
        i = j + 1
    pos = labels > 0
    n_pos, n_neg = int(pos.sum()), int((~pos).sum())
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return (ranks[pos].sum() - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg)


def retrieval_eval(member_emb: np.ndarray, job_emb: np.ndarray,
                   eng_src: np.ndarray, eng_dst: np.ndarray,
                   *, k: int = 10, segment_mask: np.ndarray | None = None):
    """EBR-style evaluation: dot-product retrieval vs ground-truth engagements."""
    positives = [set() for _ in range(member_emb.shape[0])]
    for m, j in zip(eng_src, eng_dst):
        positives[m].add(int(j))
    scores = member_emb @ job_emb.T
    members = [i for i, p in enumerate(positives) if p]
    if segment_mask is not None:
        members = [i for i in members if segment_mask[i]]
    if not members:
        return {"recall": 0.0, "num_members": 0}
    sub = np.array(members)
    r = recall_at_k(scores[sub], [positives[i] for i in sub], k=k)
    return {"recall": r, "num_members": len(members)}


def pairwise_auc_eval(score_fn, pos_pairs, neg_pairs):
    """AUC over explicit positive/negative (member, job) pair lists."""
    pm, pj = pos_pairs
    nm, nj = neg_pairs
    s_pos = score_fn(pm, pj)
    s_neg = score_fn(nm, nj)
    labels = np.concatenate([np.ones(len(s_pos)), np.zeros(len(s_neg))])
    return auc(labels, np.concatenate([s_pos, s_neg]))
