"""Offline evaluation: recall@k, AUC, per-segment metrics.

These are the offline proxies for the paper's online A/B metrics (§7): the
synthetic graph's ground-truth match function defines relevance, so recall
and AUC measure exactly what the GNN is supposed to learn.
"""
from __future__ import annotations

import numpy as np


def recall_at_k(scores: np.ndarray, positives: list, k: int = 10) -> float:
    """scores [num_members, num_jobs]; positives[i] = set of relevant job ids.

    Memory-flat in the corpus: top-k hits are checked by flattened-key
    membership (row * num_jobs + col against the deduplicated positive
    keys) instead of a dense [n, num_jobs] bool matrix — O(n·k + P) extra,
    not O(n·J), so it survives 1M+ jobs.  Out-of-range positive ids count
    toward the denominator but can never be retrieved (identical to the
    old set-based semantics; asserted by tests/test_retrieval.py).
    """
    n, num_jobs = scores.shape
    topk = np.argpartition(-scores, min(k, num_jobs - 1), axis=1)[:, :k]
    lens = np.fromiter((len(p) for p in positives), np.int64, n)
    if not (lens > 0).any():
        return 0.0
    rows = np.repeat(np.arange(n), lens)
    cols = np.fromiter((j for p in positives for j in p), np.int64, lens.sum())
    ok = (cols >= 0) & (cols < num_jobs)
    pos_keys = np.unique(rows[ok] * num_jobs + cols[ok])
    topk_keys = np.arange(n)[:, None] * num_jobs + topk
    hits = int(np.isin(topk_keys, pos_keys).sum())
    total = int(np.minimum(lens, k).sum())
    return hits / max(total, 1)


def positives_from_edges(eng_src: np.ndarray, eng_dst: np.ndarray,
                         num_members: int) -> list:
    """positives[m] = set of engaged job ids, built by one sorted groupby
    pass over the edge list instead of a per-edge Python loop (bit-identical
    to the loop; asserted by tests/test_retrieval.py)."""
    positives = [set() for _ in range(num_members)]
    if len(eng_src) == 0:
        return positives
    src = np.asarray(eng_src, np.int64)
    dst = np.asarray(eng_dst, np.int64)
    order = np.argsort(src, kind="stable")
    src_s, dst_s = src[order], dst[order]
    uniq, starts = np.unique(src_s, return_index=True)
    for m, js in zip(uniq, np.split(dst_s, starts[1:])):
        positives[m] = set(js.tolist())
    return positives


def recall_from_retrieved(retrieved: np.ndarray, positives: list,
                          k: int = 10) -> float:
    """recall@k from already-retrieved ids [n, >=k] (a RetrievalIndex
    search result) instead of a dense score matrix; -1 entries are padding.
    Same semantics as ``recall_at_k``: denominator min(|positives|, k)."""
    n = retrieved.shape[0]
    lens = np.fromiter((len(p) for p in positives), np.int64, n)
    if not (lens > 0).any():
        return 0.0
    hits = sum(len(set(int(j) for j in row[:k] if j >= 0) & p)
               for row, p in zip(retrieved, positives))
    total = int(np.minimum(lens, k).sum())
    return hits / max(total, 1)


def auc(labels: np.ndarray, scores: np.ndarray) -> float:
    """Rank-based AUC (no sklearn dependency).

    Tied scores share their average rank (the Mann-Whitney convention: a
    pos/neg tie counts 1/2), computed vectorized from the unique-value run
    boundaries — rank of a run ending at position e with count c averages
    to e - (c-1)/2.
    """
    uniq, inv, counts = np.unique(scores, return_inverse=True,
                                  return_counts=True)
    ends = np.cumsum(counts)
    ranks = (ends - (counts - 1) / 2.0)[inv]
    pos = labels > 0
    n_pos, n_neg = int(pos.sum()), int((~pos).sum())
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return (ranks[pos].sum() - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg)


def retrieval_eval(member_emb: np.ndarray, job_emb: np.ndarray,
                   eng_src: np.ndarray, eng_dst: np.ndarray,
                   *, k: int = 10, segment_mask: np.ndarray | None = None,
                   index=None, nprobe: int | None = None):
    """EBR-style evaluation: dot-product retrieval vs ground-truth engagements.

    Default path is the exact fp32 scan.  Passing ``index`` (a
    ``core.retrieval.RetrievalIndex`` built over ``job_emb``) routes
    retrieval through the quantized ANN tier instead — ``nprobe`` forwarded
    to ``search()`` — so the same eval measures the tier's recall.
    """
    positives = positives_from_edges(eng_src, eng_dst, member_emb.shape[0])
    members = [i for i, p in enumerate(positives) if p]
    if segment_mask is not None:
        members = [i for i in members if segment_mask[i]]
    if not members:
        return {"recall": 0.0, "num_members": 0}
    sub = np.array(members)
    if index is not None:
        ids, _ = index.search(member_emb[sub], k, nprobe=nprobe)
        r = recall_from_retrieved(ids, [positives[i] for i in sub], k=k)
    else:
        scores = member_emb[sub] @ job_emb.T
        r = recall_at_k(scores, [positives[i] for i in sub], k=k)
    return {"recall": r, "num_members": len(members)}


def pairwise_auc_eval(score_fn, pos_pairs, neg_pairs):
    """AUC over explicit positive/negative (member, job) pair lists."""
    pm, pj = pos_pairs
    nm, nj = neg_pairs
    s_pos = score_fn(pm, pj)
    s_neg = score_fn(nm, nj)
    labels = np.concatenate([np.ones(len(s_pos)), np.zeros(len(s_neg))])
    return auc(labels, np.concatenate([s_pos, s_neg]))
