"""Quantized ANN retrieval tier for EBR at millions of jobs (§7.4,
DESIGN.md §14).

The EBR surface is the one serving path whose cost grows with corpus size
rather than traffic: brute-force `member_emb @ job_emb.T` is fine at 10k
jobs and dead at 10M.  This module is the real retrieval tier:

  quantize_int8     — symmetric int8 quantization of a published fp32
                      table (per-row or per-dim scale), derived ONCE per
                      version (the §9 version-pinning contract extends to
                      the quantized replica)
  build_ivf         — IVF coarse index: deterministic k-means centroids
                      over the published table, inverted lists as CSR
                      arrays; ``nprobe`` trades recall for latency
  RetrievalIndex    — one published corpus: fp32 oracle table + int8
                      replica + IVF lists behind a single ``search()``
  brute_force_topk  — the fp32 exact scorer, RETAINED as the parity
                      oracle: the exact-search config must return ids
                      bit-identical to it; quantized/nprobe arms report
                      recall-vs-QPS curves against it

Scoring convention (shared with :mod:`repro.kernels.scan_topk`): queries
are quantized per-row symmetric, score(q, c) = int8-dot accumulated in
int32, dequantized by ONE multiply with (q_scale * c_scale).  Because
``quantize_int8`` bounds d <= 1024, every partial sum is an integer below
2^24, so a float32 matmul over the codes accumulates EXACTLY the same
integers — the numpy fast path (BLAS sgemm over gathered IVF lists) and
the Pallas kernel produce bit-identical scores.  Selection is canonical
everywhere: score descending, corpus row ascending on ties.

Per-dim scale folds into the QUERY at search time (q' = q * dim_scale
before quantization), so the kernel only ever sees per-row scales on both
sides.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

# k-means seed domain separator (disjoint from the trainer / lifecycle
# uniform streams in core.embeddings)
IVF_SALT = 0x1FF

# d * 127 * 127 must stay below 2^24 so int8 dot products accumulate
# exactly in float32 (the kernel-parity contract above)
MAX_QUANT_DIM = 1024


class QuantizedTable(NamedTuple):
    """Immutable int8 replica of one published fp32 table."""
    codes: np.ndarray                 # int8 [N, d]
    scales: np.ndarray                # f32 [N] per-row dequant scale
    dim_scales: np.ndarray | None     # f32 [d] (per_dim: query pre-scale)
    scheme: str                       # "per_row" | "per_dim"


class IVFIndex(NamedTuple):
    """Coarse index over one published table: k-means centroids + CSR
    inverted lists (``ids[offsets[c]:offsets[c+1]]`` = corpus rows of
    list c, ascending)."""
    centroids: np.ndarray             # f32 [C, d]
    offsets: np.ndarray               # i64 [C + 1]
    ids: np.ndarray                   # i64 [N] rows grouped by list


def _freeze(*arrays):
    for a in arrays:
        a.setflags(write=False)


def quantize_int8(table: np.ndarray, scheme: str = "per_row") -> QuantizedTable:
    """Symmetric int8 quantization of a [N, d] fp32 table.

    per_row — scale_i = max|x_i|/127 (a row's error is bounded by its own
      dynamic range; the default for embedding tables whose row norms vary);
    per_dim — scale_d = max|x[:, d]|/127 shared by the whole corpus; the
      per-dim scale is returned as a query pre-scale so scoring stays a
      per-row-scaled int8 dot (see module doc).

    Deterministic: same bits in -> same bits out (np.rint, no RNG).
    """
    x = np.ascontiguousarray(table, np.float32)
    n, d = x.shape
    assert d <= MAX_QUANT_DIM, (d, MAX_QUANT_DIM)
    if scheme == "per_row":
        amax = np.max(np.abs(x), axis=1)
        scales = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
        codes = np.rint(x / scales[:, None])
        dim_scales = None
    elif scheme == "per_dim":
        amax = np.max(np.abs(x), axis=0) if n else np.zeros(d, np.float32)
        dim_scales = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
        codes = np.rint(x / dim_scales[None, :])
        scales = np.ones(n, np.float32)
    else:
        raise ValueError(f"unknown quantization scheme {scheme!r}")
    codes = np.clip(codes, -127, 127).astype(np.int8)
    qt = QuantizedTable(codes, scales, dim_scales, scheme)
    _freeze(qt.codes, qt.scales)
    if qt.dim_scales is not None:
        _freeze(qt.dim_scales)
    return qt


def dequantize(qt: QuantizedTable) -> np.ndarray:
    """[N, d] fp32 reconstruction; |x - dequantize| <= scale/2 per entry."""
    out = qt.codes.astype(np.float32) * qt.scales[:, None]
    if qt.dim_scales is not None:
        out *= qt.dim_scales[None, :]
    return out


def quantize_queries(q: np.ndarray, qt: QuantizedTable):
    """Per-row symmetric int8 query codes against ``qt``'s convention:
    per_dim corpora fold their dim scale into the query first, so the
    score is always (q_codes · c_codes) * (q_scale * c_scale)."""
    q = np.asarray(q, np.float32)
    if qt.dim_scales is not None:
        q = q * qt.dim_scales[None, :]
    amax = np.max(np.abs(q), axis=1) if q.shape[0] else np.zeros(0)
    scales = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    codes = np.clip(np.rint(q / scales[:, None]), -127, 127).astype(np.int8)
    return codes, scales


# ----------------------------------------------------------------- top-k


def topk_from_triples(qidx, rows, scores, *, num_queries: int, k: int):
    """Canonical per-query top-k over sparse (query, corpus row, score)
    triples: score descending, row ascending on ties.  Queries with fewer
    than k scored rows pad with row -1 / score -inf."""
    out_i = np.full((num_queries, k), -1, np.int64)
    out_v = np.full((num_queries, k), -np.inf, np.float32)
    if len(qidx) == 0:
        return out_i, out_v
    order = np.lexsort((rows, -scores.astype(np.float64), qidx))
    q_s, r_s, v_s = qidx[order], rows[order], scores[order]
    uniq, starts = np.unique(q_s, return_index=True)
    rank = np.arange(len(q_s)) - np.repeat(starts, np.diff(
        np.append(starts, len(q_s))))
    keep = rank < k
    out_i[q_s[keep], rank[keep]] = r_s[keep]
    out_v[q_s[keep], rank[keep]] = v_s[keep]
    return out_i, out_v


def _topk_1d(scores: np.ndarray, rows: np.ndarray, k: int):
    """Canonical top-k of one query's (score, corpus row) candidates:
    argpartition prefilter, tie expansion at the k-th value, lexsort
    (score descending, row ascending).  Rows must be distinct (IVF lists
    partition the corpus)."""
    n = len(scores)
    if n > k:
        part = np.argpartition(-scores, k - 1)[:k]
        kth = scores[part].min()
        keep = scores >= kth
        scores, rows = scores[keep], rows[keep]
    order = np.lexsort((rows, -scores.astype(np.float64)))[:k]
    return rows[order], scores[order]


def _dense_topk(scores: np.ndarray, k: int):
    """Canonical top-k of a dense [B, N] score block: argpartition
    prefilter, then every row tied with the k-th value goes through the
    canonical triple sort (so boundary ties break by row, not by
    argpartition's arbitrary order)."""
    b, n = scores.shape
    kk = min(k, n)
    part = np.argpartition(-scores, kk - 1, axis=1)[:, :kk]
    kth = np.min(np.take_along_axis(scores, part, axis=1), axis=1)
    qidx, rows = np.nonzero(scores >= kth[:, None])
    return topk_from_triples(qidx, rows.astype(np.int64),
                             scores[qidx, rows], num_queries=b, k=k)


def brute_force_topk(queries: np.ndarray, table: np.ndarray, k: int,
                     *, query_block: int = 64):
    """THE fp32 parity oracle: full-corpus dot-product scan, canonical
    top-k.  Chunks over queries only (never the corpus), so scores are
    bit-identical to one whole-batch matmul.

    Returns (corpus rows [B, k] i64, scores [B, k] f32); rows past the
    corpus size pad with -1 / -inf.
    """
    q = np.asarray(queries, np.float32)
    t = np.asarray(table, np.float32)
    ids = np.empty((q.shape[0], k), np.int64)
    vals = np.empty((q.shape[0], k), np.float32)
    for i in range(0, q.shape[0], query_block):
        s = q[i:i + query_block] @ t.T
        ids[i:i + query_block], vals[i:i + query_block] = _dense_topk(s, k)
    return ids, vals


# ------------------------------------------------------------------- IVF


def build_ivf(table: np.ndarray, num_lists: int, *, seed: int = 0,
              iters: int = 10, train_size: int = 65536,
              assign_block: int = 16384) -> IVFIndex:
    """Deterministic IVF coarse index over a published [N, d] table.

    Lloyd k-means (L2 assignment, first-occurrence argmin ties) trained on
    a seeded subsample of at most ``train_size`` rows, then one chunked
    full-corpus assignment pass.  Empty clusters keep their previous
    centroid.  Same (table bits, num_lists, seed) -> same index bits, so
    a per-version index is reproducible from the version's fp32 table.
    """
    x = np.ascontiguousarray(table, np.float32)
    n, d = x.shape
    c = int(min(num_lists, n))
    assert c > 0, num_lists
    rng = np.random.default_rng((seed, IVF_SALT, n, c))
    train = x[np.sort(rng.choice(n, min(train_size, n), replace=False))]
    cent = train[np.sort(rng.choice(len(train), c, replace=False))].copy()
    for _ in range(iters):
        assign = _assign_lists(train, cent, assign_block)
        counts = np.bincount(assign, minlength=c).astype(np.float32)
        sums = np.zeros((c, d), np.float32)
        np.add.at(sums, assign, train)
        nonempty = counts > 0
        cent[nonempty] = sums[nonempty] / counts[nonempty, None]
    assign = _assign_lists(x, cent, assign_block)
    order = np.lexsort((np.arange(n), assign))        # (list, row) ascending
    offsets = np.zeros(c + 1, np.int64)
    np.cumsum(np.bincount(assign, minlength=c), out=offsets[1:])
    ivf = IVFIndex(cent, offsets, order.astype(np.int64))
    _freeze(ivf.centroids, ivf.offsets, ivf.ids)
    return ivf


def _assign_lists(x: np.ndarray, cent: np.ndarray, block: int) -> np.ndarray:
    """Chunked L2 argmin assignment (never materializes [N, C] at once)."""
    c_sq = np.sum(cent * cent, axis=1)
    out = np.empty(len(x), np.int64)
    for i in range(0, len(x), block):
        xb = x[i:i + block]
        d2 = c_sq[None, :] - 2.0 * (xb @ cent.T)      # + |x|^2 is constant
        out[i:i + block] = np.argmin(d2, axis=1)
    return out


# --------------------------------------------------------------- the tier


class RetrievalIndex:
    """One published retrieval corpus: fp32 oracle table, int8 replica,
    IVF lists, and the external-id mapping, behind a single ``search()``.

    Configs (the bench arms):
      * ``quantized=False, nprobe=None`` — EXACT: full fp32 scan, ids
        bit-identical to ``brute_force_topk`` (asserted in tests and the
        launch parity gate);
      * ``quantized=False, nprobe=C`` — exact through the IVF plumbing:
        the lists partition the corpus and fp32 scoring of a gathered
        list is bit-identical to the full matmul, so this too must match
        the oracle bit-for-bit (the structural parity arm);
      * ``quantized=True, nprobe=None`` — dense int8 scan: the Pallas
        fused scan-and-topk kernel path (``impl=`` dispatches
        numpy/ref/interpret/pallas, all bit-identical);
      * ``quantized=True, nprobe=p`` — the production arm: probe the p
        best lists per query, score candidates int8, canonical top-k;
      * ``..., refine=r`` — rescoring pass: retrieve r·k candidates with
        the quantized arm, rescore them in fp32 (gathered fp32 dots are
        bit-identical to the oracle's scores for those rows), return the
        canonical top-k.  Recovers the int8 rounding loss at negligible
        cost — recall becomes pure candidate coverage.

    ``ids`` maps corpus rows to external job ids; rows are built in
    ascending-id order so the canonical row tie-break is an id tie-break.
    """

    def __init__(self, table: np.ndarray, *, ids=None,
                 quant: QuantizedTable | None = None,
                 ivf: IVFIndex | None = None, version: int | None = None):
        self.table = np.ascontiguousarray(table, np.float32)
        n = self.table.shape[0]
        self.ids = (np.arange(n, dtype=np.int64) if ids is None
                    else np.asarray(ids, np.int64))
        assert len(self.ids) == n, (len(self.ids), n)
        self.quant = quant
        self.ivf = ivf
        self.version = version
        self._codes_f32 = None         # lazy BLAS-path view of the codes
        _freeze(self.table, self.ids)

    @classmethod
    def build(cls, vectors: np.ndarray, *, ids=None, scheme="per_row",
              num_lists: int | None = None, seed: int = 0,
              kmeans_iters: int = 10, version: int | None = None):
        """Derive the whole tier from one published fp32 table:
        ``scheme=None`` skips quantization, ``num_lists=None`` skips the
        coarse index (0 auto-sizes to ~sqrt(N))."""
        table = np.ascontiguousarray(vectors, np.float32)
        quant = quantize_int8(table, scheme) if scheme else None
        ivf = None
        if num_lists is not None:
            if num_lists == 0:
                num_lists = max(1, int(round(len(table) ** 0.5)))
            ivf = build_ivf(table, num_lists, seed=seed, iters=kmeans_iters)
        return cls(table, ids=ids, quant=quant, ivf=ivf, version=version)

    @property
    def num_lists(self) -> int:
        return 0 if self.ivf is None else len(self.ivf.centroids)

    def codes_f32(self) -> np.ndarray:
        """float32 view of the int8 codes (exact — the CPU/BLAS execution
        of the kernel's int32 accumulate; see module doc)."""
        if self._codes_f32 is None:
            self._codes_f32 = self.quant.codes.astype(np.float32)
        return self._codes_f32

    # ---- search ---------------------------------------------------------
    def search(self, queries: np.ndarray, k: int, *, nprobe: int | None = None,
               quantized: bool | None = None, impl: str | None = None,
               refine: int | None = None, query_block: int = 64):
        """Top-k retrieval.  Returns (job ids [B, k] i64, scores [B, k]
        f32); queries reaching fewer than k candidates pad with -1/-inf.

        ``impl`` selects the dense-scan scorer: None = numpy on CPU (the
        BLAS stand-in) / pallas on TPU; "ref"/"interpret"/"pallas" force
        the kernel dispatch path (all bit-identical).  ``refine=r``
        rescores the quantized arm's top r·k candidates in fp32.
        """
        q = np.asarray(queries, np.float32)
        assert q.ndim == 2 and q.shape[1] == self.table.shape[1], q.shape
        if quantized is None:
            quantized = self.quant is not None
        if quantized:
            assert self.quant is not None, "index built without quantization"
        kk = max(k, min(refine * k, self.table.shape[0])) if refine else k
        if nprobe is not None:
            assert self.ivf is not None, "index built without IVF lists"
            nprobe = int(min(nprobe, self.num_lists))
            rows, vals = self._search_ivf(q, kk, nprobe, quantized)
        elif quantized:
            rows, vals = self._search_dense_int8(q, kk, impl, query_block)
        else:
            rows, vals = brute_force_topk(q, self.table, k,
                                          query_block=query_block)
        if refine and kk > k:
            rows, vals = self._refine_fp32(q, rows, k)
        return self._to_external(rows), vals

    def _refine_fp32(self, q, cand_rows, k):
        """fp32 rescoring of the per-query candidate rows (one batched
        einsum over the gathered [B, r·k, d] block): the int8 rounding
        error drops out, so refined recall is candidate coverage — the
        fraction of oracle top-k rows the quantized pre-pass surfaced."""
        b = q.shape[0]
        valid = cand_rows >= 0
        safe = np.where(valid, cand_rows, 0)
        scores = np.einsum("bd,bkd->bk", q, self.table[safe],
                           optimize=True).astype(np.float32)
        qidx, pos = np.nonzero(valid)
        return topk_from_triples(qidx, cand_rows[valid],
                                 scores[qidx, pos], num_queries=b, k=k)

    def _to_external(self, rows: np.ndarray) -> np.ndarray:
        out = np.full(rows.shape, -1, np.int64)
        hit = rows >= 0
        out[hit] = self.ids[rows[hit]]
        return out

    def _search_dense_int8(self, q, k, impl, query_block):
        qc, qs = quantize_queries(q, self.quant)
        kk = min(k, self.table.shape[0])
        if impl is None:
            import jax
            impl = "pallas" if jax.default_backend() == "tpu" else "numpy"
        if impl == "numpy":
            rows = np.empty((q.shape[0], kk), np.int64)
            vals = np.empty((q.shape[0], kk), np.float32)
            cf, cs = self.codes_f32(), self.quant.scales
            for i in range(0, q.shape[0], query_block):
                s = ((qc[i:i + query_block].astype(np.float32) @ cf.T)
                     * (qs[i:i + query_block, None] * cs[None, :]))
                rows[i:i + query_block], vals[i:i + query_block] = \
                    _dense_topk(s, kk)
        else:
            from repro.kernels import ops
            rows = np.empty((q.shape[0], kk), np.int64)
            vals = np.empty((q.shape[0], kk), np.float32)
            for i in range(0, q.shape[0], query_block):
                v, r = ops.scan_topk(qc[i:i + query_block], qs[i:i + query_block],
                                     self.quant.codes, self.quant.scales,
                                     k=kk, impl=impl)
                rows[i:i + query_block] = np.asarray(r, np.int64)
                vals[i:i + query_block] = np.asarray(v)
        return _pad_k(rows, vals, k)

    def _search_ivf(self, q, k, nprobe, quantized):
        """Grouped inverted traversal: probe the ``nprobe`` best lists per
        query, score each probed LIST once against all the queries probing
        it (one BLAS gemm per list, candidates gathered once), scatter the
        score blocks into per-query candidate buckets, and finish with a
        per-query canonical top-k (never a global sort over all triples —
        at 1M rows × nprobe=16 that sort dominated the scan itself)."""
        ivf = self.ivf
        b = q.shape[0]
        # coarse probe: top-nprobe lists by centroid inner product
        cs_scores = q @ ivf.centroids.T
        c_n = cs_scores.shape[1]
        probes = np.argpartition(-cs_scores, min(nprobe, c_n) - 1,
                                 axis=1)[:, :nprobe] if nprobe < c_n else \
            np.broadcast_to(np.arange(c_n), (b, c_n))
        qidx = np.repeat(np.arange(b), probes.shape[1])
        lid = probes.ravel()
        order = np.argsort(lid, kind="stable")
        lid_s, qidx_s = lid[order], qidx[order]
        uniq, starts = np.unique(lid_s, return_index=True)
        bounds = np.append(starts, len(lid_s))
        sizes = (ivf.offsets[1:] - ivf.offsets[:-1])
        # per-query bucket layout: query i's candidates live at
        # buckets[offs[i]:offs[i+1]] (sum of its probed list sizes)
        counts = np.zeros(b, np.int64)
        np.add.at(counts, qidx, sizes[lid])
        offs = np.zeros(b + 1, np.int64)
        np.cumsum(counts, out=offs[1:])
        cand_r = np.empty(offs[-1], np.int64)
        cand_s = np.empty(offs[-1], np.float32)
        cursor = offs[:-1].copy()
        if quantized:
            qc, qs = quantize_queries(q, self.quant)
            qf = qc.astype(np.float32)
            cf, crow = self.codes_f32(), self.quant.scales
        for u, l in enumerate(uniq):
            rows = ivf.ids[ivf.offsets[l]:ivf.offsets[l + 1]]
            m = len(rows)
            if not m:
                continue
            ql = qidx_s[bounds[u]:bounds[u + 1]]
            if quantized:
                sb = (qf[ql] @ cf[rows].T) * (qs[ql, None] * crow[rows][None, :])
            else:
                sb = q[ql] @ self.table[rows].T
            for j, qq in enumerate(ql):
                p = cursor[qq]
                cand_r[p:p + m] = rows
                cand_s[p:p + m] = sb[j]
                cursor[qq] = p + m
        kk = min(k, len(ivf.ids))
        out_r = np.full((b, kk), -1, np.int64)
        out_v = np.full((b, kk), -np.inf, np.float32)
        for i in range(b):
            r, v = _topk_1d(cand_s[offs[i]:offs[i + 1]],
                            cand_r[offs[i]:offs[i + 1]], kk)
            out_r[i, :len(r)], out_v[i, :len(v)] = r, v
        return _pad_k(out_r, out_v, k)


def _pad_k(rows: np.ndarray, vals: np.ndarray, k: int):
    if rows.shape[1] == k:
        return rows, vals
    pr = np.full((rows.shape[0], k), -1, np.int64)
    pv = np.full((vals.shape[0], k), -np.inf, np.float32)
    pr[:, :rows.shape[1]] = rows
    pv[:, :vals.shape[1]] = vals
    return pr, pv
