"""One graph substrate for training AND serving (DESIGN.md §8).

LinkSAGE's core claim is inductive learning on a heterogeneous, *evolving*
graph where training and nearline serving see the same graph semantics
(§4.1, §5.2).  This module is the single engine both paths sit on:

  GraphEngine      — the protocol: merged-degree ``counts``, fixed-fanout
                     ``sample_batched`` over an explicit uniform stream, and
                     the ``gather_features`` join
  SnapshotEngine   — static backend: CSR :class:`HeteroGraph` + the merged
                     per-type adjacency (the DeepGNN role)
  StreamingEngine  — evolving backend: bounded neighbor rings + NoSQL
                     feature store (bootstrap + live event appends)
  TileBuilder      — the one K-hop padded-tile builder shared by the
                     trainer, ``embed_nodes`` and the nearline join

Determinism contract: every sampling decision is a pure function of an
explicit uniform stream — one ``[B, slab_width]`` slab per batch, row-major
per query node (hop 1 first, then hop 2 over hop-1 slots, ...).  Backends
share the merged-neighbor-list offset contract (relation insertion order,
then within-relation order), so a SnapshotEngine of a graph and a
StreamingEngine bootstrapped from it produce **bit-identical tiles from the
same uniforms** — including after an event suffix, as long as no ring
evicts (per-relation degree stays ≤ ``max_neighbors``).  The degree-
weighted strategy is distribution- (not bit-) equivalent across backends:
snapshot uses a precomputed global cumulative-weight array, streaming a
ring-local one (see DESIGN.md §8).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Protocol, runtime_checkable

import numpy as np

from repro.core.graph import NODE_TYPE_ID, NODE_TYPES, HeteroGraph
from repro.core.stores import NeighborStore, NoSQLStore

STRATEGIES = ("uniform", "degree_weighted")


# ------------------------------------------------------------------- tiles


class ComputeGraphBatch(NamedTuple):
    """Padded K-hop compute-graph tile; arrays are host numpy (or a pytree of
    device arrays with the same structure), moved to device whole.

    ``feats[k]`` is ``[B, F1..Fk, d]``, ``types[k]`` is ``[B, F1..Fk]`` and
    ``masks[k-1]`` is ``[B, F1..Fk]`` for hop k (hop 0 = the query nodes,
    which have no mask).  The legacy 2-hop field names (``q_feat`` ...
    ``n2_mask``) are kept as read-only views.
    """
    feats: tuple
    types: tuple
    masks: tuple

    # -- legacy 2-hop views ------------------------------------------------
    @property
    def q_feat(self):
        return self.feats[0]

    @property
    def q_type(self):
        return self.types[0]

    @property
    def n1_feat(self):
        return self.feats[1]

    @property
    def n1_type(self):
        return self.types[1]

    @property
    def n1_mask(self):
        return self.masks[0]

    @property
    def n2_feat(self):
        return self.feats[2]

    @property
    def n2_type(self):
        return self.types[2]

    @property
    def n2_mask(self):
        return self.masks[1]

    @property
    def num_hops(self) -> int:
        return len(self.masks)

    @property
    def batch_size(self) -> int:
        return self.types[0].shape[0]

    @property
    def fanouts(self) -> tuple:
        return tuple(self.types[-1].shape[1:])

def bucket_pow2(n: int, minimum: int = 8, cap: int | None = None) -> int:
    """Pad batch sizes to power-of-two buckets (min ``minimum``, optionally
    capped at ``cap``) so jit compiles one executable per bucket and
    steady-state batches never retrace.  Shared by the nearline encoder and
    the trainer's ``embed_nodes``."""
    b = max(minimum, 1 << max(n - 1, 1).bit_length())
    return b if cap is None else min(b, cap)


def pad_tile(tile: ComputeGraphBatch, to: int) -> ComputeGraphBatch:
    """Zero-pad every array of the tile along the batch axis to ``to`` rows
    (all-masked padding rows encode to garbage that is sliced off)."""
    pad = to - tile.batch_size
    if pad <= 0:
        return tile

    def _pad(x):
        return np.concatenate([x, np.zeros((pad,) + x.shape[1:], x.dtype)])

    return ComputeGraphBatch(feats=tuple(_pad(x) for x in tile.feats),
                             types=tuple(_pad(x) for x in tile.types),
                             masks=tuple(_pad(x) for x in tile.masks))


def zero_like_tile(proto: ComputeGraphBatch, batch: int) -> ComputeGraphBatch:
    """An all-masked zero tile shaped like ``proto`` but with ``batch``
    rows — the idle-shard filler for block encodes (DESIGN.md §13): zero
    type rows are fully masked, so the rows encode to garbage that the
    caller never reads, exactly like ``pad_tile`` padding."""

    def _z(x):
        return np.zeros((batch,) + x.shape[1:], x.dtype)

    return ComputeGraphBatch(feats=tuple(_z(x) for x in proto.feats),
                             types=tuple(_z(x) for x in proto.types),
                             masks=tuple(_z(x) for x in proto.masks))


def hop_widths(fanouts) -> tuple:
    """Uniforms consumed per query node at each hop: (F1, F1·F2, ...).
    THE slab layout — every consumer (TileBuilder, the scalar-join oracle)
    derives its per-hop offsets from this one running product, which is what
    keeps their uniform streams bit-aligned."""
    out, w = [], 1
    for f in fanouts:
        w *= int(f)
        out.append(w)
    return tuple(out)


def slab_width(fanouts) -> int:
    """Total uniforms consumed per query node by a K-hop build."""
    return sum(hop_widths(fanouts))


def neighbor_weight(degree):
    """Degree-weighted strategy's per-neighbor weight (shared by backends):
    bias towards well-connected neighbors, +1 so zero-degree leaves stay
    reachable."""
    return degree + 1.0


# ---------------------------------------------------------------- protocol


@runtime_checkable
class GraphEngine(Protocol):
    """The backend contract: ``sample_batched`` + ``gather_features`` are
    what the TileBuilder consumes; ``counts`` (merged out-degree) backs the
    degree-weighted strategy and the parity tests."""

    feat_dim: int
    join_reads: int

    def counts(self, types: np.ndarray, ids: np.ndarray) -> np.ndarray:
        """Merged out-degree (across all outgoing edge types) per node."""
        ...

    def sample_batched(self, types: np.ndarray, ids: np.ndarray, fanout: int,
                       uniforms: np.ndarray):
        """(types [n], ids [n], uniforms [n, F]) ->
        (dst_ty [n, F] int32, dst_id [n, F] int32, mask [n, F] float32)."""
        ...

    def gather_features(self, types: np.ndarray, ids: np.ndarray) -> np.ndarray:
        """Flat (types [n], ids [n]) -> [n, feat_dim] float32 feature join."""
        ...


# ---------------------------------------------------------------- snapshot


class MergedAdjacency:
    """Per-node-type merged CSR over all outgoing edge types.

    Alongside (indptr, dst_id, dst_ty) we precompute, for the
    degree-weighted strategy, each entry's *neighbor degree* and the
    per-type cumulative weight array ``wcum`` (cumsum of degree + 1) so
    weighted sampling is a vectorized inverse-CDF searchsorted instead of a
    per-row ``rng.choice`` with per-neighbor degree lookups.
    """

    def __init__(self, graph: HeteroGraph):
        self.graph = graph
        self.merged = {}
        for ntype in NODE_TYPES:
            rels = graph.relations_from(ntype)
            n = graph.num_nodes[ntype]
            if not rels:
                self.merged[ntype] = None
                continue
            per_rel = [graph.adj[r] for r in rels]
            # concatenate all (src, dst, dst_type) triples, stable-sort by src
            src_all = np.concatenate([np.repeat(np.arange(n), np.diff(csr.indptr))
                                      for csr in per_rel])
            dst_all = np.concatenate([csr.indices for csr in per_rel])
            ty_all = np.concatenate([np.full(csr.num_edges, NODE_TYPE_ID[d], np.int8)
                                     for (s, d), csr in zip(rels, per_rel)])
            order = np.argsort(src_all, kind="stable")
            counts = np.bincount(src_all, minlength=n)
            indptr = np.zeros(n + 1, np.int64)
            np.cumsum(counts, out=indptr[1:])
            self.merged[ntype] = (indptr, dst_all[order].astype(np.int32),
                                  ty_all[order])
        # second pass: per-entry neighbor degree + cumulative weights
        self.wcum = {}
        for ntype in NODE_TYPES:
            m = self.merged[ntype]
            if m is None:
                self.wcum[ntype] = None
                continue
            _, dst_id, dst_ty = m
            nb_deg = np.zeros(dst_id.shape[0], np.float64)
            for tid, tname in enumerate(NODE_TYPES):
                sel = np.nonzero(dst_ty == tid)[0]
                if sel.size:
                    nb_deg[sel] = self.degrees(tname)[dst_id[sel]]
            self.wcum[ntype] = np.cumsum(neighbor_weight(nb_deg))

    def degrees(self, ntype: str) -> np.ndarray:
        m = self.merged[ntype]
        if m is None:
            return np.zeros(self.graph.num_nodes[ntype], np.int64)
        return np.diff(m[0])


class SnapshotEngine:
    """Static backend: the CSR HeteroGraph + merged adjacency, answering
    fixed-fanout queries over a frozen graph snapshot (the training-time
    DeepGNN role)."""

    def __init__(self, graph: HeteroGraph, strategy: str = "uniform"):
        assert strategy in STRATEGIES, strategy
        self.graph = graph
        self.strategy = strategy
        self.madj = MergedAdjacency(graph)
        self._feat = [graph.features[t] for t in NODE_TYPES]
        self.feat_dim = graph.feat_dim
        self.join_reads = 0

    def counts(self, types: np.ndarray, ids: np.ndarray) -> np.ndarray:
        out = np.zeros(len(ids), np.int64)
        for tid, tname in enumerate(NODE_TYPES):
            sel = np.nonzero(types == tid)[0]
            if sel.size == 0 or self.madj.merged[tname] is None:
                continue
            indptr = self.madj.merged[tname][0]
            nid = ids[sel]
            out[sel] = indptr[nid + 1] - indptr[nid]
        return out

    def degree(self, tid: int, nid: int) -> int:
        m = self.madj.merged[NODE_TYPES[tid]]
        if m is None:
            return 0
        indptr = m[0]
        return int(indptr[nid + 1] - indptr[nid])

    def sample_batched(self, types: np.ndarray, ids: np.ndarray, fanout: int,
                       uniforms: np.ndarray):
        n = ids.shape[0]
        out_ty = np.zeros((n, fanout), np.int32)
        out_id = np.zeros((n, fanout), np.int32)
        out_mask = np.zeros((n, fanout), np.float32)
        for tid, tname in enumerate(NODE_TYPES):
            sel = np.nonzero(types == tid)[0]
            if sel.size == 0:
                continue
            m = self.madj.merged[tname]
            if m is None:
                continue
            indptr, dst_id, dst_ty = m
            node_ids = ids[sel]
            deg = (indptr[node_ids + 1] - indptr[node_ids]).astype(np.int64)
            has = deg > 0
            if not has.any():
                continue
            rows = sel[has]
            base = indptr[node_ids[has]]
            d = deg[has]
            u = uniforms[rows]
            if self.strategy == "degree_weighted":
                # DeepGNN-style weighted sampling: bias neighbor choice by
                # the *neighbor's* own degree (well-connected nodes carry
                # more information; §4.1 lists weighted sampling support).
                # Inverse-CDF over the precomputed cumulative weights: map
                # each uniform into its row's [wcum_lo, wcum_hi) span and
                # searchsorted back to a global entry index.
                wcum = self.madj.wcum[tname]
                lo = np.where(base > 0, wcum[base - 1], 0.0)
                hi = wcum[base + d - 1]
                targets = lo[:, None] + u * (hi - lo)[:, None]
                gidx = np.searchsorted(wcum, targets, side="right")
                offs = np.clip(gidx - base[:, None], 0, (d - 1)[:, None])
            else:
                # uniform with replacement: offsets in [0, deg)
                offs = (u * d[:, None]).astype(np.int64)
            flat = base[:, None] + offs
            out_id[rows] = dst_id[flat]
            out_ty[rows] = dst_ty[flat]
            out_mask[rows] = 1.0
        return out_ty, out_id, out_mask

    def gather_features(self, types: np.ndarray, ids: np.ndarray) -> np.ndarray:
        flat_t = types.reshape(-1)
        flat_i = ids.reshape(-1)
        out = np.zeros((flat_t.shape[0], self.feat_dim), np.float32)
        for tid in range(len(NODE_TYPES)):
            sel = np.nonzero(flat_t == tid)[0]
            if sel.size:
                out[sel] = self._feat[tid][flat_i[sel]]
        self.join_reads += flat_t.shape[0]
        return out.reshape(*types.shape, self.feat_dim)


# --------------------------------------------------------------- streaming


class StreamingEngine:
    """Evolving backend: bounded neighbor rings + NoSQL feature store.

    Bootstrap from a graph snapshot, then apply live :class:`Event`-derived
    edge/feature writes; answers the same engine queries as
    :class:`SnapshotEngine` over whatever the stores currently hold — this
    is the "stateful job marketplace graph" of §5.2, now also consumable by
    the trainer (near-realtime inductive training)."""

    def __init__(self, feat_dim: int, *, max_neighbors: int = 64,
                 strategy: str = "uniform"):
        assert strategy in STRATEGIES, strategy
        self.feat_dim = feat_dim
        self.strategy = strategy
        self.neighbor_store = NeighborStore(max_neighbors)
        self.feature_store = NoSQLStore("node-features")
        self.join_reads = 0

    # ---- writes ---------------------------------------------------------
    def bootstrap_from_graph(self, graph: HeteroGraph) -> None:
        items = []
        for ntype in NODE_TYPES:
            feats = graph.features[ntype]
            tid = NODE_TYPE_ID[ntype]
            items.extend(((tid, i), feats[i]) for i in range(feats.shape[0]))
        self.feature_store.put_many(items)
        for (s, d), csr in graph.adj.items():
            self.neighbor_store.bulk_load(s, d, csr.indptr, csr.indices)

    def add_edge(self, src_type: str, src_id: int, dst_type: str,
                 dst_id: int) -> None:
        self.neighbor_store.add(src_type, src_id, dst_type, dst_id)

    def put_feature(self, tid: int, nid: int, feat: np.ndarray) -> None:
        self.feature_store.put((tid, int(nid)), feat)

    # ---- checkpoint (DESIGN.md §12) -------------------------------------
    def snapshot(self) -> dict:
        """Full streaming-graph state: neighbor rings (with relation
        insertion order) + the feature store."""
        return {"neighbors": self.neighbor_store.snapshot(),
                "features": self.feature_store.snapshot(),
                "join_reads": self.join_reads}

    def restore(self, state: dict) -> None:
        self.neighbor_store.restore(state["neighbors"])
        self.feature_store.restore(state["features"])
        self.join_reads = int(state["join_reads"])

    # ---- reads ----------------------------------------------------------
    def get_feature(self, tid: int, nid: int) -> np.ndarray:
        self.join_reads += 1
        f = self.feature_store.get((int(tid), int(nid)))
        if f is None:
            f = np.zeros(self.feat_dim, np.float32)
        return f

    def neighbors(self, tid: int, nid: int):
        """Merged (dst_type_id, dst_id) list (the scalar-join contract)."""
        return self.neighbor_store.neighbors(NODE_TYPES[tid], nid)

    def counts(self, types: np.ndarray, ids: np.ndarray) -> np.ndarray:
        out = np.zeros(len(ids), np.int64)
        for tid, tname in enumerate(NODE_TYPES):
            sel = np.nonzero(types == tid)[0]
            if sel.size:
                out[sel] = self._type_degrees(tname, ids[sel])
        return out

    def _type_degrees(self, tname: str, ids: np.ndarray) -> np.ndarray:
        out = np.zeros(len(ids), np.int64)
        for _, st in self.neighbor_store._relations(tname):
            out += st.counts(ids)
        return out

    def gather_features(self, types: np.ndarray, ids: np.ndarray) -> np.ndarray:
        """Deduped batched feature join: flat (tid, nid) pairs -> [n, d].

        One multi_get over the unique keys instead of one get per entry;
        missing keys are zero-filled.
        """
        d = self.feat_dim
        tids = types.reshape(-1)
        nids = ids.reshape(-1)
        if tids.size == 0:
            return np.zeros((0, d), np.float32)
        packed = tids.astype(np.int64) << 40 | nids.astype(np.int64)
        uniq, inv = np.unique(packed, return_inverse=True)
        keys = [(int(p >> 40), int(p & ((1 << 40) - 1))) for p in uniq]
        vals = self.feature_store.multi_get(keys)
        self.join_reads += len(keys)
        mat = np.zeros((len(keys), d), np.float32)
        for i, v in enumerate(vals):
            if v is not None:
                mat[i] = v
        return mat[inv].reshape(*types.shape, d)

    def sample_batched(self, types: np.ndarray, ids: np.ndarray, fanout: int,
                       uniforms: np.ndarray):
        if self.strategy == "degree_weighted":
            return self._sample_weighted(types, ids, fanout, uniforms)
        return self.neighbor_store.sample_batched(types, ids, fanout, uniforms)

    def _sample_weighted(self, types: np.ndarray, ids: np.ndarray, fanout: int,
                         uniforms: np.ndarray):
        """Ring-local degree-weighted inverse-CDF (the streaming counterpart
        of the snapshot ``wcum`` path).

        Candidates are the [m, R, K] ring rows (invalid slots weight 0);
        weights are ``neighbor_weight(deg)`` with ``deg`` read live from the
        rings, cumsum'd per row.  Zero-weight slots have zero-width spans,
        so the pick distribution (and the compact merged-list oracle) is
        unaffected by the padding slots.
        """
        ns = self.neighbor_store
        n = len(ids)
        out_ty = np.zeros((n, fanout), np.int32)
        out_id = np.zeros((n, fanout), np.int32)
        out_mask = np.zeros((n, fanout), np.float32)
        for tid, tname in enumerate(NODE_TYPES):
            rows = np.nonzero(types == tid)[0]
            if rows.size == 0:
                continue
            rels = ns._relations(tname)
            if not rels:
                continue
            nid = ids[rows]
            cnts = np.stack([st.counts(nid) for _, st in rels], axis=1)  # [m, R]
            has = cnts.sum(axis=1) > 0
            if not has.any():
                continue
            rows, nid, cnts = rows[has], nid[has], cnts[has]
            m, R = rows.size, len(rels)
            # work at the batch's widest resident row, not the full ring
            # width — trailing empty slots are zero-weight anyway, so
            # dropping them cannot change any pick
            K = int(cnts.max())
            cand_id = np.zeros((m, R, K), np.int32)
            cand_ty = np.zeros((m, R, K), np.int32)
            deg = np.zeros((m, R, K), np.float64)
            for r, (dtid, st) in enumerate(rels):
                cand_id[:, r] = st.rows(nid)[:, :K]
                cand_ty[:, r] = dtid
                deg[:, r] = self._type_degrees(
                    NODE_TYPES[dtid], cand_id[:, r].reshape(-1)).reshape(m, K)
            valid = np.arange(K)[None, None, :] < cnts[:, :, None]
            w = np.where(valid, neighbor_weight(deg), 0.0).reshape(m, R * K)
            cum = np.cumsum(w, axis=1)
            targets = uniforms[rows] * cum[:, -1:]                 # [m, F]
            idx = (targets[:, :, None] >= cum[:, None, :]).sum(axis=-1)
            idx = np.clip(idx, 0, R * K - 1)
            # float-boundary guard: u·total can round up onto (or past) a
            # zero-weight padding slot — walk back to the last valid entry
            bad = np.take_along_axis(w, idx, axis=1) <= 0
            if bad.any():
                last_valid = (R * K - 1) - np.argmax(w[:, ::-1] > 0, axis=1)
                idx = np.where(bad, last_valid[:, None], idx)
            out_id[rows] = np.take_along_axis(cand_id.reshape(m, R * K), idx, axis=1)
            out_ty[rows] = np.take_along_axis(cand_ty.reshape(m, R * K), idx, axis=1)
            out_mask[rows] = 1.0
        return out_ty, out_id, out_mask


# ------------------------------------------------------------ tile builder


@dataclass
class TileBuilder:
    """The one K-hop padded-tile builder (trainer, embed_nodes AND the
    nearline sequential join all go through here).

    ``fanouts`` is an arbitrary-length tuple; each build consumes one
    ``[B, slab_width(fanouts)]`` uniform slab (row-major per query node:
    hop 1, then hop 2 over hop-1 slots, ...), either passed explicitly or
    drawn from ``rng`` — which is what makes snapshot and streaming builds
    bit-identical on the same stream, and prefetched training batches a
    pure function of (seed, step).
    """

    engine: GraphEngine
    fanouts: tuple
    dedupe: bool = True

    def __post_init__(self):
        self.fanouts = tuple(int(f) for f in self.fanouts)
        assert self.fanouts, "need at least one hop"

    def _hop_gather(self, tids: np.ndarray, nids: np.ndarray) -> np.ndarray:
        """One hop's feature join.  A hot node sampled by many parents
        appears many times in the flat row list; gathering once per DISTINCT
        key and scattering back through the inverse map is bit-identical
        (same engine rows, same order within each slot) and multiplies any
        cache's effective hit rate.  ``dedupe=False`` keeps the duplicated
        gather as the oracle arm."""
        if not self.dedupe or len(tids) <= 1:
            return self.engine.gather_features(tids, nids)
        packed = tids.astype(np.int64) << 40 | nids.astype(np.int64)
        uniq, inv = np.unique(packed, return_inverse=True)
        if len(uniq) == len(packed):
            return self.engine.gather_features(tids, nids)
        rows = self.engine.gather_features(uniq >> 40, uniq & (1 << 40) - 1)
        return rows[inv]

    @property
    def slab_width(self) -> int:
        return slab_width(self.fanouts)

    def build(self, types, ids, *, rng: np.random.Generator | None = None,
              uniforms: np.ndarray | None = None) -> ComputeGraphBatch:
        """Build the padded K-hop tile for a batch of (type, id) queries.

        ``types`` is a node-type name (uniform batch) or an int array.
        Children of masked-out parents are never sampled (their type/id/mask
        stay zero), and features are joined once per hop over the valid
        entries only — the deduped multi_get path on streaming backends.
        """
        ids = np.asarray(ids)
        b = ids.shape[0]
        if isinstance(types, str):
            types = np.full(b, NODE_TYPE_ID[types], np.int64)
        types = np.asarray(types).astype(np.int64)
        if uniforms is None:
            assert rng is not None, "build() needs exactly one of rng/uniforms"
            uniforms = rng.random((b, self.slab_width))
        d = self.engine.feat_dim

        feats = [self.engine.gather_features(types, ids.astype(np.int64))]
        typs = [types.astype(np.int32)]
        masks = []
        par_ty = types.reshape(-1)
        par_id = ids.astype(np.int64).reshape(-1)
        par_mask = np.ones(b, np.float32)
        off = 0
        for k, (f, width) in enumerate(zip(self.fanouts,
                                           hop_widths(self.fanouts))):
            u_k = uniforms[:, off:off + width].reshape(-1, f)   # [parents, f]
            off += width
            rows = par_ty.shape[0]
            ty = np.zeros((rows, f), np.int32)
            id_ = np.zeros((rows, f), np.int32)
            mask = np.zeros((rows, f), np.float32)
            valid = par_mask > 0
            if valid.any():
                t, i, mk = self.engine.sample_batched(
                    par_ty[valid], par_id[valid], f, u_k[valid])
                ty[valid], id_[valid], mask[valid] = t, i, mk
            fl = mask.reshape(-1) > 0
            fm = np.zeros((rows * f, d), np.float32)
            if fl.any():
                fm[fl] = self._hop_gather(
                    ty.reshape(-1)[fl].astype(np.int64),
                    id_.reshape(-1)[fl].astype(np.int64))
            shape = (b,) + self.fanouts[:k + 1]
            feats.append(fm.reshape(*shape, d))
            typs.append(ty.reshape(shape))
            masks.append(mask.reshape(shape))
            par_ty = ty.reshape(-1).astype(np.int64)
            par_id = id_.reshape(-1).astype(np.int64)
            par_mask = mask.reshape(-1)
        return ComputeGraphBatch(tuple(feats), tuple(typs), tuple(masks))
