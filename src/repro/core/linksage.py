"""LinkSAGE model assembly + link-prediction training (paper §4).

The trainer mirrors Figure 3 (left): label tuples (memberId, jobId, label)
→ DeepGNN-role sampler builds padded compute-graph tiles → encoder–decoder
forward → sigmoid-CE loss → AdamW.  The jitted step is pure; sampling stays
host-side.

Training hot path (DESIGN.md §7):

* batches are a pure function of (seed, step index) — per-step RNG streams —
  so a :class:`~repro.core.sampler.BatchPrefetcher` can build the next K
  batches on a background thread (numpy sampling + ``jax.device_put``) while
  the device runs the current step, bit-identically to the synchronous loop;
* the jitted step donates the TrainState buffers (no params/opt copy per
  step) and encodes BOTH tiles of the link-prediction pair in one stacked
  [2B, ...] dispatch (half the kernel launches, 2×-larger matmuls);
* an optional ``("data",)`` mesh turns the same step into a shard_map
  data-parallel step: tiles sharded on the batch dim, grads pmean-reduced,
  params/opt replicated (specs in :mod:`repro.parallel`).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.linksage import GNNConfig
from repro.core import decoder as dec
from repro.core import encoder as enc
from repro.core.engine import (ComputeGraphBatch, SnapshotEngine, TileBuilder,
                               bucket_pow2)
from repro.core.sampler import BatchPrefetcher
from repro.optim import AdamWState, adamw_init, adamw_update, clip_by_global_norm


def linksage_init(key, cfg: GNNConfig):
    k1, k2 = jax.random.split(key)
    return {"encoder": enc.encoder_init(k1, cfg), "decoder": dec.decoder_init(k2, cfg)}


def encode(params, cfg: GNNConfig, tile) -> jax.Array:
    return enc.encoder_apply(params["encoder"], cfg, tile)


def stack_tiles(m_tile, j_tile) -> ComputeGraphBatch:
    """Concatenate two same-shape tiles along the batch axis -> [2B, ...]."""
    return jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                        m_tile, j_tile)


def encode_pair(params, cfg: GNNConfig, m_tile, j_tile, *, fused: bool = True):
    """Encode the (member, job) tile pair -> (m_emb [B,e], j_emb [B,e]).

    ``fused`` stacks both tiles into one [2B, ...] encode: every per-type
    transform / SAGE-layer kernel launches once instead of twice on
    2×-larger tiles.  Row-wise ops make the stacked result bit-identical to
    the two separate encodes.
    """
    if fused:
        b = m_tile.q_feat.shape[0]
        emb = encode(params, cfg, stack_tiles(m_tile, j_tile))
        return emb[:b], emb[b:]
    return encode(params, cfg, m_tile), encode(params, cfg, j_tile)


def pos_mask_from_ids(m_ids, j_ids) -> jax.Array:
    """[B, B] 0/1 labels for the in-batch score grid from the sampled pairs.

    y_ij = 1 iff (m_ids[i], j_ids[j]) is itself one of the sampled positive
    edges, i.e. ∃k with m_ids[k] == m_ids[i] and j_ids[k] == j_ids[j].
    Without this, duplicate members/jobs inside a batch train as negatives
    against their own positives (the in-batch false-negative bug).
    """
    m_eq = (m_ids[:, None] == m_ids[None, :]).astype(jnp.float32)
    j_eq = (j_ids[:, None] == j_ids[None, :]).astype(jnp.float32)
    return (m_eq @ j_eq > 0).astype(jnp.float32)


def loss_fn(params, cfg: GNNConfig, m_tile, j_tile, labels=None, pos_mask=None,
            *, fused: bool = True):
    m_emb, j_emb = encode_pair(params, cfg, m_tile, j_tile, fused=fused)
    if cfg.decoder == "inbatch":
        return dec.inbatch_loss(cfg, m_emb, j_emb, pos_mask=pos_mask)
    assert labels is not None
    return dec.pairwise_loss(params["decoder"], cfg, m_emb, j_emb, labels)


class TrainState(NamedTuple):
    params: dict
    opt: AdamWState


def make_train_step(cfg: GNNConfig, *, lr: float = 3e-3, max_norm: float = 1.0,
                    donate: bool = True, fused: bool = True, mesh=None):
    """Build the jitted training step
    ``(state, m_tile, j_tile, m_ids, j_ids) -> (state, metrics)``.

    * ``donate``: donate the TrainState argument so params/opt buffers are
      updated in place instead of copied every step (ignored by backends
      without donation support, e.g. CPU).
    * ``fused``: one stacked [2B, ...] encode for both tiles.
    * ``mesh``: optional mesh with a ``"data"`` axis — the step becomes a
      shard_map data-parallel step: tiles/ids sharded on the batch dim,
      per-shard grads pmean-reduced, params/opt replicated.  The in-batch
      decoder then scores each shard's local B/D × B/D grid (standard local
      in-batch negatives; the pos-mask is built per shard from local ids).
    """

    def step(state: TrainState, m_tile, j_tile, m_ids, j_ids):
        def lf(p):
            if cfg.decoder == "inbatch":
                return loss_fn(p, cfg, m_tile, j_tile,
                               pos_mask=pos_mask_from_ids(m_ids, j_ids),
                               fused=fused)
            labels = jnp.ones(m_ids.shape[0], jnp.float32)
            return loss_fn(p, cfg, m_tile, j_tile, labels=labels, fused=fused)

        loss, grads = jax.value_and_grad(lf)(state.params)
        if mesh is not None:
            loss = jax.lax.pmean(loss, "data")
            grads = jax.lax.pmean(grads, "data")
        grads, gnorm = clip_by_global_norm(grads, max_norm)
        params, opt = adamw_update(state.params, grads, state.opt, lr=lr,
                                   weight_decay=0.01)
        return TrainState(params, opt), {"loss": loss, "grad_norm": gnorm}

    # CPU jax has no buffer donation: requesting it only warns once per
    # compile, so the hint is dropped there instead of globally silenced
    donate_argnums = (0,) if donate and jax.default_backend() != "cpu" else ()
    if mesh is None:
        return jax.jit(step, donate_argnums=donate_argnums)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro import parallel as par

    # state placement comes from the rule machinery (today: everything
    # replicated), so a future sharded param is a _GNN_RULES change that
    # flows straight into these specs — and a rule-less new param fails
    # loudly here, at step-build time
    state_tmpl = jax.eval_shape(
        lambda: (lambda p: TrainState(p, adamw_init(p)))(
            linksage_init(jax.random.PRNGKey(0), cfg)))
    state_sp = par.gnn_state_pspecs(state_tmpl)
    tile_sp = par.gnn_tile_pspecs(len(cfg.fanouts))
    smapped = shard_map(step, mesh=mesh,
                        in_specs=(state_sp, tile_sp, tile_sp, P("data"), P("data")),
                        out_specs=(state_sp, P()),
                        check_rep=False)
    return jax.jit(smapped, donate_argnums=donate_argnums)


@dataclass
class LinkSAGETrainer:
    """End-to-end trainer over a GraphEngine (the paper's GNN training job).

    ``prefetch`` > 0 enables the background sampler pipeline with that queue
    depth; per-step RNG streams keep it bit-identical to ``prefetch=0``.
    ``mesh`` (a ``("data",)`` mesh) enables the data-parallel step.
    ``engine`` selects the graph backend: ``None`` builds a SnapshotEngine
    over ``graph`` (static training); pass a bootstrapped
    :class:`~repro.core.engine.StreamingEngine` to train against the
    evolving event-fed store — the same substrate serving reads from.
    ``feature_cache`` (slots / CacheConfig / SlabCache) puts the §11 tier-1
    slab in front of the engine's feature gathers — the BatchPrefetcher's
    single worker thread builds every tile, so the cache needs no locking,
    and cached rows mirror engine rows bit-for-bit (training batches are
    unchanged).
    """
    cfg: GNNConfig
    graph: "HeteroGraph"
    seed: int = 0
    donate: bool = True
    fused_encode: bool = True
    prefetch: int = 0
    mesh: object = None
    engine: object = None
    feature_cache: object = None

    def __post_init__(self):
        from dataclasses import replace
        from repro.core.graph import HeteroGraph  # noqa: F401 (type only)
        if self.cfg.feat_dim != self.graph.feat_dim:
            self.cfg = replace(self.cfg, feat_dim=self.graph.feat_dim)
        if self.engine is None:
            self.engine = SnapshotEngine(self.graph)
        if self.feature_cache is not None:
            from repro.core.cache import CachedEngine, as_slab_cache
            self.feature_cache = as_slab_cache(
                self.feature_cache, self.cfg.feat_dim,
                name="train-feature-cache")
            self.engine = CachedEngine(self.engine, self.feature_cache)
        self.builder = TileBuilder(self.engine, self.cfg.fanouts)
        key = jax.random.PRNGKey(self.seed)
        params = linksage_init(key, self.cfg)
        self.state = TrainState(params, adamw_init(params))
        self.rng = np.random.default_rng(self.seed)   # legacy stream
        eng = self.graph.adj[("member", "job")]
        self._pos_src = np.repeat(np.arange(len(eng.indptr) - 1), np.diff(eng.indptr))
        self._pos_dst = eng.indices
        self._step_count = 0
        self._steps: dict = {}
        self.encoder_traces = 0                        # embed_nodes retraces
        self._embed = self._make_embed()
        self.last_train_stats: dict = {}

    # -- step-indexed batch pipeline --------------------------------------
    def _step_rng(self, step: int) -> np.random.Generator:
        """One RNG stream per (trainer seed, step index): batches are a pure
        function of the step, so prefetched and synchronous runs coincide."""
        return np.random.default_rng((self.seed, step))

    def _build_batch(self, step: int, batch_size: int):
        rng = self._step_rng(step)
        idx = rng.integers(0, len(self._pos_src), batch_size)
        m_ids = self._pos_src[idx].astype(np.int32)
        j_ids = self._pos_dst[idx].astype(np.int32)
        m_tile = self.builder.build("member", m_ids, rng=rng)
        j_tile = self.builder.build("job", j_ids, rng=rng)
        return m_tile, j_tile, m_ids, j_ids

    @staticmethod
    def _transfer(batch):
        """Host→device copy of a built batch (runs on the prefetch thread)."""
        return jax.device_put(batch)

    def _get_step(self, lr: float, max_norm: float = 1.0):
        # every build input is in the key: flipping the public donate /
        # fused_encode / mesh fields mid-run gets a fresh step, not a stale
        # cache hit
        key = (float(lr), float(max_norm), self.donate, self.fused_encode,
               self.mesh)
        if key not in self._steps:
            self._steps[key] = make_train_step(
                self.cfg, lr=lr, max_norm=max_norm, donate=self.donate,
                fused=self.fused_encode, mesh=self.mesh)
        return self._steps[key]

    def sample_label_batch(self, batch_size: int):
        """Positive engagement edges; in-batch pairs provide the negatives.
        (Legacy stateful-stream variant; the trainer samples per-step.)"""
        idx = self.rng.integers(0, len(self._pos_src), batch_size)
        return self._pos_src[idx].astype(np.int32), self._pos_dst[idx].astype(np.int32)

    def step(self, batch_size: int = 128, lr: float = 3e-3):
        batch = self._transfer(self._build_batch(self._step_count, batch_size))
        self.state, metrics = self._get_step(lr)(self.state, *batch)
        self._step_count += 1
        return {k: float(v) for k, v in metrics.items()}

    def train(self, steps: int, batch_size: int = 128, lr: float = 3e-3,
              log_every: int = 20, verbose: bool = False):
        t0 = time.perf_counter()
        stall = 0.0
        if self.prefetch > 0:
            step_fn = self._get_step(lr)
            device_metrics = []
            with BatchPrefetcher(
                    lambda i: self._build_batch(i, batch_size), steps,
                    depth=self.prefetch, transfer=self._transfer,
                    start_step=self._step_count) as pf:
                for i in range(steps):
                    self.state, m = step_fn(self.state, *pf.get())
                    # the counter tracks COMPLETED steps (a mid-run failure
                    # must not rewind the per-step RNG streams onto already
                    # -trained batches on retry)
                    self._step_count += 1
                    # keep metrics on device: no per-step host sync to stall
                    # the pipeline; converted in one pass below
                    device_metrics.append(m)
                    if verbose and i % log_every == 0:
                        print(f"step {i:4d}  loss {float(m['loss']):.4f}")
                stall = pf.stall_seconds
            history = [{k: float(v) for k, v in m.items()} for m in device_metrics]
        else:
            history = []
            for i in range(steps):
                m = self.step(batch_size, lr)
                history.append(m)
                if verbose and i % log_every == 0:
                    print(f"step {i:4d}  loss {m['loss']:.4f}  gnorm {m['grad_norm']:.3f}")
        wall = time.perf_counter() - t0
        self.last_train_stats = {
            "steps": steps, "wall_s": wall,
            "steps_per_s": steps / max(wall, 1e-9),
            "sampler_stall_s": stall,
            "sampler_stall_frac": stall / max(wall, 1e-9),
        }
        return history

    # -- inference -------------------------------------------------------
    def _make_embed(self):
        cfg = self.cfg

        def fn(params, tile):
            # trace-time side effect: counts (re)compilations per bucket
            self.encoder_traces += 1
            return enc.encoder_apply(params["encoder"], cfg, tile)

        return jax.jit(fn)

    # embed_nodes RNG domain separator (keeps inference streams disjoint
    # from the (seed, step) training streams)
    _EMBED_STREAM = 1 << 24

    def embed_nodes(self, node_type: str, ids: np.ndarray, batch: int = 256,
                    *, store=None, clock: float = 0.0):
        """Chunked encoding of ``ids``.  Full chunks reuse one compiled
        executable of shape ``batch``; the final partial chunk is padded to
        its power-of-two bucket (capped at ``batch``) so repeated calls
        never retrace (asserted via ``encoder_traces``).  Neighborhoods are
        sampled from per-chunk RNG streams, so the same call yields the
        same embeddings until the graph changes.

        ``store`` (an :class:`repro.core.embeddings.EmbeddingStore`) writes
        each embedding into the online store as an in-flight record toward
        the store's next version — the trainer-side feed of the serving
        loop."""
        out = []
        for i in range(0, len(ids), batch):
            chunk = ids[i:i + batch]
            bucket = bucket_pow2(len(chunk), cap=batch)
            pad = bucket - len(chunk)
            padded = np.concatenate([chunk, np.zeros(pad, chunk.dtype)]) if pad else chunk
            rng = np.random.default_rng((self.seed, self._EMBED_STREAM, i))
            tile = self.builder.build(node_type, padded, rng=rng)
            emb = np.asarray(self._embed(self.state.params, _to_jnp(tile)))
            out.append(emb[:len(chunk)])
            if store is not None:
                for r, nid in enumerate(chunk):
                    store.put_embedding(node_type, int(nid), out[-1][r], clock)
        return np.concatenate(out, axis=0)

    def make_lifecycle(self, *, store=None, policy=None, micro_batch: int = 256,
                       jit_encoder: bool = True):
        """An :class:`~repro.core.embeddings.EmbeddingLifecycle` over this
        trainer's engine and CURRENT encoder params, with every graph node
        registered — ``publish_version()`` on it is the offline full-sweep
        inference job feeding the downstream surfaces (DESIGN.md §9)."""
        from repro.core.embeddings import EmbeddingLifecycle
        lc = EmbeddingLifecycle(
            self.cfg, self.state.params["encoder"], self.engine,
            fanouts=self.cfg.fanouts, store=store, policy=policy,
            micro_batch=micro_batch, seed=self.seed, jit_encoder=jit_encoder)
        lc.observe_bootstrap(self.graph)
        return lc

    # -- checkpointing ----------------------------------------------------
    def save_checkpoint(self, directory: str) -> str:
        """Persist the FULL TrainState (params + optimizer moments) plus the
        completed-step counter; restoring resumes the per-step RNG streams
        exactly where they left off."""
        from repro.checkpoint import save_checkpoint as _save
        return _save(directory, self._step_count, {"state": self.state})

    def restore_checkpoint(self, directory: str, step: int | None = None) -> int:
        """Restore a :meth:`save_checkpoint` dump into this trainer (the
        template structural check rejects mismatched configs); returns the
        restored step counter."""
        from repro.checkpoint import latest_step, load_checkpoint
        if step is None:
            step = latest_step(directory)
            assert step is not None, f"no checkpoints under {directory}"
        restored = load_checkpoint(directory, step, {"state": self.state})
        self.state = restored["state"]
        self._step_count = step
        return step


def _to_jnp(tile: ComputeGraphBatch) -> ComputeGraphBatch:
    return jax.tree.map(jnp.asarray, tile)
