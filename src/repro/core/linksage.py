"""LinkSAGE model assembly + link-prediction training (paper §4).

The trainer mirrors Figure 3 (left): label tuples (memberId, jobId, label)
→ DeepGNN-role sampler builds padded compute-graph tiles → encoder–decoder
forward → sigmoid-CE loss → AdamW.  The jitted step is pure; sampling stays
host-side.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.linksage import GNNConfig
from repro.core import decoder as dec
from repro.core import encoder as enc
from repro.core.sampler import ComputeGraphBatch, NeighborSampler, SamplerConfig
from repro.optim import AdamWState, adamw_init, adamw_update, clip_by_global_norm


def linksage_init(key, cfg: GNNConfig):
    k1, k2 = jax.random.split(key)
    return {"encoder": enc.encoder_init(k1, cfg), "decoder": dec.decoder_init(k2, cfg)}


def encode(params, cfg: GNNConfig, tile) -> jax.Array:
    return enc.encoder_apply(params["encoder"], cfg, tile)


def loss_fn(params, cfg: GNNConfig, m_tile, j_tile, labels=None, pos_mask=None):
    m_emb = encode(params, cfg, m_tile)
    j_emb = encode(params, cfg, j_tile)
    if cfg.decoder == "inbatch":
        return dec.inbatch_loss(cfg, m_emb, j_emb, pos_mask=pos_mask)
    assert labels is not None
    return dec.pairwise_loss(params["decoder"], cfg, m_emb, j_emb, labels)


class TrainState(NamedTuple):
    params: dict
    opt: AdamWState


@functools.partial(jax.jit, static_argnames=("cfg", "lr", "max_norm"))
def train_step(state: TrainState, cfg: GNNConfig, m_tile, j_tile, labels,
               *, lr: float = 3e-3, max_norm: float = 1.0):
    def lf(p):
        if cfg.decoder == "inbatch":
            return loss_fn(p, cfg, m_tile, j_tile)
        return loss_fn(p, cfg, m_tile, j_tile, labels=labels)

    loss, grads = jax.value_and_grad(lf)(state.params)
    grads, gnorm = clip_by_global_norm(grads, max_norm)
    params, opt = adamw_update(state.params, grads, state.opt, lr=lr,
                               weight_decay=0.01)
    return TrainState(params, opt), {"loss": loss, "grad_norm": gnorm}


@dataclass
class LinkSAGETrainer:
    """End-to-end trainer over a HeteroGraph (the paper's GNN training job)."""
    cfg: GNNConfig
    graph: "HeteroGraph"
    seed: int = 0

    def __post_init__(self):
        from dataclasses import replace
        from repro.core.graph import HeteroGraph  # noqa: F401 (type only)
        if self.cfg.feat_dim != self.graph.feat_dim:
            self.cfg = replace(self.cfg, feat_dim=self.graph.feat_dim)
        self.sampler = NeighborSampler(self.graph, SamplerConfig(fanouts=self.cfg.fanouts,
                                                                 seed=self.seed))
        key = jax.random.PRNGKey(self.seed)
        params = linksage_init(key, self.cfg)
        self.state = TrainState(params, adamw_init(params))
        self.rng = np.random.default_rng(self.seed)
        eng = self.graph.adj[("member", "job")]
        self._pos_src = np.repeat(np.arange(len(eng.indptr) - 1), np.diff(eng.indptr))
        self._pos_dst = eng.indices

    def sample_label_batch(self, batch_size: int):
        """Positive engagement edges; in-batch pairs provide the negatives."""
        idx = self.rng.integers(0, len(self._pos_src), batch_size)
        return self._pos_src[idx].astype(np.int32), self._pos_dst[idx].astype(np.int32)

    def step(self, batch_size: int = 128, lr: float = 3e-3):
        m_ids, j_ids = self.sample_label_batch(batch_size)
        m_tile, j_tile = self.sampler.sample_pair_batch(m_ids, j_ids)
        labels = jnp.ones((batch_size,), jnp.float32)
        self.state, metrics = train_step(self.state, self.cfg,
                                         _to_jnp(m_tile), _to_jnp(j_tile), labels,
                                         lr=lr)
        return {k: float(v) for k, v in metrics.items()}

    def train(self, steps: int, batch_size: int = 128, lr: float = 3e-3,
              log_every: int = 20, verbose: bool = False):
        history = []
        for i in range(steps):
            m = self.step(batch_size, lr)
            history.append(m)
            if verbose and i % log_every == 0:
                print(f"step {i:4d}  loss {m['loss']:.4f}  gnorm {m['grad_norm']:.3f}")
        return history

    # -- inference -------------------------------------------------------
    def embed_nodes(self, node_type: str, ids: np.ndarray, batch: int = 256):
        out = []
        for i in range(0, len(ids), batch):
            chunk = ids[i:i + batch]
            pad = (-len(chunk)) % batch
            padded = np.concatenate([chunk, np.zeros(pad, chunk.dtype)]) if pad else chunk
            tile = self.sampler.sample_batch(node_type, padded)
            emb = np.asarray(encode(self.state.params, self.cfg, _to_jnp(tile)))
            out.append(emb[:len(chunk)])
        return np.concatenate(out, axis=0)


def _to_jnp(tile: ComputeGraphBatch) -> ComputeGraphBatch:
    return ComputeGraphBatch(*(jnp.asarray(x) for x in tile))
