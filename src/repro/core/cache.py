"""Device-resident memory hierarchy for the tile-build hot path (DESIGN.md §11).

Every K-hop tile build re-gathers node features out of host-side dict
stores, and that host join is the one cost batching and jit cannot remove
(ROADMAP item 2; LiGNN reports exactly this feature-fetch class dominating
their end-to-end speedups).  Node popularity is power-law, so a small hot
set serves most of the traffic — this module pins that hot set in a
fixed-size slab:

  SlabCache    — the shared tier machinery: a ``[slots, dim]`` slab kept as
                 a jnp device array (hits are an on-device ``take``, misses
                 scatter through the host staging mirror), a host-side
                 dense ``(type, id) → slot`` index, frequency-based
                 admission learned from miss traffic, and CLOCK or LFU
                 eviction
  CachedEngine — tier 1: a GraphEngine wrapper whose ``gather_features``
                 serves hits out of the slab and sends only misses to the
                 wrapped engine (feature writes invalidate), plus the
                 opt-in cache-aware sampling strategy
  (tier 2 — the encoder-output cache — lives in
  :class:`repro.core.embeddings.EmbeddingLifecycle`, reusing SlabCache)

Parity contract: a slab row is always bits the wrapped engine returned for
that key, and it is dropped the moment the key's features are re-written
(``put_feature``) — so a cached ``gather_features`` is bit-identical to the
uncached engine join at every step: hit, miss, and post-eviction re-fetch.
A cache can change latency, never bits (the same rule as the serving tier's
ResultCache).  The ONE exception is the opt-in ``sampling="cache_aware"``
strategy, which is distribution- (not bit-) equivalent: it permutes each
node's merged candidate list cached-first before the inverse-CDF pick, so
the marginal pick distribution under a uniform stream is exactly the
uncached one (a fixed permutation of an equiprobable set), but a given
uniform maps to a different neighbor.  The uncached ordering is retained
as the oracle arm (same discipline as degree_weighted across backends).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.core.graph import NODE_TYPES
from repro.obs.trace import span as _obs_span

POLICIES = ("clock", "lfu")
SAMPLING = ("passthrough", "cache_aware")

# packed (tid, nid) -> int64 key layout shared with the engines' dedupe
_ID_BITS = 40
_ID_MASK = (1 << _ID_BITS) - 1


def pack_keys(tids: np.ndarray, nids: np.ndarray) -> np.ndarray:
    return tids.astype(np.int64) << _ID_BITS | nids.astype(np.int64)


@dataclass(frozen=True)
class CacheConfig:
    """One knob set per tier.

    ``slots``       — slab rows (the device-memory budget; 0 disables).
    ``admit_after`` — a key must have MISSED this many times before the
                      next miss admits it (0 = admit on first touch,
                      ``math.inf`` = never admit: the hit-rate-0 arm).
                      Admission is learned from traffic: the counters are
                      the observed miss stream, so one-shot cold nodes
                      never displace the recurring hot set.
    ``policy``      — eviction: ``clock`` (second-chance ref bits, O(1)
                      amortized) or ``lfu`` (evict the min-use slot).
    ``device``      — keep the jnp device slab in sync (on-device ``take``
                      for hits, scatter on insert).  Off = host mirror only
                      (the staging buffer doubles as the slab).
    """
    slots: int = 4096
    admit_after: float = 1
    policy: str = "clock"
    device: bool = True


class SlabCache:
    """Fixed-size keyed slab: dense ``(type, id) → slot`` index over a
    ``[slots, dim]`` row store.

    The authoritative row store is the jnp device slab (when ``device``);
    the host mirror is the pinned staging buffer misses land in before
    being scattered to the device, and what host-side tile assembly gathers
    hits from (one fancy index, no dict walk).  The index is one dense
    int32 array per node type — lookup is a vectorized ``take``, grown
    amortized-O(1) as ids appear.
    """

    def __init__(self, dim: int, config: CacheConfig | None = None, *,
                 name: str = "slab-cache", **overrides):
        cfg = config or CacheConfig(**overrides)
        assert cfg.policy in POLICIES, cfg.policy
        self.name = name
        self.dim = int(dim)
        self.config = cfg
        self.slots = int(cfg.slots)
        self._host = np.zeros((self.slots, self.dim), np.float32)
        self._dev = None
        if cfg.device and self.slots:
            import jax.numpy as jnp
            self._dev = jnp.zeros((self.slots, self.dim), jnp.float32)
        self._key_ty = np.full(self.slots, -1, np.int64)    # -1 = free slot
        self._key_id = np.zeros(self.slots, np.int64)
        self._ref = np.zeros(self.slots, np.uint8)          # CLOCK bits
        self._use = np.zeros(self.slots, np.int64)          # LFU counters
        self._hand = 0
        self._free = list(range(self.slots - 1, -1, -1))    # pop() -> 0, 1, ...
        self._pending: set = set()          # staged slots not yet on device
        self._slot_of: dict = {}                            # tid -> int32 [n]
        self._seen: dict = {}                               # tid -> int32 [n]
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.inserts = 0
        self.invalidations = 0
        self.rejected = 0                                   # failed admission

    def __len__(self) -> int:
        return self.slots - len(self._free)

    # ---- dense per-type index -------------------------------------------
    def _index(self, tid: int, upto: int, kind: str = "_slot_of") -> np.ndarray:
        d = getattr(self, kind)
        arr = d.get(tid)
        if arr is None:
            arr = np.full(max(upto, 64), -1 if kind == "_slot_of" else 0,
                          np.int64)
            d[tid] = arr
        elif upto > len(arr):
            fill = -1 if kind == "_slot_of" else 0
            grown = np.full(max(upto, 2 * len(arr)), fill, np.int64)
            grown[:len(arr)] = arr
            d[tid] = arr = grown
        return arr

    # ---- reads ----------------------------------------------------------
    def lookup(self, tids: np.ndarray, nids: np.ndarray) -> np.ndarray:
        """Vectorized slot lookup: [n] int64, -1 = miss.  No counter side
        effects — callers account hits/misses once per logical access."""
        out = np.full(len(tids), -1, np.int64)
        if not self.slots:
            return out
        for tid in np.unique(tids):
            arr = self._slot_of.get(int(tid))
            if arr is None:
                continue
            sel = np.nonzero(tids == tid)[0]
            n = nids[sel]
            ok = n < len(arr)
            if ok.any():
                out[sel[ok]] = arr[n[ok]]
        return out

    def gather(self, slots: np.ndarray) -> np.ndarray:
        """[k, dim] host gather of resident rows (tile assembly path)."""
        return self._host[slots]

    def _sync_device(self) -> None:
        """Flush staged host rows to the device slab in ONE scatter.  Device
        sync is lazy: inserts only stage + mark, so a host-only consumer (the
        nearline tile path) never pays a device copy, and a device consumer
        pays one scatter per read boundary instead of one per insert."""
        if self._dev is None or not self._pending:
            return
        import jax.numpy as jnp
        slots = np.fromiter(self._pending, np.int64, len(self._pending))
        self._pending.clear()
        self._dev = self._dev.at[jnp.asarray(slots)].set(
            jnp.asarray(self._host[slots]))

    def gather_device(self, slots):
        """On-device ``take`` of resident rows out of the jnp slab."""
        assert self._dev is not None, "device slab disabled"
        self._sync_device()
        import jax.numpy as jnp
        return jnp.take(self._dev, jnp.asarray(slots), axis=0)

    def device_table(self):
        """The jnp slab itself (a device-side consumer indexes it by slot)."""
        self._sync_device()
        return self._dev

    def touch(self, slots: np.ndarray) -> None:
        """Reference resident slots (CLOCK ref bits / LFU use counts)."""
        self._ref[slots] = 1
        np.add.at(self._use, slots, 1)

    # ---- admission + insert ---------------------------------------------
    def note_misses(self, tids: np.ndarray, nids: np.ndarray) -> np.ndarray:
        """Record one miss per (unique) key; returns the admission mask —
        keys whose observed miss count now exceeds ``admit_after``."""
        admit = np.zeros(len(tids), bool)
        thr = self.config.admit_after
        if not self.slots or math.isinf(thr):   # frozen admission: no bumps
            return admit
        for tid in np.unique(tids):
            sel = np.nonzero(tids == tid)[0]
            n = nids[sel]
            seen = self._index(int(tid), int(n.max()) + 1, "_seen")
            np.add.at(seen, n, 1)
            admit[sel] = seen[n] > thr
        return admit

    def _evict_slot(self) -> int:
        if self.config.policy == "lfu":
            victim = int(np.argmin(self._use))
        else:                                   # CLOCK second-chance sweep
            while self._ref[self._hand]:
                self._ref[self._hand] = 0
                self._hand = (self._hand + 1) % self.slots
            victim = self._hand
            self._hand = (self._hand + 1) % self.slots
        self._slot_of[int(self._key_ty[victim])][self._key_id[victim]] = -1
        self._key_ty[victim] = -1
        self.evictions += 1
        return victim

    def insert(self, tids: np.ndarray, nids: np.ndarray,
               rows: np.ndarray) -> int:
        """Stage ``rows`` into slots (evicting as needed); the device scatter
        is deferred to the next device read (``_sync_device``).  Keys already
        resident are overwritten in place.  Returns #slots written."""
        if not self.slots:
            return 0
        k = min(len(tids), self.slots)
        slots = np.empty(k, np.int64)
        for i in range(k):
            tid, nid = int(tids[i]), int(nids[i])
            idx = self._index(tid, nid + 1)
            s = idx[nid]
            if s < 0:
                s = self._free.pop() if self._free else self._evict_slot()
                idx[nid] = s
            slots[i] = s
            self._key_ty[s] = tid
            self._key_id[s] = nid
            self._ref[s] = 1
            self._use[s] = 1
        self._host[slots] = rows[:k]            # the pinned staging write
        if self._dev is not None:
            self._pending.update(slots.tolist())
        self.inserts += k
        self.rejected += len(tids) - k
        return k

    # ---- invalidation ----------------------------------------------------
    def invalidate(self, tid: int, nid: int) -> bool:
        """Drop one key (feature rewrite / dirty mark); True if resident."""
        arr = self._slot_of.get(int(tid))
        if arr is None or nid >= len(arr) or arr[nid] < 0:
            return False
        s = int(arr[nid])
        arr[nid] = -1
        self._key_ty[s] = -1
        self._ref[s] = 0
        self._use[s] = 0
        self._free.append(s)
        self.invalidations += 1
        return True

    def clear(self) -> None:
        for arr in self._slot_of.values():
            arr.fill(-1)
        self._key_ty.fill(-1)
        self._ref.fill(0)
        self._use.fill(0)
        self._free = list(range(self.slots - 1, -1, -1))
        self._pending.clear()
        self._hand = 0

    # ---- checkpoint (DESIGN.md §12) -------------------------------------
    def snapshot(self) -> dict:
        """Index + host slab + eviction state.  A slab never changes bits
        (rows duplicate live store state), so snapshotting it is a warm-
        restart PERFORMANCE feature: the restored tier starts hot instead
        of re-learning admission from scratch."""
        return {
            "host": self._host.copy(),
            "key_ty": self._key_ty.copy(), "key_id": self._key_id.copy(),
            "ref": self._ref.copy(), "use": self._use.copy(),
            "hand": self._hand, "free": list(self._free),
            "slot_of": {t: a.copy() for t, a in self._slot_of.items()},
            "seen": {t: a.copy() for t, a in self._seen.items()},
            "counters": (self.hits, self.misses, self.evictions,
                         self.inserts, self.invalidations, self.rejected),
        }

    def restore(self, state: dict) -> None:
        self._host = state["host"].copy()
        self._key_ty = state["key_ty"].copy()
        self._key_id = state["key_id"].copy()
        self._ref = state["ref"].copy()
        self._use = state["use"].copy()
        self._hand = int(state["hand"])
        self._free = list(state["free"])
        self._slot_of = {t: a.copy() for t, a in state["slot_of"].items()}
        self._seen = {t: a.copy() for t, a in state["seen"].items()}
        (self.hits, self.misses, self.evictions, self.inserts,
         self.invalidations, self.rejected) = state["counters"]
        # the host mirror is now authoritative: stage every resident slot so
        # the next device read re-scatters the slab lazily
        if self._dev is not None:
            self._pending = set(np.nonzero(self._key_ty >= 0)[0].tolist())

    # ---- reporting -------------------------------------------------------
    def hit_rate(self) -> float:
        return self.hits / max(self.hits + self.misses, 1)

    def summary(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "hit_rate": self.hit_rate(), "evictions": self.evictions,
                "inserts": self.inserts, "invalidations": self.invalidations,
                "resident": len(self), "slots": self.slots}


def as_slab_cache(spec, dim: int, *, name: str, **defaults) -> SlabCache | None:
    """Normalize a cache spec: None | slot count | CacheConfig | SlabCache.
    ``defaults`` season the bare-slot-count form only (an explicit
    CacheConfig or SlabCache already states its policy)."""
    if spec is None or isinstance(spec, SlabCache):
        return spec
    if isinstance(spec, CacheConfig):
        return SlabCache(dim, spec, name=name)
    return SlabCache(dim, slots=int(spec), name=name, **defaults)


# ----------------------------------------------------------------- tier 1


class CachedEngine:
    """GraphEngine wrapper: ``gather_features`` through the slab, everything
    else delegated to the wrapped engine.

    Hits are one vectorized slot lookup + slab gather (no dict walk, no
    per-key Python); only misses reach the wrapped engine — so
    ``join_reads`` (delegated) now counts actual store reads, and the
    hit/miss counters mirror into an attached ``metrics`` object (the
    lifecycle's :class:`~repro.core.embeddings.LifecycleMetrics`).
    ``put_feature`` invalidates before writing through, which is the entire
    tier-1 coherence story: cached rows only ever duplicate live store
    bits.
    """

    def __init__(self, inner, cache: SlabCache | None = None, *,
                 sampling: str = "passthrough", metrics=None, **overrides):
        assert sampling in SAMPLING, sampling
        self.inner = inner
        self.cache = cache if cache is not None else SlabCache(
            inner.feat_dim, name="feature-cache", **overrides)
        assert self.cache.dim == inner.feat_dim, \
            (self.cache.dim, inner.feat_dim)
        self.sampling = sampling
        if sampling == "cache_aware":
            assert hasattr(inner, "neighbor_store"), \
                "cache_aware sampling needs a ring-backed (streaming) engine"
        self.metrics = metrics

    # ---- protocol --------------------------------------------------------
    @property
    def feat_dim(self) -> int:
        return self.inner.feat_dim

    @property
    def join_reads(self) -> int:
        return self.inner.join_reads

    def counts(self, types: np.ndarray, ids: np.ndarray) -> np.ndarray:
        return self.inner.counts(types, ids)

    def sample_batched(self, types: np.ndarray, ids: np.ndarray, fanout: int,
                       uniforms: np.ndarray):
        if self.sampling == "cache_aware":
            return self._sample_cache_aware(types, ids, fanout, uniforms)
        return self.inner.sample_batched(types, ids, fanout, uniforms)

    def gather_features(self, types: np.ndarray, ids: np.ndarray) -> np.ndarray:
        types = np.asarray(types)
        d = self.feat_dim
        flat_t = types.reshape(-1).astype(np.int64)
        flat_i = np.asarray(ids).reshape(-1).astype(np.int64)
        n = flat_t.shape[0]
        if n == 0:
            return np.zeros((*types.shape, d), np.float32)
        with _obs_span("cache.feature_gather") as sp:
            slots = self.cache.lookup(flat_t, flat_i)
            hit = slots >= 0
            nh = int(hit.sum())
            out = np.empty((n, d), np.float32)
            if nh:
                hs = slots[hit]
                out[hit] = self.cache.gather(hs)
                self.cache.touch(hs)
            if nh < n:
                miss = ~hit
                mt, mi = flat_t[miss], flat_i[miss]
                rows = self.inner.gather_features(mt, mi)
                out[miss] = rows
                # admission over the unique miss keys (first occurrence's row)
                uniq, first = np.unique(pack_keys(mt, mi), return_index=True)
                ut, ui = uniq >> _ID_BITS, uniq & _ID_MASK
                admit = self.cache.note_misses(ut, ui)
                if admit.any():
                    self.cache.insert(ut[admit], ui[admit], rows[first[admit]])
            self.cache.hits += nh
            self.cache.misses += n - nh
            sp.set("rows", n)
            sp.set("hits", nh)
            m = self.metrics
            if m is not None:
                m.feature_cache_hits += nh
                m.feature_cache_misses += n - nh
                m.feature_cache_evictions = self.cache.evictions
        return out.reshape(*types.shape, d)

    # ---- write-through invalidation -------------------------------------
    def put_feature(self, tid: int, nid: int, feat: np.ndarray) -> None:
        self.cache.invalidate(int(tid), int(nid))
        self.inner.put_feature(tid, nid, feat)

    def bootstrap_from_graph(self, graph) -> None:
        self.cache.clear()
        self.inner.bootstrap_from_graph(graph)

    def prewarm(self, tids: np.ndarray, nids: np.ndarray) -> int:
        """Force-admit a key set (bench/ops warm-start; bypasses the learned
        admission, never the parity contract — rows still come from the
        wrapped engine)."""
        tids = np.asarray(tids, np.int64)
        nids = np.asarray(nids, np.int64)
        rows = self.inner.gather_features(tids, nids)
        return self.cache.insert(tids, nids, rows)

    # ---- cache-aware sampling -------------------------------------------
    def _sample_cache_aware(self, types, ids, fanout, uniforms):
        """Cached-first candidate permutation + the standard inverse-CDF
        pick.

        Per parent the merged candidate list (relation order, then ring
        column order — the §2 offset contract) is stably reordered so slab-
        resident neighbors form a prefix; the pick ``j = floor(u·deg)``
        then indexes the permuted list.  For a uniform ``u`` a fixed
        permutation of an equiprobable candidate set leaves the marginal
        pick distribution exactly unchanged (the distribution contract,
        tested against the passthrough oracle), while picks under the
        deterministic per-node slabs stay pinned to the resident prefix as
        rings grow — re-picking already-cached neighbors where the
        passthrough index arithmetic would shift onto uncached ones.
        """
        ns = self.inner.neighbor_store
        n = len(ids)
        out_ty = np.zeros((n, fanout), np.int32)
        out_id = np.zeros((n, fanout), np.int32)
        out_mask = np.zeros((n, fanout), np.float32)
        for tid, tname in enumerate(NODE_TYPES):
            rows_all = np.nonzero(types == tid)[0]
            if rows_all.size == 0:
                continue
            rels = ns._relations(tname)
            if not rels:
                continue
            nid = ids[rows_all]
            cnts = np.stack([st.counts(nid) for _, st in rels], axis=1)
            total = cnts.sum(axis=1)
            has = total > 0
            if not has.any():
                continue
            rows_all, nid = rows_all[has], nid[has]
            cnts, total = cnts[has], total[has]
            m, R = rows_all.size, len(rels)
            K = int(cnts.max())
            cand_id = np.zeros((m, R, K), np.int32)
            cand_ty = np.zeros((m, R, K), np.int32)
            for r, (dtid, st) in enumerate(rels):
                cand_id[:, r] = st.rows(nid)[:, :K]
                cand_ty[:, r] = dtid
            valid = np.arange(K)[None, None, :] < cnts[:, :, None]
            resident = (self.cache.lookup(
                cand_ty.reshape(-1).astype(np.int64),
                cand_id.reshape(-1).astype(np.int64)
            ).reshape(m, R, K) >= 0) & valid
            # stable 3-way rank: resident-valid < uncached-valid < invalid;
            # compacting the valid set preserves merged-offset semantics
            rank = np.where(valid, np.where(resident, 0, 1), 2)
            order = np.argsort(rank.reshape(m, R * K), axis=1, kind="stable")
            j = (uniforms[rows_all] * total[:, None]).astype(np.int64)
            pick = np.take_along_axis(order, j, axis=1)
            out_id[rows_all] = np.take_along_axis(
                cand_id.reshape(m, R * K), pick, axis=1)
            out_ty[rows_all] = np.take_along_axis(
                cand_ty.reshape(m, R * K), pick, axis=1)
            out_mask[rows_all] = 1.0
        return out_ty, out_id, out_mask

    # everything else (neighbor_store, feature_store, add_edge, neighbors,
    # get_feature — the scalar oracle reads stay uncached —, strategy, ...)
    # delegates to the wrapped engine
    def __getattr__(self, name):
        return getattr(self.inner, name)
