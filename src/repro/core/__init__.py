"""LinkSAGE core: the paper's contribution.

  graph     — heterogeneous job-marketplace graph (§3)
  stores    — NoSQL / ring-buffer storage primitives (§5.2)
  engine    — the shared graph substrate: GraphEngine protocol, snapshot +
              streaming backends, K-hop TileBuilder (DESIGN.md §8)
  sampler   — training front-end over the engine (DeepGNN role, §4.1)
  encoder   — GraphSAGE mean/attention encoder (§4.2)
  decoder   — MLP / cosine / in-batch decoders + losses (§4.2)
  linksage  — model assembly + link-prediction training (§4.3)
  embeddings— versioned EmbeddingStore + recompute lifecycle: dirty sets,
              staleness policy, incremental drain / full sweep (§5.2, §9)
  cache     — device-resident memory hierarchy: SlabCache slabs +
              CachedEngine feature tier on the tile-build hot path (§11)
  transfer  — frozen encoder → per-surface downstream DNNs: TAJ, JYMBII,
              JobSearch, EBR registry + multi-surface training (§5.1, §7)
  nearline  — nearline inference pipeline (§5.2, Figure 4)
  eval      — offline proxies for the §7 A/B metrics
"""
