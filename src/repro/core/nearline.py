"""Nearline GNN inference framework (paper §5.2, Figure 4).

Faithfully reproduces the production dataflow without the JVM/Kafka stack:

  Kafka topics            → :class:`Topic` (append log + consumer offsets)
  NoSQL feature stores    → :class:`repro.core.stores.NoSQLStore`
  neighbor stores/type    → :class:`repro.core.stores.NeighborStore` rings
  graph substrate         → :class:`repro.core.engine.StreamingEngine`
                            (the evolving backend of the shared GraphEngine)
  sequential join         → the shared K-hop :class:`TileBuilder` — the SAME
                            builder the trainer samples through (DESIGN.md §8)
  nearline GNN inference  → the :class:`EmbeddingLifecycle`'s batched
                            priority recompute queue draining through the
                            shape-bucketed jitted encoder (DESIGN.md §9)
  online feature store    → versioned :class:`EmbeddingStore`
                            (embedding + version + computed-at timestamp)

Triggers (paper): (1) a recruiter creates a job posting; (2) new neighbors
(members who applied/saved/clicked) arrive on an existing job.  Member
embeddings refresh symmetrically on engagement/profile events.

The "stateful job marketplace graph" IS the StreamingEngine: bounded
neighbor rings + feature store, bootstrapped from a snapshot and advanced by
live events.  Events dirty nodes through the lifecycle's staleness policy
(endpoints only by default; the full K-hop dependency closure under
``StalenessPolicy(closure_radius=None)``, which makes the incremental drain
bit-equivalent to an offline full sweep — the §9 parity contract).  Every
recompute samples from per-node deterministic uniform streams, so refreshed
embeddings depend on the graph state, never on event batching.  The per-key
scalar join survives only as a benchmark baseline (and as the pre-refactor
bit-exactness oracle).
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass

import numpy as np

from repro.configs.linksage import GNNConfig
from repro.core.embeddings import (EmbeddingLifecycle,  # noqa: F401
                                   EmbeddingStore, LifecycleMetrics,
                                   StalenessPolicy)
from repro.core.engine import (ComputeGraphBatch, StreamingEngine,
                               hop_widths, slab_width)
from repro.core.graph import NODE_TYPE_ID
from repro.core.stores import (NeighborStore, NoSQLStore,  # noqa: F401
                               RingBuffer)
from repro.obs.trace import span as _obs_span

# nearline shares the lifecycle's counter set (summary() included)
NearlineMetrics = LifecycleMetrics


# --------------------------------------------------------------- messaging


@dataclass
class Event:
    time: float                      # simulated seconds
    kind: str                        # job_created | engagement | recruiter_interaction | member_update
    payload: dict


class Topic:
    """Kafka-topic stand-in: append-only log with per-consumer offsets."""

    def __init__(self, name: str):
        self.name = name
        self.log: list[Event] = []
        self.offsets: dict[str, int] = defaultdict(int)

    def publish(self, event: Event) -> None:
        self.log.append(event)

    def poll(self, consumer: str, max_events: int, *, upto_time: float | None = None):
        start = self.offsets[consumer]
        out = []
        for ev in self.log[start:start + max_events]:
            if upto_time is not None and ev.time > upto_time:
                break
            out.append(ev)
        self.offsets[consumer] += len(out)
        return out

    def lag(self, consumer: str) -> int:
        return len(self.log) - self.offsets[consumer]


def apply_marketplace_event(ev: Event, *, put_feature, add_edge, register):
    """THE §5.2 event semantics, shared by the single-engine nearline path
    and the sharded serving cluster (one definition, zero tier drift).

    ``put_feature(tid, nid, feat)`` / ``add_edge(src_t, src_i, dst_t,
    dst_i)`` / ``register(ntype, nid)`` are the write primitives of the
    hosting tier; returns the ``(ntype, nid, time)`` touched list whose
    entries the caller marks dirty.
    """
    touched = []
    p = ev.payload
    if ev.kind == "job_created":
        put_feature(NODE_TYPE_ID["job"], p["job_id"], p["features"])
        register("job", p["job_id"])
        for attr in ("title", "company", "position", "skill"):
            if attr in p:
                add_edge("job", p["job_id"], attr, p[attr])
                add_edge(attr, p[attr], "job", p["job_id"])
        touched.append(("job", p["job_id"], ev.time))
    elif ev.kind == "engagement":                  # member saved/applied/clicked
        # both rings change: the member gains the job AND the job gains
        # the member ("new neighbors arrive on an existing job", §5.2) —
        # recomputes are deterministic per node, so an unchanged ring
        # would mean an unchanged embedding
        add_edge("member", p["member_id"], "job", p["job_id"])
        add_edge("job", p["job_id"], "member", p["member_id"])
        touched.append(("job", p["job_id"], ev.time))
        touched.append(("member", p["member_id"], ev.time))
    elif ev.kind == "recruiter_interaction":       # recruiter reached out
        add_edge("job", p["job_id"], "member", p["member_id"])
        touched.append(("job", p["job_id"], ev.time))
    elif ev.kind == "member_update":
        put_feature(NODE_TYPE_ID["member"], p["member_id"], p["features"])
        register("member", p["member_id"])
        touched.append(("member", p["member_id"], ev.time))
    return touched


# the modelled few-seconds pipeline delay between an event's own time and
# the nearline refresh that processes it (staleness accounting default)
NEARLINE_LAG_S = 2.0


def poll_and_apply(topic: Topic, consumer: str, micro_batch: int, apply_event,
                   mark_dirty, *, upto_time: float | None = None,
                   max_events: int = 10**9) -> int:
    """THE ingest loop (poll → apply → dirty, NO recompute), shared by the
    single-engine and sharded tiers; returns #events applied."""
    total = 0
    while total < max_events:
        events = topic.poll(consumer, min(micro_batch, max_events - total),
                            upto_time=upto_time)
        if not events:
            break
        for ev in events:
            for (ntype, nid, t) in apply_event(ev):
                mark_dirty(ntype, nid, t)
        total += len(events)
    return total


def poll_and_process(topic: Topic, consumer: str, micro_batch: int,
                     apply_event, mark_dirty, drain, *,
                     upto_time: float | None = None,
                     max_batches: int = 10**9,
                     clock: float | None = None) -> int:
    """THE nearline loop (poll → apply → dirty → drain per micro-batch),
    shared by both tiers.  ``drain(refresh_time)`` is called once per event
    batch; ``clock`` overrides the default event-time + NEARLINE_LAG_S
    refresh stamp.  Returns #events handled."""
    total = 0
    for _ in range(max_batches):
        events = topic.poll(consumer, micro_batch, upto_time=upto_time)
        if not events:
            break
        with _obs_span("nearline.batch") as sp:
            for ev in events:
                for (ntype, nid, t) in apply_event(ev):
                    mark_dirty(ntype, nid, t)
            refresh = (clock if clock is not None
                       else max(ev.time for ev in events) + NEARLINE_LAG_S)
            drain(refresh)
            sp.set("events", len(events))
        total += len(events)
    return total


# -------------------------------------------------------------- inference


class NearlineInference:
    """The nearline pipeline: poll → update the streaming engine → dirty the
    lifecycle → drain its priority queue through the shared K-hop tile build
    + bucketed encoder → versioned embedding store (Figure 4)."""

    def __init__(self, cfg: GNNConfig, encoder_params, *, fanouts=None,
                 micro_batch: int = 64, max_neighbors: int = 64, seed: int = 0,
                 join_impl: str = "batched", jit_encoder: bool = True,
                 strategy: str = "uniform", policy: StalenessPolicy | None = None,
                 store: EmbeddingStore | None = None, feature_cache=None,
                 cache_sampling: str = "passthrough", embed_cache=None):
        from repro.core.cache import CachedEngine, as_slab_cache
        assert join_impl in ("batched", "scalar"), join_impl
        # the scalar arm is the uniform-sampling oracle; it has no weighted walk
        assert join_impl == "batched" or strategy == "uniform", (join_impl, strategy)
        # cache-aware sampling is a distributional (not bitwise) arm: the
        # scalar oracle and the weighted walk both pin the uncached ordering
        assert cache_sampling == "passthrough" or (
            join_impl == "batched" and strategy == "uniform"), (
            cache_sampling, join_impl, strategy)
        self.cfg = cfg
        self.params = encoder_params
        self.fanouts = tuple(fanouts or cfg.fanouts)
        self.micro_batch = micro_batch
        self.join_impl = join_impl
        self.jit_encoder = jit_encoder
        self.topic = Topic("job-marketplace-events")
        self.engine = StreamingEngine(cfg.feat_dim, max_neighbors=max_neighbors,
                                      strategy=strategy)
        # tier 1 of the §11 memory hierarchy: the tile builder below gathers
        # through the slab; put_feature invalidates before writing through
        cache = as_slab_cache(feature_cache, cfg.feat_dim, name="feature-cache")
        if cache is not None or cache_sampling != "passthrough":
            self.engine = CachedEngine(self.engine, cache,
                                       sampling=cache_sampling)
        self.lifecycle = EmbeddingLifecycle(
            cfg, encoder_params, self.engine, fanouts=self.fanouts,
            store=store, policy=policy, micro_batch=micro_batch, seed=seed,
            tile_fn=self._sequential_join, jit_encoder=jit_encoder,
            embed_cache=embed_cache)
        if isinstance(self.engine, CachedEngine):
            self.engine.metrics = self.lifecycle.metrics
            self.lifecycle.store.attach_cache(self.engine.cache)
        self.builder = self.lifecycle.builder

    # lifecycle views (store/metrics live on the lifecycle now)
    @property
    def embedding_store(self) -> EmbeddingStore:
        return self.lifecycle.store

    @property
    def metrics(self) -> NearlineMetrics:
        return self.lifecycle.metrics

    @metrics.setter
    def metrics(self, m) -> None:
        self.lifecycle.metrics = m
        if hasattr(self.engine, "metrics"):     # keep the CachedEngine mirror
            self.engine.metrics = m

    @property
    def feature_cache(self):
        return getattr(self.engine, "cache", None)

    # engine-store views (the stores belong to the StreamingEngine now)
    @property
    def neighbor_store(self) -> NeighborStore:
        return self.engine.neighbor_store

    @property
    def feature_store(self) -> NoSQLStore:
        return self.engine.feature_store

    # ---- store bootstrap (initial graph snapshot load) -------------------
    def bootstrap_from_graph(self, graph) -> None:
        self.engine.bootstrap_from_graph(graph)
        self.lifecycle.observe_bootstrap(graph)

    # ---- event application ----------------------------------------------
    def _add_edge(self, src_type: str, src_id: int, dst_type: str,
                  dst_id: int) -> None:
        self.engine.add_edge(src_type, src_id, dst_type, dst_id)
        self.lifecycle.observe_edge((src_type, int(src_id)),
                                    (dst_type, int(dst_id)))

    def _apply_event(self, ev: Event):
        return apply_marketplace_event(
            ev, put_feature=self.engine.put_feature, add_edge=self._add_edge,
            register=self.lifecycle.register)

    # ---- sequential join: node -> neighbors -> neighbor features ---------
    #
    # The production path is the shared TileBuilder over the StreamingEngine
    # (~one vectorized sample + one deduped multi_get per hop).  Both arms
    # consume the lifecycle's per-node uniform slabs (one slab per query
    # node, row-major over hops) and share the merged-neighbor-list offset
    # contract, so the scalar per-key baseline produces bit-identical tiles
    # — the pre-optimization O(B·F1···FK) oracle kept for benchmarking.

    def _sequential_join(self, nodes) -> ComputeGraphBatch:
        if self.join_impl == "scalar":
            reads0 = self.engine.join_reads
            tile = self._sequential_join_scalar(nodes)
            self.metrics.join_reads += self.engine.join_reads - reads0
            return tile
        return self.lifecycle.build_tile(nodes)   # accounts its own reads

    def _sequential_join_scalar(self, nodes) -> ComputeGraphBatch:
        fan = self.fanouts
        b = len(nodes)
        d = self.cfg.feat_dim
        widths = hop_widths(fan)
        feats = [np.zeros((b, d), np.float32)]
        typs = [np.zeros(b, np.int32)]
        masks = []
        for k, f in enumerate(fan):
            shape = (b,) + fan[:k + 1]
            feats.append(np.zeros(shape + (d,), np.float32))
            typs.append(np.zeros(shape, np.int32))
            masks.append(np.zeros(shape, np.float32))
        for r, (ntype, nid) in enumerate(nodes):
            u = self.lifecycle.uniform_slab(ntype, nid)
            tid = NODE_TYPE_ID[ntype]
            typs[0][r] = tid
            feats[0][r] = self.engine.get_feature(tid, nid)
            frontier = [(tid, int(nid), True)]
            off = 0
            for k, f in enumerate(fan):
                uk = u[off:off + widths[k]].reshape(-1, f)
                off += widths[k]
                fe = feats[k + 1][r].reshape(-1, d)
                ty = typs[k + 1][r].reshape(-1)
                mk = masks[k][r].reshape(-1)
                nxt = []
                for s, (pt, pi, pvalid) in enumerate(frontier):
                    merged = self.engine.neighbors(pt, pi) if pvalid else []
                    for v in range(f):
                        if not merged:
                            nxt.append((0, 0, False))
                            continue
                        t2, i2 = merged[int(uk[s, v] * len(merged))]
                        ty[s * f + v], mk[s * f + v] = t2, 1.0
                        fe[s * f + v] = self.engine.get_feature(t2, i2)
                        nxt.append((t2, i2, True))
                frontier = nxt
        return ComputeGraphBatch(tuple(feats), tuple(typs), tuple(masks))

    # ---- the nearline loop ------------------------------------------------
    def ingest(self, *, upto_time: float | None = None,
               max_events: int = 10**9) -> int:
        """Apply pending events to the engine and dirty the lifecycle WITHOUT
        recomputing (the offline publish path ingests a whole window, then
        sweeps).  Returns #events applied."""
        return poll_and_apply(self.topic, "nearline", self.micro_batch,
                              self._apply_event, self.lifecycle.mark_dirty,
                              upto_time=upto_time, max_events=max_events)

    def process(self, *, upto_time: float | None = None, max_batches: int = 10**9,
                clock: float | None = None) -> int:
        """Drain pending events in micro-batches; returns #events handled.

        ``clock`` is the simulated wall time when processing happens (for
        staleness accounting); defaults to each event's own time + the
        NEARLINE_LAG_S pipeline delay, modelling the few-seconds lag.
        """
        total = poll_and_process(
            self.topic, "nearline", self.micro_batch, self._apply_event,
            self.lifecycle.mark_dirty,
            lambda refresh: self.lifecycle.drain(clock=refresh),
            upto_time=upto_time, max_batches=max_batches, clock=clock)
        self.metrics.events_processed += total
        return total


class OfflineBatchInference:
    """The pre-nearline baseline (§5.2): daily batch job — embeddings refresh
    only at day boundaries, so new jobs wait up to 24 h (Table 10 control).

    ``mode="drain"`` replays the window through the incremental path at each
    boundary (the legacy staleness baseline); ``mode="publish"`` ingests the
    window and runs the lifecycle's full-sweep ``publish_version`` — every
    registered node recomputed at the boundary graph state, frozen as a
    numbered version (the offline side of the §9 parity contract).
    """

    def __init__(self, nearline: NearlineInference, *, period_s: float = 86_400.0,
                 mode: str = "drain"):
        assert mode in ("drain", "publish"), mode
        self.inner = nearline
        self.period = period_s
        self.mode = mode
        self.last_run = 0.0

    def maybe_run(self, now: float) -> int:
        ran = 0
        while self.last_run + self.period <= now:
            self.last_run += self.period
            if self.mode == "publish":
                ran += self.inner.ingest(upto_time=self.last_run)
                self.inner.lifecycle.publish_version(clock=self.last_run)
            else:
                ran += self.inner.process(upto_time=self.last_run,
                                          clock=self.last_run)
        return ran
