"""Nearline GNN inference framework (paper §5.2, Figure 4).

Faithfully reproduces the production dataflow without the JVM/Kafka stack:

  Kafka topics            → :class:`Topic` (append log + consumer offsets)
  NoSQL feature stores    → :class:`NoSQLStore` (keyed store with I/O counters)
  neighbor stores/type    → :class:`NeighborStore` (bounded per-node rings)
  sequential join         → :meth:`NearlineInference._sequential_join`
                            (batched multi_get joins; see DESIGN.md §5)
  nearline GNN inference  → shape-bucketed jitted encoder on the joined tiles
  online feature store    → :class:`EmbeddingStore` (embedding + timestamp)

Triggers (paper): (1) a recruiter creates a job posting; (2) new neighbors
(members who applied/saved/clicked) arrive on an existing job.  Member
embeddings refresh symmetrically on engagement/profile events.

The "stateful job marketplace graph" emerges from the stores: during
inference only neighbors + their input features are needed — not a full
graph engine with temporal processing/sampling (§5.2) — which is exactly
what the sequential join provides.
"""
from __future__ import annotations

import time as _time
from collections import defaultdict
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.linksage import GNNConfig
from repro.core.graph import NODE_TYPE_ID, NODE_TYPES
from repro.core.sampler import ComputeGraphBatch


# --------------------------------------------------------------- messaging


@dataclass
class Event:
    time: float                      # simulated seconds
    kind: str                        # job_created | engagement | recruiter_interaction | member_update
    payload: dict


class Topic:
    """Kafka-topic stand-in: append-only log with per-consumer offsets."""

    def __init__(self, name: str):
        self.name = name
        self.log: list[Event] = []
        self.offsets: dict[str, int] = defaultdict(int)

    def publish(self, event: Event) -> None:
        self.log.append(event)

    def poll(self, consumer: str, max_events: int, *, upto_time: float | None = None):
        start = self.offsets[consumer]
        out = []
        for ev in self.log[start:start + max_events]:
            if upto_time is not None and ev.time > upto_time:
                break
            out.append(ev)
        self.offsets[consumer] += len(out)
        return out

    def lag(self, consumer: str) -> int:
        return len(self.log) - self.offsets[consumer]


# ------------------------------------------------------------------ stores


class NoSQLStore:
    """In-memory NoSQL store with read/write accounting (I/O bottleneck
    analysis, §5.2 challenge (c))."""

    def __init__(self, name: str):
        self.name = name
        self._d: dict = {}
        self.reads = 0
        self.writes = 0

    def put(self, key, value) -> None:
        self._d[key] = value
        self.writes += 1

    def get(self, key, default=None):
        self.reads += 1
        return self._d.get(key, default)

    def put_many(self, items) -> None:
        """Bulk write (one RPC in the real store): items is (key, value)s."""
        items = list(items)
        self._d.update(items)
        self.writes += len(items)

    def multi_get(self, keys):
        self.reads += len(keys)
        return [self._d.get(k) for k in keys]

    def __contains__(self, key):
        return key in self._d

    def __len__(self):
        return len(self._d)


class RingBuffer:
    """Array-backed bounded neighbor lists for one (src_type, dst_type) edge
    type: a [capacity, K] int32 ring per source node with a write cursor.

    Replaces the old list-copy-append NoSQLStore values: ``add`` is an O(1)
    in-place write, bulk bootstrap is a vectorized fill, and batched
    sampling reads the backing arrays directly (no per-key dict gets).
    Neighbor *order* inside a row is not meaningful once the ring wraps —
    sampling is uniform over the resident set, so only membership matters.
    """

    def __init__(self, name: str, max_neighbors: int, capacity: int = 1024):
        self.name = name
        self.K = max_neighbors
        self.buf = np.zeros((capacity, max_neighbors), np.int32)
        self.count = np.zeros(capacity, np.int32)
        self.head = np.zeros(capacity, np.int32)
        self.reads = 0
        self.writes = 0

    @property
    def capacity(self) -> int:
        return self.buf.shape[0]

    def _ensure(self, n: int) -> None:
        cap = self.capacity
        if n <= cap:
            return
        new_cap = max(cap * 2, n)
        self.buf = np.concatenate(
            [self.buf, np.zeros((new_cap - cap, self.K), np.int32)])
        self.count = np.concatenate([self.count, np.zeros(new_cap - cap, np.int32)])
        self.head = np.concatenate([self.head, np.zeros(new_cap - cap, np.int32)])

    def add(self, src_id: int, dst_id: int) -> None:
        self._ensure(src_id + 1)
        self.buf[src_id, self.head[src_id]] = dst_id
        self.head[src_id] = (self.head[src_id] + 1) % self.K
        self.count[src_id] = min(self.count[src_id] + 1, self.K)
        self.writes += 1

    def bulk_load(self, indptr: np.ndarray, indices: np.ndarray) -> None:
        """Vectorized bootstrap from a CSR: keep the last K neighbors/node."""
        n = len(indptr) - 1
        self._ensure(n)
        deg = np.diff(indptr)
        cnt = np.minimum(deg, self.K).astype(np.int64)
        total = int(cnt.sum())
        rows = np.repeat(np.arange(n), cnt)
        offs = np.zeros(n + 1, np.int64)
        np.cumsum(cnt, out=offs[1:])
        pos = np.arange(total) - np.repeat(offs[:-1], cnt)
        src_idx = np.repeat(indptr[1:] - cnt, cnt) + pos
        self.buf[rows, pos] = indices[src_idx]
        self.count[:n] = cnt
        self.head[:n] = cnt % self.K
        self.writes += total

    def counts(self, ids: np.ndarray) -> np.ndarray:
        """Vectorized degree lookup; ids beyond capacity have degree 0."""
        self.reads += len(ids)
        out = np.zeros(len(ids), np.int64)
        ok = ids < self.capacity
        out[ok] = self.count[ids[ok]]
        return out

    def row(self, src_id: int) -> np.ndarray:
        self.reads += 1
        if src_id >= self.capacity:
            return self.buf[:0, 0]
        return self.buf[src_id, :self.count[src_id]]


class NeighborStore:
    """Per-edge-type bounded neighbor rings keyed by (node_type, id).

    One store monitors job neighbors per node type (paper: "multiple feature
    stores that monitor job neighbors per node type").
    """

    def __init__(self, max_neighbors: int = 64):
        self.stores: dict = {}
        self.max_neighbors = max_neighbors

    def _store(self, src_type: str, dst_type: str) -> RingBuffer:
        key = (src_type, dst_type)
        if key not in self.stores:
            self.stores[key] = RingBuffer(f"neigh:{src_type}->{dst_type}",
                                          self.max_neighbors)
        return self.stores[key]

    def add(self, src_type: str, src_id: int, dst_type: str, dst_id: int) -> None:
        self._store(src_type, dst_type).add(src_id, dst_id)

    def bulk_load(self, src_type: str, dst_type: str, indptr, indices) -> None:
        self._store(src_type, dst_type).bulk_load(indptr, indices)

    def _relations(self, node_type: str):
        return [(NODE_TYPE_ID[d], st) for (s, d), st in self.stores.items()
                if s == node_type]

    def neighbors(self, node_type: str, node_id: int):
        """Merged (dst_type_id, dst_id) neighbor list across edge types.

        Entry order — relation insertion order, then ring column order — is
        the contract shared with :meth:`sample_batched`: offset ``j`` into
        this list and offset ``j`` of the batched path address the same
        neighbor, which is what makes the scalar and batched joins
        bit-identical on the same uniform stream.
        """
        out = []
        for tid, st in self._relations(node_type):
            out.extend((tid, int(i)) for i in st.row(node_id))
        return out

    def sample_batched(self, types: np.ndarray, ids: np.ndarray, fanout: int,
                       uniforms: np.ndarray):
        """Vectorized fixed-fanout sampling for a batch of (type, id) nodes.

        types [n] int, ids [n] int, uniforms [n, fanout] in [0, 1) ->
        (dst_ty [n, F] int32, dst_id [n, F] int32, mask [n, F] float32).
        Draw j = floor(u · deg) indexes the merged neighbor list (see
        :meth:`neighbors`) without ever materializing it.
        """
        n = len(ids)
        out_ty = np.zeros((n, fanout), np.int32)
        out_id = np.zeros((n, fanout), np.int32)
        out_mask = np.zeros((n, fanout), np.float32)
        for tid, tname in enumerate(NODE_TYPES):
            rows = np.nonzero(types == tid)[0]
            if rows.size == 0:
                continue
            rels = self._relations(tname)
            if not rels:
                continue
            nid = ids[rows]
            cnts = np.stack([st.counts(nid) for _, st in rels], axis=1)  # [m, R]
            total = cnts.sum(axis=1)
            has = total > 0
            if not has.any():
                continue
            rows, nid, cnts, total = rows[has], nid[has], cnts[has], total[has]
            j = (uniforms[rows] * total[:, None]).astype(np.int64)       # [m, F]
            cum = np.cumsum(cnts, axis=1)
            rel_idx = (j[:, :, None] >= cum[:, None, :]).sum(axis=-1)    # [m, F]
            start = cum - cnts
            slot = j - np.take_along_axis(start, rel_idx, axis=1)        # [m, F]
            for r, (dtid, st) in enumerate(rels):
                rr, ff = np.nonzero(rel_idx == r)
                if rr.size == 0:
                    continue
                out_id[rows[rr], ff] = st.buf[nid[rr], slot[rr, ff]]
                out_ty[rows[rr], ff] = dtid
            out_mask[rows] = 1.0
        return out_ty, out_id, out_mask


class EmbeddingStore(NoSQLStore):
    """Online feature store: (node_type, id) -> (embedding, refresh_time)."""

    def put_embedding(self, node_type: str, node_id: int, emb: np.ndarray,
                      t: float) -> None:
        self.put((node_type, int(node_id)), (emb, t))

    def get_embedding(self, node_type: str, node_id: int):
        return self.get((node_type, int(node_id)))


def bucket_pow2(n: int, minimum: int = 8) -> int:
    """Pad batch sizes to power-of-two buckets (min ``minimum``) so jit
    compiles one executable per bucket and steady-state batches never
    retrace.  Shared by the nearline encoder and the trainer's
    ``embed_nodes``."""
    return max(minimum, 1 << max(n - 1, 1).bit_length())


def _pad_tile(tile: ComputeGraphBatch, to: int) -> ComputeGraphBatch:
    """Zero-pad every array of the tile along the batch axis to ``to`` rows
    (all-masked padding rows encode to garbage that is sliced off)."""
    b = tile.q_feat.shape[0]
    pad = to - b
    if pad <= 0:
        return tile
    return ComputeGraphBatch(*(
        np.concatenate([x, np.zeros((pad,) + x.shape[1:], x.dtype)]) for x in tile))


# -------------------------------------------------------------- inference


@dataclass
class NearlineMetrics:
    events_processed: int = 0
    batches: int = 0
    nodes_refreshed: int = 0
    encoder_seconds: float = 0.0
    join_seconds: float = 0.0
    encoder_traces: int = 0                         # jit retrace count
    staleness: list = field(default_factory=list)   # event.time -> refresh time deltas
    join_reads: int = 0

    def summary(self) -> dict:
        st = np.array(self.staleness) if self.staleness else np.array([0.0])
        return {
            "events": self.events_processed,
            "batches": self.batches,
            "nodes_refreshed": self.nodes_refreshed,
            "encoder_ms_per_batch": 1e3 * self.encoder_seconds / max(self.batches, 1),
            "join_ms_per_batch": 1e3 * self.join_seconds / max(self.batches, 1),
            "encoder_traces": self.encoder_traces,
            "staleness_p50_s": float(np.percentile(st, 50)),
            "staleness_p99_s": float(np.percentile(st, 99)),
            "join_reads": self.join_reads,
        }


class NearlineInference:
    """The nearline pipeline: poll → update stores → sequential join → encode
    → push embeddings (Figure 4)."""

    def __init__(self, cfg: GNNConfig, encoder_params, *, fanouts=None,
                 micro_batch: int = 64, max_neighbors: int = 64, seed: int = 0,
                 join_impl: str = "batched", jit_encoder: bool = True):
        assert join_impl in ("batched", "scalar"), join_impl
        self.cfg = cfg
        self.params = encoder_params
        self.fanouts = fanouts or cfg.fanouts
        self.micro_batch = micro_batch
        self.join_impl = join_impl
        self.jit_encoder = jit_encoder
        self.topic = Topic("job-marketplace-events")
        self.neighbor_store = NeighborStore(max_neighbors)
        self.feature_store = NoSQLStore("node-features")      # input features per node
        self.embedding_store = EmbeddingStore("gnn-embeddings")
        self.metrics = NearlineMetrics()
        self.rng = np.random.default_rng(seed)
        self._encode = self._make_encode()  # shape-bucketed jitted encoder

    # ---- bucketed jitted encoder ----------------------------------------
    def _make_encode(self):
        from repro.core import encoder as enc
        cfg = self.cfg

        def fn(params, tile):
            # trace-time side effect: counts (re)compilations per bucket
            self.metrics.encoder_traces += 1
            return enc.encoder_apply(params, cfg, tile)

        return jax.jit(fn)

    @staticmethod
    def _bucket(n: int) -> int:
        return bucket_pow2(n)

    # ---- store bootstrap (initial graph snapshot load) -------------------
    def bootstrap_from_graph(self, graph) -> None:
        items = []
        for ntype in NODE_TYPES:
            feats = graph.features[ntype]
            tid = NODE_TYPE_ID[ntype]
            items.extend(((tid, i), feats[i]) for i in range(feats.shape[0]))
        self.feature_store.put_many(items)
        for (s, d), csr in graph.adj.items():
            self.neighbor_store.bulk_load(s, d, csr.indptr, csr.indices)

    # ---- event application ----------------------------------------------
    def _apply_event(self, ev: Event):
        touched = []
        p = ev.payload
        if ev.kind == "job_created":
            self.feature_store.put((NODE_TYPE_ID["job"], p["job_id"]), p["features"])
            for attr in ("title", "company", "position", "skill"):
                if attr in p:
                    self.neighbor_store.add("job", p["job_id"], attr, p[attr])
                    self.neighbor_store.add(attr, p[attr], "job", p["job_id"])
            touched.append(("job", p["job_id"], ev.time))
        elif ev.kind == "engagement":                  # member saved/applied/clicked
            self.neighbor_store.add("member", p["member_id"], "job", p["job_id"])
            touched.append(("job", p["job_id"], ev.time))
            touched.append(("member", p["member_id"], ev.time))
        elif ev.kind == "recruiter_interaction":       # recruiter reached out
            self.neighbor_store.add("job", p["job_id"], "member", p["member_id"])
            touched.append(("job", p["job_id"], ev.time))
        elif ev.kind == "member_update":
            self.feature_store.put((NODE_TYPE_ID["member"], p["member_id"]), p["features"])
            touched.append(("member", p["member_id"], ev.time))
        return touched

    # ---- sequential join: node -> neighbors -> neighbor features ---------
    #
    # Both implementations consume the SAME uniform stream in the same order
    # (one rng.random(f1 + f1*f2) slab per query node, row-major) and share
    # the merged-neighbor-list offset contract of NeighborStore.neighbors /
    # sample_batched, so they produce bit-identical tiles from the same seed.
    # ``batched`` is the production path (~6 vectorized gathers + deduped
    # multi_gets per micro-batch); ``scalar`` is the pre-optimization
    # O(B·F1·F2) per-key baseline kept for benchmarking and as a correctness
    # oracle.

    def _fetch_feats(self, tid: int, nid: int) -> np.ndarray:
        f = self.feature_store.get((tid, nid))
        self.metrics.join_reads += 1
        if f is None:
            f = np.zeros(self.cfg.feat_dim, np.float32)
        return f

    def _multi_fetch_feats(self, tids: np.ndarray, nids: np.ndarray) -> np.ndarray:
        """Deduped batched feature lookup: flat (tid, nid) pairs -> [n, d].

        One multi_get over the unique keys per hop instead of one get per
        (node, neighbor, neighbor-of-neighbor) feature; missing keys are
        zero-filled.
        """
        d = self.cfg.feat_dim
        if tids.size == 0:
            return np.zeros((0, d), np.float32)
        packed = tids.astype(np.int64) << 40 | nids.astype(np.int64)
        uniq, inv = np.unique(packed, return_inverse=True)
        keys = [(int(p >> 40), int(p & ((1 << 40) - 1))) for p in uniq]
        vals = self.feature_store.multi_get(keys)
        self.metrics.join_reads += len(keys)
        mat = np.zeros((len(keys), d), np.float32)
        for i, v in enumerate(vals):
            if v is not None:
                mat[i] = v
        return mat[inv]

    def _sequential_join(self, nodes) -> ComputeGraphBatch:
        if self.join_impl == "scalar":
            return self._sequential_join_scalar(nodes)
        return self._sequential_join_batched(nodes)

    def _sequential_join_batched(self, nodes) -> ComputeGraphBatch:
        f1, f2 = self.fanouts
        b = len(nodes)
        d = self.cfg.feat_dim
        q_type = np.array([NODE_TYPE_ID[t] for t, _ in nodes], np.int64)
        q_id = np.array([i for _, i in nodes], np.int64)
        u = self.rng.random((b, f1 + f1 * f2))
        u1, u2 = u[:, :f1], u[:, f1:].reshape(b, f1, f2)

        # hop 0+1: one batched sample over all query nodes
        n1_type, n1_id, n1_mask = self.neighbor_store.sample_batched(
            q_type, q_id, f1, u1)
        q_feat = self._multi_fetch_feats(q_type, q_id)

        m1 = n1_mask.reshape(-1) > 0
        n1_feat = np.zeros((b * f1, d), np.float32)
        n1_feat[m1] = self._multi_fetch_feats(n1_type.reshape(-1)[m1],
                                              n1_id.reshape(-1)[m1])

        # hop 2: batched sample over all valid hop-1 neighbors
        n2_type = np.zeros((b * f1, f2), np.int32)
        n2_id = np.zeros((b * f1, f2), np.int32)
        n2_mask = np.zeros((b * f1, f2), np.float32)
        if m1.any():
            t2, i2, mk2 = self.neighbor_store.sample_batched(
                n1_type.reshape(-1)[m1].astype(np.int64),
                n1_id.reshape(-1)[m1].astype(np.int64),
                f2, u2.reshape(b * f1, f2)[m1])
            n2_type[m1], n2_id[m1], n2_mask[m1] = t2, i2, mk2
        m2 = n2_mask.reshape(-1) > 0
        n2_feat = np.zeros((b * f1 * f2, d), np.float32)
        n2_feat[m2] = self._multi_fetch_feats(n2_type.reshape(-1)[m2],
                                              n2_id.reshape(-1)[m2])

        return ComputeGraphBatch(
            q_feat, q_type.astype(np.int32),
            n1_feat.reshape(b, f1, d), n1_type, n1_mask,
            n2_feat.reshape(b, f1, f2, d), n2_type.reshape(b, f1, f2),
            n2_mask.reshape(b, f1, f2))

    def _sequential_join_scalar(self, nodes) -> ComputeGraphBatch:
        f1, f2 = self.fanouts
        b = len(nodes)
        d = self.cfg.feat_dim
        q_feat = np.zeros((b, d), np.float32)
        q_type = np.zeros(b, np.int32)
        n1_feat = np.zeros((b, f1, d), np.float32)
        n1_type = np.zeros((b, f1), np.int32)
        n1_mask = np.zeros((b, f1), np.float32)
        n2_feat = np.zeros((b, f1, f2, d), np.float32)
        n2_type = np.zeros((b, f1, f2), np.int32)
        n2_mask = np.zeros((b, f1, f2), np.float32)
        for r, (ntype, nid) in enumerate(nodes):
            u = self.rng.random(f1 + f1 * f2)
            u1, u2 = u[:f1], u[f1:].reshape(f1, f2)
            tid = NODE_TYPE_ID[ntype]
            q_type[r] = tid
            q_feat[r] = self._fetch_feats(tid, nid)
            merged = self.neighbor_store.neighbors(ntype, nid)
            for s in range(f1):
                if not merged:
                    break
                t1, i1 = merged[int(u1[s] * len(merged))]
                n1_type[r, s], n1_mask[r, s] = t1, 1.0
                n1_feat[r, s] = self._fetch_feats(t1, i1)
                merged2 = self.neighbor_store.neighbors(NODE_TYPES[t1], i1)
                for v in range(f2):
                    if not merged2:
                        break
                    t2, i2 = merged2[int(u2[s, v] * len(merged2))]
                    n2_type[r, s, v], n2_mask[r, s, v] = t2, 1.0
                    n2_feat[r, s, v] = self._fetch_feats(t2, i2)
        return ComputeGraphBatch(q_feat, q_type, n1_feat, n1_type, n1_mask,
                                 n2_feat, n2_type, n2_mask)

    # ---- the nearline loop ------------------------------------------------
    def process(self, *, upto_time: float | None = None, max_batches: int = 10**9,
                clock: float | None = None) -> int:
        """Drain pending events in micro-batches; returns #events handled.

        ``clock`` is the simulated wall time when processing happens (for
        staleness accounting); defaults to each event's own time + a small
        pipeline delay, modelling the few-seconds nearline lag.
        """
        from repro.core.linksage import _to_jnp  # local import (cycle)
        from repro.core import encoder as enc

        total = 0
        for _ in range(max_batches):
            events = self.topic.poll("nearline", self.micro_batch, upto_time=upto_time)
            if not events:
                break
            touched: dict = {}
            for ev in events:
                for (ntype, nid, t) in self._apply_event(ev):
                    touched[(ntype, nid)] = t   # newest trigger wins
            nodes = list(touched.keys())
            t0 = _time.perf_counter()
            tile = self._sequential_join(nodes)
            self.metrics.join_seconds += _time.perf_counter() - t0
            t0 = _time.perf_counter()
            if self.jit_encoder:
                # pad the tile to its power-of-two bucket: one compiled
                # executable per bucket, reused across batches — steady-state
                # nearline batches never retrace
                tile = _pad_tile(tile, self._bucket(len(nodes)))
                emb = np.asarray(self._encode(self.params, _to_jnp(tile)))
            else:
                tile = _pad_tile(tile, len(nodes) + (-len(nodes)) % 8)
                emb = np.asarray(enc.encoder_apply(self.params, self.cfg,
                                                   _to_jnp(tile)))
            self.metrics.encoder_seconds += _time.perf_counter() - t0
            refresh_time = (clock if clock is not None
                            else max(ev.time for ev in events) + 2.0)
            for r, (ntype, nid) in enumerate(nodes):
                self.embedding_store.put_embedding(ntype, nid, emb[r], refresh_time)
                self.metrics.staleness.append(refresh_time - touched[(ntype, nid)])
            self.metrics.events_processed += len(events)
            self.metrics.batches += 1
            self.metrics.nodes_refreshed += len(nodes)
            total += len(events)
        return total


class OfflineBatchInference:
    """The pre-nearline baseline (§5.2): daily batch job — embeddings refresh
    only at day boundaries, so new jobs wait up to 24 h (Table 10 control)."""

    def __init__(self, nearline: NearlineInference, *, period_s: float = 86_400.0):
        self.inner = nearline
        self.period = period_s
        self.last_run = 0.0

    def maybe_run(self, now: float) -> int:
        ran = 0
        while self.last_run + self.period <= now:
            self.last_run += self.period
            ran += self.inner.process(upto_time=self.last_run, clock=self.last_run)
        return ran
