"""Nearline GNN inference framework (paper §5.2, Figure 4).

Faithfully reproduces the production dataflow without the JVM/Kafka stack:

  Kafka topics            → :class:`Topic` (append log + consumer offsets)
  NoSQL feature stores    → :class:`NoSQLStore` (keyed store with I/O counters)
  neighbor stores/type    → :class:`NeighborStore` (bounded per-node lists)
  sequential join         → :meth:`NearlineInference._sequential_join`
  nearline GNN inference  → batched jitted encoder on the joined tiles
  online feature store    → :class:`EmbeddingStore` (embedding + timestamp)

Triggers (paper): (1) a recruiter creates a job posting; (2) new neighbors
(members who applied/saved/clicked) arrive on an existing job.  Member
embeddings refresh symmetrically on engagement/profile events.

The "stateful job marketplace graph" emerges from the stores: during
inference only neighbors + their input features are needed — not a full
graph engine with temporal processing/sampling (§5.2) — which is exactly
what the sequential join provides.
"""
from __future__ import annotations

import time as _time
from collections import defaultdict
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.linksage import GNNConfig
from repro.core.graph import NODE_TYPE_ID, NODE_TYPES
from repro.core.sampler import ComputeGraphBatch


# --------------------------------------------------------------- messaging


@dataclass
class Event:
    time: float                      # simulated seconds
    kind: str                        # job_created | engagement | recruiter_interaction | member_update
    payload: dict


class Topic:
    """Kafka-topic stand-in: append-only log with per-consumer offsets."""

    def __init__(self, name: str):
        self.name = name
        self.log: list[Event] = []
        self.offsets: dict[str, int] = defaultdict(int)

    def publish(self, event: Event) -> None:
        self.log.append(event)

    def poll(self, consumer: str, max_events: int, *, upto_time: float | None = None):
        start = self.offsets[consumer]
        out = []
        for ev in self.log[start:start + max_events]:
            if upto_time is not None and ev.time > upto_time:
                break
            out.append(ev)
        self.offsets[consumer] += len(out)
        return out

    def lag(self, consumer: str) -> int:
        return len(self.log) - self.offsets[consumer]


# ------------------------------------------------------------------ stores


class NoSQLStore:
    """In-memory NoSQL store with read/write accounting (I/O bottleneck
    analysis, §5.2 challenge (c))."""

    def __init__(self, name: str):
        self.name = name
        self._d: dict = {}
        self.reads = 0
        self.writes = 0

    def put(self, key, value) -> None:
        self._d[key] = value
        self.writes += 1

    def get(self, key, default=None):
        self.reads += 1
        return self._d.get(key, default)

    def multi_get(self, keys):
        self.reads += len(keys)
        return [self._d.get(k) for k in keys]

    def __contains__(self, key):
        return key in self._d

    def __len__(self):
        return len(self._d)


class NeighborStore:
    """Per-edge-type bounded neighbor lists keyed by (node_type, id).

    One store monitors job neighbors per node type (paper: "multiple feature
    stores that monitor job neighbors per node type").
    """

    def __init__(self, max_neighbors: int = 64):
        self.stores: dict = {}
        self.max_neighbors = max_neighbors

    def _store(self, src_type: str, dst_type: str) -> NoSQLStore:
        key = (src_type, dst_type)
        if key not in self.stores:
            self.stores[key] = NoSQLStore(f"neigh:{src_type}->{dst_type}")
        return self.stores[key]

    def add(self, src_type: str, src_id: int, dst_type: str, dst_id: int) -> None:
        st = self._store(src_type, dst_type)
        cur = st.get(src_id) or []
        cur = (cur + [dst_id])[-self.max_neighbors:]
        st.put(src_id, cur)

    def neighbors(self, node_type: str, node_id: int):
        """Merged (dst_type_id, dst_id) neighbor list across edge types."""
        out = []
        for (s, d), st in self.stores.items():
            if s != node_type:
                continue
            ids = st.get(node_id)
            if ids:
                tid = NODE_TYPE_ID[d]
                out.extend((tid, i) for i in ids)
        return out


class EmbeddingStore(NoSQLStore):
    """Online feature store: (node_type, id) -> (embedding, refresh_time)."""

    def put_embedding(self, node_type: str, node_id: int, emb: np.ndarray,
                      t: float) -> None:
        self.put((node_type, int(node_id)), (emb, t))

    def get_embedding(self, node_type: str, node_id: int):
        return self.get((node_type, int(node_id)))


# -------------------------------------------------------------- inference


@dataclass
class NearlineMetrics:
    events_processed: int = 0
    batches: int = 0
    nodes_refreshed: int = 0
    encoder_seconds: float = 0.0
    staleness: list = field(default_factory=list)   # event.time -> refresh time deltas
    join_reads: int = 0

    def summary(self) -> dict:
        st = np.array(self.staleness) if self.staleness else np.array([0.0])
        return {
            "events": self.events_processed,
            "batches": self.batches,
            "nodes_refreshed": self.nodes_refreshed,
            "encoder_ms_per_batch": 1e3 * self.encoder_seconds / max(self.batches, 1),
            "staleness_p50_s": float(np.percentile(st, 50)),
            "staleness_p99_s": float(np.percentile(st, 99)),
            "join_reads": self.join_reads,
        }


class NearlineInference:
    """The nearline pipeline: poll → update stores → sequential join → encode
    → push embeddings (Figure 4)."""

    def __init__(self, cfg: GNNConfig, encoder_params, *, fanouts=None,
                 micro_batch: int = 64, max_neighbors: int = 64, seed: int = 0):
        self.cfg = cfg
        self.params = encoder_params
        self.fanouts = fanouts or cfg.fanouts
        self.micro_batch = micro_batch
        self.topic = Topic("job-marketplace-events")
        self.neighbor_store = NeighborStore(max_neighbors)
        self.feature_store = NoSQLStore("node-features")      # input features per node
        self.embedding_store = EmbeddingStore("gnn-embeddings")
        self.metrics = NearlineMetrics()
        self.rng = np.random.default_rng(seed)
        self._encode = None  # jitted lazily (needs tile shapes)

    # ---- store bootstrap (initial graph snapshot load) -------------------
    def bootstrap_from_graph(self, graph) -> None:
        for ntype in NODE_TYPES:
            feats = graph.features[ntype]
            for i in range(feats.shape[0]):
                self.feature_store.put((NODE_TYPE_ID[ntype], i), feats[i])
        for (s, d), csr in graph.adj.items():
            for src in range(len(csr.indptr) - 1):
                for dst in csr.neighbors(src):
                    self.neighbor_store.add(s, src, d, int(dst))

    # ---- event application ----------------------------------------------
    def _apply_event(self, ev: Event):
        touched = []
        p = ev.payload
        if ev.kind == "job_created":
            self.feature_store.put((NODE_TYPE_ID["job"], p["job_id"]), p["features"])
            for attr in ("title", "company", "position", "skill"):
                if attr in p:
                    self.neighbor_store.add("job", p["job_id"], attr, p[attr])
                    self.neighbor_store.add(attr, p[attr], "job", p["job_id"])
            touched.append(("job", p["job_id"], ev.time))
        elif ev.kind == "engagement":                  # member saved/applied/clicked
            self.neighbor_store.add("member", p["member_id"], "job", p["job_id"])
            touched.append(("job", p["job_id"], ev.time))
            touched.append(("member", p["member_id"], ev.time))
        elif ev.kind == "recruiter_interaction":       # recruiter reached out
            self.neighbor_store.add("job", p["job_id"], "member", p["member_id"])
            touched.append(("job", p["job_id"], ev.time))
        elif ev.kind == "member_update":
            self.feature_store.put((NODE_TYPE_ID["member"], p["member_id"]), p["features"])
            touched.append(("member", p["member_id"], ev.time))
        return touched

    # ---- sequential join: node -> neighbors -> neighbor features ---------
    def _fetch_feats(self, tid: int, nid: int) -> np.ndarray:
        f = self.feature_store.get((tid, nid))
        self.metrics.join_reads += 1
        if f is None:
            f = np.zeros(self.cfg.feat_dim, np.float32)
        return f

    def _sample_neighbors(self, tid: int, nid: int, fanout: int):
        merged = self.neighbor_store.neighbors(NODE_TYPES[tid], nid)
        ty = np.zeros(fanout, np.int32)
        ids = np.zeros(fanout, np.int32)
        mask = np.zeros(fanout, np.float32)
        if merged:
            picks = self.rng.integers(0, len(merged), fanout)
            for slot, pk in enumerate(picks):
                t, i = merged[pk]
                ty[slot], ids[slot], mask[slot] = t, i, 1.0
        return ty, ids, mask

    def _sequential_join(self, nodes) -> ComputeGraphBatch:
        f1, f2 = self.fanouts
        b = len(nodes)
        d = self.cfg.feat_dim
        q_feat = np.zeros((b, d), np.float32)
        q_type = np.zeros(b, np.int32)
        n1_feat = np.zeros((b, f1, d), np.float32)
        n1_type = np.zeros((b, f1), np.int32)
        n1_mask = np.zeros((b, f1), np.float32)
        n2_feat = np.zeros((b, f1, f2, d), np.float32)
        n2_type = np.zeros((b, f1, f2), np.int32)
        n2_mask = np.zeros((b, f1, f2), np.float32)
        for r, (ntype, nid) in enumerate(nodes):
            tid = NODE_TYPE_ID[ntype]
            q_type[r] = tid
            q_feat[r] = self._fetch_feats(tid, nid)
            ty, ids, m = self._sample_neighbors(tid, nid, f1)
            n1_type[r], n1_mask[r] = ty, m
            for s in range(f1):
                if m[s] == 0:
                    continue
                n1_feat[r, s] = self._fetch_feats(ty[s], ids[s])
                ty2, ids2, m2 = self._sample_neighbors(ty[s], ids[s], f2)
                n2_type[r, s], n2_mask[r, s] = ty2, m2
                for u in range(f2):
                    if m2[u]:
                        n2_feat[r, s, u] = self._fetch_feats(ty2[u], ids2[u])
        return ComputeGraphBatch(q_feat, q_type, n1_feat, n1_type, n1_mask,
                                 n2_feat, n2_type, n2_mask)

    # ---- the nearline loop ------------------------------------------------
    def process(self, *, upto_time: float | None = None, max_batches: int = 10**9,
                clock: float | None = None) -> int:
        """Drain pending events in micro-batches; returns #events handled.

        ``clock`` is the simulated wall time when processing happens (for
        staleness accounting); defaults to each event's own time + a small
        pipeline delay, modelling the few-seconds nearline lag.
        """
        from repro.core.linksage import _to_jnp  # local import (cycle)
        from repro.core import encoder as enc

        total = 0
        for _ in range(max_batches):
            events = self.topic.poll("nearline", self.micro_batch, upto_time=upto_time)
            if not events:
                break
            touched: dict = {}
            for ev in events:
                for (ntype, nid, t) in self._apply_event(ev):
                    touched[(ntype, nid)] = t   # newest trigger wins
            nodes = list(touched.keys())
            pad = (-len(nodes)) % 8 if len(nodes) % 8 else 0
            tile = self._sequential_join(nodes + nodes[:1] * pad)
            t0 = _time.perf_counter()
            emb = np.asarray(enc.encoder_apply(self.params, self.cfg, _to_jnp(tile)))
            self.metrics.encoder_seconds += _time.perf_counter() - t0
            refresh_time = (clock if clock is not None
                            else max(ev.time for ev in events) + 2.0)
            for r, (ntype, nid) in enumerate(nodes):
                self.embedding_store.put_embedding(ntype, nid, emb[r], refresh_time)
                self.metrics.staleness.append(refresh_time - touched[(ntype, nid)])
            self.metrics.events_processed += len(events)
            self.metrics.batches += 1
            self.metrics.nodes_refreshed += len(nodes)
            total += len(events)
        return total


class OfflineBatchInference:
    """The pre-nearline baseline (§5.2): daily batch job — embeddings refresh
    only at day boundaries, so new jobs wait up to 24 h (Table 10 control)."""

    def __init__(self, nearline: NearlineInference, *, period_s: float = 86_400.0):
        self.inner = nearline
        self.period = period_s
        self.last_run = 0.0

    def maybe_run(self, now: float) -> int:
        ran = 0
        while self.last_run + self.period <= now:
            self.last_run += self.period
            ran += self.inner.process(upto_time=self.last_run, clock=self.last_run)
        return ran
