"""Nearline GNN inference framework (paper §5.2, Figure 4).

Faithfully reproduces the production dataflow without the JVM/Kafka stack:

  Kafka topics            → :class:`Topic` (append log + consumer offsets)
  NoSQL feature stores    → :class:`repro.core.stores.NoSQLStore`
  neighbor stores/type    → :class:`repro.core.stores.NeighborStore` rings
  graph substrate         → :class:`repro.core.engine.StreamingEngine`
                            (the evolving backend of the shared GraphEngine)
  sequential join         → the shared K-hop :class:`TileBuilder` — the SAME
                            builder the trainer samples through (DESIGN.md §8)
  nearline GNN inference  → shape-bucketed jitted encoder on the joined tiles
  online feature store    → :class:`EmbeddingStore` (embedding + timestamp)

Triggers (paper): (1) a recruiter creates a job posting; (2) new neighbors
(members who applied/saved/clicked) arrive on an existing job.  Member
embeddings refresh symmetrically on engagement/profile events.

The "stateful job marketplace graph" IS the StreamingEngine: bounded
neighbor rings + feature store, bootstrapped from a snapshot and advanced by
live events.  Because the trainer can consume the same engine, training and
serving share one graph semantics — the paper's near-realtime inductive
story.  The per-key scalar join survives only as a benchmark baseline (and
as the pre-refactor bit-exactness oracle).
"""
from __future__ import annotations

import time as _time
from collections import defaultdict
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.configs.linksage import GNNConfig
from repro.core.engine import (ComputeGraphBatch, StreamingEngine, TileBuilder,
                               bucket_pow2, hop_widths, pad_tile, slab_width)
from repro.core.graph import NODE_TYPE_ID, NODE_TYPES
from repro.core.stores import (EmbeddingStore, NeighborStore,  # noqa: F401
                               NoSQLStore, RingBuffer)


# --------------------------------------------------------------- messaging


@dataclass
class Event:
    time: float                      # simulated seconds
    kind: str                        # job_created | engagement | recruiter_interaction | member_update
    payload: dict


class Topic:
    """Kafka-topic stand-in: append-only log with per-consumer offsets."""

    def __init__(self, name: str):
        self.name = name
        self.log: list[Event] = []
        self.offsets: dict[str, int] = defaultdict(int)

    def publish(self, event: Event) -> None:
        self.log.append(event)

    def poll(self, consumer: str, max_events: int, *, upto_time: float | None = None):
        start = self.offsets[consumer]
        out = []
        for ev in self.log[start:start + max_events]:
            if upto_time is not None and ev.time > upto_time:
                break
            out.append(ev)
        self.offsets[consumer] += len(out)
        return out

    def lag(self, consumer: str) -> int:
        return len(self.log) - self.offsets[consumer]


# -------------------------------------------------------------- inference


@dataclass
class NearlineMetrics:
    events_processed: int = 0
    batches: int = 0
    nodes_refreshed: int = 0
    encoder_seconds: float = 0.0
    join_seconds: float = 0.0
    encoder_traces: int = 0                         # jit retrace count
    staleness: list = field(default_factory=list)   # event.time -> refresh time deltas
    join_reads: int = 0

    def summary(self) -> dict:
        st = np.array(self.staleness) if self.staleness else np.array([0.0])
        return {
            "events": self.events_processed,
            "batches": self.batches,
            "nodes_refreshed": self.nodes_refreshed,
            "encoder_ms_per_batch": 1e3 * self.encoder_seconds / max(self.batches, 1),
            "join_ms_per_batch": 1e3 * self.join_seconds / max(self.batches, 1),
            "encoder_traces": self.encoder_traces,
            "staleness_p50_s": float(np.percentile(st, 50)),
            "staleness_p99_s": float(np.percentile(st, 99)),
            "join_reads": self.join_reads,
        }


class NearlineInference:
    """The nearline pipeline: poll → update the streaming engine → shared
    K-hop tile build → encode → push embeddings (Figure 4)."""

    def __init__(self, cfg: GNNConfig, encoder_params, *, fanouts=None,
                 micro_batch: int = 64, max_neighbors: int = 64, seed: int = 0,
                 join_impl: str = "batched", jit_encoder: bool = True,
                 strategy: str = "uniform"):
        assert join_impl in ("batched", "scalar"), join_impl
        # the scalar arm is the uniform-sampling oracle; it has no weighted walk
        assert join_impl == "batched" or strategy == "uniform", (join_impl, strategy)
        self.cfg = cfg
        self.params = encoder_params
        self.fanouts = tuple(fanouts or cfg.fanouts)
        self.micro_batch = micro_batch
        self.join_impl = join_impl
        self.jit_encoder = jit_encoder
        self.topic = Topic("job-marketplace-events")
        self.engine = StreamingEngine(cfg.feat_dim, max_neighbors=max_neighbors,
                                      strategy=strategy)
        self.builder = TileBuilder(self.engine, self.fanouts)
        self.embedding_store = EmbeddingStore("gnn-embeddings")
        self.metrics = NearlineMetrics()
        self.rng = np.random.default_rng(seed)
        self._encode = self._make_encode()  # shape-bucketed jitted encoder

    # engine-store views (the stores belong to the StreamingEngine now)
    @property
    def neighbor_store(self) -> NeighborStore:
        return self.engine.neighbor_store

    @property
    def feature_store(self) -> NoSQLStore:
        return self.engine.feature_store

    # ---- bucketed jitted encoder ----------------------------------------
    def _make_encode(self):
        from repro.core import encoder as enc
        cfg = self.cfg

        def fn(params, tile):
            # trace-time side effect: counts (re)compilations per bucket
            self.metrics.encoder_traces += 1
            return enc.encoder_apply(params, cfg, tile)

        return jax.jit(fn)

    @staticmethod
    def _bucket(n: int) -> int:
        return bucket_pow2(n)

    # ---- store bootstrap (initial graph snapshot load) -------------------
    def bootstrap_from_graph(self, graph) -> None:
        self.engine.bootstrap_from_graph(graph)

    # ---- event application ----------------------------------------------
    def _apply_event(self, ev: Event):
        touched = []
        p = ev.payload
        if ev.kind == "job_created":
            self.engine.put_feature(NODE_TYPE_ID["job"], p["job_id"], p["features"])
            for attr in ("title", "company", "position", "skill"):
                if attr in p:
                    self.engine.add_edge("job", p["job_id"], attr, p[attr])
                    self.engine.add_edge(attr, p[attr], "job", p["job_id"])
            touched.append(("job", p["job_id"], ev.time))
        elif ev.kind == "engagement":                  # member saved/applied/clicked
            self.engine.add_edge("member", p["member_id"], "job", p["job_id"])
            touched.append(("job", p["job_id"], ev.time))
            touched.append(("member", p["member_id"], ev.time))
        elif ev.kind == "recruiter_interaction":       # recruiter reached out
            self.engine.add_edge("job", p["job_id"], "member", p["member_id"])
            touched.append(("job", p["job_id"], ev.time))
        elif ev.kind == "member_update":
            self.engine.put_feature(NODE_TYPE_ID["member"], p["member_id"],
                                    p["features"])
            touched.append(("member", p["member_id"], ev.time))
        return touched

    # ---- sequential join: node -> neighbors -> neighbor features ---------
    #
    # The production path is the shared TileBuilder over the StreamingEngine
    # (~one vectorized sample + one deduped multi_get per hop).  The scalar
    # per-key baseline consumes the SAME uniform stream in the same order
    # (one rng.random(slab_width) slab per query node, row-major over hops)
    # and shares the merged-neighbor-list offset contract, so it produces
    # bit-identical tiles from the same seed — the pre-optimization
    # O(B·F1···FK) oracle kept for benchmarking.

    def _sequential_join(self, nodes) -> ComputeGraphBatch:
        reads0 = self.engine.join_reads
        if self.join_impl == "scalar":
            tile = self._sequential_join_scalar(nodes)
        else:
            q_type = np.array([NODE_TYPE_ID[t] for t, _ in nodes], np.int64)
            q_id = np.array([i for _, i in nodes], np.int64)
            tile = self.builder.build(q_type, q_id, rng=self.rng)
        self.metrics.join_reads += self.engine.join_reads - reads0
        return tile

    def _sequential_join_scalar(self, nodes) -> ComputeGraphBatch:
        fan = self.fanouts
        b = len(nodes)
        d = self.cfg.feat_dim
        widths = hop_widths(fan)
        feats = [np.zeros((b, d), np.float32)]
        typs = [np.zeros(b, np.int32)]
        masks = []
        for k, f in enumerate(fan):
            shape = (b,) + fan[:k + 1]
            feats.append(np.zeros(shape + (d,), np.float32))
            typs.append(np.zeros(shape, np.int32))
            masks.append(np.zeros(shape, np.float32))
        for r, (ntype, nid) in enumerate(nodes):
            u = self.rng.random(slab_width(fan))
            tid = NODE_TYPE_ID[ntype]
            typs[0][r] = tid
            feats[0][r] = self.engine.get_feature(tid, nid)
            frontier = [(tid, int(nid), True)]
            off = 0
            for k, f in enumerate(fan):
                uk = u[off:off + widths[k]].reshape(-1, f)
                off += widths[k]
                fe = feats[k + 1][r].reshape(-1, d)
                ty = typs[k + 1][r].reshape(-1)
                mk = masks[k][r].reshape(-1)
                nxt = []
                for s, (pt, pi, pvalid) in enumerate(frontier):
                    merged = self.engine.neighbors(pt, pi) if pvalid else []
                    for v in range(f):
                        if not merged:
                            nxt.append((0, 0, False))
                            continue
                        t2, i2 = merged[int(uk[s, v] * len(merged))]
                        ty[s * f + v], mk[s * f + v] = t2, 1.0
                        fe[s * f + v] = self.engine.get_feature(t2, i2)
                        nxt.append((t2, i2, True))
                frontier = nxt
        return ComputeGraphBatch(tuple(feats), tuple(typs), tuple(masks))

    # ---- the nearline loop ------------------------------------------------
    def process(self, *, upto_time: float | None = None, max_batches: int = 10**9,
                clock: float | None = None) -> int:
        """Drain pending events in micro-batches; returns #events handled.

        ``clock`` is the simulated wall time when processing happens (for
        staleness accounting); defaults to each event's own time + a small
        pipeline delay, modelling the few-seconds nearline lag.
        """
        from repro.core.linksage import _to_jnp  # local import (cycle)
        from repro.core import encoder as enc

        total = 0
        for _ in range(max_batches):
            events = self.topic.poll("nearline", self.micro_batch, upto_time=upto_time)
            if not events:
                break
            touched: dict = {}
            for ev in events:
                for (ntype, nid, t) in self._apply_event(ev):
                    touched[(ntype, nid)] = t   # newest trigger wins
            nodes = list(touched.keys())
            t0 = _time.perf_counter()
            tile = self._sequential_join(nodes)
            self.metrics.join_seconds += _time.perf_counter() - t0
            t0 = _time.perf_counter()
            if self.jit_encoder:
                # pad the tile to its power-of-two bucket: one compiled
                # executable per bucket, reused across batches — steady-state
                # nearline batches never retrace
                tile = pad_tile(tile, self._bucket(len(nodes)))
                emb = np.asarray(self._encode(self.params, _to_jnp(tile)))
            else:
                tile = pad_tile(tile, len(nodes) + (-len(nodes)) % 8)
                emb = np.asarray(enc.encoder_apply(self.params, self.cfg,
                                                   _to_jnp(tile)))
            self.metrics.encoder_seconds += _time.perf_counter() - t0
            refresh_time = (clock if clock is not None
                            else max(ev.time for ev in events) + 2.0)
            for r, (ntype, nid) in enumerate(nodes):
                self.embedding_store.put_embedding(ntype, nid, emb[r], refresh_time)
                self.metrics.staleness.append(refresh_time - touched[(ntype, nid)])
            self.metrics.events_processed += len(events)
            self.metrics.batches += 1
            self.metrics.nodes_refreshed += len(nodes)
            total += len(events)
        return total


class OfflineBatchInference:
    """The pre-nearline baseline (§5.2): daily batch job — embeddings refresh
    only at day boundaries, so new jobs wait up to 24 h (Table 10 control)."""

    def __init__(self, nearline: NearlineInference, *, period_s: float = 86_400.0):
        self.inner = nearline
        self.period = period_s
        self.last_run = 0.0

    def maybe_run(self, now: float) -> int:
        ran = 0
        while self.last_run + self.period <= now:
            self.last_run += self.period
            ran += self.inner.process(upto_time=self.last_run, clock=self.last_run)
        return ran
