"""Versioned embedding lifecycle: the serving loop's missing middle
(paper §5–§7, DESIGN.md §9).

The paper's system claim is a *decoupled* pipeline: the GNN encoder computes
member/job embeddings near-realtime, and downstream DNN rankers on four
product surfaces consume them as frozen features.  Everything between the
encoder and the rankers — versioning, staleness, and fan-out — lives here:

  EmbeddingRecord    — (embedding, computed-at time, version)
  EmbeddingStore     — the online feature store: live table + frozen
                       published version tables (leakage-safe reads)
  StalenessPolicy    — what gets recomputed when: dirty-closure radius,
                       age-out threshold, per-type priority
  RecomputeQueue     — batched priority queue of dirty nodes
  EmbeddingLifecycle — dirty-set tracking keyed by graph events + the two
                       recompute paths: incremental ``drain`` (nearline)
                       and full-sweep ``publish_version`` (offline batch)

Determinism contract: every recompute of node (type, id) consumes the SAME
per-node uniform slab ``default_rng((seed, UNIFORM_SALT, tid, nid))`` — a
pure function of the node, not of processing order or batch grouping.  The
encoder is row-wise (bucket padding never leaks across rows), so an
embedding's bits depend only on (params, node, graph state).  Hence the
parity contract: with ``closure_radius=None`` (the full K-hop dependency
radius) an incremental drain over an event stream converges to a table
bit-identical to one full sweep at the final graph state — asserted by
tests/test_embeddings.py and the transfer_bench parity row.  (Dirty
closure walks the reverse-edge index, so it is exact in the append-only
regime; a ring eviction mutates the evicting node's own ring, which the
closure also covers.)
"""
from __future__ import annotations

import heapq
import time as _time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import NamedTuple

import jax
import numpy as np

from repro.configs.linksage import GNNConfig
from repro.core.engine import TileBuilder, bucket_pow2, pad_tile
from repro.core.graph import NODE_TYPE_ID, NODE_TYPES
from repro.core.stores import NoSQLStore
from repro.obs.trace import span as _obs_span

# domain separator for the per-node recompute uniform streams (disjoint from
# the trainer's (seed, step) and embed_nodes' (seed, 1<<24, chunk) streams)
UNIFORM_SALT = 0x5EED


def node_uniform_slab(seed: int, node_type: str, node_id: int,
                      width: int) -> np.ndarray:
    """THE per-node uniform stream: every recompute of (type, id) — scalar
    or batched, drain or sweep — consumes this same slab, making sampled
    neighborhoods a pure function of (seed, node, graph state)."""
    return np.random.default_rng(
        (seed, UNIFORM_SALT, NODE_TYPE_ID[node_type],
         int(node_id))).random(width)


class EmbeddingRecord(NamedTuple):
    emb: np.ndarray
    time: float                   # computed-at (simulated wall clock)
    version: int                  # version the record was computed toward


class EmbeddingStore(NoSQLStore):
    """Versioned online feature store: (node_type, id) -> EmbeddingRecord.

    The *live* table is what nearline writes into; ``publish()`` freezes it
    as an immutable numbered version table.  Downstream consumers read via
    ``gather(..., version=v)`` which only accepts published versions — a
    ranker whose label window must postdate its features cannot accidentally
    train on still-mutating embeddings (§5.1 leakage safety).
    """

    def __init__(self, name: str):
        super().__init__(name)
        self.version = 0                       # last published version
        self._tables: dict[int, dict] = {}     # version -> frozen live table
        # version -> publish clock (freshness monitors read version lag;
        # None when the caller published without a clock)
        self.published_at: dict[int, float | None] = {}
        self._caches: list = []                # attached SlabCaches (§11)
        # derived read replicas of published tables (DESIGN.md §14):
        # (version, node_type, scheme) -> QuantizedTable, and
        # (version, node_type) -> (ids, dense matrix).  Pure functions of
        # the frozen fp32 table — memoized, NOT snapshotted (a restore
        # re-derives bit-identically, like the lifecycle's uniform memo).
        self._derived: dict = {}
        # (node_type, scheme) pairs to quantize EAGERLY at publish() — the
        # paper's pipeline derives the serving replica as part of the
        # publish step, not lazily on first query
        self.quantize_on_publish: tuple = ()

    def attach_cache(self, cache) -> None:
        """Register a memory-hierarchy SlabCache whose counters this store's
        ``summary()`` should surface (the ops view: one store, its caches)."""
        self._caches.append(cache)

    # ---- writes ---------------------------------------------------------
    def put_embedding(self, node_type: str, node_id: int, emb: np.ndarray,
                      t: float, version: int | None = None) -> None:
        v = self.version + 1 if version is None else int(version)
        self.put((node_type, int(node_id)), EmbeddingRecord(emb, float(t), v))

    def publish(self, *, clock: float | None = None) -> int:
        """Freeze the live table as the next version; returns it.  Any
        (node_type, scheme) pairs in ``quantize_on_publish`` get their int8
        replica derived here, as part of the publish step.  ``clock`` stamps
        ``published_at`` for the §15 version-lag freshness monitor."""
        with _obs_span("store.publish") as sp:
            self.version += 1
            self._tables[self.version] = dict(self._d)  # records are immutable
            self.published_at[self.version] = (
                float(clock) if clock is not None else None)
            for ntype, scheme in self.quantize_on_publish:
                self.quantized_table(ntype, version=self.version,
                                     scheme=scheme)
            sp.set("version", self.version)
            sp.set("records", len(self._d))
        return self.version

    # ---- reads ----------------------------------------------------------
    def get_embedding(self, node_type: str, node_id: int):
        """Legacy (emb, time) view of the live record, or None."""
        rec = self.get((node_type, int(node_id)))
        return None if rec is None else (rec.emb, rec.time)

    def record(self, node_type: str, node_id: int) -> EmbeddingRecord | None:
        return self.get((node_type, int(node_id)))

    def published_versions(self) -> list[int]:
        return sorted(self._tables)

    def table(self, version: int) -> dict:
        if version not in self._tables:
            raise KeyError(f"version {version} not published "
                           f"(have {self.published_versions()})")
        return self._tables[version]

    def gather(self, node_type: str, ids, *, version: int) -> np.ndarray:
        """[len(ids), d] embedding matrix read out of a *published* version.

        Missing nodes are a hard error: a node absent from version ``v``
        did not exist when ``v`` was computed, so silently zero-filling it
        would leak post-window information into the consumer's features.
        """
        tab = self.table(version)
        rows = []
        for i in ids:
            rec = tab.get((node_type, int(i)))
            if rec is None:
                raise KeyError(f"({node_type}, {int(i)}) missing from "
                               f"version {version}")
            rows.append(rec.emb)
        self.reads += len(rows)
        return np.stack(rows).astype(np.float32)

    def live_embeddings(self) -> dict:
        """{key: emb} snapshot of the live table (parity comparisons)."""
        return {k: rec.emb for k, rec in self._d.items()}

    # ---- derived read replicas (DESIGN.md §14) ---------------------------
    def dense_table(self, node_type: str, *, version: int):
        """One published version's ``node_type`` rows as (ids [N] i64
        ascending, matrix [N, d] f32), both frozen.  Ascending-id order is
        the retrieval tier's canonical row order: a corpus-row tie-break
        is an id tie-break.  Memoized per (version, node_type) — the
        version table is immutable, so the replica is too."""
        key = (int(version), node_type)
        hit = self._derived.get(key)
        if hit is not None:
            return hit
        tab = self.table(version)
        ids = np.array(sorted(i for t, i in tab if t == node_type), np.int64)
        mat = (np.stack([tab[(node_type, int(i))].emb for i in ids])
               .astype(np.float32) if len(ids)
               else np.zeros((0, 0), np.float32))
        self.reads += len(ids)
        ids.setflags(write=False)
        mat.setflags(write=False)
        self._derived[key] = (ids, mat)
        return ids, mat

    def quantized_table(self, node_type: str, *, version: int,
                        scheme: str = "per_row"):
        """The version-pinning contract extended to quantized replicas: an
        immutable int8 ``QuantizedTable`` derived ONCE per (version,
        node_type, scheme) from the frozen fp32 table.  Deterministic —
        re-deriving after snapshot/restore yields the same bits, so the
        memo is rebuilt lazily rather than checkpointed.  Returns
        (ids [N] i64, QuantizedTable)."""
        from repro.core.retrieval import quantize_int8
        key = (int(version), node_type, scheme)
        hit = self._derived.get(key)
        if hit is not None:
            return hit
        ids, mat = self.dense_table(node_type, version=version)
        qt = quantize_int8(mat, scheme) if mat.size else None
        self._derived[key] = (ids, qt)
        return ids, qt

    def retrieval_index(self, node_type: str, *, version: int,
                        scheme: str | None = "per_row",
                        num_lists: int | None = 0, seed: int = 0):
        """Build the full retrieval tier (fp32 oracle + int8 replica + IVF
        lists) over one published version's ``node_type`` table — the
        offline-batch step that turns a publish into a servable ANN corpus.
        Memoized per (version, node_type, scheme, num_lists, seed)."""
        from repro.core.retrieval import RetrievalIndex
        key = (int(version), node_type, scheme, num_lists, seed, "ivf")
        hit = self._derived.get(key)
        if hit is not None:
            return hit
        ids, mat = self.dense_table(node_type, version=version)
        idx = RetrievalIndex.build(mat, ids=ids, scheme=scheme,
                                   num_lists=num_lists, seed=seed,
                                   version=int(version))
        self._derived[key] = idx
        return idx

    # ---- checkpoint (DESIGN.md §12) -------------------------------------
    def snapshot(self) -> dict:
        """Live records + every published version table + the version
        counter (records are immutable, so dict copies suffice)."""
        state = super().snapshot()
        state["version"] = self.version
        state["tables"] = {v: dict(tab) for v, tab in self._tables.items()}
        state["published_at"] = dict(self.published_at)
        return state

    def restore(self, state: dict) -> None:
        super().restore(state)
        self.version = int(state["version"])
        self._tables = {int(v): dict(tab) for v, tab in state["tables"].items()}
        self.published_at = {int(v): t for v, t
                             in state.get("published_at", {}).items()}
        # derived replicas are pure functions of the frozen tables: drop the
        # memo and let them re-derive (bit-identically) on demand
        self._derived = {}

    def summary(self) -> dict:
        """Store-side counters (the online-feature-store view of the same
        accounting the lifecycle's ``LifecycleMetrics.summary`` reports)."""
        out = {
            "live_records": len(self),
            "published_versions": len(self._tables),
            "latest_version": self.version,
            "reads": self.reads,
            "writes": self.writes,
        }
        for c in self._caches:
            out[c.name] = c.summary()
        return out


def tables_bitwise_equal(a: dict, b: dict) -> bool:
    """Same key set and bit-identical embeddings (EmbeddingRecord values or
    raw arrays on either side) — the parity-contract comparator."""
    if a.keys() != b.keys():
        return False
    unwrap = lambda v: v.emb if isinstance(v, EmbeddingRecord) else v
    return all(np.array_equal(unwrap(a[k]), unwrap(b[k])) for k in a)


# ---------------------------------------------------------------- staleness


@dataclass(frozen=True)
class StalenessPolicy:
    """What gets recomputed when.

    ``closure_radius`` — how far an event's dirtiness propagates along
      *reverse* edges: 0 marks only the touched endpoints (the cheap
      eventually-consistent nearline default); ``None`` resolves to the
      tile dependency radius ``len(fanouts)``, i.e. every node whose K-hop
      tile could have changed — the regime where incremental drain is
      bit-equivalent to a full sweep (the parity contract).
    ``max_staleness_s`` — age-out refresh: ``drain(clock=...)`` re-enqueues
      any registered node whose record is older than this even without a
      graph event (bounds embedding age between publishes).
    ``type_order`` — priority tie-break within one trigger time: earlier
      types refresh first (fresh jobs are the product-critical case, §5.2).
    """
    closure_radius: int | None = 0
    max_staleness_s: float = float("inf")
    type_order: tuple = ("job", "member", "skill", "title", "company",
                         "position")

    def radius(self, num_hops: int) -> int:
        return num_hops if self.closure_radius is None else self.closure_radius

    def priority(self, node_type: str, trigger_time: float) -> tuple:
        rank = (self.type_order.index(node_type)
                if node_type in self.type_order else len(self.type_order))
        return (trigger_time, rank)


class RecomputeQueue:
    """Batched priority queue of dirty nodes.

    Min-heap on the policy priority with lazy-deletion dedup: the ``_trigger``
    /``_prio`` maps are authoritative (earliest trigger / best priority win);
    a heap entry is live only while its priority matches the key's current
    best, so entries left behind by a pop cannot resurface a re-pushed key
    ahead of genuinely older dirt.
    """

    def __init__(self):
        self._heap: list = []
        self._trigger: dict = {}
        self._prio: dict = {}
        self._seq = 0

    def push(self, key, priority: tuple, trigger_time: float) -> None:
        if key in self._trigger:
            self._trigger[key] = min(self._trigger[key], trigger_time)
            self._prio[key] = min(self._prio[key], priority)
        else:
            self._trigger[key] = trigger_time
            self._prio[key] = priority
        heapq.heappush(self._heap, (priority, self._seq, key))
        self._seq += 1

    def pop_batch(self, n: int) -> list:
        """Up to ``n`` distinct (key, earliest_trigger) pairs, best first."""
        out = []
        while self._heap and len(out) < n:
            prio, _, key = heapq.heappop(self._heap)
            if self._prio.get(key) != prio:     # popped earlier, or outranked
                continue
            del self._prio[key]
            out.append((key, self._trigger.pop(key)))
        return out

    def extract(self, keys) -> list:
        """Remove ``keys`` from the pending set, returning the live
        ``(key, priority, trigger)`` triples (reshard migration: the dirt
        moves WITH the node).  Heap entries left behind go stale and are
        skipped by the lazy-deletion check in ``pop_batch``."""
        out = []
        for key in keys:
            if key in self._trigger:
                out.append((key, self._prio.pop(key), self._trigger.pop(key)))
        return out

    def clear(self) -> None:
        self._heap.clear()
        self._trigger.clear()
        self._prio.clear()

    # ---- checkpoint (DESIGN.md §12) -------------------------------------
    def snapshot(self) -> dict:
        """Heap entries AND the authoritative maps: restoring the heap
        verbatim (stale entries included) reproduces pop order exactly,
        tie-breaks and all — required for partial-drain bit parity."""
        return {"heap": list(self._heap), "trigger": dict(self._trigger),
                "prio": dict(self._prio), "seq": self._seq}

    def restore(self, state: dict) -> None:
        self._heap = list(state["heap"])
        heapq.heapify(self._heap)          # already a heap; cheap + explicit
        self._trigger = dict(state["trigger"])
        self._prio = dict(state["prio"])
        self._seq = int(state["seq"])

    def __len__(self) -> int:
        return len(self._trigger)

    def __contains__(self, key) -> bool:
        return key in self._trigger


# ------------------------------------------------------------------ metrics


@dataclass
class LifecycleMetrics:
    """Recompute-pipeline counters (shared by nearline as NearlineMetrics).

    High-water-mark policy (DESIGN.md §15): ``queue_depth_peak`` — like
    every field here — is PROCESS-LOCAL observability state, outside the
    §12 bits surface.  ``snapshot()/restore()`` neither saves nor resets
    it (a warm rollback keeps the peak observed so far; a cold restart
    starts a fresh one), and ``reshard()`` carries each shard's peak
    unchanged — tests/test_obs.py pins all three."""
    events_processed: int = 0
    batches: int = 0
    nodes_refreshed: int = 0
    encoder_seconds: float = 0.0
    join_seconds: float = 0.0
    encoder_traces: int = 0                         # jit retrace count
    staleness: list = field(default_factory=list)   # trigger -> refresh deltas
    join_reads: int = 0
    sweeps: int = 0                                 # publish_version calls
    queue_depth_peak: int = 0                       # high-water recompute queue
    cache_hits: int = 0                             # serving ResultCache reads
    cache_misses: int = 0
    feature_cache_hits: int = 0                     # tier-1 slab (DESIGN §11)
    feature_cache_misses: int = 0
    feature_cache_evictions: int = 0
    embed_cache_hits: int = 0                       # tier-2 slab (DESIGN §11)
    embed_cache_misses: int = 0
    embed_cache_evictions: int = 0
    shed_queue_full: int = 0                        # overload control (§12):
    shed_deadline: int = 0                          #   sheds by reason, and
    requests_degraded: int = 0                      #   stale-served admissions

    def summary(self) -> dict:
        st = np.array(self.staleness) if self.staleness else np.array([0.0])
        return {
            "events": self.events_processed,
            "batches": self.batches,
            "nodes_refreshed": self.nodes_refreshed,
            "encoder_ms_per_batch": 1e3 * self.encoder_seconds / max(self.batches, 1),
            "join_ms_per_batch": 1e3 * self.join_seconds / max(self.batches, 1),
            "encoder_traces": self.encoder_traces,
            "staleness_p50_s": float(np.percentile(st, 50)),
            "staleness_p99_s": float(np.percentile(st, 99)),
            "join_reads": self.join_reads,
            "sweeps": self.sweeps,
            "queue_depth_peak": self.queue_depth_peak,
            "cache_hit_rate": (self.cache_hits
                               / max(self.cache_hits + self.cache_misses, 1)),
            "feature_cache_hits": self.feature_cache_hits,
            "feature_cache_misses": self.feature_cache_misses,
            "feature_cache_evictions": self.feature_cache_evictions,
            "feature_cache_hit_rate": (
                self.feature_cache_hits
                / max(self.feature_cache_hits + self.feature_cache_misses, 1)),
            "embed_cache_hits": self.embed_cache_hits,
            "embed_cache_misses": self.embed_cache_misses,
            "embed_cache_evictions": self.embed_cache_evictions,
            "embed_cache_hit_rate": (
                self.embed_cache_hits
                / max(self.embed_cache_hits + self.embed_cache_misses, 1)),
            "shed_queue_full": self.shed_queue_full,
            "shed_deadline": self.shed_deadline,
            "requests_degraded": self.requests_degraded,
        }


def index_reverse_edges(graph, rev: dict) -> None:
    """Index a snapshot's edges src->dst as ``rev[dst] ∋ src`` — the ONE
    reverse-edge walk both the single lifecycle and the sharded cluster
    bootstrap their dirty-closure index from.  ``rev`` may be a plain dict
    or a defaultdict(set); missing keys are created."""
    for (s, d), csr in graph.adj.items():
        src = np.repeat(np.arange(len(csr.indptr) - 1), np.diff(csr.indptr))
        for u, v in zip(src, csr.indices):
            rev.setdefault((d, int(v)), set()).add((s, int(u)))


# ---------------------------------------------------------------- lifecycle


class EmbeddingLifecycle:
    """Dirty-set tracking + the two recompute paths over one GraphEngine.

    Owns the registry of known nodes, the reverse-edge index the dirty
    closure walks, the priority recompute queue, and the shared batched
    encode (TileBuilder tile -> power-of-two bucket pad -> jitted encoder).
    ``tile_fn`` lets a caller substitute its own tile builder for the same
    node batch (nearline passes its scalar-join oracle arm through here).
    """

    def __init__(self, cfg: GNNConfig, encoder_params, engine, *,
                 fanouts=None, store: EmbeddingStore | None = None,
                 policy: StalenessPolicy | None = None, micro_batch: int = 64,
                 seed: int = 0, metrics=None, tile_fn=None,
                 jit_encoder: bool = True, embed_cache=None):
        from repro.core.cache import as_slab_cache
        self.cfg = cfg
        self.params = encoder_params
        self.engine = engine
        self.fanouts = tuple(fanouts or cfg.fanouts)
        self.builder = TileBuilder(engine, self.fanouts)
        self.store = store if store is not None else EmbeddingStore("gnn-embeddings")
        self.policy = policy or StalenessPolicy()
        self.micro_batch = micro_batch
        self.seed = seed
        self.metrics = metrics if metrics is not None else LifecycleMetrics()
        self.tile_fn = tile_fn or self.build_tile
        self.jit_encoder = jit_encoder
        # tier 2 of the §11 memory hierarchy: recently computed embeddings,
        # invalidated by the FULL K-hop dirty ball in mark_dirty (same rule
        # as the serving ResultCache — a hit may change latency, never bits).
        # A miss costs a full encoder pass, so the bare-slots form admits on
        # first compute rather than waiting out the tier-1 miss threshold.
        self.embed_cache = as_slab_cache(embed_cache, cfg.embed_dim,
                                         name="embed-cache", admit_after=0)
        if self.embed_cache is not None:
            self.store.attach_cache(self.embed_cache)
        self.registry: set = set()                  # known (ntype, nid) keys
        self._rev: dict = defaultdict(set)          # key -> in-neighbor keys
        self.queue = RecomputeQueue()
        # per-node uniform slabs are a pure function of (seed, node) — the
        # memo is the third hot-path tier (§11): a hot node re-dirtied every
        # batch would otherwise pay a fresh Generator construction (~30 µs)
        # per recompute.  Pure ⇒ no invalidation, bits can never change.
        self._uniform_memo: dict = {}
        self._encode = self._make_encode()

    # ---- registry + reverse index ---------------------------------------
    def register(self, node_type: str, node_id: int) -> None:
        self.registry.add((node_type, int(node_id)))

    def observe_bootstrap(self, graph) -> None:
        """Register every snapshot node and index its edges for closure."""
        for ntype in NODE_TYPES:
            for i in range(graph.num_nodes.get(ntype, 0)):
                self.registry.add((ntype, i))
        index_reverse_edges(graph, self._rev)

    def observe_edge(self, src_key, dst_key) -> None:
        """Record a live edge src->dst (src can now sample dst's subtree)."""
        self._rev[dst_key].add(src_key)

    # ---- dirty tracking -------------------------------------------------
    def dirty_closure(self, keys, radius: int | None = None) -> set:
        """Touched nodes plus everything within ``radius`` (default: the
        policy radius) along reverse edges — the nodes whose padded tiles
        could have changed."""
        seen = set(keys)
        frontier = set(keys)
        if radius is None:
            radius = self.policy.radius(len(self.fanouts))
        for _ in range(radius):
            nxt = set()
            for k in frontier:
                nxt |= self._rev.get(k, frozenset())
            frontier = nxt - seen
            if not frontier:
                break
            seen |= frontier
        return seen

    def enqueue_dirty(self, key, t: float) -> None:
        """Register + queue ONE dirty key and bump the queue-depth peak —
        the shared enqueue step of both the single-engine ``mark_dirty``
        and the sharded cluster's owner-routed marking."""
        self.registry.add(key)
        self.queue.push(key, self.policy.priority(key[0], t), t)
        self.metrics.queue_depth_peak = max(self.metrics.queue_depth_peak,
                                            len(self.queue))

    def mark_dirty(self, node_type: str, node_id: int, t: float) -> int:
        """Dirty a touched node and its closure; returns #enqueued keys."""
        touched = {(node_type, int(node_id))}
        keys = self.dirty_closure(touched)
        self.invalidate_embed_cache(touched, closure=keys)
        for key in keys:
            self.enqueue_dirty(key, t)
        return len(keys)

    def invalidate_embed_cache(self, touched, *, closure=None) -> None:
        """Drop tier-2 rows over the FULL K-hop dependency ball of the
        touched keys — regardless of the (possibly cheaper) policy radius
        used for recompute scheduling.  The recompute queue may tolerate an
        eventually-consistent radius; a cache may not, or a hit would
        resurface embeddings the policy decided to refresh lazily (the same
        rule the serving ResultCache applies)."""
        if self.embed_cache is None:
            return
        full = (closure if closure is not None
                and self.policy.closure_radius is None
                else self.dirty_closure(touched, radius=len(self.fanouts)))
        for nt, ni in full:
            self.embed_cache.invalidate(NODE_TYPE_ID[nt], ni)

    def enqueue_stale(self, now: float) -> int:
        """Age-out: enqueue registered nodes older than max_staleness_s."""
        if not np.isfinite(self.policy.max_staleness_s):
            return 0
        n = 0
        for key in self.registry:
            if key in self.queue:
                continue
            rec = self._d_peek(key)
            if rec is not None and now - rec.time > self.policy.max_staleness_s:
                self.queue.push(key, self.policy.priority(key[0], rec.time),
                                rec.time)
                n += 1
        return n

    def _d_peek(self, key):
        # raw read without inflating the store's RPC accounting
        return self.store._d.get(key)

    # ---- deterministic recompute ----------------------------------------
    def uniform_slab(self, node_type: str, node_id: int) -> np.ndarray:
        key = (node_type, int(node_id))
        slab = self._uniform_memo.get(key)
        if slab is None:
            slab = node_uniform_slab(self.seed, node_type, node_id,
                                     self.builder.slab_width)
            self._uniform_memo[key] = slab
        return slab

    def recompute_uniforms(self, nodes) -> np.ndarray:
        return np.stack([self.uniform_slab(nt, ni) for nt, ni in nodes])

    def build_tile(self, nodes):
        """Default tile path: the shared K-hop TileBuilder over the engine,
        fed the stacked per-node uniform slabs.  Join-read accounting lives
        here (and in any substituted ``tile_fn``), not in ``encode_nodes``,
        so a tile function that tracks its own reads is never double-counted."""
        reads0 = self.engine.join_reads
        q_ty = np.array([NODE_TYPE_ID[t] for t, _ in nodes], np.int64)
        q_id = np.array([i for _, i in nodes], np.int64)
        tile = self.builder.build(q_ty, q_id,
                                  uniforms=self.recompute_uniforms(nodes))
        self.metrics.join_reads += self.engine.join_reads - reads0
        return tile

    def _make_encode(self):
        from repro.core import encoder as enc
        cfg = self.cfg

        def fn(params, tile):
            # trace-time side effect: counts (re)compilations per bucket
            self.metrics.encoder_traces += 1
            return enc.encoder_apply(params, cfg, tile)

        return jax.jit(fn)

    def encode_nodes(self, nodes) -> np.ndarray:
        """Batched (re)compute with the tier-2 cache in front: resident keys
        are served out of the slab (bits of a previous compute, still valid
        because ``invalidate_embed_cache`` dropped every key whose tile
        could have changed), only misses reach the encoder.  The encoder is
        row-wise (bucket padding never leaks across rows), so encoding the
        miss subset alone is bit-identical to encoding the full batch."""
        cache = self.embed_cache
        if cache is None or not cache.slots:
            return self._encode_fresh(nodes)
        tids = np.array([NODE_TYPE_ID[t] for t, _ in nodes], np.int64)
        nids = np.array([int(i) for _, i in nodes], np.int64)
        slots = cache.lookup(tids, nids)
        hit = slots >= 0
        nh = int(hit.sum())
        out = np.empty((len(nodes), self.cfg.embed_dim), np.float32)
        if nh:
            hs = slots[hit]
            out[hit] = cache.gather(hs)
            cache.touch(hs)
        if nh < len(nodes):
            miss = np.nonzero(~hit)[0]
            rows = self._encode_fresh([nodes[i] for i in miss])
            out[miss] = rows
            admit = cache.note_misses(tids[miss], nids[miss])
            if admit.any():
                cache.insert(tids[miss][admit], nids[miss][admit], rows[admit])
        cache.hits += nh
        cache.misses += len(nodes) - nh
        self.metrics.embed_cache_hits += nh
        self.metrics.embed_cache_misses += len(nodes) - nh
        self.metrics.embed_cache_evictions = cache.evictions
        return out

    def _encode_fresh(self, nodes) -> np.ndarray:
        """One batched recompute: tile_fn -> bucket pad -> encode -> [n, e]."""
        from repro.core import encoder as enc
        from repro.core.linksage import _to_jnp
        t0 = _time.perf_counter()
        with _obs_span("tile.build") as sp:
            tile = self.tile_fn(nodes)
            sp.set("rows", len(nodes))
        self.metrics.join_seconds += _time.perf_counter() - t0
        t0 = _time.perf_counter()
        if self.jit_encoder:
            # one compiled executable per power-of-two bucket: steady-state
            # batches never retrace
            with _obs_span("encode.stage") as sp:
                tile = pad_tile(tile, bucket_pow2(len(nodes)))
                tj = _to_jnp(tile)
                sp.set("bucket", bucket_pow2(len(nodes)))
            with _obs_span("encode.dispatch"):
                emb = np.asarray(self._encode(self.params, tj))
        else:
            with _obs_span("encode.stage"):
                tile = pad_tile(tile, len(nodes) + (-len(nodes)) % 8)
                tj = _to_jnp(tile)
            with _obs_span("encode.dispatch"):
                emb = np.asarray(enc.encoder_apply(self.params, self.cfg, tj))
        self.metrics.encoder_seconds += _time.perf_counter() - t0
        self.metrics.batches += 1
        self.metrics.nodes_refreshed += len(nodes)
        return emb[:len(nodes)]

    # ---- the two recompute paths ----------------------------------------
    def drain(self, *, clock: float = 0.0, max_nodes: int | None = None) -> int:
        """Incremental path (NearlineInference): pop dirty nodes by priority,
        recompute in micro-batches, write into the live table as in-flight
        records toward the next version.  Returns #nodes refreshed."""
        self.enqueue_stale(clock)
        self.metrics.queue_depth_peak = max(self.metrics.queue_depth_peak,
                                            len(self.queue))
        total = 0
        while len(self.queue):
            room = self.micro_batch if max_nodes is None else min(
                self.micro_batch, max_nodes - total)
            if room <= 0:
                break
            batch = self.queue.pop_batch(room)
            nodes = [k for k, _ in batch]
            with _obs_span("drain.batch") as sp:
                emb = self.encode_nodes(nodes)
                for r, ((nt, ni), trig) in enumerate(batch):
                    self.store.put_embedding(nt, ni, emb[r], clock,
                                             version=self.store.version + 1)
                    self.metrics.staleness.append(clock - trig)
                sp.set("nodes", len(nodes))
            total += len(nodes)
        return total

    # ---- checkpoint (DESIGN.md §12) -------------------------------------
    def snapshot(self) -> dict:
        """Everything a warm restart must reproduce: store (live records +
        published tables), registry, and the pending recompute queue.  NOT
        included: the uniform memo (pure function of (seed, node) — it
        regrows bit-identically) and the reverse index (owned by whoever
        built it: the cluster snapshots its ONE shared index once)."""
        return {"store": self.store.snapshot(),
                "registry": set(self.registry),
                "queue": self.queue.snapshot()}

    def restore(self, state: dict) -> None:
        self.store.restore(state["store"])
        self.registry = set(state["registry"])
        self.queue.restore(state["queue"])

    def publish_version(self, *, clock: float = 0.0) -> int:
        """Full-sweep path (OfflineBatchInference): recompute EVERY registry
        node at the current graph state, freeze the table, return the new
        version.  The sweep supersedes all pending dirt."""
        keys = sorted(self.registry,
                      key=lambda k: (NODE_TYPE_ID[k[0]], k[1]))
        for i in range(0, len(keys), self.micro_batch):
            chunk = keys[i:i + self.micro_batch]
            emb = self.encode_nodes(chunk)
            for r, (nt, ni) in enumerate(chunk):
                self.store.put_embedding(nt, ni, emb[r], clock,
                                         version=self.store.version + 1)
        self.queue.clear()
        self.metrics.sweeps += 1
        return self.store.publish(clock=clock)

    def pending(self) -> int:
        return len(self.queue)
