"""Heterogeneous job-marketplace graph (paper §3).

Node types (Table 1): member, job, skill, title, company, position.
Edge types (Table 2), stored directed with explicit reciprocals (§4.3 found
bidirectional member↔title / member↔skill / member,job↔position edges
optimal):

    attribute edges   member→{skill,title,company,position}
                      job→{skill,title,company,position}   (+ reverses)
    engagement edges  member→job  (save/apply/click)
    recruiter edges   job→member  (reach-outs)

Storage is CSR per edge type (host-side numpy).  Fixed-fanout sampling
queries are answered by :class:`repro.core.engine.SnapshotEngine` wrapping
this graph (the DeepGNN role); device-side code only ever sees the padded
K-hop tiles produced by :class:`repro.core.engine.TileBuilder`.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

NODE_TYPES = ["member", "job", "skill", "title", "company", "position"]
NODE_TYPE_ID = {t: i for i, t in enumerate(NODE_TYPES)}
NUM_NODE_TYPES = len(NODE_TYPES)

# canonical directed edge types; reverses are added explicitly
EDGE_TYPES = [
    ("member", "skill"), ("member", "title"), ("member", "company"), ("member", "position"),
    ("job", "skill"), ("job", "title"), ("job", "company"), ("job", "position"),
    ("member", "job"),    # seeker engagement
    ("job", "member"),    # recruiter interaction
    # reciprocal attribute edges (graph densification, §4.3)
    ("skill", "member"), ("title", "member"), ("company", "member"), ("position", "member"),
    ("skill", "job"), ("title", "job"), ("company", "job"), ("position", "job"),
]


@dataclass
class CSR:
    """Compressed sparse rows for one directed edge type."""
    indptr: np.ndarray    # [num_src + 1] int64
    indices: np.ndarray   # [num_edges] int32 destination node ids (type-local)

    @staticmethod
    def from_edges(src: np.ndarray, dst: np.ndarray, num_src: int) -> "CSR":
        order = np.argsort(src, kind="stable")
        src_s, dst_s = src[order], dst[order]
        counts = np.bincount(src_s, minlength=num_src)
        indptr = np.zeros(num_src + 1, np.int64)
        np.cumsum(counts, out=indptr[1:])
        return CSR(indptr=indptr, indices=dst_s.astype(np.int32))

    def neighbors(self, node: int) -> np.ndarray:
        return self.indices[self.indptr[node]:self.indptr[node + 1]]

    def degree(self, node: int) -> int:
        return int(self.indptr[node + 1] - self.indptr[node])

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])


@dataclass
class HeteroGraph:
    """The job-marketplace graph: per-type features + per-edge-type CSR."""
    num_nodes: dict                     # node_type -> int
    features: dict                      # node_type -> [n, d_feat] float32
    adj: dict = field(default_factory=dict)   # (src_t, dst_t) -> CSR
    feat_dim: int = 0

    def __post_init__(self):
        if self.features:
            self.feat_dim = next(iter(self.features.values())).shape[1]

    def add_edges(self, src_type: str, dst_type: str, src: np.ndarray, dst: np.ndarray,
                  *, reciprocal: bool = False) -> None:
        assert src_type in NODE_TYPE_ID and dst_type in NODE_TYPE_ID
        self.adj[(src_type, dst_type)] = CSR.from_edges(
            np.asarray(src), np.asarray(dst), self.num_nodes[src_type])
        if reciprocal:
            self.adj[(dst_type, src_type)] = CSR.from_edges(
                np.asarray(dst), np.asarray(src), self.num_nodes[dst_type])

    def edge_count(self, src_type: str, dst_type: str) -> int:
        key = (src_type, dst_type)
        return self.adj[key].num_edges if key in self.adj else 0

    def relations_from(self, node_type: str):
        """Edge types outgoing from ``node_type`` present in this graph."""
        return [(s, d) for (s, d) in self.adj if s == node_type]

    def census(self) -> dict:
        """Table 1 + Table 2 style statistics."""
        return {
            "nodes": dict(self.num_nodes),
            "edges": {f"{s}->{d}": csr.num_edges for (s, d), csr in self.adj.items()},
            "total_nodes": int(sum(self.num_nodes.values())),
            "total_edges": int(sum(c.num_edges for c in self.adj.values())),
        }
