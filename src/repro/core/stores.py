"""Storage primitives shared by the graph backends (DESIGN.md §2).

These are the host-side stores both :class:`repro.core.engine.StreamingEngine`
and the nearline pipeline are built from:

  NoSQLStore      — dict-backed keyed store with read/write accounting
                    (models the real store's scalar vs batched RPCs)
  RingBuffer      — array-backed bounded neighbor rings for one edge type
  NeighborStore   — per-edge-type rings keyed by (node_type, id)

The messaging layer (Topic/Event) stays in :mod:`repro.core.nearline`, and
the versioned online :class:`repro.core.embeddings.EmbeddingStore` lives in
the embedding-lifecycle module; these primitives carry no event or version
semantics of their own.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core.graph import NODE_TYPE_ID, NODE_TYPES


@dataclass(frozen=True)
class StoreLatency:
    """Read-path cost model for the REMOTE store the in-memory dict stands
    in for (§5.2; DESIGN.md §11).

    The real serving tier fetches features over RPC from a disk-backed
    NoSQL store, so a read costs per-RPC dispatch plus a per-key media +
    deserialization charge — the cost structure that makes feature fetch
    dominate the tile-build path in production (and the regime the §11
    feature cache exists for).  The dict-backed store reads in ~1 µs, three
    orders of magnitude off; opting a store into this model charges the
    difference as a deterministic spin so wall-clock measurements see it.
    Defaults are conservative for a LOCAL disk-backed KV (one dispatch +
    an uncached point read of a ~1 KB row); networked stores are 10-100x
    worse.  Only reads are charged — writes are async/bulk in the real
    tier, and the read path is what the cache tier intercepts.
    """
    per_rpc_us: float = 500.0
    per_key_us: float = 20.0

    def charge(self, nkeys: int) -> None:
        end = time.perf_counter() + (
            self.per_rpc_us + self.per_key_us * nkeys) * 1e-6
        while time.perf_counter() < end:
            pass


class NoSQLStore:
    """In-memory NoSQL store with read/write accounting (I/O bottleneck
    analysis, §5.2 challenge (c)).  ``latency`` opts the read path into the
    :class:`StoreLatency` remote-store cost model (None = free reads)."""

    def __init__(self, name: str, latency: StoreLatency | None = None):
        self.name = name
        self.latency = latency
        self._d: dict = {}
        self.reads = 0
        self.writes = 0

    def put(self, key, value) -> None:
        self._d[key] = value
        self.writes += 1

    def get(self, key, default=None):
        self.reads += 1
        if self.latency is not None:
            self.latency.charge(1)
        return self._d.get(key, default)

    def put_many(self, items) -> None:
        """Bulk write (one RPC in the real store): items is (key, value)s."""
        items = list(items)
        self._d.update(items)
        self.writes += len(items)

    def multi_get(self, keys):
        self.reads += len(keys)
        if self.latency is not None:
            self.latency.charge(len(keys))
        return [self._d.get(k) for k in keys]

    def __contains__(self, key):
        return key in self._d

    def __len__(self):
        return len(self._d)

    # ---- checkpoint (DESIGN.md §12) -------------------------------------
    def snapshot(self) -> dict:
        """Copy of the full keyed state (values are treated as immutable —
        every write path replaces whole values, never mutates in place)."""
        return {"d": dict(self._d), "reads": self.reads, "writes": self.writes}

    def restore(self, state: dict) -> None:
        self._d = dict(state["d"])
        self.reads = int(state["reads"])
        self.writes = int(state["writes"])


class RingBuffer:
    """Array-backed bounded neighbor lists for one (src_type, dst_type) edge
    type: a [capacity, K] int32 ring per source node with a write cursor.

    ``add`` is an O(1) in-place write, bulk bootstrap is a vectorized fill,
    and batched sampling reads the backing arrays directly (no per-key dict
    gets).  Neighbor *order* inside a row is append order until the ring
    wraps; once it wraps, sampling is uniform over the resident set, so only
    membership matters.
    """

    def __init__(self, name: str, max_neighbors: int, capacity: int = 1024):
        self.name = name
        self.K = max_neighbors
        self.buf = np.zeros((capacity, max_neighbors), np.int32)
        self.count = np.zeros(capacity, np.int32)
        self.head = np.zeros(capacity, np.int32)
        self.reads = 0
        self.writes = 0

    @property
    def capacity(self) -> int:
        return self.buf.shape[0]

    def _ensure(self, n: int) -> None:
        cap = self.capacity
        if n <= cap:
            return
        new_cap = max(cap * 2, n)
        self.buf = np.concatenate(
            [self.buf, np.zeros((new_cap - cap, self.K), np.int32)])
        self.count = np.concatenate([self.count, np.zeros(new_cap - cap, np.int32)])
        self.head = np.concatenate([self.head, np.zeros(new_cap - cap, np.int32)])

    def add(self, src_id: int, dst_id: int) -> None:
        self._ensure(src_id + 1)
        self.buf[src_id, self.head[src_id]] = dst_id
        self.head[src_id] = (self.head[src_id] + 1) % self.K
        self.count[src_id] = min(self.count[src_id] + 1, self.K)
        self.writes += 1

    def bulk_load(self, indptr: np.ndarray, indices: np.ndarray) -> None:
        """Vectorized bootstrap from a CSR: keep the last K neighbors/node."""
        n = len(indptr) - 1
        self._ensure(n)
        deg = np.diff(indptr)
        cnt = np.minimum(deg, self.K).astype(np.int64)
        total = int(cnt.sum())
        rows = np.repeat(np.arange(n), cnt)
        offs = np.zeros(n + 1, np.int64)
        np.cumsum(cnt, out=offs[1:])
        pos = np.arange(total) - np.repeat(offs[:-1], cnt)
        src_idx = np.repeat(indptr[1:] - cnt, cnt) + pos
        self.buf[rows, pos] = indices[src_idx]
        self.count[:n] = cnt
        self.head[:n] = cnt % self.K
        self.writes += total

    def counts(self, ids: np.ndarray) -> np.ndarray:
        """Vectorized degree lookup; ids beyond capacity have degree 0."""
        self.reads += len(ids)
        out = np.zeros(len(ids), np.int64)
        ok = ids < self.capacity
        out[ok] = self.count[ids[ok]]
        return out

    def rows(self, ids: np.ndarray) -> np.ndarray:
        """Vectorized [len(ids), K] row gather; out-of-capacity ids are all
        zeros (their count is 0, so the padding is never dereferenced)."""
        self.reads += len(ids)
        out = np.zeros((len(ids), self.K), np.int32)
        ok = ids < self.capacity
        out[ok] = self.buf[ids[ok]]
        return out

    def row(self, src_id: int) -> np.ndarray:
        self.reads += 1
        if src_id >= self.capacity:
            return self.buf[:0, 0]
        return self.buf[src_id, :self.count[src_id]]

    # ---- checkpoint + migration (DESIGN.md §12) -------------------------
    def snapshot(self) -> dict:
        """Copy of (buf, count, head) — ring content is a pure function of
        the per-source event subsequence, so this IS the replayable state."""
        return {"buf": self.buf.copy(), "count": self.count.copy(),
                "head": self.head.copy(), "reads": self.reads,
                "writes": self.writes}

    def restore(self, state: dict) -> None:
        self.buf = state["buf"].copy()
        self.count = state["count"].copy()
        self.head = state["head"].copy()
        self.reads = int(state["reads"])
        self.writes = int(state["writes"])

    def export_row(self, src_id: int):
        """(buf_row, count, head) for one source node, or None if empty —
        the unit of cross-shard ring migration."""
        if src_id >= self.capacity or self.count[src_id] == 0:
            return None
        return (self.buf[src_id].copy(), int(self.count[src_id]),
                int(self.head[src_id]))

    def import_row(self, src_id: int, buf_row: np.ndarray, count: int,
                   head: int) -> None:
        """Install one exported row (cursor included, so append semantics
        continue exactly where the source shard left off)."""
        self._ensure(src_id + 1)
        self.buf[src_id] = buf_row
        self.count[src_id] = count
        self.head[src_id] = head

    def clear_row(self, src_id: int) -> None:
        if src_id < self.capacity:
            self.buf[src_id] = 0
            self.count[src_id] = 0
            self.head[src_id] = 0


class NeighborStore:
    """Per-edge-type bounded neighbor rings keyed by (node_type, id).

    One store monitors job neighbors per node type (paper: "multiple feature
    stores that monitor job neighbors per node type").
    """

    def __init__(self, max_neighbors: int = 64):
        self.stores: dict = {}
        self.max_neighbors = max_neighbors

    def _store(self, src_type: str, dst_type: str) -> RingBuffer:
        key = (src_type, dst_type)
        if key not in self.stores:
            self.stores[key] = RingBuffer(f"neigh:{src_type}->{dst_type}",
                                          self.max_neighbors)
        return self.stores[key]

    def add(self, src_type: str, src_id: int, dst_type: str, dst_id: int) -> None:
        self._store(src_type, dst_type).add(src_id, dst_id)

    def bulk_load(self, src_type: str, dst_type: str, indptr, indices) -> None:
        self._store(src_type, dst_type).bulk_load(indptr, indices)

    def _relations(self, node_type: str):
        return [(NODE_TYPE_ID[d], st) for (s, d), st in self.stores.items()
                if s == node_type]

    # ---- checkpoint + migration (DESIGN.md §12) -------------------------
    def register_relations_like(self, other: "NeighborStore") -> None:
        """Create every relation ``other`` holds, in ``other``'s insertion
        order, with zero rows — the merged-offset contract requires a fresh
        shard to agree on relation order before any row migrates in."""
        for (s, d) in other.stores:
            self._store(s, d)

    def snapshot(self) -> dict:
        """Relations in insertion order (the merged-offset contract is part
        of the state) with each ring's full array snapshot."""
        return {"relations": [((s, d), st.snapshot())
                              for (s, d), st in self.stores.items()]}

    def restore(self, state: dict) -> None:
        self.stores.clear()
        for (s, d), ring_state in state["relations"]:
            self._store(s, d).restore(ring_state)

    def export_node(self, node_type: str, node_id: int) -> list:
        """Pop every ring row sourced at (node_type, id), in relation
        insertion order — the migration unit ``import_node`` consumes."""
        out = []
        for (s, d), st in self.stores.items():
            if s != node_type:
                continue
            row = st.export_row(node_id)
            if row is not None:
                out.append(((s, d), row))
                st.clear_row(node_id)
        return out

    def import_node(self, node_id: int, rows: list) -> None:
        for (s, d), (buf_row, count, head) in rows:
            self._store(s, d).import_row(node_id, buf_row, count, head)

    def neighbors(self, node_type: str, node_id: int):
        """Merged (dst_type_id, dst_id) neighbor list across edge types.

        Entry order — relation insertion order, then ring column order — is
        the contract shared with :meth:`sample_batched`: offset ``j`` into
        this list and offset ``j`` of the batched path address the same
        neighbor, which is what makes the scalar and batched joins
        bit-identical on the same uniform stream.
        """
        out = []
        for tid, st in self._relations(node_type):
            out.extend((tid, int(i)) for i in st.row(node_id))
        return out

    def sample_batched(self, types: np.ndarray, ids: np.ndarray, fanout: int,
                       uniforms: np.ndarray):
        """Vectorized fixed-fanout sampling for a batch of (type, id) nodes.

        types [n] int, ids [n] int, uniforms [n, fanout] in [0, 1) ->
        (dst_ty [n, F] int32, dst_id [n, F] int32, mask [n, F] float32).
        Draw j = floor(u · deg) indexes the merged neighbor list (see
        :meth:`neighbors`) without ever materializing it.
        """
        n = len(ids)
        out_ty = np.zeros((n, fanout), np.int32)
        out_id = np.zeros((n, fanout), np.int32)
        out_mask = np.zeros((n, fanout), np.float32)
        for tid, tname in enumerate(NODE_TYPES):
            rows = np.nonzero(types == tid)[0]
            if rows.size == 0:
                continue
            rels = self._relations(tname)
            if not rels:
                continue
            nid = ids[rows]
            cnts = np.stack([st.counts(nid) for _, st in rels], axis=1)  # [m, R]
            total = cnts.sum(axis=1)
            has = total > 0
            if not has.any():
                continue
            rows, nid, cnts, total = rows[has], nid[has], cnts[has], total[has]
            j = (uniforms[rows] * total[:, None]).astype(np.int64)       # [m, F]
            cum = np.cumsum(cnts, axis=1)
            rel_idx = (j[:, :, None] >= cum[:, None, :]).sum(axis=-1)    # [m, F]
            start = cum - cnts
            slot = j - np.take_along_axis(start, rel_idx, axis=1)        # [m, F]
            for r, (dtid, st) in enumerate(rels):
                rr, ff = np.nonzero(rel_idx == r)
                if rr.size == 0:
                    continue
                out_id[rows[rr], ff] = st.buf[nid[rr], slot[rr, ff]]
                out_ty[rows[rr], ff] = dtid
            out_mask[rows] = 1.0
        return out_ty, out_id, out_mask
