"""Pytree checkpointing: flat-keyed .npz payload + json manifest.

Layout on disk::

    <dir>/step_000100/
        manifest.json   # treedef repr, flat key order, dtypes, shapes
        arrays.npz      # one entry per leaf, keyed by flat path

Restore rebuilds the exact pytree structure; a structural mismatch against a
template is a hard error (guards against silent config drift).
"""
from __future__ import annotations

import json
import os
import re

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)
    leaves = [(jax.tree_util.keystr(path), leaf) for path, leaf in flat[0]]
    return leaves, flat[1]


def save_checkpoint(directory: str, step: int, tree) -> str:
    path = os.path.join(directory, f"step_{step:06d}")
    os.makedirs(path, exist_ok=True)
    leaves, _ = _flatten_with_paths(tree)
    arrays = {key: np.asarray(leaf) for key, leaf in leaves}
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": [k for k, _ in leaves],
        "shapes": {k: list(np.asarray(v).shape) for k, v in leaves},
        "dtypes": {k: str(np.asarray(v).dtype) for k, v in leaves},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return path


def load_checkpoint(directory: str, step: int, template):
    """Restore into the structure of ``template`` (values are replaced)."""
    path = os.path.join(directory, f"step_{step:06d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten_with_paths(template)
    keys = [k for k, _ in leaves]
    if keys != manifest["keys"]:
        missing = set(manifest["keys"]) ^ set(keys)
        raise ValueError(f"checkpoint structure mismatch; differing keys: {sorted(missing)[:8]}")
    restored = [data[k] for k in keys]
    return jax.tree_util.tree_unflatten(treedef, restored)


def save_state(directory: str, step: int, state, *, name: str = "state") -> str:
    """Persist a nested Python/numpy state blob (the serving tier's cluster
    snapshots — dicts keyed by (type, id) tuples, heaps, ring arrays) next
    to the pytree layout, as ``<name>.npy`` inside the same ``step_*`` dir.
    Arbitrary structure rules out the flat-npz manifest; a 1-element object
    array keeps the on-disk idiom numpy end to end."""
    path = os.path.join(directory, f"step_{step:06d}")
    os.makedirs(path, exist_ok=True)
    blob = np.empty(1, object)
    blob[0] = state
    np.save(os.path.join(path, f"{name}.npy"), blob, allow_pickle=True)
    return path


def load_state(directory: str, step: int, *, name: str = "state"):
    path = os.path.join(directory, f"step_{step:06d}", f"{name}.npy")
    return np.load(path, allow_pickle=True)[0]


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.match(r"step_(\d+)$", d))]
    return max(steps) if steps else None
