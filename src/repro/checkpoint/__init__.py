from repro.checkpoint.checkpoint import (save_checkpoint, load_checkpoint,
                                         save_state, load_state, latest_step)

__all__ = ["save_checkpoint", "load_checkpoint", "save_state", "load_state",
           "latest_step"]
