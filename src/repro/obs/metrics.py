"""Metrics registry: labeled counters, gauges, time series, and
deterministic fixed-bucket log-scale histograms (DESIGN.md §15).

The registry is the ONE rollup path for the serving tier's counters.  The
ad-hoc dataclasses (``LifecycleMetrics``, ``BatcherMetrics``, slab-cache
counters, ``SLOReport``) stay where they are — they are hot-path-local and
cheap — and :func:`collect_cluster` mirrors them into one registry whose
``to_json()`` is the telemetry artifact.  Counters incremented *natively*
on the registry (the cluster's ``attach_registry`` lane) ride the §12
resilience state surface: ``snapshot()/restore()`` round-trips every
metric, so a warm rollback + replay re-derives monotonic counts with no
double-counting (tests/test_obs.py pins this).

Histogram semantics (the documented quantile contract):

* Buckets are FIXED log-scale edges ``edge[i] = lo * base**i`` with
  ``base = 10 ** (1 / buckets_per_decade)`` — independent of the data, so
  two histograms with the same spec merge bucket-for-bucket and a
  snapshot/restore is exact.
* ``record(v)`` with ``v < lo`` lands in the underflow bucket, ``v >= hi``
  in the overflow bucket; exact running min/max/sum/count are kept.
* ``quantile(q)`` locates the nearest-rank order statistic (index
  ``ceil(q * (n - 1))``) in the cumulative counts and returns the
  geometric midpoint of its bucket, clamped into ``[min, max]``.  The
  estimate is therefore within a factor of ``sqrt(base)`` of that order
  statistic — with the default 24 buckets/decade, a relative error bound
  of ~4.9%.  Against ``np.percentile`` (any interpolation) the estimate is
  bracketed by ``[percentile(q, 'lower') / sqrt(base),
  percentile(q, 'higher') * sqrt(base)]`` — the regression gate
  tests/test_obs.py asserts.

Telemetry never changes bits: nothing in this module touches RNG state or
the data path, and the Null* objects make disabled mode allocation-free.
"""
from __future__ import annotations

import json
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class HistogramSpec:
    """Fixed log-scale bucket layout: ``buckets_per_decade`` buckets per
    power of ten over ``[lo, hi)``, plus underflow/overflow."""
    lo: float = 1e-6               # seconds: 1 µs
    hi: float = 1e5                # ~28 h — covers age histograms too
    buckets_per_decade: int = 24   # base 10**(1/24): ~4.9% quantile error

    @property
    def base(self) -> float:
        return 10.0 ** (1.0 / self.buckets_per_decade)

    @property
    def num_buckets(self) -> int:
        return int(round(np.log10(self.hi / self.lo)
                         * self.buckets_per_decade))


DEFAULT_SPEC = HistogramSpec()


class Histogram:
    """Deterministic fixed-bucket log-scale histogram (module docstring has
    the quantile contract)."""

    def __init__(self, spec: HistogramSpec | None = None):
        self.spec = spec or DEFAULT_SPEC
        n = self.spec.num_buckets
        # counts[0] = underflow (< lo), counts[1:n+1] = log buckets,
        # counts[n+1] = overflow (>= hi)
        self.counts = np.zeros(n + 2, np.int64)
        self.count = 0
        self.sum = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self._lnb = np.log(self.spec.base)

    def _bucket_of(self, v: np.ndarray) -> np.ndarray:
        s = self.spec
        n = s.num_buckets
        v = np.asarray(v, np.float64)
        idx = np.zeros(v.shape, np.int64)
        in_range = (v >= s.lo) & (v < s.hi)
        with np.errstate(divide="ignore", invalid="ignore"):
            k = np.floor(np.log(np.maximum(v, s.lo) / s.lo) / self._lnb)
        idx[in_range] = 1 + np.clip(k[in_range], 0, n - 1).astype(np.int64)
        idx[v >= s.hi] = n + 1
        return idx

    def record(self, v: float) -> None:
        self.record_many(np.asarray([v], np.float64))

    def record_many(self, values) -> None:
        v = np.asarray(values, np.float64).reshape(-1)
        if v.size == 0:
            return
        np.add.at(self.counts, self._bucket_of(v), 1)
        self.count += int(v.size)
        self.sum += float(v.sum())
        self.vmin = min(self.vmin, float(v.min()))
        self.vmax = max(self.vmax, float(v.max()))

    def edges(self) -> np.ndarray:
        """The documented bucket edges: ``lo * base**i`` for the in-range
        buckets (len = num_buckets + 1)."""
        s = self.spec
        return s.lo * s.base ** np.arange(s.num_buckets + 1)

    def quantile(self, q: float) -> float:
        """Nearest-rank bucket quantile (contract in module docstring);
        0.0 on an empty histogram."""
        if self.count == 0:
            return 0.0
        k = int(np.ceil(q * (self.count - 1)))     # order statistic index
        k = min(max(k, 0), self.count - 1)
        cum = np.cumsum(self.counts)
        b = int(np.searchsorted(cum, k + 1, side="left"))
        n = self.spec.num_buckets
        if b == 0:                                  # underflow bucket
            est = self.vmin
        elif b == n + 1:                            # overflow bucket
            est = self.vmax
        else:
            e_lo = self.spec.lo * self.spec.base ** (b - 1)
            est = e_lo * np.sqrt(self.spec.base)    # geometric midpoint
        return float(min(max(est, self.vmin), self.vmax))

    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def merge(self, other: "Histogram") -> None:
        assert self.spec == other.spec, "cannot merge different specs"
        self.counts += other.counts
        self.count += other.count
        self.sum += other.sum
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)

    # ---- checkpoint (rides the §12 state surface) -----------------------
    def snapshot(self) -> dict:
        return {"spec": (self.spec.lo, self.spec.hi,
                         self.spec.buckets_per_decade),
                "counts": self.counts.copy(), "count": self.count,
                "sum": self.sum, "vmin": self.vmin, "vmax": self.vmax}

    def restore(self, state: dict) -> None:
        lo, hi, bpd = state["spec"]
        self.spec = HistogramSpec(lo, hi, int(bpd))
        self._lnb = np.log(self.spec.base)
        self.counts = np.array(state["counts"], np.int64)
        self.count = int(state["count"])
        self.sum = float(state["sum"])
        self.vmin = float(state["vmin"])
        self.vmax = float(state["vmax"])

    def to_dict(self) -> dict:
        nz = np.nonzero(self.counts)[0]
        return {"count": self.count, "sum": self.sum,
                "min": self.vmin if self.count else 0.0,
                "max": self.vmax if self.count else 0.0,
                "mean": self.mean(),
                "p50": self.quantile(0.50), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99),
                "spec": {"lo": self.spec.lo, "hi": self.spec.hi,
                         "buckets_per_decade": self.spec.buckets_per_decade},
                # sparse encoding: only occupied buckets
                "buckets": {int(i): int(self.counts[i]) for i in nz}}


class Counter:
    """Monotonic counter.  ``inc`` is the native lane; mirrors use Gauges."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-observed value (mirrored dataclass counters land here)."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class TimeSeries:
    """Append-only (t, value) samples — the hit-rate-over-time lane."""
    __slots__ = ("samples",)

    def __init__(self):
        self.samples: list = []

    def append(self, t: float, v: float) -> None:
        self.samples.append((float(t), float(v)))


# ---- disabled mode: shared no-op singletons, zero per-event allocation --

class _NullMetric:
    __slots__ = ()

    def inc(self, n: int = 1) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def record(self, v: float) -> None:
        pass

    def record_many(self, values) -> None:
        pass

    def append(self, t: float, v: float) -> None:
        pass


NULL_METRIC = _NullMetric()


class NullRegistry:
    """Disabled-mode registry: every accessor returns the ONE shared no-op
    metric — no dict lookups, no allocation on any hot path."""
    __slots__ = ()
    enabled = False

    def counter(self, name: str, **labels):
        return NULL_METRIC

    def gauge(self, name: str, **labels):
        return NULL_METRIC

    def histogram(self, name: str, spec=None, **labels):
        return NULL_METRIC

    def series(self, name: str, **labels):
        return NULL_METRIC


NULL_REGISTRY = NullRegistry()

_KINDS = ("counters", "gauges", "histograms", "series")


class MetricsRegistry:
    """Labeled metric registry.  Accessors are get-or-create and return the
    live metric object — hot paths hold the handle and pay zero lookups
    per event.  Keys are ``name{k=v,...}`` with labels sorted."""
    enabled = True

    def __init__(self):
        self._m: dict = {k: {} for k in _KINDS}

    @staticmethod
    def _key(name: str, labels: dict) -> str:
        if not labels:
            return name
        inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
        return f"{name}{{{inner}}}"

    def _get(self, kind: str, name: str, labels: dict, factory):
        key = self._key(name, labels)
        m = self._m[kind].get(key)
        if m is None:
            m = self._m[kind][key] = factory()
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counters", name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauges", name, labels, Gauge)

    def histogram(self, name: str, spec: HistogramSpec | None = None,
                  **labels) -> Histogram:
        return self._get("histograms", name, labels, lambda: Histogram(spec))

    def series(self, name: str, **labels) -> TimeSeries:
        return self._get("series", name, labels, TimeSeries)

    def names(self, kind: str | None = None) -> list:
        if kind is not None:
            return sorted(self._m[kind])
        return sorted(k for d in self._m.values() for k in d)

    def __len__(self) -> int:
        return sum(len(d) for d in self._m.values())

    # ---- checkpoint (rides the §12 state surface) -----------------------
    def snapshot(self) -> dict:
        return {
            "counters": {k: c.value for k, c in self._m["counters"].items()},
            "gauges": {k: g.value for k, g in self._m["gauges"].items()},
            "histograms": {k: h.snapshot()
                           for k, h in self._m["histograms"].items()},
            "series": {k: list(s.samples)
                       for k, s in self._m["series"].items()},
        }

    def restore(self, state: dict) -> None:
        """Restore IN PLACE: metric objects already handed out stay live
        (the cluster's counter handles keep working after a warm rollback)."""
        self._prune(state)
        for k, v in state["counters"].items():
            self.counter_by_key(k).value = int(v)
        for k, v in state["gauges"].items():
            self.gauge_by_key(k).value = float(v)
        for k, st in state["histograms"].items():
            h = self._m["histograms"].get(k)
            if h is None:
                h = self._m["histograms"][k] = Histogram()
            h.restore(st)
        for k, samples in state["series"].items():
            s = self._m["series"].get(k)
            if s is None:
                s = self._m["series"][k] = TimeSeries()
            s.samples = [tuple(x) for x in samples]

    def _prune(self, state: dict) -> None:
        # metrics born after the checkpoint reset to zero-state rather than
        # surviving a rollback they predate
        for kind in _KINDS:
            for k in list(self._m[kind]):
                if k not in state[kind]:
                    m = self._m[kind][k]
                    if isinstance(m, Counter):
                        m.value = 0
                    elif isinstance(m, Gauge):
                        m.value = 0.0
                    elif isinstance(m, Histogram):
                        fresh = Histogram(m.spec)
                        m.restore(fresh.snapshot())
                    else:
                        m.samples = []

    def counter_by_key(self, key: str) -> Counter:
        m = self._m["counters"].get(key)
        if m is None:
            m = self._m["counters"][key] = Counter()
        return m

    def gauge_by_key(self, key: str) -> Gauge:
        m = self._m["gauges"].get(key)
        if m is None:
            m = self._m["gauges"][key] = Gauge()
        return m

    # ---- artifact -------------------------------------------------------
    def to_json(self) -> dict:
        """The telemetry artifact (§6 artifact index): plain-JSON view of
        every metric, histograms with their quantiles + sparse buckets."""
        return {
            "counters": {k: c.value for k, c in self._m["counters"].items()},
            "gauges": {k: g.value for k, g in self._m["gauges"].items()},
            "histograms": {k: h.to_dict()
                           for k, h in self._m["histograms"].items()},
            "series": {k: s.samples for k, s in self._m["series"].items()},
        }

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2)


# ---- the ONE rollup path: mirror the ad-hoc dataclasses -----------------

_SKIP_FIELDS = ("staleness", "occupancy", "latencies_s")


def _mirror_fields(reg: MetricsRegistry, prefix: str, obj, **labels) -> None:
    """Mirror every scalar field of a metrics dataclass into gauges.
    Mirrors are last-observed copies (idempotent — re-collecting never
    double-counts), which is why they are gauges, not counters."""
    for k, v in vars(obj).items():
        if k.startswith("_") or k in _SKIP_FIELDS:
            continue
        if isinstance(v, (bool, int, float, np.integer, np.floating)):
            reg.gauge(f"{prefix}.{k}", **labels).set(float(v))


def mirror_lifecycle_metrics(reg: MetricsRegistry, m, **labels) -> None:
    """LifecycleMetrics → gauges + the staleness (event→re-rank lag)
    histogram."""
    _mirror_fields(reg, "lifecycle", m, **labels)
    if m.staleness:
        h = reg.histogram("lifecycle.staleness_s", **labels)
        h.restore(Histogram(h.spec).snapshot())    # rebuild: mirror, not sum
        h.record_many(np.asarray(m.staleness))


def mirror_batcher_metrics(reg: MetricsRegistry, bm, **labels) -> None:
    _mirror_fields(reg, "batcher", bm, **labels)
    if bm.occupancy:
        reg.gauge("batcher.occupancy_mean", **labels).set(
            float(np.mean(bm.occupancy)))


def mirror_slab_cache(reg: MetricsRegistry, cache, **labels) -> None:
    """SlabCache counters → gauges under ``cache.*`` with a tier label."""
    for k in ("hits", "misses", "evictions", "inserts", "invalidations"):
        reg.gauge(f"cache.{k}", **labels).set(float(getattr(cache, k, 0)))
    reg.gauge("cache.hit_rate", **labels).set(float(cache.hit_rate()))


def mirror_slo_report(reg: MetricsRegistry, report, **labels) -> None:
    _mirror_fields(reg, "slo", report, **labels)


def collect_cluster(reg: MetricsRegistry, cluster, *, slo_report=None,
                    now: float | None = None) -> MetricsRegistry:
    """THE rollup: one call mirrors a :class:`ShardedNearline` cluster's
    whole counter surface (aggregate + per-shard lifecycle metrics, every
    cache tier, retired-batcher overload counters, an optional SLO report)
    and the freshness gauges into ``reg``.  Safe to call repeatedly —
    mirrors overwrite, they never accumulate."""
    from repro.obs.freshness import observe_freshness
    mirror_lifecycle_metrics(reg, cluster.aggregate_metrics(), scope="cluster")
    for p, lc in enumerate(cluster.shards):
        mirror_lifecycle_metrics(reg, lc.metrics, shard=str(p))
    for p, fc in enumerate(cluster.feature_caches):
        mirror_slab_cache(reg, fc, tier="feature", shard=str(p))
    for p, ec in enumerate(cluster.embed_caches):
        mirror_slab_cache(reg, ec, tier="embed", shard=str(p))
    for i, rc in enumerate(cluster.caches):
        reg.gauge("cache.hits", tier="result", idx=str(i)).set(
            rc.metrics.cache_hits)
        reg.gauge("cache.misses", tier="result", idx=str(i)).set(
            rc.metrics.cache_misses)
        reg.gauge("cache.hit_rate", tier="result", idx=str(i)).set(
            rc.hit_rate())
    if slo_report is not None:
        mirror_slo_report(reg, slo_report, scope="cluster")
    observe_freshness(reg, cluster, now=now)
    return reg
