"""Unified telemetry (DESIGN.md §15): metrics registry with deterministic
log-scale histograms, span tracing with a Chrome trace-event exporter, and
graph-signal freshness monitors.  Hard contract: telemetry never changes
bits, and disabled mode (the default) is allocation-free no-op objects."""
from repro.obs.freshness import (AGE_SPEC, embedding_age_histogram,  # noqa: F401
                                 format_freshness, freshness_report,
                                 observe_freshness)
from repro.obs.metrics import (DEFAULT_SPEC, Counter, Gauge,  # noqa: F401
                               Histogram, HistogramSpec, MetricsRegistry,
                               NULL_REGISTRY, TimeSeries, collect_cluster,
                               mirror_batcher_metrics,
                               mirror_lifecycle_metrics, mirror_slab_cache,
                               mirror_slo_report)
from repro.obs.trace import (NULL_TRACER, Span, TickClock,  # noqa: F401
                             Tracer, emit, enabled, get_tracer, set_tracer,
                             span)
