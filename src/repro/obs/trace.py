"""Span tracing over the serve path, with a Chrome trace-event exporter
(DESIGN.md §15).

Span taxonomy (the instrumented request path, in flow order):

  ``router.cache_lookup``   ResultCache probe loop
  ``batcher.queue_wait``    per-request queue wait (sim-clock track)
  ``nearline.batch``        one poll→apply→dirty→drain micro-batch
  ``drain.batch``           one lifecycle recompute micro-batch
  ``tile.build``            K-hop TileBuilder / tile_fn
  ``cache.feature_gather``  tier-1 slab gather inside the tile build
  ``encode.stage``          host→device staging (``_to_jnp``)
  ``encode.dispatch``       the bucketed jitted encoder call
  ``mesh.block_encode``     one shard_map block dispatch (§13)
  ``mesh.exchange``         the all_to_all miss exchange (§13)
  ``router.exchange``       host-sequential per-owner miss loop (oracle arm)
  ``router.score_batch``    full scatter-gather scoring call
  ``store.publish``         version freeze
  ``serve.batch``           one served batch on the sim-clock track

Dual-clock rule: a tracer owns ONE clock for code spans — wall
(``time.perf_counter``) for perf runs, or the deterministic
:class:`TickClock` for tests/CI, which advances a fixed tick per reading
so span trees and durations are a pure function of control flow.
Simulated-time measurements (queue wait, batch service — the load
generator's event clock, i.e. the nearline batch timeline) enter via
:meth:`Tracer.emit` with EXPLICIT timestamps and render on a separate
``pid`` in the Chrome export, so the two timelines never mix on one track.

Never-changes-bits contract: spans only *read* clocks and attach
attributes — no RNG, no data-path branching.  Disabled mode
(:data:`NULL_TRACER`, the module default) hands every call the one shared
``_NullSpan``/no-op — zero per-event allocation, so instrumented code
paths cost a function call when telemetry is off (obs_bench bounds this
at <2% of the nearline hot path).
"""
from __future__ import annotations

import json
import time as _time

import numpy as np


class _NullSpan:
    """The shared disabled-mode span: context-manager no-op, no state."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, key, value):
        pass


_NULL_SPAN = _NullSpan()


class NullTracer:
    __slots__ = ()
    enabled = False

    def span(self, name: str):
        return _NULL_SPAN

    def emit(self, name: str, t0: float, t1: float, *, track: str = "sim",
             **attrs) -> None:
        pass


NULL_TRACER = NullTracer()


class TickClock:
    """Deterministic clock: every reading advances one fixed tick, so span
    durations count clock *readings* between start and finish — a pure
    function of control flow, identical across runs (the dual-clock rule's
    test/CI arm)."""
    __slots__ = ("t", "tick_s")
    kind = "tick"

    def __init__(self, tick_s: float = 1e-3):
        self.t = 0.0
        self.tick_s = float(tick_s)

    def __call__(self) -> float:
        self.t += self.tick_s
        return self.t


class Span:
    """One finished-or-open span.  ``track`` picks the Chrome-export pid:
    "code" = tracer-clock spans, "sim" = explicit simulated-time spans."""
    __slots__ = ("name", "t0", "t1", "span_id", "parent_id", "attrs",
                 "track", "_tracer")

    def __init__(self, tracer, name, t0, span_id, parent_id, track="code"):
        self._tracer = tracer
        self.name = name
        self.t0 = t0
        self.t1 = t0
        self.span_id = span_id
        self.parent_id = parent_id
        self.attrs = None
        self.track = track

    def set(self, key, value) -> None:
        if self.attrs is None:
            self.attrs = {}
        self.attrs[key] = value

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self._tracer._finish(self)
        return False


class Tracer:
    """Parented span collection over ONE clock (wall | tick | callable)."""
    enabled = True

    def __init__(self, clock="wall", *, tick_s: float = 1e-3):
        if clock == "wall":
            self.clock, self.clock_kind = _time.perf_counter, "wall"
        elif clock == "tick":
            self.clock, self.clock_kind = TickClock(tick_s), "tick"
        elif callable(clock):
            self.clock = clock
            self.clock_kind = getattr(clock, "kind", "custom")
        else:
            raise ValueError(f"unknown clock {clock!r}")
        self.spans: list[Span] = []
        self._stack: list[int] = []          # open span ids (parenting)
        self._next_id = 1

    def span(self, name: str) -> Span:
        sid = self._next_id
        self._next_id += 1
        parent = self._stack[-1] if self._stack else 0
        s = Span(self, name, self.clock(), sid, parent)
        self._stack.append(sid)
        return s

    def _finish(self, s: Span) -> None:
        s.t1 = self.clock()
        if self._stack and self._stack[-1] == s.span_id:
            self._stack.pop()
        self.spans.append(s)

    def emit(self, name: str, t0: float, t1: float, *, track: str = "sim",
             **attrs) -> None:
        """Record a span with EXPLICIT timestamps (the simulated-time lane:
        queue waits, served batches).  Not parented — sim spans live on
        their own timeline/track."""
        sid = self._next_id
        self._next_id += 1
        s = Span(self, name, float(t0), sid, 0, track=track)
        s.t1 = float(t1)
        if attrs:
            s.attrs = dict(attrs)
        self.spans.append(s)

    # ---- Chrome trace-event export (perfetto-loadable) ------------------
    def to_chrome(self) -> dict:
        """``{"traceEvents": [...]}`` with "X" (complete) events, ts/dur in
        µs.  pid 0 = code spans on the tracer clock, pid 1 = simulated-time
        spans — chrome://tracing and ui.perfetto.dev load it directly."""
        evs = [
            {"ph": "M", "name": "process_name", "pid": 0, "tid": 0,
             "args": {"name": f"serve path ({self.clock_kind} clock)"}},
            {"ph": "M", "name": "process_name", "pid": 1, "tid": 0,
             "args": {"name": "simulated time (batch clock)"}},
        ]
        for s in self.spans:
            ev = {"name": s.name, "cat": s.track, "ph": "X",
                  "ts": s.t0 * 1e6, "dur": max(s.t1 - s.t0, 0.0) * 1e6,
                  "pid": 0 if s.track == "code" else 1, "tid": 0,
                  "args": {"id": s.span_id, "parent": s.parent_id}}
            if s.attrs:
                ev["args"].update(s.attrs)
            evs.append(ev)
        return {"traceEvents": evs, "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)

    # ---- per-stage latency decomposition --------------------------------
    def decomposition(self) -> dict:
        """Per-span-name summary: count / total / mean / p50 / p99 (seconds),
        quantiles through the shared Histogram helper."""
        from repro.obs.metrics import Histogram
        groups: dict = {}
        for s in self.spans:
            groups.setdefault(s.name, []).append(s.t1 - s.t0)
        out = {}
        for name, durs in groups.items():
            h = Histogram()
            h.record_many(np.asarray(durs))
            out[name] = {"count": len(durs), "total_s": float(np.sum(durs)),
                         "mean_s": float(np.mean(durs)),
                         "p50_s": h.quantile(0.50),
                         "p99_s": h.quantile(0.99)}
        return out

    def format_decomposition(self) -> str:
        """The latency-decomposition table, widest stages first."""
        rows = sorted(self.decomposition().items(),
                      key=lambda kv: -kv[1]["total_s"])
        lines = [f"{'stage':<24} {'count':>7} {'total_ms':>10} "
                 f"{'mean_ms':>9} {'p50_ms':>9} {'p99_ms':>9}"]
        for name, d in rows:
            lines.append(
                f"{name:<24} {d['count']:>7} {d['total_s'] * 1e3:>10.2f} "
                f"{d['mean_s'] * 1e3:>9.3f} {d['p50_s'] * 1e3:>9.3f} "
                f"{d['p99_s'] * 1e3:>9.3f}")
        return "\n".join(lines)


# ---- module-level tracer (the instrumentation call surface) -------------

_TRACER = NULL_TRACER


def get_tracer():
    return _TRACER


def set_tracer(tracer) -> None:
    """Install ``tracer`` as the process tracer (None → disabled)."""
    global _TRACER
    _TRACER = tracer if tracer is not None else NULL_TRACER


def enabled() -> bool:
    return _TRACER.enabled


def span(name: str):
    """The ONE hot-path entry point: ``with span("tile.build") as sp:``.
    Disabled mode returns the shared null span — no allocation."""
    return _TRACER.span(name)


def emit(name: str, t0: float, t1: float, *, track: str = "sim",
         **attrs) -> None:
    _TRACER.emit(name, t0, t1, track=track, **attrs)
