"""Graph-signal freshness monitors (DESIGN.md §15).

LinkSAGE's operational claim — "up-to-date graph signals in near
realtime" — becomes a measurable surface here:

  * **embedding-age histogram** over the live :class:`EmbeddingStore`
    tables (``now − record.time`` per live record);
  * **dirty-queue depth** and **recompute lag** (``now − earliest pending
    trigger``) gauges on the lifecycle queues;
  * **published-version lag**: ``now − published_at`` of the latest frozen
    version (stores record publish clocks when given);
  * **event→re-rank lag**: the drain staleness deltas
    (``refresh_clock − trigger_time``) as a histogram — the paper's
    freshness curve (p50/p99 seconds from a marketplace event to the
    re-ranked embedding);
  * **cache-tier hit rates** (result / feature / embed) as point gauges
    and, via :class:`~repro.obs.metrics.TimeSeries`, over time.

All functions accept a ``ShardedNearline`` cluster, an
``EmbeddingLifecycle``, or a ``NearlineInference`` and only READ state —
freshness monitoring never changes bits.
"""
from __future__ import annotations

import numpy as np

from repro.obs.metrics import Histogram, HistogramSpec, MetricsRegistry

AGE_SPEC = HistogramSpec(lo=1e-3, hi=1e6, buckets_per_decade=24)


def _lifecycles(obj) -> list:
    """Normalize cluster | lifecycle | nearline-pipeline to lifecycles."""
    if hasattr(obj, "shards"):                    # ShardedNearline
        return list(obj.shards)
    if hasattr(obj, "lifecycle"):                 # NearlineInference
        return [obj.lifecycle]
    return [obj]                                  # EmbeddingLifecycle


def default_now(obj) -> float:
    """Latest record time across the live tables (a simulated-clock run has
    no wall 'now'; ages are relative to the newest write)."""
    times = [rec.time for lc in _lifecycles(obj)
             for rec in lc.store._d.values()]
    return max(times) if times else 0.0


def embedding_age_histogram(obj, *, now: float | None = None,
                            spec: HistogramSpec | None = None) -> Histogram:
    """Histogram of ``now − computed-at`` over every live record."""
    lcs = _lifecycles(obj)
    if now is None:
        now = default_now(obj)
    h = Histogram(spec or AGE_SPEC)
    for lc in lcs:
        times = np.array([rec.time for rec in lc.store._d.values()])
        if times.size:
            h.record_many(now - times)
    return h


def _tier_rates(obj) -> dict:
    """Per-cache-tier (hits, misses, hit_rate) rollup."""

    def rate(pairs):
        h = sum(p[0] for p in pairs)
        m = sum(p[1] for p in pairs)
        return {"hits": h, "misses": m, "hit_rate": h / max(h + m, 1)}

    tiers = {}
    if hasattr(obj, "shards"):                    # cluster: real tier lists
        tiers["result"] = rate(
            [(obj.retired_cache_hits, obj.retired_cache_misses)]
            + [(c.metrics.cache_hits, c.metrics.cache_misses)
               for c in obj.caches])
        tiers["feature"] = rate([(fc.hits, fc.misses)
                                 for fc in obj.feature_caches])
        tiers["embed"] = rate([(ec.hits, ec.misses)
                               for ec in obj.embed_caches])
    else:
        lc = _lifecycles(obj)[0]
        m = lc.metrics
        tiers["result"] = rate([(m.cache_hits, m.cache_misses)])
        tiers["feature"] = rate([(m.feature_cache_hits,
                                  m.feature_cache_misses)])
        tiers["embed"] = rate([(m.embed_cache_hits, m.embed_cache_misses)])
    return tiers


def freshness_report(obj, *, now: float | None = None) -> dict:
    """The one-call freshness surface (see module docstring for fields)."""
    lcs = _lifecycles(obj)
    if now is None:
        now = default_now(obj)
    age = embedding_age_histogram(obj, now=now)
    lag = Histogram()
    for lc in lcs:
        if lc.metrics.staleness:
            lag.record_many(np.asarray(lc.metrics.staleness))
    pending = sum(len(lc.queue) for lc in lcs)
    triggers = [t for lc in lcs for t in lc.queue._trigger.values()]
    versions = [lc.store.version for lc in lcs]
    pub_ages = [now - lc.store.published_at[lc.store.version]
                for lc in lcs
                if lc.store.published_at.get(lc.store.version) is not None]
    return {
        "now": float(now),
        "live_records": age.count,
        "age_p50_s": age.quantile(0.50),
        "age_p99_s": age.quantile(0.99),
        "age_max_s": age.vmax if age.count else 0.0,
        "dirty_queue_depth": pending,
        "recompute_lag_s": (now - min(triggers)) if triggers else 0.0,
        "lag_count": lag.count,                     # event→re-rank lag
        "lag_p50_s": lag.quantile(0.50),
        "lag_p99_s": lag.quantile(0.99),
        "published_version": max(versions) if versions else 0,
        "publish_lag_s": max(pub_ages) if pub_ages else None,
        "cache_tiers": _tier_rates(obj),
    }


def format_freshness(rep: dict) -> str:
    tiers = "  ".join(
        f"{t}={d['hit_rate']:.0%} ({d['hits']}/{d['hits'] + d['misses']})"
        for t, d in rep["cache_tiers"].items())
    pub = ("n/a" if rep["publish_lag_s"] is None
           else f"{rep['publish_lag_s']:.1f}s")
    return (
        f"freshness @ t={rep['now']:.1f}s: {rep['live_records']} live "
        f"embeddings, age p50={rep['age_p50_s']:.2f}s "
        f"p99={rep['age_p99_s']:.2f}s max={rep['age_max_s']:.2f}s\n"
        f"  event->re-rank lag: p50={rep['lag_p50_s']:.2f}s "
        f"p99={rep['lag_p99_s']:.2f}s over {rep['lag_count']} refreshes; "
        f"dirty queue depth {rep['dirty_queue_depth']}, recompute lag "
        f"{rep['recompute_lag_s']:.2f}s\n"
        f"  published v{rep['published_version']} (lag {pub}); "
        f"cache hit rates: {tiers}")


def observe_freshness(reg: MetricsRegistry, obj, *,
                      now: float | None = None) -> dict:
    """Publish one freshness observation into the registry: gauges for the
    point-in-time values, the age histogram, and (t, hit-rate) /
    (t, queue-depth) time-series samples.  Returns the report."""
    if now is None:
        now = default_now(obj)
    rep = freshness_report(obj, now=now)
    for k in ("live_records", "age_p50_s", "age_p99_s", "dirty_queue_depth",
              "recompute_lag_s", "lag_p50_s", "lag_p99_s",
              "published_version"):
        reg.gauge(f"freshness.{k}").set(float(rep[k]))
    age_h = reg.histogram("freshness.embedding_age_s", spec=AGE_SPEC)
    age_h.restore(Histogram(age_h.spec).snapshot())    # mirror, not sum
    age_h.merge(embedding_age_histogram(obj, now=now))
    reg.series("freshness.dirty_queue_depth").append(
        now, rep["dirty_queue_depth"])
    for tier, d in rep["cache_tiers"].items():
        reg.gauge("freshness.cache_hit_rate", tier=tier).set(d["hit_rate"])
        reg.series("freshness.cache_hit_rate", tier=tier).append(
            now, d["hit_rate"])
    return rep
