from repro.data.synthetic_graph import (GraphGenConfig,
                                        generate_job_marketplace_graph,
                                        marketplace_event_stream)
from repro.data.lm_data import synthetic_lm_batch, SyntheticTokenStream

__all__ = [
    "GraphGenConfig",
    "generate_job_marketplace_graph",
    "marketplace_event_stream",
    "synthetic_lm_batch",
    "SyntheticTokenStream",
]
