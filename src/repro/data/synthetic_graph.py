"""Synthetic job-marketplace graph generator.

Produces a scaled-down graph whose *ratios* mimic the paper's Tables 1–2:
members ≫ jobs ≫ positions ≫ companies ≫ skills ≈ titles; members average
~1.2 top skills, jobs ~0.67; engagement edges dominate the edge census.

Ground truth: every member/job has a latent "competency" vector z ∈ R^k.
Attribute assignment and engagement both derive from z, so a model that
propagates information across the graph can recover match quality — this
gives the offline proxy benchmarks (recall@k / AUC) real signal, including a
cold-start segment of members with very few engagement edges (paper §7.2's
"members lacking predictive data").
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.graph import HeteroGraph, NODE_TYPES


@dataclass(frozen=True)
class GraphGenConfig:
    num_members: int = 2000
    num_jobs: int = 500
    num_skills: int = 120
    num_titles: int = 40
    num_companies: int = 80
    num_positions: int = 160
    latent_dim: int = 16
    feat_dim: int = 64
    # engagement density: expected positive engagements per member
    engagements_per_member: float = 3.0
    recruiter_edges_per_job: float = 0.5
    top_skills_per_member: float = 1.2   # Table 2: avg top-skill degree
    top_skills_per_job: float = 0.67
    # fraction of members in the sparse "cold-start" segment (few engagements)
    cold_start_frac: float = 0.3
    feature_noise: float = 0.3
    seed: int = 0


def _latent_cluster_assign(rng, z, num_attrs, temperature=1.0):
    """Assign each row of z to one attribute id via soft latent clustering."""
    centers = rng.normal(size=(num_attrs, z.shape[1]))
    logits = z @ centers.T / temperature
    logits += rng.gumbel(size=logits.shape)
    return logits.argmax(axis=1).astype(np.int32), centers


def generate_job_marketplace_graph(cfg: GraphGenConfig):
    """Returns (graph, truth) where truth holds latent vectors + label edges."""
    rng = np.random.default_rng(cfg.seed)
    k = cfg.latent_dim

    z_member = rng.normal(size=(cfg.num_members, k))
    z_job = rng.normal(size=(cfg.num_jobs, k))

    # --- attribute assignment from latent space --------------------------
    member_title, title_centers = _latent_cluster_assign(rng, z_member, cfg.num_titles)
    job_title, _ = _latent_cluster_assign(rng, z_job @ np.eye(k), cfg.num_titles)
    # jobs share the member title centers so titles genuinely bridge them
    job_title = (z_job @ title_centers.T + rng.gumbel(size=(cfg.num_jobs, cfg.num_titles))).argmax(1).astype(np.int32)

    member_company = rng.integers(0, cfg.num_companies, cfg.num_members).astype(np.int32)
    job_company = rng.integers(0, cfg.num_companies, cfg.num_jobs).astype(np.int32)

    # position = <company, title> tuple; build a joint id table
    pos_table = {}
    def position_id(company, title):
        key = (int(company), int(title))
        if key not in pos_table and len(pos_table) < cfg.num_positions:
            pos_table[key] = len(pos_table)
        return pos_table.get(key, hash(key) % cfg.num_positions)

    member_position = np.array([position_id(c, t) for c, t in zip(member_company, member_title)], np.int32)
    job_position = np.array([position_id(c, t) for c, t in zip(job_company, job_title)], np.int32)

    # --- top-skill edges (sparse by design, §3) ---------------------------
    skill_centers = rng.normal(size=(cfg.num_skills, k))

    def top_skill_edges(z, avg_per_node):
        n = z.shape[0]
        # Bernoulli on the best-matching skill, binomial extras
        affinity = z @ skill_centers.T
        best = affinity.argmax(1)
        keep = rng.random(n) < min(avg_per_node, 1.0)
        src = np.nonzero(keep)[0]
        dst = best[keep]
        extra = max(avg_per_node - 1.0, 0.0)
        if extra > 0:
            second = np.argsort(-affinity, axis=1)[:, 1]
            keep2 = rng.random(n) < extra
            src = np.concatenate([src, np.nonzero(keep2)[0]])
            dst = np.concatenate([dst, second[keep2]])
        return src.astype(np.int32), dst.astype(np.int32)

    m_skill_src, m_skill_dst = top_skill_edges(z_member, cfg.top_skills_per_member)
    j_skill_src, j_skill_dst = top_skill_edges(z_job, cfg.top_skills_per_job)

    # --- engagement edges (ground-truth match function) -------------------
    # score(m, j) combines latent similarity with attribute agreement
    def match_logit(mi, ji):
        sim = (z_member[mi] * z_job[ji]).sum(-1) / np.sqrt(k)
        bonus = 0.75 * (member_title[mi] == job_title[ji]) + 0.5 * (member_company[mi] == job_company[ji])
        return sim + bonus

    num_cold = int(cfg.num_members * cfg.cold_start_frac)
    cold_members = rng.permutation(cfg.num_members)[:num_cold]
    is_cold = np.zeros(cfg.num_members, bool)
    is_cold[cold_members] = True

    eng_src, eng_dst = [], []
    jobs_all = np.arange(cfg.num_jobs)
    for m in range(cfg.num_members):
        lam = cfg.engagements_per_member * (0.15 if is_cold[m] else 1.0)
        n_eng = rng.poisson(lam)
        if n_eng == 0:
            continue
        cand = rng.choice(jobs_all, size=min(64, cfg.num_jobs), replace=False)
        logit = match_logit(np.full(cand.shape, m), cand)
        top = cand[np.argsort(-logit)[:n_eng]]
        eng_src.extend([m] * len(top))
        eng_dst.extend(top.tolist())
    eng_src = np.array(eng_src, np.int32)
    eng_dst = np.array(eng_dst, np.int32)

    # recruiter interactions job→member (sparser, Table 2: 26M vs 2.7B)
    rec_src, rec_dst = [], []
    for j in range(cfg.num_jobs):
        n_rec = rng.poisson(cfg.recruiter_edges_per_job)
        if n_rec == 0:
            continue
        cand = rng.choice(cfg.num_members, size=min(64, cfg.num_members), replace=False)
        logit = match_logit(cand, np.full(cand.shape, j))
        top = cand[np.argsort(-logit)[:n_rec]]
        rec_src.extend([j] * len(top))
        rec_dst.extend(top.tolist())
    rec_src = np.array(rec_src, np.int32)
    rec_dst = np.array(rec_dst, np.int32)

    # --- node input features ----------------------------------------------
    d = cfg.feat_dim
    proj_m = rng.normal(size=(k, d)) / np.sqrt(k)
    proj_j = rng.normal(size=(k, d)) / np.sqrt(k)

    def feats(z, proj):
        x = z @ proj + cfg.feature_noise * rng.normal(size=(z.shape[0], d))
        return x.astype(np.float32)

    features = {
        "member": feats(z_member, proj_m),
        "job": feats(z_job, proj_j),
        "skill": feats(skill_centers, proj_m),
        "title": feats(title_centers, proj_m),
        "company": cfg.feature_noise * rng.normal(size=(cfg.num_companies, d)).astype(np.float32),
        "position": cfg.feature_noise * rng.normal(size=(cfg.num_positions, d)).astype(np.float32),
    }

    graph = HeteroGraph(
        num_nodes={
            "member": cfg.num_members, "job": cfg.num_jobs, "skill": cfg.num_skills,
            "title": cfg.num_titles, "company": cfg.num_companies, "position": cfg.num_positions,
        },
        features=features,
    )
    mem_ids = np.arange(cfg.num_members, dtype=np.int32)
    job_ids = np.arange(cfg.num_jobs, dtype=np.int32)
    graph.add_edges("member", "title", mem_ids, member_title, reciprocal=True)
    graph.add_edges("member", "company", mem_ids, member_company, reciprocal=True)
    graph.add_edges("member", "position", mem_ids, member_position, reciprocal=True)
    graph.add_edges("member", "skill", m_skill_src, m_skill_dst, reciprocal=True)
    graph.add_edges("job", "title", job_ids, job_title, reciprocal=True)
    graph.add_edges("job", "company", job_ids, job_company, reciprocal=True)
    graph.add_edges("job", "position", job_ids, job_position, reciprocal=True)
    graph.add_edges("job", "skill", j_skill_src, j_skill_dst, reciprocal=True)
    graph.add_edges("member", "job", eng_src, eng_dst)
    graph.add_edges("job", "member", rec_src, rec_dst)

    truth = {
        "z_member": z_member,
        "z_job": z_job,
        "member_title": member_title,
        "job_title": job_title,
        "member_company": member_company,
        "job_company": job_company,
        "is_cold": is_cold,
        "engagements": (eng_src, eng_dst),
        "match_logit": match_logit,
    }
    return graph, truth


def strip_skill_nodes(graph: HeteroGraph) -> HeteroGraph:
    """Ablation graph for the §3 skill-node study: drop all skill edges."""
    g = HeteroGraph(num_nodes=dict(graph.num_nodes), features=dict(graph.features))
    g.adj = {k: v for k, v in graph.adj.items() if "skill" not in k}
    return g


def marketplace_event_stream(graph, rng, n, *, job_every: int = 16,
                             attrs=("title", "company"),
                             zipf: float | None = None):
    """THE synthetic §5.2 event mix every bench/test/launcher replay uses:
    every ``job_every``-th event posts a fresh job (random features + one
    attribute edge per name in ``attrs``), the rest are random member→job
    engagements.  One definition, so workload arms differ only by their
    (n, job_every, attrs, zipf) parameters — never by drifting payload
    shapes.

    ``zipf`` skews engagement endpoints power-law (pmf ∝ 1/rank^zipf over a
    node-id permutation — the Signal Integration System access pattern that
    makes the §11 hot-node caches pay): ``None`` keeps the original uniform
    draws bit-for-bit (the uniform path's draw order is untouched).
    """
    from repro.core.nearline import Event   # lazy: data stays core-free

    def skewed(num: int):
        # draw a zipf rank (rejection on the unbounded tail), then map rank
        # -> node id through a per-stream permutation so the hot set is not
        # just the low ids (which bootstrap graphs treat specially)
        perm = rng.permutation(num)
        def draw():
            while True:
                r = int(rng.zipf(zipf))
                if r <= num:
                    return int(perm[r - 1])
        return draw

    if zipf is not None:
        draw_member = skewed(graph.num_nodes["member"])
        draw_job = skewed(graph.num_nodes["job"])
    else:
        draw_member = lambda: int(rng.integers(0, graph.num_nodes["member"]))
        draw_job = lambda: int(rng.integers(0, graph.num_nodes["job"]))

    events = []
    base_job = graph.num_nodes["job"]
    for i in range(n):
        if i % job_every == 0:
            payload = {"job_id": base_job + i,
                       "features": rng.normal(size=graph.feat_dim).astype(np.float32)}
            for a in attrs:
                payload[a] = int(rng.integers(0, graph.num_nodes[a]))
            events.append(Event(time=float(i), kind="job_created",
                                payload=payload))
        else:
            events.append(Event(time=float(i), kind="engagement", payload={
                "member_id": draw_member(), "job_id": draw_job()}))
    return events
