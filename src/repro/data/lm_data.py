"""Synthetic token pipeline for the assigned LM architectures.

A Zipfian n-gram-ish stream gives the loss a learnable structure (bigram
statistics) so a few hundred training steps show a clearly decreasing loss —
enough to validate the end-to-end driver without real corpora.
"""
from __future__ import annotations

import numpy as np


class SyntheticTokenStream:
    def __init__(self, vocab_size: int, *, seed: int = 0, order: int = 2,
                 branching: int = 8):
        self.vocab = vocab_size
        rng = np.random.default_rng(seed)
        # sparse bigram transition table: each token can be followed by
        # `branching` likely successors
        self.next_tokens = rng.integers(0, vocab_size, size=(vocab_size, branching))
        self.rng = rng

    def sample(self, batch: int, seq_len: int) -> np.ndarray:
        out = np.empty((batch, seq_len + 1), np.int32)
        cur = self.rng.integers(0, self.vocab, size=batch)
        out[:, 0] = cur
        for t in range(1, seq_len + 1):
            explore = self.rng.random(batch) < 0.1
            choice = self.rng.integers(0, self.next_tokens.shape[1], size=batch)
            nxt = self.next_tokens[cur, choice]
            rand = self.rng.integers(0, self.vocab, size=batch)
            cur = np.where(explore, rand, nxt)
            out[:, t] = cur
        return out


def synthetic_lm_batch(vocab_size: int, batch: int, seq_len: int, *, seed: int = 0):
    """One (tokens, labels) pair: labels are next-token shifted inputs."""
    stream = SyntheticTokenStream(vocab_size, seed=seed)
    toks = stream.sample(batch, seq_len)
    return toks[:, :-1], toks[:, 1:]
