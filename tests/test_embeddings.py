"""The versioned embedding lifecycle (DESIGN.md §9): store versioning,
staleness policy, dirty closure, the priority recompute queue, and the
sweep-vs-incremental bit-parity contract."""
import numpy as np
import jax
import pytest
from dataclasses import replace

from repro.configs.linksage import smoke as gnn_smoke
from repro.core import encoder as enc
from repro.core.embeddings import (EmbeddingLifecycle, EmbeddingRecord,
                                   EmbeddingStore, RecomputeQueue,
                                   StalenessPolicy, node_uniform_slab,
                                   tables_bitwise_equal)
from repro.core.nearline import Event, NearlineInference
from repro.data import (GraphGenConfig, generate_job_marketplace_graph,
                        marketplace_event_stream)


@pytest.fixture(scope="module")
def setup():
    g, truth = generate_job_marketplace_graph(
        GraphGenConfig(num_members=120, num_jobs=40, seed=5))
    cfg = replace(gnn_smoke(), feat_dim=g.feat_dim)
    params = enc.encoder_init(jax.random.PRNGKey(0), cfg)
    return g, cfg, params


def _event_stream(g, rng, n=60):
    """Engagements + fresh job postings (the two §5.2 trigger kinds)."""
    return marketplace_event_stream(g, rng, n, job_every=12,
                                    attrs=("title", "skill"))


# ----------------------------------------------------------------- store


def test_store_versioning_and_gather():
    st = EmbeddingStore("t")
    st.put_embedding("job", 1, np.ones(4, np.float32), 1.0)
    rec = st.record("job", 1)
    assert isinstance(rec, EmbeddingRecord)
    assert rec.version == 1 and rec.time == 1.0       # in-flight toward v1
    assert st.get_embedding("job", 1)[1] == 1.0       # legacy (emb, t) view
    v1 = st.publish()
    assert v1 == 1 and st.published_versions() == [1]
    # live writes after publish do not mutate the frozen table
    st.put_embedding("job", 1, 2 * np.ones(4, np.float32), 2.0)
    assert np.all(st.table(1)[("job", 1)].emb == 1.0)
    got = st.gather("job", [1], version=1)
    assert got.shape == (1, 4) and np.all(got == 1.0)


def test_store_gather_is_leakage_safe():
    """Reads require an explicit PUBLISHED version; unpublished versions and
    nodes missing from the version are hard errors."""
    st = EmbeddingStore("t")
    st.put_embedding("job", 1, np.ones(4, np.float32), 1.0)
    with pytest.raises(KeyError):
        st.gather("job", [1], version=1)              # not published yet
    st.publish()
    with pytest.raises(KeyError):
        st.gather("job", [2], version=1)              # node not in v1
    assert st.gather("job", [1], version=1).shape == (1, 4)


def test_tables_bitwise_equal_comparator():
    a = {("job", 1): np.float32([1.0, 2.0])}
    assert tables_bitwise_equal(a, {("job", 1): np.float32([1.0, 2.0])})
    assert not tables_bitwise_equal(
        a, {("job", 1): np.float32([1.0, np.nextafter(np.float32(2.0),
                                                      np.float32(3.0))])})
    assert not tables_bitwise_equal(a, {})


# --------------------------------------------------------------- queue


def test_recompute_queue_priority_and_dedup():
    q = RecomputeQueue()
    pol = StalenessPolicy()
    q.push(("member", 1), pol.priority("member", 5.0), 5.0)
    q.push(("job", 2), pol.priority("job", 5.0), 5.0)
    q.push(("job", 3), pol.priority("job", 1.0), 1.0)
    # re-push of an existing key keeps the EARLIEST trigger
    q.push(("job", 2), pol.priority("job", 0.5), 0.5)
    assert len(q) == 3
    batch = q.pop_batch(2)
    # oldest trigger first; jobs outrank members at equal time
    assert batch[0] == (("job", 2), 0.5)
    assert batch[1] == (("job", 3), 1.0)
    assert q.pop_batch(10) == [(("member", 1), 5.0)]
    assert len(q) == 0 and q.pop_batch(4) == []


def test_recompute_queue_repush_after_pop_keeps_order():
    """Regression: a key re-pushed AFTER being popped must rank at its new
    priority — stale heap entries from before the pop must not resurface
    it ahead of genuinely older dirt."""
    q = RecomputeQueue()
    pol = StalenessPolicy()
    q.push(("job", 1), pol.priority("job", 1.0), 1.0)
    q.push(("job", 1), pol.priority("job", 2.0), 2.0)   # stale entry stays
    assert q.pop_batch(1) == [(("job", 1), 1.0)]
    q.push(("job", 9), pol.priority("job", 10.0), 10.0)
    q.push(("job", 1), pol.priority("job", 50.0), 50.0)
    assert q.pop_batch(2) == [(("job", 9), 10.0), (("job", 1), 50.0)]


def test_recompute_queue_drain_while_marking_no_drop_no_double():
    """Concurrent-style interleaving: new dirty marks land BETWEEN drain
    batches (including re-marks of already-popped and still-queued keys).
    Every key is processed at least once after its last mark, and never
    twice for one mark."""
    q = RecomputeQueue()
    pol = StalenessPolicy()
    for i in range(6):
        q.push(("job", i), pol.priority("job", float(i)), float(i))
    processed = []
    # batch 1 pops jobs 0,1; between batches jobs 6,7 arrive, job 2 (still
    # queued) is re-marked older->newer, and job 0 (already popped) re-dirties
    processed += q.pop_batch(2)
    q.push(("job", 6), pol.priority("job", 0.5), 0.5)
    q.push(("job", 2), pol.priority("job", 9.0), 9.0)     # dup of queued key
    q.push(("job", 0), pol.priority("job", 10.0), 10.0)   # re-dirty popped key
    while len(q):
        processed += q.pop_batch(2)
    keys = [k for k, _ in processed]
    # no drops: every marked key appears; job 0 exactly twice (two marks
    # separated by a pop), job 2 exactly once (dedup of the double mark)
    assert sorted(set(keys)) == [("job", i) for i in range(7)]
    assert keys.count(("job", 0)) == 2
    assert keys.count(("job", 2)) == 1
    # the queued dup kept its EARLIEST trigger
    assert dict(processed)[("job", 2)] == 2.0
    assert len(q) == 0


def test_recompute_queue_interleaved_triggers_order():
    """Marks arriving mid-drain sort against surviving dirt by priority,
    not arrival: an older-trigger late mark is served before newer dirt."""
    q = RecomputeQueue()
    pol = StalenessPolicy()
    q.push(("member", 1), pol.priority("member", 5.0), 5.0)
    q.push(("member", 2), pol.priority("member", 6.0), 6.0)
    assert q.pop_batch(1) == [(("member", 1), 5.0)]
    q.push(("member", 3), pol.priority("member", 1.0), 1.0)  # late, older
    assert [k for k, _ in q.pop_batch(2)] == [("member", 3), ("member", 2)]


def test_lifecycle_drain_interleaved_with_marks_converges(setup):
    """End-to-end interleaving through the lifecycle: capped drains with
    fresh dirt arriving between them neither drop nor double-process, and
    the final table matches an uninterleaved pipeline bit-for-bit."""
    g, cfg, params = setup
    events = _event_stream(g, np.random.default_rng(21), n=24)
    policy = StalenessPolicy(closure_radius=None)

    inter = NearlineInference(cfg, params, micro_batch=4, seed=3, policy=policy)
    inter.bootstrap_from_graph(g)
    for i, ev in enumerate(events):
        inter.topic.publish(ev)
        inter.ingest(max_events=1)                 # mark while queue nonempty
        if i % 3 == 0:
            inter.lifecycle.drain(clock=ev.time, max_nodes=6)  # partial drain
    inter.lifecycle.drain(clock=99.0)

    plain = NearlineInference(cfg, params, micro_batch=4, seed=3, policy=policy)
    plain.bootstrap_from_graph(g)
    for ev in events:
        plain.topic.publish(ev)
    plain.ingest()
    plain.lifecycle.drain(clock=99.0)
    assert tables_bitwise_equal(inter.embedding_store.live_embeddings(),
                                plain.embedding_store.live_embeddings())


def test_staleness_policy_radius_and_priority():
    assert StalenessPolicy().radius(2) == 0
    assert StalenessPolicy(closure_radius=None).radius(3) == 3
    assert StalenessPolicy(closure_radius=1).radius(3) == 1
    pol = StalenessPolicy()
    assert pol.priority("job", 1.0) < pol.priority("member", 1.0)
    assert pol.priority("member", 1.0) < pol.priority("job", 2.0)


# ------------------------------------------------------------ lifecycle


def test_per_node_uniform_slabs_are_order_independent():
    a = node_uniform_slab(7, "member", 3, 20)
    assert np.array_equal(a, node_uniform_slab(7, "member", 3, 20))
    assert not np.array_equal(a, node_uniform_slab(7, "member", 4, 20))
    assert not np.array_equal(a, node_uniform_slab(8, "member", 3, 20))


def test_dirty_closure_radius(setup):
    g, cfg, params = setup
    nl = NearlineInference(cfg, params, seed=0,
                           policy=StalenessPolicy(closure_radius=None))
    nl.bootstrap_from_graph(g)
    lc = nl.lifecycle
    # radius 0 == the touched node itself
    lc.policy = StalenessPolicy(closure_radius=0)
    assert lc.dirty_closure({("member", 3)}) == {("member", 3)}
    # radius K grows monotonically and stays a superset
    lc.policy = StalenessPolicy(closure_radius=1)
    c1 = lc.dirty_closure({("member", 3)})
    lc.policy = StalenessPolicy(closure_radius=None)   # K = len(fanouts)
    cK = lc.dirty_closure({("member", 3)})
    assert {("member", 3)} < c1 <= cK
    # closure contains exactly the reverse-reachable ball: every node with
    # an edge INTO member 3 is in c1
    assert lc._rev[("member", 3)] <= c1


def test_drain_writes_inflight_records_and_staleness(setup):
    g, cfg, params = setup
    nl = NearlineInference(cfg, params, micro_batch=16, seed=0)
    nl.bootstrap_from_graph(g)
    nl.topic.publish(Event(time=4.0, kind="engagement",
                           payload={"member_id": 2, "job_id": 3}))
    nl.process(clock=6.5)
    rec = nl.embedding_store.record("job", 3)
    assert rec.version == 1 and rec.time == 6.5       # toward 1st publish
    assert nl.metrics.staleness[-2:] == [2.5, 2.5]    # 6.5 - 4.0, both ends
    v = nl.lifecycle.publish_version(clock=7.0)
    assert v == 1
    assert nl.embedding_store.record("job", 3).version == 1


def test_drain_order_does_not_change_bits(setup):
    """Two pipelines, same events in different micro-batch groupings, end
    with bit-identical live embeddings — per-node uniform streams plus the
    full dependency closure (radius 0 is only eventually-consistent: a
    node's last recompute could predate a neighbor-ring change)."""
    g, cfg, params = setup
    rng = np.random.default_rng(2)
    events = _event_stream(g, rng, n=24)

    def run(micro):
        nl = NearlineInference(cfg, params, micro_batch=micro, seed=9,
                               policy=StalenessPolicy(closure_radius=None))
        nl.bootstrap_from_graph(g)
        for ev in events:
            nl.topic.publish(ev)
        nl.process()
        return nl.embedding_store.live_embeddings()

    assert tables_bitwise_equal(run(4), run(16))


def test_publish_sweep_covers_registry_and_new_nodes(setup):
    g, cfg, params = setup
    nl = NearlineInference(cfg, params, micro_batch=32, seed=0)
    nl.bootstrap_from_graph(g)
    new_job = g.num_nodes["job"] + 7
    nl.topic.publish(Event(time=1.0, kind="job_created", payload={
        "job_id": new_job, "features": np.ones(g.feat_dim, np.float32),
        "title": 1}))
    nl.ingest()
    v = nl.lifecycle.publish_version(clock=2.0)
    table = nl.embedding_store.table(v)
    assert len(table) == sum(g.num_nodes.values()) + 1
    assert ("job", new_job) in table
    assert nl.lifecycle.pending() == 0                 # sweep supersedes dirt


def test_ageout_policy_recomputes_without_events(setup):
    g, cfg, params = setup
    nl = NearlineInference(cfg, params, micro_batch=64, seed=0,
                           policy=StalenessPolicy(max_staleness_s=10.0))
    nl.bootstrap_from_graph(g)
    nl.topic.publish(Event(time=0.0, kind="engagement",
                           payload={"member_id": 0, "job_id": 0}))
    nl.process(clock=1.0)
    t0 = nl.embedding_store.record("job", 0).time
    # a later unrelated event, processed past the age-out horizon, drags the
    # stale record back through the queue
    nl.topic.publish(Event(time=20.0, kind="engagement",
                           payload={"member_id": 5, "job_id": 6}))
    nl.process(clock=22.0)
    assert nl.embedding_store.record("job", 0).time == 22.0 != t0


# ------------------------------------------------- the parity contract


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_sweep_vs_incremental_bit_parity(setup, seed):
    """THE §9 contract: over one event stream, incremental dirty-closure
    drains converge to a live table bit-identical to one offline full sweep
    at the final graph state."""
    g, cfg, params = setup
    events = _event_stream(g, np.random.default_rng((seed, 1)), n=60)
    policy = StalenessPolicy(closure_radius=None)

    def make():
        nl = NearlineInference(cfg, params, micro_batch=8, seed=13,
                               policy=policy)
        nl.bootstrap_from_graph(g)
        nl.lifecycle.publish_version(clock=0.0)   # shared v1 baseline
        for ev in events:
            nl.topic.publish(ev)
        return nl

    inc = make()
    inc.process()                                  # drain per micro-batch
    off = make()
    off.ingest()                                   # apply all, no recompute
    v = off.lifecycle.publish_version(clock=99.0)  # one sweep at final state

    assert v == 2
    assert tables_bitwise_equal(inc.embedding_store.live_embeddings(),
                                off.embedding_store.table(v))


def test_offline_batch_publish_mode_produces_versions(setup):
    from repro.core.nearline import OfflineBatchInference
    g, cfg, params = setup
    nl = NearlineInference(cfg, params, micro_batch=64, seed=0)
    nl.bootstrap_from_graph(g)
    off = OfflineBatchInference(nl, period_s=10.0, mode="publish")
    for i in range(4):
        nl.topic.publish(Event(time=2.0 + 10.0 * i, kind="engagement",
                               payload={"member_id": i, "job_id": i}))
    ran = off.maybe_run(now=25.0)                  # two day boundaries
    assert ran == 2                                # events at t=2, t=12 only
    assert nl.embedding_store.published_versions() == [1, 2]
    # boundary tables differ where the second window touched the graph
    # (the t=12 engagement grew job 1's ring between v1 and v2)
    t1, t2 = nl.embedding_store.table(1), nl.embedding_store.table(2)
    assert not np.array_equal(t1[("job", 1)].emb, t2[("job", 1)].emb)


def test_trainer_embed_nodes_writes_store(setup):
    from repro.core.linksage import LinkSAGETrainer
    g, cfg, params = setup
    tr = LinkSAGETrainer(cfg, g, seed=0)
    store = EmbeddingStore("trainer-out")
    emb = tr.embed_nodes("member", np.arange(10), store=store, clock=3.0)
    assert len(store) == 10
    rec = store.record("member", 4)
    assert np.array_equal(rec.emb, emb[4]) and rec.time == 3.0
    v = store.publish()
    assert np.array_equal(store.gather("member", [4], version=v)[0], emb[4])
