import os
import sys

# tests must see ONE device (the dry-run sets 512 itself, in its own process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def make_parity_case(seed, *, num_events=40, max_neighbors=64, feat_dim=8):
    """Random small hetero graph + a random engagement-event suffix.

    Returns ``(snapshot_final_graph, streaming_engine)``: the streaming
    engine is bootstrapped from the BASE graph and then fed the suffix via
    ``add_edge``; the snapshot graph is built directly from base+suffix
    edge lists (suffix appended per relation, matching ring append order).
    Per-(relation, src) degree is capped below ``max_neighbors`` by
    construction so no ring evicts — the regime where the engine contract
    promises bit-identical sampling (DESIGN.md §8).
    """
    import numpy as np

    from repro.core.engine import StreamingEngine
    from repro.core.graph import NODE_TYPES, HeteroGraph

    rng = np.random.default_rng((seed, 0xE7))
    num_nodes = {t: 1 for t in NODE_TYPES}
    num_nodes["member"] = int(rng.integers(12, 48))
    num_nodes["job"] = int(rng.integers(6, 24))
    num_nodes["skill"] = int(rng.integers(3, 9))
    features = {t: rng.normal(size=(num_nodes[t], feat_dim)).astype(np.float32)
                for t in NODE_TYPES}
    rels = [("member", "job"), ("job", "member"),
            ("member", "skill"), ("skill", "member")]
    deg: dict = {}

    def admit(rel, s, d, out):
        if deg.get((rel, s), 0) < max_neighbors - 1:
            deg[(rel, s)] = deg.get((rel, s), 0) + 1
            out.append((s, d))

    base = {rel: [] for rel in rels}
    for rel in rels:
        s_t, d_t = rel
        for _ in range(int(rng.integers(5, 70))):
            admit(rel, int(rng.integers(0, num_nodes[s_t])),
                  int(rng.integers(0, num_nodes[d_t])), base[rel])
    suffix = {rel: [] for rel in rels}
    for _ in range(num_events):
        m = int(rng.integers(0, num_nodes["member"]))
        j = int(rng.integers(0, num_nodes["job"]))
        admit(("member", "job"), m, j, suffix[("member", "job")])
        admit(("job", "member"), j, m, suffix[("job", "member")])

    def graph_of(edge_lists):
        g = HeteroGraph(num_nodes=dict(num_nodes),
                        features={t: f.copy() for t, f in features.items()})
        for rel in rels:
            pairs = edge_lists[rel] or [(0, 0)]   # keep every relation present
            src = np.array([s for s, _ in pairs])
            dst = np.array([d for _, d in pairs])
            g.add_edges(rel[0], rel[1], src, dst)
        return g

    streaming = StreamingEngine(feat_dim, max_neighbors=max_neighbors)
    streaming.bootstrap_from_graph(graph_of(base))
    for rel in rels:
        for s, d in suffix[rel]:
            streaming.add_edge(rel[0], s, rel[1], d)
    final = graph_of({rel: base[rel] + suffix[rel] for rel in rels})
    return final, streaming


def assert_tiles_equal(ta, tb, msg=""):
    """Bit-exact equality of two K-hop ComputeGraphBatch tiles."""
    import numpy as np

    assert len(ta.masks) == len(tb.masks)
    for name, hop_a, hop_b in zip(ta._fields, ta, tb):
        for k, (a, b) in enumerate(zip(hop_a, hop_b)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{msg}{name}[{k}]")
