"""The online serving subsystem (DESIGN.md §10): sharded cluster parity
vs the single-engine nearline path, dynamic batching policy, the version-
pinned result cache, scatter-gather routing, and the open-loop SLO
harness."""
import numpy as np
import jax
import pytest
from dataclasses import replace

from repro.configs.linksage import smoke as gnn_smoke
from repro.core import encoder as enc
from repro.core.embeddings import StalenessPolicy, tables_bitwise_equal
from repro.core.nearline import Event, NearlineInference
from repro.core.partition import GraphPartitioner
from repro.serving import (BatchPolicy, DynamicBatcher, LoadConfig,
                           LoadGenerator, ResultCache, Router, ScoreRequest,
                           ShardedNearline, serve_trace, simulate_open_loop)
from repro.data import (GraphGenConfig, generate_job_marketplace_graph,
                        marketplace_event_stream)


@pytest.fixture(scope="module")
def setup():
    g, truth = generate_job_marketplace_graph(
        GraphGenConfig(num_members=120, num_jobs=40, seed=5))
    cfg = replace(gnn_smoke(), feat_dim=g.feat_dim)
    params = enc.encoder_init(jax.random.PRNGKey(0), cfg)
    return g, cfg, params


def _event_stream(g, rng, n=40):
    return marketplace_event_stream(g, rng, n, job_every=12,
                                    attrs=("title", "skill"))


def _cluster(g, cfg, params, P, *, strategy="hash", policy=None, seed=13):
    part = GraphPartitioner(P, strategy)
    if strategy == "greedy":
        part.fit(g)
    cl = ShardedNearline(cfg, params, part, micro_batch=8, seed=seed,
                         policy=policy)
    cl.bootstrap_from_graph(g)
    return cl


# ------------------------------------------------- THE §10 parity gate


@pytest.mark.parametrize("P,strategy", [(1, "hash"), (2, "hash"),
                                        (4, "hash"), (2, "greedy")])
def test_sharded_cluster_bit_parity_with_single_nearline(setup, P, strategy):
    """Same bootstrap + event stream: the union of the P shard stores is
    bit-identical to the single-engine NearlineInference live table."""
    g, cfg, params = setup
    events = _event_stream(g, np.random.default_rng(2))
    policy = StalenessPolicy(closure_radius=None)

    nl = NearlineInference(cfg, params, micro_batch=8, seed=13, policy=policy)
    nl.bootstrap_from_graph(g)
    cl = _cluster(g, cfg, params, P, strategy=strategy, policy=policy)
    for ev in events:
        nl.topic.publish(ev)
        cl.topic.publish(ev)
    nl.process()
    cl.process()
    assert tables_bitwise_equal(nl.embedding_store.live_embeddings(),
                                cl.live_embeddings())
    assert cl.pending() == nl.lifecycle.pending() == 0


def test_router_scatter_gather_matches_single_engine_bits(setup):
    """Router-resolved embeddings == single-lifecycle encode, bit for bit,
    with and without the cache in the path."""
    g, cfg, params = setup
    nl = NearlineInference(cfg, params, micro_batch=8, seed=13)
    nl.bootstrap_from_graph(g)
    cl = _cluster(g, cfg, params, 3)
    keys = [("member", 3), ("job", 7), ("member", 55), ("job", 0),
            ("member", 119)]
    golden = nl.lifecycle.encode_nodes(keys)
    router = Router(cl, cache=ResultCache(64))
    for _ in range(2):                       # second pass: all cache hits
        emb = router.resolve_embeddings(keys)
        for i, k in enumerate(keys):
            assert np.array_equal(golden[i], emb[k]), k
    assert router.cache.metrics.cache_hits == len(keys)


def test_cluster_routes_dirty_closure_keys_to_owners(setup):
    g, cfg, params = setup
    cl = _cluster(g, cfg, params, 3, policy=StalenessPolicy(closure_radius=None))
    n = cl.mark_dirty("member", 3, 1.0)
    assert n >= 1
    total_queued = sum(len(lc.queue) for lc in cl.shards)
    assert total_queued == n                 # each key on exactly one shard
    for lc in cl.shards:
        for key in lc.queue._trigger:
            assert cl.partitioner.shard_of(*key) == cl.shards.index(lc)


def test_cluster_publish_version_aligns_shards(setup):
    g, cfg, params = setup
    cl = _cluster(g, cfg, params, 2)
    v = cl.publish_version(clock=1.0)
    assert v == 1
    sizes = [len(lc.store.table(1)) for lc in cl.shards]
    assert sum(sizes) == sum(g.num_nodes.values())
    assert all(s > 0 for s in sizes)         # both shards own something


# ------------------------------------------------------------- batcher


def test_batcher_fires_when_full():
    b = DynamicBatcher(BatchPolicy(max_batch=4, max_wait_s=1.0))
    for i in range(6):
        assert b.submit(ScoreRequest(time=0.1 * i, member_id=i, job_ids=(0,)))
    assert b.full() and b.trigger_time() == pytest.approx(0.3)
    batch = b.pop_batch()
    assert [r.member_id for r in batch] == [0, 1, 2, 3]    # FIFO
    # remainder waits for its deadline
    assert not b.full()
    assert b.trigger_time() == pytest.approx(0.4 + 1.0)


def test_batcher_deadline_fires_partial_batch():
    b = DynamicBatcher(BatchPolicy(max_batch=32, max_wait_s=0.05))
    b.submit(ScoreRequest(time=1.0, member_id=0, job_ids=(0,)))
    b.submit(ScoreRequest(time=1.01, member_id=1, job_ids=(0,)))
    assert b.trigger_time() == pytest.approx(1.05)         # oldest + max_wait
    batch = b.pop_batch()
    assert len(batch) == 2 and len(b) == 0
    assert b.trigger_time() is None


def test_batcher_bounded_queue_sheds():
    b = DynamicBatcher(BatchPolicy(max_batch=4, max_wait_s=1.0, max_queue=3))
    oks = [b.submit(ScoreRequest(time=0.0, member_id=i, job_ids=(0,)))
           for i in range(5)]
    assert oks == [True, True, True, False, False]
    m = b.metrics.summary()
    assert m["submitted"] == 5 and m["shed"] == 2
    assert m["queue_depth_peak"] == 3


def test_batcher_occupancy_accounting():
    b = DynamicBatcher(BatchPolicy(max_batch=8, max_wait_s=0.0))
    for i in range(12):
        b.submit(ScoreRequest(time=float(i), member_id=i, job_ids=(0,)))
    b.pop_batch()
    b.pop_batch()
    m = b.metrics.summary()
    assert m["batches"] == 2 and m["coalesced"] == 12
    assert m["occupancy_mean"] == pytest.approx((1.0 + 0.5) / 2)
    assert m["requests_per_batch"] == 6.0


# --------------------------------------------------------------- cache


def test_result_cache_lru_and_counters():
    c = ResultCache(capacity=2)
    c.put(("job", 1), np.ones(3), version=1)
    c.put(("job", 2), 2 * np.ones(3), version=1)
    assert c.get(("job", 1), version=1) is not None        # 1 now MRU
    c.put(("job", 3), 3 * np.ones(3), version=1)           # evicts 2
    assert c.get(("job", 2), version=1) is None
    assert c.evictions == 1
    m = c.metrics
    assert m.cache_hits == 1 and m.cache_misses == 1
    assert c.hit_rate() == 0.5


def test_result_cache_version_pin_and_invalidation():
    c = ResultCache(capacity=8)
    c.put(("job", 1), np.ones(3), version=1)
    # a read pinned to a different version misses AND evicts for good
    assert c.get(("job", 1), version=2) is None
    assert ("job", 1) not in c
    c.put(("job", 2), np.ones(3), version=1)
    assert c.invalidate([("job", 2), ("job", 99)]) == 1
    assert c.invalidations == 1 and ("job", 2) not in c


def test_dirty_event_invalidates_cache_and_changes_scores(setup):
    """An engagement on a cached job drops the entry; the recomputed
    embedding differs (its ring changed) — the cache never serves stale."""
    g, cfg, params = setup
    cl = _cluster(g, cfg, params, 2)
    router = Router(cl, cache=ResultCache(256))
    key = ("job", 3)
    before = router.resolve_embeddings([key])[key].copy()
    assert key in router.cache
    for i in range(6):                       # new distinct neighbors
        cl.topic.publish(Event(time=float(i), kind="engagement",
                               payload={"member_id": 30 + i, "job_id": 3}))
    cl.ingest()                              # dirty marks → invalidation hook
    assert key not in router.cache
    after = router.resolve_embeddings([key])[key]
    assert np.max(np.abs(before - after)) > 1e-6


def test_cache_invalidation_covers_full_dependency_ball(setup):
    """Regression: cache coherence must NOT follow the recompute-policy
    radius.  Under the default endpoints-only policy, an engagement on job
    J must still invalidate cached embeddings of members whose K-hop tile
    reaches J — a hit must always equal a fresh recompute."""
    g, cfg, params = setup
    cl = _cluster(g, cfg, params, 2)          # default policy: radius 0
    # a member with a bootstrap engagement edge onto job 3 sits inside
    # job 3's reverse 1-hop ball, so its 2-hop tile can sample job 3's ring
    rev_members = [k for k in cl._rev[("job", 3)] if k[0] == "member"]
    assert rev_members, "fixture graph must have an engaged member"
    mkey = rev_members[0]
    router = Router(cl, cache=ResultCache(256))
    router.resolve_embeddings([mkey, ("job", 3)])
    assert mkey in router.cache
    cl.topic.publish(Event(time=1.0, kind="engagement",
                           payload={"member_id": 50, "job_id": 3}))
    cl.ingest()
    # policy radius 0 queued only the two endpoints...
    assert cl.pending() == 2
    # ...but the cache dropped the full dependency ball, member included
    assert mkey not in router.cache and ("job", 3) not in router.cache
    # and a cached-path resolve equals a cache-free resolve, bit for bit
    again = router.resolve_embeddings([mkey])[mkey]
    fresh = Router(cl).resolve_embeddings([mkey])[mkey]
    assert np.array_equal(again, fresh)


def test_router_close_detaches_cache_and_serve_trace_autocloses(setup):
    g, cfg, params = setup
    cl = _cluster(g, cfg, params, 2)
    cache = ResultCache(64)
    router = Router(cl, cache=cache)
    assert Router(cl, cache=cache).cache is cache   # no duplicate attach
    assert len(cl.caches) == 1
    router.close()
    assert cl.caches == []
    reqs = [ScoreRequest(time=0.0, member_id=0, job_ids=(0,))]
    _, _, r2 = serve_trace(cl, reqs, cache=ResultCache(64))
    assert cl.caches == []                          # auto-closed
    # retired caches' traffic stays in the cluster roll-up (no double count
    # when the same cache re-attaches for a replay)
    agg = cl.aggregate_metrics()
    assert agg.cache_misses == r2.cache.metrics.cache_misses > 0
    serve_trace(cl, reqs, cache=r2.cache)
    agg2 = cl.aggregate_metrics()
    assert (agg2.cache_hits + agg2.cache_misses
            == r2.cache.metrics.cache_hits + r2.cache.metrics.cache_misses)


# ------------------------------------------------------------- loadgen


def test_load_generator_is_deterministic_poisson():
    lg = LoadConfig(rate_hz=100.0, num_requests=64, candidates=3, seed=4)
    gen = LoadGenerator(lg, num_members=50, num_jobs=20)
    a, b = gen.requests(), gen.requests()
    assert [r.time for r in a] == [r.time for r in b]
    assert all(len(r.job_ids) == 3 for r in a)
    times = np.array([r.time for r in a])
    assert (np.diff(times) > 0).all()
    # mean gap ~ 1/rate (loose tolerance at n=64)
    assert 0.3 / 100 < np.mean(np.diff(times)) < 3.0 / 100


class _StubRouter:
    def __init__(self):
        self.batches = []

    def score_batch(self, requests):
        self.batches.append([r.member_id for r in requests])
        return [np.zeros(len(r.job_ids)) for r in requests]


def test_simulate_open_loop_deterministic_latencies():
    """Fixed service time → exact, hand-checkable batching + latencies."""
    reqs = [ScoreRequest(time=t, member_id=i, job_ids=(0,))
            for i, t in enumerate([0.0, 0.01, 0.02, 0.5])]
    router = _StubRouter()
    b = DynamicBatcher(BatchPolicy(max_batch=2, max_wait_s=0.1))
    rep = simulate_open_loop(router, b, reqs, slo_ms=100.0, service_s=0.05)
    # batch 1: reqs 0,1 fire full at t=0.01, done 0.06
    # batch 2: req 2 fires at deadline 0.12, done 0.17
    # batch 3: req 3 fires at deadline 0.6, done 0.65
    assert router.batches == [[0, 1], [2], [3]]
    assert rep.completed == 4 and rep.batches == 3
    np.testing.assert_allclose(sorted(rep.latencies_s),
                               sorted([0.06, 0.05, 0.15, 0.15]), atol=1e-9)
    assert rep.slo_violation_rate == pytest.approx(0.5)    # two > 100 ms
    assert rep.occupancy_mean == pytest.approx((1.0 + 0.5 + 0.5) / 3)


def test_simulate_open_loop_backlog_coalesces():
    """With the worker busy, arrivals accumulate and later batches fill."""
    reqs = [ScoreRequest(time=0.001 * i, member_id=i, job_ids=(0,))
            for i in range(10)]
    router = _StubRouter()
    b = DynamicBatcher(BatchPolicy(max_batch=4, max_wait_s=0.001))
    rep = simulate_open_loop(router, b, reqs, service_s=0.1)
    assert rep.completed == 10
    assert [len(x) for x in router.batches] == [1, 4, 4, 1]
    # open loop: queueing delay is visible in the tail
    assert rep.latency_p99_ms > rep.latency_p50_ms


def test_serve_trace_end_to_end_real_cluster(setup):
    g, cfg, params = setup
    cl = _cluster(g, cfg, params, 2)
    reqs = LoadGenerator(
        LoadConfig(rate_hz=1000.0, num_requests=24, candidates=3, seed=2),
        num_members=g.num_nodes["member"], num_jobs=g.num_nodes["job"]).requests()
    report, batcher, router = serve_trace(
        cl, reqs, policy=BatchPolicy(max_batch=8, max_wait_s=0.01),
        cache=ResultCache(512), slo_ms=200.0)
    assert report.completed == 24 and report.shed == 0
    assert report.batches == batcher.metrics.batches
    assert report.throughput_rps > 0
    assert report.latency_p99_ms >= report.latency_p95_ms >= report.latency_p50_ms
    # scores are reproducible: same trace again via a fresh router is equal
    scores_a = Router(cl).score_batch(reqs[:5])
    scores_b = Router(cl, cache=ResultCache(64)).score_batch(reqs[:5])
    for x, y in zip(scores_a, scores_b):
        assert np.array_equal(x, y)


# ------------------------------------------- shared metrics counters


def test_serving_counters_flow_into_lifecycle_summary(setup):
    g, cfg, params = setup
    cl = _cluster(g, cfg, params, 2)
    router = Router(cl, cache=ResultCache(128))
    keys = [("member", 1), ("member", 2), ("job", 5)]
    router.resolve_embeddings(keys)          # 3 misses
    router.resolve_embeddings(keys)          # 3 hits
    s = router.cache.metrics.summary()
    assert s["cache_hit_rate"] == pytest.approx(0.5)
    # queue-depth peak survives the drain
    cl.topic.publish(Event(time=0.0, kind="engagement",
                           payload={"member_id": 0, "job_id": 0}))
    cl.process()
    agg = cl.aggregate_metrics()
    assert agg.queue_depth_peak >= 1
    assert agg.nodes_refreshed >= 2
    assert cl.aggregate_metrics().summary()["queue_depth_peak"] >= 1
