"""Substrate layers: optimizer, schedules, checkpointing, nn primitives."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import nn
from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_schedule, global_norm, linear_warmup_cosine)


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    target = jnp.asarray([1.0, 2.0])

    @jax.jit
    def step(params, opt):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return adamw_update(params, g, opt, lr=0.1, weight_decay=0.0)

    for _ in range(300):
        params, opt = step(params, opt)
    np.testing.assert_allclose(params["w"], target, atol=1e-2)


def test_adamw_moments_in_fp32_for_bf16_params():
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    opt = adamw_init(params)
    assert opt.m["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4,), jnp.bfloat16)}
    p2, opt2 = adamw_update(params, g, opt, lr=1e-2)
    assert p2["w"].dtype == jnp.bfloat16
    assert opt2.v["w"].dtype == jnp.float32


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(norm), np.sqrt(90 + 160), rtol=1e-5)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-4)


def test_schedules():
    lr = cosine_schedule(1.0, 100, final_frac=0.1)
    assert abs(float(lr(jnp.asarray(0))) - 1.0) < 1e-6
    assert abs(float(lr(jnp.asarray(100))) - 0.1) < 1e-6
    wlr = linear_warmup_cosine(1.0, 10, 110)
    assert float(wlr(jnp.asarray(5))) == pytest.approx(0.5, rel=1e-5)
    assert float(wlr(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-3)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"layer": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                      "b": jnp.ones(3)},
            "step": jnp.asarray(7)}
    d = str(tmp_path)
    save_checkpoint(d, 100, tree)
    assert latest_step(d) == 100
    template = jax.tree.map(jnp.zeros_like, tree)
    restored = load_checkpoint(d, 100, template)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_structure_mismatch_raises(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, {"a": jnp.zeros(2)})
    with pytest.raises(ValueError, match="structure mismatch"):
        load_checkpoint(d, 1, {"b": jnp.zeros(2)})


def test_rmsnorm_unit_scale_property():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 64)) * 10)
    p = nn.rmsnorm_init(64)
    y = nn.rmsnorm_apply(p, x)
    rms = jnp.sqrt(jnp.mean(y.astype(jnp.float32) ** 2, axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)


def test_param_count_and_bytes():
    tree = {"w": jnp.zeros((3, 4), jnp.bfloat16), "b": jnp.zeros(4, jnp.float32)}
    assert nn.param_count(tree) == 16
    assert nn.param_bytes(tree) == 12 * 2 + 4 * 4
