"""Unified telemetry (DESIGN.md §15): histogram quantile contract, the
metrics registry on the §12 state surface, span tracing determinism, the
never-changes-bits serve-path gate, high-water-mark policy pins, and the
freshness monitors."""
import json

import jax
import numpy as np
import pytest
from dataclasses import replace

from repro.configs.linksage import smoke as gnn_smoke
from repro.core import encoder as enc
from repro.core.embeddings import StalenessPolicy, tables_bitwise_equal
from repro.core.nearline import Event, NearlineInference
from repro.core.partition import GraphPartitioner
from repro.data import (GraphGenConfig, generate_job_marketplace_graph,
                        marketplace_event_stream)
from repro.obs import (DEFAULT_SPEC, Histogram, HistogramSpec,
                       MetricsRegistry, Tracer, collect_cluster,
                       format_freshness, freshness_report, set_tracer)
from repro.obs import trace as obs_trace
from repro.serving import (BatchPolicy, DynamicBatcher, FaultInjector,
                           LoadConfig, LoadGenerator, MeshFanout, ResultCache,
                           Router, ScoreRequest, ShardedNearline,
                           load_cluster_checkpoint, restore_cluster,
                           run_with_faults, serve_trace, simulate_open_loop,
                           split_shard)


@pytest.fixture(scope="module")
def setup():
    g, truth = generate_job_marketplace_graph(
        GraphGenConfig(num_members=120, num_jobs=40, seed=5))
    cfg = replace(gnn_smoke(), feat_dim=g.feat_dim)
    params = enc.encoder_init(jax.random.PRNGKey(0), cfg)
    return g, cfg, params


@pytest.fixture(autouse=True)
def _tracer_off():
    """Every test starts and ends with the process tracer disabled."""
    set_tracer(None)
    yield
    set_tracer(None)


def _events(g, rng, n=40):
    return marketplace_event_stream(g, rng, n, job_every=12,
                                    attrs=("title", "skill"))


def _cluster(g, cfg, params, P, *, seed=13):
    cl = ShardedNearline(cfg, params, GraphPartitioner(P, "hash"),
                        micro_batch=8, seed=seed,
                        policy=StalenessPolicy(closure_radius=None))
    cl.bootstrap_from_graph(g)
    return cl


# ------------------------------------------------- histogram contract


def _bracket(vals, q, spec=DEFAULT_SPEC):
    """The documented bound: [percentile(q,'lower')/sqrt(base),
    percentile(q,'higher')*sqrt(base)]."""
    rb = np.sqrt(spec.base)
    lo = np.percentile(vals, q * 100, method="lower") / rb
    hi = np.percentile(vals, q * 100, method="higher") * rb
    return lo, hi


@pytest.mark.parametrize("q", [0.5, 0.95, 0.99])
def test_histogram_quantile_brackets_np_percentile(q):
    vals = np.random.default_rng(0).lognormal(mean=-6.0, sigma=2.0, size=3000)
    h = Histogram()
    h.record_many(vals)
    lo, hi = _bracket(vals, q)
    est = h.quantile(q)
    assert lo <= est <= hi, (q, lo, est, hi)
    assert vals.min() <= est <= vals.max()      # clamped to exact min/max


def test_histogram_edges_and_spec():
    h = Histogram()
    e = h.edges()
    assert e[0] == DEFAULT_SPEC.lo
    assert np.allclose(e[1:] / e[:-1], DEFAULT_SPEC.base)
    assert len(e) == DEFAULT_SPEC.num_buckets + 1
    assert np.isclose(e[-1], DEFAULT_SPEC.hi)


def test_histogram_under_overflow_and_empty():
    h = Histogram(HistogramSpec(lo=1e-3, hi=1e3, buckets_per_decade=8))
    assert h.quantile(0.5) == 0.0               # empty
    h.record(1e-9)                              # underflow
    h.record(1e9)                               # overflow
    assert h.count == 2
    assert h.quantile(0.0) == 1e-9              # exact vmin
    assert h.quantile(1.0) == 1e9               # exact vmax


def test_histogram_snapshot_restore_and_merge():
    rng = np.random.default_rng(1)
    a, b = rng.exponential(0.01, 500), rng.exponential(0.1, 500)
    h1, h2, whole = Histogram(), Histogram(), Histogram()
    h1.record_many(a)
    h2.record_many(b)
    whole.record_many(np.concatenate([a, b]))
    h1.merge(h2)
    assert np.array_equal(h1.counts, whole.counts)
    assert h1.quantile(0.95) == whole.quantile(0.95)
    h3 = Histogram()
    h3.restore(h1.snapshot())
    assert np.array_equal(h3.counts, h1.counts)
    assert (h3.count, h3.sum, h3.vmin, h3.vmax) == (
        h1.count, h1.sum, h1.vmin, h1.vmax)


# ------------------------------------------------- registry


def test_registry_labels_handles_and_artifact(tmp_path):
    reg = MetricsRegistry()
    c0 = reg.counter("serving.events", shard="0")
    c1 = reg.counter("serving.events", shard="1")
    assert c0 is not c1
    assert reg.counter("serving.events", shard="0") is c0   # get-or-create
    c0.inc(5)
    reg.gauge("freshness.age_p50_s").set(1.5)
    reg.histogram("lag").record_many([0.01, 0.02])
    reg.series("hit_rate", tier="result").append(1.0, 0.5)
    art = reg.to_json()
    assert art["counters"]["serving.events{shard=0}"] == 5
    assert art["histograms"]["lag"]["count"] == 2
    p = tmp_path / "metrics.json"
    reg.write(str(p))
    assert json.loads(p.read_text())["gauges"]["freshness.age_p50_s"] == 1.5


def test_registry_restore_in_place_and_prune():
    reg = MetricsRegistry()
    c = reg.counter("events")
    c.inc(5)
    snap = reg.snapshot()
    c.inc(10)
    late = reg.counter("born.after.checkpoint")
    late.inc(3)
    reg.restore(snap)
    assert c.value == 5                 # the handed-out handle stays live
    assert late.value == 0              # post-checkpoint metric pruned


# ------------------------------------------------- tracer


def test_tracer_parenting_and_chrome_schema():
    tr = Tracer(clock="tick")
    with tr.span("outer") as outer:
        with tr.span("inner") as inner:
            inner.set("rows", 3)
    tr.emit("batcher.queue_wait", 1.0, 2.5, requests=4)
    chrome = tr.to_chrome()
    evs = [e for e in chrome["traceEvents"] if e["ph"] == "X"]
    by_name = {e["name"]: e for e in evs}
    assert by_name["inner"]["args"]["parent"] == outer.span_id
    assert by_name["inner"]["args"]["rows"] == 3
    assert by_name["outer"]["args"]["parent"] == 0
    # sim-track spans render on pid 1, code spans on pid 0
    assert by_name["batcher.queue_wait"]["pid"] == 1
    assert by_name["outer"]["pid"] == 0
    assert by_name["batcher.queue_wait"]["dur"] == pytest.approx(1.5e6)
    for e in evs:
        assert {"name", "ph", "ts", "dur", "pid", "tid"} <= e.keys()


def test_tick_clock_traces_are_deterministic():
    def program(tr):
        with tr.span("a"):
            with tr.span("b"):
                pass
        with tr.span("c"):
            pass

    t1, t2 = Tracer(clock="tick"), Tracer(clock="tick")
    program(t1)
    program(t2)
    assert json.dumps(t1.to_chrome()) == json.dumps(t2.to_chrome())
    d = t1.decomposition()
    assert d["a"]["count"] == 1 and d["b"]["count"] == 1
    assert "stage" in t1.format_decomposition()


def test_null_tracer_is_shared_noop():
    set_tracer(None)
    s1 = obs_trace.span("x")
    s2 = obs_trace.span("y")
    assert s1 is s2                     # the ONE shared null span
    with s1 as sp:
        sp.set("k", 1)                  # all no-ops
    assert not obs_trace.enabled()


# ------------------------------------------------- satellite (a): SLOReport


class _FixedRouter:
    def score_batch(self, requests):
        return np.zeros((len(requests), 1))


def test_slo_report_quantiles_match_percentile_within_bucket_resolution():
    reqs = [ScoreRequest(time=i * 0.01, member_id=i, job_ids=(0,))
            for i in range(64)]
    batcher = DynamicBatcher(BatchPolicy(max_batch=4, max_wait_s=0.005))
    rep = simulate_open_loop(_FixedRouter(), batcher, reqs, slo_ms=50.0,
                             service_s=0.02)
    lat = np.asarray(rep.latencies_s)
    assert len(lat) == 64               # raw latencies stay exact
    for q, got_ms in ((0.50, rep.latency_p50_ms), (0.95, rep.latency_p95_ms),
                      (0.99, rep.latency_p99_ms)):
        lo, hi = _bracket(lat, q)
        assert lo * 1e3 <= got_ms <= hi * 1e3, (q, got_ms)
    assert rep.latency_p99_ms >= rep.latency_p95_ms >= rep.latency_p50_ms


# ------------------------------------------------- satellite (b): peak policy


def test_queue_depth_peak_survives_snapshot_restore(setup):
    """§15 policy pin: high-water marks are process-local observability
    state — snapshot() does not save them, restore() does not reset them."""
    g, cfg, params = setup
    nl = NearlineInference(cfg, params, micro_batch=8, seed=13)
    nl.bootstrap_from_graph(g)
    for ev in _events(g, np.random.default_rng(3)):
        nl.topic.publish(ev)
    nl.process()
    peak = nl.lifecycle.metrics.queue_depth_peak
    assert peak > 0
    snap = nl.lifecycle.snapshot()
    assert "metrics" not in snap        # peaks are NOT on the bits surface
    nl.lifecycle.restore(snap)
    assert nl.lifecycle.metrics.queue_depth_peak == peak   # warm: kept


def test_batcher_peak_survives_snapshot_restore():
    b = DynamicBatcher(BatchPolicy(max_batch=8))
    for i in range(3):
        b.submit(ScoreRequest(time=float(i) * 1e-4, member_id=i, job_ids=(0,)))
    assert b.metrics.queue_depth_peak == 3
    b.restore(b.snapshot())
    assert b.metrics.queue_depth_peak == 3     # restore only rebuilds queue
    assert len(b) == 3


def test_reshard_carries_peaks(setup):
    g, cfg, params = setup
    cl = _cluster(g, cfg, params, 2)
    for ev in _events(g, np.random.default_rng(4)):
        cl.topic.publish(ev)
    cl.process()
    before = [lc.metrics.queue_depth_peak for lc in cl.shards]
    assert max(before) > 0
    split_shard(cl, 0)
    after = [lc.metrics.queue_depth_peak for lc in cl.shards[:2]]
    # never reset by a reshard; migration may only raise them
    assert all(a >= b for a, b in zip(after, before))


# ------------------------------------------------- satellite (c): registry
# counters across warm rollback and cold restart


def _counter_state(reg):
    js = reg.to_json()
    return (js["counters"],
            js["histograms"]["serving.event_to_rerank_lag_s"]["count"],
            js["histograms"]["serving.event_to_rerank_lag_s"]["buckets"])


def test_registry_counters_no_double_count_across_faults(setup, tmp_path):
    g, cfg, params = setup
    events = _events(g, np.random.default_rng(7), n=32)

    # golden arm: uninterrupted
    gold = _cluster(g, cfg, params, 2)
    reg_gold = MetricsRegistry()
    gold.attach_registry(reg_gold)
    for ev in events:
        gold.topic.publish(ev)
    gold.process()

    # warm arm: kills + rollback + replay, same registry throughout
    warm = _cluster(g, cfg, params, 2)
    reg_warm = MetricsRegistry()
    warm.attach_registry(reg_warm)
    for ev in events:
        warm.topic.publish(ev)
    st = run_with_faults(warm, injector=FaultInjector(kill_at=(1, 4)),
                         checkpoint_every=2)
    assert st["kills"] == 2 and st["replayed"] > 0
    assert _counter_state(reg_warm) == _counter_state(reg_gold)
    assert tables_bitwise_equal(gold.live_embeddings(),
                                warm.live_embeddings())

    # cold arm: a fresh cluster + FRESH registry restore the mid-stream
    # disk checkpoint (which re-seeds the counters) and replay the suffix
    crash = _cluster(g, cfg, params, 2)
    reg_crash = MetricsRegistry()
    crash.attach_registry(reg_crash)
    for ev in events:
        crash.topic.publish(ev)
    run_with_faults(crash, injector=FaultInjector(kill_at=(2,)),
                    checkpoint_every=2, directory=str(tmp_path))
    reg_cold = MetricsRegistry()
    cold = restore_cluster(load_cluster_checkpoint(str(tmp_path)),
                           cfg=cfg, params=params, topic=crash.topic,
                           registry=reg_cold)
    cold.process()
    assert _counter_state(reg_cold) == _counter_state(reg_gold)
    assert tables_bitwise_equal(gold.live_embeddings(),
                                cold.live_embeddings())


# ------------------------------------------------- the §15 acceptance gate:
# telemetry never changes bits on the serve path


def _serve_arm(g, cfg, params, P, *, instrument, mesh=False):
    if instrument:
        tracer = Tracer(clock="tick")
        set_tracer(tracer)
        reg = MetricsRegistry()
    try:
        cl = _cluster(g, cfg, params, P)
        if instrument:
            cl.attach_registry(reg)
        fanout = None
        if mesh:
            fanout = MeshFanout(cl)
            cl.attach_mesh(fanout)
        for ev in _events(g, np.random.default_rng(11)):
            cl.topic.publish(ev)
        cl.process()
        reqs = LoadGenerator(
            LoadConfig(rate_hz=400.0, num_requests=48, candidates=4, seed=3),
            num_members=120, num_jobs=40).requests()
        report, _, router = serve_trace(
            cl, reqs, policy=BatchPolicy(max_batch=8, max_wait_s=0.01),
            cache=ResultCache(128), service_s=0.004, mesh=fanout)
        probe = [("member", 3), ("job", 7), ("member", 55), ("job", 0)]
        resolved = Router(cl, mesh=fanout).resolve_embeddings(probe)
        live = cl.live_embeddings()
    finally:
        if instrument:
            set_tracer(None)
    spans = tracer.spans if instrument else []
    return live, resolved, report.latencies_s, spans


@pytest.mark.parametrize("P,mesh", [(1, False), (2, False), (4, False),
                                    (2, True)])
def test_telemetry_never_changes_bits_on_serve_path(setup, P, mesh):
    g, cfg, params = setup
    live0, res0, lat0, _ = _serve_arm(g, cfg, params, P, instrument=False,
                                      mesh=mesh)
    live1, res1, lat1, spans = _serve_arm(g, cfg, params, P, instrument=True,
                                          mesh=mesh)
    assert tables_bitwise_equal(live0, live1)
    assert lat0 == lat1
    for k in res0:
        assert np.array_equal(res0[k], res1[k])
    names = {s.name for s in spans}
    assert {"batcher.queue_wait", "tile.build", "encode.stage",
            "encode.dispatch", "drain.batch", "nearline.batch",
            "router.score_batch", "serve.batch"} <= names
    # the exchange stage is present in BOTH arms (§13 oracle naming)
    assert "router.exchange" in names or "mesh.exchange" in names


# ------------------------------------------------- freshness + rollup


def test_freshness_report_fields_and_format(setup):
    g, cfg, params = setup
    nl = NearlineInference(cfg, params, micro_batch=8, seed=13)
    nl.bootstrap_from_graph(g)
    for ev in _events(g, np.random.default_rng(9)):
        nl.topic.publish(ev)
    nl.process()
    nl.lifecycle.publish_version(clock=100.0)
    rep = freshness_report(nl, now=110.0)
    assert rep["live_records"] > 0
    assert rep["dirty_queue_depth"] == 0         # full-drain regime
    assert rep["lag_count"] > 0                  # event→re-rank samples
    assert 0 <= rep["lag_p50_s"] <= rep["lag_p99_s"] or rep["lag_count"] == 0
    assert rep["published_version"] >= 1
    assert rep["publish_lag_s"] == pytest.approx(10.0)
    assert set(rep["cache_tiers"]) == {"result", "feature", "embed"}
    txt = format_freshness(rep)
    assert "event->re-rank lag" in txt and "published v" in txt


def test_dirty_queue_and_recompute_lag_visible_before_drain(setup):
    g, cfg, params = setup
    nl = NearlineInference(cfg, params, micro_batch=8, seed=13)
    nl.bootstrap_from_graph(g)
    nl.topic.publish(Event(time=5.0, kind="engagement",
                           payload={"member_id": 3, "job_id": 7}))
    nl.ingest()                                   # dirty, not yet drained
    rep = freshness_report(nl, now=8.0)
    assert rep["dirty_queue_depth"] > 0
    assert rep["recompute_lag_s"] == pytest.approx(3.0)


def test_collect_cluster_rollup_is_idempotent(setup):
    g, cfg, params = setup
    cl = _cluster(g, cfg, params, 2)
    for ev in _events(g, np.random.default_rng(10)):
        cl.topic.publish(ev)
    cl.process()
    report, _, _ = serve_trace(
        cl, [ScoreRequest(time=0.0, member_id=1, job_ids=(2, 3))],
        service_s=0.001)
    reg = MetricsRegistry()
    collect_cluster(reg, cl, slo_report=report)

    def point_in_time(r):
        js = {k: v for k, v in r.to_json().items() if k != "series"}
        return json.dumps(js, sort_keys=True)

    first = point_in_time(reg)
    n_samples = len(reg.series("freshness.dirty_queue_depth").samples)
    collect_cluster(reg, cl, slo_report=report)
    # mirrors (gauges/histograms) overwrite; only time SERIES accumulate
    assert point_in_time(reg) == first
    assert len(reg.series("freshness.dirty_queue_depth").samples) == \
        n_samples + 1
    js = reg.to_json()
    agg = cl.aggregate_metrics()
    assert (js["gauges"]["lifecycle.nodes_refreshed{scope=cluster}"]
            == agg.nodes_refreshed)
    assert (js["histograms"]["lifecycle.staleness_s{scope=cluster}"]["count"]
            == len(agg.staleness))
    assert js["gauges"]["slo.completed{scope=cluster}"] == report.completed
    assert "freshness.embedding_age_s" in js["histograms"]
