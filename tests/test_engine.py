"""The unified graph substrate (DESIGN.md §8): snapshot/streaming backend
parity, the shared K-hop TileBuilder, golden equivalence with the
pre-refactor scalar join, and K=3 end-to-end."""
import numpy as np
import jax
import pytest
from dataclasses import replace

from conftest import assert_tiles_equal, make_parity_case
from repro.configs.linksage import smoke as gnn_smoke
from repro.core import encoder as enc
from repro.core.engine import (SnapshotEngine, StreamingEngine, TileBuilder,
                               bucket_pow2, neighbor_weight, pad_tile,
                               slab_width)
from repro.core.graph import NODE_TYPES
from repro.core.linksage import LinkSAGETrainer, _to_jnp, linksage_init
from repro.core.nearline import Event, NearlineInference
from repro.data import GraphGenConfig, generate_job_marketplace_graph


@pytest.fixture(scope="module")
def small_graph():
    return generate_job_marketplace_graph(
        GraphGenConfig(num_members=200, num_jobs=60, seed=3))


# ----------------------------------------------- backend parity (uniform)


@pytest.mark.parametrize("fanouts", [(10, 5), (4, 3, 2)])
def test_snapshot_and_streaming_build_bit_identical_tiles(small_graph, fanouts):
    """The tentpole contract: same uniforms through either backend -> the
    same K-hop tile, bit for bit."""
    g, _ = small_graph
    snap = SnapshotEngine(g)
    stream = StreamingEngine(g.feat_dim, max_neighbors=512)
    stream.bootstrap_from_graph(g)
    rng = np.random.default_rng(7)
    ids = rng.integers(0, g.num_nodes["member"], 24)
    u = rng.random((24, slab_width(fanouts)))
    ta = TileBuilder(snap, fanouts).build("member", ids, uniforms=u)
    tb = TileBuilder(stream, fanouts).build("member", ids, uniforms=u)
    assert_tiles_equal(ta, tb)
    assert ta.fanouts == tuple(fanouts) and ta.batch_size == 24


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("fanouts", [(5, 3), (3, 2, 2)])
def test_event_suffix_parity_deterministic(seed, fanouts):
    """Snapshot-of-final-state vs bootstrap+live-appends (the deterministic
    arm of the hypothesis property test, run even without hypothesis)."""
    final, streaming = make_parity_case(seed)
    snap = SnapshotEngine(final)
    rng = np.random.default_rng((seed, 1))
    n = 16
    types = rng.integers(0, 2, n).astype(np.int64)   # member/job queries
    ids = np.array([rng.integers(0, final.num_nodes[NODE_TYPES[t]])
                    for t in types])
    u = rng.random((n, slab_width(fanouts)))
    ta = TileBuilder(snap, fanouts).build(types, ids, uniforms=u)
    tb = TileBuilder(streaming, fanouts).build(types, ids, uniforms=u)
    assert_tiles_equal(ta, tb, msg=f"seed={seed} ")


# ------------------------------------- golden equivalence (scalar oracle)


def test_khop_builder_matches_pre_refactor_scalar_join(small_graph):
    """Golden equivalence: with fanouts (10, 5) and a fixed seed, the K-hop
    builder on BOTH backends reproduces the per-key scalar join bit for
    bit, and the encoder output is bit-identical too.  Both consume the
    canonical per-node recompute slabs (`embeddings.node_uniform_slab`) —
    the stream every lifecycle recompute path draws from."""
    from repro.core.embeddings import node_uniform_slab

    g, _ = small_graph
    cfg = replace(gnn_smoke(), feat_dim=g.feat_dim, fanouts=(10, 5))
    params = linksage_init(jax.random.PRNGKey(0), cfg)
    nodes = [("member", 3), ("job", 5), ("member", 3), ("skill", 2),
             ("job", 59), ("title", 0), ("member", 199)]

    def scalar_tile(seed):
        nl = NearlineInference(cfg, params["encoder"], fanouts=(10, 5),
                               seed=seed, join_impl="scalar")
        nl.bootstrap_from_graph(g)
        return nl._sequential_join(nodes)

    q_ty = np.array([NODE_TYPES.index(t) for t, _ in nodes], np.int64)
    q_id = np.array([i for _, i in nodes], np.int64)
    u = np.stack([node_uniform_slab(11, t, i, slab_width((10, 5)))
                  for t, i in nodes])

    stream = StreamingEngine(g.feat_dim)
    stream.bootstrap_from_graph(g)
    t_stream = TileBuilder(stream, (10, 5)).build(q_ty, q_id, uniforms=u)
    t_snap = TileBuilder(SnapshotEngine(g), (10, 5)).build(q_ty, q_id,
                                                           uniforms=u)
    t_scalar = scalar_tile(11)
    assert_tiles_equal(t_stream, t_scalar, msg="stream-vs-scalar ")
    assert_tiles_equal(t_snap, t_scalar, msg="snapshot-vs-scalar ")

    e_new = np.asarray(enc.encoder_apply(params["encoder"], cfg, _to_jnp(t_snap)))
    e_old = np.asarray(enc.encoder_apply(params["encoder"], cfg, _to_jnp(t_scalar)))
    np.testing.assert_array_equal(e_new, e_old)


def test_scalar_join_generalizes_to_k3(small_graph):
    """The retained baseline consumes the canonical stream at K=3 too."""
    g, _ = small_graph
    cfg = replace(gnn_smoke(), feat_dim=g.feat_dim).with_fanouts((4, 3, 2))
    params = linksage_init(jax.random.PRNGKey(0), cfg)
    nodes = [("member", 1), ("job", 2), ("skill", 0)]
    tiles = {}
    for impl in ("batched", "scalar"):
        nl = NearlineInference(cfg, params["encoder"], seed=4, join_impl=impl)
        nl.bootstrap_from_graph(g)
        tiles[impl] = nl._sequential_join(nodes)
    assert_tiles_equal(tiles["batched"], tiles["scalar"])
    assert tiles["batched"].num_hops == 3


# ----------------------------------------- degree-weighted (streaming)


def test_streaming_degree_weighted_parity_with_snapshot(small_graph):
    """Satellite: weighted sampling on the streaming backend.  Masks are
    bit-identical to the snapshot engine; the picks themselves agree on all
    but float-boundary draws (global- vs ring-local cumulative weights), and
    both oversample hubs vs uniform."""
    g, _ = small_graph
    engines = {}
    for strat in ("uniform", "degree_weighted"):
        engines[("snap", strat)] = SnapshotEngine(g, strategy=strat)
        e = StreamingEngine(g.feat_dim, max_neighbors=512, strategy=strat)
        e.bootstrap_from_graph(g)
        engines[("stream", strat)] = e
    rng = np.random.default_rng(0)
    n, f = 256, 32
    types = np.zeros(n, np.int64)                   # member queries
    ids = rng.integers(0, g.num_nodes["member"], n)
    u = rng.random((n, f))
    ref = engines[("snap", "uniform")]
    out = {k: e.sample_batched(types, ids, f, u) for k, e in engines.items()}
    for k, (ty, i, mk) in out.items():
        np.testing.assert_array_equal(mk, out[("snap", "uniform")][2], err_msg=str(k))

    def mean_deg(ty, i, mk):
        degs = ref.counts(ty.reshape(-1).astype(np.int64),
                          i.reshape(-1).astype(np.int64))
        return degs[mk.reshape(-1) > 0].mean()

    d_su = mean_deg(*out[("snap", "uniform")])
    d_sw = mean_deg(*out[("snap", "degree_weighted")])
    d_tw = mean_deg(*out[("stream", "degree_weighted")])
    assert d_sw > 1.2 * d_su and d_tw > 1.2 * d_su
    # pick-level parity: identical on all but (rare) float-boundary draws
    same = (out[("snap", "degree_weighted")][1] ==
            out[("stream", "degree_weighted")][1])
    assert same.mean() > 0.99, same.mean()


def test_streaming_weighted_matches_compact_merged_list_oracle(small_graph):
    """The ring-local inverse-CDF must pick exactly what a per-node scalar
    walk over the compact merged neighbor list (weights deg+1) picks —
    zero-weight padding slots have zero-width spans."""
    g, _ = small_graph
    e = StreamingEngine(g.feat_dim, max_neighbors=512,
                        strategy="degree_weighted")
    e.bootstrap_from_graph(g)
    rng = np.random.default_rng(5)
    n, f = 48, 16
    types = rng.integers(0, 2, n).astype(np.int64)
    ids = rng.integers(0, g.num_nodes["job"], n)
    u = rng.random((n, f))
    ty, nid, mk = e.sample_batched(types, ids, f, u)
    for r in range(n):
        merged = e.neighbors(int(types[r]), int(ids[r]))
        if not merged:
            assert mk[r].sum() == 0
            continue
        w = np.array([neighbor_weight(
            e._type_degrees(NODE_TYPES[t], np.array([i]))[0])
            for t, i in merged])
        cum = np.cumsum(w)
        for s in range(f):
            j = min(int(np.searchsorted(cum, u[r, s] * cum[-1], side="right")),
                    len(merged) - 1)
            assert (int(ty[r, s]), int(nid[r, s])) == merged[j], (r, s)


def test_nearline_degree_weighted_serving_runs(small_graph):
    """Weighted nearline sampling (unlocked by the shared strategy
    machinery) serves finite embeddings end to end."""
    g, _ = small_graph
    cfg = replace(gnn_smoke(), feat_dim=g.feat_dim)
    params = linksage_init(jax.random.PRNGKey(0), cfg)
    nl = NearlineInference(cfg, params["encoder"], micro_batch=8,
                           strategy="degree_weighted")
    nl.bootstrap_from_graph(g)
    for i in range(6):
        nl.topic.publish(Event(time=float(i), kind="engagement",
                               payload={"member_id": i, "job_id": i}))
    nl.process()
    emb, _ = nl.embedding_store.get_embedding("job", 3)
    assert np.all(np.isfinite(emb))


# --------------------------------------------------- K=3 through the stack


def test_k3_trains_and_serves_through_shared_path(small_graph):
    """A K=3 config runs the full loop: train (loss drops), embed_nodes
    (no retrace across calls), and nearline serving — all through the same
    TileBuilder code path."""
    g, _ = small_graph
    cfg = replace(gnn_smoke(), feat_dim=g.feat_dim).with_fanouts((4, 3, 2))
    tr = LinkSAGETrainer(cfg, g, seed=0, prefetch=2)
    hist = tr.train(20, batch_size=32)
    assert hist[-1]["loss"] < hist[0]["loss"]
    emb = tr.embed_nodes("member", np.arange(40), batch=32)
    assert emb.shape == (40, cfg.embed_dim)
    traces = tr.encoder_traces
    emb2 = tr.embed_nodes("member", np.arange(40), batch=32)
    assert tr.encoder_traces == traces
    np.testing.assert_allclose(emb, emb2, rtol=1e-6, atol=1e-6)

    nl = NearlineInference(cfg, tr.state.params["encoder"], micro_batch=8)
    nl.bootstrap_from_graph(g)
    nl.topic.publish(Event(time=1.0, kind="engagement",
                           payload={"member_id": 1, "job_id": 2}))
    nl.process()
    emb3, _ = nl.embedding_store.get_embedding("job", 2)
    assert np.all(np.isfinite(emb3))


def test_streaming_trainer_sees_live_edges(small_graph):
    """Training on the StreamingEngine: after live engagement events the
    sampled neighborhoods (and hence batches) change — the near-realtime
    inductive story."""
    g, _ = small_graph
    cfg = replace(gnn_smoke(), feat_dim=g.feat_dim)
    eng = StreamingEngine(g.feat_dim)
    eng.bootstrap_from_graph(g)
    tr = LinkSAGETrainer(cfg, g, seed=0, engine=eng)
    before = tr._build_batch(0, 32)
    static = LinkSAGETrainer(cfg, g, seed=0)._build_batch(0, 32)
    assert_tiles_equal(before[0], static[0], msg="pre-event ")
    rng = np.random.default_rng(0)
    for _ in range(300):
        eng.add_edge("member", int(rng.integers(0, 200)),
                     "job", int(rng.integers(0, 60)))
    after = tr._build_batch(0, 32)     # same step -> same uniforms, new graph
    changed = any(not np.array_equal(a, b)
                  for a, b in zip(jax.tree.leaves(before[0]),
                                  jax.tree.leaves(after[0])))
    assert changed


# ------------------------------------------------------------ tile helpers


def test_bucket_pow2_and_pad_tile(small_graph):
    g, _ = small_graph
    assert bucket_pow2(1) == 8 and bucket_pow2(9) == 16
    assert bucket_pow2(50, cap=48) == 48
    tile = TileBuilder(SnapshotEngine(g), (3, 2)).build(
        "member", np.arange(5), rng=np.random.default_rng(0))
    padded = pad_tile(tile, 8)
    assert padded.batch_size == 8
    for m in padded.masks:
        assert m[5:].sum() == 0
    for x in padded.feats:
        assert np.all(x[5:] == 0)
    assert pad_tile(tile, 4) is tile          # never truncates
