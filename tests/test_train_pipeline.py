"""Training hot-path behaviour: per-step RNG prefetch pipeline, donated /
fused / data-parallel train step, in-batch pos-mask, embed_nodes bucketing."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from dataclasses import replace

from repro import parallel as par
from repro.configs.linksage import smoke as gnn_smoke
from repro.core.linksage import (LinkSAGETrainer, _to_jnp, linksage_init,
                                 loss_fn, make_train_step, pos_mask_from_ids)
from repro.data import GraphGenConfig, generate_job_marketplace_graph


@pytest.fixture(scope="module")
def small_graph():
    cfg = GraphGenConfig(num_members=200, num_jobs=60, seed=7)
    return generate_job_marketplace_graph(cfg)


def _smoke_cfg(g, **kw):
    return replace(gnn_smoke(), feat_dim=g.feat_dim, **kw)


# ----------------------------------------------------------- pos-mask fix


def test_pos_mask_from_ids_marks_duplicate_pairs():
    # batch (A,X), (A,Y), (B,Y): (A,Y) is a positive at BOTH (0,1) and (1,2)
    # would-be-negative grid slots, via duplicate member A and duplicate job Y
    m_ids = jnp.asarray([0, 0, 1], jnp.int32)
    j_ids = jnp.asarray([5, 6, 6], jnp.int32)
    mask = np.asarray(pos_mask_from_ids(m_ids, j_ids))
    want = np.array([[1, 1, 1],
                     [1, 1, 1],
                     [0, 1, 1]], np.float32)
    np.testing.assert_array_equal(mask, want)


def test_pos_mask_defaults_to_diagonal_without_duplicates():
    m_ids = jnp.asarray([0, 1, 2], jnp.int32)
    j_ids = jnp.asarray([5, 6, 7], jnp.int32)
    np.testing.assert_array_equal(np.asarray(pos_mask_from_ids(m_ids, j_ids)),
                                  np.eye(3, dtype=np.float32))


def test_trainer_step_applies_pos_mask(small_graph):
    """The trainer's jitted step must score duplicates as positives — its
    loss equals loss_fn with the id-derived mask, not the bare diagonal."""
    g, _ = small_graph
    cfg = _smoke_cfg(g)
    tr = LinkSAGETrainer(cfg, g, seed=3)
    m_tile, j_tile, m_ids, j_ids = tr._build_batch(0, 64)
    pm = pos_mask_from_ids(jnp.asarray(m_ids), jnp.asarray(j_ids))
    assert float(jnp.sum(pm)) > 64, "batch has no duplicates; pick a new seed"
    with_mask = float(loss_fn(tr.state.params, cfg, _to_jnp(m_tile),
                              _to_jnp(j_tile), pos_mask=pm))
    diag_only = float(loss_fn(tr.state.params, cfg, _to_jnp(m_tile),
                              _to_jnp(j_tile)))
    got = tr.step(64)["loss"]
    assert got == pytest.approx(with_mask, rel=1e-6)
    assert got != pytest.approx(diag_only, rel=1e-6)


# ------------------------------------------------- prefetch == synchronous


def test_prefetch_matches_sync_loss_history(small_graph):
    g, _ = small_graph
    cfg = _smoke_cfg(g)
    sync = LinkSAGETrainer(cfg, g, seed=0)
    pre = LinkSAGETrainer(cfg, g, seed=0, prefetch=3)
    h_sync = sync.train(10, batch_size=32)
    h_pre = pre.train(10, batch_size=32)
    assert [m["loss"] for m in h_sync] == [m["loss"] for m in h_pre]
    assert [m["grad_norm"] for m in h_sync] == [m["grad_norm"] for m in h_pre]
    assert pre.last_train_stats["sampler_stall_frac"] >= 0.0


def test_prefetch_resumes_step_streams_across_train_calls(small_graph):
    """Two successive train() calls must continue the per-step RNG streams —
    identical to one long run, prefetched or not."""
    g, _ = small_graph
    cfg = _smoke_cfg(g)
    one = LinkSAGETrainer(cfg, g, seed=1, prefetch=2)
    two = LinkSAGETrainer(cfg, g, seed=1, prefetch=2)
    h_one = one.train(8, batch_size=16)
    h_two = two.train(4, batch_size=16) + two.train(4, batch_size=16)
    assert [m["loss"] for m in h_one] == [m["loss"] for m in h_two]


def test_fused_dual_tile_encode_matches_unfused(small_graph):
    g, _ = small_graph
    cfg = _smoke_cfg(g)
    tr = LinkSAGETrainer(cfg, g, seed=0)
    m_tile, j_tile, *_ = tr._build_batch(0, 16)
    fused = loss_fn(tr.state.params, cfg, _to_jnp(m_tile), _to_jnp(j_tile),
                    fused=True)
    unfused = loss_fn(tr.state.params, cfg, _to_jnp(m_tile), _to_jnp(j_tile),
                      fused=False)
    np.testing.assert_allclose(float(fused), float(unfused), rtol=1e-6)


@pytest.mark.parametrize("aggregator", ["mean", "attention"])
def test_donated_fused_step_trains(small_graph, aggregator):
    g, _ = small_graph
    cfg = _smoke_cfg(g, aggregator=aggregator)
    tr = LinkSAGETrainer(cfg, g, seed=0, prefetch=2)
    hist = tr.train(20, batch_size=32)
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert np.isfinite(hist[-1]["grad_norm"])


# ------------------------------------------------------------ data parallel


def test_dp_step_matches_single_device(small_graph):
    """shard_map over a 1-device ("data",) mesh must reproduce the plain
    step exactly (pmean over one shard is the identity)."""
    g, _ = small_graph
    cfg = _smoke_cfg(g)
    plain = LinkSAGETrainer(cfg, g, seed=0)
    mesh = jax.make_mesh((1,), ("data",))
    dp = LinkSAGETrainer(cfg, g, seed=0, mesh=mesh)
    h_plain = plain.train(4, batch_size=16)
    h_dp = dp.train(4, batch_size=16)
    assert [m["loss"] for m in h_plain] == [m["loss"] for m in h_dp]


def test_gnn_param_pspecs_cover_every_leaf(small_graph):
    from jax.sharding import PartitionSpec as P
    g, _ = small_graph
    for decoder in ("inbatch", "mlp"):
        cfg = _smoke_cfg(g, decoder=decoder)
        params = jax.eval_shape(lambda c=cfg: linksage_init(jax.random.PRNGKey(0), c))
        specs = par.gnn_param_pspecs(params)
        leaves_p = jax.tree.leaves(params)
        leaves_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(leaves_p) == len(leaves_s)
        for p, s in zip(leaves_p, leaves_s):
            assert len(s) == p.ndim


def test_gnn_param_pspecs_reject_unknown_paths():
    with pytest.raises(ValueError, match="no GNN sharding rule"):
        par.gnn_param_pspecs({"mystery": {"w": np.zeros((2, 2))}})


@pytest.mark.parametrize("num_hops", [2, 3])
def test_gnn_tile_pspecs_shard_batch_dim_only(num_hops):
    from jax.sharding import PartitionSpec as P
    specs = par.gnn_tile_pspecs(num_hops)
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == 3 * num_hops + 2          # feats+types per hop+q, masks per hop
    for s in leaves:
        assert s[0] == "data"
        assert all(ax is None for ax in s[1:])


# -------------------------------------------------- embed_nodes bucketing


def test_embed_nodes_no_retrace_across_calls(small_graph):
    g, _ = small_graph
    cfg = _smoke_cfg(g)
    tr = LinkSAGETrainer(cfg, g, seed=0)
    ids = np.arange(70)
    emb = tr.embed_nodes("member", ids, batch=32)     # chunks 32, 32, 6→8
    assert emb.shape == (70, cfg.embed_dim)
    traces = tr.encoder_traces
    assert traces == 2                                 # full bucket + tail bucket
    emb2 = tr.embed_nodes("member", ids, batch=32)
    assert tr.encoder_traces == traces                 # pure cache hits
    np.testing.assert_allclose(emb, emb2, rtol=1e-6, atol=1e-6)
    # same tile shapes for the other node type: still no retrace
    tr.embed_nodes("job", np.arange(40), batch=32)
    assert tr.encoder_traces == traces


def test_embed_nodes_partial_tail_bucket_caps_at_batch(small_graph):
    g, _ = small_graph
    cfg = _smoke_cfg(g)
    tr = LinkSAGETrainer(cfg, g, seed=0)
    # tail of 50 would bucket to 64 > batch=48: must cap at batch and reuse
    # the full-chunk executable instead of compiling a 64-wide one
    tr.embed_nodes("member", np.arange(48 + 30), batch=48)
    assert tr.encoder_traces == 2                      # 48-wide + 32-bucket
    tr.embed_nodes("member", np.arange(48 + 47), batch=48)
    assert tr.encoder_traces == 2                      # 47→cap 48: pure hit


# ------------------------------------------------------- checkpointing


def test_trainstate_checkpoint_roundtrip_bit_parity(small_graph, tmp_path):
    """save -> restore -> step must be bit-identical to stepping the
    original trainer: the FULL TrainState (params + optimizer moments) and
    the completed-step counter round-trip, so the restored run replays the
    exact per-step RNG streams."""
    g, _ = small_graph
    cfg = _smoke_cfg(g)
    tr1 = LinkSAGETrainer(cfg, g, seed=3)
    tr1.train(3, batch_size=16)
    path = tr1.save_checkpoint(str(tmp_path))
    assert "step_000003" in path

    tr2 = LinkSAGETrainer(cfg, g, seed=3)      # fresh init, same template
    assert tr2.restore_checkpoint(str(tmp_path)) == 3
    assert tr2._step_count == 3

    # the restored state matches bit for bit (params AND opt moments)...
    for a, b in zip(jax.tree.leaves(tr1.state), jax.tree.leaves(tr2.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # ...and one more step from each produces identical metrics and params
    m1 = tr1.step(batch_size=16)
    m2 = tr2.step(batch_size=16)
    assert m1 == m2
    for a, b in zip(jax.tree.leaves(tr1.state.params),
                    jax.tree.leaves(tr2.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_checkpoint_rejects_structural_mismatch(small_graph, tmp_path):
    g, _ = small_graph
    tr1 = LinkSAGETrainer(_smoke_cfg(g), g, seed=0)
    tr1.train(1, batch_size=16)
    tr1.save_checkpoint(str(tmp_path))
    # a different architecture (attention adds attn_q/attn_k leaves) must
    # fail the template structural check loudly
    tr3 = LinkSAGETrainer(_smoke_cfg(g, aggregator="attention"), g, seed=0)
    with pytest.raises(ValueError, match="structure mismatch"):
        tr3.restore_checkpoint(str(tmp_path))
