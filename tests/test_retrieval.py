"""Quantized ANN retrieval tier (core/retrieval, DESIGN.md §14): parity
against the fp32 brute-force oracle, quantization determinism, the
version-pinned replica contract on EmbeddingStore, and the eval-satellite
regressions (recall_at_k memory fix, vectorized positives build)."""
import numpy as np
import pytest

from repro.core import retrieval as rt
from repro.core.embeddings import EmbeddingStore
from repro.core.eval import (positives_from_edges, recall_at_k,
                             recall_from_retrieved, retrieval_eval)

RNG = np.random.default_rng(7)


def _corpus(n=3000, d=24, nq=41, seed=1):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, d)).astype(np.float32),
            rng.normal(size=(nq, d)).astype(np.float32))


# ------------------------------------------------------------ quantization


@pytest.mark.parametrize("scheme", ["per_row", "per_dim"])
def test_quantize_roundtrip_error_bounded_by_scale(scheme):
    x = (RNG.normal(size=(200, 32)) * RNG.uniform(0.01, 10, (200, 1))
         ).astype(np.float32)
    qt = rt.quantize_int8(x, scheme)
    err = np.abs(rt.dequantize(qt) - x)
    if scheme == "per_row":
        bound = qt.scales[:, None] * 0.5
    else:
        bound = np.broadcast_to(qt.dim_scales[None, :] * 0.5, x.shape)
    assert np.all(err <= bound * (1 + 1e-5) + 1e-7)


@pytest.mark.parametrize("scheme", ["per_row", "per_dim"])
def test_quantize_deterministic_same_bits(scheme):
    x = RNG.normal(size=(64, 16)).astype(np.float32)
    a, b = rt.quantize_int8(x, scheme), rt.quantize_int8(x.copy(), scheme)
    assert np.array_equal(a.codes, b.codes)
    assert np.array_equal(a.scales, b.scales)


def test_quantize_zero_rows_and_immutability():
    x = np.zeros((4, 8), np.float32)
    qt = rt.quantize_int8(x)
    assert np.all(qt.codes == 0) and np.all(qt.scales == 1.0)
    with pytest.raises(ValueError):
        qt.codes[0, 0] = 1          # frozen replica


def test_quantize_rejects_unsafe_dim():
    with pytest.raises(AssertionError):
        rt.quantize_int8(np.zeros((2, rt.MAX_QUANT_DIM + 1), np.float32))


# ------------------------------------------------------- oracle bit parity


def test_exact_search_bit_identical_to_oracle():
    x, q = _corpus()
    oi, ov = rt.brute_force_topk(q, x, 10)
    idx = rt.RetrievalIndex.build(x, scheme="per_row", num_lists=32)
    ei, ev = idx.search(q, 10, quantized=False)
    assert np.array_equal(ei, oi) and np.array_equal(ev, ov)


def test_ivf_all_lists_fp32_bit_identical_to_oracle():
    """Structural parity: the inverted lists partition the corpus and
    gathered fp32 gemms reproduce the full-matmul elements bit-for-bit,
    so probing EVERY list must equal brute force exactly."""
    x, q = _corpus(seed=2)
    oi, ov = rt.brute_force_topk(q, x, 10)
    idx = rt.RetrievalIndex.build(x, scheme="per_row", num_lists=32)
    ai, av = idx.search(q, 10, quantized=False, nprobe=32)
    assert np.array_equal(ai, oi) and np.array_equal(av, ov)


def test_int8_numpy_ref_interpret_bitwise_identical():
    """The CPU/BLAS fast path and the kernel dispatch path implement the
    same int8 scoring convention exactly (fp32 accumulation of int8
    products is exact for d <= 1024)."""
    x, q = _corpus(n=700, d=32, seed=3)
    idx = rt.RetrievalIndex.build(x, scheme="per_row")
    base_i, base_v = idx.search(q, 10, impl="numpy")
    for impl in ("ref", "interpret"):
        i2, v2 = idx.search(q, 10, impl=impl)
        assert np.array_equal(i2, base_i), impl
        assert np.array_equal(v2, base_v), impl


def test_canonical_tie_break_lowest_id():
    """Duplicate corpus rows score identically; the canonical order (score
    desc, row asc) must list the lower copy first, on every path."""
    base = RNG.normal(size=(10, 16)).astype(np.float32)
    x = np.concatenate([base, base])              # rows i and i+10 identical
    q = RNG.normal(size=(5, 16)).astype(np.float32)
    oi, _ = rt.brute_force_topk(q, x, 2)          # top-2 = both copies of the
    assert np.all(oi[:, 0] < 10)                  # best vector, low row first
    assert np.array_equal(oi[:, 1], oi[:, 0] + 10)
    idx = rt.RetrievalIndex.build(x, scheme="per_row", num_lists=4)
    for kwargs in ({"quantized": False}, {"quantized": False, "nprobe": 4},
                   {}, {"nprobe": 4}, {"impl": "ref"}, {"refine": 3}):
        ids, _ = idx.search(q, 2, **kwargs)
        assert np.all(ids[:, 0] < 10), kwargs
        assert np.array_equal(ids[:, 1], ids[:, 0] + 10), kwargs


def test_refine_recovers_quantization_loss():
    x, q = _corpus(n=2000, d=16, seed=4)
    oi, _ = rt.brute_force_topk(q, x, 10)
    idx = rt.RetrievalIndex.build(x, scheme="per_row", num_lists=16)
    ri, _ = idx.search(q, 10, nprobe=16, refine=4)   # full coverage
    assert np.array_equal(np.sort(ri, 1), np.sort(oi, 1))


def test_search_pads_when_k_exceeds_corpus():
    x, q = _corpus(n=4, d=8, nq=6, seed=5)
    idx = rt.RetrievalIndex.build(x, scheme="per_row", num_lists=2)
    for kwargs in ({}, {"quantized": False}, {"nprobe": 2}, {"refine": 3}):
        ids, vals = idx.search(q, 10, **kwargs)
        assert ids.shape == (6, 10), kwargs
        assert np.all(ids[:, 4:] == -1) and np.all(vals[:, 4:] == -np.inf)
        assert np.all(ids[:, :4] >= 0)


def test_external_ids_mapping():
    x, q = _corpus(n=50, d=8, seed=6)
    ext = np.arange(50, dtype=np.int64) * 7 + 3
    idx = rt.RetrievalIndex.build(x, ids=ext, scheme="per_row")
    rows, _ = rt.brute_force_topk(q, x, 5)
    ids, _ = idx.search(q, 5, quantized=False)
    assert np.array_equal(ids, ext[rows])


# ---------------------------------------------------------------- IVF index


def test_build_ivf_deterministic_and_partitions_corpus():
    x, _ = _corpus(n=500, d=12, seed=8)
    a = rt.build_ivf(x, 8, seed=3)
    b = rt.build_ivf(x.copy(), 8, seed=3)
    assert np.array_equal(a.centroids, b.centroids)
    assert np.array_equal(a.offsets, b.offsets)
    assert np.array_equal(a.ids, b.ids)
    # CSR sanity: every corpus row in exactly one list, ascending per list
    assert np.array_equal(np.sort(a.ids), np.arange(500))
    for c in range(8):
        seg = a.ids[a.offsets[c]:a.offsets[c + 1]]
        assert np.all(np.diff(seg) > 0) if len(seg) > 1 else True


def test_build_ivf_seed_changes_index():
    x, _ = _corpus(n=500, d=12, seed=8)
    a, b = rt.build_ivf(x, 8, seed=0), rt.build_ivf(x, 8, seed=1)
    assert not np.array_equal(a.centroids, b.centroids)


# ----------------------------------------- version-pinned replicas (store)


def _seeded_store(n=20, d=16, seed=0):
    rng = np.random.default_rng(seed)
    store = EmbeddingStore("t")
    for i in range(n):
        store.put_embedding("job", i, rng.normal(size=d).astype(np.float32),
                            0.0)
    return store


def test_quantized_table_version_pinned_and_memoized():
    store = _seeded_store()
    v1 = store.publish()
    ids, qt = store.quantized_table("job", version=v1)
    assert store.quantized_table("job", version=v1)[1] is qt   # memoized
    before = qt.codes.copy()
    # mutate the LIVE table and publish again: v1's replica must not move
    rng = np.random.default_rng(9)
    for i in range(20):
        store.put_embedding("job", i, rng.normal(size=16).astype(np.float32),
                            1.0)
    v2 = store.publish()
    _, qt2 = store.quantized_table("job", version=v2)
    assert np.array_equal(store.quantized_table("job", version=v1)[1].codes,
                          before)
    assert not np.array_equal(qt2.codes, before)
    with pytest.raises(ValueError):
        qt.codes[0, 0] = 0                                     # immutable


def test_quantized_replica_rederives_bitwise_after_restore():
    store = _seeded_store(seed=4)
    v = store.publish()
    ids1, qt1 = store.quantized_table("job", version=v, scheme="per_dim")
    snap = store.snapshot()
    other = EmbeddingStore("r")
    other.restore(snap)
    ids2, qt2 = other.quantized_table("job", version=v, scheme="per_dim")
    assert np.array_equal(ids1, ids2)
    assert np.array_equal(qt1.codes, qt2.codes)
    assert np.array_equal(qt1.scales, qt2.scales)
    assert np.array_equal(qt1.dim_scales, qt2.dim_scales)
    # restore on the original store drops the memo and re-derives too
    store.restore(snap)
    _, qt3 = store.quantized_table("job", version=v, scheme="per_dim")
    assert qt3 is not qt1 and np.array_equal(qt3.codes, qt1.codes)


def test_quantize_on_publish_eager():
    store = _seeded_store(seed=5)
    store.quantize_on_publish = (("job", "per_row"),)
    v = store.publish()
    assert (v, "job", "per_row") in store._derived


def test_dense_table_sorted_and_frozen():
    store = _seeded_store(seed=6)
    v = store.publish()
    ids, mat = store.dense_table("job", version=v)
    assert np.array_equal(ids, np.arange(20))
    np.testing.assert_array_equal(
        mat[7], store.gather("job", [7], version=v)[0])
    with pytest.raises(ValueError):
        mat[0, 0] = 0


def test_store_retrieval_index_end_to_end():
    store = _seeded_store(n=60, seed=7)
    v = store.publish()
    idx = store.retrieval_index("job", version=v, num_lists=4)
    assert store.retrieval_index("job", version=v, num_lists=4) is idx
    q = np.random.default_rng(0).normal(size=(5, 16)).astype(np.float32)
    _, mat = store.dense_table("job", version=v)
    oi, _ = rt.brute_force_topk(q, mat, 5)
    ei, _ = idx.search(q, 5, quantized=False)
    assert np.array_equal(ei, oi)


# -------------------------------------------------------- eval satellites


def _recall_at_k_dense_reference(scores, positives, k=10):
    """The pre-§14 implementation (dense [n, num_jobs] bool membership
    matrix), kept verbatim as the regression reference."""
    n, num_jobs = scores.shape
    topk = np.argpartition(-scores, min(k, num_jobs - 1), axis=1)[:, :k]
    lens = np.fromiter((len(p) for p in positives), np.int64, n)
    if not (lens > 0).any():
        return 0.0
    rows = np.repeat(np.arange(n), lens)
    cols = np.fromiter((j for p in positives for j in p), np.int64, lens.sum())
    ok = (cols >= 0) & (cols < num_jobs)
    pos_mat = np.zeros((n, num_jobs), bool)
    pos_mat[rows[ok], cols[ok]] = True
    hits = int(pos_mat[np.arange(n)[:, None], topk].sum())
    total = int(np.minimum(lens, k).sum())
    return hits / max(total, 1)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_recall_at_k_matches_dense_reference(seed):
    rng = np.random.default_rng(seed)
    n, j, k = 40, 90, 10
    scores = rng.normal(size=(n, j)).astype(np.float32)
    positives = []
    for _ in range(n):
        p = set(rng.integers(0, j, rng.integers(0, 25)).tolist())
        if rng.random() < 0.3:                  # out-of-range ids: count in
            p |= {int(j + rng.integers(0, 5)), -1}   # denominator, never hit
        positives.append(p)
    assert recall_at_k(scores, positives, k=k) == \
        _recall_at_k_dense_reference(scores, positives, k=k)


def test_recall_at_k_empty_positives():
    scores = RNG.normal(size=(3, 5)).astype(np.float32)
    assert recall_at_k(scores, [set(), set(), set()], k=2) == 0.0


def test_positives_from_edges_matches_loop():
    rng = np.random.default_rng(3)
    src = rng.integers(0, 50, 400)
    dst = rng.integers(0, 200, 400)
    want = [set() for _ in range(50)]
    for m, j in zip(src, dst):
        want[m].add(int(j))
    assert positives_from_edges(src, dst, 50) == want
    assert positives_from_edges(np.array([]), np.array([]), 3) == \
        [set(), set(), set()]


def test_retrieval_eval_index_arm_matches_dense_on_exact_config():
    rng = np.random.default_rng(5)
    m = rng.normal(size=(30, 12)).astype(np.float32)
    j = rng.normal(size=(80, 12)).astype(np.float32)
    src = rng.integers(0, 30, 120)
    dst = rng.integers(0, 80, 120)
    base = retrieval_eval(m, j, src, dst, k=10)
    idx = rt.RetrievalIndex.build(j, scheme=None, num_lists=None)
    via_index = retrieval_eval(m, j, src, dst, k=10, index=idx)
    assert via_index == base


def test_recall_from_retrieved_ignores_padding():
    ids = np.array([[3, 1, -1, -1], [0, 2, 5, -1]])
    positives = [{3, 9}, {5}]
    # member 0: 1 of min(2, k)=2; member 1: 1 of 1 -> (1 + 1) / 3
    assert recall_from_retrieved(ids, positives, k=4) == pytest.approx(2 / 3)
