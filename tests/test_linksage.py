"""LinkSAGE core behaviour: graph construction, sampling, encoder/decoders,
end-to-end training signal."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from dataclasses import replace

from repro.configs.linksage import CONFIG as GNN_CONFIG, smoke as gnn_smoke
from repro.core import decoder as dec
from repro.core import encoder as enc
from repro.core.eval import auc, recall_at_k, retrieval_eval
from repro.core.graph import EDGE_TYPES, NODE_TYPES, HeteroGraph
from repro.core.linksage import LinkSAGETrainer, _to_jnp, linksage_init
from repro.core.sampler import NeighborSampler, SamplerConfig
from repro.data import GraphGenConfig, generate_job_marketplace_graph
from repro.data.synthetic_graph import strip_skill_nodes


@pytest.fixture(scope="module")
def small_graph():
    cfg = GraphGenConfig(num_members=300, num_jobs=100, seed=7)
    return generate_job_marketplace_graph(cfg)


def test_graph_has_paper_node_and_edge_types(small_graph):
    g, _ = small_graph
    assert set(g.num_nodes) == set(NODE_TYPES)
    census = g.census()
    # paper Table 2: engagement edges dominate recruiter edges
    assert census["edges"]["member->job"] > census["edges"]["job->member"]
    # reciprocal attribute edges exist (§4.3 bidirectionality)
    for a in ("skill", "title", "company", "position"):
        assert census["edges"][f"member->{a}"] > 0
        assert census["edges"][f"{a}->member"] > 0


def test_skill_ablation_strips_only_skill_edges(small_graph):
    g, _ = small_graph
    g2 = strip_skill_nodes(g)
    assert all("skill" not in k for k in g2.adj)
    assert g2.edge_count("member", "job") == g.edge_count("member", "job")


def test_sampler_shapes_and_masks(small_graph):
    g, _ = small_graph
    s = NeighborSampler(g, SamplerConfig(fanouts=(5, 3), seed=0))
    ids = np.arange(32)
    tile = s.sample_batch("member", ids)
    assert tile.q_feat.shape == (32, g.feat_dim)
    assert tile.n1_feat.shape == (32, 5, g.feat_dim)
    assert tile.n2_feat.shape == (32, 5, 3, g.feat_dim)
    # masked hop-2 entries must be zero-featured
    masked = tile.n2_mask == 0
    assert np.all(tile.n2_feat[masked] == 0)
    # a member always has attribute edges -> hop-1 fully valid
    assert tile.n1_mask.mean() > 0.9


def test_sampler_respects_edge_direction(small_graph):
    g, _ = small_graph
    s = NeighborSampler(g, SamplerConfig(fanouts=(64, 1), seed=0))
    tile = s.sample_batch("member", np.arange(20))
    # neighbors of members are attrs or jobs, never other members
    member_tid = NODE_TYPES.index("member")
    valid = tile.n1_mask > 0
    assert not np.any(tile.n1_type[valid] == member_tid)


@pytest.mark.parametrize("aggregator", ["mean", "attention"])
def test_encoder_shapes_and_finiteness(small_graph, aggregator):
    g, _ = small_graph
    cfg = replace(gnn_smoke(), aggregator=aggregator, feat_dim=g.feat_dim)
    s = NeighborSampler(g, SamplerConfig(fanouts=cfg.fanouts, seed=0))
    params = linksage_init(jax.random.PRNGKey(0), cfg)
    tile = _to_jnp(s.sample_batch("member", np.arange(16)))
    emb = enc.encoder_apply(params["encoder"], cfg, tile)
    assert emb.shape == (16, cfg.embed_dim)
    assert bool(jnp.all(jnp.isfinite(emb)))


def test_encoder_uses_neighbor_information(small_graph):
    """Zeroing hop-1 masks must change the embedding (the GNN actually
    aggregates; paper §3 information-propagation claim)."""
    g, _ = small_graph
    cfg = replace(gnn_smoke(), feat_dim=g.feat_dim)
    s = NeighborSampler(g, SamplerConfig(fanouts=cfg.fanouts, seed=0))
    params = linksage_init(jax.random.PRNGKey(0), cfg)
    tile = s.sample_batch("member", np.arange(8))
    emb = enc.encoder_apply(params["encoder"], cfg, _to_jnp(tile))
    blinded = tile._replace(masks=tuple(np.zeros_like(m) for m in tile.masks))
    emb2 = enc.encoder_apply(params["encoder"], cfg, _to_jnp(blinded))
    assert float(jnp.max(jnp.abs(emb - emb2))) > 1e-4


@pytest.mark.parametrize("decoder", ["inbatch", "mlp", "cosine"])
def test_decoders(decoder):
    cfg = replace(gnn_smoke(), decoder=decoder)
    key = jax.random.PRNGKey(0)
    m = jax.random.normal(key, (8, cfg.embed_dim))
    j = jax.random.normal(jax.random.PRNGKey(1), (8, cfg.embed_dim))
    params = dec.decoder_init(key, cfg)
    if decoder == "inbatch":
        loss = dec.inbatch_loss(cfg, m, j)
    else:
        loss = dec.pairwise_loss(params, cfg, m, j, jnp.ones(8))
    assert np.isfinite(float(loss))


def test_sigmoid_ce_matches_naive():
    logits = jnp.asarray([-5.0, -0.1, 0.0, 2.0, 10.0])
    labels = jnp.asarray([0.0, 1.0, 1.0, 0.0, 1.0])
    naive = -(labels * jnp.log(jax.nn.sigmoid(logits))
              + (1 - labels) * jnp.log(1 - jax.nn.sigmoid(logits) + 1e-12))
    np.testing.assert_allclose(dec.sigmoid_ce(logits, labels), naive,
                               rtol=1e-4, atol=1e-4)


def test_training_beats_random_retrieval(small_graph):
    g, truth = small_graph
    cfg = replace(GNN_CONFIG, hidden_dim=64, embed_dim=64, fanouts=(6, 3))
    tr = LinkSAGETrainer(cfg, g, seed=0)
    hist = tr.train(120, batch_size=64)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.5
    m_emb = tr.embed_nodes("member", np.arange(300))
    j_emb = tr.embed_nodes("job", np.arange(100))
    src, dst = truth["engagements"]
    r = retrieval_eval(m_emb, j_emb, src, dst, k=10)["recall"]
    rng = np.random.default_rng(0)
    r_rand = retrieval_eval(rng.normal(size=m_emb.shape),
                            rng.normal(size=j_emb.shape), src, dst, k=10)["recall"]
    assert r > 3 * r_rand, (r, r_rand)


# ------------------------------------------------------------- eval utils


def test_auc_known_values():
    labels = np.array([1, 1, 0, 0])
    assert auc(labels, np.array([0.9, 0.8, 0.2, 0.1])) == 1.0
    assert auc(labels, np.array([0.1, 0.2, 0.8, 0.9])) == 0.0
    assert abs(auc(labels, np.array([0.5, 0.5, 0.5, 0.5])) - 0.5) < 1e-9


def test_recall_at_k_perfect_and_zero():
    scores = np.eye(4) + 0.01
    positives = [{0}, {1}, {2}, {3}]
    assert recall_at_k(scores, positives, k=1) == 1.0
    positives_wrong = [{3}, {2}, {1}, {0}]
    assert recall_at_k(scores, positives_wrong, k=1) == 0.0


def test_recall_at_k_vectorized_matches_set_semantics():
    """The vectorized recall must reproduce the per-member set-intersection
    loop exactly — including empty sets, out-of-range positive ids (count
    toward the denominator, never retrievable) and k > num_jobs."""
    rng = np.random.default_rng(0)
    for _ in range(50):
        n, num_jobs = int(rng.integers(1, 30)), int(rng.integers(2, 20))
        scores = np.round(rng.normal(size=(n, num_jobs)), 1)
        positives = [set(map(int, rng.integers(0, num_jobs + 3,
                                               rng.integers(0, 6))))
                     for _ in range(n)]
        k = int(rng.integers(1, num_jobs + 5))
        topk = np.argpartition(-scores, min(k, num_jobs - 1), axis=1)[:, :k]
        hits, total = 0, 0
        for i, pos in enumerate(positives):
            if not pos:
                continue
            hits += len(set(topk[i].tolist()) & pos)
            total += min(len(pos), k)
        assert recall_at_k(scores, positives, k) == hits / max(total, 1)


def test_auc_tie_handling():
    """Regression: tied scores spanning a positive and a negative count as
    half a concordant pair (average-rank convention)."""
    # pairs: (.5+, .5-) ties -> 1/2; (.5+, .1-)=1; (.9+, .5-)=1; (.9+, .1-)=1
    got = auc(np.array([1, 0, 1, 0]), np.array([0.5, 0.5, 0.9, 0.1]))
    assert got == pytest.approx(3.5 / 4)
    # all-tied scores are exactly chance, not 0 or 1
    assert auc(np.array([1, 0, 1, 0]), np.zeros(4)) == pytest.approx(0.5)
    # a fully tied positive block above a tied negative block is perfect
    assert auc(np.array([1, 1, 0, 0]), np.array([2.0, 2.0, 1.0, 1.0])) == 1.0


def test_degree_weighted_sampling(small_graph):
    """DeepGNN-style weighted sampling (§4.1): high-degree neighbors are
    over-represented relative to uniform sampling."""
    g, _ = small_graph
    uni = NeighborSampler(g, SamplerConfig(fanouts=(32, 1), strategy="uniform", seed=0))
    wei = NeighborSampler(g, SamplerConfig(fanouts=(32, 1), strategy="degree_weighted", seed=0))
    ids = np.arange(64)
    t_u = uni.sample_batch("member", ids)
    t_w = wei.sample_batch("member", ids)

    # structural check: same shapes/masks, sampling remains valid
    assert t_w.n1_feat.shape == t_u.n1_feat.shape
    assert t_w.n1_mask.sum() == t_u.n1_mask.sum()
    # distributional check: weighted sampling raises the mean degree of the
    # sampled hop-1 neighborhood (hubs over-represented)
    feat_norm_w = np.linalg.norm(t_w.n1_feat[t_w.n1_mask > 0], axis=-1)
    feat_norm_u = np.linalg.norm(t_u.n1_feat[t_u.n1_mask > 0], axis=-1)
    assert feat_norm_w.size == feat_norm_u.size  # same valid count
    # degree itself via the sampler's merged adjacency proxy: resample ids
    # through a direct hop and compare mean neighbor degree
    def mean_deg(sampler):
        ty, ids, mask = sampler._sample_hop(
            np.zeros(64, np.int8), np.arange(64, dtype=np.int32), 32)
        degs = [sampler._degree_of(int(t), int(i))
                for t, i, m in zip(ty.ravel(), ids.ravel(), mask.ravel()) if m]
        return np.mean(degs)

    assert mean_deg(wei) > mean_deg(uni)


def test_inbatch_cosine_normalizes_both_towers():
    """Regression (satellite): the in-batch cosine arm must score the SAME
    normalized cosine as pair_scores — the grid diagonal and the aligned
    pair scores agree, and logits are bounded by the scale."""
    cfg = replace(gnn_smoke(), decoder="cosine")
    m = 5.0 * jax.random.normal(jax.random.PRNGKey(0), (8, cfg.embed_dim))
    j = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (8, cfg.embed_dim))
    grid = dec.inbatch_logits(cfg, m, j)
    diag = jnp.diagonal(grid)
    aligned = dec.pair_scores({}, cfg, m, j)
    np.testing.assert_allclose(np.asarray(diag), np.asarray(aligned),
                               rtol=1e-5, atol=1e-5)
    # cosine logits are |s| <= cosine_scale; the old unnormalized arm blew
    # far past it on mismatched tower norms
    assert float(jnp.max(jnp.abs(grid))) <= cfg.cosine_scale * (1 + 1e-5)
    assert np.isfinite(float(dec.inbatch_loss(cfg, m, j)))
