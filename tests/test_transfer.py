"""The §7 surface registry: per-surface heads, the jitted multi-surface
train step (one shared embedding gather), version-pinned store reads, and
the EBR-beats-control acceptance gate."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from dataclasses import replace

from repro.configs.linksage import CONFIG as GNN_CONFIG
from repro.core.embeddings import EmbeddingStore
from repro.core.eval import auc, recall_at_k
from repro.core.linksage import LinkSAGETrainer
from repro.core.transfer import (SURFACES, MultiSurfaceTrainer, RankerConfig,
                                 surface_configs)
from repro.data import GraphGenConfig, generate_job_marketplace_graph
from repro.launch.transfer import build_surface_datasets, fit_surfaces


def _toy_tables(rng, M=64, J=24, f=8, e=8):
    return {"m_feat": rng.normal(size=(M, f)).astype(np.float32),
            "j_feat": rng.normal(size=(J, f)).astype(np.float32),
            "m_gnn": rng.normal(size=(M, e)).astype(np.float32),
            "j_gnn": rng.normal(size=(J, e)).astype(np.float32),
            "q_feat": rng.normal(size=(M, f)).astype(np.float32)}


def test_registry_has_all_four_paper_surfaces():
    assert set(SURFACES) >= {"taj", "jymbii", "jobsearch", "ebr"}


@pytest.mark.parametrize("name", ["taj", "jymbii", "jobsearch", "ebr"])
@pytest.mark.parametrize("use_gnn", [True, False])
def test_surface_heads_apply_finite(name, use_gnn):
    rng = np.random.default_rng(0)
    cfg = replace(RankerConfig(name=name), other_feat_dim=8, gnn_embed_dim=8,
                  hidden=16, use_gnn=use_gnn, query_dim=8, tower_dim=8)
    params = SURFACES[name].init(jax.random.PRNGKey(0), cfg)
    tables = _toy_tables(rng)
    batch = {"m_feat": jnp.asarray(tables["m_feat"][:6]),
             "j_feat": jnp.asarray(tables["j_feat"][:6]),
             "m_gnn": jnp.asarray(tables["m_gnn"][:6]),
             "j_gnn": jnp.asarray(tables["j_gnn"][:6]),
             "q_feat": jnp.asarray(tables["q_feat"][:6]),
             "label": jnp.ones(6)}
    logits = SURFACES[name].apply(params, cfg, batch)
    assert logits.shape == (6,)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert np.isfinite(float(SURFACES[name].loss(params, cfg, batch)))


def test_control_arm_is_blind_to_gnn_tables():
    """use_gnn=False heads must produce identical logits whatever the GNN
    columns hold — the A/B control genuinely excludes the treatment."""
    rng = np.random.default_rng(1)
    tables = _toy_tables(rng)
    cfgs = surface_configs(other_feat_dim=8, gnn_embed_dim=8, hidden=16,
                           use_gnn=False, query_dim=8)
    mst = MultiSurfaceTrainer(cfgs, seed=0)
    pairs = (rng.integers(0, 64, 32), rng.integers(0, 24, 32))
    s1 = mst.score(tables, pairs)
    tables2 = dict(tables, m_gnn=10 + tables["m_gnn"], j_gnn=-tables["j_gnn"])
    s2 = mst.score(tables2, pairs)
    for name in cfgs:
        np.testing.assert_array_equal(s1[name], s2[name])


def test_multi_surface_fit_trains_every_head():
    rng = np.random.default_rng(2)
    tables = _toy_tables(rng)
    # learnable structure: label correlates with the gnn dot product
    m_idx = rng.integers(0, 64, 512)
    j_idx = rng.integers(0, 24, 512)
    sim = np.sum(tables["m_gnn"][m_idx] * tables["j_gnn"][j_idx], axis=1)
    label = (sim > 0).astype(np.float32)
    labels = {n: label for n in ("taj", "jymbii", "jobsearch", "ebr")}
    cfgs = surface_configs(other_feat_dim=8, gnn_embed_dim=8, hidden=32,
                           query_dim=8)
    mst = MultiSurfaceTrainer(cfgs, seed=0)
    hist = mst.fit(tables, (m_idx, j_idx), labels, epochs=16, batch_size=128,
                   lr=3e-3)
    for name, losses in hist.items():
        assert losses[-1] < losses[0], (name, losses[0], losses[-1])
    scores = mst.score(tables, (m_idx, j_idx))
    for name in cfgs:
        assert auc(label, scores[name]) > 0.75, name


def test_ebr_two_tower_retrieval_vectors():
    rng = np.random.default_rng(3)
    tables = _toy_tables(rng)
    cfgs = surface_configs(names=("ebr",), other_feat_dim=8, gnn_embed_dim=8,
                           hidden=16, tower_dim=12)
    mst = MultiSurfaceTrainer(cfgs, seed=0)
    m_vec, j_vec = mst.ebr_vectors(tables)
    assert m_vec.shape == (64, 12) and j_vec.shape == (24, 12)
    # pair scoring equals the dot of the tower vectors (the retrieval
    # contract that lets the ANN index stand in for the head)
    pairs = (np.arange(10), np.arange(10))
    s = mst.score(tables, pairs)["ebr"]
    np.testing.assert_allclose(
        s, np.sum(m_vec[:10] * j_vec[:10], axis=1), rtol=1e-5, atol=1e-5)


# ----------------------------------------------- end-to-end acceptance


@pytest.fixture(scope="module")
def trained():
    g, truth = generate_job_marketplace_graph(
        GraphGenConfig(num_members=300, num_jobs=100, seed=0))
    cfg = replace(GNN_CONFIG, hidden_dim=64, embed_dim=64, fanouts=(8, 4))
    tr = LinkSAGETrainer(cfg, g, seed=0)
    tr.train(150, batch_size=64)
    return g, truth, cfg, tr


def test_surfaces_train_from_version_pinned_store(trained):
    """The full loop: publish a version, gather member/job tables out of
    the store AT that version, fit all four surfaces — and the EBR
    two-tower head with GNN embeddings beats the use_gnn=False control on
    recall@k (the acceptance criterion)."""
    g, truth, cfg, tr = trained
    lc = tr.make_lifecycle()
    v = lc.publish_version(clock=0.0)
    M, J = g.num_nodes["member"], g.num_nodes["job"]
    m_gnn = lc.store.gather("member", np.arange(M), version=v)
    j_gnn = lc.store.gather("job", np.arange(J), version=v)

    pairs, labels, feat_tables = build_surface_datasets(
        g, truth, num_members=M, num_jobs=J, seed=0)
    report = {}
    for arm, use_gnn in (("gnn", True), ("control", False)):
        tables = (dict(feat_tables, m_gnn=m_gnn, j_gnn=j_gnn)
                  if use_gnn else dict(feat_tables))
        report[arm], _ = fit_surfaces(tables, pairs, labels,
                                      embed_dim=cfg.embed_dim,
                                      feat_dim=g.feat_dim, use_gnn=use_gnn,
                                      epochs=5,
                                      eval_truth=truth["engagements"])
    assert report["gnn"]["ebr"] > report["control"]["ebr"], report
    # the ranking surfaces hold their own against control on average too
    mean_gnn = np.mean([report["gnn"][s] for s in ("taj", "jymbii", "jobsearch")])
    mean_ctl = np.mean([report["control"][s] for s in ("taj", "jymbii", "jobsearch")])
    assert mean_gnn > mean_ctl - 0.02, report


def test_raw_gnn_embeddings_already_retrieve(trained):
    """Sanity anchor for the gate above: the published GNN tables retrieve
    engagements well above chance even before any head is trained."""
    g, truth, cfg, tr = trained
    lc = tr.make_lifecycle()
    v = lc.publish_version(clock=0.0)
    m = lc.store.gather("member", np.arange(g.num_nodes["member"]), version=v)
    j = lc.store.gather("job", np.arange(g.num_nodes["job"]), version=v)
    src, dst = truth["engagements"]
    positives = [set() for _ in range(m.shape[0])]
    for a, b in zip(src, dst):
        positives[a].add(int(b))
    members = np.array([i for i, p in enumerate(positives) if p])
    r = recall_at_k((m @ j.T)[members], [positives[i] for i in members], k=10)
    assert r > 0.25, r
