"""Per-architecture smoke tests: reduced same-family variant, one forward +
one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, get_smoke_config
from repro.launch import steps as ST
from repro.models import (decode_step, forward_train, init_decode_state,
                          lm_loss, model_init)
from repro.optim import adamw_init


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    assert cfg.source, f"{arch} must cite its source"
    # spot-check the assigned table
    expected = {
        "llama3_8b": (32, 4096, 32, 8, 14336, 128256),
        "arctic_480b": (35, 7168, 56, 8, 4864, 32000),
        "pixtral_12b": (40, 5120, 32, 8, 14336, 131072),
        "jamba_1_5_large_398b": (72, 8192, 64, 8, 24576, 65536),
        "mamba2_780m": (48, 1536, 0, 0, 0, 50280),
        "phi3_5_moe_42b": (32, 4096, 32, 8, 6400, 32064),
        "musicgen_medium": (48, 1536, 24, 24, 6144, 2048),
        "yi_6b": (32, 4096, 32, 4, 11008, 64000),
        "qwen1_5_32b": (64, 5120, 40, 40, 27392, 152064),
        "codeqwen1_5_7b": (32, 4096, 32, 32, 13440, 92416),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, f"{arch}: {got} != {expected}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_config_is_reduced(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    assert cfg.family == get_config(arch).family


def _smoke_batch(cfg, rng, b=2, s=24):   # s > max prefix (16) + some text
    s_text = s - cfg.num_prefix_embeddings if cfg.modality != "text" else s
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s_text)),
                                   jnp.int32)}
    labels = rng.integers(0, cfg.vocab_size, (b, s))
    if cfg.modality != "text":
        labels[:, :cfg.num_prefix_embeddings] = -1
        batch["prefix_emb"] = jnp.asarray(
            rng.normal(size=(b, cfg.num_prefix_embeddings, cfg.d_model)),
            jnp.float32)
    batch["labels"] = jnp.asarray(labels, jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = get_smoke_config(arch)
    params = model_init(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(cfg, rng)
    b, s = batch["labels"].shape

    hidden, aux = forward_train(params, cfg, batch["tokens"],
                                prefix_emb=batch.get("prefix_emb"))
    assert hidden.shape == (b, s, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(hidden))), "NaN in hidden states"

    step = ST.make_train_step(cfg, lr=1e-3)
    opt = adamw_init(params)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    delta = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(a - b_))),
                         params, params2)
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch, rng):
    cfg = get_smoke_config(arch)
    params = model_init(jax.random.PRNGKey(0), cfg)
    b = 2
    state = init_decode_state(cfg, b, 32, dtype=jnp.float32)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (b,)), jnp.int32)
    logits, state2 = decode_step(params, cfg, tok, state)
    assert logits.shape == (b, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert int(state2.step) == 1


@pytest.mark.parametrize("arch", ["llama3_8b", "mamba2_780m",
                                  "jamba_1_5_large_398b", "phi3_5_moe_42b",
                                  "musicgen_medium"])
def test_smoke_training_reduces_loss(arch, rng):
    """Overfitting a single fixed batch must reduce the loss clearly."""
    cfg = get_smoke_config(arch)
    params = model_init(jax.random.PRNGKey(1), cfg)
    step = jax.jit(ST.make_train_step(cfg, lr=3e-3))
    opt = adamw_init(params)
    batch = _smoke_batch(cfg, np.random.default_rng(0), b=4, s=24)
    losses = []
    for _ in range(15):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all(), losses
    assert losses[-1] < losses[0] * 0.9, f"loss did not decrease: {losses}"


def test_input_specs_cover_all_pairs():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in INPUT_SHAPES.values():
            specs = ST.input_specs(cfg, shape)
            assert specs, (arch, shape.name)
            if shape.kind == "decode":
                assert "state" in specs and "token" in specs
            else:
                assert specs["tokens"].shape[0] == shape.global_batch
