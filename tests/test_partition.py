"""Graph partitioning + sharded engine (DESIGN.md §10): ownership maps,
edge-cut quality, and the cross-shard neighbor-resolution bit-parity
contract."""
import numpy as np
import pytest

from conftest import assert_tiles_equal, make_parity_case
from repro.core.engine import StreamingEngine, TileBuilder
from repro.core.graph import NODE_TYPE_ID, NODE_TYPES
from repro.core.partition import (GraphPartitioner, ShardedEngine, ShardView,
                                  _hash_shard)
from repro.data import GraphGenConfig, generate_job_marketplace_graph


@pytest.fixture(scope="module")
def graph():
    g, _ = generate_job_marketplace_graph(
        GraphGenConfig(num_members=150, num_jobs=50, seed=7))
    return g


# ------------------------------------------------------------ partitioner


def test_hash_partitioner_is_deterministic_and_total():
    part = GraphPartitioner(4, "hash")
    for tid in range(len(NODE_TYPES)):
        for nid in (0, 1, 17, 10**6, 10**9):
            s = part.shard_of(tid, nid)
            assert 0 <= s < 4
            assert s == part.shard_of(NODE_TYPES[tid], nid)   # name == id
    # vectorized path agrees with the scalar path
    tids = np.repeat(np.arange(6), 50)
    nids = np.tile(np.arange(50), 6)
    arr = part.shard_array(tids, nids)
    assert all(arr[i] == part.shard_of(int(tids[i]), int(nids[i]))
               for i in range(len(arr)))


def test_hash_partitioner_spreads_load():
    owners = _hash_shard(np.zeros(4096, np.int64), np.arange(4096), 8)
    counts = np.bincount(owners, minlength=8)
    assert counts.min() > 0
    assert counts.max() / counts.mean() < 1.4


def test_greedy_partitioner_beats_hash_on_edge_cut(graph):
    hashed = GraphPartitioner(4, "hash")
    greedy = GraphPartitioner(4, "greedy").fit(graph)
    h, g = hashed.cut_stats(graph), greedy.cut_stats(graph)
    assert g["cut_fraction"] < h["cut_fraction"]
    assert g["balance"] <= greedy.balance_slack + 1e-9
    assert sum(g["shard_sizes"]) == sum(graph.num_nodes.values())


def test_greedy_falls_back_to_hash_for_unseen_nodes(graph):
    greedy = GraphPartitioner(2, "greedy").fit(graph)
    hashed = GraphPartitioner(2, "hash")
    unseen = graph.num_nodes["job"] + 12345
    assert greedy.shard_of("job", unseen) == hashed.shard_of("job", unseen)


# --------------------------------------------------------- sharded engine


def _sharded_of(graph, P, *, strategy="hash"):
    part = GraphPartitioner(P, strategy)
    if strategy == "greedy":
        part.fit(graph)
    eng = ShardedEngine(graph.feat_dim, part, max_neighbors=64)
    eng.bootstrap_from_graph(graph)
    return eng


@pytest.mark.parametrize("P,strategy", [(1, "hash"), (3, "hash"), (2, "greedy")])
def test_sharded_engine_bit_parity_with_single_engine(P, strategy):
    """Same bootstrap + event suffix, same uniforms → bit-identical K-hop
    tiles from the composite and the un-sharded engine."""
    final_graph, _ = make_parity_case(3, num_events=30)
    part = GraphPartitioner(P, strategy)
    if strategy == "greedy":
        part.fit(final_graph)
    sharded = ShardedEngine(final_graph.feat_dim, part, max_neighbors=64)
    sharded.bootstrap_from_graph(final_graph)
    snap = StreamingEngine(final_graph.feat_dim, max_neighbors=64)
    snap.bootstrap_from_graph(final_graph)

    rng = np.random.default_rng(5)
    q_ty = np.array([0, 1, 0, 2, 1, 0], np.int64)
    q_id = np.array([3, 1, 7, 0, 2, 11], np.int64)
    for fanouts in [(4, 3), (3, 2, 2)]:
        b_single = TileBuilder(snap, fanouts)
        b_sharded = TileBuilder(sharded, fanouts)
        uniforms = rng.random((len(q_id), b_single.slab_width))
        assert_tiles_equal(b_single.build(q_ty, q_id, uniforms=uniforms),
                           b_sharded.build(q_ty, q_id, uniforms=uniforms),
                           msg=f"P={P} fanouts={fanouts} ")


def test_sharded_engine_parity_after_live_events(graph):
    """add_edge routed by source owner keeps per-node rings bit-identical."""
    single = StreamingEngine(graph.feat_dim, max_neighbors=64)
    single.bootstrap_from_graph(graph)
    sharded = _sharded_of(graph, 3)
    rng = np.random.default_rng(11)
    for _ in range(60):
        m = int(rng.integers(0, graph.num_nodes["member"]))
        j = int(rng.integers(0, graph.num_nodes["job"]))
        for eng in (single, sharded):
            eng.add_edge("member", m, "job", j)
            eng.add_edge("job", j, "member", m)
    ty = np.concatenate([np.zeros(40, np.int64), np.ones(20, np.int64)])
    ids = np.concatenate([rng.integers(0, graph.num_nodes["member"], 40),
                          rng.integers(0, graph.num_nodes["job"], 20)])
    assert np.array_equal(single.counts(ty, ids), sharded.counts(ty, ids))
    u = rng.random((60, 5))
    for a, b in zip(single.sample_batched(ty, ids, 5, u),
                    sharded.sample_batched(ty, ids, 5, u)):
        assert np.array_equal(a, b)
    assert np.array_equal(single.gather_features(ty, ids),
                          sharded.gather_features(ty, ids))


def test_sharded_engine_feature_writes_route_to_owner(graph):
    sharded = _sharded_of(graph, 4)
    part = sharded.partitioner
    new_id = graph.num_nodes["job"] + 5
    feat = np.full(graph.feat_dim, 3.0, np.float32)
    sharded.put_feature(NODE_TYPE_ID["job"], new_id, feat)
    owner = part.shard_of("job", new_id)
    assert (NODE_TYPE_ID["job"], new_id) in sharded.shards[owner].feature_store
    for p in range(4):
        if p != owner:
            assert (NODE_TYPE_ID["job"], new_id) not in sharded.shards[p].feature_store
    assert np.array_equal(sharded.get_feature(NODE_TYPE_ID["job"], new_id), feat)


def test_shard_view_accounts_local_vs_remote_rows(graph):
    sharded = _sharded_of(graph, 2)
    view = ShardView(sharded, home=0)
    ty = np.zeros(30, np.int64)
    ids = np.arange(30)
    view.counts(ty, ids)
    owners = sharded.partitioner.shard_array(ty, ids)
    assert view.local_rows == int((owners == 0).sum())
    assert view.remote_rows == int((owners != 0).sum())
    assert view.local_rows + view.remote_rows == 30
    # join_reads flows through the composite accounting
    before = view.join_reads
    view.gather_features(ty, ids)
    assert view.join_reads > before


def test_sharded_join_reads_match_single_engine(graph):
    """The deduped multi_get accounting is preserved: unique keys partition
    by owner, so total reads are identical."""
    single = StreamingEngine(graph.feat_dim, max_neighbors=64)
    single.bootstrap_from_graph(graph)
    sharded = _sharded_of(graph, 3)
    ty = np.zeros(64, np.int64)
    ids = np.concatenate([np.arange(32), np.arange(32)])   # dupes dedupe
    r0s, r0p = single.join_reads, sharded.join_reads
    single.gather_features(ty, ids)
    sharded.gather_features(ty, ids)
    assert single.join_reads - r0s == sharded.join_reads - r0p == 32


# ------------------------------------- vectorized fit / cut_stats (§13)


def test_vectorized_fit_matches_reference_assignment(graph):
    """The chunked multi-pass fit is bit-identical to the reference greedy
    loop — same owner for every node — including chunk sizes that split
    the frontier mid-degree-class."""
    for P in (1, 2, 4):
        for chunk_size in (7, 64, 8192):
            ref = GraphPartitioner(P, "greedy")._fit_reference(graph)
            new = GraphPartitioner(P, "greedy").fit(graph,
                                                    chunk_size=chunk_size)
            assert set(ref._dense) == set(new._dense)
            for tid in ref._dense:
                assert np.array_equal(ref._dense[tid], new._dense[tid]), (
                    P, chunk_size, tid)


def test_cut_stats_matches_python_reference(graph):
    """The grouped-numpy cut_stats equals a per-edge Python walk on every
    reported field."""
    part = GraphPartitioner(3, "greedy").fit(graph)
    s = part.cut_stats(graph)
    cut = tot = 0
    for (stype, dtype), csr in graph.adj.items():
        for u in range(graph.num_nodes[stype]):
            for v in csr.neighbors(u):
                tot += 1
                if part.shard_of(stype, u) != part.shard_of(dtype, int(v)):
                    cut += 1
    sizes = [0] * part.num_shards
    for tname, n in graph.num_nodes.items():
        for i in range(n):
            sizes[part.shard_of(tname, i)] += 1
    assert s["cut_edges"] == cut
    assert s["total_edges"] == tot
    assert s["shard_sizes"] == sizes
    assert s["cut_fraction"] == pytest.approx(cut / tot)
    assert s["balance"] == pytest.approx(max(sizes) / (sum(sizes) / len(sizes)))


def test_assign_overrides_shadow_dense_owner(graph):
    """Explicit reshard assignments shadow the fitted dense owner arrays,
    on both the scalar and the vectorized ownership paths."""
    part = GraphPartitioner(2, "greedy").fit(graph)
    key = ("job", 3)
    base = part.shard_of(*key)
    assert int(part._dense[NODE_TYPE_ID["job"]][3]) == base  # dense-covered
    part.assign([key], 1 - base)
    assert part.shard_of(*key) == 1 - base
    own = part.shard_array(np.array([NODE_TYPE_ID["job"]]), np.array([3]))
    assert int(own[0]) == 1 - base
    # unrelated dense-covered keys are untouched
    assert part.shard_of("job", 4) == int(part._dense[NODE_TYPE_ID["job"]][4])


def test_refit_precedence_contract(graph):
    """The §13 precedence contract: overrides survive ``add_shard`` (frozen
    hash modulus, nothing re-homes implicitly) but are RESET by ``fit`` —
    a refit is a global re-optimization and must not be shadowed by stale
    migration pins."""
    part = GraphPartitioner(2, "greedy").fit(graph)
    key = ("member", 5)
    new_shard = part.add_shard()
    part.assign([key], new_shard)
    assert part.shard_of(*key) == new_shard          # survives add_shard
    before = {t: a.copy() for t, a in part._dense.items()}
    for t, a in before.items():                      # add_shard moved nothing
        assert np.array_equal(part._dense[t], a)
    part.fit(graph)                                  # refit: overrides reset
    fresh = GraphPartitioner(3, "greedy").fit(graph)
    for tid in fresh._dense:
        assert np.array_equal(part._dense[tid], fresh._dense[tid])
    tid = NODE_TYPE_ID["member"]
    assert part.shard_of(*key) == int(part._dense[tid][5])
    assert not part._over                            # no pins survive a refit
