"""Resilience layer (DESIGN.md §12): crash/warm-restart parity, elastic
resharding, overload control, and the fault-injection harness."""
import numpy as np
import jax
import pytest
from dataclasses import replace

from repro.configs.linksage import smoke as gnn_smoke
from repro.core import encoder as enc
from repro.core.embeddings import StalenessPolicy, tables_bitwise_equal
from repro.core.graph import NODE_TYPE_ID
from repro.core.partition import GraphPartitioner
from repro.data import (GraphGenConfig, generate_job_marketplace_graph,
                        marketplace_event_stream)
from repro.serving import (BatchPolicy, DynamicBatcher, FaultInjector,
                           LoadConfig, LoadGenerator, ResultCache, Router,
                           ScoreRequest, ShardedNearline, hottest_shard,
                           load_cluster_checkpoint, merge_shards,
                           restore_cluster, run_with_faults,
                           save_cluster_checkpoint, serve_trace, split_shard,
                           simulate_open_loop)


@pytest.fixture(scope="module")
def setup():
    g, _ = generate_job_marketplace_graph(
        GraphGenConfig(num_members=100, num_jobs=32, seed=5))
    cfg = replace(gnn_smoke(), feat_dim=g.feat_dim)
    params = enc.encoder_init(jax.random.PRNGKey(0), cfg)
    return g, cfg, params


def _events(g, seed=2, n=40):
    return marketplace_event_stream(g, np.random.default_rng(seed), n,
                                    job_every=10)


def _cluster(g, cfg, params, P, *, strategy="hash", jit=False):
    part = GraphPartitioner(P, strategy)
    if strategy == "greedy":
        part.fit(g)
    cl = ShardedNearline(cfg, params, part, micro_batch=8, seed=13,
                         policy=StalenessPolicy(closure_radius=None),
                         jit_encoder=jit)
    cl.bootstrap_from_graph(g)
    return cl


def _publish(cl, events):
    for ev in events:
        cl.topic.publish(ev)


# --------------------------------------------- partitioner elasticity


def test_partitioner_add_shard_freezes_hash_map():
    part = GraphPartitioner(3, "hash")
    before = {("member", i): part.shard_of("member", i) for i in range(64)}
    q = part.add_shard()
    assert q == 3 and part.num_shards == 4
    after = {k: part.shard_of(*k) for k in before}
    assert before == after, "add_shard re-homed keys without assignment"


def test_partitioner_assign_overrides_and_snapshot_roundtrip():
    part = GraphPartitioner(2, "hash")
    part.add_shard()
    part.assign([("member", 5), ("job", 0)], 2)
    assert part.shard_of("member", 5) == 2
    assert part.shard_of("job", 0) == 2
    tids = np.full(8, NODE_TYPE_ID["member"])
    owners = part.shard_array(tids, np.arange(8))
    assert owners[5] == 2
    clone = GraphPartitioner.from_snapshot(part.snapshot())
    assert [clone.shard_of("member", i) for i in range(8)] == \
           [part.shard_of("member", i) for i in range(8)]
    assert clone.shard_of("job", 0) == 2


# --------------------------------------------- snapshot / warm restart


def test_cluster_snapshot_restore_mid_stream_bit_identical(setup):
    """Crash between micro-batches: a cluster restored from a mid-stream
    snapshot (pending dirt included) finishes bit-identical to one that
    never crashed — at EVERY subsequent read point."""
    g, cfg, params = setup
    events = _events(g)
    golden = _cluster(g, cfg, params, 2)
    faulted = _cluster(g, cfg, params, 2)
    _publish(golden, events)
    _publish(faulted, events)
    golden.process(max_batches=2)
    faulted.process(max_batches=2)
    snap = faulted.snapshot()
    assert snap["topic_offset"] == 16 and faulted.pending() >= 0

    golden.process()                          # uninterrupted to the end
    faulted.process(max_batches=1)            # progress past the snapshot...
    faulted.restore(snap)                     # ...then crash + roll back
    assert faulted.topic.offsets["sharded-nearline"] == 16
    while faulted.process(max_batches=1):     # replay the suffix
        pass
    assert tables_bitwise_equal(golden.live_embeddings(),
                                faulted.live_embeddings())
    assert faulted.pending() == golden.pending() == 0


def test_snapshot_restores_pending_queue_exactly(setup):
    g, cfg, params = setup
    cl = _cluster(g, cfg, params, 2)
    _publish(cl, _events(g))
    cl.ingest()                                # dirt without recompute
    pending_before = cl.pending()
    assert pending_before > 0
    snap = cl.snapshot()
    cl.drain()
    assert cl.pending() == 0
    cl.restore(snap)
    assert cl.pending() == pending_before


def test_disk_checkpoint_cold_restart_parity(setup, tmp_path):
    """save → new process (restore_cluster from the snapshot's own config)
    → replay suffix: store union AND router reads bit-identical."""
    g, cfg, params = setup
    events = _events(g)
    golden = _cluster(g, cfg, params, 2)
    _publish(golden, events)
    golden.process()

    crashed = _cluster(g, cfg, params, 2)
    _publish(crashed, events)
    crashed.process(max_batches=3)
    save_cluster_checkpoint(crashed, str(tmp_path), 0)

    cold = restore_cluster(load_cluster_checkpoint(str(tmp_path)),
                           cfg=cfg, params=params, topic=crashed.topic,
                           jit_encoder=False)
    assert cold.num_shards == 2
    cold.process()
    assert tables_bitwise_equal(golden.live_embeddings(),
                                cold.live_embeddings())
    probe = [("member", 3), ("job", 7), ("member", 11)]
    want = Router(golden).resolve_embeddings(probe)
    got = Router(cold).resolve_embeddings(probe)
    assert all(np.array_equal(want[k], got[k]) for k in probe)


@pytest.mark.parametrize("P", [1, 2, 4])
def test_run_with_faults_kill_restart_parity(setup, P):
    g, cfg, params = setup
    events = _events(g)
    golden = _cluster(g, cfg, params, P)
    _publish(golden, events)
    golden.process()

    faulted = _cluster(g, cfg, params, P)
    _publish(faulted, events)
    inj = FaultInjector(kill_at=(1, 3))
    st = run_with_faults(faulted, injector=inj, checkpoint_every=2)
    assert st["kills"] == 2 and inj.kills == [1, 3]
    assert st["replayed"] >= 1                 # kill 3 lands past a checkpoint
    assert tables_bitwise_equal(golden.live_embeddings(),
                                faulted.live_embeddings())


def test_fault_injector_fires_each_offset_once():
    inj = FaultInjector(kill_at=(0, 2))
    fired = [inj.tick() for _ in range(5)]
    assert fired == [True, False, True, False, False]
    assert inj.kills == [0, 2] and inj.ticks == 5


# --------------------------------------------- elastic resharding


def test_split_and_merge_preserve_union_bits(setup):
    g, cfg, params = setup
    control = _cluster(g, cfg, params, 2)
    elastic = _cluster(g, cfg, params, 2)
    events = _events(g)
    for cl in (control, elastic):
        _publish(cl, events)
        cl.process()
    p = hottest_shard(elastic)
    s = split_shard(elastic)
    assert s["src"] == p and elastic.num_shards == 3 and s["moved"] > 0
    assert tables_bitwise_equal(control.live_embeddings(),
                                elastic.live_embeddings())
    m = merge_shards(elastic, s["dst"], s["src"])
    assert m["moved"] == s["moved"]
    assert len(elastic.shards[s["dst"]].registry) == 0
    assert tables_bitwise_equal(control.live_embeddings(),
                                elastic.live_embeddings())


def test_resharded_cluster_tracks_continued_stream(setup):
    """After a split, the grown cluster must keep BIT parity with a never-
    resharded control on fresh events — including events touching moved
    nodes (rings, features, and dirt migrated with them)."""
    g, cfg, params = setup
    control = _cluster(g, cfg, params, 2)
    elastic = _cluster(g, cfg, params, 2)
    for cl in (control, elastic):
        _publish(cl, _events(g))
        cl.process()
    split_shard(elastic)
    more = _events(g, seed=9, n=24)
    for cl in (control, elastic):
        _publish(cl, more)
        cl.process()
    assert tables_bitwise_equal(control.live_embeddings(),
                                elastic.live_embeddings())


def test_reshard_migrates_pending_dirt(setup):
    """Dirt enqueued before the reshard drains on the NEW owner and the
    result still matches an un-resharded control."""
    g, cfg, params = setup
    control = _cluster(g, cfg, params, 2)
    elastic = _cluster(g, cfg, params, 2)
    events = _events(g)
    for cl in (control, elastic):
        _publish(cl, events)
        cl.ingest()                            # pending dirt, no recompute
    assert elastic.pending() > 0
    q = elastic.add_shard()
    src = hottest_shard(elastic)
    moved = sorted(elastic.shards[src].registry,
                   key=lambda k: (NODE_TYPE_ID[k[0]], k[1]))[::2]
    stats = elastic.reshard({k: q for k in moved})
    assert stats["dirty"] > 0, "no dirt migrated — fixture too small"
    assert all(elastic.partitioner.shard_of(*k) == q for k in moved)
    control.drain()
    elastic.drain()
    assert tables_bitwise_equal(control.live_embeddings(),
                                elastic.live_embeddings())
    assert elastic.pending() == 0


def test_reshard_invalidates_result_cache_ball(setup):
    g, cfg, params = setup
    cl = _cluster(g, cfg, params, 2)
    cl.process()
    cache = ResultCache(512)
    router = Router(cl, cache=cache)
    keys = [("member", i) for i in range(6)] + [("job", j) for j in range(4)]
    router.resolve_embeddings(keys)
    assert len(cache) == len(keys)
    q = cl.add_shard()
    cl.reshard({("member", 0): q, ("job", 0): q})
    assert ("member", 0) not in cache and ("job", 0) not in cache
    # a re-resolve after the move still returns identical bits
    again = router.resolve_embeddings(keys)
    fresh = Router(cl).resolve_embeddings(keys)
    assert all(np.array_equal(again[k], fresh[k]) for k in keys)


# --------------------------------------------- overload control


def _req(t, m=0, jobs=(0,)):
    return ScoreRequest(time=t, member_id=m, job_ids=tuple(jobs))


def test_batcher_shed_oldest_drops_head_admits_new():
    b = DynamicBatcher(BatchPolicy(max_batch=8, max_queue=2,
                                   overload="shed_oldest"))
    assert b.submit(_req(0.0, 1)) and b.submit(_req(0.1, 2))
    assert b.submit(_req(0.2, 3))              # head (t=0.0) pays, new admitted
    assert len(b) == 2
    assert [r.member_id for r in b.pop_batch()] == [2, 3]
    m = b.metrics.summary()
    assert m["shed"] == 1 and m["shed_queue_full"] == 1
    assert m["shed_deadline"] == 0


def test_batcher_degrade_admits_past_bound_flagged():
    b = DynamicBatcher(BatchPolicy(max_batch=8, max_queue=2,
                                   overload="degrade"))
    b.submit(_req(0.0)), b.submit(_req(0.1))
    assert b.submit(_req(0.2)) and len(b) == 3
    batch = b.pop_batch()
    assert [r.degraded for r in batch] == [False, False, True]
    assert b.metrics.degraded == 1 and b.metrics.shed == 0


def test_batcher_deadline_shed_at_pop():
    b = DynamicBatcher(BatchPolicy(max_batch=4, max_wait_s=0.01,
                                   shed_after_s=0.05))
    for i in range(3):
        b.submit(_req(0.01 * i, i))
    batch = b.pop_batch(now=0.06)              # t=0.00 expired (0.06 > 0.05)
    assert [r.member_id for r in batch] == [1, 2]
    m = b.metrics.summary()
    assert m["shed_deadline"] == 1 and m["shed"] == 1
    assert m["shed_queue_full"] == 0


def test_per_reason_shed_counters_under_bursty_arrivals():
    """A flash-crowd trace through a tiny bounded queue: queue-full sheds
    during the burst, deadline sheds on the backlog — both surfaced
    separately in the batcher summary AND the SLO report."""
    gen = LoadGenerator(
        LoadConfig(rate_hz=500.0, num_requests=96, candidates=2, seed=3,
                   burst_at_s=0.02, burst_factor=8.0, burst_duration_s=0.1),
        num_members=50, num_jobs=20)
    b = DynamicBatcher(BatchPolicy(max_batch=4, max_wait_s=0.002,
                                   max_queue=6, shed_after_s=0.02))

    class _NullRouter:
        def score_batch(self, requests):
            return [np.zeros(len(r.job_ids)) for r in requests]

    rep = simulate_open_loop(_NullRouter(), b, gen.requests(), slo_ms=10.0,
                             service_s=0.03)
    s = b.metrics.summary()
    assert s["shed_queue_full"] > 0 and s["shed_deadline"] > 0
    assert s["shed"] == s["shed_queue_full"] + s["shed_deadline"]
    assert rep.shed_queue_full == s["shed_queue_full"]
    assert rep.shed_deadline == s["shed_deadline"]
    assert rep.completed + rep.shed == 96


def test_degrade_mode_serves_stale_records_end_to_end(setup):
    g, cfg, params = setup
    cl = _cluster(g, cfg, params, 2)
    cl.publish_version()                       # records exist -> stale path
    gen = LoadGenerator(LoadConfig(rate_hz=2000.0, num_requests=64,
                                   candidates=3, seed=7, zipf=1.4),
                        num_members=100, num_jobs=32)
    pol = BatchPolicy(max_batch=4, max_wait_s=0.002, max_queue=4,
                      overload="degrade")
    rep, batcher, router = serve_trace(
        cl, gen.requests(), policy=pol, slo_ms=25.0,
        service_s=lambda b: 0.004 * sum(not r.degraded for r in b) + 1e-4)
    assert rep.degraded > 0 and rep.shed == 0
    assert rep.completed == 64                 # degrade converts, never drops
    assert router.stale_served_keys > 0
    assert router.degraded_requests == rep.degraded
    agg = cl.aggregate_metrics()
    assert agg.requests_degraded == rep.degraded
    assert "requests_degraded" in agg.summary()
    assert "shed_queue_full" in agg.summary()


def test_degraded_bits_match_published_records(setup):
    """What the stale path serves IS the pinned published record — bit
    equality against the store, and fresh-resolve fallback for cold keys."""
    g, cfg, params = setup
    cl = _cluster(g, cfg, params, 2)
    cl.publish_version()
    router = Router(cl)
    keys = [("member", 1), ("job", 2)]
    out = router.resolve_stale(keys)
    for k in keys:
        assert np.array_equal(out[k], cl.record(*k).emb)
    assert router.stale_served_keys == 2 and router.stale_fallback_keys == 0


# --------------------------------------------- serve_trace teardown


def test_serve_trace_teardown_runs_on_mid_trace_crash(setup, monkeypatch):
    """A request that raises mid-trace must not leak the router's cache
    into the cluster's invalidation fan-out (try/finally teardown)."""
    g, cfg, params = setup
    cl = _cluster(g, cfg, params, 2)
    boom = RuntimeError("scoring exploded")

    def _explode(self, requests):
        raise boom

    monkeypatch.setattr(Router, "score_batch", _explode)
    reqs = [_req(0.001 * i, i % 10, (i % 5,)) for i in range(8)]
    with pytest.raises(RuntimeError):
        serve_trace(cl, reqs, cache=ResultCache(64))
    assert cl.caches == [], "crashed trace leaked its cache"


def test_loadgen_default_draws_unchanged_by_new_knobs():
    """zipf/burst default OFF must reproduce the original vectorized draw
    sequence bit-for-bit (regression pin for the §10 benchmarks)."""
    c = LoadConfig(rate_hz=100.0, num_requests=32, candidates=4, seed=11)
    reqs = LoadGenerator(c, num_members=40, num_jobs=16).requests()
    rng = np.random.default_rng((11, 0x10AD))
    times = np.cumsum(rng.exponential(1.0 / 100.0, 32))
    members = rng.integers(0, 40, 32)
    jobs = rng.integers(0, 16, (32, 4))
    for i, r in enumerate(reqs):
        assert r.time == float(times[i]) and r.member_id == int(members[i])
        assert r.job_ids == tuple(int(j) for j in jobs[i])


def test_loadgen_zipf_skews_and_burst_compresses():
    base = LoadConfig(rate_hz=100.0, num_requests=200, candidates=2, seed=1)
    uni = LoadGenerator(base, num_members=500, num_jobs=50).requests()
    skew = LoadGenerator(replace(base, zipf=1.2), num_members=500,
                         num_jobs=50).requests()
    top = lambda rs: max(np.bincount([r.member_id for r in rs],
                                     minlength=500))
    assert top(skew) > top(uni)                # a hot member emerges
    burst = LoadGenerator(replace(base, burst_at_s=0.5, burst_factor=10.0,
                                  burst_duration_s=0.5),
                          num_members=500, num_jobs=50).requests()
    inside = sum(1 for r in burst if 0.5 <= r.time < 1.0)
    flat = sum(1 for r in uni if 0.5 <= r.time < 1.0)
    assert inside > flat                       # arrivals pile into the window
    # both deterministic per seed
    again = LoadGenerator(replace(base, zipf=1.2), num_members=500,
                          num_jobs=50).requests()
    assert [r.member_id for r in again] == [r.member_id for r in skew]
