"""Device-resident memory hierarchy (DESIGN.md §11): SlabCache admission/
eviction, CachedEngine bit-parity, hop dedupe, tier-2 embed cache
invalidation, cache-aware sampling distribution contract, counters."""
from dataclasses import replace

import numpy as np
import pytest

from conftest import assert_tiles_equal, make_parity_case
from repro.configs.linksage import CONFIG
from repro.core.cache import (CacheConfig, CachedEngine, SlabCache,
                              as_slab_cache)
from repro.core.embeddings import (LifecycleMetrics, StalenessPolicy,
                                   tables_bitwise_equal)
from repro.core.engine import SnapshotEngine, StreamingEngine, TileBuilder
from repro.core.graph import NODE_TYPE_ID, NODE_TYPES
from repro.data import GraphGenConfig, generate_job_marketplace_graph
from repro.data.synthetic_graph import marketplace_event_stream


@pytest.fixture(scope="module")
def graph():
    g, _ = generate_job_marketplace_graph(
        GraphGenConfig(num_members=150, num_jobs=50, seed=1))
    return g


@pytest.fixture(scope="module")
def small_cfg(graph):
    return replace(CONFIG, hidden_dim=32, embed_dim=16, fanouts=(4, 3),
                   feat_dim=graph.feat_dim)


@pytest.fixture(scope="module")
def enc_params(small_cfg):
    import jax
    from repro.core.linksage import linksage_init
    return linksage_init(jax.random.PRNGKey(0), small_cfg)["encoder"]


def _engine(graph, **kw):
    eng = StreamingEngine(graph.feat_dim, max_neighbors=32, **kw)
    eng.bootstrap_from_graph(graph)
    return eng


# ---------------------------------------------------------------- SlabCache


def test_slab_insert_lookup_gather_roundtrip():
    c = SlabCache(4, slots=8)
    rows = np.arange(12, dtype=np.float32).reshape(3, 4)
    assert c.insert(np.array([0, 0, 1]), np.array([5, 6, 5]), rows) == 3
    slots = c.lookup(np.array([0, 1, 0, 2]), np.array([6, 5, 99, 5]))
    assert slots[2] == -1 and slots[3] == -1           # absent id / type
    np.testing.assert_array_equal(c.gather(slots[:2]),
                                  rows[[1, 2]])
    assert len(c) == 3


def test_slab_admission_learned_from_miss_traffic():
    c = SlabCache(4, slots=8, admit_after=2)
    t, i = np.array([0]), np.array([7])
    assert not c.note_misses(t, i).any()               # 1st miss: below thr
    assert not c.note_misses(t, i).any()               # 2nd: at thr
    assert c.note_misses(t, i).all()                   # 3rd: admitted
    # inf never admits (the hit-rate-0 parity arm)
    c2 = SlabCache(4, slots=8, admit_after=float("inf"))
    for _ in range(50):
        assert not c2.note_misses(t, i).any()


def test_slab_clock_eviction_second_chance():
    c = SlabCache(2, slots=2, policy="clock")
    c.insert(np.zeros(2, int), np.array([0, 1]),
             np.ones((2, 2), np.float32))
    # reference key 0 only; the sweep must clear ref bits and evict key 1
    c.touch(c.lookup(np.array([0]), np.array([0])))
    c._ref[c.lookup(np.array([0]), np.array([1]))] = 0
    c.insert(np.zeros(1, int), np.array([2]), np.ones((1, 2), np.float32))
    assert c.lookup(np.array([0]), np.array([0]))[0] >= 0     # survived
    assert c.lookup(np.array([0]), np.array([1]))[0] == -1    # evicted
    assert c.evictions == 1


def test_slab_lfu_evicts_min_use():
    c = SlabCache(2, slots=2, policy="lfu")
    c.insert(np.zeros(2, int), np.array([0, 1]), np.ones((2, 2), np.float32))
    for _ in range(5):
        c.touch(c.lookup(np.array([0]), np.array([0])))
    c.insert(np.zeros(1, int), np.array([2]), np.ones((1, 2), np.float32))
    assert c.lookup(np.array([0]), np.array([0]))[0] >= 0
    assert c.lookup(np.array([0]), np.array([1]))[0] == -1


def test_slab_invalidate_frees_slot_and_counts():
    c = SlabCache(3, slots=4)
    c.insert(np.array([1]), np.array([9]), np.ones((1, 3), np.float32))
    assert c.invalidate(1, 9) and not c.invalidate(1, 9)
    assert c.lookup(np.array([1]), np.array([9]))[0] == -1
    assert c.invalidations == 1 and len(c) == 0
    # freed slot is reused before any eviction
    c.insert(np.array([2]), np.array([3]), np.ones((1, 3), np.float32))
    assert c.evictions == 0


def test_slab_device_mirror_matches_host():
    c = SlabCache(5, slots=6, device=True)
    rng = np.random.default_rng(0)
    c.insert(np.zeros(4, int), np.arange(4),
             rng.normal(size=(4, 5)).astype(np.float32))
    slots = c.lookup(np.zeros(4, int), np.arange(4))
    np.testing.assert_array_equal(np.asarray(c.gather_device(slots)),
                                  c.gather(slots))
    assert c.device_table().shape == (6, 5)


def test_slab_zero_slots_disabled():
    c = SlabCache(4, slots=0)
    assert c.insert(np.array([0]), np.array([0]),
                    np.ones((1, 4), np.float32)) == 0
    assert (c.lookup(np.array([0]), np.array([0])) == -1).all()


def test_as_slab_cache_spec_forms():
    assert as_slab_cache(None, 4, name="x") is None
    c = SlabCache(4, slots=2)
    assert as_slab_cache(c, 4, name="x") is c
    assert as_slab_cache(16, 4, name="x").slots == 16
    assert as_slab_cache(CacheConfig(slots=3, policy="lfu"), 4,
                         name="x").config.policy == "lfu"


# ------------------------------------------------------------- CachedEngine


def test_cached_gather_bit_parity_hit_miss_evict(graph):
    """Tiny slab forces constant eviction churn; every gather — hit, miss,
    post-eviction re-fetch — must be bit-identical to the uncached join."""
    ref, eng = _engine(graph), _engine(graph)
    ce = CachedEngine(eng, SlabCache(graph.feat_dim, slots=16, admit_after=0))
    rng = np.random.default_rng(2)
    for it in range(60):
        n = int(rng.integers(1, 32))
        ty = rng.integers(0, 2, n)
        ids = np.where(ty == 0, rng.integers(0, 150, n),
                       rng.integers(0, 50, n))
        np.testing.assert_array_equal(ce.gather_features(ty, ids),
                                      ref.gather_features(ty, ids),
                                      err_msg=f"iter {it}")
    assert ce.cache.hits > 0 and ce.cache.evictions > 0


def test_cached_put_feature_invalidates_before_write(graph):
    eng = _engine(graph)
    ce = CachedEngine(eng, SlabCache(graph.feat_dim, slots=64, admit_after=0))
    ty, ids = np.zeros(1, int), np.array([3])
    ce.gather_features(ty, ids)                         # miss + admit
    old = ce.gather_features(ty, ids)                   # hit
    new = (old[0] + 1.0).astype(np.float32)
    ce.put_feature(0, 3, new)
    np.testing.assert_array_equal(ce.gather_features(ty, ids)[0], new)
    assert ce.cache.invalidations == 1


def test_cached_engine_delegates_protocol_and_oracle_reads(graph):
    eng = _engine(graph)
    ce = CachedEngine(eng, SlabCache(graph.feat_dim, slots=8))
    assert ce.feat_dim == eng.feat_dim
    assert ce.join_reads == eng.join_reads
    # scalar oracle reads bypass the slab entirely
    np.testing.assert_array_equal(ce.get_feature(0, 1), eng.get_feature(0, 1))
    assert ce.neighbors(0, 1) == eng.neighbors(0, 1)
    ty, ids = np.zeros(4, np.int64), np.arange(4)
    np.testing.assert_array_equal(ce.counts(ty, ids), eng.counts(ty, ids))


def test_cached_engine_metrics_mirror(graph):
    eng = _engine(graph)
    m = LifecycleMetrics()
    ce = CachedEngine(eng, SlabCache(graph.feat_dim, slots=32, admit_after=0),
                      metrics=m)
    ty, ids = np.zeros(8, int), np.arange(8)
    ce.gather_features(ty, ids)
    ce.gather_features(ty, ids)
    assert m.feature_cache_misses == 8 and m.feature_cache_hits == 8
    s = m.summary()
    assert s["feature_cache_hit_rate"] == 0.5
    assert {"feature_cache_evictions", "embed_cache_hit_rate"} <= s.keys()


# ------------------------------------------------- hop dedupe (TileBuilder)


@pytest.mark.parametrize("seed", [0, 4, 9])
def test_tile_hop_dedupe_bit_parity(seed):
    """The deduped hop gather (one engine read per distinct key, scattered
    back via the inverse map) is bit-identical to the duplicated oracle on
    both backends."""
    final, streaming = make_parity_case(seed, num_events=25)
    rng = np.random.default_rng((seed, 2))
    n = 10
    types = rng.integers(0, 2, n).astype(np.int64)
    ids = np.array([rng.integers(0, final.num_nodes[NODE_TYPES[t]])
                    for t in types])
    for engine in (streaming, SnapshotEngine(final)):
        for fanouts in [(5, 3), (3, 2, 2)]:
            u = rng.random((n, TileBuilder(engine, fanouts).slab_width))
            assert_tiles_equal(
                TileBuilder(engine, fanouts, dedupe=True).build(
                    types, ids, uniforms=u),
                TileBuilder(engine, fanouts, dedupe=False).build(
                    types, ids, uniforms=u),
                msg=f"seed={seed} fanouts={fanouts} ")


def test_tile_hop_dedupe_reduces_snapshot_reads(graph):
    eng = SnapshotEngine(graph)
    tb = TileBuilder(eng, (8, 4))
    r0 = eng.join_reads
    tb.build("member", np.zeros(16, np.int64),
             rng=np.random.default_rng(0))          # 16 copies of node 0
    deduped = eng.join_reads - r0
    eng2 = SnapshotEngine(graph)
    TileBuilder(eng2, (8, 4), dedupe=False).build(
        "member", np.zeros(16, np.int64), rng=np.random.default_rng(0))
    assert deduped < eng2.join_reads


# ------------------------------------------------------- nearline wiring


def _replay(cfg, params, graph, *, zipf=1.2, n=120, seed=11, **kw):
    from repro.core.nearline import NearlineInference
    nl = NearlineInference(cfg, params, micro_batch=16, max_neighbors=32,
                           seed=7, **kw)
    nl.bootstrap_from_graph(graph)
    rng = np.random.default_rng(seed)
    for ev in marketplace_event_stream(graph, rng, n, zipf=zipf):
        nl.topic.publish(ev)
    nl.process()
    return nl


def test_nearline_cached_replay_bit_parity(graph, small_cfg, enc_params):
    base = _replay(small_cfg, enc_params, graph)
    cached = _replay(small_cfg, enc_params, graph, feature_cache=512,
                     embed_cache=512)
    assert tables_bitwise_equal(base.embedding_store.live_embeddings(),
                                cached.embedding_store.live_embeddings())
    assert cached.metrics.feature_cache_hits > 0
    # store-side ops view surfaces both attached slabs
    s = cached.embedding_store.summary()
    assert s["feature-cache"]["hits"] == cached.feature_cache.hits
    assert "embed-cache" in s


def test_nearline_hit_rate_zero_arm_parity(graph, small_cfg, enc_params):
    """admit_after=inf: the slab never admits — hit rate exactly 0, bits
    identical (the bench's cold parity row)."""
    base = _replay(small_cfg, enc_params, graph)
    cold = _replay(small_cfg, enc_params, graph,
                   feature_cache=CacheConfig(slots=512,
                                             admit_after=float("inf")))
    assert cold.metrics.feature_cache_hits == 0
    assert cold.metrics.feature_cache_misses > 0
    assert tables_bitwise_equal(base.embedding_store.live_embeddings(),
                                cold.embedding_store.live_embeddings())


def test_nearline_prewarm_high_hit_rate_parity(graph, small_cfg, enc_params):
    """Prewarming every snapshot node gives a near-1 steady hit rate (only
    fresh-job features and invalidated writes miss); bits identical (the
    bench's hot parity row)."""
    base = _replay(small_cfg, enc_params, graph)
    from repro.core.nearline import NearlineInference
    hot = NearlineInference(small_cfg, enc_params, micro_batch=16,
                            max_neighbors=32, seed=7, feature_cache=8192)
    hot.bootstrap_from_graph(graph)
    for tname in NODE_TYPES:
        n = graph.num_nodes.get(tname, 0)
        if n:
            hot.engine.prewarm(np.full(n, NODE_TYPE_ID[tname]), np.arange(n))
    rng = np.random.default_rng(11)
    for ev in marketplace_event_stream(graph, rng, 120, zipf=1.2):
        hot.topic.publish(ev)
    hot.process()
    m = hot.metrics
    rate = m.feature_cache_hits / (m.feature_cache_hits
                                   + m.feature_cache_misses)
    assert rate > 0.9
    assert tables_bitwise_equal(base.embedding_store.live_embeddings(),
                                hot.embedding_store.live_embeddings())


def test_metrics_setter_repoints_cache_mirror(graph, small_cfg, enc_params):
    from repro.core.nearline import NearlineInference
    nl = NearlineInference(small_cfg, enc_params, feature_cache=64)
    nl.bootstrap_from_graph(graph)
    nl.metrics = LifecycleMetrics()            # what every bench replay does
    nl.engine.gather_features(np.zeros(4, int), np.arange(4))
    assert nl.metrics.feature_cache_misses == 4


# ------------------------------------------------------------ tier 2 cache


def test_embed_cache_hits_are_bit_identical(graph, small_cfg, enc_params):
    from repro.core.nearline import NearlineInference
    nl = NearlineInference(small_cfg, enc_params, micro_batch=16,
                           max_neighbors=32, seed=7, embed_cache=256)
    nl.bootstrap_from_graph(graph)
    keys = [("member", i) for i in range(8)]
    e1 = nl.lifecycle.encode_nodes(keys)       # cold: all misses, admitted
    e2 = nl.lifecycle.encode_nodes(keys)       # warm: all hits
    np.testing.assert_array_equal(e1, e2)
    assert nl.metrics.embed_cache_hits == 8
    assert nl.metrics.embed_cache_misses == 8


def test_embed_cache_dirty_ball_invalidation(graph, small_cfg, enc_params):
    """An event must drop every cached embedding in its FULL K-hop ball even
    under the cheap radius-0 recompute policy: a later read recomputes and
    matches an uncached lifecycle at the same graph state."""
    from repro.core.nearline import Event, NearlineInference
    mk = lambda **kw: NearlineInference(
        small_cfg, enc_params, micro_batch=16, max_neighbors=32, seed=7,
        policy=StalenessPolicy(closure_radius=0), **kw)
    cached, plain = mk(embed_cache=256), mk()
    for nl in (cached, plain):
        nl.bootstrap_from_graph(graph)
    keys = [("member", i) for i in range(6)] + [("job", i) for i in range(6)]
    cached.lifecycle.encode_nodes(keys)        # warm the tier-2 slab
    ev = Event(time=1.0, kind="engagement",
               payload={"member_id": 2, "job_id": 3})
    for nl in (cached, plain):
        nl.topic.publish(ev)
        nl.process()
    np.testing.assert_array_equal(cached.lifecycle.encode_nodes(keys),
                                  plain.lifecycle.encode_nodes(keys))


# ---------------------------------------------------------------- sharded


def test_sharded_cached_replay_bit_parity(graph, small_cfg, enc_params):
    from repro.core.partition import GraphPartitioner
    from repro.serving.cluster import ShardedNearline
    base = _replay(small_cfg, enc_params, graph)

    cl = ShardedNearline(small_cfg, enc_params, GraphPartitioner(3, "hash"),
                         micro_batch=16, max_neighbors=32, seed=7,
                         feature_cache=256, embed_cache=256)
    cl.bootstrap_from_graph(graph)
    rng = np.random.default_rng(11)
    for ev in marketplace_event_stream(graph, rng, 120, zipf=1.2):
        cl.topic.publish(ev)
    cl.process()
    assert tables_bitwise_equal(base.embedding_store.live_embeddings(),
                                cl.live_embeddings())
    agg = cl.aggregate_metrics()
    assert agg.feature_cache_hits > 0
    assert len(cl.feature_caches) == 3 and len(cl.embed_caches) == 3
    assert agg.summary()["feature_cache_hit_rate"] > 0


def test_sharded_rejects_shared_slab_instance(small_cfg, enc_params):
    from repro.core.partition import GraphPartitioner
    from repro.serving.cluster import ShardedNearline
    with pytest.raises(AssertionError):
        ShardedNearline(small_cfg, enc_params, GraphPartitioner(2, "hash"),
                        feature_cache=SlabCache(small_cfg.feat_dim, slots=4))


# ---------------------------------------------------------------- trainer


def test_trainer_feature_cache_bit_parity(graph, small_cfg):
    from repro.core.linksage import LinkSAGETrainer
    a = LinkSAGETrainer(small_cfg, graph, seed=3)
    b = LinkSAGETrainer(small_cfg, graph, seed=3, feature_cache=1024,
                        prefetch=2)
    ha = a.train(4, batch_size=32)
    hb = b.train(4, batch_size=32)
    assert [x["loss"] for x in ha] == [y["loss"] for y in hb]
    assert b.feature_cache.hits > 0


# -------------------------------------------- cache-aware sampling contract


def _marginal_counts(engine, tid, nid, grid_mult=8):
    """Exact pick histogram over a uniform grid with G = mult·deg points:
    floor(u·deg) visits every j exactly ``mult`` times, so two samplers
    agree on marginals iff they agree on these counts."""
    from collections import Counter
    deg = int(engine.counts(np.array([tid]), np.array([nid]))[0])
    if deg == 0:
        return Counter(), 0
    G = grid_mult * deg
    us = ((np.arange(G) + 0.5) / G).reshape(-1, 1)
    t, i, m = engine.sample_batched(np.full(G, tid), np.full(G, nid), 1, us)
    assert m.all()
    return Counter(zip(t.reshape(-1).tolist(), i.reshape(-1).tolist())), deg


def test_cache_aware_sampling_distribution_contract(graph):
    """Same uniforms → same MARGINAL sampling distribution: the cached-first
    permutation reorders an equiprobable candidate set, so exact per-
    neighbor pick counts over a full uniform grid match the passthrough
    oracle for every node — warm or cold."""
    eng = _engine(graph)
    oracle = CachedEngine(eng, SlabCache(graph.feat_dim, slots=128,
                                         admit_after=0),
                          sampling="passthrough")
    aware = CachedEngine(eng, SlabCache(graph.feat_dim, slots=128,
                                        admit_after=0),
                         sampling="cache_aware")
    # warm the aware slab with a biased subset so residency actually reorders
    rng = np.random.default_rng(5)
    aware.gather_features(np.ones(20, int), rng.integers(0, 50, 20))
    checked = 0
    for tid, num in ((0, 30), (1, 20)):
        for nid in range(num):
            c_o, deg = _marginal_counts(oracle, tid, nid)
            c_a, _ = _marginal_counts(aware, tid, nid)
            assert c_o == c_a, (tid, nid)
            if deg:
                checked += 1
                # counts are 8 × ring multiplicity (multi-edges allowed)
                assert all(v % 8 == 0 for v in c_o.values())
    assert checked > 10


def test_cache_aware_requires_ring_backend(graph):
    with pytest.raises(AssertionError):
        CachedEngine(SnapshotEngine(graph),
                     SlabCache(graph.feat_dim, slots=4),
                     sampling="cache_aware")


def test_nearline_cache_aware_arm_runs(graph, small_cfg, enc_params):
    """The distributional arm serves end-to-end (no parity claim — the
    oracle arm is the passthrough replay above)."""
    nl = _replay(small_cfg, enc_params, graph, n=60, feature_cache=512,
                 cache_sampling="cache_aware")
    assert len(nl.embedding_store) > 0
    assert nl.metrics.feature_cache_hits > 0


# ------------------------------------------------------- property (hypothesis)


@pytest.mark.parametrize("_", [0])
def test_property_cached_gather_always_bit_identical(_):
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(seed=st.integers(0, 2**16), slots=st.integers(1, 12),
           admit=st.integers(0, 2),
           policy=st.sampled_from(["clock", "lfu"]))
    @settings(max_examples=25, deadline=None)
    def run(seed, slots, admit, policy):
        final, _ = make_parity_case(seed, num_events=10)
        ref = StreamingEngine(final.feat_dim, max_neighbors=16)
        eng = StreamingEngine(final.feat_dim, max_neighbors=16)
        for e in (ref, eng):
            e.bootstrap_from_graph(final)
        ce = CachedEngine(eng, SlabCache(final.feat_dim, slots=slots,
                                         admit_after=admit, policy=policy,
                                         device=False))
        rng = np.random.default_rng((seed, 0xCA))
        nm, nj = final.num_nodes["member"], final.num_nodes["job"]
        for step in range(30):
            op = rng.integers(0, 4)
            if op == 0:                        # feature rewrite (invalidate)
                tid = int(rng.integers(0, 2))
                nid = int(rng.integers(0, nj if tid else nm))
                feat = rng.normal(size=final.feat_dim).astype(np.float32)
                ce.put_feature(tid, nid, feat)
                ref.put_feature(tid, nid, feat)
            elif op == 1:                      # ring append (no cache effect)
                m, j = int(rng.integers(0, nm)), int(rng.integers(0, nj))
                ce.add_edge("member", m, "job", j)
                ref.add_edge("member", m, "job", j)
            else:                              # gather with duplicates
                n = int(rng.integers(1, 16))
                ty = rng.integers(0, 2, n)
                ids = np.where(ty == 0, rng.integers(0, nm, n),
                               rng.integers(0, nj, n))
                np.testing.assert_array_equal(
                    ce.gather_features(ty, ids),
                    ref.gather_features(ty, ids),
                    err_msg=f"seed={seed} step={step}")

    run()
