"""Hypothesis property-based tests on system invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import decoder as dec
from repro.core.eval import auc
from repro.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(n=st.integers(2, 40), f=st.integers(1, 12), d=st.integers(1, 48),
       seed=st.integers(0, 2**16))
def test_neighbor_mean_bounded_by_extremes(n, f, d, seed):
    """Masked mean stays inside [min, max] of the valid neighbors."""
    rng = np.random.default_rng(seed)
    feats = jnp.asarray(rng.normal(size=(n, f, d)).astype(np.float32))
    mask = jnp.asarray((rng.random((n, f)) < 0.6).astype(np.float32))
    out = np.asarray(ref.neighbor_mean(feats, mask))
    fa = np.asarray(feats)
    ma = np.asarray(mask) > 0
    for i in range(n):
        if not ma[i].any():
            assert np.all(out[i] == 0)
            continue
        vals = fa[i][ma[i]]
        assert np.all(out[i] <= vals.max(0) + 1e-5)
        assert np.all(out[i] >= vals.min(0) - 1e-5)


@given(n=st.integers(1, 20), f=st.integers(1, 8), d=st.integers(1, 32),
       seed=st.integers(0, 2**16))
def test_neighbor_attention_is_convex_combination(n, f, d, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(n, f, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(n, f, d)).astype(np.float32))
    mask = jnp.asarray((rng.random((n, f)) < 0.7).astype(np.float32))
    out = np.asarray(ref.neighbor_attention(q, k, v, mask))
    va, ma = np.asarray(v), np.asarray(mask) > 0
    for i in range(n):
        if not ma[i].any():
            assert np.all(out[i] == 0)
            continue
        vals = va[i][ma[i]]
        assert np.all(out[i] <= vals.max(0) + 1e-4)
        assert np.all(out[i] >= vals.min(0) - 1e-4)


@given(s=st.integers(2, 24), window=st.integers(1, 24), seed=st.integers(0, 999))
def test_attention_causality(s, window, seed):
    """Perturbing future tokens never changes past outputs (any window)."""
    rng = np.random.default_rng(seed)
    b, h, dh = 1, 2, 8
    q = jnp.asarray(rng.normal(size=(b, h, s, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, h, s, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, h, s, dh)).astype(np.float32))
    t = s // 2
    out1 = np.asarray(ref.mha(q, k, v, causal=True, window=window))
    k2 = k.at[:, :, t:, :].add(10.0)
    v2 = v.at[:, :, t:, :].add(-5.0)
    out2 = np.asarray(ref.mha(q, k2, v2, causal=True, window=window))
    np.testing.assert_allclose(out1[:, :, :t], out2[:, :, :t], rtol=1e-5,
                               atol=1e-5)


@given(L=st.sampled_from([16, 32, 64]), chunk=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 2**16))
def test_ssd_chunk_invariance(L, chunk, seed):
    """Chunked SSD must be exactly chunk-size invariant (linear recurrence)."""
    rng = np.random.default_rng(seed)
    b, H, P, N = 1, 2, 8, 12
    x = jnp.asarray(rng.normal(size=(b, L, H, P)).astype(np.float32))
    dt = jnp.asarray((rng.random((b, L, H)) * 0.2).astype(np.float32))
    A = jnp.asarray((-rng.random(H)).astype(np.float32))
    B = jnp.asarray(rng.normal(size=(b, L, N)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(b, L, N)).astype(np.float32))
    y1, s1 = ref.ssd_scan_chunked(x, dt, A, B, C, chunk=chunk)
    y2, s2 = ref.ssd_scan(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4,
                               atol=2e-4)


@given(seed=st.integers(0, 2**16), b=st.integers(2, 16))
def test_inbatch_loss_positive_and_permutation_consistent(seed, b):
    """Permuting members AND jobs consistently leaves the in-batch loss
    unchanged (the objective depends only on the pairing)."""
    from repro.configs.linksage import CONFIG
    rng = np.random.default_rng(seed)
    m = jnp.asarray(rng.normal(size=(b, 16)).astype(np.float32))
    j = jnp.asarray(rng.normal(size=(b, 16)).astype(np.float32))
    loss = float(dec.inbatch_loss(CONFIG, m, j))
    assert loss > 0
    perm = rng.permutation(b)
    loss_p = float(dec.inbatch_loss(CONFIG, m[perm], j[perm]))
    np.testing.assert_allclose(loss, loss_p, rtol=1e-5)


@given(seed=st.integers(0, 2**16), k=st.sampled_from([(5, 3), (3, 2, 2)]),
       num_events=st.integers(0, 80))
def test_snapshot_of_final_state_matches_streaming_tiles(seed, k, num_events):
    """Random graph + random event suffix: a SnapshotEngine of the final
    state and a StreamingEngine that lived through the events build
    bit-identical K-hop tiles from the same uniform stream (the engine
    contract, DESIGN.md §8)."""
    from conftest import assert_tiles_equal, make_parity_case
    from repro.core.engine import SnapshotEngine, TileBuilder, slab_width
    from repro.core.graph import NODE_TYPES

    final, streaming = make_parity_case(seed, num_events=num_events)
    rng = np.random.default_rng((seed, 1))
    n = 12
    types = rng.integers(0, 2, n).astype(np.int64)    # member/job queries
    ids = np.array([rng.integers(0, final.num_nodes[NODE_TYPES[t]])
                    for t in types])
    u = rng.random((n, slab_width(k)))
    ta = TileBuilder(SnapshotEngine(final), k).build(types, ids, uniforms=u)
    tb = TileBuilder(streaming, k).build(types, ids, uniforms=u)
    assert_tiles_equal(ta, tb)


_SERVING_CASE: dict = {}


def _serving_case():
    """Tiny cached graph + encoder params for the restart property (one
    build per session; every example reuses it)."""
    if not _SERVING_CASE:
        from dataclasses import replace
        from repro.configs.linksage import smoke as gnn_smoke
        from repro.core import encoder as enc
        from repro.data import GraphGenConfig, generate_job_marketplace_graph
        g, _ = generate_job_marketplace_graph(
            GraphGenConfig(num_members=30, num_jobs=10, seed=4))
        cfg = replace(gnn_smoke(), feat_dim=g.feat_dim)
        _SERVING_CASE["case"] = (
            g, cfg, enc.encoder_init(jax.random.PRNGKey(0), cfg))
    return _SERVING_CASE["case"]


_GOLDEN_UNIONS: dict = {}


def _mk_cluster(g, cfg, params, P):
    from repro.core.embeddings import StalenessPolicy
    from repro.core.partition import GraphPartitioner
    from repro.serving import ShardedNearline
    cl = ShardedNearline(cfg, params, GraphPartitioner(P, "hash"),
                         micro_batch=6, seed=13,
                         policy=StalenessPolicy(closure_radius=None),
                         jit_encoder=False)
    cl.bootstrap_from_graph(g)
    return cl


@settings(max_examples=10, deadline=None)
@given(event_seed=st.integers(0, 2), P=st.sampled_from([1, 2, 4]),
       kill=st.integers(0, 5), every=st.integers(1, 2))
def test_checkpoint_kill_restore_replay_bit_identical_at_every_read(
        event_seed, P, kill, every):
    """Random event stream × random kill offset × P ∈ {1, 2, 4}: a cluster
    that checkpoints on a cadence, crashes after ``kill`` batches, restores
    its last checkpoint, and replays the event suffix is bit-identical to
    an uninterrupted run at EVERY subsequent read point (store unions
    compared after each replayed micro-batch, DESIGN.md §12)."""
    from repro.core.embeddings import tables_bitwise_equal
    from repro.data import marketplace_event_stream
    g, cfg, params = _serving_case()
    events = marketplace_event_stream(g, np.random.default_rng(event_seed),
                                      18, job_every=6)

    gkey = (event_seed, P)
    if gkey not in _GOLDEN_UNIONS:
        golden = _mk_cluster(g, cfg, params, P)
        for ev in events:
            golden.topic.publish(ev)
        unions = {}
        while golden.process(max_batches=1):
            unions[golden.topic.offsets["sharded-nearline"]] = \
                golden.live_embeddings()
        _GOLDEN_UNIONS[gkey] = unions
    unions = _GOLDEN_UNIONS[gkey]

    faulted = _mk_cluster(g, cfg, params, P)
    for ev in events:
        faulted.topic.publish(ev)
    snap = faulted.snapshot()
    batches, killed = 0, False
    while True:
        if not killed and batches == kill:
            faulted.restore(snap)              # crash: lose everything since
            killed = True
        if faulted.process(max_batches=1) == 0:
            break
        batches += 1
        off = faulted.topic.offsets["sharded-nearline"]
        assert tables_bitwise_equal(unions[off], faulted.live_embeddings()), \
            f"divergence at offset {off} (P={P}, kill={kill})"
        if batches % every == 0:
            snap = faulted.snapshot()
    assert faulted.pending() == 0


@given(seed=st.integers(0, 2**16), n=st.integers(4, 64))
def test_auc_is_shift_and_scale_invariant(seed, n):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 2, n)
    if labels.min() == labels.max():
        labels[0] = 1 - labels[0]
    scores = rng.normal(size=n)
    a1 = auc(labels, scores)
    a2 = auc(labels, scores * 3.7 + 11.0)
    np.testing.assert_allclose(a1, a2, atol=1e-12)


@given(seed=st.integers(0, 2**16))
def test_sigmoid_ce_nonnegative_and_zero_at_perfect(seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=16).astype(np.float32) * 5)
    labels = jnp.asarray((rng.random(16) < 0.5).astype(np.float32))
    ce = np.asarray(dec.sigmoid_ce(logits, labels))
    assert np.all(ce >= 0)
    big = jnp.asarray([100.0, -100.0])
    lab = jnp.asarray([1.0, 0.0])
    np.testing.assert_allclose(np.asarray(dec.sigmoid_ce(big, lab)), 0.0,
                               atol=1e-6)


@given(n=st.integers(1, 60), d=st.integers(1, 48), seed=st.integers(0, 2**16),
       scheme=st.sampled_from(["per_row", "per_dim"]),
       scale_pow=st.integers(-3, 3))
def test_int8_quantize_error_bounded_by_half_scale(n, d, seed, scheme,
                                                   scale_pow):
    """Symmetric int8 round-trip error is at most scale/2 per entry (the
    rint bound; amax/scale <= 127 exactly, so clipping never bites)."""
    from repro.core.retrieval import dequantize, quantize_int8
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(n, d)) * 10.0 ** scale_pow).astype(np.float32)
    qt = quantize_int8(x, scheme)
    bound = (qt.scales[:, None] if scheme == "per_row"
             else qt.dim_scales[None, :]) * 0.5
    assert np.all(np.abs(dequantize(qt) - x) <= bound * (1 + 1e-5) + 1e-30)
    # determinism: same bits in -> same bits out
    again = quantize_int8(x.copy(), scheme)
    assert np.array_equal(qt.codes, again.codes)
    assert np.array_equal(qt.scales, again.scales)


@given(seed=st.integers(0, 2**16), scheme=st.sampled_from(["per_row",
                                                           "per_dim"]))
def test_published_quantized_replica_deterministic_across_restore(seed,
                                                                  scheme):
    """The §14 version-pinning contract: re-deriving a published version's
    int8 replica after snapshot/restore reproduces the same bits."""
    from repro.core.embeddings import EmbeddingStore
    rng = np.random.default_rng(seed)
    store = EmbeddingStore("prop")
    for i in range(rng.integers(1, 12)):
        store.put_embedding("job", i, rng.normal(size=8).astype(np.float32),
                            0.0)
    v = store.publish()
    _, qt = store.quantized_table("job", version=v, scheme=scheme)
    restored = EmbeddingStore("prop2")
    restored.restore(store.snapshot())
    _, qt2 = restored.quantized_table("job", version=v, scheme=scheme)
    assert np.array_equal(qt.codes, qt2.codes)
    assert np.array_equal(qt.scales, qt2.scales)
