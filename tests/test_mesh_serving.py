"""Device-parallel serving fan-out (DESIGN.md §13): MeshFanout drain /
resolve parity vs the host-sequential oracle arm and the single-engine
nearline path, the ShardView accounting contract under the collective
path, ownership overrides after migration, and a real-mesh subprocess
gate (the in-process suite pins ONE device, so these tests exercise the
off-mesh fallback; the subprocess forces real devices via XLA_FLAGS)."""
import os
import subprocess
import sys
from dataclasses import replace

import jax
import numpy as np
import pytest

from repro.configs.linksage import smoke as gnn_smoke
from repro.core import encoder as enc
from repro.core.embeddings import StalenessPolicy, tables_bitwise_equal
from repro.core.nearline import NearlineInference
from repro.core.partition import GraphPartitioner
from repro.data import (GraphGenConfig, generate_job_marketplace_graph,
                        marketplace_event_stream)
from repro.serving import MeshFanout, Router, ShardedNearline

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def setup():
    g, _ = generate_job_marketplace_graph(
        GraphGenConfig(num_members=100, num_jobs=30, seed=9))
    cfg = replace(gnn_smoke(), feat_dim=g.feat_dim)
    params = enc.encoder_init(jax.random.PRNGKey(0), cfg)
    return g, cfg, params


def _cluster(g, cfg, params, P, *, strategy="hash"):
    part = GraphPartitioner(P, strategy)
    if strategy == "greedy":
        part.fit(g)
    cl = ShardedNearline(cfg, params, part, micro_batch=8, seed=13,
                         policy=StalenessPolicy(closure_radius=None))
    cl.bootstrap_from_graph(g)
    return cl


def test_attach_mesh_rejects_foreign_cluster(setup):
    g, cfg, params = setup
    a = _cluster(g, cfg, params, 2)
    b = _cluster(g, cfg, params, 2)
    fan = MeshFanout(a)
    with pytest.raises(AssertionError):
        b.attach_mesh(fan)


def test_offmesh_fallback_reports_and_empty_resolve(setup):
    """With one visible device the fanout degrades to the oracle arm:
    on_mesh False, zero mesh dispatches, empty resolve returns {}."""
    g, cfg, params = setup
    cl = _cluster(g, cfg, params, 2)
    fan = MeshFanout(cl)
    assert not fan.on_mesh          # conftest pins ONE device in-process
    assert fan.resolve([]) == {}
    assert fan.block_rounds == 0 and fan.exchange_rounds == 0


@pytest.mark.parametrize("P", [2, 4])
def test_mesh_drain_parity_with_host_and_single_engine(setup, P):
    """cluster.drain routed through the fanout (here: the fallback arm)
    stays bit-identical to an identically-fed drain_host twin AND to the
    single-engine NearlineInference table."""
    g, cfg, params = setup
    events = marketplace_event_stream(g, np.random.default_rng(3), 30,
                                      job_every=12)
    nl = NearlineInference(cfg, params, micro_batch=8, seed=13,
                           policy=StalenessPolicy(closure_radius=None))
    nl.bootstrap_from_graph(g)
    mesh_cl = _cluster(g, cfg, params, P)
    host_cl = _cluster(g, cfg, params, P)
    mesh_cl.attach_mesh(MeshFanout(mesh_cl))
    for ev in events:
        nl.topic.publish(ev)
        mesh_cl.topic.publish(ev)
        host_cl.topic.publish(ev)
    nl.process()
    mesh_cl.process()
    host_cl.process()
    assert tables_bitwise_equal(nl.embedding_store.live_embeddings(),
                                mesh_cl.live_embeddings())
    assert tables_bitwise_equal(host_cl.live_embeddings(),
                                mesh_cl.live_embeddings())
    assert mesh_cl.pending() == 0


def test_mesh_resolve_parity_and_shard_view_accounting(setup):
    """Router misses through the fanout return the oracle's bits and the
    ShardView local/remote row deltas match the host fan-out EXACTLY —
    the §13 accounting contract (tiles are built by each owner's own
    tile_fn over real keys only, so remote-row counts cannot drift)."""
    g, cfg, params = setup
    keys = [("member", 3), ("job", 7), ("member", 55), ("job", 0),
            ("member", 99), ("job", 12), ("member", 8)]
    mesh_cl = _cluster(g, cfg, params, 3)
    host_cl = _cluster(g, cfg, params, 3)
    fan = MeshFanout(mesh_cl)
    acc0_m = [(v.local_rows, v.remote_rows) for v in mesh_cl.views]
    acc0_h = [(v.local_rows, v.remote_rows) for v in host_cl.views]
    out_m = Router(mesh_cl, mesh=fan).resolve_embeddings(keys)
    out_h = Router(host_cl).resolve_embeddings(keys)
    for k in keys:
        assert np.array_equal(out_m[k], out_h[k]), k
    d_m = [(v.local_rows - a, v.remote_rows - b)
           for v, (a, b) in zip(mesh_cl.views, acc0_m)]
    d_h = [(v.local_rows - a, v.remote_rows - b)
           for v, (a, b) in zip(host_cl.views, acc0_h)]
    assert d_m == d_h
    assert any(r for _, r in d_m)       # the fan-out did cross shards


def test_mesh_resolve_after_reshard_routes_to_new_owner(setup):
    """Migrating a dense-owned key re-homes its resolution: the override
    shadows the fitted owner and the fanout resolves through the NEW
    owner's lifecycle, bits unchanged."""
    g, cfg, params = setup
    cl = _cluster(g, cfg, params, 2, strategy="greedy")
    fan = MeshFanout(cl)
    cl.attach_mesh(fan)
    key = ("member", 5)
    src = cl.partitioner.shard_of(*key)
    dst = 1 - src
    golden = Router(cl, mesh=fan).resolve_embeddings([key])[key]
    cl.reshard({key: dst})
    assert cl.partitioner.shard_of(*key) == dst
    n0 = cl.shards[dst].metrics.nodes_refreshed
    out = Router(cl, mesh=fan).resolve_embeddings([key])
    assert np.array_equal(out[key], golden)
    assert cl.shards[dst].metrics.nodes_refreshed == n0 + 1


_REAL_MESH_SCRIPT = """
import numpy as np, jax
from dataclasses import replace
from repro.configs.linksage import smoke as gnn_smoke
from repro.core import encoder as enc
from repro.core.embeddings import StalenessPolicy, tables_bitwise_equal
from repro.core.nearline import NearlineInference
from repro.core.partition import GraphPartitioner
from repro.data import (GraphGenConfig, generate_job_marketplace_graph,
                        marketplace_event_stream)
from repro.serving import MeshFanout, Router, ShardedNearline

assert len(jax.devices()) == 2, jax.devices()
g, _ = generate_job_marketplace_graph(
    GraphGenConfig(num_members=80, num_jobs=24, seed=9))
cfg = replace(gnn_smoke(), feat_dim=g.feat_dim)
params = enc.encoder_init(jax.random.PRNGKey(0), cfg)
policy = StalenessPolicy(closure_radius=None)

def cluster():
    part = GraphPartitioner(2, "hash")
    cl = ShardedNearline(cfg, params, part, micro_batch=8, seed=13,
                         policy=policy)
    cl.bootstrap_from_graph(g)
    return cl

events = marketplace_event_stream(g, np.random.default_rng(3), 20,
                                  job_every=12)
nl = NearlineInference(cfg, params, micro_batch=8, seed=13, policy=policy)
nl.bootstrap_from_graph(g)
mesh_cl, host_cl = cluster(), cluster()
fan = MeshFanout(mesh_cl)
assert fan.on_mesh
mesh_cl.attach_mesh(fan)
for ev in events:
    nl.topic.publish(ev)
    mesh_cl.topic.publish(ev)
    host_cl.topic.publish(ev)
nl.process(); mesh_cl.process(); host_cl.process()
assert fan.block_rounds > 0                      # drains went over the mesh
assert tables_bitwise_equal(nl.embedding_store.live_embeddings(),
                            mesh_cl.live_embeddings())
assert tables_bitwise_equal(host_cl.live_embeddings(),
                            mesh_cl.live_embeddings())

keys = [("member", 3), ("job", 7), ("member", 55), ("job", 0), ("member", 79)]
acc0_m = [(v.local_rows, v.remote_rows) for v in mesh_cl.views]
acc0_h = [(v.local_rows, v.remote_rows) for v in host_cl.views]
out_m = Router(mesh_cl, mesh=fan).resolve_embeddings(keys)
out_h = Router(host_cl).resolve_embeddings(keys)
assert fan.exchange_rounds == 1                  # one all_to_all dispatch
for k in keys:
    assert np.array_equal(out_m[k], out_h[k]), k
d_m = [(v.local_rows - a, v.remote_rows - b)
       for v, (a, b) in zip(mesh_cl.views, acc0_m)]
d_h = [(v.local_rows - a, v.remote_rows - b)
       for v, (a, b) in zip(host_cl.views, acc0_h)]
assert d_m == d_h, (d_m, d_h)
print("REAL-MESH-PARITY-OK")
"""


def test_real_mesh_subprocess_parity():
    """The on-mesh arm needs more devices than the in-process suite pins,
    so it runs in a subprocess under forced host-device emulation: drains
    dispatch shard_map blocks, misses go through one all_to_all, and both
    stay bit-identical to the host oracle with matching accounting."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", _REAL_MESH_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "REAL-MESH-PARITY-OK" in out.stdout
