"""Sharding-rule unit tests + tiny-mesh integration (no 512-device env)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro import parallel as par
from repro.configs import INPUT_SHAPES, get_smoke_config
from repro.launch import steps as ST
from repro.models import model_init


class FakeMesh:
    """Just enough of a Mesh for the spec rules (shape lookup)."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH_POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_param_specs_cover_every_leaf():
    for arch in ["llama3_8b", "mamba2_780m", "jamba_1_5_large_398b",
                 "phi3_5_moe_42b"]:
        cfg = get_smoke_config(arch)
        params = jax.eval_shape(lambda: model_init(jax.random.PRNGKey(0), cfg))
        specs = par.param_pspecs(cfg, params, MESH)
        leaves_p = jax.tree.leaves(params)
        leaves_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(leaves_p) == len(leaves_s)
        for p, s in zip(leaves_p, leaves_s):
            assert len(s) <= p.ndim, (s, p.shape)


def test_moe_experts_sharded_over_data():
    from repro.configs import get_config
    cfg = get_config("phi3_5_moe_42b")
    params = jax.eval_shape(lambda: model_init(jax.random.PRNGKey(0), cfg))
    specs = par.param_pspecs(cfg, params, MESH)
    moe_spec = specs["blocks"]["layers"][0]["moe"]["w_gate"]
    assert moe_spec == P(None, "data", None, "model")   # leading axis = blocks


def test_indivisible_dims_fall_back_to_replicated():
    # kv heads = 4 < model 16 → bias of wk [4*dh] may not divide: check rule
    cfg = get_smoke_config("yi_6b")
    spec = par._drop_indivisible(P("model"), (6,), MESH)
    assert spec == P(None)
    spec2 = par._drop_indivisible(P("data", "model"), (32, 48), MESH)
    assert spec2 == P(P("data").__class__() if False else "data", "model")


def test_batch_axis_selection():
    assert par._batch_axis_for(256, MESH) == "data"
    assert par._batch_axis_for(256, MESH_POD) == ("pod", "data")
    assert par._batch_axis_for(1, MESH) is None
    assert par._batch_axis_for(8, MESH_POD) is None


def test_decode_state_specs_long_context():
    from repro.configs import get_config
    cfg = ST.effective_config(get_config("llama3_8b"), INPUT_SHAPES["long_500k"])
    assert cfg.sliding_window == ST.LONG_CONTEXT_WINDOW
    state = jax.eval_shape(
        lambda: __import__("repro.models", fromlist=["init_decode_state"])
        .init_decode_state(cfg, 1, INPUT_SHAPES["long_500k"].seq_len))
    specs = par.decode_state_pspecs(cfg, state, INPUT_SHAPES["long_500k"], MESH)
    kv_specs = [s for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
                if len(s) == 5]
    assert kv_specs, "no KV specs found"
    for s in kv_specs:
        axes = s[3] if isinstance(s[3], tuple) else (s[3],)
        assert "data" in axes   # cache seq sharded over data for batch=1


def test_mamba_long_500k_state_is_constant_size():
    from repro.configs import get_config
    cfg = get_config("mamba2_780m")
    spec = ST.input_specs(cfg, INPUT_SHAPES["long_500k"])
    total = sum(np.prod(l.shape) * l.dtype.itemsize
                for l in jax.tree.leaves(spec["state"]))
    # SSM state is O(1) in seq len: must be far below a 500k KV cache
    assert total < 2 ** 31, total


def test_tiny_mesh_train_step_runs_sharded():
    """2-device mesh end-to-end: pjit train step with the production rules."""
    cfg = get_smoke_config("llama3_8b")
    devs = jax.devices()
    if len(devs) < 2:
        pytest.skip("single-device environment")
    mesh = Mesh(np.array(devs[:2]).reshape(1, 2), ("data", "model"))
    params = model_init(jax.random.PRNGKey(0), cfg)
    # ... (exercised in the dry-run; here we only check spec construction)
    specs = par.param_pspecs(cfg, params, mesh)
    assert jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
