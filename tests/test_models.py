"""Model-zoo behaviour: decode/forward consistency, sliding window, MoE
routing, SSM state handling."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from dataclasses import replace

from repro.configs import get_smoke_config
from repro.models import forward_train, init_decode_state, model_init
from repro.models import moe as M
from repro.models.transformer import (block_period, decode_step, logits_for,
                                      lm_loss, prefill, sublayer_kinds)


RNG = np.random.default_rng(0)


def _toks(cfg, b, s, seed=0):
    return jnp.asarray(np.random.default_rng(seed).integers(0, cfg.vocab_size,
                                                            (b, s)), jnp.int32)


@pytest.mark.parametrize("arch", ["llama3_8b", "mamba2_780m",
                                  "jamba_1_5_large_398b", "phi3_5_moe_42b",
                                  "qwen1_5_32b"])
def test_stepwise_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    params = model_init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    toks = _toks(cfg, B, S)
    hidden, _ = forward_train(params, cfg, toks)
    want = logits_for(params, cfg, hidden[:, -1, :])
    st = init_decode_state(cfg, B, S, dtype=jnp.float32)
    for t in range(S):
        got, st = decode_step(params, cfg, toks[:, t], st)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["llama3_8b", "jamba_1_5_large_398b"])
def test_prefill_then_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    params = model_init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    toks = _toks(cfg, B, S)
    hidden, _ = forward_train(params, cfg, toks)
    want = logits_for(params, cfg, hidden[:, -1, :])
    _, state = prefill(params, cfg, toks[:, :S - 1])
    got, _ = decode_step(params, cfg, toks[:, S - 1], state)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_sliding_window_ring_cache_consistency():
    cfg = replace(get_smoke_config("llama3_8b"), sliding_window=8)
    params = model_init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 20
    toks = _toks(cfg, B, S + 4)
    _, state = prefill(params, cfg, toks[:, :S])
    for t in range(4):
        hidden, _ = forward_train(params, cfg, toks[:, :S + t + 1])
        want = logits_for(params, cfg, hidden[:, -1, :])
        got, state = decode_step(params, cfg, toks[:, S + t], state)
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_sliding_window_cache_is_window_sized():
    cfg = replace(get_smoke_config("llama3_8b"), sliding_window=8)
    state = init_decode_state(cfg, 2, 4096, dtype=jnp.float32)
    kv = jax.tree.leaves(state.layer_state)[0]
    assert kv.shape[3] == 8   # [nblocks, B, Hkv, S_alloc, dh] -> S_alloc == window


def test_vlm_prefix_positions():
    cfg = get_smoke_config("pixtral_12b")
    params = model_init(jax.random.PRNGKey(0), cfg)
    B = 2
    prefix = jnp.asarray(RNG.normal(size=(B, cfg.num_prefix_embeddings,
                                          cfg.d_model)), jnp.float32)
    toks = _toks(cfg, B, 8)
    hidden, _ = forward_train(params, cfg, toks, prefix_emb=prefix)
    assert hidden.shape == (B, cfg.num_prefix_embeddings + 8, cfg.d_model)
    # prefix must influence text outputs
    hidden2, _ = forward_train(params, cfg, toks, prefix_emb=prefix * 0.0)
    assert float(jnp.max(jnp.abs(hidden - hidden2))) > 1e-4


def test_block_period_patterns():
    assert block_period(get_smoke_config("llama3_8b")) == 1
    jamba_full = get_smoke_config("jamba_1_5_large_398b")
    kinds = sublayer_kinds(jamba_full)
    assert any(m == "attn" for m, _ in kinds)
    assert any(m == "ssm" for m, _ in kinds)
    assert any(f == "moe" for _, f in kinds)
    from repro.configs import get_config
    kinds_full = sublayer_kinds(get_config("jamba-1.5-large-398b"))
    assert len(kinds_full) == 8
    assert sum(m == "attn" for m, _ in kinds_full) == 1   # 1:7 interleave
    assert sum(f == "moe" for _, f in kinds_full) == 4    # MoE every other


def test_moe_router_topk_and_aux():
    cfg = get_smoke_config("phi3_5_moe_42b")
    params = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(64, cfg.d_model)), jnp.float32)
    w, e, aux = M.route(params, cfg, x)
    assert w.shape == (64, cfg.experts_per_token)
    np.testing.assert_allclose(np.sum(np.asarray(w), -1), 1.0, rtol=1e-5)
    assert float(aux) >= 1.0 - 1e-3   # aux >= 1 by Cauchy-Schwarz at balance


def test_moe_local_is_capacity_free_exact():
    """The sort+ragged_dot path computes EVERY routed token (no drops):
    outputs must match a dense per-token loop."""
    cfg = get_smoke_config("phi3_5_moe_42b")
    params = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(16, cfg.d_model)), jnp.float32)
    out, aux = M.moe_ffn_local(params, cfg, x)

    w, e, _ = M.route(params, cfg, x)
    want = np.zeros_like(np.asarray(x))
    for t in range(16):
        for kk in range(cfg.experts_per_token):
            ex = int(e[t, kk])
            g = np.asarray(x[t] @ params["w_gate"][ex])
            u = np.asarray(x[t] @ params["w_up"][ex])
            h = (g / (1 + np.exp(-g))) * u
            want[t] += float(w[t, kk]) * (h @ np.asarray(params["w_down"][ex]))
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-3, atol=2e-3)


def test_moe_grads_flow_through_router():
    cfg = get_smoke_config("phi3_5_moe_42b")
    params = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(8, cfg.d_model)), jnp.float32)

    def loss(p):
        out, aux = M.moe_ffn_local(p, cfg, x)
        return jnp.sum(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(params)
    gnorm = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0
    assert float(jnp.sum(jnp.abs(g["router"]["w"]))) > 0


def test_lm_loss_chunked_equals_unchunked():
    cfg = get_smoke_config("llama3_8b")
    params = model_init(jax.random.PRNGKey(0), cfg)
    toks = _toks(cfg, 2, 16)
    hidden, _ = forward_train(params, cfg, toks)
    l1 = lm_loss(params, cfg, hidden, toks, chunk=16)
    l2 = lm_loss(params, cfg, hidden, toks, chunk=4)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_lm_loss_ignores_masked_labels():
    cfg = get_smoke_config("llama3_8b")
    params = model_init(jax.random.PRNGKey(0), cfg)
    toks = _toks(cfg, 2, 8)
    hidden, _ = forward_train(params, cfg, toks)
    full = lm_loss(params, cfg, hidden, toks)
    labels = toks.at[:, :4].set(-1)
    masked = lm_loss(params, cfg, hidden, labels)
    assert np.isfinite(float(masked)) and abs(float(masked) - float(full)) > 1e-6
