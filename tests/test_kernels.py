"""Per-kernel correctness: interpret-mode Pallas vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _arr(shape, dtype=np.float32, scale=1.0):
    return jnp.asarray((RNG.normal(size=shape) * scale).astype(dtype))


# ------------------------------------------------------------ neighbor mean


@pytest.mark.parametrize("n,f,d", [(8, 4, 32), (128, 10, 128), (300, 7, 96),
                                   (64, 25, 200)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_neighbor_mean_matches_ref(n, f, d, dtype):
    feats = _arr((n, f, d), np.float32).astype(dtype)
    mask = jnp.asarray((RNG.random((n, f)) < 0.7).astype(np.float32))
    got = ops.neighbor_mean(feats, mask, impl="interpret")
    want = ops.neighbor_mean(feats, mask, impl="ref")
    tol = 1e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_neighbor_mean_all_masked_rows_are_zero():
    feats = _arr((16, 5, 64))
    mask = jnp.zeros((16, 5))
    out = ops.neighbor_mean(feats, mask, impl="interpret")
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_neighbor_mean_leading_dims():
    feats = _arr((4, 6, 5, 32))
    mask = jnp.asarray((RNG.random((4, 6, 5)) < 0.5).astype(np.float32))
    got = ops.neighbor_mean(feats, mask, impl="interpret")
    want = ref.neighbor_mean(feats, mask)
    assert got.shape == (4, 6, 32)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ------------------------------------------------------ fused sage layer


def _sage_layer_inputs(n, f, d, h):
    h_self = _arr((n, d))
    h_neigh = _arr((n, f, d))
    mask = jnp.asarray((RNG.random((n, f)) < 0.7).astype(np.float32))
    w_self = _arr((d, h), scale=0.1)
    b_self = _arr((h,), scale=0.1)
    w_neigh = _arr((d, h), scale=0.1)
    b_neigh = _arr((h,), scale=0.1)
    return h_self, h_neigh, mask, w_self, b_self, w_neigh, b_neigh


@pytest.mark.parametrize("n,f,d,h", [(8, 4, 32, 32), (128, 10, 128, 128),
                                     (300, 7, 96, 96), (64, 25, 200, 200),
                                     (5, 3, 17, 17)])
def test_sage_layer_matches_ref(n, f, d, h):
    args = _sage_layer_inputs(n, f, d, h)
    got = ops.sage_layer(*args, impl="interpret")
    want = ops.sage_layer(*args, impl="ref")
    assert float(jnp.max(jnp.abs(got - want))) <= 1e-5


def test_sage_layer_all_masked_rows_use_self_path_only():
    n, f, d = 16, 5, 64
    h_self, h_neigh, _, w_self, b_self, w_neigh, b_neigh = \
        _sage_layer_inputs(n, f, d, d)
    mask = jnp.zeros((n, f))
    got = ops.sage_layer(h_self, h_neigh, mask, w_self, b_self,
                         w_neigh, b_neigh, impl="interpret")
    want = jax.nn.relu(h_self @ w_self + b_self + b_neigh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_sage_layer_leading_dims():
    b, f1, f, d = 4, 6, 5, 48
    h_self = _arr((b, f1, d))
    h_neigh = _arr((b, f1, f, d))
    mask = jnp.asarray((RNG.random((b, f1, f)) < 0.5).astype(np.float32))
    w = _arr((d, d), scale=0.1)
    bias = _arr((d,), scale=0.1)
    got = ops.sage_layer(h_self, h_neigh, mask, w, bias, w, bias,
                         impl="interpret")
    want = ops.sage_layer(h_self, h_neigh, mask, w, bias, w, bias, impl="ref")
    assert got.shape == (b, f1, d)
    assert float(jnp.max(jnp.abs(got - want))) <= 1e-5


def test_sage_layer_ref_equals_unfused_encoder_rule():
    """The fused oracle must equal mean-agg + two dense layers + relu."""
    n, f, d = 32, 6, 40
    h_self, h_neigh, mask, w_self, b_self, w_neigh, b_neigh = \
        _sage_layer_inputs(n, f, d, d)
    agg = ref.neighbor_mean(h_neigh, mask)
    want = jax.nn.relu(h_self @ w_self + b_self + agg @ w_neigh + b_neigh)
    got = ops.sage_layer(h_self, h_neigh, mask, w_self, b_self,
                         w_neigh, b_neigh, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# ------------------------------------------------- fused attention layer


def _sage_attention_layer_inputs(n, f, d, h):
    h_self = _arr((n, d))
    q = _arr((n, d))
    k = _arr((n, f, d))
    v = _arr((n, f, d))
    mask = jnp.asarray((RNG.random((n, f)) < 0.7).astype(np.float32))
    w_self = _arr((d, h), scale=0.1)
    b_self = _arr((h,), scale=0.1)
    w_neigh = _arr((d, h), scale=0.1)
    b_neigh = _arr((h,), scale=0.1)
    return h_self, q, k, v, mask, w_self, b_self, w_neigh, b_neigh


@pytest.mark.parametrize("n,f,d,h", [(16, 4, 32, 32), (128, 10, 64, 64),
                                     (37, 6, 40, 48), (5, 3, 17, 17)])
def test_sage_attention_layer_matches_ref(n, f, d, h):
    args = _sage_attention_layer_inputs(n, f, d, h)
    got = ops.sage_attention_layer(*args, impl="interpret")
    want = ops.sage_attention_layer(*args, impl="ref")
    assert float(jnp.max(jnp.abs(got - want))) <= 1e-5


def test_sage_attention_layer_all_masked_rows_use_self_path_only():
    n, f, d = 16, 5, 64
    h_self, q, k, v, _, w_self, b_self, w_neigh, b_neigh = \
        _sage_attention_layer_inputs(n, f, d, d)
    mask = jnp.zeros((n, f))
    got = ops.sage_attention_layer(h_self, q, k, v, mask, w_self, b_self,
                                   w_neigh, b_neigh, impl="interpret")
    want = jax.nn.relu(h_self @ w_self + b_self + b_neigh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_sage_attention_layer_leading_dims():
    b, f1, f, d = 4, 6, 5, 48
    h_self = _arr((b, f1, d))
    q = _arr((b, f1, d))
    k = _arr((b, f1, f, d))
    v = _arr((b, f1, f, d))
    mask = jnp.asarray((RNG.random((b, f1, f)) < 0.5).astype(np.float32))
    w = _arr((d, d), scale=0.1)
    bias = _arr((d,), scale=0.1)
    got = ops.sage_attention_layer(h_self, q, k, v, mask, w, bias, w, bias,
                                   impl="interpret")
    want = ops.sage_attention_layer(h_self, q, k, v, mask, w, bias, w, bias,
                                    impl="ref")
    assert got.shape == (b, f1, d)
    assert float(jnp.max(jnp.abs(got - want))) <= 1e-5


# --------------------------------------------------- kernel gradient parity
#
# The fused kernels carry custom VJPs (pallas_call has no autodiff rule);
# backward parity against jax.grad of the pure-jnp oracle is what lets the
# TRAINING loop run through the pallas/interpret paths, not just inference.


def _grad_parity(make_loss, args, names, tol=1e-5):
    argnums = tuple(range(len(args)))
    g_int = jax.grad(make_loss("interpret"), argnums=argnums)(*args)
    g_ref = jax.grad(make_loss("ref"), argnums=argnums)(*args)
    for name, a, b in zip(names, g_int, g_ref):
        err = float(jnp.max(jnp.abs(a - b)))
        assert err <= tol, (name, err)


@pytest.mark.parametrize("n,f,d,h", [(32, 6, 40, 40), (128, 10, 64, 64)])
def test_sage_layer_gradient_parity(n, f, d, h):
    h_self, h_neigh, mask, w_self, b_self, w_neigh, b_neigh = \
        _sage_layer_inputs(n, f, d, h)
    cot = _arr((n, h))

    def make_loss(impl):
        def loss(h_self, h_neigh, w_self, b_self, w_neigh, b_neigh):
            out = ops.sage_layer(h_self, h_neigh, mask, w_self, b_self,
                                 w_neigh, b_neigh, impl=impl)
            return jnp.sum(out * cot)
        return loss

    _grad_parity(make_loss, (h_self, h_neigh, w_self, b_self, w_neigh, b_neigh),
                 ("h_self", "h_neigh", "w_self", "b_self", "w_neigh", "b_neigh"))


def test_sage_layer_gradient_parity_leading_dims():
    b, f1, f, d = 3, 5, 4, 32
    h_self = _arr((b, f1, d))
    h_neigh = _arr((b, f1, f, d))
    mask = jnp.asarray((RNG.random((b, f1, f)) < 0.6).astype(np.float32))
    w = _arr((d, d), scale=0.1)
    bias = _arr((d,), scale=0.1)
    cot = _arr((b, f1, d))

    def make_loss(impl):
        def loss(h_self, h_neigh, w, bias):
            out = ops.sage_layer(h_self, h_neigh, mask, w, bias, w, bias,
                                 impl=impl)
            return jnp.sum(out * cot)
        return loss

    _grad_parity(make_loss, (h_self, h_neigh, w, bias),
                 ("h_self", "h_neigh", "w", "bias"))


@pytest.mark.parametrize("n,f,d,h", [(32, 6, 40, 40), (128, 10, 64, 64)])
def test_sage_attention_layer_gradient_parity(n, f, d, h):
    h_self, q, k, v, mask, w_self, b_self, w_neigh, b_neigh = \
        _sage_attention_layer_inputs(n, f, d, h)
    cot = _arr((n, h))

    def make_loss(impl):
        def loss(h_self, q, k, v, w_self, b_self, w_neigh, b_neigh):
            out = ops.sage_attention_layer(h_self, q, k, v, mask, w_self,
                                           b_self, w_neigh, b_neigh, impl=impl)
            return jnp.sum(out * cot)
        return loss

    _grad_parity(make_loss,
                 (h_self, q, k, v, w_self, b_self, w_neigh, b_neigh),
                 ("h_self", "q", "k", "v", "w_self", "b_self", "w_neigh",
                  "b_neigh"))


def test_sage_attention_layer_gradient_parity_with_all_masked_rows():
    n, f, d = 24, 4, 32
    h_self, q, k, v, mask, w_self, b_self, w_neigh, b_neigh = \
        _sage_attention_layer_inputs(n, f, d, d)
    mask = mask.at[:5].set(0.0)           # zero-degree rows in the batch
    cot = _arr((n, d))

    def make_loss(impl):
        def loss(q, k, v):
            out = ops.sage_attention_layer(h_self, q, k, v, mask, w_self,
                                           b_self, w_neigh, b_neigh, impl=impl)
            return jnp.sum(out * cot)
        return loss

    _grad_parity(make_loss, (q, k, v), ("q", "k", "v"))


# -------------------------------------------------------- sage attention


@pytest.mark.parametrize("n,f,d", [(16, 4, 32), (128, 10, 128), (200, 25, 64)])
def test_sage_attention_matches_ref(n, f, d):
    q = _arr((n, d))
    k = _arr((n, f, d))
    v = _arr((n, f, d))
    mask = jnp.asarray((RNG.random((n, f)) < 0.8).astype(np.float32))
    got = ops.neighbor_attention(q, k, v, mask, impl="interpret")
    want = ops.neighbor_attention(q, k, v, mask, impl="ref")
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_sage_attention_weights_sum_to_one_effect():
    # with identical v vectors, output must equal v regardless of mask pattern
    n, f, d = 32, 6, 16
    q = _arr((n, d))
    k = _arr((n, f, d))
    v = jnp.broadcast_to(_arr((n, 1, d)), (n, f, d))
    mask = jnp.ones((n, f))
    out = ops.neighbor_attention(q, k, v, mask, impl="interpret")
    np.testing.assert_allclose(out, v[:, 0], rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ flash attention


@pytest.mark.parametrize("b,hq,hkv,s,dh", [
    (2, 4, 2, 256, 64), (1, 8, 1, 128, 128), (2, 2, 2, 512, 64)])
@pytest.mark.parametrize("window", [0, 64])
def test_flash_attention_matches_ref(b, hq, hkv, s, dh, window):
    q, k, v = _arr((b, hq, s, dh)), _arr((b, hkv, s, dh)), _arr((b, hkv, s, dh))
    got = ops.mha(q, k, v, causal=True, window=window, impl="interpret",
                  block_q=128, block_k=128)
    want = ops.mha(q, k, v, causal=True, window=window, impl="ref")
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    b, hq, hkv, s, dh = 2, 4, 2, 256, 64
    q = _arr((b, hq, s, dh)).astype(jnp.bfloat16)
    k = _arr((b, hkv, s, dh)).astype(jnp.bfloat16)
    v = _arr((b, hkv, s, dh)).astype(jnp.bfloat16)
    got = ops.mha(q, k, v, impl="interpret", block_q=128, block_k=128)
    want = ops.mha(q, k, v, impl="ref")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("window", [0, 64])
def test_decode_attention_matches_ref(window):
    b, hq, hkv, s, dh = 3, 8, 2, 256, 64
    q = _arr((b, hq, dh))
    k, v = _arr((b, hkv, s, dh)), _arr((b, hkv, s, dh))
    lens = jnp.asarray([100, 256, 17], jnp.int32)
    got = ops.decode_attention(q, k, v, lens, window=window, impl="interpret",
                               block_k=128)
    want = ops.decode_attention(q, k, v, lens, window=window, impl="ref")
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_decode_attention_single_valid_slot():
    # cache_len=1: output must equal v[:, :, 0] (per GQA group)
    b, hq, hkv, s, dh = 2, 4, 2, 128, 32
    q = _arr((b, hq, dh))
    k, v = _arr((b, hkv, s, dh)), _arr((b, hkv, s, dh))
    lens = jnp.ones((b,), jnp.int32)
    out = ops.decode_attention(q, k, v, lens, impl="interpret", block_k=128)
    want = jnp.repeat(v[:, :, 0, :], hq // hkv, axis=1)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------- SSD


@pytest.mark.parametrize("b,L,H,P,N,chunk", [
    (2, 128, 3, 16, 24, 32), (1, 256, 2, 64, 128, 64), (2, 64, 4, 32, 16, 64)])
def test_ssd_chunked_ref_matches_sequential(b, L, H, P, N, chunk):
    x = _arr((b, L, H, P))
    dt = jnp.asarray(RNG.random((b, L, H)).astype(np.float32) * 0.1)
    A = jnp.asarray(-RNG.random(H).astype(np.float32))
    B = _arr((b, L, N))
    C = _arr((b, L, N))
    y0, s0 = ref.ssd_scan(x, dt, A, B, C)
    y1, s1 = ref.ssd_scan_chunked(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(y0, y1, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s0, s1, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,L,H,P,N,chunk", [
    (2, 128, 3, 16, 24, 32), (1, 128, 2, 64, 128, 64)])
def test_ssd_kernel_matches_ref(b, L, H, P, N, chunk):
    x = _arr((b, L, H, P))
    dt = jnp.asarray(RNG.random((b, L, H)).astype(np.float32) * 0.1)
    A = jnp.asarray(-RNG.random(H).astype(np.float32))
    B = _arr((b, L, N))
    C = _arr((b, L, N))
    y0, s0 = ref.ssd_scan(x, dt, A, B, C)
    y1, s1 = ops.ssd(x, dt, A, B, C, chunk=chunk, impl="interpret")
    np.testing.assert_allclose(y0, y1, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s0, s1, rtol=1e-4, atol=1e-4)


def test_ssd_decode_consistent_with_scan():
    b, L, H, P, N = 2, 16, 3, 8, 12
    x = _arr((b, L, H, P))
    dt = jnp.asarray(RNG.random((b, L, H)).astype(np.float32) * 0.1)
    A = jnp.asarray(-RNG.random(H).astype(np.float32))
    B = _arr((b, L, N))
    C = _arr((b, L, N))
    y_scan, s_final = ref.ssd_scan(x, dt, A, B, C)
    S = jnp.zeros((b, H, N, P), jnp.float32)
    ys = []
    for t in range(L):
        y, S = ref.ssd_decode_step(S, x[:, t], dt[:, t], A, B[:, t], C[:, t])
        ys.append(y)
    np.testing.assert_allclose(jnp.stack(ys, 1), y_scan, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(S, s_final, rtol=1e-4, atol=1e-4)


# ----------------------------------------------------- fused scan-and-topk


def _quantized_pair(nq, n, d, seed=0):
    from repro.core.retrieval import quantize_int8, quantize_queries
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    q = rng.normal(size=(nq, d)).astype(np.float32)
    qt = quantize_int8(x)
    qc, qs = quantize_queries(q, qt)
    return qc, qs, qt.codes, qt.scales


@pytest.mark.parametrize("nq,n,d,k", [(5, 37, 16, 1), (64, 1000, 64, 10),
                                      (150, 2048, 32, 10), (3, 17, 8, 17),
                                      (128, 512, 128, 32)])
def test_scan_topk_interpret_bitwise_matches_ref(nq, n, d, k):
    """Scores AND ids bit-identical: int8 products accumulate exactly in
    both int32 (kernel) and fp32 (ref) for d <= 1024, and both ends use
    the canonical score-desc/row-asc order."""
    qc, qs, cc, cs = _quantized_pair(nq, n, d, seed=n)
    v0, i0 = ops.scan_topk(qc, qs, cc, cs, k=min(k, n), impl="ref")
    v1, i1 = ops.scan_topk(qc, qs, cc, cs, k=min(k, n), impl="interpret")
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))


def test_scan_topk_block_decomposition_invariant():
    """The running-topk merge is a total order, so the result cannot
    depend on how the corpus is cut into blocks."""
    qc, qs, cc, cs = _quantized_pair(16, 1000, 32, seed=5)
    v0, i0 = ops.scan_topk(qc, qs, cc, cs, k=10, impl="interpret",
                           block_c=128)
    v1, i1 = ops.scan_topk(qc, qs, cc, cs, k=10, impl="interpret",
                           block_c=512)
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))


def test_scan_topk_tie_break_is_lowest_row():
    from repro.core.retrieval import quantize_int8, quantize_queries
    rng = np.random.default_rng(11)
    base = rng.normal(size=(20, 16)).astype(np.float32)
    x = np.concatenate([base, base])          # rows i and i+20 identical
    qt = quantize_int8(x)
    qc, qs = quantize_queries(rng.normal(size=(6, 16)).astype(np.float32), qt)
    for impl in ("ref", "interpret"):
        _, ids = ops.scan_topk(qc, qs, qt.codes, qt.scales, k=2, impl=impl)
        ids = np.asarray(ids)
        assert np.all(ids[:, 0] < 20), impl
        np.testing.assert_array_equal(ids[:, 1], ids[:, 0] + 20)
