"""End-to-end behaviour tests for the LinkSAGE system (paper pipeline):
GNN training → frozen-encoder transfer → nearline refresh → downstream eval.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from dataclasses import replace

from repro.configs.linksage import CONFIG as GNN_CONFIG
from repro.core.eval import auc, retrieval_eval
from repro.core.linksage import LinkSAGETrainer
from repro.core.nearline import Event, NearlineInference
from repro.core.transfer import (DownstreamRanker, RankerConfig,
                                 build_ranker_dataset)
from repro.data import GraphGenConfig, generate_job_marketplace_graph


@pytest.fixture(scope="module")
def pipeline():
    """Train the GNN once; reuse across system tests (expensive)."""
    g, truth = generate_job_marketplace_graph(
        GraphGenConfig(num_members=400, num_jobs=120, seed=0))
    cfg = replace(GNN_CONFIG, hidden_dim=64, embed_dim=64, fanouts=(8, 4))
    tr = LinkSAGETrainer(cfg, g, seed=0)
    tr.train(150, batch_size=64)
    m_emb = tr.embed_nodes("member", np.arange(400))
    j_emb = tr.embed_nodes("job", np.arange(120))
    return g, truth, cfg, tr, m_emb, j_emb


def test_gnn_embeddings_encode_match_structure(pipeline):
    g, truth, cfg, tr, m_emb, j_emb = pipeline
    src, dst = truth["engagements"]
    r = retrieval_eval(m_emb, j_emb, src, dst, k=10)["recall"]
    assert r > 0.3, r


def test_cold_start_members_benefit(pipeline):
    """Paper §7.2/Table 7: members lacking predictive data still get useful
    embeddings via attribute-edge propagation."""
    g, truth, cfg, tr, m_emb, j_emb = pipeline
    src, dst = truth["engagements"]
    cold = retrieval_eval(m_emb, j_emb, src, dst, k=10,
                          segment_mask=truth["is_cold"])
    rng = np.random.default_rng(0)
    rand = retrieval_eval(rng.normal(size=m_emb.shape),
                          rng.normal(size=j_emb.shape), src, dst, k=10,
                          segment_mask=truth["is_cold"])
    assert cold["recall"] > 2 * max(rand["recall"], 1e-6)


def test_transfer_learning_ranker_beats_no_gnn_on_weak_features(pipeline):
    """Core A/B claim: plugging the frozen GNN encoder into a downstream
    ranker lifts AUC when the ranker's own features are weak (the realistic
    production regime — LinkedIn's rankers already have features; GNN adds
    graph signal they lack)."""
    g, truth, cfg, tr, m_emb, j_emb = pipeline
    src, dst = truth["engagements"]
    rng = np.random.default_rng(1)
    # weak "other features": heavy noise over profile features
    weak_m = g.features["member"] * 0.1 + rng.normal(size=g.features["member"].shape).astype(np.float32)
    weak_j = g.features["job"] * 0.1 + rng.normal(size=g.features["job"].shape).astype(np.float32)
    n = len(src)
    neg_m = rng.integers(0, 400, n).astype(np.int32)
    neg_j = rng.integers(0, 120, n).astype(np.int32)
    pairs = (np.concatenate([src, neg_m]), np.concatenate([dst, neg_j]))
    labels = np.concatenate([np.ones(n), np.zeros(n)]).astype(np.float32)
    order = rng.permutation(len(labels))
    tr_idx, te_idx = order[:int(0.8 * len(order))], order[int(0.8 * len(order)):]

    def run(use_gnn):
        ds = build_ranker_dataset(weak_m, weak_j, m_emb, j_emb,
                                  (pairs[0], pairs[1]), labels, use_gnn=use_gnn)
        tr_ds = {k: v[tr_idx] for k, v in ds.items()}
        te_ds = {k: v[te_idx] for k, v in ds.items()}
        rk = DownstreamRanker(RankerConfig(gnn_embed_dim=64, other_feat_dim=64,
                                           use_gnn=use_gnn), seed=0)
        rk.fit(tr_ds, epochs=5)
        return auc(te_ds["label"], rk.score(te_ds))

    auc_gnn = run(True)
    auc_plain = run(False)
    assert auc_gnn > auc_plain + 0.02, (auc_gnn, auc_plain)


def test_nearline_embedding_close_to_batch_embedding(pipeline):
    """The nearline sequential-join tile must reproduce the graph-engine
    embedding distribution (same encoder, store-backed neighbors)."""
    g, truth, cfg, tr, m_emb, j_emb = pipeline
    nl = NearlineInference(cfg, tr.state.params["encoder"], micro_batch=32,
                           fanouts=cfg.fanouts, seed=0)
    nl.bootstrap_from_graph(g)
    for jid in range(16):
        nl.topic.publish(Event(time=float(jid), kind="engagement",
                               payload={"member_id": jid, "job_id": jid % 120}))
    nl.process()
    sims = []
    for jid in range(16):
        rec = nl.embedding_store.get_embedding("member", jid)
        assert rec is not None
        e = rec[0]
        sim = float(e @ m_emb[jid] / (np.linalg.norm(e) * np.linalg.norm(m_emb[jid]) + 1e-9))
        sims.append(sim)
    assert np.mean(sims) > 0.7, np.mean(sims)


def test_ebr_retrieval_with_served_embeddings(pipeline):
    """EBR (§7.4): retrieval from the online store's embeddings works."""
    g, truth, cfg, tr, m_emb, j_emb = pipeline
    src, dst = truth["engagements"]
    mn = m_emb / (np.linalg.norm(m_emb, axis=1, keepdims=True) + 1e-9)
    jn = j_emb / (np.linalg.norm(j_emb, axis=1, keepdims=True) + 1e-9)
    r = retrieval_eval(mn, jn, src, dst, k=10)["recall"]
    assert r > 0.3
