"""LinkSAGE technique part B applied to the transformer backbones:
``gnn_conditioning=True`` lets any assigned arch consume the frozen GNN
member/job embeddings as a soft-prompt bias (the paper's transfer-learning
integration, §5.1, generalized to LLM rankers)."""
from dataclasses import replace

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.launch import steps as ST
from repro.models import decode_step, forward_train, init_decode_state, model_init
from repro.optim import adamw_init


@pytest.mark.parametrize("arch", ["llama3_8b", "mamba2_780m"])
def test_gnn_conditioning_changes_outputs(arch):
    cfg = replace(get_smoke_config(arch), gnn_conditioning=True, gnn_embed_dim=32)
    params = model_init(jax.random.PRNGKey(0), cfg)
    assert "gnn_proj" in params
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    gnn = jnp.asarray(rng.normal(size=(2, 64)), jnp.float32)
    h1, _ = forward_train(params, cfg, toks, gnn_emb=gnn)
    h2, _ = forward_train(params, cfg, toks, gnn_emb=gnn * 0)
    assert float(jnp.max(jnp.abs(h1 - h2))) > 1e-4


def test_gnn_conditioning_train_step():
    cfg = replace(get_smoke_config("llama3_8b"), gnn_conditioning=True,
                  gnn_embed_dim=32)
    params = model_init(jax.random.PRNGKey(0), cfg)
    step = jax.jit(ST.make_train_step(cfg, lr=1e-3))
    opt = adamw_init(params)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32),
        "gnn_emb": jnp.asarray(rng.normal(size=(2, 64)), jnp.float32),
    }
    params2, _, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    # the gnn projection itself must receive gradient
    delta = float(jnp.max(jnp.abs(params2["gnn_proj"]["w"] - params["gnn_proj"]["w"])))
    assert delta > 0


def test_gnn_conditioned_decode():
    cfg = replace(get_smoke_config("llama3_8b"), gnn_conditioning=True,
                  gnn_embed_dim=32)
    params = model_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    state = init_decode_state(cfg, 2, 16, dtype=jnp.float32)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (2,)), jnp.int32)
    gnn = jnp.asarray(rng.normal(size=(2, 64)), jnp.float32)
    l1, _ = decode_step(params, cfg, tok, state, gnn_emb=gnn)
    l2, _ = decode_step(params, cfg, tok, state, gnn_emb=gnn * 0)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-5


def test_input_specs_include_gnn_emb():
    from repro.configs import INPUT_SHAPES
    cfg = replace(get_smoke_config("llama3_8b"), gnn_conditioning=True)
    specs = ST.input_specs(cfg, INPUT_SHAPES["train_4k"])
    assert "gnn_emb" in specs
    assert specs["gnn_emb"].shape == (256, 2 * cfg.gnn_embed_dim)
