"""Nearline inference pipeline (§5.2): event flow, sequential join,
staleness vs the offline daily-batch baseline."""
import numpy as np
import jax
import pytest
from dataclasses import replace

from repro.configs.linksage import smoke as gnn_smoke
from repro.core.linksage import LinkSAGETrainer
from repro.core.nearline import (EmbeddingStore, Event, NearlineInference,
                                 NoSQLStore, OfflineBatchInference, RingBuffer,
                                 Topic)
from repro.data import GraphGenConfig, generate_job_marketplace_graph


@pytest.fixture(scope="module")
def setup():
    g, truth = generate_job_marketplace_graph(
        GraphGenConfig(num_members=200, num_jobs=60, seed=3))
    cfg = replace(gnn_smoke(), feat_dim=g.feat_dim)
    tr = LinkSAGETrainer(cfg, g, seed=0)
    tr.train(20, batch_size=32)
    return g, truth, cfg, tr


def test_topic_offsets_are_per_consumer():
    t = Topic("x")
    for i in range(5):
        t.publish(Event(time=float(i), kind="engagement", payload={}))
    assert len(t.poll("a", 3)) == 3
    assert len(t.poll("b", 10)) == 5
    assert len(t.poll("a", 10)) == 2
    assert t.lag("a") == 0


def test_topic_lag_tracks_each_consumer_independently():
    t = Topic("x")
    for i in range(4):
        t.publish(Event(time=float(i), kind="engagement", payload={}))
    t.poll("a", 1)
    assert t.lag("a") == 3
    assert t.lag("b") == 4          # never-polled consumer lags the full log
    t.publish(Event(time=9.0, kind="engagement", payload={}))
    assert t.lag("a") == 4 and t.lag("b") == 5
    t.poll("a", 100)
    assert t.lag("a") == 0 and t.lag("b") == 5


def test_topic_poll_upto_time_boundary():
    """``upto_time`` is inclusive, and events past it stay unconsumed (the
    consumer offset only advances over what was actually returned)."""
    t = Topic("x")
    for i in range(5):
        t.publish(Event(time=float(i), kind="engagement", payload={}))
    got = t.poll("c", 10, upto_time=2.0)
    assert [ev.time for ev in got] == [0.0, 1.0, 2.0]   # t == upto included
    assert t.lag("c") == 2
    # a poll entirely beyond the horizon returns nothing and holds position
    assert t.poll("c", 10, upto_time=2.5) == []
    assert t.lag("c") == 2
    assert [ev.time for ev in t.poll("c", 10)] == [3.0, 4.0]
    assert t.lag("c") == 0


def test_nearline_metrics_summary_counters():
    from repro.core.nearline import NearlineMetrics
    m = NearlineMetrics()
    empty = m.summary()                 # no div-by-zero on a fresh pipeline
    assert empty["events"] == 0 and empty["encoder_ms_per_batch"] == 0.0
    assert empty["staleness_p50_s"] == 0.0 and empty["sweeps"] == 0
    m.events_processed, m.batches, m.nodes_refreshed = 10, 4, 7
    m.encoder_seconds, m.join_seconds, m.encoder_traces = 0.8, 0.4, 2
    m.staleness, m.join_reads, m.sweeps = [1.0, 3.0], 55, 1
    s = m.summary()
    assert s["events"] == 10 and s["batches"] == 4 and s["nodes_refreshed"] == 7
    assert s["encoder_ms_per_batch"] == pytest.approx(200.0)
    assert s["join_ms_per_batch"] == pytest.approx(100.0)
    assert s["encoder_traces"] == 2 and s["join_reads"] == 55 and s["sweeps"] == 1
    assert s["staleness_p50_s"] == pytest.approx(2.0)
    assert s["staleness_p99_s"] == pytest.approx(np.percentile([1.0, 3.0], 99))


def test_nearline_metrics_queue_and_cache_counters():
    """The serving-shared counters: queue-depth peak and cache hit rate
    flow through summary() with exact accounting."""
    from repro.core.nearline import NearlineMetrics
    m = NearlineMetrics()
    s = m.summary()
    assert s["queue_depth_peak"] == 0 and s["cache_hit_rate"] == 0.0
    m.queue_depth_peak = 7
    m.cache_hits, m.cache_misses = 3, 1
    s = m.summary()
    assert s["queue_depth_peak"] == 7
    assert s["cache_hit_rate"] == pytest.approx(0.75)


def test_queue_depth_peak_tracks_high_water_mark(setup):
    """mark_dirty raises the peak; draining does not reset it."""
    g, truth, cfg, tr = setup
    nl = NearlineInference(cfg, tr.state.params["encoder"], micro_batch=64)
    nl.bootstrap_from_graph(g)
    for i in range(5):
        nl.topic.publish(Event(time=1.0, kind="engagement",
                               payload={"member_id": i, "job_id": i}))
    nl.process()
    s = nl.metrics.summary()
    # 5 engagements dirty 5 members + 5 jobs before one drain
    assert s["queue_depth_peak"] == 10
    assert nl.lifecycle.pending() == 0                 # drained, peak kept


def test_embedding_store_summary_counters():
    st = EmbeddingStore("t")
    st.put_embedding("job", 1, np.ones(4, np.float32), 1.0)
    st.put_embedding("member", 2, np.ones(4, np.float32), 1.0)
    v = st.publish()
    st.gather("job", [1], version=v)
    s = st.summary()
    assert s["live_records"] == 2 and s["published_versions"] == 1
    assert s["latest_version"] == 1
    assert s["writes"] == 2 and s["reads"] == 1


def test_nosql_store_counts_io():
    s = NoSQLStore("t")
    s.put("k", 1)
    s.get("k")
    s.multi_get(["k", "missing"])
    assert s.writes == 1 and s.reads == 3


def test_job_created_gets_embedding_nearline(setup):
    g, truth, cfg, tr = setup
    nl = NearlineInference(cfg, tr.state.params["encoder"], micro_batch=16)
    nl.bootstrap_from_graph(g)
    new_job_id = g.num_nodes["job"] + 1
    nl.topic.publish(Event(time=5.0, kind="job_created", payload={
        "job_id": new_job_id, "features": np.ones(g.feat_dim, np.float32),
        "title": 2, "company": 1, "skill": 4}))
    nl.process()
    rec = nl.embedding_store.get_embedding("job", new_job_id)
    assert rec is not None
    emb, t = rec
    assert np.all(np.isfinite(emb)) and t >= 5.0


def test_engagement_refreshes_both_endpoints(setup):
    g, truth, cfg, tr = setup
    nl = NearlineInference(cfg, tr.state.params["encoder"], micro_batch=16)
    nl.bootstrap_from_graph(g)
    nl.topic.publish(Event(time=1.0, kind="engagement",
                           payload={"member_id": 5, "job_id": 7}))
    nl.process()
    assert nl.embedding_store.get_embedding("member", 5) is not None
    assert nl.embedding_store.get_embedding("job", 7) is not None


def test_embedding_changes_after_new_neighbors(setup):
    """The inductive property: new engagement edges change the refreshed
    embedding without retraining (the paper's core serving claim)."""
    g, truth, cfg, tr = setup
    nl = NearlineInference(cfg, tr.state.params["encoder"], micro_batch=16,
                           fanouts=(8, 4))
    nl.bootstrap_from_graph(g)
    nl.topic.publish(Event(time=0.5, kind="engagement",
                           payload={"member_id": 9, "job_id": 3}))
    nl.process()
    emb1 = nl.embedding_store.get_embedding("job", 3)[0]
    # pile on distinct new neighbors
    for i in range(10):
        nl.topic.publish(Event(time=1.0 + i, kind="engagement",
                               payload={"member_id": 20 + i, "job_id": 3}))
    nl.process()
    emb2 = nl.embedding_store.get_embedding("job", 3)[0]
    assert np.max(np.abs(emb1 - emb2)) > 1e-5


def test_nearline_staleness_beats_offline(setup):
    """Table 10 mechanism: nearline refresh lag is seconds; offline daily
    batch leaves up to 24h of staleness."""
    g, truth, cfg, tr = setup
    rng = np.random.default_rng(0)

    def event_stream():
        return [Event(time=float(3600 * i), kind="engagement",
                      payload={"member_id": int(rng.integers(0, 200)),
                               "job_id": int(rng.integers(0, 60))})
                for i in range(24)]

    nl = NearlineInference(cfg, tr.state.params["encoder"], micro_batch=4)
    nl.bootstrap_from_graph(g)
    for ev in event_stream():
        nl.topic.publish(ev)
        nl.process()          # nearline: processed as they arrive
    near_p99 = nl.metrics.summary()["staleness_p99_s"]

    off_inner = NearlineInference(cfg, tr.state.params["encoder"], micro_batch=1000)
    off_inner.bootstrap_from_graph(g)
    off = OfflineBatchInference(off_inner, period_s=86_400.0)
    for ev in event_stream():
        off_inner.topic.publish(ev)
    off.maybe_run(now=86_400.0)
    off_p99 = off_inner.metrics.summary()["staleness_p99_s"]

    assert near_p99 < 60.0, near_p99
    assert off_p99 > 3600.0, off_p99
    assert near_p99 < off_p99 / 100


def test_ring_buffer_is_bounded_and_keeps_latest():
    rb = RingBuffer("t", max_neighbors=4)
    for i in range(10):
        rb.add(0, i)
    assert rb.count[0] == 4
    assert set(rb.row(0)) == {6, 7, 8, 9}
    # capacity growth past the initial allocation
    rb.add(5000, 42)
    assert rb.capacity > 5000 and rb.row(5000).tolist() == [42]
    assert rb.counts(np.array([0, 5000, 10**6])).tolist() == [4, 1, 0]


def test_ring_buffer_bulk_load_matches_incremental():
    indptr = np.array([0, 2, 2, 9], np.int64)
    indices = np.arange(9, dtype=np.int32)
    bulk = RingBuffer("bulk", max_neighbors=4)
    bulk.bulk_load(indptr, indices)
    inc = RingBuffer("inc", max_neighbors=4)
    for node in range(3):
        for dst in indices[indptr[node]:indptr[node + 1]]:
            inc.add(node, int(dst))
    for node in range(3):
        assert set(bulk.row(node)) == set(inc.row(node)), node
        assert bulk.count[node] == inc.count[node]


def test_batched_join_matches_scalar_join_same_rng(setup):
    """The vectorized join and the per-key scalar baseline consume the same
    uniform stream and must produce bit-identical tiles."""
    g, truth, cfg, tr = setup

    def make(impl):
        nl = NearlineInference(cfg, tr.state.params["encoder"], micro_batch=16,
                               fanouts=(4, 3), seed=11, join_impl=impl)
        nl.bootstrap_from_graph(g)
        return nl

    batched, scalar = make("batched"), make("scalar")
    nodes = [("member", 3), ("job", 5), ("member", 3), ("skill", 2),
             ("job", 59), ("title", 0), ("member", 199)]
    from conftest import assert_tiles_equal
    tile_b = batched._sequential_join(nodes)
    tile_s = scalar._sequential_join(nodes)
    assert_tiles_equal(tile_b, tile_s)
    # the batched path must fetch strictly fewer (deduped) feature keys
    assert batched.metrics.join_reads < scalar.metrics.join_reads


def test_batched_join_matches_scalar_end_to_end(setup):
    """Same events through both join impls -> identical served embeddings."""
    g, truth, cfg, tr = setup

    def run(impl):
        nl = NearlineInference(cfg, tr.state.params["encoder"], micro_batch=8,
                               fanouts=(4, 3), seed=5, join_impl=impl)
        nl.bootstrap_from_graph(g)
        for i in range(12):
            nl.topic.publish(Event(time=float(i), kind="engagement",
                                   payload={"member_id": 3 * i, "job_id": i}))
        nl.process()
        return nl

    a, b = run("batched"), run("scalar")
    for i in range(12):
        ea = a.embedding_store.get_embedding("job", i)[0]
        eb = b.embedding_store.get_embedding("job", i)[0]
        np.testing.assert_allclose(ea, eb, rtol=1e-6, atol=1e-6)


def test_no_retrace_across_same_bucket_batches(setup):
    """Consecutive nearline batches with differing node counts inside one
    power-of-two bucket must reuse the compiled encoder (1 trace total)."""
    g, truth, cfg, tr = setup
    nl = NearlineInference(cfg, tr.state.params["encoder"], micro_batch=16)
    nl.bootstrap_from_graph(g)
    for n_events in (3, 2, 4, 1):       # 2-8 touched nodes -> bucket 8
        for i in range(n_events):
            nl.topic.publish(Event(time=1.0, kind="engagement",
                                   payload={"member_id": i, "job_id": i}))
        nl.process()
    assert nl.metrics.batches == 4
    assert nl.metrics.encoder_traces == 1
    # a batch in a new bucket compiles exactly once more
    for i in range(8):
        nl.topic.publish(Event(time=2.0, kind="engagement",
                               payload={"member_id": 10 + i, "job_id": 10 + i}))
    nl.process()
    assert nl.metrics.encoder_traces == 2


def test_sequential_join_reads_are_bounded(setup):
    g, truth, cfg, tr = setup
    nl = NearlineInference(cfg, tr.state.params["encoder"], micro_batch=8,
                           fanouts=(4, 2))
    nl.bootstrap_from_graph(g)
    nl.topic.publish(Event(time=0.0, kind="engagement",
                           payload={"member_id": 0, "job_id": 0}))
    nl.process()
    # 2 nodes refreshed, fanouts (4,2): joins <= nodes*(1 + 4 + 4*2) + padding
    assert nl.metrics.join_reads <= 8 * (1 + 4 + 8)
