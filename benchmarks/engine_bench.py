"""Graph-substrate benchmark (DESIGN.md §8): K-hop tile-build throughput of
the two GraphEngine backends through the one shared TileBuilder.

Arms: {snapshot, streaming} × {K=2 (8,4), K=3 (8,4,2)} at a fixed query
batch, plus the structural row the refactor's acceptance gate tracks —
bit-identical tiles from both backends on the same uniform stream (the
training/serving-parity claim, not a timing).

The snapshot arm is the trainer's sampling hot path (merged-CSR gathers);
the streaming arm is the nearline join hot path (ring sampling + deduped
feature multi_gets).  K=3 costs ~F3× the hop-2 work, which is exactly the
padded-tile scaling the encoder inherits.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, standard_graph, timed
from repro.core.engine import (SnapshotEngine, StreamingEngine, TileBuilder,
                               slab_width)

BATCH = 64
FANOUT_ARMS = (("k2", (8, 4)), ("k3", (8, 4, 2)))


def _engines(g):
    stream = StreamingEngine(g.feat_dim, max_neighbors=128)
    stream.bootstrap_from_graph(g)
    return {"snapshot": SnapshotEngine(g), "streaming": stream}


def bench_engine_tile_build():
    g, _ = standard_graph(0)
    engines = _engines(g)
    ids = np.arange(BATCH) % g.num_nodes["member"]
    for kname, fanouts in FANOUT_ARMS:
        for ename, engine in engines.items():
            builder = TileBuilder(engine, fanouts)

            def build(b=builder):
                return b.build("member", ids, rng=np.random.default_rng(0))

            tile, us = timed(build, repeats=5)
            emit(f"engine_tile_build_{ename}_{kname}", us,
                 f"query_nodes_per_s={BATCH / (us / 1e6):.0f};"
                 f"fanouts={'x'.join(map(str, fanouts))};"
                 f"tile_entries={tile.types[-1].size};"
                 f"hop_mask_mean={tile.masks[-1].mean():.3f}")


def bench_engine_backend_parity():
    """Not a timing: asserts the substrate contract the refactor rests on —
    both backends emit bit-identical tiles from one uniform stream."""
    g, _ = standard_graph(0)
    engines = _engines(g)
    ids = np.arange(BATCH) % g.num_nodes["member"]
    for kname, fanouts in FANOUT_ARMS:
        u = np.random.default_rng(3).random((BATCH, slab_width(fanouts)))
        tiles = [TileBuilder(e, fanouts).build("member", ids, uniforms=u)
                 for e in engines.values()]
        flat = [np.concatenate([np.asarray(x, np.float64).ravel()
                                for hop in t for x in hop]) for t in tiles]
        bitmatch = bool(np.array_equal(flat[0], flat[1]))
        emit(f"engine_backend_parity_{kname}", 0.0,
             f"tiles_bitmatch={bitmatch};uniforms={u.size}")
        assert bitmatch, f"backend parity broken at {kname}"  # fail the run, not just the row


ALL_ENGINE = [
    bench_engine_tile_build,
    bench_engine_backend_parity,
]
