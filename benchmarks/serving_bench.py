"""Online-serving benchmark (DESIGN.md §10): partition quality, the
sharded-vs-single bit-parity gate, dynamic batching vs sequential scoring,
shard scaling, and the result cache.

Rows:

  * serving_partition_{hash,greedy} — edge-cut fraction + balance of the
    two partitioners over the standard graph;
  * serving_parity_p{1,2,4} — THE acceptance gate: after the same
    bootstrap + event stream, the union of the P shard stores is
    bit-identical to the single-engine ``NearlineInference`` live table,
    and the router's scatter-gather embeddings match bit-for-bit;
  * serving_batched / serving_sequential — the same Poisson request trace
    through the DynamicBatcher (max_batch=16) vs the unbatched baseline
    (max_batch=1), both identically warmed: events/s + p50/p95/p99 + SLO
    violation rate (at least the batched arm must win on events/s);
  * serving_shards_p{1,2,4} — batched throughput vs shard count with the
    remote-resolution fraction (the scatter-gather fan-out cost);
  * serving_cache — ResultCache arm: hit rate + throughput on a re-played
    trace (hits return bit-identical embeddings, so this is pure win).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, standard_graph
from repro.configs.linksage import smoke as gnn_smoke
from repro.core import encoder as enc
from repro.core.embeddings import StalenessPolicy, tables_bitwise_equal
from repro.core.nearline import NearlineInference
from repro.data import marketplace_event_stream
from repro.core.partition import GraphPartitioner
from repro.serving import (BatchPolicy, LoadConfig, LoadGenerator, ResultCache,
                           ShardedNearline, serve_trace)

N_EVENTS = 96
N_REQUESTS = 128
MICRO_BATCH = 32
SEED = 13


def _cfg(g):
    from dataclasses import replace
    return replace(gnn_smoke(), feat_dim=g.feat_dim)


def _params(cfg):
    import jax
    return enc.encoder_init(jax.random.PRNGKey(0), cfg)


def _event_stream(g, rng, n=N_EVENTS):
    return marketplace_event_stream(g, rng, n)


def _cluster(g, cfg, params, P, *, strategy="hash", policy=None):
    part = GraphPartitioner(P, strategy)
    if strategy == "greedy":
        part.fit(g)
    cl = ShardedNearline(cfg, params, part, micro_batch=MICRO_BATCH,
                         seed=SEED, policy=policy)
    cl.bootstrap_from_graph(g)
    return cl


def _requests(g, *, n=N_REQUESTS, rate=2000.0, candidates=4, seed=1):
    gen = LoadGenerator(LoadConfig(rate_hz=rate, num_requests=n,
                                   candidates=candidates, seed=seed),
                        num_members=g.num_nodes["member"],
                        num_jobs=g.num_nodes["job"])
    return gen.requests()


def bench_serving_partition_quality():
    """Hash vs greedy edge-cut over the standard graph."""
    g, _ = standard_graph(0)
    for strategy in ("hash", "greedy"):
        part = GraphPartitioner(4, strategy)
        if strategy == "greedy":
            part.fit(g)
        s = part.cut_stats(g)
        emit(f"serving_partition_{strategy}", 0.0,
             f"shards=4;cut_fraction={s['cut_fraction']:.3f};"
             f"balance={s['balance']:.2f}")


def bench_serving_parity():
    """The §10 acceptance gate: P ∈ {1, 2, 4} sharded stores and router
    reads are bit-identical to the single-engine nearline path."""
    g, _ = standard_graph(0)
    cfg = _cfg(g)
    params = _params(cfg)
    events = _event_stream(g, np.random.default_rng(0))
    policy = StalenessPolicy(closure_radius=None)

    nl = NearlineInference(cfg, params, micro_batch=MICRO_BATCH, seed=SEED,
                           policy=policy)
    nl.bootstrap_from_graph(g)
    for ev in events:
        nl.topic.publish(ev)
    nl.process()
    golden = nl.embedding_store.live_embeddings()

    probe = [("member", 3), ("job", 7), ("member", 11), ("job", 0)]
    golden_probe = nl.lifecycle.encode_nodes(probe)

    for P in (1, 2, 4):
        cl = _cluster(g, cfg, params, P, policy=policy)
        for ev in events:
            cl.topic.publish(ev)
        cl.process()
        ok_table = tables_bitwise_equal(golden, cl.live_embeddings())
        from repro.serving import Router
        emb = Router(cl).resolve_embeddings(probe)
        ok_router = all(np.array_equal(golden_probe[i], emb[k])
                        for i, k in enumerate(probe))
        emit(f"serving_parity_p{P}", 0.0,
             f"bitwise_identical={int(ok_table and ok_router)};"
             f"table={int(ok_table)};router={int(ok_router)};"
             f"remote_frac={cl.remote_fraction():.3f}")
        assert ok_table and ok_router, f"P={P} sharded parity violated"


def bench_serving_batched_vs_sequential():
    """Dynamic micro-batching vs one-request-at-a-time scoring, identically
    warmed; the batched arm must win on events/s."""
    g, _ = standard_graph(0)
    cfg = _cfg(g)
    params = _params(cfg)
    cl = _cluster(g, cfg, params, 2)
    reqs = _requests(g)
    arms = {"batched": BatchPolicy(max_batch=16, max_wait_s=0.02),
            "sequential": BatchPolicy(max_batch=1, max_wait_s=0.0)}
    rps = {}
    for name, pol in arms.items():
        serve_trace(cl, reqs, policy=pol)        # warm the jit buckets
        rep, _, _ = serve_trace(cl, reqs, policy=pol)
        s = rep.summary()
        rps[name] = s["throughput_rps"]
        emit(f"serving_{name}", 1e6 / max(s["throughput_rps"], 1e-9),
             f"events_per_s={s['throughput_rps']:.0f};"
             f"p50_ms={s['latency_p50_ms']:.1f};"
             f"p95_ms={s['latency_p95_ms']:.1f};"
             f"p99_ms={s['latency_p99_ms']:.1f};"
             f"slo_violation={s['slo_violation_rate']:.2f};"
             f"occupancy={s['occupancy_mean']:.2f}")
    assert rps["batched"] > rps["sequential"], rps


def bench_serving_shard_scaling():
    """Batched throughput vs shard count + the remote-row fraction."""
    g, _ = standard_graph(0)
    cfg = _cfg(g)
    params = _params(cfg)
    reqs = _requests(g)
    pol = BatchPolicy(max_batch=16, max_wait_s=0.02)
    for P in (1, 2, 4):
        cl = _cluster(g, cfg, params, P)
        serve_trace(cl, reqs, policy=pol)        # warm
        rep, _, _ = serve_trace(cl, reqs, policy=pol)
        s = rep.summary()
        emit(f"serving_shards_p{P}", 1e6 / max(s["throughput_rps"], 1e-9),
             f"events_per_s={s['throughput_rps']:.0f};"
             f"p99_ms={s['latency_p99_ms']:.1f};"
             f"remote_frac={cl.remote_fraction():.3f}")


def bench_serving_cache():
    """ResultCache on a re-played trace: hit rate + throughput vs cold."""
    g, _ = standard_graph(0)
    cfg = _cfg(g)
    params = _params(cfg)
    cl = _cluster(g, cfg, params, 2)
    reqs = _requests(g)
    pol = BatchPolicy(max_batch=16, max_wait_s=0.02)
    serve_trace(cl, reqs, policy=pol)            # warm jit, no cache
    cold, _, _ = serve_trace(cl, reqs, policy=pol)
    cache = ResultCache(8192)
    serve_trace(cl, reqs, policy=pol, cache=cache)      # populate
    warm, _, router = serve_trace(cl, reqs, policy=pol, cache=cache)
    emit("serving_cache", 1e6 / max(warm.throughput_rps, 1e-9),
         f"hit_rate={cache.hit_rate():.2f};"
         f"events_per_s={warm.throughput_rps:.0f};"
         f"cold_events_per_s={cold.throughput_rps:.0f};"
         f"entries={len(cache)}")


ALL_SERVING = [
    bench_serving_partition_quality,
    bench_serving_parity,
    bench_serving_batched_vs_sequential,
    bench_serving_shard_scaling,
    bench_serving_cache,
]
