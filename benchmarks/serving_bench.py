"""Online-serving benchmark (DESIGN.md §10): partition quality, the
sharded-vs-single bit-parity gate, dynamic batching vs sequential scoring,
shard scaling, and the result cache.

Rows:

  * serving_partition_{hash,greedy} — edge-cut fraction + balance of the
    two partitioners over the standard graph;
  * serving_parity_p{1,2,4} — THE acceptance gate: after the same
    bootstrap + event stream, the union of the P shard stores is
    bit-identical to the single-engine ``NearlineInference`` live table,
    and the router's scatter-gather embeddings match bit-for-bit;
  * serving_batched / serving_sequential — the same Poisson request trace
    through the DynamicBatcher (max_batch=16) vs the unbatched baseline
    (max_batch=1), both identically warmed: events/s + p50/p95/p99 + SLO
    violation rate (at least the batched arm must win on events/s);
  * serving_shards_p{1,2,4} — batched throughput vs shard count with the
    remote-resolution fraction (the scatter-gather fan-out cost);
  * serving_cache — ResultCache arm: hit rate + throughput on a re-played
    trace (hits return bit-identical embeddings, so this is pure win);
  * serving_mesh_fanout_p{2,4} (``--mesh`` suite, §13) — one shard_map
    block dispatch vs P sequential per-shard dispatches, bit parity
    asserted; derived ``mesh_speedup_p{2,4}``;
  * serving_partition_fit_{300k,10m} (``--mesh`` suite) — chunked greedy
    fit vs the reference Python loop (identical assignment asserted) and
    the 10M-edge scale row (derived ``partition_fit_10m_edges_s``).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, standard_graph
from repro.configs.linksage import smoke as gnn_smoke
from repro.core import encoder as enc
from repro.core.embeddings import StalenessPolicy, tables_bitwise_equal
from repro.core.nearline import NearlineInference
from repro.data import marketplace_event_stream
from repro.core.partition import GraphPartitioner
from repro.serving import (BatchPolicy, LoadConfig, LoadGenerator, ResultCache,
                           ShardedNearline, serve_trace)

N_EVENTS = 96
N_REQUESTS = 128
MICRO_BATCH = 32
SEED = 13


def _cfg(g):
    from dataclasses import replace
    return replace(gnn_smoke(), feat_dim=g.feat_dim)


def _params(cfg):
    import jax
    return enc.encoder_init(jax.random.PRNGKey(0), cfg)


def _event_stream(g, rng, n=N_EVENTS):
    return marketplace_event_stream(g, rng, n)


def _cluster(g, cfg, params, P, *, strategy="hash", policy=None):
    part = GraphPartitioner(P, strategy)
    if strategy == "greedy":
        part.fit(g)
    cl = ShardedNearline(cfg, params, part, micro_batch=MICRO_BATCH,
                         seed=SEED, policy=policy)
    cl.bootstrap_from_graph(g)
    return cl


def _requests(g, *, n=N_REQUESTS, rate=2000.0, candidates=4, seed=1):
    gen = LoadGenerator(LoadConfig(rate_hz=rate, num_requests=n,
                                   candidates=candidates, seed=seed),
                        num_members=g.num_nodes["member"],
                        num_jobs=g.num_nodes["job"])
    return gen.requests()


def bench_serving_partition_quality():
    """Hash vs greedy edge-cut over the standard graph."""
    g, _ = standard_graph(0)
    for strategy in ("hash", "greedy"):
        part = GraphPartitioner(4, strategy)
        if strategy == "greedy":
            part.fit(g)
        s = part.cut_stats(g)
        emit(f"serving_partition_{strategy}", 0.0,
             f"shards=4;cut_fraction={s['cut_fraction']:.3f};"
             f"balance={s['balance']:.2f}")


def bench_serving_parity():
    """The §10 acceptance gate: P ∈ {1, 2, 4} sharded stores and router
    reads are bit-identical to the single-engine nearline path."""
    g, _ = standard_graph(0)
    cfg = _cfg(g)
    params = _params(cfg)
    events = _event_stream(g, np.random.default_rng(0))
    policy = StalenessPolicy(closure_radius=None)

    nl = NearlineInference(cfg, params, micro_batch=MICRO_BATCH, seed=SEED,
                           policy=policy)
    nl.bootstrap_from_graph(g)
    for ev in events:
        nl.topic.publish(ev)
    nl.process()
    golden = nl.embedding_store.live_embeddings()

    probe = [("member", 3), ("job", 7), ("member", 11), ("job", 0)]
    golden_probe = nl.lifecycle.encode_nodes(probe)

    for P in (1, 2, 4):
        cl = _cluster(g, cfg, params, P, policy=policy)
        for ev in events:
            cl.topic.publish(ev)
        cl.process()
        ok_table = tables_bitwise_equal(golden, cl.live_embeddings())
        from repro.serving import Router
        emb = Router(cl).resolve_embeddings(probe)
        ok_router = all(np.array_equal(golden_probe[i], emb[k])
                        for i, k in enumerate(probe))
        emit(f"serving_parity_p{P}", 0.0,
             f"bitwise_identical={int(ok_table and ok_router)};"
             f"table={int(ok_table)};router={int(ok_router)};"
             f"remote_frac={cl.remote_fraction():.3f}")
        assert ok_table and ok_router, f"P={P} sharded parity violated"


def bench_serving_batched_vs_sequential():
    """Dynamic micro-batching vs one-request-at-a-time scoring, identically
    warmed; the batched arm must win on events/s."""
    g, _ = standard_graph(0)
    cfg = _cfg(g)
    params = _params(cfg)
    cl = _cluster(g, cfg, params, 2)
    reqs = _requests(g)
    arms = {"batched": BatchPolicy(max_batch=16, max_wait_s=0.02),
            "sequential": BatchPolicy(max_batch=1, max_wait_s=0.0)}
    rps = {}
    for name, pol in arms.items():
        serve_trace(cl, reqs, policy=pol)        # warm the jit buckets
        rep, _, _ = serve_trace(cl, reqs, policy=pol)
        s = rep.summary()
        rps[name] = s["throughput_rps"]
        emit(f"serving_{name}", 1e6 / max(s["throughput_rps"], 1e-9),
             f"events_per_s={s['throughput_rps']:.0f};"
             f"p50_ms={s['latency_p50_ms']:.1f};"
             f"p95_ms={s['latency_p95_ms']:.1f};"
             f"p99_ms={s['latency_p99_ms']:.1f};"
             f"slo_violation={s['slo_violation_rate']:.2f};"
             f"occupancy={s['occupancy_mean']:.2f}")
    assert rps["batched"] > rps["sequential"], rps


def bench_serving_shard_scaling():
    """Batched throughput vs shard count + the remote-row fraction."""
    g, _ = standard_graph(0)
    cfg = _cfg(g)
    params = _params(cfg)
    reqs = _requests(g)
    pol = BatchPolicy(max_batch=16, max_wait_s=0.02)
    for P in (1, 2, 4):
        cl = _cluster(g, cfg, params, P)
        serve_trace(cl, reqs, policy=pol)        # warm
        rep, _, _ = serve_trace(cl, reqs, policy=pol)
        s = rep.summary()
        emit(f"serving_shards_p{P}", 1e6 / max(s["throughput_rps"], 1e-9),
             f"events_per_s={s['throughput_rps']:.0f};"
             f"p99_ms={s['latency_p99_ms']:.1f};"
             f"remote_frac={cl.remote_fraction():.3f}")


def bench_serving_cache():
    """ResultCache on a re-played trace: hit rate + throughput vs cold."""
    g, _ = standard_graph(0)
    cfg = _cfg(g)
    params = _params(cfg)
    cl = _cluster(g, cfg, params, 2)
    reqs = _requests(g)
    pol = BatchPolicy(max_batch=16, max_wait_s=0.02)
    serve_trace(cl, reqs, policy=pol)            # warm jit, no cache
    cold, _, _ = serve_trace(cl, reqs, policy=pol)
    cache = ResultCache(8192)
    serve_trace(cl, reqs, policy=pol, cache=cache)      # populate
    warm, _, router = serve_trace(cl, reqs, policy=pol, cache=cache)
    emit("serving_cache", 1e6 / max(warm.throughput_rps, 1e-9),
         f"hit_rate={cache.hit_rate():.2f};"
         f"events_per_s={warm.throughput_rps:.0f};"
         f"cold_events_per_s={cold.throughput_rps:.0f};"
         f"entries={len(cache)}")


def _owned_keys(cl, per_shard):
    """``per_shard`` member keys owned by each shard, in shard-major order."""
    buckets = [[] for _ in range(cl.num_shards)]
    i = 0
    while any(len(b) < per_shard for b in buckets):
        p = cl.partitioner.shard_of("member", i)
        if len(buckets[p]) < per_shard:
            buckets[p].append(("member", i))
        i += 1
    return buckets


def bench_mesh_fanout():
    """§13 device-parallel fan-out: P padded per-shard tiles through ONE
    shard_map block dispatch vs P sequential per-shard dispatches (the host
    oracle arm), identical bits asserted.  On a single-core CI host the win
    is dispatch amortization (P jit round-trips -> 1), so the bench uses
    the B=8 bucket where per-dispatch overhead dominates.  Emits
    ``mesh_speedup_p{2,4}``; off-mesh (fewer devices than shards) the row
    reports on_mesh=0 and no speedup claim."""
    import time

    from repro.core.engine import pad_tile
    from repro.serving import MeshFanout
    g, _ = standard_graph(0)
    cfg = _cfg(g)
    params = _params(cfg)
    B, ROUNDS = 8, 10
    for P in (2, 4):
        cl = _cluster(g, cfg, params, P)
        fan = MeshFanout(cl)
        if not fan.on_mesh:
            emit(f"serving_mesh_fanout_p{P}", 0.0,
                 "on_mesh=0;mesh_speedup_unavailable=1")
            continue
        tiles = [pad_tile(lc.tile_fn(keys), B) for lc, keys in
                 zip(cl.shards, _owned_keys(cl, B))]

        def mesh_arm():
            for _ in range(ROUNDS):
                rows = fan.encode_block(tiles)
            return rows

        def host_arm():
            for _ in range(ROUNDS):
                rows = fan.encode_block_host(tiles)
            return rows

        mesh_rows = mesh_arm()                   # warm both jit arms
        host_rows = host_arm()
        assert np.array_equal(mesh_rows, host_rows), f"P={P} block parity"
        best_m = best_h = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            mesh_arm()
            best_m = min(best_m, time.perf_counter() - t0)
            t0 = time.perf_counter()
            host_arm()
            best_h = min(best_h, time.perf_counter() - t0)
        speedup = best_h / best_m
        emit(f"serving_mesh_fanout_p{P}", best_m / ROUNDS * 1e6,
             f"on_mesh=1;mesh_speedup_p{P}={speedup:.2f};"
             f"host_us={best_h / ROUNDS * 1e6:.0f};batch={B};"
             f"bitwise_identical=1")


def _random_bipartite(num_members, num_jobs, num_edges, seed):
    """A big random member-job graph with ``num_edges`` stored directed
    edges (reciprocal CSRs, so fit sees 2x that many)."""
    from repro.core.graph import HeteroGraph
    rng = np.random.default_rng(seed)
    g = HeteroGraph(
        num_nodes={"member": num_members, "job": num_jobs},
        features={"member": np.zeros((1, 4), np.float32),
                  "job": np.zeros((1, 4), np.float32)})
    g.add_edges("member", "job",
                rng.integers(0, num_members, num_edges),
                rng.integers(0, num_jobs, num_edges), reciprocal=True)
    return g


def bench_partition_fit():
    """The chunked multi-pass greedy fit vs the reference Python loop.

    Two rows: a head-to-head at ~300k stored edges with the
    identical-assignment contract asserted (``fit_speedup``), and the
    10M-edge scale row the reference arm cannot afford in CI
    (``partition_fit_10m_edges_s``, new fit only — the contract is
    enforced at the small scale and by the tier-1 tests)."""
    import time

    g = _random_bipartite(60_000, 20_000, 300_000, seed=3)
    ref, new = GraphPartitioner(4, "greedy"), GraphPartitioner(4, "greedy")
    t0 = time.perf_counter()
    ref._fit_reference(g)
    t_ref = time.perf_counter() - t0
    t0 = time.perf_counter()
    new.fit(g)
    t_new = time.perf_counter() - t0
    same = all(np.array_equal(ref._dense[t], new._dense[t])
               for t in ref._dense)
    assert same, "vectorized fit diverged from reference assignment"
    emit("serving_partition_fit_300k", t_new * 1e6,
         f"fit_s={t_new:.2f};ref_s={t_ref:.2f};"
         f"fit_speedup={t_ref / t_new:.1f};identical_assignment=1")

    g10 = _random_bipartite(1_200_000, 400_000, 10_000_000, seed=4)
    big = GraphPartitioner(8, "greedy")
    t0 = time.perf_counter()
    big.fit(g10)
    t_10m = time.perf_counter() - t0
    s = big.cut_stats(g10)
    emit("serving_partition_fit_10m", t_10m * 1e6,
         f"partition_fit_10m_edges_s={t_10m:.2f};"
         f"cut_fraction={s['cut_fraction']:.3f};balance={s['balance']:.2f}")


ALL_SERVING = [
    bench_serving_partition_quality,
    bench_serving_parity,
    bench_serving_batched_vs_sequential,
    bench_serving_shard_scaling,
    bench_serving_cache,
]

# the §13 device-parallel arm: run via ``benchmarks.run --mesh`` under
# XLA_FLAGS=--xla_force_host_platform_device_count=4 (CPU CI) — separate
# from ALL_SERVING because the mesh rows need the forced device count and
# the 10M-edge fit row needs a fresh process (memory-pressure timing)
ALL_SERVING_MESH = [
    bench_mesh_fanout,
    bench_partition_fit,
]
