"""Training hot-path benchmark (the other half of the paper's decoupled
train/serve methodology, §4 / Figure 3).

Replays the same synthetic-graph training job through the arms of the
sync-vs-prefetch × donated-vs-copy × mean-vs-attention matrix:

  * ``sync_copy_unfused``       — the PR 1 baseline: synchronous host
                                  sampling, un-donated TrainState, one
                                  encode dispatch per tile, a host sync on
                                  the metrics every step;
  * ``prefetch_donated_fused``  — the pipelined hot path: background-thread
                                  sampler with double-buffered device_put,
                                  donated TrainState buffers, one stacked
                                  [2B, ...] encode, metrics fetched after
                                  the loop;
  * the two mixed arms isolate each lever; the ``*_attn`` arms run the same
    comparison through the attention aggregator (the fused Pallas
    sage_attention_layer path).

All arms are identically warmed (same warmup steps compile + prime every
executable outside the timed region), timed best-of-``REPEATS`` (shared CPU
containers are noisy), and share per-step RNG streams, so the equivalence
row can assert the prefetch trainer reproduces the synchronous trainer's
loss history bit-for-bit at equal seeds.

On CPU the step compute (the 6-type masked transform, FLOP-bound) dwarfs
the vectorized sampler, so the headline arm ratio under-sells the pipeline;
``sampler_stall_frac`` ≈ 0 is the structural claim — the sampler and the
host→device copies are fully hidden behind compute, which is exactly what
scales on accelerators where the compute side is ~free (LiGNN's regime).
The component row reports the raw sample/step split backing that up.
"""
from __future__ import annotations

import time
from dataclasses import replace

import jax
import numpy as np

from benchmarks.common import emit, standard_graph
from repro.configs.linksage import CONFIG as GNN_CONFIG
from repro.core.linksage import LinkSAGETrainer

N_STEPS = 30
WARMUP = 4
BATCH = 128
REPEATS = 2


def _bench_cfg(g, aggregator: str = "mean"):
    return replace(GNN_CONFIG, hidden_dim=64, embed_dim=64, fanouts=(8, 4),
                   aggregator=aggregator, feat_dim=g.feat_dim)


def _run_arm(g, cfg, *, prefetch: int, donate: bool, fused: bool,
             steps: int = N_STEPS, batch: int = BATCH, seed: int = 0,
             repeats: int = REPEATS):
    tr = LinkSAGETrainer(cfg, g, seed=seed, prefetch=prefetch, donate=donate,
                         fused_encode=fused)
    tr.train(WARMUP, batch_size=batch)          # identical warmup in every arm
    hist, stats = None, None
    for r in range(repeats):                    # best-of rate: shared-CPU noise
        h = tr.train(steps, batch_size=batch)
        if r == 0:
            hist = h                            # fixed step window across arms
        if stats is None or tr.last_train_stats["steps_per_s"] > stats["steps_per_s"]:
            stats = tr.last_train_stats
    return hist, stats


def bench_train_components():
    """Raw per-step cost split: host sampling vs device step (the overlap
    budget the prefetcher can hide)."""
    g, _ = standard_graph(0)
    cfg = _bench_cfg(g)
    tr = LinkSAGETrainer(cfg, g, seed=0)
    tr.train(WARMUP, batch_size=BATCH)
    t0 = time.perf_counter()
    for i in range(10):
        batch = tr._build_batch(i, BATCH)
    t_sample = (time.perf_counter() - t0) / 10
    xb = tr._transfer(batch)
    step = tr._get_step(3e-3)
    state, m = step(tr.state, *xb)
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for _ in range(10):
        state, m = step(state, *xb)
    jax.block_until_ready(m["loss"])
    t_step = (time.perf_counter() - t0) / 10
    emit("train_component_split", t_step * 1e6,
         f"sample_ms={t_sample * 1e3:.2f};step_ms={t_step * 1e3:.2f};"
         f"hideable_frac={t_sample / (t_sample + t_step):.3f}")


def bench_train_pipeline():
    g, _ = standard_graph(0)
    cfg = _bench_cfg(g)
    arms = {
        "sync_copy_unfused":      dict(prefetch=0, donate=False, fused=False),
        "sync_donated_fused":     dict(prefetch=0, donate=True, fused=True),
        "prefetch_copy_unfused":  dict(prefetch=2, donate=False, fused=False),
        "prefetch_donated_fused": dict(prefetch=2, donate=True, fused=True),
    }
    rates = {}
    for label, kw in arms.items():
        hist, s = _run_arm(g, cfg, **kw)
        rates[label] = s["steps_per_s"]
        emit(f"train_pipeline_{label}", 1e6 / max(s["steps_per_s"], 1e-9),
             f"steps_per_s={s['steps_per_s']:.2f};"
             f"sampler_stall_frac={s['sampler_stall_frac']:.3f};"
             f"final_loss={hist[-1]['loss']:.4f}")
    emit("train_pipeline_speedup", 0.0,
         f"steps_per_s_ratio={rates['prefetch_donated_fused'] / rates['sync_copy_unfused']:.2f}x;"
         f"pipelined={rates['prefetch_donated_fused']:.2f};"
         f"baseline={rates['sync_copy_unfused']:.2f}")


def bench_train_pipeline_attention():
    """Same matrix endpoints through the fused attention-aggregator kernel."""
    g, _ = standard_graph(0)
    cfg = _bench_cfg(g, aggregator="attention")
    rates = {}
    for label, kw in (
            ("sync_copy_unfused_attn", dict(prefetch=0, donate=False, fused=False)),
            ("prefetch_donated_fused_attn", dict(prefetch=2, donate=True, fused=True))):
        hist, s = _run_arm(g, cfg, **kw)
        rates[label] = s["steps_per_s"]
        emit(f"train_pipeline_{label}", 1e6 / max(s["steps_per_s"], 1e-9),
             f"steps_per_s={s['steps_per_s']:.2f};"
             f"sampler_stall_frac={s['sampler_stall_frac']:.3f};"
             f"final_loss={hist[-1]['loss']:.4f}")
    emit("train_pipeline_speedup_attn", 0.0,
         f"steps_per_s_ratio={rates['prefetch_donated_fused_attn'] / rates['sync_copy_unfused_attn']:.2f}x")


def bench_train_prefetch_equivalence():
    """Prefetch must reproduce the synchronous loss history bit-for-bit at
    equal seeds (same per-step RNG streams, same donated+fused step)."""
    g, _ = standard_graph(0)
    cfg = _bench_cfg(g)
    h_sync, _ = _run_arm(g, cfg, prefetch=0, donate=True, fused=True,
                         steps=12, batch=64, repeats=1)
    h_pre, s = _run_arm(g, cfg, prefetch=4, donate=True, fused=True,
                        steps=12, batch=64, repeats=1)
    l_sync = [m["loss"] for m in h_sync]
    l_pre = [m["loss"] for m in h_pre]
    emit("train_prefetch_equivalence", 0.0,
         f"loss_bitmatch={l_sync == l_pre};"
         f"max_abs_delta={max(abs(a - b) for a, b in zip(l_sync, l_pre)):.1e};"
         f"sampler_stall_frac={s['sampler_stall_frac']:.3f}")


ALL_TRAIN = [
    bench_train_components,
    bench_train_pipeline,
    bench_train_pipeline_attention,
    bench_train_prefetch_equivalence,
]
