"""Shared helpers for the benchmark harness.

Every benchmark corresponds to a table/claim in the paper (see DESIGN.md §6)
and prints ``name,us_per_call,derived`` CSV rows.  Online A/B metrics are
not reproducible offline; each benchmark reports the stated offline proxy on
synthetic data, labelled in the ``derived`` field.
"""
from __future__ import annotations

import time
from dataclasses import replace

import numpy as np

from repro.configs.linksage import CONFIG as GNN_CONFIG
from repro.core.linksage import LinkSAGETrainer
from repro.data import GraphGenConfig, generate_job_marketplace_graph

ROWS: list[tuple] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn, *args, repeats: int = 3, **kwargs):
    """Returns (result, us_per_call) — best of `repeats` after one warmup."""
    fn(*args, **kwargs)
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6


_CACHE: dict = {}


def standard_graph(seed: int = 0):
    key = ("graph", seed)
    if key not in _CACHE:
        _CACHE[key] = generate_job_marketplace_graph(
            GraphGenConfig(num_members=600, num_jobs=180, seed=seed))
    return _CACHE[key]


def trained_gnn(seed: int = 0, steps: int = 150, aggregator: str = "mean"):
    key = ("gnn", seed, steps, aggregator)
    if key not in _CACHE:
        g, truth = standard_graph(seed)
        cfg = replace(GNN_CONFIG, hidden_dim=64, embed_dim=64, fanouts=(8, 4),
                      aggregator=aggregator)
        tr = LinkSAGETrainer(cfg, g, seed=seed)
        tr.train(steps, batch_size=64)
        m_emb = tr.embed_nodes("member", np.arange(g.num_nodes["member"]))
        j_emb = tr.embed_nodes("job", np.arange(g.num_nodes["job"]))
        _CACHE[key] = (g, truth, cfg, tr, m_emb, j_emb)
    return _CACHE[key]
