"""One benchmark per paper table (DESIGN.md §6 index).

Paper artifact → offline proxy mapping:
  Table 1/2   graph census + sampler throughput
  §3 claim    skill-node ablation (recall@10 delta; paper: +1.5%)
  Table 4/5   TAJ: recruiter-interaction ranker AUC lift from GNN features
  Table 6     JYMBII: engagement ranker AUC lift
  Table 7     segment analysis: cold-start member lift
  Table 8     Job Search: per-query ranking AUC lift
  Table 9     EBR: retrieval recall@10, GNN vs feature-projection baseline
  Table 10    nearline vs offline embedding freshness for new jobs
"""
from __future__ import annotations

import time
from dataclasses import replace

import numpy as np

from benchmarks.common import emit, standard_graph, timed, trained_gnn
from repro.configs.linksage import CONFIG as GNN_CONFIG
from repro.core.eval import auc, retrieval_eval
from repro.core.linksage import LinkSAGETrainer
from repro.core.nearline import Event, NearlineInference, OfflineBatchInference
from repro.core.sampler import NeighborSampler, SamplerConfig
from repro.core.transfer import (DownstreamRanker, RankerConfig,
                                 build_ranker_dataset)
from repro.data import GraphGenConfig, generate_job_marketplace_graph
from repro.data.synthetic_graph import strip_skill_nodes


# ------------------------------------------------------- Table 1/2: graph


def bench_graph_construction():
    t0 = time.perf_counter()
    g, truth = generate_job_marketplace_graph(
        GraphGenConfig(num_members=600, num_jobs=180, seed=0))
    build_us = (time.perf_counter() - t0) * 1e6
    census = g.census()
    emit("table1_2_graph_census", build_us,
         f"nodes={census['total_nodes']};edges={census['total_edges']}")

    sampler = NeighborSampler(g, SamplerConfig(fanouts=(10, 5), seed=0))
    ids = np.arange(128)
    _, us = timed(sampler.sample_batch, "member", ids)
    emit("table1_2_sampler_throughput", us,
         f"nodes_per_s={128 / (us / 1e6):.0f}")


# ------------------------------------------------- §3: skill-node ablation


def bench_skill_ablation():
    g, truth = standard_graph(0)
    g_noskill = strip_skill_nodes(g)
    cfg = replace(GNN_CONFIG, hidden_dim=64, embed_dim=64, fanouts=(8, 4))
    src, dst = truth["engagements"]

    def recall_for(graph, mask=None):
        tr = LinkSAGETrainer(cfg, graph, seed=0)
        tr.train(150, batch_size=64)
        m = tr.embed_nodes("member", np.arange(graph.num_nodes["member"]))
        j = tr.embed_nodes("job", np.arange(graph.num_nodes["job"]))
        return retrieval_eval(m, j, src, dst, k=10, segment_mask=mask)["recall"]

    t0 = time.perf_counter()
    cold = truth["is_cold"]
    r_with = recall_for(g)
    r_with_cold = recall_for(g, cold)
    r_without = recall_for(g_noskill)
    r_without_cold = recall_for(g_noskill, cold)
    us = (time.perf_counter() - t0) * 1e6
    rel = (r_with - r_without) / max(r_without, 1e-9) * 100
    rel_cold = (r_with_cold - r_without_cold) / max(r_without_cold, 1e-9) * 100
    emit("s3_skill_node_ablation", us,
         f"recall_with={r_with:.4f};recall_without={r_without:.4f};"
         f"rel_delta_pct={rel:+.1f};cold_with={r_with_cold:.4f};"
         f"cold_without={r_without_cold:.4f};rel_delta_cold_pct={rel_cold:+.1f};"
         f"paper=+1.5pct")


# -------------------------------------------- shared ranker-lift machinery


def _ranker_lift(label_pairs, seed=0, epochs=5, ctx=None):
    """AUC with vs without GNN features on weak 'other features'."""
    g, truth, cfg, tr, m_emb, j_emb = ctx if ctx is not None else trained_gnn(0)
    rng = np.random.default_rng(seed)
    nm, nj = g.num_nodes["member"], g.num_nodes["job"]
    weak_m = (g.features["member"] * 0.1
              + rng.normal(size=g.features["member"].shape)).astype(np.float32)
    weak_j = (g.features["job"] * 0.1
              + rng.normal(size=g.features["job"].shape)).astype(np.float32)
    pm, pj = label_pairs
    n = len(pm)
    neg_m = rng.integers(0, nm, n).astype(np.int32)
    neg_j = rng.integers(0, nj, n).astype(np.int32)
    pairs = (np.concatenate([pm, neg_m]), np.concatenate([pj, neg_j]))
    labels = np.concatenate([np.ones(n), np.zeros(n)]).astype(np.float32)
    order = rng.permutation(2 * n)
    cut = int(0.8 * 2 * n)
    tr_i, te_i = order[:cut], order[cut:]

    out = {}
    for use_gnn in (True, False):
        ds = build_ranker_dataset(weak_m, weak_j, m_emb, j_emb, pairs, labels,
                                  use_gnn=use_gnn)
        rk = DownstreamRanker(RankerConfig(gnn_embed_dim=cfg.embed_dim,
                                           other_feat_dim=weak_m.shape[1],
                                           use_gnn=use_gnn), seed=0)
        rk.fit({k: v[tr_i] for k, v in ds.items()}, epochs=epochs)
        out[use_gnn] = auc(labels[te_i], rk.score({k: v[te_i] for k, v in ds.items()}))
    return out[True], out[False]


# ------------------------------------------------------ Table 4/5: TAJ


def bench_taj():
    """TAJ optimizes recruiter interactions after application → label =
    recruiter edges (job→member).  Uses a recruiter-dense graph variant
    (TAJ serves Premium members, an engagement-rich segment)."""
    t0 = time.perf_counter()
    g, truth = generate_job_marketplace_graph(
        GraphGenConfig(num_members=600, num_jobs=180, seed=2,
                       recruiter_edges_per_job=4.0))
    cfg = replace(GNN_CONFIG, hidden_dim=64, embed_dim=64, fanouts=(8, 4))
    tr = LinkSAGETrainer(cfg, g, seed=0)
    tr.train(150, batch_size=64)
    m_emb = tr.embed_nodes("member", np.arange(600))
    j_emb = tr.embed_nodes("job", np.arange(180))
    rec = g.adj[("job", "member")]
    pj = np.repeat(np.arange(len(rec.indptr) - 1), np.diff(rec.indptr))
    pm = rec.indices
    a_gnn, a_plain = _ranker_lift((pm.astype(np.int32), pj.astype(np.int32)),
                                  ctx=(g, truth, cfg, tr, m_emb, j_emb))
    us = (time.perf_counter() - t0) * 1e6
    emit("table4_5_taj_recruiter_interactions", us,
         f"auc_gnn={a_gnn:.4f};auc_baseline={a_plain:.4f};"
         f"lift={a_gnn - a_plain:+.4f};n_labels={len(pm)};"
         f"paper=+1.0pct_hearing_back")


# ------------------------------------------------------- Table 6: JYMBII


def bench_jymbii():
    g, truth, cfg, tr, m_emb, j_emb = trained_gnn(0)
    src, dst = truth["engagements"]
    t0 = time.perf_counter()
    a_gnn, a_plain = _ranker_lift((src, dst))
    us = (time.perf_counter() - t0) * 1e6
    emit("table6_jymbii_qualified_applications", us,
         f"auc_gnn={a_gnn:.4f};auc_baseline={a_plain:.4f};"
         f"lift={a_gnn - a_plain:+.4f};paper=+2.2pct_QA")


# ------------------------------------------- Table 7: cold-start segments


def bench_segments():
    g, truth, cfg, tr, m_emb, j_emb = trained_gnn(0)
    src, dst = truth["engagements"]
    t0 = time.perf_counter()
    res_all = retrieval_eval(m_emb, j_emb, src, dst, k=10)
    res_cold = retrieval_eval(m_emb, j_emb, src, dst, k=10,
                              segment_mask=truth["is_cold"])
    res_power = retrieval_eval(m_emb, j_emb, src, dst, k=10,
                               segment_mask=~truth["is_cold"])
    rng = np.random.default_rng(0)
    res_rand = retrieval_eval(rng.normal(size=m_emb.shape),
                              rng.normal(size=j_emb.shape), src, dst, k=10,
                              segment_mask=truth["is_cold"])
    us = (time.perf_counter() - t0) * 1e6
    emit("table7_segment_cold_start", us,
         f"recall_cold={res_cold['recall']:.4f};recall_power={res_power['recall']:.4f};"
         f"recall_all={res_all['recall']:.4f};recall_cold_random={res_rand['recall']:.4f};"
         f"paper=+3.2pct_QA_opportunistic")


# ---------------------------------------------------- Table 8: Job Search


def bench_job_search():
    """Search proxy: per-member ranking among title-matched candidates
    (search narrows candidates; ranking quality within them is the metric)."""
    g, truth, cfg, tr, m_emb, j_emb = trained_gnn(0)
    src, dst = truth["engagements"]
    member_title = truth["member_title"]
    job_title = truth["job_title"]
    t0 = time.perf_counter()
    pos = {}
    for m, j in zip(src, dst):
        pos.setdefault(m, set()).add(int(j))
    aucs, aucs_feat = [], []
    for m, js in list(pos.items())[:200]:
        cand = np.nonzero(job_title == member_title[m])[0]
        cand = np.union1d(cand, np.array(sorted(js)))
        if len(cand) < 4:
            continue
        labels = np.array([1 if int(c) in js else 0 for c in cand])
        if labels.min() == labels.max():
            continue
        aucs.append(auc(labels, m_emb[m] @ j_emb[cand].T))
        aucs_feat.append(auc(labels, g.features["member"][m] @ g.features["job"][cand].T))
    us = (time.perf_counter() - t0) * 1e6
    emit("table8_job_search_ranking", us,
         f"mean_auc_gnn={np.mean(aucs):.4f};mean_auc_feature_baseline="
         f"{np.mean(aucs_feat):.4f};queries={len(aucs)};paper=+0.6pct_sessions")


# ----------------------------------------------------------- Table 9: EBR


def bench_ebr():
    g, truth, cfg, tr, m_emb, j_emb = trained_gnn(0)
    src, dst = truth["engagements"]
    t0 = time.perf_counter()
    mn = m_emb / (np.linalg.norm(m_emb, axis=1, keepdims=True) + 1e-9)
    jn = j_emb / (np.linalg.norm(j_emb, axis=1, keepdims=True) + 1e-9)
    r_gnn = retrieval_eval(mn, jn, src, dst, k=10)["recall"]
    fm, fj = g.features["member"], g.features["job"]
    fmn = fm / (np.linalg.norm(fm, axis=1, keepdims=True) + 1e-9)
    fjn = fj / (np.linalg.norm(fj, axis=1, keepdims=True) + 1e-9)
    r_feat = retrieval_eval(fmn, fjn, src, dst, k=10)["recall"]
    us = (time.perf_counter() - t0) * 1e6
    emit("table9_ebr_retrieval", us,
         f"recall10_gnn={r_gnn:.4f};recall10_feature_baseline={r_feat:.4f};"
         f"rel_lift_pct={(r_gnn - r_feat) / max(r_feat, 1e-9) * 100:+.1f};"
         f"paper=+2.4pct_sessions_organic")


# ----------------------------------------- Table 10: nearline vs offline


def bench_nearline_ablation():
    """New jobs posted during the day: nearline serves fresh embeddings in
    seconds; the offline daily batch leaves them embedding-less (cold) until
    the next day — measured as retrieval coverage + staleness."""
    g, truth, cfg, tr, m_emb, j_emb = trained_gnn(0)
    rng = np.random.default_rng(0)
    feat_dim = g.feat_dim

    def make_pipeline(micro_batch):
        nl = NearlineInference(cfg, tr.state.params["encoder"],
                               micro_batch=micro_batch, fanouts=cfg.fanouts)
        nl.bootstrap_from_graph(g)
        return nl

    events = []
    base_job = g.num_nodes["job"]
    for i in range(24):
        t = 3600.0 * i
        events.append(Event(time=t, kind="job_created", payload={
            "job_id": base_job + i,
            "features": rng.normal(size=feat_dim).astype(np.float32),
            "title": int(rng.integers(0, g.num_nodes["title"])),
            "company": int(rng.integers(0, g.num_nodes["company"])),
        }))
        events.append(Event(time=t + 10, kind="engagement", payload={
            "member_id": int(rng.integers(0, g.num_nodes["member"])),
            "job_id": base_job + i}))

    # nearline arm
    near = make_pipeline(4)
    t0 = time.perf_counter()
    for ev in events:
        near.topic.publish(ev)
        near.process()
    near_summary = near.metrics.summary()
    near_cov = sum(near.embedding_store.get_embedding("job", base_job + i)
                   is not None for i in range(24)) / 24
    us = (time.perf_counter() - t0) * 1e6

    # offline arm: daily batch at t=86400 — during the day nothing is fresh
    off_inner = make_pipeline(1000)
    off = OfflineBatchInference(off_inner, period_s=86_400.0)
    for ev in events:
        off_inner.topic.publish(ev)
    covered_during_day = sum(
        off_inner.embedding_store.get_embedding("job", base_job + i) is not None
        for i in range(24)) / 24
    off.maybe_run(now=86_400.0)
    off_summary = off_inner.metrics.summary()

    emit("table10_nearline_vs_offline", us,
         f"nearline_staleness_p50_s={near_summary['staleness_p50_s']:.1f};"
         f"offline_staleness_p50_s={off_summary['staleness_p50_s']:.1f};"
         f"nearline_day_coverage={near_cov:.2f};offline_day_coverage={covered_during_day:.2f};"
         f"encoder_ms_per_batch={near_summary['encoder_ms_per_batch']:.1f};"
         f"paper=+0.8pct_sessions")


ALL_TABLES = [
    bench_graph_construction,
    bench_skill_ablation,
    bench_taj,
    bench_jymbii,
    bench_segments,
    bench_job_search,
    bench_ebr,
    bench_nearline_ablation,
]
