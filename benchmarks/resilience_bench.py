"""Resilience benchmark (DESIGN.md §12): crash/warm-restart parity,
elastic-reshard parity, and graceful degradation under overload.

Rows:

  * resilience_restart_parity_p{1,2,4} — THE leg-(a) gate: a cluster
    killed mid-stream (deterministic FaultInjector), rolled back to its
    last disk checkpoint, and replayed over the event suffix ends
    bit-identical — store union AND router reads — to an uninterrupted
    run; a cold restart from the latest checkpoint passes the same gate.
    Timed column = checkpoint+restore round-trip cost;
  * resilience_reshard_split / resilience_reshard_merge — leg (b): online
    split of the hottest shard / merge back, each gated on post == pre
    union bits and on continued-stream parity vs a never-resharded run;
  * resilience_overload_x{1,2,4} — leg (c) degradation curve: the same
    skewed trace (zipf keys + flash-crowd burst) at 1x/2x/4x offered load
    through a bounded-queue shedding batcher — shed rate must rise
    MONOTONICALLY with offered load;
  * resilience_overload_degrade — the degrade-to-cached arm vs the
    no-overload-control baseline at the top load: p99 must stay bounded
    (below the baseline's) while overflow converts to staleness-served
    requests, with the undegraded arm as freshness oracle.

Service time is a deterministic MODEL here (fresh requests cost encoder
passes, degraded ones don't), so the curves — and the monotonicity
asserts — are reproducible on any machine.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks.common import emit, standard_graph
from repro.configs.linksage import smoke as gnn_smoke
from repro.core import encoder as enc
from repro.core.embeddings import StalenessPolicy, tables_bitwise_equal
from repro.data import marketplace_event_stream
from repro.core.partition import GraphPartitioner
from repro.serving import (BatchPolicy, FaultInjector, LoadConfig,
                           LoadGenerator, Router, ShardedNearline,
                           load_cluster_checkpoint, merge_shards,
                           restore_cluster, run_with_faults, serve_trace,
                           split_shard)

N_EVENTS = 96
MICRO_BATCH = 16
SEED = 13
PROBE = [("member", 3), ("job", 7), ("member", 11), ("job", 0)]


def _cfg(g):
    from dataclasses import replace
    return replace(gnn_smoke(), feat_dim=g.feat_dim)


def _params(cfg):
    import jax
    return enc.encoder_init(jax.random.PRNGKey(0), cfg)


def _cluster(g, cfg, params, P, *, strategy="hash"):
    part = GraphPartitioner(P, strategy)
    if strategy == "greedy":
        part.fit(g)
    cl = ShardedNearline(cfg, params, part, micro_batch=MICRO_BATCH,
                         seed=SEED, policy=StalenessPolicy(closure_radius=None))
    cl.bootstrap_from_graph(g)
    return cl


def _publish(cl, events):
    for ev in events:
        cl.topic.publish(ev)


def _router_probe(cl):
    return Router(cl).resolve_embeddings(PROBE)


def bench_resilience_restart_parity():
    """Kill → rollback → replay (warm) and latest-checkpoint cold restart,
    both bit-identical to the uninterrupted run, for P ∈ {1, 2, 4}."""
    g, _ = standard_graph(0)
    cfg = _cfg(g)
    params = _params(cfg)
    events = marketplace_event_stream(g, np.random.default_rng(0), N_EVENTS)
    for P in (1, 2, 4):
        golden = _cluster(g, cfg, params, P)
        _publish(golden, events)
        golden.process()
        gold_union = golden.live_embeddings()
        gold_probe = _router_probe(golden)

        faulted = _cluster(g, cfg, params, P)
        _publish(faulted, events)
        with tempfile.TemporaryDirectory() as ckpt_dir:
            inj = FaultInjector(kill_at=(1, 4))
            t0 = time.perf_counter()
            st = run_with_faults(faulted, injector=inj,
                                 checkpoint_every=2, directory=ckpt_dir)
            run_us = (time.perf_counter() - t0) * 1e6
            cold = restore_cluster(load_cluster_checkpoint(ckpt_dir),
                                   cfg=cfg, params=params,
                                   topic=faulted.topic, jit_encoder=True)
            cold.process()
        ok_warm = tables_bitwise_equal(gold_union, faulted.live_embeddings())
        ok_cold = tables_bitwise_equal(gold_union, cold.live_embeddings())
        probe = _router_probe(faulted)
        ok_router = all(np.array_equal(gold_probe[k], probe[k])
                        for k in gold_probe)
        emit(f"resilience_restart_parity_p{P}", run_us,
             f"bitwise_identical={int(ok_warm and ok_cold and ok_router)};"
             f"warm={int(ok_warm)};cold={int(ok_cold)};"
             f"router={int(ok_router)};kills={st['kills']};"
             f"checkpoints={st['checkpoints']};replayed={st['replayed']}")
        assert ok_warm and ok_cold and ok_router, \
            f"P={P} kill/restart parity violated"


def bench_resilience_reshard():
    """Online split of the hottest shard, then merge back — union bits
    unchanged at each step, and a continued event stream lands bit-
    identical to a never-resharded control cluster."""
    g, _ = standard_graph(0)
    cfg = _cfg(g)
    params = _params(cfg)
    events = marketplace_event_stream(g, np.random.default_rng(0), N_EVENTS)
    control = _cluster(g, cfg, params, 2)
    elastic = _cluster(g, cfg, params, 2)
    for cl in (control, elastic):
        _publish(cl, events)
        cl.process()

    t0 = time.perf_counter()
    s = split_shard(elastic)                     # parity gate inside reshard
    split_us = (time.perf_counter() - t0) * 1e6
    ok_split = tables_bitwise_equal(control.live_embeddings(),
                                    elastic.live_embeddings())
    emit("resilience_reshard_split", split_us,
         f"bitwise_identical={int(ok_split)};moved={s['moved']};"
         f"records={s['records']};ring_rows={s['ring_rows']};"
         f"shards={elastic.num_shards}")
    assert ok_split, "split parity violated"

    t0 = time.perf_counter()
    m = merge_shards(elastic, s["dst"], s["src"])
    merge_us = (time.perf_counter() - t0) * 1e6
    more = marketplace_event_stream(g, np.random.default_rng(1), 32)
    for cl in (control, elastic):
        _publish(cl, more)
        cl.process()
    ok_merge = tables_bitwise_equal(control.live_embeddings(),
                                    elastic.live_embeddings())
    emit("resilience_reshard_merge", merge_us,
         f"bitwise_identical={int(ok_merge)};moved={m['moved']};"
         f"records={m['records']};ring_rows={m['ring_rows']};"
         f"continued_stream=1")
    assert ok_merge, "merge / continued-stream parity violated"


def _skewed_requests(g, *, n, rate, seed=5):
    gen = LoadGenerator(
        LoadConfig(rate_hz=rate, num_requests=n, candidates=4, seed=seed,
                   zipf=1.3, burst_at_s=0.2 * n / rate, burst_factor=4.0,
                   burst_duration_s=0.4 * n / rate),
        num_members=g.num_nodes["member"], num_jobs=g.num_nodes["job"])
    return gen.requests()


def _service_model(batch):
    # deterministic cost model: a fresh request pays an encoder pass,
    # a degraded one only a record read (~40x cheaper)
    fresh = sum(0.0 if r.degraded else 1.0 for r in batch)
    return 2e-3 * fresh + 5e-5 * (len(batch) - fresh) + 2e-4


def bench_resilience_overload():
    """Graceful-degradation curves on a deterministic service-time model:
    shed rate rises monotonically with offered load on the bounded-shed
    arm; the degrade arm keeps p99 under the no-control baseline's by
    converting overflow to staleness-served requests."""
    g, _ = standard_graph(0)
    cfg = _cfg(g)
    params = _params(cfg)
    cl = _cluster(g, cfg, params, 2)
    cl.publish_version()       # every node has a record -> stale serving
    base_rate, n = 400.0, 192

    shed_rates = []
    for mult in (1, 2, 4):
        reqs = _skewed_requests(g, n=n, rate=base_rate * mult)
        pol = BatchPolicy(max_batch=8, max_wait_s=0.01, max_queue=16,
                          overload="shed")
        rep, _, _ = serve_trace(cl, reqs, policy=pol, slo_ms=50.0,
                                service_s=_service_model)
        s = rep.summary()
        rate = s["shed"] / max(s["shed"] + s["completed"], 1)
        shed_rates.append(rate)
        emit(f"resilience_overload_x{mult}", 0.0,
             f"offered_rps={base_rate * mult:.0f};shed_rate={rate:.3f};"
             f"shed_queue_full={s['shed_queue_full']};"
             f"shed_deadline={s['shed_deadline']};"
             f"p99_ms={s['latency_p99_ms']:.1f};"
             f"completed={s['completed']}")
    assert all(a <= b for a, b in zip(shed_rates, shed_rates[1:])), \
        f"shed rate not monotone in offered load: {shed_rates}"

    # top load: no-control baseline vs degrade-to-cached
    reqs = _skewed_requests(g, n=n, rate=base_rate * 4)
    base_pol = BatchPolicy(max_batch=8, max_wait_s=0.01, max_queue=10**9)
    base, _, _ = serve_trace(cl, reqs, policy=base_pol, slo_ms=50.0,
                             service_s=_service_model)
    deg_pol = BatchPolicy(max_batch=8, max_wait_s=0.01, max_queue=16,
                          overload="degrade")
    deg, _, router = serve_trace(cl, reqs, policy=deg_pol, slo_ms=50.0,
                                 service_s=_service_model)
    ds = deg.summary()
    ok = (deg.latency_p99_ms < base.latency_p99_ms and ds["degraded"] > 0
          and ds["shed"] == 0)
    emit("resilience_overload_degrade", 0.0,
         f"p99_bounded={int(ok)};p99_ms={deg.latency_p99_ms:.1f};"
         f"baseline_p99_ms={base.latency_p99_ms:.1f};"
         f"degraded_frac={ds['degraded_frac']:.3f};"
         f"stale_served_keys={router.stale_served_keys};"
         f"shed={ds['shed']}")
    assert ok, (deg.latency_p99_ms, base.latency_p99_ms, ds)


ALL_RESILIENCE = [
    bench_resilience_restart_parity,
    bench_resilience_reshard,
    bench_resilience_overload,
]
