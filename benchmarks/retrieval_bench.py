"""Quantized ANN retrieval-tier benchmark (§7.4 EBR at scale, DESIGN.md §14).

The claim (ROADMAP item 4): at 1M+ jobs the int8+IVF tier delivers >=10x
the QPS of the fp32 brute-force scan at <=2pt recall@10 loss, while the
EXACT-search config stays bit-identical in returned ids to the oracle.

Corpus: a clustered synthetic job space — unit-norm points around ~N/1000
cluster centers — because IVF's win is exactly the clusteredness real
embedding tables have (random gaussians are the adversarial no-structure
case; tests cover that regime).  Queries are perturbed corpus points, the
EBR situation (member vectors land near the job manifold).

Arms per corpus size, all emitting ``qps=...;recall_at_10=...``:

  retrieval_oracle_<n>       — fp32 brute-force scan (recall 1 by definition)
  retrieval_exact_<n>        — the exact ANN config; asserts ids bitwise ==
                               oracle (the parity gate)
  retrieval_int8_<n>         — dense int8 scan, no IVF: isolates pure
                               quantization recall loss
  retrieval_ivf_<n>_p<probe> — the production arm: int8 + IVF + fp32
                               refine of the top 4k candidates, nprobe
                               sweep (recall = candidate coverage)
  retrieval_acceptance       — best arm meeting recall >= 0.98 at the
                               largest corpus; asserts speedup >= 10x
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.retrieval import RetrievalIndex, brute_force_topk

CORPUS_SIZES = (200_000, 1_000_000)
NPROBES = (4, 16, 64)
DIM = 32
NUM_QUERIES = 256
K = 10


def _clustered_corpus(n: int, d: int = DIM, seed: int = 0):
    """Unit-norm points around n/1000 cluster centers + query set."""
    rng = np.random.default_rng((seed, 0xA21, n))
    c = max(n // 1000, 8)
    centers = rng.normal(size=(c, d)).astype(np.float32)
    assign = rng.integers(0, c, n)
    x = centers[assign] + 0.15 * rng.normal(size=(n, d)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    picks = rng.integers(0, n, NUM_QUERIES)
    q = x[picks] + 0.05 * rng.normal(size=(NUM_QUERIES, d)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    return x.astype(np.float32), q.astype(np.float32)


def _qps(fn, nq: int, repeats: int = 2) -> float:
    fn()                                   # warmup (BLAS threads, memo fills)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return nq / best


def _recall_vs_oracle(ids: np.ndarray, oracle_ids: np.ndarray) -> float:
    """Mean top-k overlap fraction with the oracle's top-k."""
    return float(np.mean([len(set(a.tolist()) & set(b.tolist())) / len(b)
                          for a, b in zip(ids, oracle_ids)]))


def bench_retrieval_tier():
    accept = None
    for n in CORPUS_SIZES:
        x, q = _clustered_corpus(n)
        index = RetrievalIndex.build(x, scheme="per_row", num_lists=0, seed=0)

        oracle_ids, _ = brute_force_topk(q, x, K)
        oracle_qps = _qps(lambda: brute_force_topk(q, x, K), len(q))
        emit(f"retrieval_oracle_{n}", 1e6 * len(q) / oracle_qps / len(q),
             f"qps={oracle_qps:.1f};recall_at_10=1.0000;corpus={n}")

        # parity gate: the exact-search config must return the oracle's ids
        exact_ids, _ = index.search(q, K, quantized=False)
        assert np.array_equal(exact_ids, oracle_ids), "exact != oracle"
        emit(f"retrieval_exact_{n}", 0.0,
             f"qps={oracle_qps:.1f};recall_at_10=1.0000;bitwise_oracle=1")

        int8_ids, _ = index.search(q, K)
        int8_qps = _qps(lambda: index.search(q, K), len(q))
        emit(f"retrieval_int8_{n}", 1e6 / int8_qps,
             f"qps={int8_qps:.1f};"
             f"recall_at_10={_recall_vs_oracle(int8_ids, oracle_ids):.4f};"
             f"quant_only=1")

        for nprobe in NPROBES:
            ids, _ = index.search(q, K, nprobe=nprobe, refine=4)
            qps = _qps(lambda: index.search(q, K, nprobe=nprobe, refine=4),
                       len(q))
            rec = _recall_vs_oracle(ids, oracle_ids)
            emit(f"retrieval_ivf_{n}_p{nprobe}", 1e6 / qps,
                 f"qps={qps:.1f};recall_at_10={rec:.4f};"
                 f"nprobe={nprobe};lists={index.num_lists};refine=4;"
                 f"speedup={qps / oracle_qps:.1f}")
            if n == max(CORPUS_SIZES) and rec >= 0.98:
                cand = (qps / oracle_qps, nprobe, rec)
                if accept is None or cand > accept:
                    accept = cand

    assert accept is not None, "no IVF arm reached recall@10 >= 0.98 at 1M"
    speedup, nprobe, rec = accept
    emit("retrieval_acceptance", 0.0,
         f"speedup={speedup:.1f};recall_at_10={rec:.4f};nprobe={nprobe};"
         f"corpus={max(CORPUS_SIZES)};pass={int(speedup >= 10.0)}")
    assert speedup >= 10.0, f"only {speedup:.1f}x at recall {rec:.4f}"


ALL_RETRIEVAL = [bench_retrieval_tier]
