"""Kernel micro-benchmarks + roofline table readout.

Kernel timings on CPU use the XLA ``ref`` path (the interpret-mode Pallas
path is a Python-level simulator — correctness tool, not a perf proxy).
The per-kernel derived field reports achieved elements/s; real-TPU numbers
come from the dry-run roofline (bench_roofline below reads those JSONs).
"""
from __future__ import annotations

import glob
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels import ops

RNG = np.random.default_rng(0)


def bench_neighbor_mean():
    feats = jnp.asarray(RNG.normal(size=(4096, 10, 128)).astype(np.float32))
    mask = jnp.asarray((RNG.random((4096, 10)) < 0.8).astype(np.float32))
    fn = jax.jit(lambda f, m: ops.neighbor_mean(f, m, impl="ref"))
    out, us = timed(lambda: jax.block_until_ready(fn(feats, mask)))
    emit("kernel_neighbor_mean_4096x10x128", us,
         f"gb_per_s={feats.nbytes / (us / 1e6) / 1e9:.2f}")


def bench_sage_attention():
    q = jnp.asarray(RNG.normal(size=(4096, 128)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(4096, 10, 128)).astype(np.float32))
    mask = jnp.asarray((RNG.random((4096, 10)) < 0.8).astype(np.float32))
    fn = jax.jit(lambda q_, k_, m: ops.neighbor_attention(q_, k_, k_, m, impl="ref"))
    out, us = timed(lambda: jax.block_until_ready(fn(q, k, mask)))
    emit("kernel_sage_attention_4096x10x128", us,
         f"gb_per_s={k.nbytes * 2 / (us / 1e6) / 1e9:.2f}")


def bench_sage_layer():
    n, f, d = 4096, 10, 128
    h_self = jnp.asarray(RNG.normal(size=(n, d)).astype(np.float32))
    h_neigh = jnp.asarray(RNG.normal(size=(n, f, d)).astype(np.float32))
    mask = jnp.asarray((RNG.random((n, f)) < 0.8).astype(np.float32))
    w = jnp.asarray((RNG.normal(size=(d, d)) * 0.1).astype(np.float32))
    b = jnp.zeros((d,), jnp.float32)
    fn = jax.jit(lambda hs, hn, m: ops.sage_layer(hs, hn, m, w, b, w, b,
                                                  impl="ref"))
    out, us = timed(lambda: jax.block_until_ready(fn(h_self, h_neigh, mask)))
    flops = 2 * 2 * n * d * d + n * f * d          # dual matmul + masked mean
    emit("kernel_sage_layer_4096x10x128", us,
         f"gflops_per_s={flops / (us / 1e6) / 1e9:.1f}")


def bench_flash_attention_ref():
    b, hq, hkv, s, dh = 1, 8, 2, 2048, 64
    q = jnp.asarray(RNG.normal(size=(b, hq, s, dh)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, dh)).astype(np.float32))
    fn = jax.jit(lambda q_, k_: ops.mha(q_, k_, k_, causal=True, impl="ref"))
    out, us = timed(lambda: jax.block_until_ready(fn(q, k)))
    flops = 4 * b * hq * s * s * dh
    emit("kernel_flash_attention_2k_ref", us,
         f"gflops_per_s={flops / (us / 1e6) / 1e9:.1f}")


def bench_ssd_scan_ref():
    b, L, H, P, N = 2, 2048, 8, 64, 128
    x = jnp.asarray(RNG.normal(size=(b, L, H, P)).astype(np.float32))
    dt = jnp.asarray((RNG.random((b, L, H)) * 0.1).astype(np.float32))
    A = jnp.asarray(-RNG.random(H).astype(np.float32))
    B = jnp.asarray(RNG.normal(size=(b, L, N)).astype(np.float32))
    C = jnp.asarray(RNG.normal(size=(b, L, N)).astype(np.float32))
    fn = jax.jit(lambda *a: ops.ssd(*a, chunk=128, impl="ref")[0])
    out, us = timed(lambda: jax.block_until_ready(fn(x, dt, A, B, C)))
    emit("kernel_ssd_scan_2k_ref", us,
         f"tokens_per_s={b * L / (us / 1e6):.0f}")


def bench_roofline():
    """Read the dry-run artifacts and print the roofline rows (one per
    compiled arch × shape baseline on the single-pod mesh)."""
    base = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
    rows = 0
    for path in sorted(glob.glob(os.path.join(base, "*__16x16.json"))):
        with open(path) as f:
            d = json.load(f)
        if d.get("status") != "compiled" or "t_compute_s" not in d:
            continue
        rows += 1
        emit(f"roofline_{d['arch']}_{d['shape']}",
             d.get("compile_seconds", 0) * 1e6,
             f"t_compute_ms={d['t_compute_s'] * 1e3:.2f};"
             f"t_memory_ms={d['t_memory_s'] * 1e3:.2f};"
             f"t_collective_ms={d['t_collective_s'] * 1e3:.2f};"
             f"dominant={d['dominant']};useful={d['useful_flops_ratio']:.2f}")
    if rows == 0:
        emit("roofline_table", 0.0, "no_dryrun_artifacts_yet_run_repro.launch.dryrun")


ALL_KERNELS = [
    bench_neighbor_mean,
    bench_sage_attention,
    bench_sage_layer,
    bench_flash_attention_ref,
    bench_ssd_scan_ref,
    bench_roofline,
]
