# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter on benchmark name")
    ap.add_argument("--quick", action="store_true",
                    help="graph census + engine + kernel + nearline + "
                         "train-pipeline + embedding-lifecycle/transfer + "
                         "serving benchmarks only (skips the slow "
                         "GNN-training tables; CI mode)")
    ap.add_argument("--skip-slow", action="store_true",
                    help="deprecated alias of --quick")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the emitted rows as JSON to PATH")
    args = ap.parse_args()

    from benchmarks.cache_bench import ALL_CACHE
    from benchmarks.engine_bench import ALL_ENGINE
    from benchmarks.kernels_bench import ALL_KERNELS
    from benchmarks.nearline_bench import ALL_NEARLINE
    from benchmarks.resilience_bench import ALL_RESILIENCE
    from benchmarks.serving_bench import ALL_SERVING
    from benchmarks.tables import ALL_TABLES
    from benchmarks.train_bench import ALL_TRAIN
    from benchmarks.transfer_bench import ALL_TRANSFER

    benches = (list(ALL_TABLES) + list(ALL_ENGINE) + list(ALL_KERNELS)
               + list(ALL_CACHE) + list(ALL_NEARLINE) + list(ALL_TRAIN)
               + list(ALL_TRANSFER) + list(ALL_SERVING) + list(ALL_RESILIENCE))
    if args.skip_slow or args.quick:
        benches = [b for b in benches if b.__name__ == "bench_graph_construction"]
        benches += (list(ALL_ENGINE) + list(ALL_KERNELS) + list(ALL_CACHE)
                    + list(ALL_NEARLINE) + list(ALL_TRAIN) + list(ALL_TRANSFER)
                    + list(ALL_SERVING) + list(ALL_RESILIENCE))
    if args.only:
        benches = [b for b in benches if args.only in b.__name__]

    print("name,us_per_call,derived")
    failures = 0
    for bench in dict.fromkeys(benches):
        try:
            bench()
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{bench.__name__},nan,FAILED")
    if args.json:
        from benchmarks.common import ROWS
        with open(args.json, "w") as f:
            json.dump([{"name": n, "us_per_call": us, "derived": d}
                       for (n, us, d) in ROWS], f, indent=2)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
