# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter on benchmark name")
    ap.add_argument("--skip-slow", action="store_true",
                    help="skip the GNN-training benchmarks (tables 3-10)")
    args = ap.parse_args()

    from benchmarks.kernels_bench import ALL_KERNELS
    from benchmarks.tables import ALL_TABLES

    benches = list(ALL_TABLES) + list(ALL_KERNELS)
    if args.skip_slow:
        benches = [b for b in benches if b.__name__ == "bench_graph_construction"]
        benches += list(ALL_KERNELS)
    if args.only:
        benches = [b for b in benches if args.only in b.__name__]

    print("name,us_per_call,derived")
    failures = 0
    for bench in dict.fromkeys(benches):
        try:
            bench()
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{bench.__name__},nan,FAILED")
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
