# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import argparse
import json
import os
import sys
import traceback


def _parse_derived(derived: str) -> dict:
    """``k1=v1;k2=v2`` -> {k: float} (non-numeric values dropped)."""
    out = {}
    for kv in derived.split(";"):
        k, sep, v = kv.partition("=")
        if sep:
            try:
                out[k] = float(v)
            except ValueError:
                pass
    return out


def _serving_regression_line(baseline_rows, rows, path: str) -> str:
    """One-line diff vs the previous JSON artifact: events/s and fit-time
    deltas for serving rows, QPS (relative) and recall@10 (absolute
    points) deltas for retrieval rows."""
    base = {r["name"]: _parse_derived(r["derived"]) for r in baseline_rows}
    parts = []
    for name, _us, derived in rows:
        if (not name.startswith(("serving_", "retrieval_",
                                 "transfer_retrieval", "obs_"))
                or name not in base):
            continue
        cur, old = _parse_derived(derived), base[name]
        for key, fmt in (("events_per_s", "{:+.1%} ev/s"),
                         ("fit_s", "{:+.1%} fit-s"),
                         ("partition_fit_10m_edges_s", "{:+.1%} fit-s"),
                         ("qps", "{:+.1%} qps")):
            if key in cur and old.get(key):
                parts.append(f"{name} {fmt.format(cur[key] / old[key] - 1)}")
        if "recall_at_10" in cur and "recall_at_10" in old:
            d = cur["recall_at_10"] - old["recall_at_10"]
            if d:
                parts.append(f"{name} {d:+.4f} recall@10")
        # §15 gate row: absolute delta (the value itself is ~0.1%, so a
        # relative diff would be noise); tolerant of missing baseline keys
        # on the first post-merge run (``name not in base`` already skips
        # rows with no baseline at all)
        if "disabled_overhead_frac" in cur and "disabled_overhead_frac" in old:
            d = cur["disabled_overhead_frac"] - old["disabled_overhead_frac"]
            parts.append(f"{name} {d:+.4%} obs-overhead")
    if not parts:
        return f"serving diff vs {path}: no comparable rows"
    return f"serving diff vs {path}: " + ", ".join(parts)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter on benchmark name")
    ap.add_argument("--quick", action="store_true",
                    help="graph census + engine + kernel + nearline + "
                         "train-pipeline + embedding-lifecycle/transfer + "
                         "serving benchmarks only (skips the slow "
                         "GNN-training tables; CI mode).  With --json, also "
                         "prints a one-line serving regression diff vs the "
                         "previous artifact at that path")
    ap.add_argument("--skip-slow", action="store_true",
                    help="deprecated alias of --quick")
    ap.add_argument("--mesh", action="store_true",
                    help="the §13 device-parallel suite ONLY: shard_map "
                         "fan-out speedup (needs XLA_FLAGS="
                         "--xla_force_host_platform_device_count=4 on CPU) "
                         "and the 10M-edge partition-fit scale row")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the emitted rows as JSON to PATH "
                         "(an existing file there is read first as the "
                         "regression baseline)")
    args = ap.parse_args()

    # read the previous artifact BEFORE the run overwrites it
    baseline = None
    if args.json and os.path.exists(args.json):
        try:
            with open(args.json) as f:
                baseline = json.load(f)
        except (OSError, ValueError):
            baseline = None

    from benchmarks.cache_bench import ALL_CACHE
    from benchmarks.engine_bench import ALL_ENGINE
    from benchmarks.kernels_bench import ALL_KERNELS
    from benchmarks.nearline_bench import ALL_NEARLINE
    from benchmarks.obs_bench import ALL_OBS
    from benchmarks.resilience_bench import ALL_RESILIENCE
    from benchmarks.retrieval_bench import ALL_RETRIEVAL
    from benchmarks.serving_bench import ALL_SERVING, ALL_SERVING_MESH
    from benchmarks.tables import ALL_TABLES
    from benchmarks.train_bench import ALL_TRAIN
    from benchmarks.transfer_bench import ALL_TRANSFER

    benches = (list(ALL_TABLES) + list(ALL_ENGINE) + list(ALL_KERNELS)
               + list(ALL_CACHE) + list(ALL_NEARLINE) + list(ALL_TRAIN)
               + list(ALL_TRANSFER) + list(ALL_RETRIEVAL) + list(ALL_SERVING)
               + list(ALL_RESILIENCE) + list(ALL_OBS))
    if args.skip_slow or args.quick:
        benches = [b for b in benches if b.__name__ == "bench_graph_construction"]
        benches += (list(ALL_ENGINE) + list(ALL_KERNELS) + list(ALL_CACHE)
                    + list(ALL_NEARLINE) + list(ALL_TRAIN) + list(ALL_TRANSFER)
                    + list(ALL_RETRIEVAL) + list(ALL_SERVING)
                    + list(ALL_RESILIENCE) + list(ALL_OBS))
    if args.mesh:
        benches = list(ALL_SERVING_MESH)
    if args.only:
        benches = [b for b in benches if args.only in b.__name__]

    print("name,us_per_call,derived")
    failures = 0
    for bench in dict.fromkeys(benches):
        try:
            bench()
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{bench.__name__},nan,FAILED")
    from benchmarks.common import ROWS
    if args.json:
        with open(args.json, "w") as f:
            json.dump([{"name": n, "us_per_call": us, "derived": d}
                       for (n, us, d) in ROWS], f, indent=2)
    if (args.quick or args.mesh) and baseline is not None:
        print(_serving_regression_line(baseline, ROWS, args.json))
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
